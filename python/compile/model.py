"""Layer-2 JAX model: the paper's CIFAR-10 training CNNs in 16-bit fixed
point, composed from the Layer-1 Pallas kernels.

Network family (§IV-A): '1X' is 16C3-16C3-P-32C3-32C3-P-64C3-64C3-P-FC;
2X/4X scale every feature-map count by 2x/4x.  All convolutions are 3x3,
stride 1, pad 1, ReLU; pooling is 2x2 max with stored indices; the single FC
layer maps the flattened 4x4 maps to 10 classes.

Everything here runs ONCE at build time: `aot.py` lowers each layer-op (and
a fused per-image train step) to HLO text artifacts which the rust
coordinator loads via PJRT.  Images are processed one at a time, exactly
like the accelerator (batch processing is sequential, §IV-B); gradient
accumulation over a batch and the SGD-momentum weight update live in the
rust weight-update unit.
"""

import numpy as np
import jax.numpy as jnp

from . import fixedpoint as fx
from .kernels import (
    conv_bp, conv_fp, conv_wu, fc_bp, fc_fp, fc_wu, maxpool, scale_mask,
    upsample_scale,
)
from .kernels.ref import loss_grad_euclid_ref, loss_grad_hinge_ref

# Paper Table II unroll factors: Pox = Poy = 8; Pof = 16/32/64 for 1X/2X/4X.
NETS = {
    "1x": {"widths": [16, 16, 32, 32, 64, 64], "pof": 16},
    "2x": {"widths": [32, 32, 64, 64, 128, 128], "pof": 32},
    "4x": {"widths": [64, 64, 128, 128, 256, 256], "pof": 64},
}
IMG = (3, 32, 32)
NCLASS = 10


def net_layers(scale="1x", img=IMG, nclass=NCLASS):
    """Expand a scale name into the concrete per-layer shape table.

    Returns a list of dicts mirroring what the rust RTL-compiler's network
    description holds: conv layers (cin, cout, h, w), pool layers, one fc.
    """
    widths = NETS[scale]["widths"]
    layers = []
    cin, h = img[0], img[1]
    for i, cout in enumerate(widths):
        layers.append({"kind": "conv", "name": f"c{i + 1}", "cin": cin,
                       "cout": cout, "h": h, "w": h, "k": 3})
        cin = cout
        if i % 2 == 1:  # pool after every second conv
            layers.append({"kind": "pool", "name": f"p{i // 2 + 1}",
                           "c": cout, "h": h, "w": h, "pool": 2})
            h //= 2
    layers.append({"kind": "fc", "name": "fc", "cin": cin * h * h,
                   "cout": nclass})
    return layers


def init_params(scale="1x", seed=1234):
    """He-style float init, quantized to the fixed grid.  Deterministic so
    the rust side can regenerate identical parameters (same algorithm is
    implemented in rust/src/nn/init.rs from the same seed)."""
    rng = np.random.default_rng(seed)
    params = {}
    for l in net_layers(scale):
        if l["kind"] == "conv":
            fan_in = l["cin"] * l["k"] * l["k"]
            w = rng.standard_normal((l["cout"], l["cin"], l["k"], l["k"]))
            w *= np.sqrt(2.0 / fan_in)
            params[f"w_{l['name']}"] = fx.quantize(w, fx.FW)
            params[f"b_{l['name']}"] = jnp.zeros((l["cout"],), jnp.int32)
        elif l["kind"] == "fc":
            w = rng.standard_normal((l["cout"], l["cin"]))
            w *= np.sqrt(2.0 / l["cin"])
            params[f"w_{l['name']}"] = fx.quantize(w, fx.FW)
            params[f"b_{l['name']}"] = jnp.zeros((l["cout"],), jnp.int32)
    return params


def forward(params, x, scale="1x", pof=None):
    """FP phase for one image. Returns (logits, cache) where cache holds
    what the accelerator stores on-chip/DRAM during FP: post-ReLU
    activations (-> binary activation-gradient masks) and pool indices."""
    pof = pof or NETS[scale]["pof"]
    cache = {"x": x}
    a = x
    for l in net_layers(scale):
        if l["kind"] == "conv":
            a = conv_fp(a, params[f"w_{l['name']}"], params[f"b_{l['name']}"],
                        pof=pof)
            cache[f"a_{l['name']}"] = a
        elif l["kind"] == "pool":
            a, idx = maxpool(a, k=l["pool"])
            cache[f"a_{l['name']}"] = a
            cache[f"idx_{l['name']}"] = idx
        else:
            flat = a.reshape(1, -1)
            cache["flat"] = flat
            a = fc_fp(flat, params["w_fc"], params["b_fc"])
    return a, cache


def backward(params, cache, g_out, scale="1x", pof=None):
    """BP + per-image WU phases. g_out: (1, 10) loss gradient at FG.
    Returns dict of per-image weight/bias gradients (dw at FWG, db at FG),
    which the rust weight-update unit accumulates over the batch."""
    pof = pof or NETS[scale]["pof"]
    grads = {}
    layers = net_layers(scale)
    dw_fc, db_fc = fc_wu(g_out, cache["flat"])
    grads["w_fc"], grads["b_fc"] = dw_fc, db_fc
    g_flat = fc_bp(g_out, params["w_fc"])

    # walk conv/pool layers in reverse
    rev = [l for l in layers if l["kind"] != "fc"][::-1]
    last_pool = rev[0]
    g = g_flat.reshape(last_pool["c"], last_pool["h"] // 2,
                       last_pool["w"] // 2)
    for i, l in enumerate(rev):
        if l["kind"] == "pool":
            prev_conv = rev[i + 1]
            mask = (cache[f"a_{prev_conv['name']}"] > 0).astype(jnp.int32)
            g = upsample_scale(g, cache[f"idx_{l['name']}"], mask,
                               k=l["pool"])
        else:
            below = rev[i + 1]["name"] if i + 1 < len(rev) else None
            x_in = cache["x"] if below is None else cache[f"a_{below}"]
            dw, db = conv_wu(x_in, g, pof=pof)
            grads[f"w_{l['name']}"], grads[f"b_{l['name']}"] = dw, db
            if below is not None:
                g = conv_bp(g, params[f"w_{l['name']}"], pof=pof)
                if rev[i + 1]["kind"] == "conv":
                    mask = (cache[f"a_{below}"] > 0).astype(jnp.int32)
                    g = scale_mask(g, mask)
    return grads


def loss_grad(a, y, kind="hinge"):
    """Loss unit (§III-B): square hinge (default) or euclidean."""
    if kind == "hinge":
        return loss_grad_hinge_ref(a, y)
    return loss_grad_euclid_ref(a, y)


def param_order(scale="1x"):
    """Canonical flat ordering of the parameter pytree, shared with rust."""
    names = []
    for l in net_layers(scale):
        if l["kind"] in ("conv", "fc"):
            names += [f"w_{l['name']}", f"b_{l['name']}"]
    return names


def fused_step(params_list, x, y, scale="1x", loss="hinge"):
    """One whole per-image FP+BP+WU pass as a single computation (used by
    the fused-artifact ablation and the e2e trainer's fast path).

    params_list follows param_order(); returns [loss, logits, *grads]."""
    order = param_order(scale)
    params = dict(zip(order, params_list))
    logits, cache = forward(params, x, scale)
    g, lval = loss_grad(logits, y, loss)
    grads = backward(params, cache, g, scale)
    return [lval.reshape(1), logits] + [grads[n] for n in order]
