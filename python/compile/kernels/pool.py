"""Pallas kernels for the pooling / upsampling / scaling units (§III-G).

Max-pool is a *key layer* (reads a fresh tile from DRAM); it emits both the
pooled activations and the flat window-argmax indices that the paper stores
in on-chip index buffers (2-bit for a 2x2 window).  Upsample+scale is the BP
counterpart: a demultiplexer keyed by the stored index routes the gradient
to the max position, then the result is scaled by the binary ReLU activation
gradient.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import sat16

PC = 16  # channel tile (per-grid-step feature maps)


def _pick_tile(n, pref):
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


def _maxpool_kernel(x_ref, o_ref, i_ref, *, k):
    pc, h, w = x_ref.shape
    x = x_ref[...]
    xr = x.reshape(pc, h // k, k, w // k, k)
    xr = jnp.transpose(xr, (0, 1, 3, 2, 4)).reshape(pc, h // k, w // k, k * k)
    o_ref[...] = jnp.max(xr, axis=-1)
    i_ref[...] = jnp.argmax(xr, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "pc"))
def maxpool(x, *, k=2, pc=PC):
    """k x k max pooling with indices. x: (C, H, W) int32."""
    c, h, w = x.shape
    pc = _pick_tile(c, pc)
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k),
        grid=(c // pc,),
        in_specs=[pl.BlockSpec((pc, h, w), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((pc, h // k, w // k), lambda i: (i, 0, 0)),
            pl.BlockSpec((pc, h // k, w // k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, h // k, w // k), jnp.int32),
            jax.ShapeDtypeStruct((c, h // k, w // k), jnp.int32),
        ],
        interpret=True,
    )(x)


def _upsample_scale_kernel(g_ref, i_ref, m_ref, o_ref, *, k):
    pc, ho, wo = g_ref.shape
    g = g_ref[...]
    idx = i_ref[...]
    onehot = (idx[..., None] == jnp.arange(k * k, dtype=jnp.int32)).astype(jnp.int32)
    up = g[..., None] * onehot
    up = up.reshape(pc, ho, wo, k, k)
    up = jnp.transpose(up, (0, 1, 3, 2, 4)).reshape(pc, ho * k, wo * k)
    o_ref[...] = sat16(up * m_ref[...])


@functools.partial(jax.jit, static_argnames=("k", "pc"))
def upsample_scale(g, idx, mask, *, k=2, pc=PC):
    """Upsample pooled gradients through stored indices, scale by the binary
    ReLU activation gradient. g/idx: (C, Ho, Wo), mask: (C, Ho*k, Wo*k)."""
    c, ho, wo = g.shape
    pc = _pick_tile(c, pc)
    return pl.pallas_call(
        functools.partial(_upsample_scale_kernel, k=k),
        grid=(c // pc,),
        in_specs=[
            pl.BlockSpec((pc, ho, wo), lambda i: (i, 0, 0)),
            pl.BlockSpec((pc, ho, wo), lambda i: (i, 0, 0)),
            pl.BlockSpec((pc, ho * k, wo * k), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((pc, ho * k, wo * k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, ho * k, wo * k), jnp.int32),
        interpret=True,
    )(g, idx, mask)


def _scale_mask_kernel(g_ref, m_ref, o_ref):
    o_ref[...] = sat16(g_ref[...] * m_ref[...])


@functools.partial(jax.jit, static_argnames=("pc",))
def scale_mask(g, mask, *, pc=PC):
    """Scaling unit at a ReLU node that has no pooling: g * relu'(a)."""
    c, h, w = g.shape
    pc = _pick_tile(c, pc)
    return pl.pallas_call(
        _scale_mask_kernel,
        grid=(c // pc,),
        in_specs=[
            pl.BlockSpec((pc, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((pc, h, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((pc, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), jnp.int32),
        interpret=True,
    )(g, mask)
