"""Pallas integer matmul — the MAC array serving the fully-connected layers.

The same physical array does FC forward (normal weights), FC backward
(transposed weight matrix, §II) and FC weight update (outer product of the
local-gradient vector and the activation vector); each mode is just a
different operand routing, like the table in Fig. 6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE, sat16


def _matmul_kernel(a_ref, b_ref, o_ref, *, shift, relu, saturate):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32)
    if shift > 0:
        acc = (acc + jnp.int32(1 << (shift - 1))) >> shift
    if saturate:
        acc = sat16(acc)
    if relu:
        acc = jnp.maximum(acc, 0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("shift", "relu", "saturate"))
def matmul_q(a, b, *, shift, relu=False, saturate=True):
    """Requantizing integer matmul: (M, K) @ (K, N) -> (M, N)."""
    m, k = a.shape
    _, n = b.shape
    return pl.pallas_call(
        functools.partial(_matmul_kernel, shift=shift, relu=relu,
                          saturate=saturate),
        in_specs=[pl.BlockSpec((m, k), lambda: (0, 0)),
                  pl.BlockSpec((k, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((m, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)


@jax.jit
def fc_fp(x, w, b):
    """FC forward: x (1, K) at FA, w (N, K) at FW, b (N,) at FA+FW."""
    out = matmul_q(x, w.T, shift=0, saturate=False)
    acc = out + b[None, :]
    half = jnp.int32(1 << (SHIFT_CONV_FP - 1))
    return sat16((acc + half) >> SHIFT_CONV_FP)


@jax.jit
def fc_bp(g, w):
    """FC backward with transposed weight matrix: g (1, N) -> (1, K)."""
    return matmul_q(g, w, shift=SHIFT_CONV_BP)


@jax.jit
def fc_wu(g, x):
    """FC weight gradients: outer(g, x) at FWG, bias grads at FG."""
    dw = matmul_q(g.T, x, shift=SHIFT_WU_STORE, saturate=False)
    db = jnp.sum(g, axis=0)
    return dw, db
