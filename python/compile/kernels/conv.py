"""Pallas convolution kernels — the MAC-array compute of the accelerator.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
``Pox x Poy x Pof`` systolic MAC array becomes the kernel grid/BlockSpec
tiling.  Each grid step produces one ``(Pof, Poy, Nox)`` output tile by a
``(Pof, Nif) @ (Nif, Poy*Nox)`` MXU-shaped integer contraction per kernel
tap — i.e. a weight-stationary tile, which is exactly how the MAC array in
Fig. 6 is fed (rows share inputs, columns share weights).

The BP convolution reuses the *same* kernel body with the transposable
weight access pattern (flip + if/of interchange) applied in index space, so
— like the paper's circulant transposable buffer (Fig. 5) — there is never a
second materialized copy of the weights in the artifact's live set beyond
the transient rearranged view XLA streams through.

All kernels use ``interpret=True``: the CPU PJRT backend cannot execute
Mosaic custom-calls; interpret mode lowers the kernel to plain HLO so the
rust runtime can compile and run it.  (On a real TPU the same BlockSpecs
express the HBM->VMEM schedule the paper implements with DMA tiles.)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE, sat16

# Default unroll factors — the paper's Table II configuration uses
# Pox = Poy = 8 and Pof in {16, 32, 64}.
POY = 8
POF = 16


def _conv_fp_kernel(x_ref, w_ref, b_ref, o_ref, *, nky, nkx, shift, relu, poy):
    """One (Pof, Poy, Nox) output tile.

    x_ref: full padded input (Nif, H+2p, W+2p) — spatial halos make
           overlapping BlockSpecs impossible, so rows are selected with
           pl.ds from the grid position (the data-router of Fig. 4).
    w_ref: (Pof, Nif, Nky, Nkx) weight block for this tile's output maps.
    b_ref: (Pof,) bias at accumulator fraction.
    o_ref: (Pof, Poy, Nox).
    """
    pof = o_ref.shape[0]
    nox = o_ref.shape[2]
    nif = x_ref.shape[0]
    row0 = pl.program_id(1) * poy
    acc = jnp.zeros((pof, poy * nox), jnp.int32)
    for ky in range(nky):
        for kx in range(nkx):
            xs = pl.load(
                x_ref,
                (slice(None), pl.ds(row0 + ky, poy), pl.ds(kx, nox)),
            ).reshape(nif, poy * nox)
            wk = w_ref[:, :, ky, kx]
            acc = acc + jnp.dot(wk, xs, preferred_element_type=jnp.int32)
    acc = acc + b_ref[...][:, None]
    if shift > 0:
        acc = (acc + jnp.int32(1 << (shift - 1))) >> shift
    out = sat16(acc)
    if relu:
        out = jnp.maximum(out, 0)
    o_ref[...] = out.reshape(pof, poy, nox)


def _pick_tile(n, pref):
    """Largest divisor of n that is <= pref (unroll factors must divide)."""
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(
    jax.jit,
    static_argnames=("pad", "relu", "shift", "pof", "poy"),
)
def conv_fp(x, w, b, *, pad=1, relu=True, shift=SHIFT_CONV_FP,
            pof=POF, poy=POY):
    """Tiled FP convolution (stride 1). See conv_fp_ref for semantics."""
    nof, nif, nky, nkx = w.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = xp.shape[1] - nky + 1
    ow = xp.shape[2] - nkx + 1
    pof = _pick_tile(nof, pof)
    poy = _pick_tile(oh, poy)
    grid = (nof // pof, oh // poy)
    return pl.pallas_call(
        functools.partial(_conv_fp_kernel, nky=nky, nkx=nkx, shift=shift,
                          relu=relu, poy=poy),
        grid=grid,
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i, j: (0, 0, 0)),
            pl.BlockSpec((pof, nif, nky, nkx), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((pof,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((pof, poy, ow), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nof, oh, ow), jnp.int32),
        interpret=True,
    )(xp, w, b)


def transpose_flip(w):
    """The transposable-buffer access pattern (Fig. 5) in index space:
    interchange if/of and rotate the taps by 180 degrees."""
    return jnp.flip(jnp.transpose(w, (1, 0, 2, 3)), axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("pad", "pof", "poy"))
def conv_bp(g, w, *, pad=1, pof=POF, poy=POY):
    """BP convolution (Eq. 3): same MAC-array kernel, transposed/flipped
    weight view, no ReLU, gradient requantization shift."""
    wt = transpose_flip(w)
    zb = jnp.zeros((wt.shape[0],), jnp.int32)
    return conv_fp(g, wt, zb, pad=pad, relu=False, shift=SHIFT_CONV_BP,
                   pof=pof, poy=poy)


def _conv_wu_kernel(x_ref, g_ref, dw_ref, *, nky, nkx, shift):
    """Weight-gradient tile: all (Pof x Nif) kernel-gradient planes of one
    output-channel block computed per grid step.

    This is the MAC load-balance formulation of Fig. 8: a WU convolution's
    output feature map is only Nky x Nkx, which would idle most of the MAC
    array; batching every (of, if) plane of the block into a single
    (Pof, Noy*Nox) @ (Noy*Nox, Nif) contraction keeps the array full.

    x_ref: full padded activations (Nif, H+2p, W+2p);
    g_ref: (Pof, Noy, Nox) local-gradient block; dw_ref: (Pof, Nif, Nky, Nkx).
    """
    pof, noy, nox = g_ref.shape
    nif = x_ref.shape[0]
    gb = g_ref[...].reshape(pof, noy * nox)
    for ky in range(nky):
        for kx in range(nkx):
            xs = pl.load(
                x_ref, (slice(None), pl.ds(ky, noy), pl.ds(kx, nox)),
            ).reshape(nif, noy * nox)
            acc = jnp.dot(gb, xs.T, preferred_element_type=jnp.int32)
            if shift > 0:
                acc = (acc + jnp.int32(1 << (shift - 1))) >> shift
            dw_ref[:, :, ky, kx] = acc


@functools.partial(jax.jit, static_argnames=("pad", "pof"))
def conv_wu(x, g, *, pad=1, pof=POF):
    """WU convolution (Eq. 4): returns (dw at FWG, db at FG)."""
    nky = nkx = 2 * pad + 1
    nif = x.shape[0]
    nof, noy, nox = g.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    pof = _pick_tile(nof, pof)
    dw = pl.pallas_call(
        functools.partial(_conv_wu_kernel, nky=nky, nkx=nkx,
                          shift=SHIFT_WU_STORE),
        grid=(nof // pof,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((pof, noy, nox), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((pof, nif, nky, nkx), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nof, nif, nky, nkx), jnp.int32),
        interpret=True,
    )(xp, g)
    db = jnp.sum(g.reshape(nof, -1), axis=1)
    return dw, db
