"""Pure-jnp correctness oracle for every Pallas kernel.

These are deliberately *untiled*, direct-from-the-equations implementations
of the paper's Eq. (1) (FP convolution), Eq. (3) (BP convolution with
180-degree-flipped kernels and if/of interchange), and Eq. (4) (WU
weight-gradient convolution), plus max-pool-with-indices and the
upsample+scale unit of §III-G.  The Pallas kernels (tiled like the paper's
Pox x Poy x Pof MAC array) are asserted against these in pytest.

Layouts: activations/gradients are (C, H, W); conv weights are
(Nof, Nif, Nky, Nkx); all int32 fixed-point (see fixedpoint.py).
"""

import jax.numpy as jnp

from ..fixedpoint import (
    FA, FG, FW, SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE,
    requant, sat16, shift_round,
)


def pad_hw(x, p):
    """Zero-pad the two trailing (H, W) dims by p on each side."""
    return jnp.pad(x, ((0, 0), (p, p), (p, p)))


def conv_fp_ref(x, w, b, *, pad=1, relu=True, shift=SHIFT_CONV_FP):
    """Eq. (1): out[of] = sum_if sum_ky,kx w[of,if,ky,kx] * x[if,y+ky,x+kx].

    x: (Nif, H, W) at FA;  w: (Nof, Nif, Nky, Nkx) at FW;
    b: (Nof,) at FA+FW (accumulator fraction).  Returns (Nof, H', W') at FA.
    """
    nof, nif, nky, nkx = w.shape
    xp = pad_hw(x, pad)
    oh = xp.shape[1] - nky + 1
    ow = xp.shape[2] - nkx + 1
    acc = jnp.zeros((nof, oh, ow), jnp.int32)
    for ky in range(nky):
        for kx in range(nkx):
            xs = xp[:, ky:ky + oh, kx:kx + ow].reshape(nif, -1)
            acc = acc + jnp.einsum(
                "oi,ip->op", w[:, :, ky, kx], xs,
                preferred_element_type=jnp.int32,
            ).reshape(nof, oh, ow)
    acc = acc + b[:, None, None]
    out = requant(acc, shift)
    if relu:
        out = jnp.maximum(out, 0)
    return out


def conv_bp_ref(g, w, *, pad=1):
    """Eq. (3) convolution part: local gradients of layer l from those of
    layer l+1, using 180-degree-rotated kernels with if/of interchanged.

    g: (Nof, H, W) at FG; w: (Nof, Nif, Nky, Nkx) at FW (the FP kernels).
    Returns (Nif, H', W') at FG.  (Activation-gradient scaling is a separate
    affiliated op — see scale_mask_ref.)
    """
    wt = jnp.flip(jnp.transpose(w, (1, 0, 2, 3)), axis=(2, 3))
    zero_b = jnp.zeros((wt.shape[0],), jnp.int32)
    return conv_fp_ref(g, wt, zero_b, pad=pad, relu=False, shift=SHIFT_CONV_BP)


def conv_wu_ref(x, g, *, pad=1):
    """Eq. (4): kernel gradients = conv of FP input activations with local
    gradients used as (large) kernels; one (of, if) plane per output kernel.

    x: (Nif, H, W) at FA; g: (Nof, H, W) at FG.
    Returns (dw, db): dw (Nof, Nif, Nky, Nkx) i32 accumulators requantized
    from FA+FG down to FWG; db (Nof,) = sum of g, kept at FG.
    Kernel spatial size is inferred as 2*pad + 1 (stride-1 same-conv case).
    """
    nky = nkx = 2 * pad + 1
    nif = x.shape[0]
    nof, oh, ow = g.shape
    xp = pad_hw(x, pad)
    gb = g.reshape(nof, -1)
    dw = jnp.zeros((nof, nif, nky, nkx), jnp.int32)
    for ky in range(nky):
        for kx in range(nkx):
            xs = xp[:, ky:ky + oh, kx:kx + ow].reshape(nif, -1)
            dw = dw.at[:, :, ky, kx].set(
                jnp.einsum("op,ip->oi", gb, xs,
                           preferred_element_type=jnp.int32))
    dw = shift_round(dw, SHIFT_WU_STORE)
    db = jnp.sum(gb, axis=1)
    return dw, db


def maxpool_ref(x, *, k=2):
    """k x k max pooling with flat window-argmax indices (paper §III-B:
    pooling window size determines the index bit-width; k=2 -> 2-bit).

    x: (C, H, W).  Returns (pooled (C, H/k, W/k), idx int32 in [0, k*k)).
    Window positions are ordered row-major: idx = dy * k + dx.
    """
    c, h, w = x.shape
    xr = x.reshape(c, h // k, k, w // k, k)
    xr = jnp.transpose(xr, (0, 1, 3, 2, 4)).reshape(c, h // k, w // k, k * k)
    return jnp.max(xr, axis=-1), jnp.argmax(xr, axis=-1).astype(jnp.int32)


def upsample_scale_ref(g, idx, mask, *, k=2):
    """§III-G: route the pooled-node gradient to the max pixel position
    (demultiplexer keyed by the stored index) and scale by the binary ReLU
    activation gradient.

    g: (C, Ho, Wo) at FG; idx: (C, Ho, Wo) int32 in [0, k*k);
    mask: (C, H, W) int32 in {0, 1}.  Returns (C, H, W) at FG.
    """
    c, ho, wo = g.shape
    onehot = (idx[..., None] == jnp.arange(k * k, dtype=jnp.int32)).astype(jnp.int32)
    up = g[..., None] * onehot                      # (C, Ho, Wo, k*k)
    up = up.reshape(c, ho, wo, k, k)
    up = jnp.transpose(up, (0, 1, 3, 2, 4)).reshape(c, ho * k, wo * k)
    return sat16(up * mask)


def scale_mask_ref(g, mask):
    """Scaling unit at a ReLU node without pooling: g * relu'(a)."""
    return sat16(g * mask)


def relu_mask_ref(a):
    """Binary activation gradient of ReLU (paper stores these during FP)."""
    return (a > 0).astype(jnp.int32)


def fc_fp_ref(x, w, b, *, relu=False, shift=SHIFT_CONV_FP):
    """Fully-connected forward: x (1, K) at FA, w (N, K) at FW, b (N,) at
    FA+FW. Returns (1, N) at FA."""
    acc = jnp.einsum("mk,nk->mn", x, w, preferred_element_type=jnp.int32)
    out = requant(acc + b[None, :], shift)
    if relu:
        out = jnp.maximum(out, 0)
    return out


def fc_bp_ref(g, w):
    """FC backward: transposed weight matrix (paper §II). g (1, N) at FG,
    w (N, K) at FW -> (1, K) at FG."""
    acc = jnp.einsum("mn,nk->mk", g, w, preferred_element_type=jnp.int32)
    return requant(acc, SHIFT_CONV_BP)


def fc_wu_ref(g, x):
    """FC weight update gradients: outer product of local-gradient vector
    and activation vector (paper §II). g (1, N) at FG, x (1, K) at FA.
    Returns (dw (N, K) at FWG, db (N,) at FG)."""
    acc = jnp.einsum("mn,mk->nk", g, x, preferred_element_type=jnp.int32)
    return shift_round(acc, SHIFT_WU_STORE), jnp.sum(g, axis=0)


def loss_grad_hinge_ref(a, y):
    """Squared hinge loss (paper's default loss unit) and its gradient.

    a: (1, N) logits at FA; y: (1, N) in {-1, +1} * 2^FA at FA.
    L = sum max(0, 1 - y*a)^2 ; dL/da = -2 y max(0, 1 - y*a).
    Returns (g at FG shape (1, N), loss i32 at 2*FA).
    """
    one = jnp.int32(1 << FA)
    ya = shift_round(a * y, FA)                     # frac FA
    margin = jnp.maximum(one - ya, 0)               # frac FA
    g_fa = sat16(-2 * shift_round(y * margin, FA))  # frac FA
    g = sat16(g_fa << (FG - FA))                    # frac FG
    # loss is logging-only; requantize each term to frac FA so the i32
    # sum cannot wrap (margin^2 is at 2*FA)
    loss = jnp.sum(shift_round(margin * margin, FA))  # frac FA
    return g, loss


def loss_grad_euclid_ref(a, y):
    """Euclidean (quadratic) loss, Eq. (2): dC/da = (a - y).

    a, y: (1, N) at FA.  Returns (g at FG, loss at 2*FA)."""
    d = sat16(a - y)                                # frac FA
    g = sat16(d << (FG - FA))                       # frac FG
    loss = jnp.sum(shift_round(d * d, FA)) >> 1     # (1/2) sum d^2, frac FA
    return g, loss
