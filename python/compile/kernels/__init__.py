"""Layer-1 Pallas kernels (tiled like the paper's MAC array) and the
pure-jnp oracle (ref) they are verified against."""

from .conv import conv_bp, conv_fp, conv_wu, transpose_flip
from .matmul import fc_bp, fc_fp, fc_wu, matmul_q
from .pool import maxpool, scale_mask, upsample_scale

__all__ = [
    "conv_fp", "conv_bp", "conv_wu", "transpose_flip",
    "maxpool", "upsample_scale", "scale_mask",
    "matmul_q", "fc_fp", "fc_bp", "fc_wu",
]
