"""Fixed-point (Q-format) arithmetic used across the whole stack.

The paper trains with 16-bit fixed-point weights/activations/gradients with
"dedicated resolution/range assignment for different variables" (§II).  We
pin the following Q formats (fraction bits), mirrored exactly by the rust
`fixed` crate module:

    activations      FA = 8    (range ±128,  resolution 1/256)
    weights          FW = 12   (range ±8,    resolution 1/4096)
    local gradients  FG = 12
    stored weight-gradient accumulators  FWG = 16 (i32, DRAM-resident)
    momentum buffer  FV = 16 (i32)

All tensors are carried as int32 (values saturated to the i16 range
[-32768, 32767]) so that HLO artifacts and the rust golden model perform
*identical* integer arithmetic: i32 wrap-around accumulation, round-half-up
requantization `(acc + (1 << (s-1))) >> s`, and saturation.
"""

import numpy as np
import jax.numpy as jnp

# Fraction bits per tensor kind (keep in sync with rust/src/fixed/mod.rs).
FA = 8    # activations
FW = 12   # weights / biases-as-weights
FG = 12   # local gradients
FWG = 16  # accumulated weight gradients (i32, not i16-saturated)
FV = 16   # momentum buffer (i32)

I16_MIN = -32768
I16_MAX = 32767

# Requantization shifts used by the layer ops.
SHIFT_CONV_FP = FW            # acc frac FA+FW -> FA
SHIFT_CONV_BP = FW            # acc frac FG+FW -> FG
SHIFT_WU_STORE = FA + FG - FWG  # acc frac FA+FG -> FWG (=4)


def sat16(x):
    """Saturate an int32 tensor into the i16 value range (still int32)."""
    return jnp.clip(x, I16_MIN, I16_MAX)


def requant(acc, shift):
    """Round-half-up arithmetic right shift, then saturate to i16 range.

    `acc` is an int32 accumulator at fraction `f_hi`; result is at fraction
    `f_hi - shift`.  shift == 0 is the identity (plus saturation).
    """
    if shift > 0:
        half = jnp.int32(1 << (shift - 1))
        acc = (acc + half) >> shift
    return sat16(acc)


def shift_round(acc, shift):
    """Round-half-up shift WITHOUT i16 saturation (i32 accumulators)."""
    if shift > 0:
        half = jnp.int32(1 << (shift - 1))
        acc = (acc + half) >> shift
    return acc


def quantize(x, frac):
    """Float -> fixed grid (int32, i16-saturated). Build-time/test helper.
    Rounds half away from zero (matches rust `Fx::quantize`)."""
    q = np.clip(np.round(np.asarray(x, np.float64) * (1 << frac)),
                I16_MIN, I16_MAX).astype(np.int32)
    return jnp.asarray(q)


def dequantize(q, frac):
    """Fixed -> float. Build-time/test helper."""
    return np.asarray(q, np.float64) / (1 << frac)
