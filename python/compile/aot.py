"""AOT lowering: JAX/Pallas layer-ops -> HLO text artifacts for the rust
coordinator (the only place python ever runs — once, at build time).

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 rust crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits, per network scale:
  artifacts/<op>.hlo.txt        one artifact per layer-op (the accelerator
                                executes layer-by-layer, so does rust)
  artifacts/fused_step_<s>.hlo.txt  whole per-image FP+BP+WU (ablation +
                                e2e fast path)
  artifacts/manifest.json       op signatures + network table + Q formats
  artifacts/params_<s>.bin      deterministic initial parameters
  artifacts/testvec_<s>.bin     one golden train-step input/output bundle
                                (rust integration tests replay it through
                                both PJRT and the rust golden model)

Binary tensor-bundle format (reader: rust/src/nn/tensorio.rs):
  magic b"FXTB", u32 n; then per tensor: u32 name_len, name (utf8),
  u32 ndim, u32 dims[ndim], i32 data[prod(dims)]  — all little-endian.
"""

import argparse
import json
import os
import struct
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import fixedpoint as fx
from . import model as M
from .kernels import (
    conv_bp, conv_fp, conv_wu, fc_bp, fc_fp, fc_wu, maxpool, scale_mask,
    upsample_scale,
)
from .kernels.ref import loss_grad_euclid_ref, loss_grad_hinge_ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def s32(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.int32)


def op_table(scale):
    """All per-layer ops for one network scale: name -> (fn, [input specs]).

    Every op returns a tuple; shapes mirror the accelerator's layer table.
    """
    pof = M.NETS[scale]["pof"]
    layers = M.net_layers(scale)
    ops = {}
    seq = [l for l in layers if l["kind"] != "fc"]
    for i, l in enumerate(seq):
        n = l["name"]
        if l["kind"] == "conv":
            cin, cout, h, w, k = l["cin"], l["cout"], l["h"], l["w"], l["k"]
            ops[f"conv_fp_{n}"] = (
                lambda x, wt, b, pof=pof: (conv_fp(x, wt, b, pof=pof),),
                [s32(cin, h, w), s32(cout, cin, k, k), s32(cout)],
            )
            ops[f"conv_wu_{n}"] = (
                lambda x, g, pof=pof: conv_wu(x, g, pof=pof),
                [s32(cin, h, w), s32(cout, h, w)],
            )
            if i > 0:  # c1 needs no input gradient
                ops[f"conv_bp_{n}"] = (
                    lambda g, wt, pof=pof: (conv_bp(g, wt, pof=pof),),
                    [s32(cout, h, w), s32(cout, cin, k, k)],
                )
            if i + 1 < len(seq) and seq[i + 1]["kind"] == "conv":
                # conv->conv boundary: BP scaling unit over this output
                ops[f"smask_{n}"] = (
                    lambda g, m: (scale_mask(g, m),),
                    [s32(cout, h, w), s32(cout, h, w)],
                )
        else:  # pool
            c, h, w, k = l["c"], l["h"], l["w"], l["pool"]
            ops[f"pool_{n}"] = (
                lambda x, k=k: tuple(maxpool(x, k=k)),
                [s32(c, h, w)],
            )
            ops[f"ups_{n}"] = (
                lambda g, idx, m, k=k: (upsample_scale(g, idx, m, k=k),),
                [s32(c, h // k, w // k), s32(c, h // k, w // k), s32(c, h, w)],
            )
    fc = layers[-1]
    kk, nn = fc["cin"], fc["cout"]
    ops["fc_fp"] = (lambda x, wt, b: (fc_fp(x, wt, b),),
                    [s32(1, kk), s32(nn, kk), s32(nn)])
    ops["fc_bp"] = (lambda g, wt: (fc_bp(g, wt),), [s32(1, nn), s32(nn, kk)])
    ops["fc_wu"] = (lambda g, x: tuple(fc_wu(g, x)), [s32(1, nn), s32(1, kk)])
    ops["loss_hinge"] = (
        lambda a, y: (lambda r: (r[0], r[1].reshape(1)))(
            loss_grad_hinge_ref(a, y)),
        [s32(1, nn), s32(1, nn)])
    ops["loss_euclid"] = (
        lambda a, y: (lambda r: (r[0], r[1].reshape(1)))(
            loss_grad_euclid_ref(a, y)),
        [s32(1, nn), s32(1, nn)])
    return ops


def write_bundle(path, tensors):
    """Write an ordered {name: np.int32 array} dict in FXTB format."""
    with open(path, "wb") as f:
        f.write(b"FXTB")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(np.asarray(arr, np.int32))
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.astype("<i4").tobytes())


def make_testvec(scale, seed=7):
    """One deterministic per-image train step: inputs + every output."""
    params = M.init_params(scale)
    rng = np.random.default_rng(seed)
    x = np.asarray(fx.quantize(rng.standard_normal(M.IMG) * 0.5, fx.FA))
    y_oh = (np.eye(M.NCLASS)[seed % M.NCLASS] * 2 - 1) * (1 << fx.FA)
    y = np.asarray(y_oh[None, :], np.int32)
    out = M.fused_step([params[n] for n in M.param_order(scale)],
                       jnp.asarray(x), jnp.asarray(y), scale)
    bundle = {"x": x, "y": y, "loss": np.asarray(out[0]),
              "logits": np.asarray(out[1])}
    for name, g in zip(M.param_order(scale), out[2:]):
        bundle[f"g_{name}"] = np.asarray(g)
    return bundle


def lower_op(name, fn, specs, out_dir, manifest):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *specs)
    manifest["ops"][name] = {
        "file": os.path.basename(path),
        "inputs": [list(s.shape) for s in specs],
        "outputs": [list(o.shape) for o in jax.tree_util.tree_leaves(outs)],
    }
    print(f"  {name}: {len(text)} chars, "
          f"{len(specs)} in -> {len(jax.tree_util.tree_leaves(outs))} out")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scales", default="1x",
                    help="comma list of network scales (1x,2x,4x)")
    ap.add_argument("--fused", action="store_true", default=True)
    ap.add_argument("--no-fused", dest="fused", action="store_false")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "qformat": {"fa": fx.FA, "fw": fx.FW, "fg": fx.FG,
                    "fwg": fx.FWG, "fv": fx.FV},
        "ops": {}, "nets": {},
    }
    for scale in args.scales.split(","):
        print(f"[aot] scale {scale}")
        layers = M.net_layers(scale)
        manifest["nets"][scale] = {
            "layers": layers,
            "pof": M.NETS[scale]["pof"],
            "param_order": M.param_order(scale),
            "params_file": f"params_{scale}.bin",
            "testvec_file": f"testvec_{scale}.bin",
        }
        for name, (fn, specs) in op_table(scale).items():
            # op names are shared across scales only when shapes match;
            # suffix with the scale to keep them distinct.
            lower_op(f"{name}_{scale}", fn, specs, args.out_dir, manifest)
        if args.fused:
            order = M.param_order(scale)
            params = M.init_params(scale)
            pspecs = [s32(*params[n].shape) for n in order]
            fused = lambda ps, x, y, s=scale: tuple(M.fused_step(ps, x, y, s))
            lowered = jax.jit(fused).lower(
                pspecs, s32(*M.IMG), s32(1, M.NCLASS))
            text = to_hlo_text(lowered)
            fpath = os.path.join(args.out_dir, f"fused_step_{scale}.hlo.txt")
            with open(fpath, "w") as f:
                f.write(text)
            manifest["ops"][f"fused_step_{scale}"] = {
                "file": os.path.basename(fpath),
                "inputs": [list(params[n].shape) for n in order]
                          + [list(M.IMG), [1, M.NCLASS]],
                "outputs": [[1], [1, M.NCLASS]]
                           + [list(params[n].shape) for n in order],
            }
            print(f"  fused_step_{scale}: {len(text)} chars")
        params = M.init_params(scale)
        write_bundle(os.path.join(args.out_dir, f"params_{scale}.bin"),
                     {n: params[n] for n in M.param_order(scale)})
        write_bundle(os.path.join(args.out_dir, f"testvec_{scale}.bin"),
                     make_testvec(scale))
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['ops'])} artifacts + manifest to "
          f"{args.out_dir}")


if __name__ == "__main__":
    main()
