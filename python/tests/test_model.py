"""Layer-2 model tests: network table, forward/backward shapes, loss units,
fused-step consistency, and a small does-it-learn sanity run."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import fixedpoint as fx
from compile import model as M
from compile.kernels import ref
from .helpers import randi


class TestNetLayers:
    def test_1x_structure(self):
        """16C3-16C3-P-32C3-32C3-P-64C3-64C3-P-FC (§IV-A)."""
        kinds = [l["kind"] for l in M.net_layers("1x")]
        assert kinds == ["conv", "conv", "pool", "conv", "conv", "pool",
                         "conv", "conv", "pool", "fc"]
        widths = [l["cout"] for l in M.net_layers("1x") if l["kind"] == "conv"]
        assert widths == [16, 16, 32, 32, 64, 64]

    @pytest.mark.parametrize("scale,mult", [("2x", 2), ("4x", 4)])
    def test_wider_nets_scale_feature_maps(self, scale, mult):
        w1 = [l["cout"] for l in M.net_layers("1x") if l["kind"] == "conv"]
        ws = [l["cout"] for l in M.net_layers(scale) if l["kind"] == "conv"]
        assert ws == [mult * w for w in w1]

    @pytest.mark.parametrize("scale,k", [("1x", 1024), ("2x", 2048),
                                         ("4x", 4096)])
    def test_fc_input_size(self, scale, k):
        assert M.net_layers(scale)[-1]["cin"] == k

    def test_spatial_dims_halve_at_pools(self):
        hs = [l["h"] for l in M.net_layers("1x") if l["kind"] == "conv"]
        assert hs == [32, 32, 16, 16, 8, 8]

    def test_param_order_covers_all_weights(self):
        order = M.param_order("1x")
        assert len(order) == 14  # 6 conv + 1 fc, w + b each
        assert order[0] == "w_c1" and order[-1] == "b_fc"


class TestInitParams:
    def test_deterministic(self):
        p1 = M.init_params("1x", seed=42)
        p2 = M.init_params("1x", seed=42)
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]),
                                          np.asarray(p2[k]))

    def test_weights_in_i16_range(self):
        for k, v in M.init_params("1x").items():
            a = np.asarray(v)
            assert a.dtype == np.int32
            assert a.min() >= -32768 and a.max() <= 32767

    def test_biases_zero(self):
        p = M.init_params("1x")
        for k in p:
            if k.startswith("b_"):
                assert not np.asarray(p[k]).any()


class TestForwardBackward:
    @pytest.fixture(scope="class")
    def setup(self):
        params = M.init_params("1x")
        rng = np.random.default_rng(0)
        x = fx.quantize(rng.standard_normal(M.IMG) * 0.5, fx.FA)
        y = jnp.asarray(((np.eye(10)[4] * 2 - 1) * (1 << fx.FA))[None, :],
                        jnp.int32)
        logits, cache = M.forward(params, x)
        g, loss = M.loss_grad(logits, y)
        grads = M.backward(params, cache, g)
        return params, x, y, logits, cache, g, loss, grads

    def test_logit_shape(self, setup):
        assert setup[3].shape == (1, 10)

    def test_cache_holds_pool_indices(self, setup):
        cache = setup[4]
        for p, shape in [("p1", (16, 16, 16)), ("p2", (32, 8, 8)),
                         ("p3", (64, 4, 4))]:
            assert cache[f"idx_{p}"].shape == shape

    def test_grad_shapes_match_params(self, setup):
        params, grads = setup[0], setup[7]
        for k in params:
            assert grads[k].shape == params[k].shape, k

    def test_fused_step_equals_stepwise(self, setup):
        params, x, y, logits, _, _, loss, grads = setup
        out = M.fused_step([params[n] for n in M.param_order()], x, y)
        assert int(out[0][0]) == int(loss)
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(logits))
        for n, g in zip(M.param_order(), out[2:]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(grads[n]),
                                          err_msg=n)

    def test_relu_masks_derivable_from_cache(self, setup):
        """The paper stores binary activation gradients during FP; ours are
        recomputed from the cached post-ReLU activations (a > 0)."""
        cache = setup[4]
        m = np.asarray(ref.relu_mask_ref(cache["a_c1"]))
        assert set(np.unique(m)).issubset({0, 1})


class TestLoss:
    def test_hinge_zero_at_perfect_prediction(self):
        y = jnp.asarray([[1, -1, -1]], jnp.int32) * (2 << fx.FA)
        a = jnp.asarray([[2, -2, -2]], jnp.int32) * (1 << fx.FA)
        g, loss = ref.loss_grad_hinge_ref(a, y // 2)
        # margins = 1 - y*a = 1 - 2 < 0 -> clamped to 0
        assert int(loss) == 0
        assert not np.asarray(g).any()

    def test_hinge_gradient_sign(self):
        """Under-confident correct class gets negative gradient (push up)."""
        one = 1 << fx.FA
        y = jnp.asarray([[one, -one]], jnp.int32)
        a = jnp.zeros((1, 2), jnp.int32)
        g, loss = ref.loss_grad_hinge_ref(a, y)
        assert int(loss) > 0
        assert int(g[0, 0]) < 0 and int(g[0, 1]) > 0

    def test_euclid_gradient_is_difference(self):
        a = jnp.asarray([[300, -200]], jnp.int32)
        y = jnp.asarray([[256, 0]], jnp.int32)
        g, loss = ref.loss_grad_euclid_ref(a, y)
        want = (np.asarray([[44, -200]]) * (1 << (fx.FG - fx.FA)))
        np.testing.assert_array_equal(np.asarray(g), want)
        # per-term requant to frac FA, then halved
        t1 = (44 * 44 + (1 << (fx.FA - 1))) >> fx.FA
        t2 = (200 * 200 + (1 << (fx.FA - 1))) >> fx.FA
        assert int(loss) == (t1 + t2) >> 1

    def test_loss_decreases_under_sgd(self):
        """Tiny does-it-learn check on one repeated example: plain SGD on
        the fixed-point gradients must reduce the hinge loss."""
        params = M.init_params("1x", seed=3)
        rng = np.random.default_rng(3)
        x = fx.quantize(rng.standard_normal(M.IMG) * 0.5, fx.FA)
        y = jnp.asarray(((np.eye(10)[2] * 2 - 1) * (1 << fx.FA))[None, :],
                        jnp.int32)
        order = M.param_order()

        def loss_of(p):
            logits, _ = M.forward(p, x)
            _, l = M.loss_grad(logits, y)
            return int(l)

        l0 = loss_of(params)
        for _ in range(3):
            logits, cache = M.forward(params, x)
            g, _ = M.loss_grad(logits, y)
            grads = M.backward(params, cache, g)
            for n in order:
                gq = np.asarray(grads[n], np.int64)
                if n.startswith("w_"):
                    # dw at FWG -> weight at FW: align fracs, lr = 2^-6
                    step = gq >> (fx.FWG - fx.FW + 6)
                else:
                    step = gq >> (fx.FG - fx.FW + 6)
                newp = np.clip(np.asarray(params[n], np.int64) - step,
                               -32768, 32767).astype(np.int32)
                params[n] = jnp.asarray(newp)
        assert loss_of(params) < l0
