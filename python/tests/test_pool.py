"""Max-pool / upsample+scale / scaling-unit kernels vs the oracle, plus the
gradient-routing invariants of §III-G (gradients only flow through the
selected max pixel; all other window pixels receive zero)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # degrade: only the property sweeps skip; every deterministic
    # test in this module still runs
    from .helpers import hyp_given as given, hyp_settings as \
        settings, hyp_st as st

from compile.kernels import maxpool, scale_mask, upsample_scale
from compile.kernels import ref
from .helpers import randi

POOL_SHAPES = [(16, 32), (32, 16), (64, 8), (128, 16), (256, 8)]


@pytest.mark.parametrize("c,hw", POOL_SHAPES)
def test_maxpool_matches_ref(rng, c, hw):
    x = randi(rng, (c, hw, hw))
    p, i = maxpool(x)
    pr, ir = ref.maxpool_ref(x)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_maxpool_selects_window_max(rng):
    x = randi(rng, (4, 8, 8))
    p, idx = maxpool(x)
    xn = np.asarray(x)
    pn, idxn = np.asarray(p), np.asarray(idx)
    for c in range(4):
        for y in range(4):
            for xx in range(4):
                win = xn[c, 2 * y:2 * y + 2, 2 * xx:2 * xx + 2]
                assert pn[c, y, xx] == win.max()
                dy, dx = divmod(idxn[c, y, xx], 2)
                assert win[dy, dx] == win.max()


def test_maxpool_indices_2bit(rng):
    """Paper: a 2x2 window needs 2-bit indices — values in [0, 4)."""
    x = randi(rng, (16, 16, 16))
    _, idx = maxpool(x)
    assert np.asarray(idx).min() >= 0
    assert np.asarray(idx).max() < 4


def test_maxpool_4x4_window(rng):
    x = randi(rng, (4, 16, 16))
    p, i = maxpool(x, k=4)
    pr, ir = ref.maxpool_ref(x, k=4)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    assert np.asarray(i).max() < 16


@pytest.mark.parametrize("c,hw", POOL_SHAPES[:3])
def test_upsample_scale_matches_ref(rng, c, hw):
    x = randi(rng, (c, hw, hw))
    _, idx = maxpool(x)
    g = randi(rng, (c, hw // 2, hw // 2))
    mask = (x > 0).astype(jnp.int32)
    got = upsample_scale(g, idx, mask)
    want = ref.upsample_scale_ref(g, idx, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_upsample_routes_only_to_max_position(rng):
    """The demultiplexer property: exactly one pixel per window carries the
    gradient (before masking)."""
    x = randi(rng, (2, 4, 4))
    _, idx = maxpool(x)
    g = randi(rng, (2, 2, 2), 1, 100)       # strictly positive gradients
    ones = jnp.ones((2, 4, 4), jnp.int32)   # no relu masking
    up = np.asarray(upsample_scale(g, idx, ones))
    for c in range(2):
        for y in range(2):
            for xx in range(2):
                win = up[c, 2 * y:2 * y + 2, 2 * xx:2 * xx + 2]
                assert (win != 0).sum() == 1
                assert win.sum() == int(np.asarray(g)[c, y, xx])


def test_upsample_zero_mask_kills_gradient(rng):
    x = randi(rng, (4, 8, 8))
    _, idx = maxpool(x)
    g = randi(rng, (4, 4, 4))
    zero = jnp.zeros((4, 8, 8), jnp.int32)
    assert not np.asarray(upsample_scale(g, idx, zero)).any()


@pytest.mark.parametrize("c,hw", [(16, 32), (32, 16), (64, 8)])
def test_scale_mask_matches_ref(rng, c, hw):
    g = randi(rng, (c, hw, hw))
    mask = (randi(rng, (c, hw, hw)) > 0).astype(jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(scale_mask(g, mask)),
        np.asarray(ref.scale_mask_ref(g, mask)))


def test_relu_mask_is_binary_step(rng):
    a = randi(rng, (8, 8, 8))
    m = np.asarray(ref.relu_mask_ref(a))
    an = np.asarray(a)
    np.testing.assert_array_equal(m, (an > 0).astype(np.int32))
    assert set(np.unique(m)).issubset({0, 1})


@given(c=st.sampled_from([1, 2, 4, 8, 16]), hw=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_pool_roundtrip_hypothesis(c, hw, seed):
    """maxpool(upsampled max-routed values) reproduces the pooled plane."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.integers(1, 1000, (c, hw, hw)), jnp.int32)
    p, idx = maxpool(x)
    ones = jnp.ones((c, hw, hw), jnp.int32)
    up = upsample_scale(p, idx, ones)
    p2, _ = maxpool(up)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))
