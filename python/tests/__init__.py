"""Test package marker: lets pytest import these modules as
``tests.test_*`` so the relative ``from .helpers import randi`` imports
resolve regardless of rootdir (conftest.py puts ``python/`` on sys.path
for the ``compile`` package itself)."""
