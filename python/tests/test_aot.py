"""AOT pipeline tests: op-table signatures, HLO-text emission, bundle
format round-trip, manifest consistency.  A single representative op is
lowered end-to-end (full artifact builds happen in `make artifacts`)."""

import json
import os
import struct
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


class TestOpTable:
    @pytest.fixture(scope="class")
    def ops(self):
        return aot.op_table("1x")

    def test_expected_op_set(self, ops):
        names = set(ops)
        for i in range(1, 7):
            assert f"conv_fp_c{i}" in names
            assert f"conv_wu_c{i}" in names
        for i in range(2, 7):
            assert f"conv_bp_c{i}" in names
        assert "conv_bp_c1" not in names  # input layer needs no x-gradient
        assert {"smask_c1", "smask_c3", "smask_c5"} <= names
        for j in (1, 2, 3):
            assert f"pool_p{j}" in names and f"ups_p{j}" in names
        assert {"fc_fp", "fc_bp", "fc_wu", "loss_hinge",
                "loss_euclid"} <= names

    def test_op_count(self, ops):
        # 6 conv_fp + 6 conv_wu + 5 conv_bp + 3 smask + 3 pool + 3 ups
        # + fc_fp/bp/wu + 2 losses = 31
        assert len(ops) == 31

    def test_every_op_evaluates_at_declared_shapes(self, ops):
        for name, (fn, specs) in ops.items():
            outs = jax.eval_shape(fn, *specs)
            leaves = jax.tree_util.tree_leaves(outs)
            assert len(leaves) >= 1, name
            for o in leaves:
                assert o.dtype == jnp.int32, name

    def test_conv_fp_c1_signature(self, ops):
        _, specs = ops["conv_fp_c1"]
        assert [tuple(s.shape) for s in specs] == [
            (3, 32, 32), (16, 3, 3, 3), (16,)]


class TestHloEmission:
    def test_lower_one_op_to_hlo_text(self):
        ops = aot.op_table("1x")
        fn, specs = ops["fc_bp"]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "HloModule" in text
        assert "ENTRY" in text
        # interchange contract: parseable text, parameters present
        assert "parameter(0)" in text and "parameter(1)" in text

    def test_hlo_has_no_mosaic_custom_call(self):
        """interpret=True must lower to plain HLO (no Mosaic custom-calls
        the CPU PJRT client cannot execute)."""
        ops = aot.op_table("1x")
        fn, specs = ops["conv_fp_c1"]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()


class TestBundleFormat:
    def test_roundtrip(self):
        tensors = {
            "a": np.arange(24, dtype=np.int32).reshape(2, 3, 4),
            "b": np.asarray([-5], np.int32),
        }
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "t.bin")
            aot.write_bundle(path, tensors)
            with open(path, "rb") as f:
                blob = f.read()
        assert blob[:4] == b"FXTB"
        (n,) = struct.unpack_from("<I", blob, 4)
        assert n == 2
        off = 8
        for name, arr in tensors.items():
            (ln,) = struct.unpack_from("<I", blob, off); off += 4
            assert blob[off:off + ln].decode() == name; off += ln
            (nd,) = struct.unpack_from("<I", blob, off); off += 4
            dims = struct.unpack_from(f"<{nd}I", blob, off); off += 4 * nd
            assert dims == arr.shape
            count = int(np.prod(dims))
            data = np.frombuffer(blob, "<i4", count, off)
            np.testing.assert_array_equal(data.reshape(dims), arr)
            off += 4 * count
        assert off == len(blob)


class TestTestvec:
    def test_testvec_contents(self):
        tv = aot.make_testvec("1x")
        assert {"x", "y", "loss", "logits"} <= set(tv)
        for n in M.param_order("1x"):
            assert f"g_{n}" in tv
        assert tv["x"].shape == M.IMG
        assert tv["loss"].shape == (1,)


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        p = os.path.join(os.path.dirname(__file__),
                         "../../artifacts/manifest.json")
        with open(p) as f:
            return json.load(f)

    def test_manifest_lists_all_files(self, manifest):
        adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for name, op in manifest["ops"].items():
            assert os.path.exists(os.path.join(adir, op["file"])), name

    def test_manifest_qformat_matches(self, manifest):
        from compile import fixedpoint as fx
        q = manifest["qformat"]
        assert (q["fa"], q["fw"], q["fg"], q["fwg"], q["fv"]) == (
            fx.FA, fx.FW, fx.FG, fx.FWG, fx.FV)

    def test_param_bin_exists_and_parses(self, manifest):
        adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for scale, net in manifest["nets"].items():
            path = os.path.join(adir, net["params_file"])
            with open(path, "rb") as f:
                assert f.read(4) == b"FXTB"


class TestWiderScales:
    """2X/4X op tables must evaluate at their declared shapes (artifacts
    for them are opt-in via --scales; the rust golden path covers their
    numerics, but the signatures must stay lowerable)."""

    @pytest.mark.parametrize("scale", ["2x", "4x"])
    def test_op_table_shapes(self, scale):
        ops = aot.op_table(scale)
        assert len(ops) == 31
        for name, (fn, specs) in ops.items():
            outs = jax.eval_shape(fn, *specs)
            for o in jax.tree_util.tree_leaves(outs):
                assert o.dtype == jnp.int32, f"{scale}:{name}"

    def test_4x_conv_shapes_scale(self):
        ops = aot.op_table("4x")
        _, specs = ops["conv_fp_c1"]
        assert tuple(specs[1].shape) == (64, 3, 3, 3)
        _, specs6 = ops["conv_fp_c6"]
        assert tuple(specs6[1].shape) == (256, 256, 3, 3)
