"""Unit + property tests for the Q-format fixed-point helpers.

These semantics are mirrored bit-for-bit by rust/src/fixed — any change
here must be reflected there (the rust integration tests replay the AOT
test vector through both paths)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # degrade: only the property sweeps skip; every deterministic
    # test in this module still runs
    from .helpers import hyp_given as given, hyp_settings as \
        settings, hyp_st as st

from compile import fixedpoint as fx


class TestSat16:
    def test_identity_in_range(self):
        x = jnp.asarray([0, 1, -1, 32767, -32768], jnp.int32)
        np.testing.assert_array_equal(np.asarray(fx.sat16(x)), np.asarray(x))

    def test_clamps(self):
        x = jnp.asarray([32768, 100000, -32769, -(1 << 30)], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(fx.sat16(x)), [32767, 32767, -32768, -32768])


class TestRequant:
    def test_shift_zero_is_saturate_only(self):
        x = jnp.asarray([5, -7, 70000], jnp.int32)
        np.testing.assert_array_equal(np.asarray(fx.requant(x, 0)),
                                      [5, -7, 32767])

    def test_round_half_up(self):
        # (x + 2) >> 2 for shift 2 == floor(x/4 + 0.5)
        x = jnp.asarray([2, -2, 3, -3, 6, -6], jnp.int32)
        np.testing.assert_array_equal(np.asarray(fx.requant(x, 2)),
                                      [1, 0, 1, -1, 2, -1])

    @given(st.integers(-(1 << 28), 1 << 28), st.integers(1, 16))
    @settings(max_examples=200, deadline=None)
    def test_matches_float_rounding(self, v, s):
        got = int(fx.requant(jnp.asarray([v], jnp.int32), s)[0])
        want = int(np.floor(v / (1 << s) + 0.5))
        want = max(-32768, min(32767, want))
        assert got == want


class TestQuantize:
    def test_roundtrip_on_grid(self):
        vals = np.asarray([0.0, 1.0, -1.0, 0.5, 127.99609375])
        q = fx.quantize(vals, fx.FA)
        back = fx.dequantize(q, fx.FA)
        np.testing.assert_allclose(back, vals)

    def test_saturates(self):
        q = fx.quantize(np.asarray([1000.0, -1000.0]), fx.FA)
        np.testing.assert_array_equal(np.asarray(q), [32767, -32768])

    @given(st.floats(-10, 10, allow_nan=False), st.integers(4, 14))
    @settings(max_examples=200, deadline=None)
    def test_error_within_half_lsb(self, v, frac):
        q = int(fx.quantize(np.asarray([v]), frac)[0])
        if -32768 < q < 32767:
            assert abs(q / (1 << frac) - v) <= 0.5 / (1 << frac) + 1e-12


class TestShiftConstants:
    def test_fraction_bookkeeping(self):
        # conv FP: FA + FW - SHIFT_CONV_FP == FA
        assert fx.FA + fx.FW - fx.SHIFT_CONV_FP == fx.FA
        # conv BP: FG + FW - SHIFT_CONV_BP == FG
        assert fx.FG + fx.FW - fx.SHIFT_CONV_BP == fx.FG
        # WU store: FA + FG - SHIFT_WU_STORE == FWG
        assert fx.FA + fx.FG - fx.SHIFT_WU_STORE == fx.FWG

    def test_all_shifts_nonnegative(self):
        assert fx.SHIFT_CONV_FP >= 0
        assert fx.SHIFT_CONV_BP >= 0
        assert fx.SHIFT_WU_STORE >= 0
