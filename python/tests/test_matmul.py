"""FC-layer Pallas matmul kernels vs the oracle: forward (normal weights),
backward (transposed weight matrix, §II), weight update (outer product)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # degrade: only the property sweeps skip; every deterministic
    # test in this module still runs
    from .helpers import hyp_given as given, hyp_settings as \
        settings, hyp_st as st

from compile import fixedpoint as fx
from compile.kernels import fc_bp, fc_fp, fc_wu, matmul_q
from compile.kernels import ref
from .helpers import randi

FC_SHAPES = [(1024, 10), (2048, 10), (4096, 10), (64, 10)]


@pytest.mark.parametrize("k,n", FC_SHAPES)
def test_fc_fp_matches_ref(rng, k, n):
    x = randi(rng, (1, k))
    w = randi(rng, (n, k), -150, 150)
    b = randi(rng, (n,), -2000, 2000)
    np.testing.assert_array_equal(np.asarray(fc_fp(x, w, b)),
                                  np.asarray(ref.fc_fp_ref(x, w, b)))


@pytest.mark.parametrize("k,n", FC_SHAPES)
def test_fc_bp_matches_ref(rng, k, n):
    g = randi(rng, (1, n))
    w = randi(rng, (n, k), -150, 150)
    np.testing.assert_array_equal(np.asarray(fc_bp(g, w)),
                                  np.asarray(ref.fc_bp_ref(g, w)))


@pytest.mark.parametrize("k,n", FC_SHAPES)
def test_fc_wu_matches_ref(rng, k, n):
    g = randi(rng, (1, n))
    x = randi(rng, (1, k))
    dw, db = fc_wu(g, x)
    dwr, dbr = ref.fc_wu_ref(g, x)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dbr))


def test_fc_bp_uses_transpose(rng):
    """BP through FC is g @ W (the transposed use of the (N,K) matrix that
    FP uses as x @ W^T) — check against explicit numpy."""
    g = randi(rng, (1, 10))
    w = randi(rng, (10, 64), -150, 150)
    want = np.asarray(g, np.int64) @ np.asarray(w, np.int64)
    want = np.floor(want / (1 << fx.SHIFT_CONV_BP) + 0.5)
    want = np.clip(want, -32768, 32767).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(fc_bp(g, w)), want)


def test_fc_wu_is_outer_product(rng):
    g = randi(rng, (1, 4))
    x = randi(rng, (1, 8))
    dw, _ = fc_wu(g, x)
    want = np.outer(np.asarray(g)[0].astype(np.int64),
                    np.asarray(x)[0].astype(np.int64))
    want = np.floor(want / (1 << fx.SHIFT_WU_STORE) + 0.5).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(dw), want)


def test_matmul_q_saturates(rng):
    a = jnp.full((2, 4), 10000, jnp.int32)
    b = jnp.full((4, 2), 10000, jnp.int32)
    out = np.asarray(matmul_q(a, b, shift=0))
    assert (out == 32767).all()


def test_matmul_q_relu(rng):
    a = randi(rng, (2, 8))
    b = randi(rng, (8, 4))
    out = np.asarray(matmul_q(a, b, shift=4, relu=True))
    assert out.min() >= 0


@given(m=st.integers(1, 4), k=st.integers(1, 32), n=st.integers(1, 16),
       shift=st.sampled_from([0, 4, 12]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matmul_q_hypothesis(m, k, n, shift, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(-300, 300, (m, k)), jnp.int32)
    b = jnp.asarray(r.integers(-300, 300, (k, n)), jnp.int32)
    got = np.asarray(matmul_q(a, b, shift=shift))
    acc = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    if shift > 0:
        acc = np.floor(acc / (1 << shift) + 0.5).astype(np.int64)
    want = np.clip(acc, -32768, 32767).astype(np.int32)
    np.testing.assert_array_equal(got, want)
