import numpy as np


def randi(rng, shape, lo=-400, hi=400):
    import jax.numpy as jnp
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int32)
