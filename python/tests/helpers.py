import numpy as np


def randi(rng, shape, lo=-400, hi=400):
    import jax.numpy as jnp
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int32)


# ---------------------------------------------------------------------
# hypothesis degradation shims: when hypothesis is not installed, the
# @given property sweeps report as individually skipped instead of
# erroring the whole module at collection (every deterministic test in
# the module keeps running).  Usage in a test module:
#
#   try:
#       from hypothesis import given, settings, strategies as st
#   except ImportError:
#       from .helpers import hyp_given as given, hyp_settings as \
#           settings, hyp_st as st


def hyp_given(*_args, **_kwargs):
    """Stand-in for hypothesis.given: the decorated test skips at run
    time.  The wrapper takes ``*args`` so pytest does not try to
    fixture-inject the strategy parameter names."""
    def deco(fn):
        def skipped(*args, **kwargs):
            import pytest
            pytest.skip("hypothesis not installed")
        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped
    return deco


def hyp_settings(*_args, **_kwargs):
    """Stand-in for hypothesis.settings: identity decorator."""
    def deco(fn):
        return fn
    return deco


class _HypStrategyStub:
    """Stand-in for hypothesis.strategies: any strategy constructor
    returns a placeholder (hyp_given ignores its arguments)."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


hyp_st = _HypStrategyStub()
