"""Pallas conv kernels (tiled like the paper's MAC array) vs the untiled
pure-jnp oracle — exact integer equality, across shapes/tilings/dtypes of
the CIFAR nets plus hypothesis-driven shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # degrade: only the property sweeps skip; every deterministic
    # test in this module still runs
    from .helpers import hyp_given as given, hyp_settings as \
        settings, hyp_st as st

from compile import fixedpoint as fx
from compile.kernels import conv_bp, conv_fp, conv_wu, transpose_flip
from compile.kernels import ref
from .helpers import randi

# every distinct conv shape in the paper's 1X/2X/4X nets (cin, cout, hw)
PAPER_SHAPES = [
    (3, 16, 32), (16, 16, 32), (16, 32, 16), (32, 32, 16),
    (32, 64, 8), (64, 64, 8),
    (3, 64, 32), (64, 128, 16), (128, 256, 8),  # 2X/4X representatives
]


@pytest.mark.parametrize("cin,cout,hw", PAPER_SHAPES)
def test_conv_fp_matches_ref(rng, cin, cout, hw):
    x = randi(rng, (cin, hw, hw))
    w = randi(rng, (cout, cin, 3, 3), -150, 150)
    b = randi(rng, (cout,), -2000, 2000)
    got = conv_fp(x, w, b)
    want = ref.conv_fp_ref(x, w, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pof,poy", [(4, 2), (8, 8), (16, 4), (16, 16)])
def test_conv_fp_tiling_invariance(rng, pof, poy):
    """Unroll factors (the paper's design variables) must never change
    numerics — only the schedule."""
    x = randi(rng, (8, 16, 16))
    w = randi(rng, (16, 8, 3, 3), -150, 150)
    b = randi(rng, (16,), -2000, 2000)
    base = ref.conv_fp_ref(x, w, b)
    got = conv_fp(x, w, b, pof=pof, poy=poy)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_conv_fp_no_relu_shift(rng):
    x = randi(rng, (4, 8, 8))
    w = randi(rng, (8, 4, 3, 3), -150, 150)
    b = jnp.zeros((8,), jnp.int32)
    got = conv_fp(x, w, b, relu=False, shift=fx.SHIFT_CONV_BP)
    want = ref.conv_fp_ref(x, w, b, relu=False, shift=fx.SHIFT_CONV_BP)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert (np.asarray(got) < 0).any()  # relu disabled


def test_conv_fp_saturation(rng):
    """Large operands must saturate to the i16 range, not wrap."""
    # magnitudes chosen so the i32 accumulator does NOT wrap (18 products
    # of 5000*5000 = 4.5e8 < 2^31) but the requantized value exceeds i16
    x = jnp.full((2, 8, 8), 5000, jnp.int32)
    w = jnp.full((4, 2, 3, 3), 5000, jnp.int32)
    b = jnp.zeros((4,), jnp.int32)
    got = np.asarray(conv_fp(x, w, b, relu=False))
    want = np.asarray(ref.conv_fp_ref(x, w, b, relu=False))
    np.testing.assert_array_equal(got, want)
    assert got.max() == 32767


@pytest.mark.parametrize("cin,cout,hw", PAPER_SHAPES[:6])
def test_conv_bp_matches_ref(rng, cin, cout, hw):
    g = randi(rng, (cout, hw, hw))
    w = randi(rng, (cout, cin, 3, 3), -150, 150)
    got = conv_bp(g, w)
    want = ref.conv_bp_ref(g, w)
    assert got.shape == (cin, hw, hw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_bp_equals_explicit_flip_transpose(rng):
    """Eq. (3): BP conv == FP conv with 180-degree-flipped, if/of-swapped
    kernels — the transposable-buffer contract (Fig. 5)."""
    g = randi(rng, (8, 8, 8))
    w = randi(rng, (8, 4, 3, 3), -150, 150)
    wt = transpose_flip(w)
    explicit = ref.conv_fp_ref(g, wt, jnp.zeros((4,), jnp.int32),
                               relu=False, shift=fx.SHIFT_CONV_BP)
    np.testing.assert_array_equal(np.asarray(conv_bp(g, w)),
                                  np.asarray(explicit))


def test_transpose_flip_involution(rng):
    """Applying the transposable access twice restores the original kernels
    (reading the circulant buffer back in non-transpose mode)."""
    w = randi(rng, (6, 4, 3, 3))
    np.testing.assert_array_equal(
        np.asarray(transpose_flip(transpose_flip(w))), np.asarray(w))


@pytest.mark.parametrize("cin,cout,hw", PAPER_SHAPES[:6])
def test_conv_wu_matches_ref(rng, cin, cout, hw):
    x = randi(rng, (cin, hw, hw))
    g = randi(rng, (cout, hw, hw))
    dw, db = conv_wu(x, g)
    dwr, dbr = ref.conv_wu_ref(x, g)
    assert dw.shape == (cout, cin, 3, 3)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dbr))


def test_conv_wu_is_4d_intra_tile_accumulation(rng):
    """Eq. (4): each (of, if) plane is an independent 1-in-1-out FP conv —
    check one plane against a manual single-channel convolution."""
    x = randi(rng, (3, 8, 8))
    g = randi(rng, (4, 8, 8))
    dw, _ = conv_wu(x, g)
    xp = np.asarray(ref.pad_hw(x, 1))
    gb = np.asarray(g)
    manual = np.zeros((3, 3), np.int64)
    for ky in range(3):
        for kx in range(3):
            manual[ky, kx] = (gb[2].astype(np.int64)
                              * xp[1, ky:ky + 8, kx:kx + 8]).sum()
    manual = np.floor(manual / (1 << fx.SHIFT_WU_STORE) + 0.5).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(dw)[2, 1], manual)


def test_conv_zero_gradient_gives_zero_update(rng):
    x = randi(rng, (4, 8, 8))
    g = jnp.zeros((8, 8, 8), jnp.int32)
    dw, db = conv_wu(x, g)
    assert not np.asarray(dw).any()
    assert not np.asarray(db).any()


@given(
    cin=st.integers(1, 8), cout=st.integers(1, 12),
    hw=st.sampled_from([4, 6, 8, 12]),
    pof=st.sampled_from([1, 2, 4, 8, 16]),
    poy=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_conv_fp_hypothesis_sweep(cin, cout, hw, pof, poy, seed):
    """Shape/tiling sweep: the Pallas kernel must equal the oracle for any
    layer geometry the RTL compiler could be asked to build."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.integers(-300, 300, (cin, hw, hw)), jnp.int32)
    w = jnp.asarray(r.integers(-150, 150, (cout, cin, 3, 3)), jnp.int32)
    b = jnp.asarray(r.integers(-2000, 2000, (cout,)), jnp.int32)
    got = conv_fp(x, w, b, pof=pof, poy=poy)
    want = ref.conv_fp_ref(x, w, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    cin=st.integers(1, 6), cout=st.integers(1, 8),
    hw=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_conv_bp_wu_hypothesis_sweep(cin, cout, hw, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.integers(-300, 300, (cin, hw, hw)), jnp.int32)
    g = jnp.asarray(r.integers(-300, 300, (cout, hw, hw)), jnp.int32)
    w = jnp.asarray(r.integers(-150, 150, (cout, cin, 3, 3)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(conv_bp(g, w)),
                                  np.asarray(ref.conv_bp_ref(g, w)))
    dw, db = conv_wu(x, g)
    dwr, dbr = ref.conv_wu_ref(x, g)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dbr))


@pytest.mark.parametrize("k", [1, 5])
def test_conv_fp_other_kernel_sizes(rng, k):
    """The RTL library is parameterized in Nkx/Nky (Table I); the Pallas
    kernel must match the oracle for 1x1 and 5x5 same-convolutions."""
    pad = (k - 1) // 2
    x = randi(rng, (4, 8, 8))
    w = randi(rng, (6, 4, k, k), -150, 150)
    b = randi(rng, (6,), -2000, 2000)
    got = conv_fp(x, w, b, pad=pad)
    want = ref.conv_fp_ref(x, w, b, pad=pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("k", [1, 5])
def test_conv_bp_wu_other_kernel_sizes(rng, k):
    pad = (k - 1) // 2
    g = randi(rng, (6, 8, 8))
    w = randi(rng, (6, 4, k, k), -150, 150)
    x = randi(rng, (4, 8, 8))
    np.testing.assert_array_equal(
        np.asarray(conv_bp(g, w, pad=pad)),
        np.asarray(ref.conv_bp_ref(g, w, pad=pad)))
    dw, db = conv_wu(x, g, pad=pad)
    dwr, dbr = ref.conv_wu_ref(x, g, pad=pad)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dbr))
