//! `ckpt_diff A.ckpt B.ckpt` — compare two stratus checkpoints on
//! their *deterministic* content: fingerprint, cursor, hyper, every
//! parameter tensor, every optimizer/statistic state, and the
//! deterministic training metrics (images, batches, bit-exact
//! loss_sum).  Exits 0 when they match, 1 on any divergence, 2 on
//! usage/load errors.
//!
//! The performance metrics (sim_cycles, host_seconds) are *reported*
//! but never gated: different topologies and instance counts project
//! different cycle counts and run at different host speeds by design —
//! the bit-identity contract covers the training stream only.  CI's
//! topology smoke step trains the same spec under `--topology ring`
//! and `--topology hier` (and through an elastic resize) and diffs the
//! checkpoints with this tool.

use std::path::Path;
use std::process::exit;

use stratus::ckpt::Checkpoint;

fn load(arg: &str) -> Checkpoint {
    match Checkpoint::load(Path::new(arg)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("ckpt_diff: loading {arg}: {e:#}");
            exit(2);
        }
    }
}

fn check(diffs: &mut Vec<String>, ok: bool, what: &str) {
    if !ok {
        diffs.push(what.to_string());
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [pa, pb] = args.as_slice() else {
        eprintln!("usage: ckpt_diff <A.ckpt> <B.ckpt>");
        exit(2);
    };
    let a = load(pa);
    let b = load(pb);
    let mut diffs: Vec<String> = Vec::new();

    check(&mut diffs, a.fingerprint == b.fingerprint, "fingerprint");
    check(&mut diffs, a.cursor == b.cursor,
          "cursor (epoch/batch/seed/images)");
    check(&mut diffs, a.hyper.lr_q16 == b.hyper.lr_q16, "hyper.lr_q16");
    check(&mut diffs, a.hyper.beta_q15 == b.hyper.beta_q15,
          "hyper.beta_q15");
    check(&mut diffs, a.hyper.batch == b.hyper.batch, "hyper.batch");
    check(&mut diffs, a.metrics.images == b.metrics.images,
          "metrics.images");
    check(&mut diffs, a.metrics.batches == b.metrics.batches,
          "metrics.batches");
    check(&mut diffs,
          a.metrics.loss_sum.to_bits() == b.metrics.loss_sum.to_bits(),
          "metrics.loss_sum (bit-exact)");

    check(&mut diffs, a.params.len() == b.params.len(),
          "params (tensor count)");
    for ((na, ta), (nb, tb)) in a.params.iter().zip(&b.params) {
        if na != nb {
            diffs.push(format!("params order: {na} vs {nb}"));
        } else if ta != tb {
            diffs.push(format!("params[{na}] data"));
        }
    }
    check(&mut diffs, a.states.len() == b.states.len(),
          "states (entry count)");
    for ((na, sa), (nb, sb)) in a.states.iter().zip(&b.states) {
        if na != nb {
            diffs.push(format!("states order: {na} vs {nb}"));
            continue;
        }
        if sa.kind != sb.kind {
            diffs.push(format!("states[{na}].kind"));
        }
        if sa.grad_acc != sb.grad_acc {
            diffs.push(format!("states[{na}].grad_acc"));
        }
        if sa.momentum != sb.momentum {
            diffs.push(format!("states[{na}].momentum"));
        }
        if sa.count != sb.count {
            diffs.push(format!("states[{na}].count"));
        }
    }

    // informational only: these legitimately differ across topologies
    println!("sim_cycles     : {} vs {}", a.metrics.sim_cycles,
             b.metrics.sim_cycles);
    println!("host_seconds   : {:.3} vs {:.3}", a.metrics.host_seconds,
             b.metrics.host_seconds);

    if diffs.is_empty() {
        println!("ckpt_diff      : deterministic content identical \
                  ({} params, {} states)",
                 a.params.len(), a.states.len());
        exit(0);
    }
    eprintln!("ckpt_diff      : {} divergence(s):", diffs.len());
    for d in &diffs {
        eprintln!("  - {d}");
    }
    exit(1);
}
