//! Latency & traffic anatomy of one training iteration (the data behind
//! Fig. 9), for any of the paper's three CNNs: per-scheduled-step logic
//! vs DRAM cycles, phase totals, and where the 51% weight-update share
//! comes from.
//!
//! Run: `cargo run --release --example latency_breakdown [-- 4x]`

use anyhow::Result;

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::sim::simulate;

fn main() -> Result<()> {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "4x".into());
    let scale = match arg.as_str() {
        "1x" => 1,
        "2x" => 2,
        _ => 4,
    };
    let net = Network::cifar(scale);
    let dv = DesignVars::for_scale(scale);
    let acc = RtlCompiler::default().compile(&net, &dv)?;
    let r = simulate(&acc, 40);

    println!("== {} @ BS 40: per-step costs ==", net.name);
    println!("{:<6} {:<6} {:<14} {:>10} {:>10} {:>10}", "phase",
             "layer", "op", "logic", "dram", "latency");
    for (phase, layer, op, cost) in &r.steps {
        println!("{:<6} {:<6} {:<14} {:>10} {:>10} {:>10}",
                 format!("{phase:?}"), layer, format!("{op:?}"),
                 cost.logic_cycles, cost.dram_cycles,
                 cost.latency_cycles);
    }

    println!("\nphase totals (cycles):");
    for (name, p) in [("FP", &r.fp), ("BP", &r.bp), ("WU", &r.wu),
                      ("UPDATE/batch", &r.update)] {
        println!("  {:<12} logic {:>10}  dram {:>10}  latency {:>10}",
                 name, p.logic_cycles, p.dram_cycles, p.latency_cycles);
    }
    let wu_share = (r.wu.latency_cycles as f64
        + r.update.latency_cycles as f64 / 40.0)
        / r.cycles_per_image();
    println!("\nweight-update share of one iteration: {:.1}% (paper \
              Fig. 9: 51% for 4X)", wu_share * 100.0);
    println!("per image: {:.3} ms; epoch (50k): {:.2} s; {:.0} GOPS",
             r.seconds_per_image() * 1e3, r.seconds_per_epoch(50_000),
             r.gops());
    println!("DRAM traffic: {:.2} MB/image + {:.2} MB/batch-update",
             acc.schedule.image_bytes() as f64 / 1e6,
             acc.schedule.batch_bytes() as f64 / 1e6);
    Ok(())
}
