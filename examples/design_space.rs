//! Design-space exploration: sweep the RTL compiler's unroll factors
//! (Pox/Poy/Pof — the paper's design variables, Table I) over the 1X
//! network and report resources, power, epoch latency and GOPS for every
//! point that fits the Stratix 10 GX device.  This is the workflow the
//! paper's compiler enables: "the user provides ... design variables to
//! characterize FPGA hardware usage" (§I).
//!
//! Run: `cargo run --release --example design_space`

use anyhow::Result;

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::sim::simulate;

fn main() -> Result<()> {
    let net = Network::cifar(1);
    let compiler = RtlCompiler::default();
    println!("== design-space sweep: {} ==", net.name);
    println!("{:>4} {:>4} {:>4} {:>6} {:>6} {:>7} {:>8} {:>10} {:>8} \
              {:>9}",
             "Pox", "Poy", "Pof", "MACs", "DSP", "BRAM", "power W",
             "epoch s", "GOPS", "GOPS/W");

    let mut best: Option<(f64, DesignVars)> = None;
    for &pox in &[4usize, 8, 16] {
        for &poy in &[4usize, 8] {
            for &pof in &[8usize, 16, 32, 64] {
                let mut dv = DesignVars::for_scale(1);
                dv.pox = pox;
                dv.poy = poy;
                dv.pof = pof;
                match compiler.compile(&net, &dv) {
                    Err(_) => {
                        println!("{pox:>4} {poy:>4} {pof:>4}   -- does \
                                  not fit device --");
                    }
                    Ok(acc) => {
                        let r = simulate(&acc, 40);
                        let gops = r.gops();
                        let eff = gops / acc.power.total();
                        println!(
                            "{:>4} {:>4} {:>4} {:>6} {:>6} {:>7.1} \
                             {:>8.1} {:>10.2} {:>8.0} {:>9.2}",
                            pox, poy, pof, dv.mac_count(),
                            acc.resources.dsp, acc.resources.bram_mbits,
                            acc.power.total(),
                            r.seconds_per_epoch(50_000), gops, eff
                        );
                        if best.as_ref().map(|(e, _)| eff > *e)
                            .unwrap_or(true)
                        {
                            best = Some((eff, dv.clone()));
                        }
                    }
                }
            }
        }
    }
    if let Some((eff, dv)) = best {
        println!(
            "\nbest efficiency: {:.2} GOPS/W at Pox={} Poy={} Pof={} \
             (paper's 1X choice: 8x8x16)",
            eff, dv.pox, dv.poy, dv.pof
        );
    }
    Ok(())
}
