//! End-to-end driver (DESIGN.md "End-to-end validation"): train the
//! paper's CIFAR-10 1X CNN in 16-bit fixed point through the FULL system
//! — rust coordinator executing the compiled layer-by-layer schedule,
//! numerics on AOT-compiled JAX/Pallas artifacts via PJRT, gradient
//! accumulation + SGD-momentum in the weight-update unit, cycle
//! accounting from the hardware model — on the synthetic CIFAR-like
//! task, side by side with an f32 floating-point reference, reproducing
//! the paper's claim that 16-bit fixed-point training matches the float
//! baseline (§IV-B).
//!
//! Run: `make artifacts && cargo run --release --example train_cifar`
//! Env knobs: IMAGES (default 256), EPOCHS (12), BATCH (8),
//! BACKEND (fused|perop), LR (0.002 — the paper's), SEED (7),
//! NOISE (0.8).
//!
//! Results are recorded in EXPERIMENTS.md §Accuracy.

use std::path::Path;

use anyhow::{bail, Result};

use stratus::coordinator::Backend;
use stratus::data::Synthetic;
use stratus::nn::floatref::{image_f32, FTensor, FloatTrainer};
use stratus::session::{Session, Spec};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let images = env_usize("IMAGES", 256);
    let epochs = env_usize("EPOCHS", 12);
    let batch = env_usize("BATCH", 8);
    let lr = env_f64("LR", 0.002);
    let seed = env_usize("SEED", 7) as u64;
    let backend = match std::env::var("BACKEND").as_deref() {
        Ok("perop") => Backend::PerOp,
        _ => Backend::Fused,
    };
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }

    let spec = Spec::builder()
        .preset("1x")
        .backend(backend)
        .artifacts(artifacts)
        .batch(batch)
        .lr(lr)
        .momentum(0.9)
        .build()?;
    let session = Session::new(spec)?;
    let clock_hz = session.design().clock_mhz * 1e6;
    let mut fixed = session.trainer()?;
    // f32 reference starts from the SAME (dequantized) parameters
    let mut float = FloatTrainer::from_params(session.network(),
                                              &fixed.params, lr, 0.9)?;

    let noise = env_f64("NOISE", 0.8);
    let data = Synthetic::new(10, (3, 32, 32), seed, noise);
    let train: Vec<_> = data.batch(0, images);
    // eval window right after the training window (the session
    // convention: disjoint by construction at any IMAGES)
    let test: Vec<_> = data.batch(images as u64, 200);
    let ftrain: Vec<(FTensor, usize)> = train
        .iter()
        .map(|s| (image_f32(&s.image), s.label))
        .collect();

    println!("== end-to-end: CIFAR-10 1X, 16-bit fixed (full stack, \
              {backend:?} PJRT backend) vs f32 reference ==");
    println!("{} train / {} test images, BS {batch}, lr {lr}, \
              momentum 0.9", images, test.len());
    println!("{:<6} {:>12} {:>10} {:>10} {:>12} {:>9}",
             "epoch", "fixed-loss", "fixed-acc", "float-acc",
             "sim-time(s)", "host(s)");

    for epoch in 1..=epochs {
        let mut floss = 0.0;
        let mut nb = 0;
        for (chunk, fchunk) in
            train.chunks(batch).zip(ftrain.chunks(batch))
        {
            floss += fixed.train_batch(chunk)?;
            float.train_batch(fchunk);
            nb += 1;
        }
        let acc_fixed = fixed.evaluate(&test)?;
        let acc_float = {
            let mut c = 0;
            for s in &test {
                if float.predict(&image_f32(&s.image)) == s.label {
                    c += 1;
                }
            }
            c as f64 / test.len() as f64
        };
        println!("{:<6} {:>12.1} {:>9.1}% {:>9.1}% {:>12.2} {:>9.1}",
                 epoch, floss / nb as f64, acc_fixed * 100.0,
                 acc_float * 100.0,
                 fixed.metrics.sim_seconds(clock_hz),
                 fixed.metrics.host_seconds);
    }
    println!("\ntrained {} images through {} PJRT step executions; \
              paper claim: 16-bit fixed training accuracy ~= float \
              baseline (§IV-B)",
             fixed.metrics.images, fixed.metrics.images);
    Ok(())
}
