//! Quickstart: compile the paper's CIFAR-10 1X accelerator, inspect the
//! generated design, cycle-simulate it, and train a couple of batches
//! through the golden backend (no artifacts needed).
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::coordinator::{Backend, Trainer};
use stratus::data::Synthetic;
use stratus::sim::simulate;

fn main() -> Result<()> {
    // 1. describe the network (or Network::parse a .cfg file) and the
    //    FPGA design variables — the two inputs of the RTL compiler
    let net = Network::cifar(1);
    let dv = DesignVars::for_scale(1); // Pox=Poy=8, Pof=16, 240 MHz

    // 2. run the RTL compiler: module selection, schedule, buffers,
    //    resources, power, structural netlist
    let compiler = RtlCompiler::default();
    let acc = compiler.compile(&net, &dv)?;
    println!("compiled {}: {} modules, {} per-image schedule steps",
             net.name, acc.modules.len(), acc.schedule.per_image.len());
    println!("resources: {} DSP, {:.1} Mbit BRAM, {:.1} W total",
             acc.resources.dsp, acc.resources.bram_mbits,
             acc.power.total());

    // 3. cycle-simulate a training epoch (Table II methodology)
    let sim = simulate(&acc, 40);
    println!("simulated: {:.2} s / 50k-image epoch, {:.0} GOPS",
             sim.seconds_per_epoch(50_000), sim.gops());

    // 4. train two batches on the synthetic CIFAR-like task (golden
    //    backend: pure rust, bit-identical to the AOT artifacts)
    let mut trainer = Trainer::new(&net, &dv, 10, 0.002, 0.9,
                                   Backend::Golden, None)?;
    let data = Synthetic::cifar_like(7);
    for step in 0..2 {
        let batch = data.batch(step * 10, 10);
        let loss = trainer.train_batch(&batch)?;
        println!("batch {step}: mean loss {loss:.1} (simulated {:.1} ms)",
                 trainer.metrics.sim_seconds(dv.clock_mhz * 1e6) * 1e3);
    }

    // 5. emit the generated structural netlist
    let verilog = compiler.verilog(&acc);
    println!("generated netlist: {} lines (see `stratus compile \
              --emit-verilog`)", verilog.lines().count());
    Ok(())
}
