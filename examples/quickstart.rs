//! Quickstart: describe the paper's CIFAR-10 1X experiment as one
//! `session::Spec`, compile the accelerator, inspect the generated
//! design, cycle-simulate it, and train a couple of batches through
//! the golden backend (no artifacts needed).
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use stratus::compiler::RtlCompiler;
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

fn main() -> Result<()> {
    // 1. one validated experiment description: the network preset (or
    //    an inline/file network in the layer grammar), the design-
    //    variable overrides, and the training hyper-parameters.
    //    `spec.render()` serializes it — the same JSON `stratus train
    //    --spec run.json` consumes.
    let spec = Spec::builder()
        .preset("1x") // Pox=Poy=8, Pof=16, 240 MHz per-scale defaults
        .batch(10)
        .lr(0.002)
        .momentum(0.9)
        .build()?;
    let session = Session::new(spec)?;
    let net = session.network();

    // 2. run the RTL compiler: module selection, schedule, buffers,
    //    resources, power, structural netlist
    let acc = session.compile()?;
    println!("compiled {}: {} modules, {} per-image schedule steps",
             net.name, acc.modules.len(), acc.schedule.per_image.len());
    println!("resources: {} DSP, {:.1} Mbit BRAM, {:.1} W total",
             acc.resources.dsp, acc.resources.bram_mbits,
             acc.power.total());

    // 3. cycle-simulate a training epoch (Table II methodology)
    let sim = session.simulate()?;
    println!("simulated: {:.2} s / 50k-image epoch, {:.0} GOPS",
             sim.seconds_per_epoch(50_000), sim.gops());

    // 4. train two batches on the synthetic CIFAR-like task (golden
    //    backend: pure rust, bit-identical to the AOT artifacts)
    let mut trainer = session.trainer()?;
    let clock_hz = session.design().clock_mhz * 1e6;
    let data = Synthetic::cifar_like(7);
    for step in 0..2 {
        let batch = data.batch(step * 10, 10);
        let loss = trainer.train_batch(&batch)?;
        println!("batch {step}: mean loss {loss:.1} (simulated {:.1} ms)",
                 trainer.metrics.sim_seconds(clock_hz) * 1e3);
    }

    // 5. emit the generated structural netlist
    let verilog = RtlCompiler::default().verilog(&acc);
    println!("generated netlist: {} lines (see `stratus compile \
              --emit-verilog`)", verilog.lines().count());
    Ok(())
}
