//! Custom-network workflow: exactly what the paper's compiler promises —
//! "the user provides the high-level CNN network configurations along
//! with the design variables" (§I) and gets a training accelerator.
//!
//! Defines a non-CIFAR network (different depth, a 5x5 stem, 4x4
//! pooling) in the text config grammar, compiles it at two design
//! points, simulates both, runs the adaptive fixed-point calibration
//! pass, and trains a few batches through the golden backend.
//!
//! Run: `cargo run --release --example custom_net`

use anyhow::Result;

use stratus::compiler::calibrate;
use stratus::config::Network;
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "\
name  tiny-vision-5x5
input 3 16 16
conv  stem 12 k5 s1 p2 relu
conv  c2   24 k3 s1 p1 relu
pool  p1 2
conv  c3   32 k3 s1 p1 relu
pool  p2 2
fc    fc 10
loss  euclid
";

fn main() -> Result<()> {
    let net: Network = Network::parse(NET_CFG)?;
    println!("parsed `{}`: {} layers, {} parameters, loss {:?}",
             net.name, net.layers.len(), net.param_count(), net.loss);

    // two design points over the same network: one spec each, the
    // pof override riding on the per-scale defaults
    for (label, pof) in [("small array", 8), ("wide array", 32)] {
        let session = Session::new(
            Spec::builder()
                .net_inline(NET_CFG)
                .pof(pof)
                .batch(16)
                .build()?,
        )?;
        let acc = session.compile()?;
        let sim = session.simulate()?;
        println!(
            "{label:<12} Pof={pof:<3} {} MACs: {} DSP, {:.1} Mbit, \
             {:.2} ms/image, {:.0} GOPS",
            session.design().mac_count(), acc.resources.dsp,
            acc.resources.bram_mbits,
            sim.seconds_per_image() * 1e3, sim.gops()
        );
    }

    // adaptive fixed-point calibration on this topology
    let params = stratus::nn::init::init_params(&net, 99);
    let data = Synthetic::new(10, (3, 16, 16), 5, 0.3);
    let report = calibrate(&net, &params, &data.batch(0, 8))?;
    println!("\nadaptive fixed-point calibration:\n{}", report.render());

    // train it (golden backend: no artifacts needed for custom nets)
    let spec = Spec::builder()
        .net_inline(NET_CFG)
        .batch(8)
        .lr(0.01)
        .momentum(0.9)
        .build()?;
    let mut t = Session::new(spec)?.trainer()?;
    let train = data.batch(0, 64);
    for epoch in 1..=4 {
        let mut loss = 0.0;
        for chunk in train.chunks(8) {
            loss += t.train_batch(chunk)?;
        }
        let acc_tr = t.evaluate(&train)?;
        println!("epoch {epoch}: loss {:>9.1}, train acc {:>5.1}%",
                 loss / 8.0, acc_tr * 100.0);
    }
    Ok(())
}
