//! Batch-norm quickstart: build a small CNN with integer batch
//! normalization (the paper's §IV-B extension) through the layer
//! grammar, compile it, and train it on the golden backend — watching
//! the loss fall and the running statistics converge.
//!
//! BN rides the layer-ops registry end to end: the same descriptor
//! drives the schedule (`BnFp`/`BnBp` steps), the buffer plan, the
//! control ROM, the simulator, and the trainer's deterministic
//! statistic merge (bit-identical at any `--workers x --accelerators`).
//! BN networks are golden-backend-only until Pallas BN kernels land.
//!
//! Run: `cargo run --release --example bn_net`

use anyhow::Result;

use stratus::data::Synthetic;
use stratus::fixed::dequantize;
use stratus::session::{Session, Spec};

fn main() -> Result<()> {
    // 1. a conv -> bn+relu topology in the text grammar (`bn <name>
    //    [relu]`) inside one spec; `.preset("bn1x"|"bn2x"|"bn4x")`
    //    selects the full-size family instead.  The builder is also
    //    where BN's golden-backend-only rule is enforced — a
    //    `.backend(Backend::PerOp)` here would be a typed SpecError.
    let spec = Spec::builder()
        .net_inline(
            "name tinybn\n\
             input 3 8 8\n\
             conv c1 8 k3 s1 p1\n\
             bn n1 relu\n\
             conv c2 8 k3 s1 p1\n\
             bn n2 relu\n\
             pool p1 2\n\
             fc fc 10\n\
             loss hinge\n",
        )
        .batch(8)
        .lr(0.02)
        .momentum(0.9)
        .workers(2)
        .build()?;
    let session = Session::new(spec)?;
    let net = session.network();

    // 2. the registry gives bn layers schedule steps, buffers, a
    //    control-ROM word, and a batchnorm_unit in the module list
    let acc = session.compile()?;
    println!("compiled {}: {} layers, {} per-image steps, modules: {}",
             net.name,
             net.layers.len(),
             acc.schedule.per_image.len(),
             acc.modules
                 .iter()
                 .map(|m| m.entity())
                 .collect::<Vec<_>>()
                 .join(", "));

    // 3. train: per-image schedule + batch-end weight update + the
    //    deterministic BN statistic refresh
    let mut trainer = session.trainer()?;
    let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
    let batch = data.batch(0, 8);
    for step in 0..8 {
        let loss = trainer.train_batch(&batch)?;
        if step % 2 == 0 {
            println!("batch {step}: mean loss {loss:.1}");
        }
    }

    // 4. the running statistics have converged toward the activations
    //    the first bn layer actually sees
    let rm = trainer.params.get("rm_n1")?;
    let rv = trainer.params.get("rv_n1")?;
    println!("n1 running mean[0] = {:+.3}, running var[0] = {:.3}",
             dequantize(rm.data()[0], 8),
             dequantize(rv.data()[0], 16));
    println!("(bit-identical at any --workers x --accelerators; see \
              rust/tests/bn.rs)");
    Ok(())
}
