//! Batch-parallel training: shard each batch across engine worker
//! threads (ISSUE 1 tentpole) and verify the engine's core contract —
//! any worker count produces bit-identical parameters, because gradient
//! accumulation is integer addition and shards merge in fixed order
//! (see rust/src/engine/mod.rs).
//!
//! Run: `cargo run --release --example parallel_train [-- MAX_WORKERS]`

use anyhow::Result;

use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "\
name  engine-demo
input 3 16 16
conv  c1 8 k3 s1 p1 relu
conv  c2 8 k3 s1 p1 relu
pool  p1 2
fc    fc 10
loss  hinge
";

fn main() -> Result<()> {
    let max_workers: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(8);
    let data = Synthetic::new(10, (3, 16, 16), 7, 0.3);
    let batch = data.batch(0, 32);

    println!("training engine-demo for 3 batches of {} at each worker \
              count", batch.len());
    println!("{:<8} {:>10} {:>12} {:>16}", "workers", "images/s",
             "mean loss", "params");

    let mut reference: Option<(f64, Vec<i32>)> = None;
    for workers in [1usize, 2, 4, 8, 16]
        .into_iter()
        .filter(|&w| w <= max_workers.max(1))
    {
        // one spec per worker count — everything else identical, so
        // the bit-identity comparison below is apples to apples
        let spec = Spec::builder()
            .net_inline(NET_CFG)
            .batch(batch.len())
            .lr(0.02)
            .momentum(0.9)
            .workers(workers)
            .build()?;
        let mut t = Session::new(spec)?.trainer()?;
        let mut loss = 0.0;
        for _ in 0..3 {
            loss = t.train_batch(&batch)?;
        }
        let flat = t.flat_params();
        let verdict = match &reference {
            None => "(reference)",
            Some((l0, f0)) if *l0 == loss && *f0 == flat => {
                "bit-identical"
            }
            Some(_) => "MISMATCH",
        };
        if reference.is_none() {
            reference = Some((loss, flat));
        }
        println!("{:<8} {:>10.1} {:>12.1} {:>16}", workers,
                 t.metrics.images_per_second(), loss, verdict);
        if verdict == "MISMATCH" {
            anyhow::bail!("engine equivalence violated at {workers} \
                           workers");
        }
    }
    println!("\nevery row trained the same batch stream; the engine's \
              fixed-order i32 merge keeps results bit-identical at any \
              worker count.");
    Ok(())
}
