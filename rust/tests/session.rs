//! Session/Spec integration suite (ISSUE 5 acceptance): the spec
//! round-trips through JSON with an identical fingerprint, the builder
//! rejects every invalid configuration with its pinned typed error,
//! spec-driven training is bit-identical to directly-built training,
//! the fingerprint format stays byte-compatible with pre-Spec
//! checkpoints, and the eval window is derived from the epoch width
//! (never overlapping the training data).

use std::path::PathBuf;

use stratus::config::Topology;
use stratus::coordinator::Backend;
use stratus::data::Synthetic;
use stratus::session::{Session, Spec, SpecBuilder};

const TINY: &str = "name tiny\ninput 3 8 8\nconv c1 8 k3 s1 p1 relu\n\
                    conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                    loss hinge";

fn tiny_builder() -> SpecBuilder {
    Spec::builder()
        .net_inline(TINY)
        .batch(4)
        .lr(0.02)
        .momentum(0.9)
        .epochs(2)
        .images(12)
        .seed(7)
        .eval(4)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stratus_session_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn spec_round_trips_with_identical_fingerprint() {
    // build -> serialize -> parse: structurally identical spec AND an
    // identical run fingerprint (the acceptance criterion's core)
    let spec = tiny_builder()
        .workers(2)
        .accelerators(3)
        .pox(4)
        .clock_mhz(120.5)
        .noise(0.25)
        .topology(Topology::Hier)
        .link_gbytes(12.5)
        .link_efficiency(0.75)
        .checkpoint_dir("/tmp/stratus-rt")
        .checkpoint_every(2)
        .resize_accelerators(6)
        .build()
        .unwrap();
    let text = spec.render();
    let back = Spec::parse(&text).unwrap();
    assert_eq!(back, spec, "round trip changed the spec:\n{text}");
    let s1 = Session::new(spec).unwrap();
    let s2 = Session::new(back).unwrap();
    assert_eq!(s1.fingerprint(), s2.fingerprint());
    // and the rendered form is itself stable (canonical key order)
    assert_eq!(s2.spec().render(), text);
}

#[test]
fn builder_rejection_table() {
    // every validation rule, with its user-facing message pinned
    let artifacts = || Spec::builder().net_inline(TINY).artifacts("a");
    let cases: Vec<(SpecBuilder, &str)> = vec![
        (Spec::builder().batch(0), "batch must be at least 1"),
        (Spec::builder().epochs(0), "epochs must be at least 1"),
        (Spec::builder().images(0), "images must be at least 1"),
        (Spec::builder().eval(0), "eval must be at least 1"),
        (Spec::builder().workers(0), "workers must be at least 1"),
        (Spec::builder().accelerators(0),
         "accelerators must be at least 1"),
        (Spec::builder().pox(0), "pox must be at least 1"),
        (Spec::builder().poy(0), "poy must be at least 1"),
        (Spec::builder().pof(0), "pof must be at least 1"),
        (Spec::builder().tile_rows(0), "tile-rows must be at least 1"),
        (Spec::builder().checkpoint_dir("/tmp/x").checkpoint_every(0),
         "checkpoint-every must be at least 1"),
        (Spec::builder().preset("3x"),
         "unknown scale `3x` (use 1x|2x|4x|bn1x|bn2x|bn4x"),
        (Spec::builder().net_inline("input 3 8 8\nconv c1 4 k3 s2 p1\n\
                                     fc fc 10"),
         "invalid network description"),
        (Spec::builder().backend(Backend::PerOp),
         "backend perop needs an artifacts directory"),
        (Spec::builder().backend(Backend::Fused),
         "backend fused needs an artifacts directory"),
        (artifacts().preset("bn1x").backend(Backend::Fused),
         "golden-backend-only until Pallas BN kernels land"),
        (Spec::builder().checkpoint_every(5),
         "checkpoint-every needs checkpoint-dir"),
        (Spec::builder().resume(true),
         "resume needs checkpoint-dir"),
        (Spec::builder().images(12).eval_offset(4),
         "eval window starting at 4 overlaps the training window \
          [0, 12)"),
        // the range-analyzer gate: a batch whose worst-case BN moment
        // sum provably wraps the i32 statistic accumulator is refused
        (Spec::builder().preset("bn1x").batch(128),
         "batch 128 can wrap the i32 moment-sum accumulator of layer \
          `n1`"),
        // serializability guards: JSON numbers are f64
        (Spec::builder().seed(1u64 << 60),
         "seed wants an integer at most 2^53"),
        (Spec::builder().images(1u64 << 60),
         "images wants an integer at most 2^53"),
        (Spec::builder().lr(f64::INFINITY),
         "lr wants a finite number"),
        (Spec::builder().noise(f64::NAN),
         "noise wants a finite number"),
        // collective link parameters (ISSUE 8 satellite): the cost
        // model divides by bandwidth and scales by efficiency, so both
        // are range-checked at spec-build time
        (Spec::builder().link_gbytes(0.0),
         "link-gbs must be positive (got 0)"),
        (Spec::builder().link_gbytes(-2.5),
         "link-gbs must be positive (got -2.5)"),
        (Spec::builder().link_efficiency(0.0),
         "link-eff must be in (0, 1] (got 0)"),
        (Spec::builder().link_efficiency(1.5),
         "link-eff must be in (0, 1] (got 1.5)"),
        (Spec::builder().link_efficiency(f64::NAN),
         "link_efficiency wants a finite number"),
        (Spec::builder().resize_accelerators(0),
         "resize-accelerators must be at least 1"),
        (Spec::builder().resize_accelerators(4),
         "resize-accelerators needs checkpoint-dir"),
    ];
    for (builder, want) in cases {
        let err = builder.build().expect_err(want);
        let msg = err.to_string();
        assert!(msg.contains(want), "`{msg}` does not pin `{want}`");
    }
    // eval_offset == epoch width is the boundary: disjoint, accepted
    assert!(Spec::builder()
        .net_inline(TINY)
        .images(12)
        .eval_offset(12)
        .build()
        .is_ok());
}

#[test]
fn spec_driven_training_is_bit_identical_to_direct() {
    // the same description through two construction paths — builder
    // object vs parsed JSON text (what `--spec run.json` does) — must
    // produce the same fingerprint and bit-identical training
    let train = |spec: Spec| -> (String, Vec<i32>, u64) {
        let session = Session::new(spec).unwrap();
        let fp = session.fingerprint();
        let out = session.train(|_, _, _| Ok(())).unwrap();
        (fp, out.trainer.flat_params(),
         out.trainer.metrics.loss_sum.to_bits())
    };
    let direct = tiny_builder().workers(2).build().unwrap();
    let parsed = Spec::parse(&direct.render()).unwrap();
    let (f1, p1, l1) = train(direct);
    let (f2, p2, l2) = train(parsed);
    assert_eq!(f1, f2, "fingerprint diverged");
    assert_eq!(p1, p2, "parameters diverged");
    assert_eq!(l1, l2, "loss sums diverged");
}

#[test]
fn fingerprint_matches_trainer_and_pins_ckpt_format() {
    // Session::fingerprint == Trainer::fingerprint (no drift between
    // the facade and the checkpoint layer) ...
    let session = Session::new(tiny_builder().build().unwrap()).unwrap();
    assert_eq!(session.fingerprint(),
               session.trainer().unwrap().fingerprint());
    // ... and the format is byte-compatible with pre-Spec SCKP v1
    // checkpoints — this literal is the historical format; a mismatch
    // means existing checkpoints would be refused (migration gate)
    let fc_only = Session::new(
        Spec::builder()
            .net_inline("input 3 8 8\nfc fc 10\nloss hinge")
            .batch(4)
            .lr(0.002)
            .momentum(0.9)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_eq!(
        fc_only.fingerprint(),
        "stratus-ckpt net=custom input=(3, 8, 8) nclass=10 \
         loss=SquareHinge layers=[Fc { name: \"fc\", cin: 192, \
         cout: 10 }] hyper(lr_q16=131,beta_q15=29491,batch=4) \
         dv(pox=8,poy=8,pof=16,clock_mhz=240,dram_gbytes=16.9,\
         dram_efficiency=0.6,load_balance=true,double_buffer=true,\
         tile_rows=8,data_bits=16)"
    );
}

#[test]
fn session_resume_continues_bit_identically() {
    // spec-driven checkpointed run resumed by a freshly parsed spec:
    // equal to the uninterrupted run (params + exact loss sums)
    let dir = tmp_dir("resume");
    let with = |epochs: u64, resume: bool| {
        tiny_builder()
            .epochs(epochs)
            .checkpoint_dir(&dir)
            .checkpoint_every(1)
            .resume(resume)
            .build()
            .unwrap()
    };
    let full = Session::new(tiny_builder().build().unwrap())
        .unwrap()
        .train(|_, _, _| Ok(()))
        .unwrap();
    Session::new(with(1, false))
        .unwrap()
        .train(|_, _, _| Ok(()))
        .unwrap();
    // the resuming session goes through serialize -> parse first, as
    // `stratus train --spec run.json --resume` would
    let resumed_spec = Spec::parse(&with(2, true).render()).unwrap();
    let resumed = Session::new(resumed_spec)
        .unwrap()
        .resume(|_, _, _| Ok(()))
        .unwrap();
    assert_eq!(resumed.start.epoch, 1, "did not resume at epoch 2");
    assert_eq!(full.trainer.flat_params(), resumed.trainer.flat_params());
    assert_eq!(full.trainer.metrics.loss_sum.to_bits(),
               resumed.trainer.metrics.loss_sum.to_bits());
    assert_eq!(full.end, resumed.end);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_conflicts_are_typed_and_pinned() {
    let dir = tmp_dir("conflict");
    Session::new(
        tiny_builder()
            .epochs(1)
            .checkpoint_dir(&dir)
            .build()
            .unwrap(),
    )
    .unwrap()
    .train(|_, _, _| Ok(()))
    .unwrap();
    // conflicting explicit seed
    let err = Session::new(
        tiny_builder()
            .seed(9)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap_err();
    assert!(format!("{err:#}")
                .contains("seed 9 conflicts with the checkpoint's \
                           recorded seed 7"),
            "{err:#}");
    // conflicting explicit epoch width
    let err = Session::new(
        tiny_builder()
            .images(99)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap_err();
    assert!(format!("{err:#}")
                .contains("images 99 conflicts with the checkpoint's \
                           recorded epoch width 12"),
            "{err:#}");
    // dropping the overrides resumes cleanly (recorded values win)
    let ok = Session::new(
        Spec::builder()
            .net_inline(TINY)
            .batch(4)
            .lr(0.02)
            .momentum(0.9)
            .epochs(2)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap();
    assert_eq!(ok.end.epoch, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_a_different_noise() {
    // noise is the one data parameter the cursor does not record, so
    // it rides the fingerprint (appended only when non-default) — a
    // resume that would silently train on different pixels is refused
    let dir = tmp_dir("noise");
    Session::new(
        tiny_builder()
            .epochs(1)
            .noise(0.5)
            .checkpoint_dir(&dir)
            .build()
            .unwrap(),
    )
    .unwrap()
    .train(|_, _, _| Ok(()))
    .unwrap();
    // default-noise spec against the 0.5-noise checkpoint: refused
    let err = Session::new(
        tiny_builder()
            .epochs(2)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    // the matching noise resumes cleanly
    let ok = Session::new(
        tiny_builder()
            .epochs(2)
            .noise(0.5)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap();
    assert_eq!(ok.end.epoch, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resize_accelerators_reshards_the_run() {
    // --resize-accelerators N: the (possibly resumed) trainer is
    // re-sharded onto N instances before the run starts, and the
    // resumed stream stays bit-identical to the never-resized one
    let dir = tmp_dir("resize");
    let spec = tiny_builder()
        .epochs(1)
        .checkpoint_dir(&dir)
        .resize_accelerators(3)
        .build()
        .unwrap();
    let run = Session::new(spec).unwrap().begin(false).unwrap();
    assert_eq!(run.trainer().accelerators, 3);

    // full reference run, unresized and uncheckpointed
    let full = Session::new(tiny_builder().build().unwrap())
        .unwrap()
        .train(|_, _, _| Ok(()))
        .unwrap();
    // stage 1: one epoch at 1 instance; stage 2: resume resized to 4
    Session::new(
        tiny_builder().epochs(1).checkpoint_dir(&dir).build().unwrap(),
    )
    .unwrap()
    .train(|_, _, _| Ok(()))
    .unwrap();
    let resumed = Session::new(
        tiny_builder()
            .checkpoint_dir(&dir)
            .resume(true)
            .resize_accelerators(4)
            .build()
            .unwrap(),
    )
    .unwrap()
    .resume(|_, _, _| Ok(()))
    .unwrap();
    assert_eq!(resumed.trainer.accelerators, 4);
    assert_eq!(full.trainer.flat_params(),
               resumed.trainer.flat_params(),
               "resized resume diverged from the unresized run");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eval_window_derives_from_epoch_width() {
    // the eval set starts right where the training window ends — at
    // ANY epoch width (the old CLI's hardcoded offset 1_000_000
    // collided once --images reached it)
    let spec = tiny_builder().images(8).eval(3).build().unwrap();
    let session = Session::new(spec).unwrap();
    let run = session.begin(false).unwrap();
    assert_eq!(run.train_set().len(), 8);
    assert_eq!(run.eval_set().len(), 3);
    let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
    for (i, s) in run.eval_set().iter().enumerate() {
        let want = data.sample(8 + i as u64);
        assert_eq!(s.image, want.image, "eval[{i}] not at offset 8+{i}");
        assert_eq!(s.label, want.label);
    }
    // an explicit offset clear of the window is honored
    let spec = tiny_builder()
        .images(8)
        .eval(2)
        .eval_offset(100)
        .build()
        .unwrap();
    let run = Session::new(spec).unwrap().begin(false).unwrap();
    assert_eq!(run.eval_set()[0].image, data.sample(100).image);
}

#[test]
fn finished_resume_is_a_no_op() {
    let dir = tmp_dir("finished");
    Session::new(
        tiny_builder()
            .epochs(1)
            .checkpoint_dir(&dir)
            .build()
            .unwrap(),
    )
    .unwrap()
    .train(|_, _, _| Ok(()))
    .unwrap();
    let session = Session::new(
        tiny_builder()
            .epochs(1)
            .checkpoint_dir(&dir)
            .resume(true)
            .build()
            .unwrap(),
    )
    .unwrap();
    let run = session.begin(true).unwrap();
    assert!(run.finished());
    let before = run.trainer().flat_params();
    let mut epochs_seen = 0;
    let out = run
        .execute(|_, _, _| {
            epochs_seen += 1;
            Ok(())
        })
        .unwrap();
    assert_eq!(epochs_seen, 0, "a finished run must not train");
    assert_eq!(out.trainer.flat_params(), before);
    assert_eq!(out.start, out.end);
    let _ = std::fs::remove_dir_all(&dir);
}
