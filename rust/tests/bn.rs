//! End-to-end integer batch-norm coverage (ISSUE 4 acceptance): a BN
//! network parses, compiles, simulates, and trains with loss
//! decreasing; training is bit-identical across every tested
//! workers x accelerators grouping (the BN statistic merge rule rides
//! the same fixed-order accumulator machinery as gradients); and a BN
//! checkpoint kill-and-resume round trip — params, optimizer state,
//! running statistics, metrics — is bit-for-bit identical to never
//! having stopped.

use std::path::PathBuf;

use stratus::ckpt::Cursor;
use stratus::compiler::OpKind;
use stratus::coordinator::{CheckpointPolicy, TrainRun, Trainer};
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

const SEED: u64 = 7;
const BATCH: usize = 4;
const IMAGES: u64 = 12; // 3 batches per epoch
const EPOCHS: u64 = 2;
const KILL_AFTER: u64 = 2;

const TINY_BN_CFG: &str = "\
name tinybn
input 3 8 8
conv c1 8 k3 s1 p1
bn n1 relu
conv c2 8 k3 s1 p1
bn n2 relu
pool p1 2
fc fc 10
loss hinge
";

fn bn_session(workers: usize, accelerators: usize) -> Session {
    let spec = Spec::builder()
        .net_inline(TINY_BN_CFG)
        .batch(BATCH)
        .lr(0.02)
        .momentum(0.9)
        .workers(workers)
        .accelerators(accelerators)
        .build()
        .unwrap();
    Session::new(spec).unwrap()
}

fn trainer(workers: usize, accelerators: usize) -> Trainer {
    bn_session(workers, accelerators).trainer().unwrap()
}

fn tmp_ckpt(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stratus_bn_test_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("ckpt.stratus")
}

/// Everything the BN bit-identity contract covers: parameters, the
/// running statistics, the full optimizer/stat accumulator state, and
/// the deterministic metrics.
#[derive(Debug, PartialEq)]
struct Signature {
    params: Vec<i32>,
    running: Vec<Vec<i32>>,
    grad_accs: Vec<Vec<i32>>,
    momenta: Vec<Vec<i32>>,
    counts: Vec<usize>,
    images: u64,
    batches: u64,
    loss_sum_bits: u64,
}

fn signature(t: &Trainer) -> Signature {
    Signature {
        params: t.flat_params(),
        running: t
            .acc
            .net
            .state_order()
            .iter()
            .map(|n| t.params.get(n).unwrap().data().to_vec())
            .collect(),
        grad_accs: t
            .param_states()
            .iter()
            .map(|(_, s)| s.grad_acc.data().to_vec())
            .collect(),
        momenta: t
            .param_states()
            .iter()
            .map(|(_, s)| s.momentum.data().to_vec())
            .collect(),
        counts: t.param_states().iter().map(|(_, s)| s.count).collect(),
        images: t.metrics.images,
        batches: t.metrics.batches,
        loss_sum_bits: t.metrics.loss_sum.to_bits(),
    }
}

#[test]
fn bn_net_parses_compiles_simulates_and_trains() {
    let session = bn_session(1, 1);
    // compiles with BN steps in the schedule
    let acc = session.compile().unwrap();
    assert!(acc
        .schedule
        .per_image
        .iter()
        .any(|s| s.op == OpKind::BnFp));
    assert!(acc
        .schedule
        .per_image
        .iter()
        .any(|s| s.op == OpKind::BnBp));
    // simulates with nonzero cycles (at the spec's batch size)
    let r = session.simulate().unwrap();
    assert!(r.cycles_per_image() > 0.0);
    // trains with loss decreasing over epochs
    let mut t = trainer(1, 1);
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let batch = data.batch(0, BATCH);
    let first = t.train_batch(&batch).unwrap();
    let mut last = first;
    for _ in 0..8 {
        last = t.train_batch(&batch).unwrap();
    }
    assert!(last < first, "bn loss {first} -> {last}");
    // and the running statistics left their init values
    let rv = t.params.get("rv_n1").unwrap();
    assert!(rv.data().iter().any(|&v| v != 1 << 16),
            "running variance never moved");
}

#[test]
fn bn_training_bit_identical_across_parallelism() {
    // the acceptance grid: {1,2,4} workers x {1,3} accelerators must
    // produce bit-identical params, running stats, optimizer state,
    // and exact loss sums after multiple batches (stats refresh between
    // batches, so divergence would compound and be caught)
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let batch = data.batch(0, 10);
    let mut reference = trainer(1, 1);
    for _ in 0..3 {
        reference.train_batch(&batch).unwrap();
    }
    let want = signature(&reference);
    for &workers in &[1usize, 2, 4] {
        for &accels in &[1usize, 3] {
            if (workers, accels) == (1, 1) {
                continue;
            }
            let mut t = trainer(workers, accels);
            for _ in 0..3 {
                t.train_batch(&batch).unwrap();
            }
            let got = signature(&t);
            assert_eq!(got, want,
                       "{workers}w x {accels}a diverged from 1x1");
        }
    }
}

#[test]
fn bn_kill_and_resume_is_bit_identical() {
    // train K batches, checkpoint, drop the trainer, resume in a fresh
    // one, finish: equal to the uninterrupted run — including the BN
    // running statistics and stat accumulators
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let cfg_plain = TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: None,
        max_batches: None,
    };
    for &(workers, accels) in &[(1usize, 1usize), (2, 3)] {
        let tag = format!("w{workers}a{accels}");
        let mut full = trainer(workers, accels);
        let end = full
            .run(&data, &cfg_plain, Cursor::start(SEED, IMAGES),
                 |_, _| Ok(()))
            .unwrap();
        assert_eq!(end, Cursor { epoch: EPOCHS, batch: 0, seed: SEED,
                                 images: IMAGES });

        let path = tmp_ckpt(&tag);
        let killed_cfg = TrainRun {
            checkpoint: Some(CheckpointPolicy {
                path: path.clone(),
                every_batches: KILL_AFTER,
                resize: None,
            }),
            max_batches: Some(KILL_AFTER),
            ..cfg_plain.clone()
        };
        let mut killed = trainer(workers, accels);
        let stopped = killed
            .run(&data, &killed_cfg, Cursor::start(SEED, IMAGES),
                 |_, _| Ok(()))
            .unwrap();
        assert_eq!(stopped.batch, KILL_AFTER, "{tag}");
        drop(killed); // the "crash"

        let mut resumed = trainer(workers, accels);
        let cur = resumed.resume_from(&path).unwrap();
        assert_eq!(cur, stopped, "{tag}: cursor did not round-trip");
        // the restored running statistics match a fresh partial run
        let mut partial = trainer(workers, accels);
        let partial_cfg = TrainRun {
            max_batches: Some(KILL_AFTER),
            ..cfg_plain.clone()
        };
        partial
            .run(&data, &partial_cfg, Cursor::start(SEED, IMAGES),
                 |_, _| Ok(()))
            .unwrap();
        for name in resumed.acc.net.state_order() {
            assert_eq!(resumed.params.get(&name).unwrap(),
                       partial.params.get(&name).unwrap(),
                       "{tag}: {name} not restored bit-exactly");
        }

        let end2 = resumed
            .run(&data, &cfg_plain, cur, |_, _| Ok(()))
            .unwrap();
        assert_eq!(end2, end);
        assert_eq!(signature(&full), signature(&resumed),
                   "{tag}: resumed run diverged from uninterrupted");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}

#[test]
fn bn_checkpoint_resumes_at_different_parallelism() {
    // a BN checkpoint taken at 1x1 resumes at 4x3: grouping is
    // irrelevant to gradients AND to the statistic merge
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let cfg = TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: None,
        max_batches: None,
    };
    let mut full = trainer(1, 1);
    full.run(&data, &cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(()))
        .unwrap();

    let path = tmp_ckpt("cross");
    let killed_cfg = TrainRun {
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: KILL_AFTER,
            resize: None,
        }),
        max_batches: Some(KILL_AFTER),
        ..cfg.clone()
    };
    let mut killed = trainer(1, 1);
    killed
        .run(&data, &killed_cfg, Cursor::start(SEED, IMAGES),
             |_, _| Ok(()))
        .unwrap();
    drop(killed);

    let mut resumed = trainer(4, 3);
    let cur = resumed.resume_from(&path).unwrap();
    resumed.run(&data, &cfg, cur, |_, _| Ok(())).unwrap();
    assert_eq!(full.flat_params(), resumed.flat_params());
    for name in full.acc.net.state_order() {
        assert_eq!(full.params.get(&name).unwrap(),
                   resumed.params.get(&name).unwrap(),
                   "{name} diverged across parallelism");
    }
    assert_eq!(full.metrics.loss_sum.to_bits(),
               resumed.metrics.loss_sum.to_bits());
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn bn_checkpoint_refuses_plain_topology() {
    // a BN checkpoint must not restore into the bn-free twin (layer
    // list differs => fingerprint differs)
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let path = tmp_ckpt("fpr");
    let cfg = TrainRun {
        epochs: 1,
        images: IMAGES,
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 1,
            resize: None,
        }),
        max_batches: Some(1),
    };
    let mut t = trainer(1, 1);
    t.run(&data, &cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(()))
        .unwrap();

    let plain_spec = Spec::builder()
        .net_inline(
            "name tinybn\ninput 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv \
             c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .batch(BATCH)
        .lr(0.02)
        .momentum(0.9)
        .build()
        .unwrap();
    let mut other =
        Session::new(plain_spec).unwrap().trainer().unwrap();
    let err = other.resume_from(&path).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}
