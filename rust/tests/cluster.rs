//! Cluster equivalence suite (ISSUE 2 acceptance criterion): training
//! data-parallel across N accelerator instances with the ring
//! all-reduce must be a pure performance transform — same seed, same
//! batch stream, any instance count => bit-identical parameters,
//! losses, and optimizer state after every `end_batch`.  Mirrors
//! rust/tests/engine.rs one level up, and checks the simulator's
//! cluster event timeline carries the all-reduce phases.

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::coordinator::Trainer;
use stratus::data::Synthetic;
use stratus::session::{NetSource, Session, Spec};
use stratus::sim::event::simulate_cluster_events;
use stratus::sim::simulate;

/// Session-built trainer: the accelerator-instance count rides in
/// through the spec's design overrides (`DesignVars::cluster`).
fn trainer(src: &NetSource, batch: usize, accelerators: usize,
           workers: usize) -> Trainer {
    let spec = Spec::builder()
        .net(src.clone())
        .batch(batch)
        .lr(0.002)
        .momentum(0.9)
        .accelerators(accelerators)
        .workers(workers)
        .build()
        .unwrap();
    Session::new(spec).unwrap().trainer().unwrap()
}

fn assert_equivalent(src: &NetSource, batch_images: usize,
                     batches: usize, accelerators: usize,
                     workers: usize) {
    let net: Network = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let stream = data.batch(0, batch_images * batches);
    let mut seq = trainer(src, batch_images, 1, 1);
    let mut par = trainer(src, batch_images, accelerators, workers);
    for chunk in stream.chunks(batch_images) {
        let l_seq = seq.train_batch(chunk).unwrap();
        let l_par = par.train_batch(chunk).unwrap();
        assert_eq!(l_seq, l_par,
                   "loss diverged at {accelerators} instances");
    }
    assert_eq!(seq.flat_params(), par.flat_params(),
               "parameters diverged at {accelerators} instances");
    for ((n, s), (_, p)) in
        seq.param_states().iter().zip(par.param_states())
    {
        assert_eq!(s.grad_acc, p.grad_acc, "{n} grad_acc");
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    assert_eq!(seq.metrics.images, par.metrics.images);
    assert_eq!(seq.metrics.loss_sum, par.metrics.loss_sum);
}

fn tiny_net() -> NetSource {
    NetSource::inline(
        "input 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

#[test]
fn tiny_net_four_instances_two_batches() {
    assert_equivalent(&tiny_net(), 8, 2, 4, 1);
}

#[test]
fn tiny_net_uneven_instance_shards() {
    // 10 images over 4 instances -> shards of 3/3/2/2
    assert_equivalent(&tiny_net(), 10, 1, 4, 1);
}

#[test]
fn tiny_net_more_instances_than_batch() {
    assert_equivalent(&tiny_net(), 3, 1, 16, 1);
}

#[test]
fn tiny_net_instances_and_workers_compose() {
    // 2 instances each sharding across 2 worker threads
    assert_equivalent(&tiny_net(), 12, 2, 2, 2);
}

#[test]
fn cifar_1x_two_instances_one_batch() {
    // the paper-scale network (32x32 input, 14 parameter tensors)
    assert_equivalent(&NetSource::preset("1x"), 4, 1, 2, 1);
}

#[test]
fn cluster_report_reflects_ring() {
    let src = tiny_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 5, 0.3);
    let batch = data.batch(0, 10);
    let mut t = trainer(&src, 10, 4, 1);
    t.train_batch(&batch).unwrap();
    let rep = t.last_cluster.as_ref().unwrap();
    assert_eq!(rep.instances, 4);
    assert_eq!(rep.images, 10);
    assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
    assert_eq!(rep.ring_steps, 6); // 2 * (4 - 1)
    assert!(rep.ring_words > 0);
    assert!(rep.wall_seconds >= 0.0);
    // single-instance batches never populate the cluster report
    let mut t1 = trainer(&src, 10, 1, 1);
    t1.train_batch(&batch).unwrap();
    assert!(t1.last_cluster.is_none());
    assert!(t1.last_engine.is_some());
}

#[test]
fn allreduce_cycles_appear_in_event_timeline_and_scale() {
    let net = Network::cifar(1);
    let mut cycles = Vec::new();
    for instances in [1usize, 2, 4, 8] {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = instances;
        let acc = RtlCompiler::default().compile(&net, &dv).unwrap();
        let ev = simulate_cluster_events(&acc, 40);
        let ring: Vec<_> = ev
            .events
            .iter()
            .filter(|e| e.label.starts_with("allreduce/"))
            .collect();
        let expected = if instances > 1 { 2 * (instances - 1) } else { 0 };
        assert_eq!(ring.len(), expected, "{instances} instances");
        assert_eq!(ev.allreduce_cycles,
                   ring.iter().map(|e| e.end - e.start).sum::<u64>());
        // the timeline agrees with the analytic cluster projection
        let r = simulate(&acc, 40);
        assert_eq!(ev.allreduce_cycles, r.allreduce.latency_cycles);
        cycles.push(ev.allreduce_cycles);
    }
    assert_eq!(cycles[0], 0);
    assert!(cycles[1] > 0);
    assert!(cycles.windows(2).skip(1).all(|w| w[0] < w[1]),
            "all-reduce cycles not scaling with N: {cycles:?}");
}

#[test]
fn cluster_simulated_time_beats_sequential() {
    // the whole point: 4 instances finish a batch in fewer simulated
    // cycles than 1, even after paying for the ring
    let src = tiny_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 9, 0.3);
    let batch = data.batch(0, 8);
    let mut seq = trainer(&src, 8, 1, 1);
    let mut par = trainer(&src, 8, 4, 1);
    seq.train_batch(&batch).unwrap();
    par.train_batch(&batch).unwrap();
    assert!(par.metrics.sim_cycles < seq.metrics.sim_cycles,
            "cluster {} !< sequential {}", par.metrics.sim_cycles,
            seq.metrics.sim_cycles);
    assert!(par.metrics.sim_cycles > 0.0);
}
