//! Cluster equivalence suite (ISSUE 2 acceptance criterion): training
//! data-parallel across N accelerator instances with the ring
//! all-reduce must be a pure performance transform — same seed, same
//! batch stream, any instance count => bit-identical parameters,
//! losses, and optimizer state after every `end_batch`.  Mirrors
//! rust/tests/engine.rs one level up, and checks the simulator's
//! cluster event timeline carries the all-reduce phases.

use stratus::ckpt::Cursor;
use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network, Topology};
use stratus::coordinator::{CheckpointPolicy, TrainRun, Trainer};
use stratus::data::Synthetic;
use stratus::session::{NetSource, Session, Spec};
use stratus::sim::event::simulate_cluster_events;
use stratus::sim::simulate;

/// Session-built trainer: the accelerator-instance count and collective
/// topology ride in through the spec's design overrides.
fn trainer_topo(src: &NetSource, batch: usize, accelerators: usize,
                workers: usize, topology: Topology) -> Trainer {
    let spec = Spec::builder()
        .net(src.clone())
        .batch(batch)
        .lr(0.002)
        .momentum(0.9)
        .accelerators(accelerators)
        .workers(workers)
        .topology(topology)
        .build()
        .unwrap();
    Session::new(spec).unwrap().trainer().unwrap()
}

fn trainer(src: &NetSource, batch: usize, accelerators: usize,
           workers: usize) -> Trainer {
    trainer_topo(src, batch, accelerators, workers, Topology::Ring)
}

fn assert_equivalent_topo(src: &NetSource, batch_images: usize,
                          batches: usize, accelerators: usize,
                          workers: usize, topology: Topology) {
    let net: Network = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let stream = data.batch(0, batch_images * batches);
    let mut seq = trainer(src, batch_images, 1, 1);
    let mut par =
        trainer_topo(src, batch_images, accelerators, workers, topology);
    for chunk in stream.chunks(batch_images) {
        let l_seq = seq.train_batch(chunk).unwrap();
        let l_par = par.train_batch(chunk).unwrap();
        assert_eq!(l_seq, l_par,
                   "loss diverged at {accelerators} instances");
    }
    assert_eq!(seq.flat_params(), par.flat_params(),
               "parameters diverged at {accelerators} instances");
    for ((n, s), (_, p)) in
        seq.param_states().iter().zip(par.param_states())
    {
        assert_eq!(s.grad_acc, p.grad_acc, "{n} grad_acc");
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    assert_eq!(seq.metrics.images, par.metrics.images);
    assert_eq!(seq.metrics.loss_sum, par.metrics.loss_sum);
}

fn assert_equivalent(src: &NetSource, batch_images: usize,
                     batches: usize, accelerators: usize,
                     workers: usize) {
    assert_equivalent_topo(src, batch_images, batches, accelerators,
                           workers, Topology::Ring);
}

fn tiny_net() -> NetSource {
    NetSource::inline(
        "input 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

fn tiny_bn_net() -> NetSource {
    NetSource::inline(
        "input 3 8 8\nconv c1 8 k3 s1 p1\nbn n1 relu\nconv c2 8 k3 s1 \
         p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

#[test]
fn tiny_net_four_instances_two_batches() {
    assert_equivalent(&tiny_net(), 8, 2, 4, 1);
}

#[test]
fn tiny_net_uneven_instance_shards() {
    // 10 images over 4 instances -> shards of 3/3/2/2
    assert_equivalent(&tiny_net(), 10, 1, 4, 1);
}

#[test]
fn tiny_net_more_instances_than_batch() {
    assert_equivalent(&tiny_net(), 3, 1, 16, 1);
}

#[test]
fn tiny_net_instances_and_workers_compose() {
    // 2 instances each sharding across 2 worker threads
    assert_equivalent(&tiny_net(), 12, 2, 2, 2);
}

#[test]
fn cifar_1x_two_instances_one_batch() {
    // the paper-scale network (32x32 input, 14 parameter tensors)
    assert_equivalent(&NetSource::preset("1x"), 4, 1, 2, 1);
}

#[test]
fn cluster_report_reflects_ring() {
    let src = tiny_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 5, 0.3);
    let batch = data.batch(0, 10);
    let mut t = trainer(&src, 10, 4, 1);
    t.train_batch(&batch).unwrap();
    let rep = t.last_cluster.as_ref().unwrap();
    assert_eq!(rep.instances, 4);
    assert_eq!(rep.images, 10);
    assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
    assert_eq!(rep.ring_steps, 6); // 2 * (4 - 1)
    assert!(rep.ring_words > 0);
    assert!(rep.wall_seconds >= 0.0);
    // single-instance batches never populate the cluster report
    let mut t1 = trainer(&src, 10, 1, 1);
    t1.train_batch(&batch).unwrap();
    assert!(t1.last_cluster.is_none());
    assert!(t1.last_engine.is_some());
}

#[test]
fn allreduce_cycles_appear_in_event_timeline_and_scale() {
    let net = Network::cifar(1);
    let mut cycles = Vec::new();
    for instances in [1usize, 2, 4, 8] {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = instances;
        let acc = RtlCompiler::default().compile(&net, &dv).unwrap();
        let ev = simulate_cluster_events(&acc, 40);
        let ring: Vec<_> = ev
            .events
            .iter()
            .filter(|e| e.label.starts_with("allreduce/"))
            .collect();
        let expected = if instances > 1 { 2 * (instances - 1) } else { 0 };
        assert_eq!(ring.len(), expected, "{instances} instances");
        assert_eq!(ev.allreduce_cycles,
                   ring.iter().map(|e| e.end - e.start).sum::<u64>());
        // the timeline agrees with the analytic cluster projection
        let r = simulate(&acc, 40);
        assert_eq!(ev.allreduce_cycles, r.allreduce.latency_cycles);
        cycles.push(ev.allreduce_cycles);
    }
    assert_eq!(cycles[0], 0);
    assert!(cycles[1] > 0);
    assert!(cycles.windows(2).skip(1).all(|w| w[0] < w[1]),
            "all-reduce cycles not scaling with N: {cycles:?}");
}

#[test]
fn hier_64_instances_bit_identical_to_one() {
    // the ISSUE 8 acceptance sweep: a 64-accelerator hierarchical
    // all-reduce (8x8 groups, or whatever divisor the compiler picks)
    // trains bit-identically to a single instance
    assert_equivalent_topo(&tiny_net(), 8, 2, 64, 1, Topology::Hier);
}

#[test]
fn hier_64_instances_bn_net_bit_identical() {
    // bn nets merge statistic accumulators alongside gradients — the
    // grouped collective must re-shard those identically too
    assert_equivalent_topo(&tiny_bn_net(), 6, 1, 64, 1, Topology::Hier);
}

#[test]
fn auto_topology_is_bit_identical_at_16() {
    // whatever plan auto resolves to, training must not notice
    assert_equivalent_topo(&tiny_net(), 8, 1, 16, 1, Topology::Auto);
}

#[test]
fn hier_composes_with_workers() {
    assert_equivalent_topo(&tiny_net(), 12, 1, 4, 2, Topology::Hier);
}

#[test]
fn elastic_resize_chain_matches_unresized() {
    // kill-resize-resume chain (ISSUE 8 satellite): train at 1
    // instance, kill; resume the checkpoint at 4 (hier), kill; resume
    // at 2 to completion.  Every stage re-shards the same batch stream,
    // so the final state is bit-identical to the uninterrupted
    // single-instance run.
    let dir = std::env::temp_dir().join(format!(
        "stratus-elastic-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("elastic.ckpt");
    let src = tiny_net();
    let net = src.resolve().unwrap();
    const IMAGES: u64 = 24;
    const BATCH: usize = 4;
    const EPOCHS: u64 = 2;
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let cfg = |max_batches: Option<u64>| TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 1,
            resize: None,
        }),
        max_batches,
    };

    // reference: uninterrupted, never resized, no checkpointing
    let mut reference = trainer(&src, BATCH, 1, 1);
    let plain = TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: None,
        max_batches: None,
    };
    reference
        .run(&data, &plain, Cursor::start(77, IMAGES), |_, _| Ok(()))
        .unwrap();

    // stage 1: single instance, 3 batches, then "killed"
    let mut t1 = trainer(&src, BATCH, 1, 1);
    t1.run(&data, &cfg(Some(3)), Cursor::start(77, IMAGES),
           |_, _| Ok(()))
        .unwrap();
    drop(t1);

    // stage 2: resume onto 4 instances with the grouped collective
    let mut t4 =
        trainer_topo(&src, BATCH, 1, 1, Topology::Hier)
            .with_accelerators(4);
    let cur = t4.resume_from(&path).unwrap();
    assert_eq!(cur.batch, 3);
    t4.run(&data, &cfg(Some(4)), cur, |_, _| Ok(())).unwrap();
    drop(t4);

    // stage 3: resume onto 2 instances and finish the run
    let mut t2 = trainer(&src, BATCH, 1, 1).with_accelerators(2);
    let cur = t2.resume_from(&path).unwrap();
    let end = t2.run(&data, &cfg(None), cur, |_, _| Ok(())).unwrap();
    assert_eq!(end.epoch, EPOCHS);

    assert_eq!(reference.flat_params(), t2.flat_params(),
               "elastic chain diverged from the unresized run");
    for ((n, s), (_, p)) in
        reference.param_states().iter().zip(t2.param_states())
    {
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn cluster_simulated_time_beats_sequential() {
    // the whole point: 4 instances finish a batch in fewer simulated
    // cycles than 1, even after paying for the ring
    let src = tiny_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 9, 0.3);
    let batch = data.batch(0, 8);
    let mut seq = trainer(&src, 8, 1, 1);
    let mut par = trainer(&src, 8, 4, 1);
    seq.train_batch(&batch).unwrap();
    par.train_batch(&batch).unwrap();
    assert!(par.metrics.sim_cycles < seq.metrics.sim_cycles,
            "cluster {} !< sequential {}", par.metrics.sim_cycles,
            seq.metrics.sim_cycles);
    assert!(par.metrics.sim_cycles > 0.0);
}
