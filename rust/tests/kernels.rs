//! Tiled-vs-reference kernel bit-identity (ISSUE 7 tentpole).
//!
//! The tiled hot-path kernels in `nn::{conv, fc, pool}` claim exact
//! bit-identity with the scalar oracles in `nn::reference` — same
//! wrapping i32 accumulation per output element in the same term
//! order.  This suite enforces the claim two ways:
//!
//! 1. property sweeps over randomized shapes, paddings, and amplitudes
//!    (including fully saturated inputs, where wrapping actually
//!    happens) for every kernel, and
//! 2. a fixed-seed end-to-end pin: a whole train batch stepped by a
//!    hand-rolled reference-kernel loop through the public engine must
//!    leave parameters bit-identical to the tiled
//!    [`Trainer`](stratus::coordinator) at every worker × accelerator
//!    grouping.
//!
//! Stride is fixed at 1 throughout — the layer grammar admits only
//! `s1` (config::Network::parse), so there is no stride axis to sweep.

use anyhow::Result;
use stratus::config::Network;
use stratus::data::{Sample, Synthetic};
use stratus::engine::{self, StepOut};
use stratus::fixed::{SHIFT_CONV_BP, SHIFT_CONV_FP};
use stratus::nn::init::init_params;
use stratus::nn::loss::{encode_label, loss_grad};
use stratus::nn::pool::{relu_mask, scale_mask};
use stratus::nn::sgd::{ParamKind, ParamState, SgdHyper};
use stratus::nn::tensor::Tensor;
use stratus::nn::testutil::{randi, Lcg};
use stratus::nn::{conv, fc, pool, reference, Scratch};
use stratus::session::{Session, Spec};

/// Kernel sizes the conv generators draw from (odd, like the grammar).
const KS: [usize; 3] = [1, 3, 5];

/// Random conv-like spatial extent guaranteeing at least one output
/// pixel: `h + 2*pad - k + 1 >= 1`.
fn rand_hw(rng: &mut Lcg, k: usize, pad: usize) -> usize {
    (k.saturating_sub(2 * pad)).max(1) + rng.below(8) as usize
}

/// Every 5th case runs fully saturated so the wrapping adds actually
/// wrap; otherwise activation-scale amplitudes.
fn amp_for(case: usize) -> i32 {
    if case % 5 == 0 { 32767 } else { 900 }
}

#[test]
fn conv_fp_tiled_matches_reference_over_random_shapes() {
    let mut rng = Lcg::new(101);
    let mut s = Scratch::new();
    for case in 0..60 {
        let k = KS[rng.below(3) as usize];
        let pad = rng.below(3) as usize;
        let nif = 1 + rng.below(5) as usize;
        // up to 9 output channels crosses the OFB = 4 register block
        // boundary with a remainder
        let nof = 1 + rng.below(9) as usize;
        let h = rand_hw(&mut rng, k, pad);
        let w = rand_hw(&mut rng, k, pad);
        let amp = amp_for(case);
        let x = randi(&mut rng, &[nif, h, w], amp);
        let wt = randi(&mut rng, &[nof, nif, k, k], amp.min(4000));
        let b: Vec<i32> =
            (0..nof).map(|_| rng.int_pm(1 << 20)).collect();
        let relu = rng.below(2) == 0;
        let shift =
            if case % 2 == 0 { SHIFT_CONV_FP } else { SHIFT_CONV_BP };
        let want = reference::conv_fp(&x, &wt, &b, pad, relu, shift);
        let got = conv::conv_fp(&x, &wt, &b, pad, relu, shift);
        assert_eq!(got, want,
                   "conv_fp case {case}: k={k} pad={pad} nif={nif} \
                    nof={nof} h={h} w={w} amp={amp}");
        // the scratch-reusing variant must agree too (dirty buffers
        // from previous cases must be fully overwritten)
        let got_s =
            conv::conv_fp_s(&x, &wt, &b, pad, relu, shift, &mut s);
        assert_eq!(got_s, want, "conv_fp_s case {case}");
    }
}

#[test]
fn conv_bp_tiled_matches_reference_over_random_shapes() {
    let mut rng = Lcg::new(202);
    let mut s = Scratch::new();
    for case in 0..40 {
        let k = KS[rng.below(3) as usize];
        let pad = rng.below(3) as usize;
        let nif = 1 + rng.below(6) as usize;
        let nof = 1 + rng.below(6) as usize;
        let h = rand_hw(&mut rng, k, pad);
        let w = rand_hw(&mut rng, k, pad);
        let amp = amp_for(case);
        let g = randi(&mut rng, &[nof, h, w], amp);
        let wt = randi(&mut rng, &[nof, nif, k, k], amp.min(4000));
        let want = reference::conv_bp(&g, &wt, pad);
        assert_eq!(conv::conv_bp(&g, &wt, pad), want,
                   "conv_bp case {case}: k={k} pad={pad}");
        // cached-flip variant: unique key per case, exercised twice so
        // the second call replays the cache
        let key = format!("w{case}");
        assert_eq!(conv::conv_bp_s(&g, &wt, &key, pad, &mut s), want);
        assert_eq!(conv::conv_bp_s(&g, &wt, &key, pad, &mut s), want);
        // invalidation forces a recompute to the same result
        s.invalidate();
        assert_eq!(conv::conv_bp_s(&g, &wt, &key, pad, &mut s), want);
    }
}

#[test]
fn conv_wu_tiled_matches_reference_over_random_shapes() {
    let mut rng = Lcg::new(303);
    let mut s = Scratch::new();
    for case in 0..40 {
        // WU geometry: k = 2*pad + 1, gradient plane same spatial
        // extent as the input
        let pad = rng.below(3) as usize;
        let nif = 1 + rng.below(5) as usize;
        let nof = 1 + rng.below(5) as usize;
        let h = 1 + rng.below(8) as usize;
        let w = 1 + rng.below(8) as usize;
        let amp = amp_for(case);
        let x = randi(&mut rng, &[nif, h, w], amp);
        let mut g = randi(&mut rng, &[nof, h, w], amp);
        // pool-style sparsity exercises the zero-skip path
        for v in g.data_mut() {
            if rng.below(4) == 0 {
                *v = 0;
            }
        }
        let (dw_want, db_want) = reference::conv_wu(&x, &g, pad);
        let (dw, db) = conv::conv_wu(&x, &g, pad);
        assert_eq!(dw, dw_want, "conv_wu case {case}: pad={pad}");
        assert_eq!(db, db_want, "conv_wu db case {case}");
        let (dw_s, db_s) = conv::conv_wu_s(&x, &g, pad, &mut s);
        assert_eq!((dw_s, db_s), (dw, db), "conv_wu_s case {case}");
    }
}

#[test]
fn fc_tiled_matches_reference_over_random_shapes() {
    let mut rng = Lcg::new(404);
    for case in 0..60 {
        // n up to 9 crosses the RB = 4 row block with remainders
        let n = 1 + rng.below(9) as usize;
        let k = 1 + rng.below(40) as usize;
        let amp = amp_for(case);
        let x: Vec<i32> = (0..k).map(|_| rng.int_pm(amp)).collect();
        let w = randi(&mut rng, &[n, k], amp.min(4000));
        let b: Vec<i32> =
            (0..n).map(|_| rng.int_pm(1 << 20)).collect();
        let g: Vec<i32> = (0..n).map(|_| rng.int_pm(amp)).collect();
        assert_eq!(fc::fc_fp(&x, &w, &b), reference::fc_fp(&x, &w, &b),
                   "fc_fp case {case}: n={n} k={k} amp={amp}");
        assert_eq!(fc::fc_bp(&g, &w), reference::fc_bp(&g, &w),
                   "fc_bp case {case}: n={n} k={k}");
        assert_eq!(fc::fc_wu(&g, &x), reference::fc_wu(&g, &x),
                   "fc_wu case {case}: n={n} k={k}");
    }
}

#[test]
fn pool_kernels_match_reference_including_ties() {
    let mut rng = Lcg::new(505);
    for case in 0..30 {
        let k = 2 + rng.below(2) as usize;
        let c = 1 + rng.below(4) as usize;
        let oh = 1 + rng.below(4) as usize;
        let ow = 1 + rng.below(4) as usize;
        let (h, w) = (oh * k, ow * k);
        // every 3rd case is all-constant: the strict-> first-max
        // tie-break must pick identical indices on both sides
        let x = if case % 3 == 0 {
            Tensor::from_vec(&[c, h, w], vec![7; c * h * w])
        } else {
            randi(&mut rng, &[c, h, w], amp_for(case))
        };
        let (p_want, i_want) = reference::maxpool(&x, k);
        let (p, i) = pool::maxpool(&x, k);
        assert_eq!(p, p_want, "maxpool case {case}: k={k}");
        assert_eq!(i, i_want, "maxpool idx case {case}: k={k}");
        let g = randi(&mut rng, &[c, oh, ow], amp_for(case));
        let mask = relu_mask(&randi(&mut rng, &[c, h, w], 100));
        assert_eq!(pool::upsample_scale(&g, &i, &mask, k),
                   reference::upsample_scale(&g, &i_want, &mask, k),
                   "upsample case {case}: k={k}");
    }
}

#[test]
fn saturated_extremes_stay_bit_identical() {
    // randi cannot emit i32::MIN-style extremes; build the worst-case
    // alternating pattern by hand so the wrapped sums really wrap
    let pat = |n: usize, a: i32, b: i32| -> Vec<i32> {
        (0..n).map(|i| if i % 2 == 0 { a } else { b }).collect()
    };
    let x = Tensor::from_vec(&[2, 6, 6], pat(72, 32767, -32768));
    let w = Tensor::from_vec(&[3, 2, 3, 3], pat(54, -32768, 32767));
    let b = vec![i32::MAX, i32::MIN, 0];
    assert_eq!(
        conv::conv_fp(&x, &w, &b, 1, false, SHIFT_CONV_FP),
        reference::conv_fp(&x, &w, &b, 1, false, SHIFT_CONV_FP)
    );
    let g = Tensor::from_vec(&[3, 6, 6], pat(108, 32767, -32768));
    assert_eq!(conv::conv_bp(&g, &w, 1), reference::conv_bp(&g, &w, 1));
    assert_eq!(conv::conv_wu(&x, &g, 1), reference::conv_wu(&x, &g, 1));
    let fx = pat(33, 32767, -32768);
    let fw = Tensor::from_vec(&[5, 33], pat(165, -32768, 32767));
    let fb = pat(5, i32::MAX, i32::MIN);
    let fg = pat(5, 32767, -32768);
    assert_eq!(fc::fc_fp(&fx, &fw, &fb),
               reference::fc_fp(&fx, &fw, &fb));
    assert_eq!(fc::fc_bp(&fg, &fw), reference::fc_bp(&fg, &fw));
    assert_eq!(fc::fc_wu(&fg, &fx), reference::fc_wu(&fg, &fx));
}

// ---------------------------------------------------------------------
// End-to-end pin: reference-kernel train loop vs the tiled Trainer
// ---------------------------------------------------------------------

const NET: &str = "input 3 8 8\nconv c1 4 k3 s1 p1 relu\n\
                   conv c2 4 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                   loss hinge";

/// One per-image train step built *only* from the scalar reference
/// kernels — the pre-tiling golden model, hand-rolled for `NET` (conv
/// → conv → pool → fc, both convs with fused ReLU, pool without).
fn reference_step(net: &Network,
                  params: &stratus::nn::golden::Params,
                  s: &Sample) -> Result<StepOut> {
    let y = encode_label(s.label, net.nclass);
    let w1 = params.get("w_c1")?;
    let b1 = params.get("b_c1")?;
    let w2 = params.get("w_c2")?;
    let b2 = params.get("b_c2")?;
    let wf = params.get("w_fc")?;
    let bf = params.get("b_fc")?;
    // FP
    let a1 = reference::conv_fp_std(&s.image, w1, b1.data(), true);
    let a2 = reference::conv_fp_std(&a1, w2, b2.data(), true);
    let (p, idx) = reference::maxpool(&a2, 2);
    let flat = p.data().to_vec();
    let logits = reference::fc_fp(&flat, wf, bf.data());
    let (g_out, loss) = loss_grad(net.loss, &logits, &y);
    // BP + WU (the pool fuses no ReLU, so fc applies no mask; the
    // pool's upsampler applies c2's, and c1's rides the conv-bp scale)
    let (dw_fc, db_fc) = reference::fc_wu(&g_out, &flat);
    let g_flat = reference::fc_bp(&g_out, wf);
    let g3 = Tensor::from_vec(p.shape(), g_flat);
    let g2 = reference::upsample_scale(&g3, &idx, &relu_mask(&a2), 2);
    let (dw2, db2) = reference::conv_wu(&a1, &g2, 1);
    let g1 = scale_mask(&reference::conv_bp(&g2, w2, 1),
                        &relu_mask(&a1));
    let (dw1, db1) = reference::conv_wu(&s.image, &g1, 1);
    let mut grads = std::collections::HashMap::new();
    grads.insert("w_c1".to_string(), dw1);
    grads.insert("b_c1".to_string(),
                 Tensor::from_vec(&[db1.len()], db1));
    grads.insert("w_c2".to_string(), dw2);
    grads.insert("b_c2".to_string(),
                 Tensor::from_vec(&[db2.len()], db2));
    grads.insert("w_fc".to_string(), dw_fc);
    grads.insert("b_fc".to_string(),
                 Tensor::from_vec(&[db_fc.len()], db_fc));
    let gs = net
        .param_order()
        .iter()
        .map(|n| grads.remove(n).expect("grad emitted"))
        .collect();
    Ok(StepOut { loss, grads: gs })
}

#[test]
fn train_loop_pins_scalar_vs_tiled_across_groupings() {
    let (batch_n, lr, momentum) = (12, 0.02, 0.9);
    let net = Network::parse(NET).unwrap();
    let batch = Synthetic::new(10, (3, 8, 8), 41, 0.3).batch(0, batch_n);

    // reference side: sequential engine run over the scalar kernels,
    // from the same seed-1234 init the golden Trainer uses, with the
    // same end-of-batch SGD application
    let mut params = init_params(&net, 1234);
    let mut states: Vec<(String, ParamState)> = net
        .param_order()
        .into_iter()
        .map(|name| {
            let kind = if name.starts_with("w_") {
                ParamKind::Weight
            } else {
                ParamKind::Bias
            };
            let shape =
                params.get(&name).unwrap().shape().to_vec();
            (name, ParamState::new(kind, &shape))
        })
        .collect();
    let step = |s: &Sample, _: &mut Scratch| -> Result<StepOut> {
        reference_step(&net, &params, s)
    };
    let (ref_loss, _) =
        engine::run_batch(&batch, 1, &mut states, &step).unwrap();
    let hyper = SgdHyper::new(lr, momentum, batch_n);
    for (name, st) in &mut states {
        st.apply(params.get_mut(name).unwrap(), &hyper);
    }
    let ref_flat: Vec<i32> = net
        .param_order()
        .iter()
        .flat_map(|n| params.get(n).unwrap().data().to_vec())
        .collect();

    // tiled side: the public Session/Trainer path at every grouping
    for workers in [1usize, 2, 4] {
        for accelerators in [1usize, 3] {
            let spec = Spec::builder()
                .net_inline(NET)
                .batch(batch_n)
                .lr(lr)
                .momentum(momentum)
                .workers(workers)
                .accelerators(accelerators)
                .build()
                .unwrap();
            let mut t =
                Session::new(spec).unwrap().trainer().unwrap();
            let loss = t.train_batch(&batch).unwrap();
            assert!(
                (loss - ref_loss as f64 / batch_n as f64).abs() < 1e-9,
                "loss diverged at {workers}w/{accelerators}a"
            );
            assert_eq!(
                t.flat_params(),
                ref_flat,
                "params diverged from the scalar-kernel loop at \
                 {workers} workers x {accelerators} accelerators"
            );
        }
    }
}
