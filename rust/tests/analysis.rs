//! Adversarial soundness tests for the static fixed-point range
//! analyzer: drive the golden kernels with fully ±i16-saturated inputs
//! while an i64 mirror of each accumulation chain records the peak
//! magnitude actually reached, and assert the observed peak never
//! exceeds the bound the layer's `AccContract` promises.  Plus the
//! regression the analyzer exists for: the pre-PR-4 BN moment layout
//! must be rediscovered as overflow-possible, and the spec gate must
//! refuse a provably wrapping batch size with a typed error naming the
//! layer.

use stratus::analysis::{analyze, analyze_model, Model, I32_SAFE};
use stratus::config::{DesignVars, Layer, Network};
use stratus::fixed::{
    requant, shift_round, SHIFT_CONV_FP, SHIFT_WU_STORE,
};
use stratus::nn::bn::{image_stats, FQ_SHIFT};
use stratus::nn::conv::{conv_fp, conv_wu};
use stratus::nn::fc::fc_fp;
use stratus::nn::tensor::Tensor;
use stratus::ops;
use stratus::session::Spec;

/// The contract rows of one layer, keyed by accumulator tag.
fn contract(l: &Layer, acc: &str) -> ops::AccContract {
    ops::for_layer(l)
        .range_contracts(l)
        .into_iter()
        .find(|c| c.acc == acc)
        .unwrap_or_else(|| panic!("no `{acc}` contract on {}", l.name()))
}

#[test]
fn conv_fp_saturated_peak_within_contract() {
    // worst case: every activation at i16::MIN, every weight at
    // i16::MAX, bias at the SGD clamp — all taps push one direction
    let (cin, cout, h, w, k, pad) = (2, 3, 4, 4, 3, 1);
    let l = Layer::Conv {
        name: "cx".into(),
        cin,
        cout,
        h,
        w,
        k,
        pad,
        stride: 1,
        relu: false,
    };
    let c = contract(&l, "fp-mac");
    let x = Tensor::from_vec(&[cin, h, w], vec![-32768; cin * h * w]);
    let wt = Tensor::from_vec(&[cout, cin, k, k],
                              vec![32767; cout * cin * k * k]);
    let b = vec![-(1 << 28); cout];

    // i64 mirror of conv_fp's accumulation, tracking the running peak
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let mut peak: i64 = 0;
    let mut mirror = vec![0i64; h * w];
    for of in 0..cout {
        for m in mirror.iter_mut() {
            *m = i64::from(b[of]);
        }
        for ci in 0..cin {
            for ky in 0..k {
                for kx in 0..k {
                    let tap = i64::from(wt.at4(of, ci, ky, kx));
                    for oy in 0..h {
                        for ox in 0..w {
                            let xv = xp.data()
                                [(ci * hp + oy + ky) * wp + kx + ox];
                            let m = &mut mirror[oy * w + ox];
                            *m += tap * i64::from(xv);
                            peak = peak.max(m.unsigned_abs() as i64);
                        }
                    }
                }
            }
        }
        // the mirror, wrapped to i32 and requantized, must reproduce
        // the kernel exactly — otherwise the mirror proves nothing
        let out = conv_fp(&x, &wt, &b, pad, false, SHIFT_CONV_FP);
        for oy in 0..h {
            for ox in 0..w {
                let wrapped = mirror[oy * w + ox] as i32;
                assert_eq!(out.at3(of, oy, ox),
                           requant(wrapped, SHIFT_CONV_FP));
            }
        }
    }
    assert!(peak > 0);
    assert!(
        peak <= c.per_image_raw,
        "observed fp-mac peak {peak} exceeds predicted {}",
        c.per_image_raw
    );
}

#[test]
fn conv_wu_saturated_peaks_within_contracts() {
    let (cin, cout, h, w, k, pad) = (2, 2, 6, 6, 3, 1);
    let l = Layer::Conv {
        name: "cx".into(),
        cin,
        cout,
        h,
        w,
        k,
        pad,
        stride: 1,
        relu: false,
    };
    let wu = contract(&l, "wu-mac");
    let bg = contract(&l, "bgrad-sum");
    let x = Tensor::from_vec(&[cin, h, w], vec![-32768; cin * h * w]);
    let g = Tensor::from_vec(&[cout, h, w], vec![32767; cout * h * w]);

    // i64 mirror of the center-tap chain (ky = kx = pad: every output
    // pixel overlaps a real input pixel, the worst chain of the pass)
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let mut acc: i64 = 0;
    let mut peak: i64 = 0;
    for y in 0..h {
        for xx in 0..w {
            let gv = i64::from(g.at3(0, y, xx));
            let xv = i64::from(xp.data()[(y + pad) * wp + pad + xx]);
            acc += gv * xv;
            peak = peak.max(acc.unsigned_abs() as i64);
        }
    }
    assert!(
        peak <= wu.per_image_raw,
        "observed wu-mac peak {peak} exceeds predicted {}",
        wu.per_image_raw
    );
    // the kernel's center tap equals the wrapped, store-shifted mirror
    let (dw, db) = conv_wu(&x, &g, pad);
    assert_eq!(dw.at4(0, 0, pad, pad),
               shift_round(acc as i32, SHIFT_WU_STORE));

    // bias-gradient sum: h·w saturated gradients per image
    let observed_db: i64 = (0..h * w)
        .map(|i| i64::from(g.data()[i]))
        .sum();
    assert!(observed_db.abs() <= bg.per_image_raw);
    assert_eq!(db[0], observed_db as i32, "no wrap expected here");
}

#[test]
fn fc_saturated_peak_within_contract() {
    let (cin, cout) = (64, 10);
    let l = Layer::Fc { name: "fc".into(), cin, cout };
    let c = contract(&l, "fp-mac");
    let x = vec![-32768; cin];
    let wt = Tensor::from_vec(&[cout, cin], vec![32767; cout * cin]);
    let b = vec![-(1 << 28); cout];
    let mut acc: i64 = 0;
    let mut peak: i64 = 0;
    for &xv in &x {
        acc += i64::from(xv) * 32767;
        peak = peak.max(acc.unsigned_abs() as i64);
    }
    acc += i64::from(b[0]);
    peak = peak.max(acc.unsigned_abs() as i64);
    assert!(
        peak <= c.per_image_raw,
        "observed fc fp-mac peak {peak} exceeds predicted {}",
        c.per_image_raw
    );
    // faithfulness: the kernel output is the wrapped mirror, requantized
    let out = fc_fp(&x, &wt, &b);
    assert_eq!(out[0], requant(acc as i32, SHIFT_CONV_FP));
}

#[test]
fn bn_saturated_statistics_within_contracts() {
    let (ch, h, w) = (1, 8, 8);
    let l = Layer::Bn { name: "nx".into(), c: ch, h, w, relu: true };
    let mean_c = contract(&l, "mean-sum");
    let mom_c = contract(&l, "moment-sum");
    // a fully saturated image is the worst statistic producer
    let x = Tensor::from_vec(&[ch, h, w], vec![-32768; ch * h * w]);
    let (m, q) = image_stats(&x);
    let observed_mean = i64::from(m.data()[0]).abs();
    let observed_moment = i64::from(q.data()[0]);
    assert!(observed_mean <= mean_c.per_image_stored());
    assert!(observed_moment <= mom_c.per_image_stored());
    // the analyzer's exact moment bound: 2^(2·16-2) >> FQ_SHIFT
    assert_eq!(mom_c.per_image_stored(), 1 << (30 - FQ_SHIFT));
    // and its first-wrap arithmetic: 127 worst images fit, 128 do not
    let per = mom_c.per_image_stored();
    assert!(127 * per <= I32_SAFE);
    assert!(128 * per > I32_SAFE);
}

#[test]
fn analyzer_rediscovers_the_pre_pr4_bn_overflow() {
    let net = Network::cifar_bn(1);
    let dv = DesignVars::for_scale(1);
    // as shipped: the moment sum is the binding constraint, first
    // wrapping at exactly 128 worst-case images
    assert_eq!(analyze(&net, &dv, 127).overflow_count(), 0);
    let report = analyze(&net, &dv, 128);
    let row = report.first_overflow().expect("flagged at 128");
    assert_eq!(row.acc, "moment-sum");
    assert_eq!(row.layer, "n1");
    assert!(row.verdict.label().contains("overflow-possible(>= 128"));
    // pre-PR-4 layout (moments stored at full 2·FA, no headroom
    // shift): wraps at 2 saturated images — the bug the analyzer
    // exists to catch before it ships again
    let legacy = Model { bn_moment_shift: 0 };
    let flagged = analyze_model(&net, &dv, 128, &legacy);
    let row = flagged.first_overflow().expect("legacy layout flagged");
    assert!(row.verdict.label().contains("overflow-possible(>= 2"));
}

#[test]
fn spec_gate_refuses_wrapping_batch_with_typed_error() {
    // bn preset at batch 128: the moment-sum accumulator of the first
    // BN layer can wrap, so the build must refuse with the pinned
    // message naming layer and first wrapping count
    let err = Spec::builder()
        .preset("bn1x")
        .batch(128)
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains(
            "can wrap the i32 moment-sum accumulator of layer `n1`"
        ),
        "unexpected message: {msg}"
    );
    assert!(msg.contains("128 images"), "unexpected message: {msg}");
    assert!(msg.contains("batch 127"), "unexpected message: {msg}");

    // one image under the wrap bound builds fine...
    assert!(Spec::builder().preset("bn1x").batch(127).build().is_ok());
    // ...and non-BN nets have no must-stay-exact accumulators to
    // protect, so the same batch size is accepted there
    assert!(Spec::builder().preset("1x").batch(128).build().is_ok());
}

#[test]
fn analyze_reports_all_presets_clean_at_defaults() {
    // the acceptance sweep CI runs through the CLI, in-process
    let dv = DesignVars::for_scale(1);
    for (preset, bn) in [
        ("1x", false),
        ("2x", false),
        ("4x", false),
        ("bn1x", true),
        ("bn2x", true),
        ("bn4x", true),
    ] {
        let spec = Spec::builder().preset(preset).build().unwrap();
        let (net, _) = spec.resolve_for_analysis().unwrap();
        let report = analyze(&net, &dv, spec.batch);
        assert_eq!(report.overflow_count(), 0, "{preset}");
        let table = report.render();
        assert!(!table.contains("overflow-possible"), "{preset}");
        assert!(table.contains("wrap-by-contract"), "{preset}");
        // BN nets carry proven must-stay-exact statistic rows
        assert_eq!(
            report.min_exact_headroom_bits().is_some(),
            bn,
            "{preset}"
        );
    }
}
