//! Checkpoint/resume integration tests (ISSUE 3 tentpole): killing a
//! training run and resuming from its checkpoint must be bit-for-bit
//! identical to never having stopped — parameters, optimizer state
//! (accumulators + momentum + counts), and the deterministic metrics
//! (images, batches, exact loss sums, simulated cycles) — at every
//! tested workers x accelerators combination, and a truncated or
//! corrupted checkpoint file must be rejected whole (CRC) rather than
//! half-loaded.

use std::path::PathBuf;

use stratus::ckpt::{Checkpoint, Cursor};
use stratus::coordinator::{CheckpointPolicy, TrainRun, Trainer};
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

const SEED: u64 = 7;
const BATCH: usize = 4;
const IMAGES: u64 = 12; // 3 batches per epoch
const EPOCHS: u64 = 2;
const KILL_AFTER: u64 = 2; // batches into epoch 0

const TINY_CFG: &str = "name tiny\ninput 3 8 8\nconv c1 8 k3 s1 p1 \
                        relu\nconv c2 8 k3 s1 p1 relu\npool p1 2\n\
                        fc fc 10\nloss hinge";

fn trainer(workers: usize, accelerators: usize) -> Trainer {
    let spec = Spec::builder()
        .net_inline(TINY_CFG)
        .batch(BATCH)
        .lr(0.02)
        .momentum(0.9)
        .workers(workers)
        .accelerators(accelerators)
        .build()
        .unwrap();
    Session::new(spec).unwrap().trainer().unwrap()
}

fn tmp_ckpt(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stratus_ckpt_test_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("ckpt.stratus")
}

fn plain_run() -> TrainRun {
    TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: None,
        max_batches: None,
    }
}

/// Everything the bit-identity contract covers, extracted for equality
/// asserts (host_seconds is wall clock and deliberately excluded).
#[derive(Debug, PartialEq)]
struct Signature {
    params: Vec<i32>,
    grad_accs: Vec<Vec<i32>>,
    momenta: Vec<Vec<i32>>,
    counts: Vec<usize>,
    images: u64,
    batches: u64,
    loss_sum_bits: u64,
    sim_cycles_bits: u64,
}

fn state_signature(t: &Trainer) -> Signature {
    Signature {
        params: t.flat_params(),
        grad_accs: t
            .param_states()
            .iter()
            .map(|(_, s)| s.grad_acc.data().to_vec())
            .collect(),
        momenta: t
            .param_states()
            .iter()
            .map(|(_, s)| s.momentum.data().to_vec())
            .collect(),
        counts: t.param_states().iter().map(|(_, s)| s.count).collect(),
        images: t.metrics.images,
        batches: t.metrics.batches,
        loss_sum_bits: t.metrics.loss_sum.to_bits(),
        sim_cycles_bits: t.metrics.sim_cycles.to_bits(),
    }
}

#[test]
fn kill_and_resume_is_bit_identical_across_parallelism() {
    // ISSUE 3 acceptance: train K batches, checkpoint, drop the
    // trainer, resume in a fresh one, finish — equal to an
    // uninterrupted run, across {1,2,4} workers x {1,3} accelerators
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    for &workers in &[1usize, 2, 4] {
        for &accels in &[1usize, 3] {
            let tag = format!("w{workers}a{accels}");
            // uninterrupted reference
            let mut full = trainer(workers, accels);
            let end = full
                .run(&data, &plain_run(), Cursor::start(SEED, IMAGES),
                     |_, _| Ok(()))
                .unwrap();
            assert_eq!(end,
                       Cursor { epoch: EPOCHS, batch: 0, seed: SEED,
                                images: IMAGES });

            // interrupted: kill after KILL_AFTER batches, mid-epoch
            let path = tmp_ckpt(&tag);
            let killed_cfg = TrainRun {
                checkpoint: Some(CheckpointPolicy {
                    path: path.clone(),
                    every_batches: KILL_AFTER,
                    resize: None,
                }),
                max_batches: Some(KILL_AFTER),
                ..plain_run()
            };
            let mut killed = trainer(workers, accels);
            let stopped = killed
                .run(&data, &killed_cfg, Cursor::start(SEED, IMAGES),
                     |_, _| Ok(()))
                .unwrap();
            assert_eq!(stopped,
                       Cursor { epoch: 0, batch: KILL_AFTER,
                                seed: SEED, images: IMAGES },
                       "{tag}: unexpected kill point");
            drop(killed); // the "crash": all in-memory state is gone

            // resume in a fresh trainer and finish the run
            let mut resumed = trainer(workers, accels);
            let cur = resumed.resume_from(&path).unwrap();
            assert_eq!(cur, stopped, "{tag}: cursor did not round-trip");
            let resumed_cfg = TrainRun {
                checkpoint: Some(CheckpointPolicy {
                    path: path.clone(),
                    every_batches: KILL_AFTER,
                    resize: None,
                }),
                ..plain_run()
            };
            let end2 = resumed
                .run(&data, &resumed_cfg, cur, |_, _| Ok(()))
                .unwrap();
            assert_eq!(end2, end);

            assert_eq!(state_signature(&full),
                       state_signature(&resumed),
                       "{tag}: resumed run diverged from uninterrupted");
            let _ = std::fs::remove_dir_all(path.parent().unwrap());
        }
    }
}

#[test]
fn resume_composes_with_different_parallelism() {
    // a checkpoint taken at 1 worker x 1 accelerator resumes at 4x3 —
    // grouping is irrelevant under the fixed-order merge, so params,
    // optimizer state, and exact loss sums still match the
    // uninterrupted single-instance run (sim_cycles differ by design:
    // the cluster charges concurrent-shard + all-reduce cycles)
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let mut full = trainer(1, 1);
    full.run(&data, &plain_run(), Cursor::start(SEED, IMAGES), |_, _| Ok(()))
        .unwrap();

    let path = tmp_ckpt("cross");
    let killed_cfg = TrainRun {
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: KILL_AFTER,
            resize: None,
        }),
        max_batches: Some(KILL_AFTER),
        ..plain_run()
    };
    let mut killed = trainer(1, 1);
    killed
        .run(&data, &killed_cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(()))
        .unwrap();
    drop(killed);

    let mut resumed = trainer(4, 3);
    let cur = resumed.resume_from(&path).unwrap();
    resumed.run(&data, &plain_run(), cur, |_, _| Ok(())).unwrap();

    assert_eq!(full.flat_params(), resumed.flat_params());
    assert_eq!(full.metrics.images, resumed.metrics.images);
    assert_eq!(full.metrics.batches, resumed.metrics.batches);
    assert_eq!(full.metrics.loss_sum.to_bits(),
               resumed.metrics.loss_sum.to_bits());
    for ((n1, s1), (n2, s2)) in
        full.param_states().iter().zip(resumed.param_states())
    {
        assert_eq!(n1, n2);
        assert_eq!(s1.momentum, s2.momentum, "{n1} momentum");
        assert_eq!(s1.grad_acc, s2.grad_acc, "{n1} accumulator");
        assert_eq!(s1.count, s2.count);
    }
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn corrupted_checkpoints_are_rejected_not_half_loaded() {
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let path = tmp_ckpt("corrupt");
    let cfg = TrainRun {
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 1,
            resize: None,
        }),
        max_batches: Some(2),
        ..plain_run()
    };
    let mut t = trainer(2, 1);
    t.run(&data, &cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(())).unwrap();
    let blob = std::fs::read(&path).unwrap();
    assert!(Checkpoint::from_bytes(&blob).is_ok());

    let mut victim = trainer(2, 1);
    let before = victim.flat_params();

    // truncation at several cuts, including mid-tensor
    for cut in [0usize, 7, 64, blob.len() / 2, blob.len() - 1] {
        std::fs::write(&path, &blob[..cut]).unwrap();
        let err = victim.resume_from(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("CRC") || msg.contains("truncated"),
            "cut={cut}: unexpected error: {msg}"
        );
        assert_eq!(victim.flat_params(), before,
                   "cut={cut}: trainer mutated by a rejected resume");
    }

    // single corrupted byte mid-payload: CRC must catch it
    let mut bad = blob.clone();
    let mid = blob.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    let err = victim.resume_from(&path).unwrap_err();
    assert!(format!("{err:#}").contains("CRC"), "{err:#}");
    assert_eq!(victim.flat_params(), before);
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn resume_refuses_a_different_network_or_hyper() {
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let path = tmp_ckpt("fingerprint");
    let cfg = TrainRun {
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 1,
            resize: None,
        }),
        max_batches: Some(1),
        ..plain_run()
    };
    let mut t = trainer(1, 1);
    t.run(&data, &cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(())).unwrap();

    // different network (wider conv): fingerprint mismatch
    let other_spec = Spec::builder()
        .net_inline(
            "name tiny\ninput 3 8 8\nconv c1 12 k3 s1 p1 relu\nconv \
             c2 12 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .batch(BATCH)
        .lr(0.02)
        .momentum(0.9)
        .build()
        .unwrap();
    let mut other =
        Session::new(other_spec).unwrap().trainer().unwrap();
    let err = other.resume_from(&path).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");

    // same network, different learning rate: also refused
    let lr_spec = Spec::builder()
        .net_inline(TINY_CFG)
        .batch(BATCH)
        .lr(0.05)
        .momentum(0.9)
        .build()
        .unwrap();
    let mut other_lr =
        Session::new(lr_spec).unwrap().trainer().unwrap();
    let err = other_lr.resume_from(&path).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");

    // the original configuration still resumes fine
    let mut same = trainer(1, 1);
    assert!(same.resume_from(&path).is_ok());
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn checkpoint_cadence_writes_at_epoch_boundaries() {
    // epoch ends always checkpoint, even when the cadence would not
    // have fired yet; the recorded cursor is normalized to the next
    // epoch's start
    let data = Synthetic::new(10, (3, 8, 8), SEED, 0.3);
    let path = tmp_ckpt("cadence");
    let cfg = TrainRun {
        epochs: 1,
        images: IMAGES,
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 100, // cadence never fires on its own
            resize: None,
        }),
        max_batches: None,
    };
    let mut t = trainer(1, 1);
    let end = t.run(&data, &cfg, Cursor::start(SEED, IMAGES), |_, _| Ok(()))
        .unwrap();
    assert_eq!(end, Cursor { epoch: 1, batch: 0, seed: SEED,
                            images: IMAGES });
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.cursor, end);
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}
