//! Experiment-service integration tests (ISSUE 10): scheduler
//! fairness and priority preemption asserted from the event log,
//! chaos kills (drop the scheduler mid-slice, re-open the serve
//! root) ending bit-identical to uninterrupted solo runs, the typed
//! rejection path for malformed submissions (pinned messages, daemon
//! survives), and the `serve`/`report serve` CLI surface.

use std::path::{Path, PathBuf};
use std::process::Command;

use stratus::ckpt::Checkpoint;
use stratus::jsonx::Json;
use stratus::metrics;
use stratus::serve::{read_events, RunPhase, Scheduler, ServeConfig,
                     Tick};
use stratus::session::{Session, Spec, SpecError};

const TINY_CFG: &str = "name tiny\ninput 3 8 8\nconv c1 8 k3 s1 p1 \
                        relu\nconv c2 8 k3 s1 p1 relu\npool p1 2\n\
                        fc fc 10\nloss hinge";
const BATCH: usize = 4;
const IMAGES: u64 = 12; // 3 batches per epoch
const EPOCHS: u64 = 2; // -> 6 batches per run
const SLICE: u64 = 2; // -> 3 slices per run

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("stratus_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tiny_spec(seed: u64) -> Spec {
    Spec::builder()
        .net_inline(TINY_CFG)
        .batch(BATCH)
        .lr(0.02)
        .momentum(0.9)
        .images(IMAGES)
        .epochs(EPOCHS)
        .seed(seed)
        .eval(4)
        .build()
        .unwrap()
}

/// A submission file body: the spec JSON plus an optional top-level
/// priority key.
fn submission(seed: u64, priority: Option<i64>) -> String {
    let Json::Obj(mut m) = tiny_spec(seed).to_json() else {
        panic!("spec JSON is always an object");
    };
    if let Some(p) = priority {
        m.insert("priority".to_string(), Json::Num(p as f64));
    }
    Json::Obj(m).pretty()
}

fn cfg(root: &Path) -> ServeConfig {
    let mut cfg = ServeConfig::new(root);
    cfg.slice_batches = SLICE;
    cfg
}

fn slice_order(root: &Path) -> Vec<String> {
    read_events(root)
        .unwrap()
        .iter()
        .filter(|e| {
            e.get("event").and_then(Json::as_str) == Some("slice")
        })
        .map(|e| {
            e.get("run").and_then(Json::as_str).unwrap().to_string()
        })
        .collect()
}

fn event_count(root: &Path, kind: &str) -> usize {
    read_events(root)
        .unwrap()
        .iter()
        .filter(|e| {
            e.get("event").and_then(Json::as_str) == Some(kind)
        })
        .count()
}

/// The `examples/ckpt_diff` deterministic-content gate, as asserts:
/// fingerprint, cursor, hyper, every param tensor, every optimizer
/// state, and the deterministic metrics.
fn assert_ckpt_identical(a: &Path, b: &Path) {
    let a = Checkpoint::load(a).unwrap();
    let b = Checkpoint::load(b).unwrap();
    assert_eq!(a.fingerprint, b.fingerprint, "fingerprint");
    assert_eq!(a.cursor, b.cursor, "cursor");
    assert_eq!(a.hyper.lr_q16, b.hyper.lr_q16, "hyper.lr_q16");
    assert_eq!(a.hyper.beta_q15, b.hyper.beta_q15, "hyper.beta_q15");
    assert_eq!(a.hyper.batch, b.hyper.batch, "hyper.batch");
    assert_eq!(a.metrics.images, b.metrics.images, "metrics.images");
    assert_eq!(a.metrics.batches, b.metrics.batches,
               "metrics.batches");
    assert_eq!(a.metrics.loss_sum.to_bits(),
               b.metrics.loss_sum.to_bits(),
               "metrics.loss_sum bits");
    assert_eq!(a.params.len(), b.params.len(), "param count");
    for ((na, ta), (nb, tb)) in a.params.iter().zip(&b.params) {
        assert_eq!(na, nb, "param order");
        assert_eq!(ta, tb, "params[{na}] data");
    }
    assert_eq!(a.states.len(), b.states.len(), "state count");
    for ((na, sa), (nb, sb)) in a.states.iter().zip(&b.states) {
        assert_eq!(na, nb, "state order");
        assert_eq!(sa.kind, sb.kind, "states[{na}].kind");
        assert_eq!(sa.grad_acc, sb.grad_acc,
                   "states[{na}].grad_acc");
        assert_eq!(sa.momentum, sb.momentum,
                   "states[{na}].momentum");
        assert_eq!(sa.count, sb.count, "states[{na}].count");
    }
}

/// Train `spec` solo (no serve) to completion, returning its final
/// checkpoint path.
fn solo_reference(seed: u64, dir: &Path) -> PathBuf {
    let spec = tiny_spec(seed)
        .to_builder()
        .checkpoint_dir(dir)
        .checkpoint_every(100) // epoch ends still always save
        .build()
        .unwrap();
    let session = Session::new(spec).unwrap();
    let out = session.train(|_, _, _| Ok(())).unwrap();
    assert_eq!(out.end.epoch, EPOCHS);
    session.checkpoint_path().unwrap()
}

#[test]
fn equal_priority_runs_interleave_slices() {
    let root = tmp_dir("fair");
    std::fs::write(root.join("inbox/a.json"), submission(7, None))
        .unwrap();
    std::fs::write(root.join("inbox/b.json"), submission(11, None))
        .unwrap();
    let mut sched = Scheduler::open(cfg(&root)).unwrap();
    let mut done = 0;
    for _ in 0..16 {
        match sched.tick().unwrap() {
            Tick::Sliced { done: true, .. } => done += 1,
            Tick::Idle => break,
            Tick::Failed { id } => panic!("run {id} failed"),
            _ => {}
        }
    }
    assert_eq!(done, 2, "both runs complete");
    // strict alternation: with equal priorities the least-served run
    // always goes next, so neither ever gets two slices in a row
    // while the other still has work
    assert_eq!(slice_order(&root),
               vec!["r0001-a", "r0002-b", "r0001-a", "r0002-b",
                    "r0001-a", "r0002-b"]);
    assert_eq!(event_count(&root, "complete"), 2);
    // the queue records agree with the event log
    for r in sched.runs() {
        assert_eq!(r.phase, RunPhase::Done, "{}", r.id);
        assert_eq!(r.slices, 3, "{}", r.id);
        assert_eq!(r.batches, 6, "{}", r.id);
        assert_eq!((r.epoch, r.batch), (EPOCHS, 0), "{}", r.id);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn higher_priority_preempts_at_the_next_slice_boundary() {
    let root = tmp_dir("preempt");
    std::fs::write(root.join("inbox/a.json"), submission(7, None))
        .unwrap();
    let mut sched = Scheduler::open(cfg(&root)).unwrap();
    // a gets one slice...
    assert_eq!(sched.tick().unwrap(),
               Tick::Sliced { id: "r0001-a".to_string(),
                              done: false });
    // ...then a priority-5 submission lands; it must win every slice
    // from the very next boundary until it finishes
    std::fs::write(root.join("inbox/c.json"), submission(11, Some(5)))
        .unwrap();
    for _ in 0..16 {
        if sched.tick().unwrap() == Tick::Idle {
            break;
        }
    }
    assert_eq!(slice_order(&root),
               vec!["r0001-a", "r0002-c", "r0002-c", "r0002-c",
                    "r0001-a", "r0001-a"]);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn chaos_kills_and_restarts_resume_bit_identically() {
    let solo_a = solo_reference(7, &tmp_dir("chaos_solo_a"));
    let solo_b = solo_reference(11, &tmp_dir("chaos_solo_b"));
    let root = tmp_dir("chaos");
    std::fs::write(root.join("inbox/a.json"), submission(7, None))
        .unwrap();
    std::fs::write(root.join("inbox/b.json"), submission(11, None))
        .unwrap();
    let mut sched = Scheduler::open(cfg(&root)).unwrap();
    // deterministic LCG picks the kill points (no wall-clock, no OS
    // randomness: the test replays identically).  This seed's draw
    // sequence mod 3 is 1,0,1,0,1,1,1,2,0,... — kills land between
    // clean slices, including one during a run's first slice (no
    // checkpoint on disk yet) and one mid-epoch after an
    // epoch-boundary save
    let mut rng: u64 = 30;
    let mut step = || {
        rng = rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        rng >> 33
    };
    let mut kills = 0;
    let mut guard = 0;
    loop {
        guard += 1;
        assert!(guard < 300, "chaos loop did not converge");
        if step() % 3 == 0 {
            // kill -9 one batch into the slice: nothing recorded,
            // durable state still says `running`; recovery (a fresh
            // open of the same root) must requeue and resume it
            match sched.tick_with_kill(Some(1)).unwrap() {
                Tick::Killed { .. } => {
                    kills += 1;
                    sched = Scheduler::open(cfg(&root)).unwrap();
                }
                Tick::Idle => break,
                Tick::Failed { id } => panic!("run {id} failed"),
                _ => {}
            }
        } else {
            match sched.tick().unwrap() {
                Tick::Idle => break,
                Tick::Failed { id } => panic!("run {id} failed"),
                _ => {}
            }
        }
    }
    assert!(kills >= 2, "the chaos schedule must actually kill \
                         (got {kills})");
    assert_eq!(event_count(&root, "recover"), kills);
    for r in sched.runs() {
        assert_eq!(r.phase, RunPhase::Done, "{}", r.id);
    }
    // the whole point: every run's final checkpoint — params,
    // optimizer state, deterministic metrics — is bit-identical to
    // the solo run that was never interrupted
    assert_ckpt_identical(
        &root.join("runs/r0001-a/ckpt/ckpt.stratus"), &solo_a);
    assert_ckpt_identical(
        &root.join("runs/r0002-b/ckpt/ckpt.stratus"), &solo_b);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn rejected_submissions_move_to_failed_and_never_crash() {
    let root = tmp_dir("reject");
    std::fs::write(root.join("inbox/garbage.json"), "{nope").unwrap();
    std::fs::write(root.join("inbox/unknown.json"),
                   submission(7, None).replacen("\"run\"", "\"runn\"",
                                                1))
        .unwrap();
    std::fs::write(root.join("inbox/badpri.json"),
                   submission(7, None).replacen(
                       '{', "{\"priority\": 1.5,", 1))
        .unwrap();
    // a good submission rides along: rejections must not starve it
    let mut ok = submission(7, None);
    ok = ok.replacen("\"epochs\": 2", "\"epochs\": 1", 1);
    std::fs::write(root.join("inbox/ok.json"), ok).unwrap();
    let mut sched = Scheduler::open(cfg(&root)).unwrap();
    for _ in 0..8 {
        if sched.tick().unwrap() == Tick::Idle {
            break;
        }
    }
    // the daemon survived, the good run completed
    assert_eq!(sched.runs().len(), 1);
    assert_eq!(sched.runs()[0].id, "r0001-ok");
    assert_eq!(sched.runs()[0].phase, RunPhase::Done);
    // rejects moved out of the inbox with pinned reasons
    assert_eq!(stratus::serve::list_submissions(
                   &root.join("inbox")).unwrap(),
               Vec::<PathBuf>::new());
    let reason = |name: &str| {
        std::fs::read_to_string(
            root.join(format!("failed/{name}.reason")))
            .unwrap()
    };
    assert!(root.join("failed/garbage.json").exists());
    assert!(reason("garbage.json")
                .starts_with("submission is not valid JSON:"),
            "{}", reason("garbage.json"));
    assert_eq!(reason("unknown.json").trim(),
               "unknown field `runn` in the spec");
    assert_eq!(reason("badpri.json").trim(),
               "priority wants an integer with magnitude at most \
                2^53");
    assert_eq!(event_count(&root, "reject"), 3);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn slice_bounded_runs_require_a_checkpoint_dir() {
    // the session-layer contract serve is built on, with its pinned
    // message
    let session = Session::new(tiny_spec(7)).unwrap();
    let err = session.begin_slice(false, SLICE).unwrap_err();
    assert_eq!(format!("{err:#}"),
               SpecError::SliceWithoutCheckpoint.to_string());
    assert_eq!(SpecError::SliceWithoutCheckpoint.to_string(),
               "a slice-bounded run needs checkpoint-dir (the slice \
                boundary must land on a checkpoint so the next slice \
                can resume)");
    let err = session.begin_slice(false, 0).unwrap_err();
    assert_eq!(format!("{err:#}"),
               "slice-batches must be at least 1");
}

#[test]
fn status_report_summarizes_a_serve_root() {
    let root = tmp_dir("status");
    std::fs::write(root.join("inbox/a.json"), submission(7, None))
        .unwrap();
    let mut sched = Scheduler::open(cfg(&root)).unwrap();
    sched.tick().unwrap(); // one slice: queued again, mid-flight
    let t = metrics::serve_report(&root).unwrap();
    assert!(t.contains("| r0001-a |"), "{t}");
    assert!(t.contains("| queued "), "{t}");
    assert!(t.contains("1 queued / 0 running / 0 done / 0 failed"),
            "{t}");
    assert!(t.contains("1 slices, 2 batches"), "{t}");
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------- the CLI surface ----------------

fn stratus(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_stratus"))
        .args(args)
        .output()
        .expect("spawning stratus");
    (out.status.success(),
     String::from_utf8_lossy(&out.stdout).into_owned(),
     String::from_utf8_lossy(&out.stderr).into_owned())
}

#[test]
fn cli_serve_drains_the_queue_and_reports_status() {
    let root = tmp_dir("cli");
    std::fs::write(root.join("inbox/one.json"), submission(7, None))
        .unwrap();
    let rootarg = root.display().to_string();
    let (ok, out, err) = stratus(&["serve", "--root", &rootarg,
                                   "--drain", "--slice-batches", "4",
                                   "--poll-ms", "10"]);
    assert!(ok, "serve --drain failed: {err}");
    // progress streamed as JSON lines
    assert!(out.contains("\"event\":\"submit\""), "{out}");
    assert!(out.contains("\"event\":\"complete\""), "{out}");
    let (ok, out, _) = stratus(&["serve", "--root", &rootarg,
                                 "--status"]);
    assert!(ok);
    assert!(out.contains("| r0001-one |"), "{out}");
    assert!(out.contains("| done "), "{out}");
    let (ok, out, _) = stratus(&["report", "serve", "--root",
                                 &rootarg]);
    assert!(ok);
    assert!(out.contains("1 done"), "{out}");
    // pinned: serve without a root is an error, not a panic
    let (ok, _, err) = stratus(&["serve"]);
    assert!(!ok);
    assert!(err.contains("serve needs --root DIR"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}
