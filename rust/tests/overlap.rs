//! Pipelined-overlap equivalence suite (ISSUE 9 acceptance
//! criterion): layer-bucketed all-reduce launched in reverse-BP order
//! on the persistent worker pool must be a pure performance transform
//! — same seed, same batch stream, any bucket cap, any instance count,
//! any topology => bit-identical parameters, losses, and optimizer
//! state to the serial monolithic merge after every `end_batch`.
//! Mirrors rust/tests/cluster.rs with the `bucket-kwords` knob turned
//! on, and pins kill/resume across bucketing changes (the fingerprint
//! deliberately excludes the knob).

use stratus::ckpt::Cursor;
use stratus::config::Topology;
use stratus::coordinator::{CheckpointPolicy, TrainRun, Trainer};
use stratus::data::Synthetic;
use stratus::engine::collective::BucketPlan;
use stratus::session::{NetSource, Session, Spec};

/// A net whose ~5.9K-word gradient actually splits at a 1 KiW bucket
/// cap (the 8x8 tiny net of tests/cluster.rs is a single bucket even
/// at kwords = 1, which would make these tests vacuous).
fn split_net() -> NetSource {
    NetSource::inline(
        "input 3 16 16\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

fn split_bn_net() -> NetSource {
    NetSource::inline(
        "input 3 16 16\nconv c1 8 k3 s1 p1\nbn n1 relu\nconv c2 8 k3 \
         s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

/// Session-built trainer with the overlap knob: `kwords == 0` is the
/// serial monolithic merge, anything else buckets at that cap.
fn trainer_kw(src: &NetSource, batch: usize, accelerators: usize,
              workers: usize, topology: Topology, kwords: usize)
              -> Trainer {
    let mut b = Spec::builder()
        .net(src.clone())
        .batch(batch)
        .lr(0.002)
        .momentum(0.9)
        .accelerators(accelerators)
        .workers(workers)
        .topology(topology);
    if kwords > 0 {
        b = b.bucket_kwords(kwords);
    }
    Session::new(b.build().unwrap()).unwrap().trainer().unwrap()
}

/// Train `serial` (1 instance, monolithic) and `pipelined` (bucketed)
/// on the same stream and require bit-identical everything.
fn assert_pipelined_matches_serial(src: &NetSource, batch_images: usize,
                                   batches: usize, accelerators: usize,
                                   workers: usize, topology: Topology,
                                   kwords: usize) {
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let stream = data.batch(0, batch_images * batches);
    let mut serial =
        trainer_kw(src, batch_images, 1, 1, Topology::Ring, 0);
    let mut pipelined = trainer_kw(src, batch_images, accelerators,
                                   workers, topology, kwords);
    for chunk in stream.chunks(batch_images) {
        let l_ser = serial.train_batch(chunk).unwrap();
        let l_pip = pipelined.train_batch(chunk).unwrap();
        assert_eq!(l_ser, l_pip,
                   "loss diverged: {accelerators} instances x {workers} \
                    workers, {topology:?}, kwords {kwords}");
    }
    assert_eq!(serial.flat_params(), pipelined.flat_params(),
               "parameters diverged: {accelerators} instances x \
                {workers} workers, {topology:?}, kwords {kwords}");
    for ((n, s), (_, p)) in
        serial.param_states().iter().zip(pipelined.param_states())
    {
        assert_eq!(s.grad_acc, p.grad_acc, "{n} grad_acc");
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    assert_eq!(serial.metrics.images, pipelined.metrics.images);
    assert_eq!(serial.metrics.loss_sum, pipelined.metrics.loss_sum);
}

#[test]
fn bucket_plan_splits_the_sweep_net() {
    // the tests below are only meaningful if kwords = 1 really buckets
    // this net; pin the plan shape and its boundary invariants
    let net = split_net().resolve().unwrap();
    let plan = BucketPlan::build(&net.ring_segments(), 1024);
    assert!(plan.buckets.len() >= 2,
            "split_net stayed monolithic: {plan:?}");
    assert_eq!(plan.total_words(), net.ring_words() as u64);
    // buckets tile [0, ring_words) contiguously from the vector tail
    let mut hi = net.ring_words();
    for b in &plan.buckets {
        assert_eq!(b.hi, hi, "{} not contiguous", b.label);
        assert!(b.lo < b.hi);
        hi = b.lo;
    }
    assert_eq!(hi, 0);
    // every boundary coincides with a parameter-segment boundary
    let mut edges = vec![0usize];
    let mut acc = 0usize;
    for (_, w) in net.ring_segments() {
        acc += w;
        edges.push(acc);
    }
    for b in &plan.buckets {
        assert!(edges.contains(&b.lo) && edges.contains(&b.hi),
                "bucket {} cuts inside a tensor", b.label);
    }
}

#[test]
fn bucketed_training_matches_serial_across_bucket_sizes() {
    // cap sweep at fixed N: from every-layer-its-own-bucket up to a
    // cap bigger than the whole gradient (degenerates to monolithic)
    for kwords in [1usize, 2, 8, 1024] {
        assert_pipelined_matches_serial(&split_net(), 8, 2, 4, 1,
                                        Topology::Ring, kwords);
    }
}

#[test]
fn pipelined_sweep_ring_matches_serial() {
    // ISSUE 9 acceptance sweep, ring half: {1,2,4} workers x
    // {1,4,16} accelerators, bucketed at 1 KiW
    for workers in [1usize, 2, 4] {
        for accelerators in [1usize, 4, 16] {
            assert_pipelined_matches_serial(&split_net(), 8, 2,
                                            accelerators, workers,
                                            Topology::Ring, 1);
        }
    }
}

#[test]
fn pipelined_sweep_hier_matches_serial() {
    // hier half of the sweep; N = 1 and 4 exercise the grouped
    // collective's degenerate fallbacks, 16 its real 4x4 grouping
    for workers in [1usize, 2, 4] {
        for accelerators in [1usize, 4, 16] {
            assert_pipelined_matches_serial(&split_net(), 8, 2,
                                            accelerators, workers,
                                            Topology::Hier, 1);
        }
    }
}

#[test]
fn bucketed_bn_net_merges_stat_tensors_identically() {
    // bn nets append statistic accumulators to the gradient vector;
    // the bucket walk must re-shard those exactly like the monolith
    assert_pipelined_matches_serial(&split_bn_net(), 6, 2, 4, 1,
                                    Topology::Hier, 1);
    assert_pipelined_matches_serial(&split_bn_net(), 6, 1, 16, 1,
                                    Topology::Auto, 1);
}

#[test]
fn uneven_shards_and_odd_caps_stay_bit_identical() {
    // boundary cases: shards of unequal size, more instances than
    // images, and a cap that forces one oversized single-tensor bucket
    assert_pipelined_matches_serial(&split_net(), 10, 1, 4, 1,
                                    Topology::Ring, 1);
    assert_pipelined_matches_serial(&split_net(), 3, 1, 16, 1,
                                    Topology::Ring, 2);
    assert_pipelined_matches_serial(&split_net(), 8, 1, 4, 2,
                                    Topology::Auto, 1);
}

#[test]
fn fingerprint_excludes_bucket_kwords_but_not_hyper() {
    let spec = |kwords: usize, lr: f64| {
        let mut b = Spec::builder()
            .net(split_net())
            .batch(8)
            .lr(lr)
            .momentum(0.9);
        if kwords > 0 {
            b = b.bucket_kwords(kwords);
        }
        Session::new(b.build().unwrap()).unwrap().fingerprint()
    };
    // bucketing is a parallelism knob: resume must compose across it
    assert_eq!(spec(0, 0.002), spec(8, 0.002),
               "bucket_kwords leaked into the fingerprint");
    // ...while real run parameters still bind
    assert_ne!(spec(0, 0.002), spec(0, 0.02));
}

#[test]
fn kill_resume_under_overlap_matches_uninterrupted() {
    // kill mid-run under the pipelined merge, resume with different
    // bucketing AND different instance count; final state must match
    // the uninterrupted serial run (and the resume itself proves the
    // fingerprint ignores bucket_kwords)
    let dir = std::env::temp_dir().join(format!(
        "stratus-overlap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("overlap.ckpt");
    let src = split_net();
    let net = src.resolve().unwrap();
    const IMAGES: u64 = 16;
    const BATCH: usize = 4;
    const EPOCHS: u64 = 2;
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let cfg = |max_batches: Option<u64>| TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: Some(CheckpointPolicy {
            path: path.clone(),
            every_batches: 1,
            resize: None,
        }),
        max_batches,
    };

    // reference: uninterrupted serial monolithic run
    let mut reference =
        trainer_kw(&src, BATCH, 1, 1, Topology::Ring, 0);
    let plain = TrainRun {
        epochs: EPOCHS,
        images: IMAGES,
        checkpoint: None,
        max_batches: None,
    };
    reference
        .run(&data, &plain, Cursor::start(77, IMAGES), |_, _| Ok(()))
        .unwrap();

    // stage 1: pipelined bucketed merge at 4 instances, then "killed"
    let mut t4 = trainer_kw(&src, BATCH, 4, 1, Topology::Ring, 1);
    t4.run(&data, &cfg(Some(3)), Cursor::start(77, IMAGES),
           |_, _| Ok(()))
        .unwrap();
    drop(t4);

    // stage 2: resume with bucketing OFF at 2 instances and finish
    let mut t2 = trainer_kw(&src, BATCH, 1, 1, Topology::Ring, 0)
        .with_accelerators(2);
    let cur = t2.resume_from(&path).unwrap();
    assert_eq!(cur.batch, 3);
    let end = t2.run(&data, &cfg(None), cur, |_, _| Ok(())).unwrap();
    assert_eq!(end.epoch, EPOCHS);

    assert_eq!(reference.flat_params(), t2.flat_params(),
               "overlap kill/resume chain diverged from serial run");
    for ((n, s), (_, p)) in
        reference.param_states().iter().zip(t2.param_states())
    {
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn metrics_split_host_time_into_compute_and_comm() {
    let src = split_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 5, 0.3);
    let batch = data.batch(0, 8);
    // cluster path: wall time splits exactly into compute + comm
    let mut t = trainer_kw(&src, 8, 4, 1, Topology::Ring, 1);
    t.train_batch(&batch).unwrap();
    let m = &t.metrics;
    assert!(m.host_seconds > 0.0);
    assert!(m.host_compute_seconds > 0.0);
    assert!(m.host_comm_seconds >= 0.0);
    assert!((m.host_compute_seconds + m.host_comm_seconds
             - m.host_seconds)
                .abs()
            < 1e-9 * m.host_seconds.max(1.0),
            "compute {} + comm {} != wall {}", m.host_compute_seconds,
            m.host_comm_seconds, m.host_seconds);
    // engine path (no collective): all host time is compute
    let mut t1 = trainer_kw(&src, 8, 1, 1, Topology::Ring, 0);
    t1.train_batch(&batch).unwrap();
    assert_eq!(t1.metrics.host_comm_seconds, 0.0);
    assert!((t1.metrics.host_compute_seconds
             - t1.metrics.host_seconds)
                .abs()
            < 1e-12);
}
