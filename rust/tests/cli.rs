//! CLI integration tests: drive the `stratus` binary end to end and
//! check the user-facing contracts (exit codes, report contents, config
//! parsing, netlist emission).

use std::process::Command;

fn stratus(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_stratus"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stratus");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (ok, _, err) = stratus(&[]);
    assert!(!ok);
    assert!(err.contains("USAGE"));
}

#[test]
fn unknown_command_fails() {
    let (ok, _, err) = stratus(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("USAGE"));
}

#[test]
fn compile_reports_design() {
    let (ok, out, _) = stratus(&["compile", "--scale", "1x"]);
    assert!(ok);
    assert!(out.contains("cifar10-1x"));
    assert!(out.contains("8x8x16 = 1024 MACs"));
    assert!(out.contains("transposable_wbuf"));
    assert!(out.contains("DSP"));
}

#[test]
fn compile_emits_verilog() {
    let tmp = std::env::temp_dir().join("stratus_cli_top.sv");
    let path = tmp.to_str().unwrap();
    let (ok, out, _) =
        stratus(&["compile", "--scale", "2x", "--emit-verilog", path]);
    assert!(ok, "{out}");
    let v = std::fs::read_to_string(&tmp).unwrap();
    assert!(v.contains("module cnn_train_top"));
    assert!(v.contains("parameter POF = 32"));
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn compile_rejects_oversized_design() {
    let (ok, _, err) = stratus(&[
        "compile", "--scale", "4x", "--pox", "32", "--poy", "32",
    ]);
    assert!(!ok);
    assert!(err.contains("does not fit"));
}

#[test]
fn simulate_prints_phase_table() {
    let (ok, out, _) =
        stratus(&["simulate", "--scale", "4x", "--batch", "40"]);
    assert!(ok);
    for phase in ["FP", "BP", "WU", "UPDATE"] {
        assert!(out.contains(phase), "{phase} missing:\n{out}");
    }
    assert!(out.contains("GOPS"));
}

#[test]
fn analyze_prints_range_table_for_every_preset() {
    for scale in ["1x", "2x", "4x", "bn1x", "bn2x", "bn4x"] {
        let (ok, out, err) = stratus(&["analyze", "--scale", scale]);
        assert!(ok, "{scale}: {out}\n{err}");
        assert!(out.contains("range analysis"), "{scale}: {out}");
        assert!(out.contains("wrap-by-contract"), "{scale}: {out}");
        // the acceptance bar: no preset is overflow-possible at the
        // default batch size
        assert!(!out.contains("overflow-possible"), "{scale}: {out}");
    }
    // --json emits the machine-readable report CI archives
    let (ok, out, _) = stratus(&["analyze", "--scale", "bn1x", "--json"]);
    assert!(ok);
    assert!(out.contains("\"overflow_possible\": 0"), "{out}");
    assert!(out.contains("\"rows\""), "{out}");
}

#[test]
fn analyze_reports_wrapping_batch_and_exits_nonzero() {
    // analyze renders the full table for a spec `train` would refuse,
    // then exits non-zero so CI can gate on it
    let (ok, out, err) =
        stratus(&["analyze", "--scale", "bn1x", "--batch", "128"]);
    assert!(!ok);
    assert!(out.contains("overflow-possible(>= 128 images)"), "{out}");
    assert!(err.contains("moment-sum"), "{err}");
    assert!(err.contains("`n1`"), "{err}");
    // the same spec is refused outright at spec-build time
    let (ok, _, err) =
        stratus(&["simulate", "--scale", "bn1x", "--batch", "128"]);
    assert!(!ok);
    assert!(
        err.contains(
            "can wrap the i32 moment-sum accumulator of layer `n1`"
        ),
        "{err}"
    );
}

#[test]
fn report_table2_has_three_networks() {
    let (ok, out, _) = stratus(&["report", "table2"]);
    assert!(ok);
    for net in ["CIFAR-10 1X", "CIFAR-10 2X", "CIFAR-10 4X"] {
        assert!(out.contains(net));
    }
}

#[test]
fn report_rejects_unknown() {
    let (ok, _, err) = stratus(&["report", "fig42"]);
    assert!(!ok);
    assert!(err.contains("unknown report"));
}

#[test]
fn calibrate_runs_on_custom_net() {
    let tmp = std::env::temp_dir().join("stratus_cli_net.cfg");
    std::fs::write(
        &tmp,
        "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 relu\n\
         pool p1 2\nfc fc 10\nloss hinge\n",
    )
    .unwrap();
    let (ok, out, _) = stratus(&[
        "calibrate", "--net", tmp.to_str().unwrap(), "--samples", "3",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("c1"));
    assert!(out.contains("rec"));
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn train_golden_tiny_runs() {
    let tmp = std::env::temp_dir().join("stratus_cli_train.cfg");
    std::fs::write(
        &tmp,
        "name tiny\ninput 3 8 8\nconv c1 4 k3 s1 p1 relu\n\
         conv c2 4 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge\n",
    )
    .unwrap();
    let (ok, out, _) = stratus(&[
        "train", "--net", tmp.to_str().unwrap(), "--backend", "golden",
        "--images", "8", "--epochs", "1", "--batch", "4", "--eval", "8",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("epoch   1"));
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn simulate_cluster_reports_allreduce_projection() {
    let (ok, out, _) = stratus(&[
        "simulate", "--scale", "1x", "--batch", "40", "--accelerators",
        "4",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("ALLREDUCE"), "{out}");
    assert!(out.contains("cluster        : 4 instances, 6 ring steps"),
            "{out}");
    // nonzero all-reduce communication cycles in the projection
    assert!(!out.contains("all-reduce 0 cycles/batch"), "{out}");
    assert!(out.contains("vs 1 instance"), "{out}");
    // single-instance runs stay free of cluster noise
    let (ok, out, _) =
        stratus(&["simulate", "--scale", "1x", "--batch", "40"]);
    assert!(ok);
    assert!(!out.contains("ALLREDUCE"));
}

/// (loss, train-acc, test-acc) triples from `stratus train` epoch lines.
fn epoch_stats(out: &str) -> Vec<(String, String, String)> {
    out.lines()
        .filter(|l| l.trim_start().starts_with("epoch"))
        .map(|l| {
            let t: Vec<&str> = l.split_whitespace().collect();
            (t[3].to_string(), t[5].to_string(), t[7].to_string())
        })
        .collect()
}

#[test]
fn train_cluster_bit_identical_to_single_instance() {
    // ISSUE 2 acceptance: `train --accelerators 4 --workers 1` produces
    // identical losses and accuracies to `--accelerators 1`
    let tmp = std::env::temp_dir().join("stratus_cli_cluster.cfg");
    std::fs::write(
        &tmp,
        "name tiny\ninput 3 8 8\nconv c1 4 k3 s1 p1 relu\n\
         conv c2 4 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge\n",
    )
    .unwrap();
    let run = |accelerators: &str| {
        let (ok, out, err) = stratus(&[
            "train", "--net", tmp.to_str().unwrap(), "--backend",
            "golden", "--images", "12", "--epochs", "2", "--batch", "4",
            "--eval", "8", "--accelerators", accelerators, "--workers",
            "1",
        ]);
        assert!(ok, "accelerators={accelerators}: {out}\n{err}");
        out
    };
    let single = run("1");
    let cluster = run("4");
    assert!(cluster.contains("4 accelerators"), "{cluster}");
    let s1 = epoch_stats(&single);
    let s4 = epoch_stats(&cluster);
    assert_eq!(s1.len(), 2);
    assert_eq!(s1, s4, "losses/accuracies diverged:\n{single}\n{cluster}");
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn report_cluster_scaling_table() {
    let (ok, out, _) = stratus(&["report", "cluster"]);
    assert!(ok);
    assert!(out.contains("cluster scaling"));
    assert!(out.contains("all-reduce cyc"));
    assert!(out.contains("instances"));
}

#[test]
fn missing_flag_value_is_an_error_not_a_switch() {
    // ISSUE 3 satellite: `--workers --backend golden` used to demote
    // --workers to a switch and silently train with 1 worker
    let (ok, _, err) =
        stratus(&["train", "--workers", "--backend", "golden"]);
    assert!(!ok);
    assert!(err.contains("--workers expects a value"), "{err}");
    assert!(err.contains("usage"), "{err}");
    // value flag at end of line is the same error
    let (ok, _, err) = stratus(&["simulate", "--batch"]);
    assert!(!ok);
    assert!(err.contains("--batch expects a value"), "{err}");
}

#[test]
fn unknown_flags_are_rejected_with_a_hint() {
    // ISSUE 3 satellite: a misspelled flag used to be silently ignored
    let (ok, _, err) = stratus(&[
        "train", "--acclerators", "4", "--backend", "golden",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown flag --acclerators"), "{err}");
    assert!(err.contains("usage"), "{err}");
    let (ok, _, err) = stratus(&["compile", "--fast"]);
    assert!(!ok);
    assert!(err.contains("unknown flag --fast"), "{err}");
    // flags accepted by one subcommand stay rejected by another
    let (ok, _, err) = stratus(&["report", "--workers", "2"]);
    assert!(!ok);
    assert!(err.contains("unknown flag --workers"), "{err}");
}

#[test]
fn zero_parallelism_counts_are_rejected() {
    // ISSUE 3 satellite: `--workers 0` / `--accelerators 0` error
    // instead of silently training with one
    // (the messages come from the SpecBuilder's typed NonPositive
    // errors now — one rule set shared by flags and spec files)
    let (ok, _, err) = stratus(&[
        "train", "--workers", "0", "--backend", "golden",
    ]);
    assert!(!ok);
    assert!(err.contains("workers must be at least 1"), "{err}");
    let (ok, _, err) =
        stratus(&["simulate", "--accelerators", "0"]);
    assert!(!ok);
    assert!(err.contains("accelerators must be at least 1"), "{err}");
    // a zero epoch count would silently train nothing
    let (ok, _, err) = stratus(&[
        "train", "--epochs", "0", "--backend", "golden",
    ]);
    assert!(!ok);
    assert!(err.contains("epochs must be at least 1"), "{err}");
}

#[test]
fn train_checkpoint_resume_end_to_end() {
    // ISSUE 3 acceptance: `stratus train --resume` continues from the
    // recorded epoch/batch cursor, and the continued run's epoch lines
    // are identical to an uninterrupted run's
    let tmp = std::env::temp_dir().join("stratus_cli_ckpt.cfg");
    std::fs::write(
        &tmp,
        "name tiny\ninput 3 8 8\nconv c1 4 k3 s1 p1 relu\n\
         conv c2 4 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge\n",
    )
    .unwrap();
    let dir = std::env::temp_dir()
        .join(format!("stratus_cli_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let base: Vec<&str> = vec![
        "train", "--net", tmp.to_str().unwrap(), "--backend", "golden",
        "--images", "8", "--batch", "4", "--eval", "8", "--workers", "2",
    ];
    let dir_s = dir.to_str().unwrap().to_string();
    let run = |extra: &[&str]| {
        let mut argv = base.clone();
        argv.extend_from_slice(extra);
        let (ok, out, err) = stratus(&argv);
        assert!(ok, "{out}\n{err}");
        out
    };
    // uninterrupted 2-epoch reference (no checkpointing)
    let full = run(&["--epochs", "2"]);
    // epoch 1 with checkpoints, then resume into epoch 2
    let first = run(&["--epochs", "1", "--checkpoint-dir", &dir_s,
                      "--checkpoint-every", "1"]);
    assert!(dir.join("ckpt.stratus").exists(), "{first}");
    let second = run(&["--epochs", "2", "--checkpoint-dir", &dir_s,
                       "--resume"]);
    assert!(second.contains("resumed"), "{second}");
    let s_full = epoch_stats(&full);
    let s1 = epoch_stats(&first);
    let s2 = epoch_stats(&second);
    assert_eq!(s_full.len(), 2);
    assert_eq!(s1.len(), 1);
    assert_eq!(s2.len(), 1, "resume must not replay epoch 1:\n{second}");
    assert_eq!(s_full[0], s1[0], "epoch 1 diverged:\n{full}\n{first}");
    assert_eq!(s_full[1], s2[0], "epoch 2 diverged:\n{full}\n{second}");
    // resuming again with the same target is a clean no-op
    let done = run(&["--epochs", "2", "--checkpoint-dir", &dir_s,
                     "--resume"]);
    assert!(done.contains("nothing to do"), "{done}");
    // --resume without --checkpoint-dir is an error
    let mut argv = base.clone();
    argv.extend_from_slice(&["--epochs", "2", "--resume"]);
    let (ok, _, err) = stratus(&argv);
    assert!(!ok);
    assert!(err.contains("resume needs checkpoint-dir"), "{err}");
    // a conflicting explicit --images on resume is refused (the cursor
    // records the epoch width; silently shrinking the data window
    // would break the bit-identity contract)
    let mut argv = base.clone();
    argv.extend_from_slice(&["--epochs", "3", "--checkpoint-dir",
                             &dir_s, "--resume", "--images", "99"]);
    let (ok, _, err) = stratus(&argv);
    assert!(!ok);
    assert!(err.contains("images 99 conflicts"), "{err}");
    let _ = std::fs::remove_file(&tmp);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_every_without_dir_is_an_error() {
    // cadence without a destination would silently save nothing
    let (ok, _, err) = stratus(&[
        "train", "--backend", "golden", "--checkpoint-every", "5",
    ]);
    assert!(!ok);
    assert!(err.contains("checkpoint-every needs checkpoint-dir"),
            "{err}");
}

#[test]
fn runtime_backends_require_explicit_artifacts() {
    // artifacts are backend-conditional now: golden runs without any,
    // and perop/fused without --artifacts is a clear error instead of
    // a silently assumed "artifacts" directory
    let (ok, _, err) = stratus(&["train", "--backend", "perop"]);
    assert!(!ok);
    assert!(err.contains("backend perop needs an artifacts directory"),
            "{err}");
    let (ok, _, err) = stratus(&["train", "--backend", "nope"]);
    assert!(!ok);
    assert!(err.contains("unknown backend `nope` (golden|perop|fused)"),
            "{err}");
}

#[test]
fn dump_spec_round_trips_and_flags_override() {
    // ISSUE 5 acceptance: `train --spec run.json` reproduces the same
    // fingerprint and bit-identical training as the equivalent flag
    // invocation; explicit flags override spec-file fields
    let cfg = std::env::temp_dir().join("stratus_cli_spec_net.cfg");
    std::fs::write(
        &cfg,
        "name tiny\ninput 3 8 8\nconv c1 4 k3 s1 p1 relu\n\
         conv c2 4 k3 s1 p1 relu\npool p1 2\nfc fc 10\nloss hinge\n",
    )
    .unwrap();
    let dir = std::env::temp_dir()
        .join(format!("stratus_cli_spec_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec_path = dir.join("run.json");
    std::fs::create_dir_all(&dir).unwrap();
    let dir_s = dir.to_str().unwrap().to_string();
    let spec_s = spec_path.to_str().unwrap().to_string();
    let base: Vec<&str> = vec![
        "train", "--net", cfg.to_str().unwrap(), "--backend", "golden",
        "--images", "8", "--epochs", "2", "--batch", "4", "--eval", "8",
        "--workers", "2",
    ];
    let run = |extra: &[&str]| {
        let mut argv = base.clone();
        argv.extend_from_slice(extra);
        let (ok, out, err) = stratus(&argv);
        assert!(ok, "{out}\n{err}");
        out
    };
    // --dump-spec writes the resolved spec and does NOT train
    let dumped = run(&["--dump-spec", &spec_s]);
    assert!(!dumped.contains("epoch"), "dump-spec trained:\n{dumped}");
    assert!(spec_path.exists());
    // flag run vs pure spec run: identical epoch lines
    let flag_out = run(&[]);
    let (ok, spec_out, err) = stratus(&["train", "--spec", &spec_s]);
    assert!(ok, "{spec_out}\n{err}");
    let s_flag = epoch_stats(&flag_out);
    assert_eq!(s_flag.len(), 2);
    assert_eq!(s_flag, epoch_stats(&spec_out),
               "spec run diverged:\n{flag_out}\n{spec_out}");
    // explicit flags override the spec file: --epochs 1 wins over 2
    let (ok, one, err) =
        stratus(&["train", "--spec", &spec_s, "--epochs", "1"]);
    assert!(ok, "{one}\n{err}");
    let s_one = epoch_stats(&one);
    assert_eq!(s_one.len(), 1, "{one}");
    assert_eq!(s_one[0], s_flag[0]);
    // a spec run resumes a FLAG run's checkpoint: the fingerprints
    // match across the two construction paths, and the continued
    // epoch 2 is bit-identical to the uninterrupted run's
    run(&["--epochs", "1", "--checkpoint-dir", &dir_s,
          "--checkpoint-every", "1"]);
    let (ok, resumed, err) = stratus(&[
        "train", "--spec", &spec_s, "--checkpoint-dir", &dir_s,
        "--resume",
    ]);
    assert!(ok, "{resumed}\n{err}");
    assert!(resumed.contains("resumed"), "{resumed}");
    let s_res = epoch_stats(&resumed);
    assert_eq!(s_res.len(), 1, "resume replayed epoch 1:\n{resumed}");
    assert_eq!(s_res[0], s_flag[1],
               "resumed epoch 2 diverged:\n{flag_out}\n{resumed}");
    let _ = std::fs::remove_file(&cfg);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spec_file_errors_are_strict_and_cited() {
    // unknown keys in a spec file are rejected (typo safety), and the
    // offending file is named in the error
    let path = std::env::temp_dir().join(format!(
        "stratus_cli_badspec_{}.json",
        std::process::id()
    ));
    std::fs::write(&path,
                   "{\"net\":{\"preset\":\"1x\"},\"runn\":{}}")
        .unwrap();
    let (ok, _, err) =
        stratus(&["train", "--spec", path.to_str().unwrap()]);
    assert!(!ok);
    assert!(err.contains("unknown field `runn`"), "{err}");
    assert!(err.contains("badspec"), "{err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bad_net_config_reports_line() {
    let tmp = std::env::temp_dir().join("stratus_cli_bad.cfg");
    std::fs::write(&tmp, "input 3 8 8\nconv c1 4 k3 s2 p1\nfc fc 10\n")
        .unwrap();
    let (ok, _, err) =
        stratus(&["compile", "--net", tmp.to_str().unwrap()]);
    assert!(!ok);
    assert!(err.contains("line 2"), "{err}");
    let _ = std::fs::remove_file(&tmp);
}
