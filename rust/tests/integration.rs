//! Integration tests across the three layers: AOT artifacts (lowered from
//! JAX/Pallas) executed via the PJRT runtime must agree BIT-FOR-BIT with
//! the pure-rust golden model, and all three trainer backends must
//! produce identical parameters after training.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use std::path::{Path, PathBuf};

use stratus::config::Network;
use stratus::coordinator::Backend;
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};
use stratus::fixed::FA;
use stratus::nn::conv::{conv_bp, conv_fp_std, conv_wu};
use stratus::nn::golden;
use stratus::nn::loss::encode_label;
use stratus::nn::pool::maxpool;
use stratus::nn::tensor::Tensor;
use stratus::nn::tensorio::Bundle;
use stratus::nn::testutil::{randi, Lcg};
use stratus::nn::Params;
use stratus::runtime::Runtime;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn conv_fp_artifact_matches_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let mut rng = Lcg::new(11);
    let x = randi(&mut rng, &[3, 32, 32], 300);
    let w = randi(&mut rng, &[16, 3, 3, 3], 150);
    let b = randi(&mut rng, &[16], 2000);
    let outs = rt.execute("conv_fp_c1_1x", &[&x, &w, &b]).unwrap();
    let want = conv_fp_std(&x, &w, b.data(), true);
    assert_eq!(outs[0], want, "PJRT conv_fp != golden conv_fp");
}

#[test]
fn conv_bp_and_wu_artifacts_match_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let mut rng = Lcg::new(12);
    // c6 of the 1X net: 64 -> 64 @ 8x8
    let g = randi(&mut rng, &[64, 8, 8], 300);
    let w = randi(&mut rng, &[64, 64, 3, 3], 150);
    let x = randi(&mut rng, &[64, 8, 8], 300);
    let bp = rt.execute("conv_bp_c6_1x", &[&g, &w]).unwrap();
    assert_eq!(bp[0], conv_bp(&g, &w, 1));
    let wu = rt.execute("conv_wu_c6_1x", &[&x, &g]).unwrap();
    let (dw, db) = conv_wu(&x, &g, 1);
    assert_eq!(wu[0], dw);
    assert_eq!(wu[1].data(), &db[..]);
}

#[test]
fn pool_artifact_matches_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let mut rng = Lcg::new(13);
    let x = randi(&mut rng, &[16, 32, 32], 400);
    let outs = rt.execute("pool_p1_1x", &[&x]).unwrap();
    let (p, idx) = maxpool(&x, 2);
    assert_eq!(outs[0], p);
    assert_eq!(outs[1], idx);
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let mut rng = Lcg::new(14);
    let bad = randi(&mut rng, &[4, 32, 32], 300);
    let w = randi(&mut rng, &[16, 3, 3, 3], 150);
    let b = randi(&mut rng, &[16], 100);
    let err = rt.execute("conv_fp_c1_1x", &[&bad, &w, &b]).unwrap_err();
    assert!(format!("{err:#}").contains("shape"));
    assert!(rt.execute("nonexistent_op", &[]).is_err());
}

#[test]
fn testvec_replays_through_golden_model() {
    // the AOT test vector was produced by the *python* model; the rust
    // golden model must reproduce every gradient exactly
    let Some(dir) = artifacts_dir() else { return };
    let tv = Bundle::load(&dir.join("testvec_1x.bin")).unwrap();
    let params =
        Params::from_bundle(&Bundle::load(&dir.join("params_1x.bin"))
            .unwrap());
    let net = Network::cifar(1);
    let x = tv.get("x").unwrap();
    let y = tv.get("y").unwrap();
    let (loss, logits, grads) =
        golden::train_step(&net, &params, x, y.data()).unwrap();
    assert_eq!(loss, tv.get("loss").unwrap().data()[0], "loss mismatch");
    assert_eq!(logits, tv.get("logits").unwrap().data(),
               "logits mismatch");
    for name in net.param_order() {
        let want = tv.get(&format!("g_{name}")).unwrap();
        assert_eq!(&grads[&name], want, "gradient mismatch for {name}");
    }
}

#[test]
fn fused_step_artifact_matches_python_testvec() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    if !rt.manifest.ops.contains_key("fused_step_1x") {
        eprintln!("skipping: fused artifact not built");
        return;
    }
    let tv = Bundle::load(&dir.join("testvec_1x.bin")).unwrap();
    let pb = Bundle::load(&dir.join("params_1x.bin")).unwrap();
    let net = Network::cifar(1);
    let mut inputs: Vec<&Tensor> = Vec::new();
    for name in net.param_order() {
        inputs.push(pb.get(&name).unwrap());
    }
    inputs.push(tv.get("x").unwrap());
    inputs.push(tv.get("y").unwrap());
    let outs = rt.execute("fused_step_1x", &inputs).unwrap();
    assert_eq!(outs[0].data()[0], tv.get("loss").unwrap().data()[0]);
    assert_eq!(&outs[1], tv.get("logits").unwrap());
    for (i, name) in net.param_order().iter().enumerate() {
        let want = tv.get(&format!("g_{name}")).unwrap();
        assert_eq!(&outs[2 + i], want, "fused grad mismatch for {name}");
    }
}

#[test]
fn all_backends_produce_identical_parameters() {
    // train the same batch through Golden / PerOp / Fused: the updated
    // parameters must be IDENTICAL integers across all three
    let Some(dir) = artifacts_dir() else { return };
    let net = Network::cifar(1);
    let data = Synthetic::cifar_like(21);
    let batch = data.batch(0, 2);

    let mut final_params: Vec<Vec<i32>> = Vec::new();
    for backend in [Backend::Golden, Backend::PerOp, Backend::Fused] {
        // artifacts ride along for golden too (ignored by its
        // numerics) so all three specs describe the same run shape
        let spec = Spec::builder()
            .preset("1x")
            .backend(backend)
            .artifacts(&dir)
            .batch(2)
            .lr(0.002)
            .momentum(0.9)
            .build()
            .unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        if backend == Backend::Golden {
            // Golden falls back to rust init; force the bundle params so
            // all three start identical
            let pb = Bundle::load(&dir.join("params_1x.bin")).unwrap();
            t.params = Params::from_bundle(&pb);
        }
        t.train_batch(&batch).unwrap();
        let mut flat = Vec::new();
        for name in net.param_order() {
            flat.extend_from_slice(t.params.get(&name).unwrap().data());
        }
        final_params.push(flat);
    }
    assert_eq!(final_params[0], final_params[1],
               "Golden vs PerOp diverged");
    assert_eq!(final_params[0], final_params[2],
               "Golden vs Fused diverged");
}

#[test]
fn per_op_training_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let spec = Spec::builder()
        .preset("1x")
        .backend(Backend::PerOp)
        .artifacts(&dir)
        .batch(4)
        .lr(0.01)
        .momentum(0.9)
        .build()
        .unwrap();
    let mut t = Session::new(spec).unwrap().trainer().unwrap();
    let data = Synthetic::cifar_like(31);
    let batch = data.batch(0, 4);
    let first = t.train_batch(&batch).unwrap();
    let mut last = first;
    for _ in 0..3 {
        last = t.train_batch(&batch).unwrap();
    }
    assert!(last < first, "per-op loss {first} -> {last}");
    assert!(t.metrics.sim_cycles > 0.0);
}

#[test]
fn golden_forward_agrees_with_per_op_logits() {
    // label encoding sanity + forward equivalence on fresh samples
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let net = Network::cifar(1);
    let pb = Bundle::load(&dir.join("params_1x.bin")).unwrap();
    let params = Params::from_bundle(&pb);
    let data = Synthetic::cifar_like(41);
    for i in 0..3 {
        let s = data.sample(i);
        let (logits, cache) =
            golden::forward(&net, &params, &s.image).unwrap();
        // run just the first conv through PJRT and compare the cache
        let w = params.get("w_c1").unwrap();
        let b = params.get("b_c1").unwrap();
        let outs = rt.execute("conv_fp_c1_1x", &[&s.image, w, b]).unwrap();
        assert_eq!(&outs[0], &cache.acts["c1"]);
        let y = encode_label(s.label, 10);
        assert_eq!(y.len(), logits.len());
        let _ = FA;
    }
}

// ------------------- failure injection -------------------

#[test]
fn corrupted_hlo_artifact_fails_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    // copy the artifacts dir metadata into a temp dir with one corrupted
    // artifact; the runtime must surface a compile/parse error for that
    // op and keep working for the rest
    let tmp = std::env::temp_dir().join("stratus_corrupt_test");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for f in ["manifest.json", "params_1x.bin", "testvec_1x.bin"] {
        std::fs::copy(dir.join(f), tmp.join(f)).unwrap();
    }
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            std::fs::copy(&p, tmp.join(p.file_name().unwrap())).unwrap();
        }
    }
    std::fs::write(tmp.join("fc_bp_1x.hlo.txt"), "NOT VALID HLO ((")
        .unwrap();
    let rt = Runtime::open(&tmp).unwrap();
    let mut rng = Lcg::new(50);
    let g = randi(&mut rng, &[1, 10], 100);
    let w = randi(&mut rng, &[10, 1024], 100);
    let err = rt.execute("fc_bp_1x", &[&g, &w]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fc_bp_1x") || msg.contains("parsing"),
            "unexpected error: {msg}");
    // an untouched op still works
    let x = randi(&mut rng, &[1, 1024], 100);
    let b = randi(&mut rng, &[10], 100);
    assert!(rt.execute("fc_fp_1x", &[&x, &w, &b]).is_ok());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn qformat_mismatch_rejected_at_open() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join("stratus_qformat_test");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))
        .unwrap()
        .replace("\"fa\": 8", "\"fa\": 9");
    std::fs::write(tmp.join("manifest.json"), manifest).unwrap();
    let err = match Runtime::open(&tmp) {
        Err(e) => e,
        Ok(_) => panic!("expected Q-format error"),
    };
    assert!(format!("{err:#}").contains("Q-format"));
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_artifacts_dir_reports_make_hint() {
    let err = match Runtime::open(Path::new("/nonexistent/artifacts")) {
        Err(e) => e,
        Ok(_) => panic!("expected open error"),
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn truncated_param_bundle_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let blob = std::fs::read(dir.join("params_1x.bin")).unwrap();
    let cut = &blob[..blob.len() / 2];
    assert!(Bundle::from_bytes(cut).is_err());
}
