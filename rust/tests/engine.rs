//! Engine equivalence suite (ISSUE 1 acceptance criterion): the
//! batch-parallel engine must be a pure performance transform — same
//! seed, same batch stream, any worker count => bit-identical
//! parameters, losses, and optimizer state after every `end_batch`.
//! Exercises the paper-scale 1X network, uneven shard splits, and
//! multi-epoch momentum state.

use stratus::config::Network;
use stratus::coordinator::Trainer;
use stratus::data::Synthetic;
use stratus::session::{NetSource, Session, Spec};

/// Session-built trainer (the per-scale design defaults are resolved
/// by the spec from the network's scale tag).
fn trainer(src: &NetSource, batch: usize, workers: usize) -> Trainer {
    let spec = Spec::builder()
        .net(src.clone())
        .batch(batch)
        .lr(0.002)
        .momentum(0.9)
        .workers(workers)
        .build()
        .unwrap();
    Session::new(spec).unwrap().trainer().unwrap()
}

fn assert_equivalent(src: &NetSource, batch_images: usize,
                     batches: usize, workers: usize) {
    let net: Network = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let stream = data.batch(0, batch_images * batches);
    let mut seq = trainer(src, batch_images, 1);
    let mut par = trainer(src, batch_images, workers);
    for chunk in stream.chunks(batch_images) {
        let l_seq = seq.train_batch(chunk).unwrap();
        let l_par = par.train_batch(chunk).unwrap();
        assert_eq!(l_seq, l_par, "loss diverged at {workers} workers");
    }
    assert_eq!(seq.flat_params(), par.flat_params(),
               "parameters diverged at {workers} workers");
    for ((n, s), (_, p)) in
        seq.param_states().iter().zip(par.param_states())
    {
        assert_eq!(s.grad_acc, p.grad_acc, "{n} grad_acc");
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    assert_eq!(seq.metrics.images, par.metrics.images);
    assert_eq!(seq.metrics.loss_sum, par.metrics.loss_sum);
    assert_eq!(seq.metrics.sim_cycles, par.metrics.sim_cycles);
}

fn tiny_net() -> NetSource {
    NetSource::inline(
        "input 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
}

#[test]
fn tiny_net_four_workers_two_batches() {
    assert_equivalent(&tiny_net(), 8, 2, 4);
}

#[test]
fn tiny_net_uneven_shards() {
    // 10 images over 4 workers -> shards of 3/3/2/2
    assert_equivalent(&tiny_net(), 10, 1, 4);
}

#[test]
fn tiny_net_more_workers_than_batch() {
    assert_equivalent(&tiny_net(), 3, 1, 16);
}

#[test]
fn cifar_1x_two_workers_one_batch() {
    // the paper-scale network (32x32 input, 14 parameter tensors)
    assert_equivalent(&NetSource::preset("1x"), 4, 1, 2);
}

#[test]
fn engine_report_reflects_sharding() {
    let src = tiny_net();
    let net = src.resolve().unwrap();
    let data = Synthetic::new(net.nclass, net.input, 5, 0.3);
    let batch = data.batch(0, 10);
    let mut t = trainer(&src, 10, 4);
    t.train_batch(&batch).unwrap();
    let rep = t.last_engine.as_ref().unwrap();
    assert_eq!(rep.workers, 4);
    assert_eq!(rep.images, 10);
    assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
    assert!(rep.wall_seconds >= 0.0);
    assert!(t.metrics.images_per_second() > 0.0);
}
