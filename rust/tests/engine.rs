//! Engine equivalence suite (ISSUE 1 acceptance criterion): the
//! batch-parallel engine must be a pure performance transform — same
//! seed, same batch stream, any worker count => bit-identical
//! parameters, losses, and optimizer state after every `end_batch`.
//! Exercises the paper-scale 1X network, uneven shard splits, and
//! multi-epoch momentum state.

use stratus::config::{DesignVars, Network};
use stratus::coordinator::{Backend, Trainer};
use stratus::data::Synthetic;

fn trainer(net: &Network, batch: usize, workers: usize) -> Trainer {
    let scale = match net.scale_tag() {
        "4x" => 4,
        "2x" => 2,
        _ => 1,
    };
    Trainer::new(net, &DesignVars::for_scale(scale), batch, 0.002, 0.9,
                 Backend::Golden, None)
        .unwrap()
        .with_workers(workers)
}

fn assert_equivalent(net: &Network, batch_images: usize, batches: usize,
                     workers: usize) {
    let data = Synthetic::new(net.nclass, net.input, 77, 0.3);
    let stream = data.batch(0, batch_images * batches);
    let mut seq = trainer(net, batch_images, 1);
    let mut par = trainer(net, batch_images, workers);
    for chunk in stream.chunks(batch_images) {
        let l_seq = seq.train_batch(chunk).unwrap();
        let l_par = par.train_batch(chunk).unwrap();
        assert_eq!(l_seq, l_par, "loss diverged at {workers} workers");
    }
    assert_eq!(seq.flat_params(), par.flat_params(),
               "parameters diverged at {workers} workers");
    for ((n, s), (_, p)) in
        seq.param_states().iter().zip(par.param_states())
    {
        assert_eq!(s.grad_acc, p.grad_acc, "{n} grad_acc");
        assert_eq!(s.momentum, p.momentum, "{n} momentum");
        assert_eq!(s.count, p.count, "{n} count");
    }
    assert_eq!(seq.metrics.images, par.metrics.images);
    assert_eq!(seq.metrics.loss_sum, par.metrics.loss_sum);
    assert_eq!(seq.metrics.sim_cycles, par.metrics.sim_cycles);
}

fn tiny_net() -> Network {
    Network::parse(
        "input 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
    .unwrap()
}

#[test]
fn tiny_net_four_workers_two_batches() {
    assert_equivalent(&tiny_net(), 8, 2, 4);
}

#[test]
fn tiny_net_uneven_shards() {
    // 10 images over 4 workers -> shards of 3/3/2/2
    assert_equivalent(&tiny_net(), 10, 1, 4);
}

#[test]
fn tiny_net_more_workers_than_batch() {
    assert_equivalent(&tiny_net(), 3, 1, 16);
}

#[test]
fn cifar_1x_two_workers_one_batch() {
    // the paper-scale network (32x32 input, 14 parameter tensors)
    assert_equivalent(&Network::cifar(1), 4, 1, 2);
}

#[test]
fn engine_report_reflects_sharding() {
    let net = tiny_net();
    let data = Synthetic::new(net.nclass, net.input, 5, 0.3);
    let batch = data.batch(0, 10);
    let mut t = trainer(&net, 10, 4);
    t.train_batch(&batch).unwrap();
    let rep = t.last_engine.as_ref().unwrap();
    assert_eq!(rep.workers, 4);
    assert_eq!(rep.images, 10);
    assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
    assert!(rep.wall_seconds >= 0.0);
    assert!(t.metrics.images_per_second() > 0.0);
}
