//! Report generation: renders the paper's tables and figures from
//! simulation / GPU-model / resource outputs as aligned text tables
//! (consumed by the CLI `report` subcommand and the bench harnesses, and
//! pasted into EXPERIMENTS.md).  The [`bench`] submodule carries the
//! bench-record / perf-regression-gate support the CI smoke jobs use.

pub mod bench;

use crate::compiler::{Accelerator, RtlCompiler};
use crate::config::{DesignVars, Network};
use crate::gpu_model::titan_xp;
use crate::hw::bram::BufferPlan;
use crate::sim::{simulate, SimReport};

/// CIFAR-10 training-set size used for epoch latencies (Table II).
pub const EPOCH_IMAGES: u64 = 50_000;

/// Render a simple aligned table.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> =
        header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {c:<w$} |"));
        }
        line
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&"-".repeat(w + 2));
        out.push('|');
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

fn compile(scale: usize) -> Accelerator {
    RtlCompiler::default()
        .compile(&Network::cifar(scale), &DesignVars::for_scale(scale))
        .expect("paper configs always compile")
}

/// Table II: resources, power, latency/epoch at BS 10/20/40, GOPS.
pub fn table2() -> String {
    let header = [
        "CNN", "DSP", "ALM", "BRAM(Mb)", "P.dsp", "P.ram", "P.logic",
        "P.clk", "P.static", "BS-10(s)", "BS-20(s)", "BS-40(s)", "GOPS",
    ];
    let mut rows = Vec::new();
    for scale in [1, 2, 4] {
        let acc = compile(scale);
        let r = &acc.resources;
        let p = &acc.power;
        let epochs: Vec<f64> = [10, 20, 40]
            .iter()
            .map(|&bs| simulate(&acc, bs).seconds_per_epoch(EPOCH_IMAGES))
            .collect();
        let gops = simulate(&acc, 40).gops();
        rows.push(vec![
            format!("CIFAR-10 {scale}X"),
            format!("{} ({:.0}%)", r.dsp, r.dsp_frac * 100.0),
            format!("{:.1}K ({:.0}%)", r.alm as f64 / 1e3,
                    r.alm_frac * 100.0),
            format!("{:.1} ({:.1}%)", r.bram_mbits, r.bram_frac * 100.0),
            format!("{:.2}", p.dsp_w),
            format!("{:.1}", p.ram_w),
            format!("{:.1}", p.logic_w),
            format!("{:.2}", p.clock_w),
            format!("{:.2}", p.static_w),
            format!("{:.2}", epochs[0]),
            format!("{:.2}", epochs[1]),
            format!("{:.2}", epochs[2]),
            format!("{:.0}", gops),
        ]);
    }
    render_table(&header, &rows)
}

/// Table III: FPGA vs Titan XP throughput and efficiency at BS 1 / 40.
pub fn table3() -> String {
    let header = [
        "CNN", "GPU B1 GOPS", "GPU B40 GOPS", "FPGA GOPS",
        "GPU B1 GOPS/W", "GPU B40 GOPS/W", "FPGA GOPS/W",
    ];
    let mut rows = Vec::new();
    for scale in [1, 2, 4] {
        let acc = compile(scale);
        let net = Network::cifar(scale);
        let fpga = simulate(&acc, 40);
        let fpga_gops = fpga.gops();
        let fpga_w = acc.power.total();
        let g1 = titan_xp(&net, 1);
        let g40 = titan_xp(&net, 40);
        rows.push(vec![
            format!("CIFAR-10 {scale}X"),
            format!("{:.2}", g1.gops),
            format!("{:.2}", g40.gops),
            format!("{:.0}", fpga_gops),
            format!("{:.2}", g1.efficiency()),
            format!("{:.2}", g40.efficiency()),
            format!("{:.2}", fpga_gops / fpga_w),
        ]);
    }
    render_table(&header, &rows)
}

/// Fig. 9: latency breakdown of the 4X CNN by phase, logic vs DRAM.
pub fn fig9() -> String {
    let acc = compile(4);
    let r: SimReport = simulate(&acc, 40);
    let header = ["Phase", "Logic (ms)", "DRAM (ms)", "Latency (ms)",
                  "% of iter"];
    let total: f64 =
        r.breakdown_ms().iter().map(|(_, _, _, l)| l).sum();
    let rows: Vec<Vec<String>> = r
        .breakdown_ms()
        .iter()
        .map(|(phase, logic, dram, lat)| {
            vec![
                phase.to_string(),
                format!("{logic:.3}"),
                format!("{dram:.3}"),
                format!("{lat:.3}"),
                format!("{:.1}%", lat / total * 100.0),
            ]
        })
        .collect();
    render_table(&header, &rows)
}

/// Batch-parallel engine scaling (ISSUE 1 tentpole): simulated per-image
/// latency and throughput when a batch is sharded across N replicated
/// accelerator instances — the hardware analogue of the host engine's
/// `train --workers N`.  The batch-end weight update stays serialized on
/// the merged accumulators, so speedup is sublinear by exactly that
/// term.
pub fn engine_scaling(scale: usize, batch: usize, engines: &[usize])
                      -> String {
    let acc = compile(scale);
    let r = simulate(&acc, batch);
    let base = r.sharded_images_per_second(1);
    let header = ["engines", "iter cycles", "ms/image", "images/s",
                  "speedup"];
    let rows: Vec<Vec<String>> = engines
        .iter()
        .map(|&e| {
            let ips = r.sharded_images_per_second(e);
            let iter = r.sharded_cycles_per_iteration(e);
            vec![
                format!("{e}"),
                format!("{iter}"),
                format!("{:.3}",
                        iter as f64 / batch as f64 / r.clock_hz * 1e3),
                format!("{ips:.0}"),
                format!("{:.2}x", ips / base),
            ]
        })
        .collect();
    render_table(&header, &rows)
}

/// Cluster scaling (ISSUE 2 tentpole): simulated batch-iteration
/// latency and throughput when training runs data-parallel across N
/// accelerator instances with a ring all-reduce of the WU gradient
/// accumulators between batch accumulation and the weight update.
/// Unlike [`engine_scaling`], the projection charges the
/// inter-accelerator communication the compiled cluster schedule
/// carries, so efficiency degrades with N instead of only the
/// serialized update.
pub fn cluster_scaling(scale: usize, batch: usize, instances: &[usize])
                       -> String {
    let net = Network::cifar(scale);
    let sim_at = |n: usize| {
        let mut dv = DesignVars::for_scale(scale);
        dv.cluster = n.max(1);
        let acc = RtlCompiler::default()
            .compile(&net, &dv)
            .expect("paper configs always compile");
        simulate(&acc, batch)
    };
    // one compile+simulate per instance count; the 1-instance baseline
    // falls out of any report's sharded projection (the per-image and
    // update phases are cluster-independent)
    let reports: Vec<(usize, SimReport)> =
        instances.iter().map(|&n| (n, sim_at(n))).collect();
    let base = reports
        .first()
        .map_or(1.0, |(_, r)| r.sharded_images_per_second(1));
    let header = ["instances", "iter cycles", "all-reduce cyc",
                  "images/s", "speedup", "efficiency"];
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|(n, r)| {
            let ips = r.cluster_images_per_second();
            vec![
                format!("{n}"),
                format!("{}", r.cluster_cycles_per_iteration()),
                format!("{}", r.allreduce.latency_cycles),
                format!("{ips:.0}"),
                format!("{:.2}x", ips / base),
                format!("{:.0}%",
                        ips / base / (*n).max(1) as f64 * 100.0),
            ]
        })
        .collect();
    render_table(&header, &rows)
}

/// Per-topology cluster projections (ISSUE 8 tentpole): ring vs
/// hierarchical all-reduce at each instance count under the same link
/// parameters.  Both topologies train bit-identically (wrapping-i32
/// reduction is associative), so the table is purely a performance
/// comparison; the `auto` column shows which plan `--topology auto`
/// resolves to.  `hier` falls back to the flat ring when no proper
/// divisor grouping exists (N prime or < 4), where the two columns
/// coincide.
pub fn topology_scaling(scale: usize, batch: usize,
                        instances: &[usize]) -> String {
    use crate::compiler::choose_collective;
    use crate::config::Topology;
    use crate::hw::link::LinkModel;
    let net = Network::cifar(scale);
    let sim_at = |n: usize, topo: Topology| {
        let mut dv = DesignVars::for_scale(scale);
        dv.cluster = n.max(1);
        dv.topology = topo;
        let acc = RtlCompiler::default()
            .compile(&net, &dv)
            .expect("paper configs always compile");
        let steps = acc.schedule.collective.len();
        (steps, simulate(&acc, batch))
    };
    let header = ["instances", "ring ar-cyc", "hier ar-cyc",
                  "hier steps", "hier speedup", "auto"];
    let rows: Vec<Vec<String>> = instances
        .iter()
        .map(|&n| {
            let (_, ring) = sim_at(n, Topology::Ring);
            let (hsteps, hier) = sim_at(n, Topology::Hier);
            let mut dv = DesignVars::for_scale(scale);
            dv.cluster = n.max(1);
            let auto = choose_collective(Topology::Auto, n.max(1),
                                         net.ring_words() as u64,
                                         &LinkModel::new(&dv));
            let rc = ring.cluster_cycles_per_iteration() as f64;
            let hc = hier.cluster_cycles_per_iteration() as f64;
            vec![
                format!("{n}"),
                format!("{}", ring.allreduce.latency_cycles),
                format!("{}", hier.allreduce.latency_cycles),
                format!("{hsteps}"),
                format!("{:.2}x", rc / hc.max(1.0)),
                auto.name().to_string(),
            ]
        })
        .collect();
    render_table(&header, &rows)
}

/// Bucketed-overlap projection table (`report overlap`): per instance
/// count and topology, how much of the bucketed all-reduce hides under
/// the backward pass and what stays exposed, against the monolithic
/// serial epilogue — the pipelined cluster engine's headline effect.
pub fn overlap_scaling(scale: usize, batch: usize,
                       instances: &[usize]) -> String {
    use crate::config::Topology;
    use crate::sim::project_overlap;
    let net = Network::cifar(scale);
    let project = |n: usize, topo: Topology| {
        let mut dv = DesignVars::for_scale(scale);
        dv.cluster = n.max(1);
        dv.topology = topo;
        dv.bucket_kwords = 32;
        let acc = RtlCompiler::default()
            .compile(&net, &dv)
            .expect("paper configs always compile");
        project_overlap(&acc, batch)
    };
    let header = ["instances", "topology", "buckets", "serial-cyc",
                  "hidden-cyc", "exposed-cyc", "comm saved"];
    let mut rows = Vec::new();
    for &n in instances {
        for topo in [Topology::Ring, Topology::Hier] {
            let r = project(n, topo);
            let saved = r.serial_comm_cycles as f64
                - r.exposed_comm_cycles as f64;
            rows.push(vec![
                format!("{n}"),
                format!("{topo:?}").to_lowercase(),
                format!("{}", r.buckets.len()),
                format!("{}", r.serial_comm_cycles),
                format!("{}", r.hidden_comm_cycles),
                format!("{}", r.exposed_comm_cycles),
                format!("{:.0}%",
                        100.0 * saved
                            / (r.serial_comm_cycles as f64).max(1.0)),
            ]);
        }
    }
    render_table(&header, &rows)
}

/// Fig. 10: buffer usage breakdown of the 4X design.
pub fn fig10() -> String {
    let net = Network::cifar(4);
    let dv = DesignVars::for_scale(4);
    let plan = BufferPlan::plan(&net, &dv);
    let header = ["Buffer group", "Kbit", "% of on-chip"];
    let total = plan.total_bits() as f64;
    let rows: Vec<Vec<String>> = plan
        .bits_by_group()
        .iter()
        .map(|(g, bits)| {
            vec![
                format!("{g:?}"),
                format!("{:.1}", *bits as f64 / 1e3),
                format!("{:.1}%", *bits as f64 / total * 100.0),
            ]
        })
        .collect();
    render_table(&header, &rows)
}

/// The `report serve` / `stratus serve --status` snapshot: every run
/// in the serve root (phase, priority, slice/batch accounting,
/// cursor), aggregate phase counts, and — when the event log spans
/// wall-clock time — the service's overall batch throughput.  Reads
/// only; a status query never mutates the root it inspects.
pub fn serve_report(root: &std::path::Path)
                    -> anyhow::Result<String> {
    use crate::jsonx::Json;
    use crate::serve::{read_events, scan_states, RunPhase};

    let runs = scan_states(root)?;
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![r.id.clone(),
                 r.priority.to_string(),
                 r.phase.name().to_string(),
                 r.slices.to_string(),
                 r.batches.to_string(),
                 format!("{}.{}", r.epoch, r.batch),
                 r.epochs.to_string(),
                 r.source.clone()]
        })
        .collect();
    let mut out = render_table(&["run", "pri", "phase", "slices",
                                 "batches", "cursor", "epochs",
                                 "source"],
                               &rows);
    let count = |p: RunPhase| {
        runs.iter().filter(|r| r.phase == p).count()
    };
    out.push_str(&format!(
        "runs           : {} queued / {} running / {} done / {} \
         failed\n",
        count(RunPhase::Queued), count(RunPhase::Running),
        count(RunPhase::Done), count(RunPhase::Failed)));
    let batches: u64 = runs.iter().map(|r| r.batches).sum();
    out.push_str(&format!(
        "progress       : {} slices, {batches} batches\n",
        runs.iter().map(|r| r.slices).sum::<u64>()));
    let events = read_events(root)?;
    let stamps: Vec<f64> = events
        .iter()
        .filter_map(|e| e.get("unix_ms").and_then(Json::as_f64))
        .collect();
    if let (Some(first), Some(last)) = (stamps.first(),
                                        stamps.last()) {
        let span_s = (last - first) / 1e3;
        let mut line = format!(
            "events         : {} over {span_s:.1} s", events.len());
        if span_s > 0.0 {
            line.push_str(&format!(" ({:.1} batches/s)",
                                   batches as f64 / span_s));
        }
        line.push('\n');
        out.push_str(&line);
    }
    for r in runs.iter().filter(|r| r.phase == RunPhase::Failed) {
        out.push_str(&format!(
            "failed         : {}: {}\n", r.id,
            r.error.as_deref().unwrap_or("(no reason recorded)")));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let t = render_table(&["a", "bb"],
                             &[vec!["xxx".into(), "y".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn table2_has_three_rows() {
        let t = table2();
        assert!(t.contains("CIFAR-10 1X"));
        assert!(t.contains("CIFAR-10 4X"));
        assert_eq!(t.lines().count(), 5);
    }

    #[test]
    fn table3_fpga_wins_b1_efficiency() {
        // the paper's headline: FPGA efficiency beats GPU at batch 1
        let t = table3();
        assert!(t.contains("CIFAR-10 2X"));
        assert_eq!(t.lines().count(), 5);
    }

    #[test]
    fn fig9_percentages_sum_to_100() {
        let t = fig9();
        let sum: f64 = t
            .lines()
            .skip(2)
            .filter_map(|l| {
                l.split('|')
                    .nth(5)
                    .and_then(|c| c.trim().trim_end_matches('%')
                              .parse::<f64>().ok())
            })
            .sum();
        assert!((sum - 100.0).abs() < 0.5, "sum = {sum}");
    }

    #[test]
    fn engine_scaling_reports_monotone_speedup() {
        let t = engine_scaling(1, 40, &[1, 2, 4, 8]);
        assert_eq!(t.lines().count(), 6);
        let speedups: Vec<f64> = t
            .lines()
            .skip(2)
            .filter_map(|l| {
                l.split('|')
                    .nth(5)
                    .and_then(|c| c.trim().trim_end_matches('x')
                              .parse::<f64>().ok())
            })
            .collect();
        assert_eq!(speedups.len(), 4);
        assert!((speedups[0] - 1.0).abs() < 1e-9);
        assert!(speedups.windows(2).all(|w| w[0] < w[1]),
                "not monotone: {speedups:?}");
    }

    #[test]
    fn cluster_scaling_charges_communication() {
        let t = cluster_scaling(1, 40, &[1, 2, 4, 8]);
        assert_eq!(t.lines().count(), 6);
        let col = |line: &str, i: usize| -> Option<f64> {
            line.split('|').nth(i).and_then(|c| {
                c.trim()
                    .trim_end_matches('x')
                    .trim_end_matches('%')
                    .parse::<f64>()
                    .ok()
            })
        };
        let rows: Vec<&str> = t.lines().skip(2).collect();
        // all-reduce cycles: zero at 1 instance, nonzero and growing after
        let ar: Vec<f64> =
            rows.iter().filter_map(|l| col(l, 3)).collect();
        assert_eq!(ar.len(), 4);
        assert_eq!(ar[0], 0.0);
        assert!(ar[1] > 0.0);
        assert!(ar.windows(2).skip(1).all(|w| w[0] < w[1]),
                "all-reduce not growing: {ar:?}");
        // speedup monotone but sublinear (efficiency < 100% beyond 1)
        let sp: Vec<f64> =
            rows.iter().filter_map(|l| col(l, 5)).collect();
        assert!((sp[0] - 1.0).abs() < 1e-9);
        assert!(sp.windows(2).all(|w| w[0] < w[1]),
                "not monotone: {sp:?}");
        assert!(sp[3] < 8.0);
    }

    #[test]
    fn topology_scaling_shows_hier_winning_at_scale() {
        let t = topology_scaling(1, 40, &[4, 64]);
        assert_eq!(t.lines().count(), 4);
        let col = |line: &str, i: usize| -> Option<String> {
            line.split('|').nth(i).map(|c| c.trim().to_string())
        };
        let rows: Vec<&str> = t.lines().skip(2).collect();
        // at N = 64 the grouped collective beats the flat ring and
        // auto resolves to it (ISSUE 8 acceptance criterion)
        let ring: f64 = col(rows[1], 2).unwrap().parse().unwrap();
        let hier: f64 = col(rows[1], 3).unwrap().parse().unwrap();
        assert!(hier < ring, "hier {hier} !< ring {ring} at N=64");
        assert_eq!(col(rows[1], 6).unwrap(), "hier");
        // the auto column only ever names a real collective
        for r in &rows {
            let a = col(r, 6).unwrap();
            assert!(a == "ring" || a == "hier", "auto = {a}");
        }
    }

    #[test]
    fn fig10_has_all_groups() {
        let t = fig10();
        for g in ["Input", "Output", "Weight", "WeightGradient",
                  "PoolIndex", "ActGradientMask"] {
            assert!(t.contains(g), "{g} missing");
        }
    }

    #[test]
    fn overlap_scaling_hides_communication() {
        let t = overlap_scaling(1, 64, &[4, 16]);
        // header + separator + (2 instance counts x 2 topologies)
        assert_eq!(t.lines().count(), 6);
        let col = |line: &str, i: usize| -> Option<f64> {
            line.split('|').nth(i).and_then(|c| {
                c.trim().trim_end_matches('%').parse::<f64>().ok()
            })
        };
        for r in t.lines().skip(2) {
            let buckets = col(r, 3).unwrap();
            assert!(buckets > 1.0, "no bucketing in row: {r}");
            let hidden = col(r, 5).unwrap();
            assert!(hidden > 0.0, "nothing hidden in row: {r}");
            // exposed never exceeds the serial epilogue at these
            // scales (ring's small-N plans and hier's grouped ones
            // both fit under the backward pass)
            let serial = col(r, 4).unwrap();
            let exposed = col(r, 6).unwrap();
            assert!(exposed <= serial,
                    "exposed {exposed} > serial {serial}: {r}");
        }
    }

    #[test]
    fn serve_report_renders_runs_and_aggregates() {
        use crate::serve::{RunPhase, RunState, ServeRoot};
        let root = std::env::temp_dir().join(format!(
            "stratus_mreport_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let sr = ServeRoot::open(&root).unwrap();
        for (id, seq, phase, err) in [
            ("r0001-a", 1, RunPhase::Done, None),
            ("r0002-b", 2, RunPhase::Failed,
             Some("batch 128 can wrap".to_string())),
        ] {
            let dir = sr.run_dir(id);
            std::fs::create_dir_all(&dir).unwrap();
            RunState {
                id: id.to_string(),
                seq,
                priority: 1,
                source: format!("{id}.json"),
                phase,
                slices: 2,
                batches: 6,
                epoch: 2,
                batch: 0,
                epochs: 2,
                error: err,
            }
            .save_atomic(&dir)
            .unwrap();
        }
        let t = serve_report(&root).unwrap();
        assert!(t.contains("| r0001-a |"), "{t}");
        assert!(t.contains("| done "), "{t}");
        assert!(t.contains("1 done / 1 failed"), "{t}");
        assert!(t.contains("4 slices, 12 batches"), "{t}");
        assert!(t.contains("r0002-b: batch 128 can wrap"), "{t}");
        // a directory that is not a serve root is refused
        assert!(serve_report(&root.join("nope")).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }
}
