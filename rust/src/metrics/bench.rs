//! Bench-harness support: smoke-mode detection, `BENCH_<name>.json`
//! result records, and the CI perf-regression comparator.
//!
//! The benches are plain `fn main` reports (no criterion in the offline
//! registry — DESIGN.md §Substitutions), so the regression gate lives
//! here in the library where every bench target and the unit tests can
//! reach it: a bench measures its headline `images_per_second`, writes
//! a JSON record next to the crate manifest (CI uploads it as a
//! workflow artifact), and exits nonzero when the result drops more
//! than [`MAX_DROP`] below the checked-in `benches/baseline.json`
//! entry.  Baselines are deliberately conservative floors (shared CI
//! runners are slow and noisy); ratchet them upward as the engine gets
//! faster.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::jsonx::Json;

/// Fractional drop below the baseline that fails the gate (30%).
pub const MAX_DROP: f64 = 0.30;

/// One bench's headline result plus free-form extra metrics.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub images_per_second: f64,
    pub smoke: bool,
    pub extra: Vec<(String, f64)>,
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

impl BenchRecord {
    pub fn new(name: &str, images_per_second: f64, smoke: bool)
               -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            images_per_second,
            smoke,
            extra: Vec::new(),
        }
    }

    /// Attach an extra metric to the record.
    pub fn push(&mut self, key: &str, value: f64) {
        self.extra.push((key.to_string(), value));
    }

    /// Render as a JSON object (insertion order preserved).
    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"name\":{:?},\"images_per_second\":{},\"smoke\":{}",
            self.name,
            fmt_f64(self.images_per_second),
            self.smoke
        );
        for (k, v) in &self.extra {
            s.push_str(&format!(",{k:?}:{}", fmt_f64(*v)));
        }
        s.push('}');
        s
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// True when a bench should run its fast CI configuration (`--smoke`
/// argument or `BENCH_SMOKE=1` in the environment).
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// Compare a result against the baseline file.  `Ok(None)` when the
/// bench has no baseline entry (informational run), `Ok(Some(msg))`
/// when within bounds, `Err` when the result regressed more than
/// [`MAX_DROP`] below baseline — or when the measurement itself is
/// non-finite (`inf` from an elapsed time that rounded to zero, or
/// NaN): every float comparison against the floor is false for those,
/// so without this check a broken measurement would sail through the
/// gate as "ok".
pub fn check_baseline(baseline: &Path, name: &str,
                      images_per_second: f64) -> Result<Option<String>> {
    if !images_per_second.is_finite() {
        return Err(anyhow!(
            "invalid measurement: {name} reported images_per_second = \
             {images_per_second} (non-finite; did the measured interval \
             round to zero?) — refusing to gate on it"
        ));
    }
    let text = std::fs::read_to_string(baseline)
        .with_context(|| format!("reading {}", baseline.display()))?;
    let json = Json::parse(&text)
        .with_context(|| format!("parsing {}", baseline.display()))?;
    let Some(base) = json
        .get(name)
        .and_then(|e| e.get("images_per_second"))
        .and_then(Json::as_f64)
    else {
        return Ok(None);
    };
    let floor = base * (1.0 - MAX_DROP);
    // measured/floor headroom: the number a ratchet decision reads
    // straight from the CI log (ISSUE 7 satellite) — >> 1.0 means the
    // floor is stale and should move up
    let ratio = if floor > 0.0 {
        images_per_second / floor
    } else {
        f64::INFINITY
    };
    if images_per_second < floor {
        return Err(anyhow!(
            "perf regression: {name} at {images_per_second:.1} images/s \
             is more than {:.0}% below the baseline {base:.1} (floor \
             {floor:.1}, measured/floor {ratio:.2}x); investigate \
             before ratcheting benches/baseline.json",
            MAX_DROP * 100.0
        ));
    }
    Ok(Some(format!(
        "{name}: {images_per_second:.1} images/s vs baseline {base:.1} \
         (floor {floor:.1}, measured/floor {ratio:.2}x) — ok"
    )))
}

/// Headline images/s of the previous `BENCH_<name>.json` in `dir`, if
/// one exists and parses — the last run's record on this machine (CI
/// keeps the cross-run trajectory as SHA-named artifacts instead).
pub fn previous_record(dir: &Path, name: &str) -> Option<f64> {
    let path = dir.join(format!("BENCH_{name}.json"));
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text)
        .ok()?
        .get("images_per_second")
        .and_then(Json::as_f64)
}

/// Bench epilogue: write the record next to the crate manifest and gate
/// it against `benches/baseline.json`.  Returns the process exit code
/// (0 ok, 1 on write failure or perf regression).
pub fn finish(record: &BenchRecord) -> i32 {
    finish_gated(record, &[])
}

/// Bench epilogue for a record carrying several gated series (the
/// per-kernel hotpath bench): write the record, print the previous
/// on-disk record's headline when one exists, then gate the headline
/// *plus* every `(name, images_per_second)` in `extra_gates` against
/// `benches/baseline.json`.  The record is written before any gate
/// decides the exit code, so a regressed run still uploads its
/// diagnostics in CI; all gates run even after one fails, so the log
/// shows every verdict.  Returns the process exit code.
pub fn finish_gated(record: &BenchRecord, extra_gates: &[(&str, f64)])
                    -> i32 {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    // read the previous record before overwriting it
    let prev = previous_record(manifest, &record.name);
    match record.write(manifest) {
        Ok(p) => println!("bench record   : wrote {}", p.display()),
        Err(e) => {
            eprintln!("bench record   : {e:#}");
            return 1;
        }
    }
    match prev {
        Some(p) if p > 0.0 => println!(
            "previous record: {p:.1} images/s -> this run {:.1} \
             ({:.2}x)",
            record.images_per_second,
            record.images_per_second / p
        ),
        Some(p) => println!("previous record: {p:.1} images/s"),
        None => println!("previous record: none on disk"),
    }
    let baseline = manifest.join("benches/baseline.json");
    let mut code = 0;
    let mut gates: Vec<(&str, f64)> =
        vec![(record.name.as_str(), record.images_per_second)];
    gates.extend_from_slice(extra_gates);
    for (name, ips) in gates {
        match check_baseline(&baseline, name, ips) {
            Ok(Some(msg)) => println!("perf gate      : {msg}"),
            Ok(None) => println!(
                "perf gate      : no baseline entry for {name} \
                 (informational)"
            ),
            Err(e) => {
                eprintln!("perf gate      : {e:#}");
                code = 1;
            }
        }
    }
    code
}

/// Measurement scaffolding shared by the scaling benches
/// (`engine_throughput`, `cluster_scaling`): per-configuration
/// throughput observations with a bit-identity check against the first
/// configuration, then the record/gate epilogue.  The record is always
/// written before the bit-identity verdict decides the exit code, so a
/// MISMATCH run still uploads its `BENCH_*.json` diagnostics in CI.
pub struct ScalingBench {
    name: &'static str,
    smoke: bool,
    reference: Option<Vec<i32>>,
    base_ips: f64,
    best_ips: f64,
    identical: bool,
}

impl ScalingBench {
    pub fn new(name: &'static str, smoke: bool) -> ScalingBench {
        ScalingBench {
            name,
            smoke,
            reference: None,
            base_ips: 0.0,
            best_ips: 0.0,
            identical: true,
        }
    }

    /// Record one configuration's throughput and final parameters.
    /// The first observation becomes the reference; returns the
    /// speedup over it and a display verdict.
    pub fn observe(&mut self, ips: f64, flat_params: Vec<i32>)
                   -> (f64, &'static str) {
        self.best_ips = self.best_ips.max(ips);
        let verdict = match &self.reference {
            None => "(reference)",
            Some(r) if *r == flat_params => "bit-identical",
            Some(_) => "MISMATCH",
        };
        if self.reference.is_none() {
            self.base_ips = ips;
            self.reference = Some(flat_params);
        } else if verdict == "MISMATCH" {
            self.identical = false;
        }
        let speedup =
            if self.base_ips > 0.0 { ips / self.base_ips } else { 1.0 };
        (speedup, verdict)
    }

    /// Write the record (best observed images/s + `extra` metrics), run
    /// the perf gate, then fold in the bit-identity verdict.  Returns
    /// the process exit code.
    pub fn finish(self, extra: &[(&str, f64)]) -> i32 {
        self.finish_with(extra, &[])
    }

    /// Like [`ScalingBench::finish`] but additionally gates every
    /// `(name, images_per_second)` in `extra_gates` against
    /// `benches/baseline.json` — the topology sweep gates its
    /// `cluster_hier` series this way without giving it a separate
    /// record file.
    pub fn finish_with(self, extra: &[(&str, f64)],
                       extra_gates: &[(&str, f64)]) -> i32 {
        let mut rec = BenchRecord::new(self.name, self.best_ips,
                                       self.smoke);
        rec.push("images_per_second_base", self.base_ips);
        rec.push("bit_identical",
                 if self.identical { 1.0 } else { 0.0 });
        for (k, v) in extra {
            rec.push(k, *v);
        }
        let code = finish_gated(&rec, extra_gates);
        if !self.identical {
            eprintln!("bit-identity   : FAILED (final params diverged \
                       from the reference configuration)");
            return 1;
        }
        code
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_baseline(text: &str) -> PathBuf {
        let path = std::env::temp_dir().join(format!(
            "stratus_baseline_{}_{text_len}.json",
            std::process::id(),
            text_len = text.len()
        ));
        std::fs::write(&path, text).unwrap();
        path
    }

    #[test]
    fn record_round_trips_through_jsonx() {
        let mut rec = BenchRecord::new("engine_throughput", 1234.5, true);
        rec.push("workers", 4.0);
        rec.push("speedup", 2.75);
        let json = Json::parse(&rec.to_json()).unwrap();
        assert_eq!(json.get("name").and_then(Json::as_str),
                   Some("engine_throughput"));
        assert_eq!(json.get("images_per_second").and_then(Json::as_f64),
                   Some(1234.5));
        assert_eq!(json.get("workers").and_then(Json::as_f64), Some(4.0));
        assert_eq!(json.get("smoke"), Some(&Json::Bool(true)));
    }

    #[test]
    fn non_finite_metrics_render_parseable() {
        let rec = BenchRecord::new("x", f64::INFINITY, false);
        let json = Json::parse(&rec.to_json()).unwrap();
        assert_eq!(json.get("images_per_second").and_then(Json::as_f64),
                   Some(0.0));
    }

    #[test]
    fn gate_rejects_non_finite_measurements() {
        // a smoke run whose elapsed time rounds to zero yields inf
        // images/s; inf > floor would otherwise read as "ok", and NaN
        // compares false both ways — both must fail the gate loudly,
        // baseline entry or not (ISSUE 3 satellite)
        let p = tmp_baseline(
            r#"{"eng":{"images_per_second":100.0}}"#,
        );
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let err = check_baseline(&p, "eng", bad).unwrap_err();
            assert!(format!("{err:#}").contains("non-finite"),
                    "bad={bad}");
            // even a bench without a baseline entry must not pass
            let err = check_baseline(&p, "unlisted", bad).unwrap_err();
            assert!(format!("{err:#}").contains("non-finite"));
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn gate_passes_within_bounds() {
        let p = tmp_baseline(
            r#"{"eng":{"images_per_second":100.0}}"#,
        );
        // 30% below exactly is still allowed; 29% below passes clearly
        assert!(check_baseline(&p, "eng", 71.0).unwrap().is_some());
        assert!(check_baseline(&p, "eng", 250.0).unwrap().is_some());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn gate_fails_on_regression() {
        let p = tmp_baseline(
            r#"{"eng":{"images_per_second":100.0},"o":{"images_per_second":1}}"#,
        );
        let err = check_baseline(&p, "eng", 60.0).unwrap_err();
        assert!(format!("{err:#}").contains("perf regression"));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn gate_messages_report_measured_over_floor_ratio() {
        // the ratchet protocol (DESIGN.md) reads the headroom ratio
        // straight out of the CI log — both verdicts must carry it.
        // own file name: tmp_baseline keys on text length, and this
        // payload's length collides with another test's
        let p = std::env::temp_dir().join(format!(
            "stratus_baseline_ratio_{}.json",
            std::process::id()
        ));
        std::fs::write(&p, r#"{"rat":{"images_per_second":200.0}}"#)
            .unwrap();
        let ok = check_baseline(&p, "rat", 280.0).unwrap().unwrap();
        // floor = 140.0, 280/140 = 2.00x
        assert!(ok.contains("measured/floor 2.00x"), "{ok}");
        let err = check_baseline(&p, "rat", 70.0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("measured/floor 0.50x"), "{msg}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn previous_record_round_trips_and_handles_absence() {
        let dir = std::env::temp_dir()
            .join(format!("stratus_prev_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(previous_record(&dir, "nothing_here"), None);
        let rec = BenchRecord::new("prevtest", 321.5, true);
        rec.write(&dir).unwrap();
        assert_eq!(previous_record(&dir, "prevtest"), Some(321.5));
        // a corrupt record reads as no previous record, not a panic
        std::fs::write(dir.join("BENCH_broken.json"), "{oops").unwrap();
        assert_eq!(previous_record(&dir, "broken"), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_skips_unknown_bench() {
        let p = tmp_baseline(r#"{"other":{"images_per_second":5}}"#);
        assert!(check_baseline(&p, "eng", 1.0).unwrap().is_none());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_baseline_file_is_an_error() {
        let p = Path::new("/nonexistent/baseline.json");
        assert!(check_baseline(p, "eng", 1.0).is_err());
    }

    #[test]
    fn scaling_bench_tracks_reference_and_identity() {
        let mut b = ScalingBench::new("x", true);
        let (sp, v) = b.observe(100.0, vec![1, 2, 3]);
        assert_eq!(v, "(reference)");
        assert!((sp - 1.0).abs() < 1e-12);
        let (sp, v) = b.observe(250.0, vec![1, 2, 3]);
        assert_eq!(v, "bit-identical");
        assert!((sp - 2.5).abs() < 1e-12);
        assert!(b.identical);
        assert_eq!(b.best_ips, 250.0);
        assert_eq!(b.base_ips, 100.0);
    }

    #[test]
    fn scaling_bench_flags_mismatch() {
        let mut b = ScalingBench::new("x", true);
        b.observe(100.0, vec![1, 2, 3]);
        let (_, v) = b.observe(90.0, vec![9, 9, 9]);
        assert_eq!(v, "MISMATCH");
        assert!(!b.identical);
    }

    #[test]
    fn checked_in_baseline_parses_and_covers_gated_benches() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("benches/baseline.json");
        let json =
            Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        for bench in [
            "engine_throughput",
            "cluster_scaling",
            "cluster_hier",
            "cluster_overlap",
            "hotpath",
            "hotpath_conv_fp",
            "hotpath_conv_bp",
            "hotpath_conv_wu",
            "hotpath_fc",
            "hotpath_bn",
            "hotpath_pool_fp",
            "hotpath_pool_bp",
        ] {
            let base = json
                .get(bench)
                .and_then(|e| e.get("images_per_second"))
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("{bench} missing baseline"));
            assert!(base > 0.0);
        }
    }
}
