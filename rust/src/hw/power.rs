//! Activity-based power model, reproducing Table II's per-component watt
//! breakdown (DSP / RAM / logic / clock / static).
//!
//! The paper obtains these from the Quartus power analyzer + Early Power
//! Estimator with post-routing toggle data at 65 degC junction.  We fit
//! each component as a power law of its driving quantity (DSP & clock on
//! MAC count, RAM on BRAM Mbit, logic on ALMs, static on device
//! utilization) through the 1X and 4X rows of Table II; the 2X row is a
//! held-out prediction (within ~25% — the paper's own toggle-dependent
//! spread).

use crate::config::{DesignVars, Network};
use crate::hw::resources::{estimate, Device, ResourceReport};

/// Per-component power in watts (Table II columns).
#[derive(Debug, Clone, Copy)]
pub struct PowerReport {
    pub dsp_w: f64,
    pub ram_w: f64,
    pub logic_w: f64,
    pub clock_w: f64,
    pub static_w: f64,
}

impl PowerReport {
    pub fn total(&self) -> f64 {
        self.dsp_w + self.ram_w + self.logic_w + self.clock_w
            + self.static_w
    }

    pub fn dynamic(&self) -> f64 {
        self.total() - self.static_w
    }

    /// Aggregate power of `instances` replicated accelerator instances
    /// (every component scales linearly — each instance is a full
    /// device, static power included).
    pub fn aggregate(&self, instances: usize) -> PowerReport {
        let n = instances.max(1) as f64;
        PowerReport {
            dsp_w: self.dsp_w * n,
            ram_w: self.ram_w * n,
            logic_w: self.logic_w * n,
            clock_w: self.clock_w * n,
            static_w: self.static_w * n,
        }
    }
}

// DSP W = A * macs^B through (1024, 0.58) and (4096, 3.48).
const A_DSP_W: f64 = 7.4625e-5;
const B_DSP_W: f64 = 1.2925;

// RAM W = A * mbits^B through (10.6, 5.7) and (54.5, 14.6).
const A_RAM_W: f64 = 1.4656;
const B_RAM_W: f64 = 0.5747;

// Logic W = A * alms^B through (20.8e3, 2.4) and (72e3, 11.0).
const A_LOGIC_W: f64 = 1.2405e-5;
const B_LOGIC_W: f64 = 1.2259;

// Clock W = A * macs^B through (1024, 1.68) and (4096, 4.95).
const A_CLOCK_W: f64 = 7.6420e-3;
const B_CLOCK_W: f64 = 0.7792;

// Static W = base + slope * dsp_utilization through (0.30, 10.28)
// and (1.00, 16.47).
const STATIC_BASE_W: f64 = 7.6271;
const STATIC_SLOPE_W: f64 = 8.8429;

/// Power estimate from a resource report.
pub fn power_from_resources(dv: &DesignVars, res: &ResourceReport)
                            -> PowerReport {
    let macs = dv.mac_count() as f64;
    // scale dynamic power with clock relative to the calibration 240 MHz
    let fclk = dv.clock_mhz / 240.0;
    PowerReport {
        dsp_w: A_DSP_W * macs.powf(B_DSP_W) * fclk,
        ram_w: A_RAM_W * res.bram_mbits.powf(B_RAM_W) * fclk,
        logic_w: A_LOGIC_W * (res.alm as f64).powf(B_LOGIC_W) * fclk,
        clock_w: A_CLOCK_W * macs.powf(B_CLOCK_W) * fclk,
        static_w: STATIC_BASE_W + STATIC_SLOPE_W * res.dsp_frac,
    }
}

/// Convenience: full estimate for a network + design point.
pub fn power(net: &Network, dv: &DesignVars, device: &Device)
             -> PowerReport {
    let res = estimate(net, dv, device);
    power_from_resources(dv, &res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::hw::resources::STRATIX10_GX;

    fn report(scale: usize) -> PowerReport {
        power(&Network::cifar(scale), &DesignVars::for_scale(scale),
              &STRATIX10_GX)
    }

    #[test]
    fn calibration_points_reproduce_table2() {
        let p1 = report(1);
        assert!((p1.dsp_w - 0.58).abs() < 0.03, "1X dsp {}", p1.dsp_w);
        assert!((p1.clock_w - 1.68).abs() < 0.05, "1X clk {}", p1.clock_w);
        assert!((p1.static_w - 10.28).abs() < 0.15,
                "1X static {}", p1.static_w);
        let p4 = report(4);
        assert!((p4.dsp_w - 3.48).abs() < 0.1, "4X dsp {}", p4.dsp_w);
        assert!((p4.static_w - 16.47).abs() < 0.2,
                "4X static {}", p4.static_w);
    }

    #[test]
    fn held_out_2x_total_within_30pct() {
        // Table II 2X total: 1.05+11.2+6.6+2.97+11 = 32.8 W
        let p2 = report(2);
        let err = (p2.total() - 32.8).abs() / 32.8;
        assert!(err < 0.30, "2X total {} ({:.0}% off)", p2.total(),
                err * 100.0);
    }

    #[test]
    fn totals_monotone_in_scale() {
        let (p1, p2, p4) = (report(1), report(2), report(4));
        assert!(p1.total() < p2.total());
        assert!(p2.total() < p4.total());
    }

    #[test]
    fn table2_total_shape_1x_4x() {
        // 1X total 20.64 W; 4X total 50.5 W — ~2.4x apart
        let ratio = report(4).total() / report(1).total();
        assert!(ratio > 1.8 && ratio < 3.2, "ratio = {ratio}");
    }

    #[test]
    fn aggregate_scales_every_component() {
        let p = report(1);
        let agg = p.aggregate(4);
        assert!((agg.total() - 4.0 * p.total()).abs() < 1e-9);
        assert!((agg.static_w - 4.0 * p.static_w).abs() < 1e-9);
        assert!((p.aggregate(0).total() - p.total()).abs() < 1e-12);
    }

    #[test]
    fn clock_scaling_reduces_dynamic_power() {
        let net = Network::cifar(1);
        let mut dv = DesignVars::for_scale(1);
        let full = power(&net, &dv, &STRATIX10_GX);
        dv.clock_mhz = 120.0;
        let half = power(&net, &dv, &STRATIX10_GX);
        assert!((half.dynamic() - full.dynamic() / 2.0).abs() < 0.05);
        assert!((half.static_w - full.static_w).abs() < 1e-9);
    }
}
