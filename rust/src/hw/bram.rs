//! On-chip buffer (BRAM) model: sizing of every buffer the generated
//! accelerator instantiates (Fig. 4 / Fig. 10) and the double-buffering
//! latency-hiding rule (§IV-B).
//!
//! Stratix 10 BRAM is organized as M20K blocks (20 Kbit each); Table II
//! reports usage in Mbit, which is what [`BufferPlan::total_mbits`]
//! reproduces.

use crate::config::{DesignVars, Network};

/// M20K block capacity in bits.
pub const M20K_BITS: u64 = 20 * 1024;

/// One named on-chip buffer of the generated design.
#[derive(Debug, Clone)]
pub struct BufferSpec {
    pub name: String,
    /// Which phase(s) the buffer serves, for the Fig. 10 breakdown.
    pub group: BufferGroup,
    /// Depth in data words.
    pub words: u64,
    /// Word width in bits.
    pub bits_per_word: u64,
    /// Double-buffered (two physical copies)?
    pub double: bool,
}

/// Fig. 10 groups buffers by what they hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferGroup {
    Input,
    Output,
    Weight,
    WeightGradient,
    PoolIndex,
    ActGradientMask,
    /// Per-channel BN statistic/parameter registers (mean, variance,
    /// precomputed scale, beta).
    BnStats,
}

impl BufferSpec {
    pub fn bits(&self) -> u64 {
        let base = self.words * self.bits_per_word;
        if self.double {
            2 * base
        } else {
            base
        }
    }

    pub fn m20k_blocks(&self) -> u64 {
        self.bits().div_ceil(M20K_BITS)
    }
}

/// The complete buffer allocation for one accelerator instance.
#[derive(Debug, Clone, Default)]
pub struct BufferPlan {
    pub buffers: Vec<BufferSpec>,
}

impl BufferPlan {
    /// Size every on-chip buffer for `net` under design variables `dv`,
    /// replicating the paper's policy: activation/gradient tiles are
    /// `tile_rows` rows deep and double-buffered; the weight buffer holds
    /// the largest layer's full weights (§IV-B: "the weight buffer size is
    /// decided by the largest layer weights", not tiled); index and
    /// activation-gradient-mask buffers are per-layer and sized to a tile.
    pub fn plan(net: &Network, dv: &DesignVars) -> BufferPlan {
        let bits = dv.data_bits as u64;
        let mut buffers = Vec::new();
        // per-kind row widths / tile depths come from the layer-ops
        // registry; this function only takes maxima and assembles specs

        // widest activation row across the network (input tiles)
        let max_row_words = net
            .layers
            .iter()
            .map(|l| crate::ops::for_layer(l).input_row_words(l))
            .max()
            .unwrap_or(0);
        buffers.push(BufferSpec {
            name: "input".into(),
            group: BufferGroup::Input,
            words: max_row_words * (dv.tile_rows as u64 + 2),
            bits_per_word: bits,
            double: dv.double_buffer,
        });

        // output tile: Pof maps x tile_rows x widest row
        let max_out_row = net
            .layers
            .iter()
            .map(|l| crate::ops::for_layer(l).output_row_words(l))
            .max()
            .unwrap_or(0);
        buffers.push(BufferSpec {
            name: "output".into(),
            group: BufferGroup::Output,
            words: (dv.pof as u64) * (dv.tile_rows as u64) * max_out_row,
            bits_per_word: bits,
            double: dv.double_buffer,
        });

        // weight buffer: whole weights of the largest layer (transposable,
        // single copy — that is the point of the circulant storage)
        let max_weights = net
            .layers
            .iter()
            .map(|l| l.weight_elems() as u64)
            .max()
            .unwrap_or(0);
        buffers.push(BufferSpec {
            name: "weight".into(),
            group: BufferGroup::Weight,
            words: max_weights,
            bits_per_word: bits,
            double: false,
        });

        // weight-gradient accumulation tile (i32 words, double-buffered to
        // overlap old-gradient reads — §IV-B)
        let max_wg_tile = net
            .layers
            .iter()
            .map(|l| {
                crate::ops::for_layer(l).weight_grad_tile_words(l, dv)
            })
            .max()
            .unwrap_or(0);
        buffers.push(BufferSpec {
            name: "weight_grad".into(),
            group: BufferGroup::WeightGradient,
            words: max_wg_tile,
            bits_per_word: 32,
            double: dv.double_buffer,
        });

        // layer-private buffers (pool indices, relu masks, bn registers)
        for l in &net.layers {
            crate::ops::for_layer(l).layer_buffers(l, dv, &mut buffers);
        }

        BufferPlan { buffers }
    }

    pub fn total_bits(&self) -> u64 {
        self.buffers.iter().map(|b| b.bits()).sum()
    }

    pub fn total_mbits(&self) -> f64 {
        self.total_bits() as f64 / 1e6
    }

    pub fn total_m20k(&self) -> u64 {
        self.buffers.iter().map(|b| b.m20k_blocks()).sum()
    }

    /// Bits per Fig. 10 group.
    pub fn bits_by_group(&self) -> Vec<(BufferGroup, u64)> {
        use BufferGroup::*;
        [Input, Output, Weight, WeightGradient, PoolIndex,
         ActGradientMask, BnStats]
            .iter()
            .map(|g| {
                (
                    *g,
                    self.buffers
                        .iter()
                        .filter(|b| b.group == *g)
                        .map(|b| b.bits())
                        .sum(),
                )
            })
            .collect()
    }
}

/// Double-buffering latency rule (§IV-B): with two copies the next tile's
/// DMA overlaps the current tile's compute, so a layer's latency is
/// max(logic, dram) + one pipeline fill; without it, logic + dram.
pub fn overlap_latency(logic: u64, dram: u64, double_buffer: bool,
                       fill: u64) -> u64 {
    if double_buffer {
        logic.max(dram) + fill
    } else {
        logic + dram
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    #[test]
    fn m20k_rounds_up() {
        let b = BufferSpec {
            name: "t".into(),
            group: BufferGroup::Input,
            words: 1,
            bits_per_word: 16,
            double: false,
        };
        assert_eq!(b.m20k_blocks(), 1);
    }

    #[test]
    fn double_doubles_bits() {
        let mut b = BufferSpec {
            name: "t".into(),
            group: BufferGroup::Input,
            words: 100,
            bits_per_word: 16,
            double: false,
        };
        let single = b.bits();
        b.double = true;
        assert_eq!(b.bits(), 2 * single);
    }

    #[test]
    fn plan_scales_with_network_width() {
        let p1 = BufferPlan::plan(&Network::cifar(1),
                                  &DesignVars::for_scale(1));
        let p4 = BufferPlan::plan(&Network::cifar(4),
                                  &DesignVars::for_scale(4));
        assert!(p4.total_bits() > 2 * p1.total_bits());
    }

    #[test]
    fn weight_buffer_holds_largest_layer() {
        let net = Network::cifar(1);
        let plan = BufferPlan::plan(&net, &DesignVars::for_scale(1));
        let wbuf = plan
            .buffers
            .iter()
            .find(|b| b.name == "weight")
            .unwrap();
        // largest 1X layer is c6: 64*64*9 = 36864 words
        assert_eq!(wbuf.words, 36864);
        assert!(!wbuf.double, "transposable buffer is single-copy");
    }

    #[test]
    fn pool_index_width_is_2bit_for_2x2() {
        let net = Network::cifar(1);
        let plan = BufferPlan::plan(&net, &DesignVars::for_scale(1));
        for b in &plan.buffers {
            if b.group == BufferGroup::PoolIndex {
                assert_eq!(b.bits_per_word, 2, "{}", b.name);
            }
        }
    }

    #[test]
    fn table2_bram_order_of_magnitude() {
        // paper Table II: 1X uses 10.6 Mbit of BRAM; our plan must land in
        // the same regime (a few Mbit — most of Table II's figure is
        // fitter-allocated overhead, so we check the order, not the value)
        let plan = BufferPlan::plan(&Network::cifar(1),
                                    &DesignVars::for_scale(1));
        let mb = plan.total_mbits();
        assert!(mb > 0.5 && mb < 12.0, "1X plan = {mb} Mbit");
    }

    #[test]
    fn overlap_rule() {
        assert_eq!(overlap_latency(100, 60, true, 5), 105);
        assert_eq!(overlap_latency(100, 60, false, 5), 160);
        assert_eq!(overlap_latency(60, 100, true, 0), 100);
    }

    #[test]
    fn bn_layers_get_stat_registers_and_masks() {
        let net = Network::cifar_bn(1);
        let plan = BufferPlan::plan(&net, &DesignVars::for_scale(1));
        let bn1 =
            plan.buffers.iter().find(|b| b.name == "bn_n1").unwrap();
        assert_eq!(bn1.group, BufferGroup::BnStats);
        assert_eq!(bn1.words, 4 * 16); // mean/var/scale/beta x 16 ch
        assert_eq!(bn1.bits_per_word, 32);
        // the bn layer fuses the relu, so it owns the mask buffer
        assert!(plan.buffers.iter().any(|b| b.name == "mask_n1"));
        // its conv dropped the relu, so no conv mask
        assert!(!plan.buffers.iter().any(|b| b.name == "mask_c1"));
        // bn registers are a rounding error next to activation tiles
        let bn_bits: u64 = plan
            .buffers
            .iter()
            .filter(|b| b.group == BufferGroup::BnStats)
            .map(|b| b.bits())
            .sum();
        assert!(bn_bits * 20 < plan.total_bits());
    }

    #[test]
    fn groups_cover_all_buffers() {
        let net = Network::cifar(2);
        let plan = BufferPlan::plan(&net, &DesignVars::for_scale(2));
        let grouped: u64 =
            plan.bits_by_group().iter().map(|(_, b)| b).sum();
        assert_eq!(grouped, plan.total_bits());
    }
}
