//! Off-chip DRAM model: DDR3 bandwidth/latency plus the DMA descriptor
//! engine (Fig. 4: "DMA control generates the required DMA descriptors
//! based on the layer type and tile sizes").
//!
//! The paper's devkit has 4 Gb DDR3 at 16.9 GB/s peak (see
//! `DesignVars::dram_gbytes` for the unit discussion); all initial
//! weights, intermediate activations and weight/loss gradients live there
//! in 16-bit words (§III-B), so DRAM traffic dominates the weight-update
//! layers (Fig. 9).  We model transfers as: per-descriptor fixed overhead
//! (protocol + address phase) plus payload at derated peak bandwidth.

use crate::config::DesignVars;

/// Fixed cycles charged per DMA descriptor (burst setup, bank activate,
/// address-phase and scatter/gather handshaking).  Calibrated together
/// with `DesignVars::dram_efficiency` (0.60) against Table II's 1X and 4X
/// epoch latencies (18.0 s / 96.2 s at BS-40); the 2X row is a held-out
/// prediction (within ~13%).
pub const DESCRIPTOR_OVERHEAD_CYCLES: u64 = 200;

/// A DRAM transfer request produced by the tile scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaDescriptor {
    /// Payload bytes.
    pub bytes: u64,
    /// True for DRAM -> on-chip (read).
    pub is_read: bool,
}

/// DDR3 channel model derived from the design variables.
#[derive(Debug, Clone, Copy)]
pub struct DramModel {
    /// Effective bytes per accelerator cycle.
    pub bytes_per_cycle: f64,
}

impl DramModel {
    pub fn new(dv: &DesignVars) -> DramModel {
        let bytes_per_sec = dv.dram_gbytes * 1e9 * dv.dram_efficiency;
        let cycles_per_sec = dv.clock_mhz * 1e6;
        DramModel { bytes_per_cycle: bytes_per_sec / cycles_per_sec }
    }

    /// Cycles for a single descriptor.
    pub fn descriptor_cycles(&self, d: &DmaDescriptor) -> u64 {
        DESCRIPTOR_OVERHEAD_CYCLES
            + (d.bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Cycles for a batch of descriptors issued back-to-back on the single
    /// channel (the paper's devkit has one DDR3 channel).
    pub fn transfer_cycles(&self, descriptors: &[DmaDescriptor]) -> u64 {
        descriptors.iter().map(|d| self.descriptor_cycles(d)).sum()
    }

    /// Convenience: cycles to move `bytes` split into `tiles` descriptors.
    pub fn tiled_transfer_cycles(&self, bytes: u64, tiles: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let tiles = tiles.max(1);
        tiles * DESCRIPTOR_OVERHEAD_CYCLES
            + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// Accumulating traffic ledger, per training phase, for reports (Fig. 9's
/// DRAM bars and the EXPERIMENTS.md traffic tables).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub descriptors: u64,
}

impl Traffic {
    pub fn add_read(&mut self, bytes: u64) {
        self.read_bytes += bytes;
        self.descriptors += 1;
    }

    pub fn add_write(&mut self, bytes: u64) {
        self.write_bytes += bytes;
        self.descriptors += 1;
    }

    pub fn total(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    pub fn merge(&mut self, other: &Traffic) {
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
        self.descriptors += other.descriptors;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignVars;

    fn model() -> DramModel {
        DramModel::new(&DesignVars::default())
    }

    #[test]
    fn bandwidth_derivation() {
        // 16.9 GB/s * 0.6 / 240 MHz = ~42.25 B/cycle
        let m = model();
        assert!((m.bytes_per_cycle - 42.25).abs() < 0.2,
                "B/cyc = {}", m.bytes_per_cycle);
    }

    #[test]
    fn descriptor_overhead_charged() {
        let m = model();
        let one = m.descriptor_cycles(&DmaDescriptor {
            bytes: 0,
            is_read: true,
        });
        assert_eq!(one, DESCRIPTOR_OVERHEAD_CYCLES);
    }

    #[test]
    fn payload_scales_linearly() {
        let m = model();
        let small = m.tiled_transfer_cycles(1 << 16, 1);
        let big = m.tiled_transfer_cycles(1 << 26, 1);
        let ratio = (big - DESCRIPTOR_OVERHEAD_CYCLES) as f64
            / (small - DESCRIPTOR_OVERHEAD_CYCLES) as f64;
        assert!((ratio / 1024.0 - 1.0).abs() < 0.02, "ratio = {ratio}");
    }

    #[test]
    fn more_tiles_cost_more_overhead() {
        let m = model();
        let few = m.tiled_transfer_cycles(1 << 16, 4);
        let many = m.tiled_transfer_cycles(1 << 16, 64);
        assert_eq!(many - few, 60 * DESCRIPTOR_OVERHEAD_CYCLES);
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(model().tiled_transfer_cycles(0, 8), 0);
    }

    #[test]
    fn traffic_ledger_merges() {
        let mut a = Traffic::default();
        a.add_read(100);
        a.add_write(50);
        let mut b = Traffic::default();
        b.add_read(10);
        b.merge(&a);
        assert_eq!(b.read_bytes, 110);
        assert_eq!(b.write_bytes, 50);
        assert_eq!(b.descriptors, 3);
        assert_eq!(b.total(), 160);
    }
}
