//! Hardware substrate models of the generated FPGA accelerator: the
//! systolic MAC array with load balancing, the DDR3 DRAM channel + DMA
//! engine, on-chip BRAM buffers with double buffering, the transposable
//! circulant weight buffer, the inter-accelerator ring link for
//! multi-instance clusters, and resource/power estimation calibrated to
//! the paper's Table II.
//!
//! These models implement the same dataflow equations the RTL executes,
//! which is what the paper itself measures ("latency was measured using
//! simulation of the synthesized accelerator", §IV-A).

pub mod bram;
pub mod dram;
pub mod link;
pub mod mac_array;
pub mod power;
pub mod resources;
pub mod transpose_buffer;

pub use bram::{overlap_latency, BufferGroup, BufferPlan, BufferSpec};
pub use dram::{DmaDescriptor, DramModel, Traffic};
pub use link::{ring_cost, AllReduceCost, LinkModel};
pub use mac_array::{layer_cycles, LogicCost, Phase};
pub use power::{power, PowerReport};
pub use resources::{estimate, Device, ResourceReport, STRATIX10_GX};
pub use transpose_buffer::TransposableBuffer;
