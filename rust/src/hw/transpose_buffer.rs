//! The transposable weight buffer (§III-D, Fig. 5): kernels stored ONCE
//! as a circulant matrix of kernel blocks across single-port column
//! buffers, readable in both non-transpose (FP) and transpose (BP) modes
//! without bank conflicts.
//!
//! Geometry: the weights of one layer form an `R x C` matrix of kernel
//! blocks (`R` = input-channel rows, `C = Pof` output-channel columns per
//! tile; each block is one `k x k` kernel).  Row `r` is circularly rotated
//! by `r` before being written, so block `(r, c)` lives in column buffer
//! `(r + c) % C` at address `r`:
//!
//! - **non-transpose read** of block-column `c` (all input channels of one
//!   output map, the FP order): address `r` in buffer `(r + c) % C` — one
//!   access per column buffer, conflict-free.
//! - **transpose read** of block-row `r` (all output maps of one input
//!   channel, the BP order): address `r` in *every* buffer — also
//!   conflict-free, single cycle.  The address translator additionally
//!   reverses the tap order (the 180-degree kernel rotation of Eq. 3).

use crate::nn::tensor::Tensor;

/// One layer's weights in circulant transposable storage.
#[derive(Debug, Clone)]
pub struct TransposableBuffer {
    /// column_buffers[c][r] = kernel block (k*k words).
    columns: Vec<Vec<Vec<i32>>>,
    rows: usize,
    cols: usize,
    k: usize,
    /// Total single-port read accesses issued (cycle accounting).
    pub reads: u64,
    /// Total writes issued.
    pub writes: u64,
}

impl TransposableBuffer {
    /// Store weights `w` of shape (Nof, Nif, k, k).  Columns = Nof (the
    /// per-tile Pof blocks of Fig. 5 generalize to the full layer here;
    /// the RTL compiler instantiates one such buffer per of-tile).
    pub fn store(w: &Tensor) -> TransposableBuffer {
        let (nof, nif, k) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        assert_eq!(w.shape()[2], w.shape()[3], "square kernels only");
        let mut columns = vec![vec![Vec::new(); nif]; nof];
        let mut writes = 0u64;
        for r in 0..nif {
            for c in 0..nof {
                // circulant placement: block (r, c) -> buffer (r + c) % C
                let buf = (r + c) % nof;
                let mut block = Vec::with_capacity(k * k);
                for ky in 0..k {
                    for kx in 0..k {
                        block.push(w.at4(c, r, ky, kx));
                    }
                }
                columns[buf][r] = block;
                writes += 1;
            }
        }
        TransposableBuffer { columns, rows: nif, cols: nof, k, reads: 0, writes }
    }

    /// Words of storage actually used (must equal the raw weight count —
    /// the whole point is zero duplication).
    pub fn storage_words(&self) -> usize {
        self.columns
            .iter()
            .flat_map(|col| col.iter())
            .map(|b| b.len())
            .sum()
    }

    /// Non-transpose read (FP): kernel block for output map `of`, input
    /// channel `r` — `W[of, r, :, :]` in original tap order.
    pub fn read_normal(&mut self, of: usize, r: usize) -> &[i32] {
        self.reads += 1;
        let buf = (r + of) % self.cols;
        &self.columns[buf][r]
    }

    /// Transpose read (BP): for input channel `r`, return all `Nof` kernel
    /// blocks with taps reversed (180-degree rotation) — the BP kernel row
    /// `W'[r, :, ::-1, ::-1]`.  One parallel access across all column
    /// buffers (conflict-free; counted as `cols` single-port reads).
    pub fn read_transpose_row(&mut self, r: usize) -> Vec<Vec<i32>> {
        self.reads += self.cols as u64;
        (0..self.cols)
            .map(|of| {
                let buf = (r + of) % self.cols;
                let mut b = self.columns[buf][r].clone();
                b.reverse(); // address translator: reversed tap order
                b
            })
            .collect()
    }

    /// Reconstruct the full original tensor from storage (test/diagnostic).
    pub fn reconstruct(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.cols, self.rows, self.k, self.k]);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let buf = (r + c) % self.cols;
                let block = &self.columns[buf][r];
                for ky in 0..self.k {
                    for kx in 0..self.k {
                        out.set4(c, r, ky, kx, block[ky * self.k + kx]);
                    }
                }
            }
        }
        out
    }

    /// Cycle cost of streaming the whole layer in FP order: one block per
    /// column-buffer port per cycle -> Nif cycles per of (all Pof columns
    /// stream concurrently in hardware; here the full Nof plays that role).
    pub fn fp_stream_cycles(&self) -> u64 {
        self.rows as u64
    }

    /// Cycle cost of streaming the whole layer in BP order — identical to
    /// FP thanks to the circulant layout (this is the claim of Fig. 5:
    /// transpose access at no extra latency, vs. Nof * Nif block reads
    /// from a naive single-port store).
    pub fn bp_stream_cycles(&self) -> u64 {
        self.rows as u64
    }

    /// What a naive (non-circulant) single-port buffer would need for the
    /// BP order: every block read conflicts on the same buffer, so reads
    /// serialize per row.
    pub fn naive_bp_stream_cycles(&self) -> u64 {
        (self.rows * self.cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::transpose_flip;
    use crate::nn::testutil::{randi, Lcg};

    fn sample(nof: usize, nif: usize, k: usize, seed: u64) -> Tensor {
        let mut rng = Lcg::new(seed);
        randi(&mut rng, &[nof, nif, k, k], 500)
    }

    #[test]
    fn zero_duplication() {
        let w = sample(16, 8, 3, 1);
        let tb = TransposableBuffer::store(&w);
        assert_eq!(tb.storage_words(), 16 * 8 * 9);
    }

    #[test]
    fn reconstruct_roundtrip() {
        let w = sample(8, 8, 3, 2);
        let tb = TransposableBuffer::store(&w);
        assert_eq!(tb.reconstruct(), w);
    }

    #[test]
    fn normal_read_matches_fp_kernels() {
        let w = sample(4, 6, 3, 3);
        let mut tb = TransposableBuffer::store(&w);
        for of in 0..4 {
            for r in 0..6 {
                let block = tb.read_normal(of, r).to_vec();
                for ky in 0..3 {
                    for kx in 0..3 {
                        assert_eq!(block[ky * 3 + kx], w.at4(of, r, ky, kx));
                    }
                }
            }
        }
    }

    #[test]
    fn transpose_read_matches_flipped_interchanged_kernels() {
        // The contract of Fig. 5: transpose mode must yield exactly what
        // conv_bp consumes — transpose_flip(w)[r, of, :, :].
        let w = sample(5, 7, 3, 4);
        let wt = transpose_flip(&w);
        let mut tb = TransposableBuffer::store(&w);
        for r in 0..7 {
            let row = tb.read_transpose_row(r);
            assert_eq!(row.len(), 5);
            for (of, block) in row.iter().enumerate() {
                for ky in 0..3 {
                    for kx in 0..3 {
                        assert_eq!(
                            block[ky * 3 + kx],
                            wt.at4(r, of, ky, kx),
                            "r={r} of={of} ky={ky} kx={kx}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn non_square_nif_gt_nof_pins_circulant_placement() {
        // More input-channel rows than column buffers (nif > nof): the
        // rotation wraps several times per row range, so this shape pins
        // the `(r + c) % nof` placement.  Storage, reconstruction, both
        // read modes, and stream latency must all hold.
        let (nof, nif) = (4usize, 6usize);
        let w = sample(nof, nif, 3, 9);
        let mut tb = TransposableBuffer::store(&w);
        assert_eq!(tb.storage_words(), nof * nif * 9);
        assert_eq!(tb.reconstruct(), w);
        assert_eq!(tb.fp_stream_cycles(), nif as u64);
        assert_eq!(tb.bp_stream_cycles(), nif as u64);
        assert_eq!(tb.naive_bp_stream_cycles(), (nif * nof) as u64);
        for of in 0..nof {
            for r in 0..nif {
                let block = tb.read_normal(of, r).to_vec();
                for ky in 0..3 {
                    for kx in 0..3 {
                        assert_eq!(block[ky * 3 + kx],
                                   w.at4(of, r, ky, kx));
                    }
                }
            }
        }
        let wt = transpose_flip(&w);
        for r in 0..nif {
            let row = tb.read_transpose_row(r);
            assert_eq!(row.len(), nof);
            for (of, block) in row.iter().enumerate() {
                for ky in 0..3 {
                    for kx in 0..3 {
                        assert_eq!(block[ky * 3 + kx],
                                   wt.at4(r, of, ky, kx),
                                   "r={r} of={of}");
                    }
                }
            }
        }
    }

    #[test]
    fn transpose_read_is_conflict_free() {
        // every block of a transpose row must come from a distinct column
        // buffer (single-port constraint)
        let w = sample(6, 4, 3, 5);
        let tb = TransposableBuffer::store(&w);
        for r in 0..4 {
            let mut seen = vec![false; 6];
            for of in 0..6 {
                let buf = (r + of) % 6;
                assert!(!seen[buf], "conflict at r={r}, of={of}");
                seen[buf] = true;
            }
            let _ = &tb;
        }
    }

    #[test]
    fn circulant_beats_naive_on_bp_stream() {
        let w = sample(16, 16, 3, 6);
        let tb = TransposableBuffer::store(&w);
        assert_eq!(tb.bp_stream_cycles(), tb.fp_stream_cycles());
        assert_eq!(tb.naive_bp_stream_cycles(),
                   16 * tb.bp_stream_cycles());
    }

    #[test]
    fn access_counters_track() {
        let w = sample(4, 4, 3, 7);
        let mut tb = TransposableBuffer::store(&w);
        assert_eq!(tb.writes, 16);
        tb.read_normal(0, 0);
        tb.read_transpose_row(1);
        assert_eq!(tb.reads, 1 + 4);
    }

    #[test]
    fn works_for_1x1_and_5x5_kernels() {
        for k in [1, 5] {
            let w = sample(3, 2, k, 8 + k as u64);
            let tb = TransposableBuffer::store(&w);
            assert_eq!(tb.reconstruct(), w);
            assert_eq!(tb.storage_words(), 3 * 2 * k * k);
        }
    }
}
