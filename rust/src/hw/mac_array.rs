//! Cycle model of the 2D systolic MAC array (Fig. 6) and the MAC
//! load-balance unit (§III-F, Fig. 8).
//!
//! The array computes `Pox * Poy * Pof` output pixels per cycle group:
//! rows share input feature data, columns share weights.  It is reused in
//! all three phases by re-routing operands (table in Fig. 6):
//!
//! | phase | input           | weights          | output           |
//! |-------|-----------------|------------------|------------------|
//! | FP    | activations     | normal kernels   | activations      |
//! | BP    | local gradients | flipped kernels  | local gradients  |
//! | WU    | activations     | local gradients  | kernel gradients |

use crate::config::{DesignVars, Layer};

/// Training phase (drives operand routing and the cycle formulas).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Fp,
    Bp,
    Wu,
}

/// Logic-cycle count for one layer in one phase, plus achieved MAC
/// utilization (fraction of array MACs doing useful work).
#[derive(Debug, Clone, Copy)]
pub struct LogicCost {
    pub cycles: u64,
    pub useful_macs: u64,
    pub utilization: f64,
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// FP / BP convolution cycles: the loop nest is tiled by the unroll
/// factors; every cycle retires up to Pox*Poy*Pof MACs.
///
/// cycles = ceil(Nof/Pof) * ceil(Noy/Poy) * ceil(Nox/Pox) * Nif * Nk^2
pub fn conv_cycles(dv: &DesignVars, cin: usize, cout: usize, h: usize,
                   w: usize, k: usize) -> LogicCost {
    let steps = ceil_div(cout, dv.pof)
        * ceil_div(h, dv.poy)
        * ceil_div(w, dv.pox)
        * cin
        * k
        * k;
    let useful = (cout * h * w * cin * k * k) as u64;
    let cycles = steps as u64;
    LogicCost {
        cycles,
        useful_macs: useful,
        utilization: useful as f64
            / (cycles as f64 * dv.mac_count() as f64),
    }
}

/// How many kernel-gradient planes the load-balance unit packs into the
/// `Pox x Poy` spatial face of the array (Fig. 8: floor(Pox/Nkx) *
/// floor(Poy/Nky); with Pox=Poy=8, k=3 this is 4 — the paper's "4X").
pub fn wu_balance_factor(dv: &DesignVars, k: usize) -> usize {
    ((dv.pox / k) * (dv.poy / k)).max(1)
}

/// WU convolution cycles (Eq. 4 as "FP conv with Nif=1" + outer loop over
/// the actual Nif, §II).  The output feature map is only Nk x Nk, so
/// without load balancing most of the spatial face idles; with it,
/// `balance` (if) planes are processed concurrently.
///
/// cycles = ceil(Nof/Pof) * ceil(Nif/balance) * Noy * Nox
pub fn wu_cycles(dv: &DesignVars, cin: usize, cout: usize, h: usize,
                 w: usize, k: usize) -> LogicCost {
    let balance = if dv.load_balance { wu_balance_factor(dv, k) } else { 1 };
    let steps =
        ceil_div(cout, dv.pof) * ceil_div(cin, balance) * h * w;
    let useful = (cout * cin * k * k * h * w) as u64;
    let cycles = steps as u64;
    LogicCost {
        cycles,
        useful_macs: useful,
        utilization: useful as f64
            / (cycles as f64 * dv.mac_count() as f64),
    }
}

/// Fully-connected cycles: the MAC array is fed as a flat dot-product
/// engine; all three phases retire `mac_count` MACs per cycle at best.
pub fn fc_cycles(dv: &DesignVars, cin: usize, cout: usize) -> LogicCost {
    let macs = (cin * cout) as u64;
    let cycles = macs.div_ceil(dv.mac_count() as u64);
    LogicCost {
        cycles,
        useful_macs: macs,
        utilization: macs as f64
            / (cycles as f64 * dv.mac_count() as f64),
    }
}

/// Pooling / upsampling cycles: one output pixel per cycle per channel
/// lane (the upsampling unit has `Pof` demux+multiply blocks).
pub fn pool_cycles(dv: &DesignVars, c: usize, h: usize, w: usize, k: usize)
                   -> u64 {
    (ceil_div(c, dv.pof) * (h / k) * (w / k)) as u64
}

/// Batch-normalization cycles: one normalized pixel per cycle per
/// channel lane through the Pof-wide multiply + shift + add datapath
/// (same shape in FP and in the statistics-as-constants BP).
pub fn bn_cycles(dv: &DesignVars, c: usize, h: usize, w: usize) -> u64 {
    (ceil_div(c, dv.pof) * h * w) as u64
}

/// Logic cycles for a layer in a phase; `None` when the phase does not
/// visit the layer (e.g. BP through the first conv layer, WU through a
/// pool).  Per-kind formulas live in the layer-ops registry
/// ([`crate::ops`]); this is the mac-array-facing delegate.
pub fn layer_cycles(dv: &DesignVars, layer: &Layer, phase: Phase,
                    is_first_conv: bool) -> Option<LogicCost> {
    crate::ops::for_layer(layer).phase_cost(dv, layer, phase,
                                            is_first_conv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dv1x() -> DesignVars {
        DesignVars::for_scale(1)
    }

    #[test]
    fn conv_cycles_exact_tiling() {
        // c2 of 1X: 16->16 @32x32, k3, Pof=16 Pox=Poy=8
        let c = conv_cycles(&dv1x(), 16, 16, 32, 32, 3);
        assert_eq!(c.cycles, 1 * 4 * 4 * 16 * 9);
        assert!((c.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn conv_cycles_partial_tile_lowers_utilization() {
        // cout 20 with Pof 16 -> 2 of-tiles, second mostly idle
        let c = conv_cycles(&dv1x(), 16, 20, 32, 32, 3);
        assert_eq!(c.cycles, 2 * 4 * 4 * 16 * 9);
        assert!(c.utilization < 0.7);
    }

    #[test]
    fn balance_factor_matches_paper_example() {
        // Pox=Poy=8, k=3 -> 2*2 = 4 kernel gradients in parallel (Fig. 8)
        assert_eq!(wu_balance_factor(&dv1x(), 3), 4);
    }

    #[test]
    fn load_balance_speeds_wu_4x() {
        let mut dv = dv1x();
        dv.pof = 16;
        let with = wu_cycles(&dv, 64, 64, 8, 8, 3);
        dv.load_balance = false;
        let without = wu_cycles(&dv, 64, 64, 8, 8, 3);
        assert_eq!(without.cycles / with.cycles, 4);
    }

    #[test]
    fn wu_cycle_formula() {
        // c6 of 1X: 64->64 @8x8: ceil(64/16)*ceil(64/4)*64 = 4*16*64
        let c = wu_cycles(&dv1x(), 64, 64, 8, 8, 3);
        assert_eq!(c.cycles, 4 * 16 * 64);
    }

    #[test]
    fn fc_cycles_rounds_up() {
        let c = fc_cycles(&dv1x(), 1024, 10);
        assert_eq!(c.cycles, (1024 * 10_u64).div_ceil(1024));
    }

    #[test]
    fn bp_skips_first_conv() {
        let l = Layer::Conv {
            name: "c1".into(),
            cin: 3,
            cout: 16,
            h: 32,
            w: 32,
            k: 3,
            pad: 1,
            stride: 1,
            relu: true,
        };
        assert!(layer_cycles(&dv1x(), &l, Phase::Bp, true).is_none());
        assert!(layer_cycles(&dv1x(), &l, Phase::Bp, false).is_some());
    }

    #[test]
    fn bn_visits_fp_and_bp_only() {
        let l = Layer::Bn {
            name: "n1".into(),
            c: 16,
            h: 32,
            w: 32,
            relu: true,
        };
        let fp = layer_cycles(&dv1x(), &l, Phase::Fp, false).unwrap();
        // 16 channels / Pof 16 -> one lane pass over 32x32 pixels
        assert_eq!(fp.cycles, 32 * 32);
        let bp = layer_cycles(&dv1x(), &l, Phase::Bp, false).unwrap();
        assert_eq!(bp.cycles, fp.cycles);
        // gamma/beta gradients ride the BP pass: no separate WU visit
        assert!(layer_cycles(&dv1x(), &l, Phase::Wu, false).is_none());
    }

    #[test]
    fn bp_conv_same_volume_as_fp() {
        let l = Layer::Conv {
            name: "c4".into(),
            cin: 32,
            cout: 32,
            h: 16,
            w: 16,
            k: 3,
            pad: 1,
            stride: 1,
            relu: true,
        };
        let fp = layer_cycles(&dv1x(), &l, Phase::Fp, false).unwrap();
        let bp = layer_cycles(&dv1x(), &l, Phase::Bp, false).unwrap();
        assert_eq!(fp.cycles, bp.cycles);
    }
}
