//! Inter-accelerator link model for multi-instance (cluster) training:
//! one point-to-point serial link per ring neighbor, used by the ring
//! all-reduce of WU gradient accumulators between batch accumulation and
//! the weight update.
//!
//! The cost accounting deliberately mirrors the DRAM model
//! ([`crate::hw::dram`]): a fixed per-message overhead (serial-link
//! framing, CRC and handshake latency) plus payload at derated peak
//! bandwidth (`DesignVars::link_gbytes * link_efficiency`).  Links are
//! full duplex, so a ring step's concurrent send and receive cost one
//! message; every ring link is busy in every step, so a whole-cluster
//! ring step costs exactly one message.

use crate::config::DesignVars;
use crate::engine::collective::CollectiveStep;

/// Fixed cycles charged per ring message — serial-link framing, CRC and
/// handshake latency, ~1 us at the 240 MHz accelerator clock (the same
/// role `DESCRIPTOR_OVERHEAD_CYCLES` plays for DRAM descriptors).
pub const MESSAGE_OVERHEAD_CYCLES: u64 = 240;

/// Point-to-point inter-accelerator link derived from the design
/// variables.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Effective payload bytes per accelerator cycle, per direction.
    pub bytes_per_cycle: f64,
}

impl LinkModel {
    pub fn new(dv: &DesignVars) -> LinkModel {
        let bytes_per_sec = dv.link_gbytes * 1e9 * dv.link_efficiency;
        let cycles_per_sec = dv.clock_mhz * 1e6;
        LinkModel { bytes_per_cycle: bytes_per_sec / cycles_per_sec }
    }

    /// Cycles to move one `bytes` message to a ring neighbor.  A zero-
    /// byte message costs nothing (no ring traffic to move).
    pub fn message_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        MESSAGE_OVERHEAD_CYCLES
            + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// Analytic cost of one ring all-reduce of `total_bytes` of gradient
/// accumulator over a cluster (reduce-scatter + all-gather): `2*(N-1)`
/// steps, each moving a `ceil(total/N)`-byte chunk on every link
/// concurrently.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllReduceCost {
    /// Ring steps (reduce-scatter plus all-gather).
    pub steps: u64,
    /// Bytes per message (one gradient chunk).
    pub chunk_bytes: u64,
    /// Bytes each instance pushes through its outgoing link in total.
    pub bytes_per_instance: u64,
    /// Link-bound cycles for the whole all-reduce.
    pub cycles: u64,
}

/// Cost of ring-all-reducing `total_bytes` across `instances`
/// accelerators over `link`.  One instance (or nothing to reduce) costs
/// zero.
pub fn ring_cost(total_bytes: u64, instances: usize, link: &LinkModel)
                 -> AllReduceCost {
    let n = instances.max(1) as u64;
    if n == 1 || total_bytes == 0 {
        return AllReduceCost::default();
    }
    let chunk_bytes = total_bytes.div_ceil(n);
    let steps = 2 * (n - 1);
    AllReduceCost {
        steps,
        chunk_bytes,
        bytes_per_instance: steps * chunk_bytes,
        cycles: steps * link.message_cycles(chunk_bytes),
    }
}

/// Link-bound cycles of one collective communication plan: each step
/// moves `chunk_words` i32 words per message, and `link_share`
/// concurrent messages time-share the busiest physical link (the
/// inter-group trunk during hierarchical cross-steps), so the step's
/// payload is charged `link_share` times over.  This is the analytic
/// floor the scheduled-step simulation must not undercut.
pub fn plan_cost(plan: &[CollectiveStep], link: &LinkModel) -> u64 {
    plan.iter()
        .map(|s| link.message_cycles(s.link_share * s.chunk_words * 4))
        .sum()
}

/// Deterministic straggler distribution for the event-driven cluster
/// simulation: per collective step, every instance draws a uniform
/// slowdown in `[0, spread]` from a splitmix64 hash of `(seed, step,
/// instance)`, and the step waits for the slowest member — the
/// classic synchronous-SGD straggler penalty, reproducible bit-for-bit
/// from the seed.  `spread = 0` (the default) disables it, keeping
/// every pinned event-timeline expectation exact.
#[derive(Debug, Clone, Copy)]
pub struct StragglerDist {
    pub seed: u64,
    /// Maximum fractional per-step slowdown (0.15 = the slowest
    /// instance can run 15% late).
    pub spread: f64,
}

impl Default for StragglerDist {
    fn default() -> StragglerDist {
        StragglerDist { seed: 0, spread: 0.0 }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl StragglerDist {
    /// The synchronization skew of collective step `step` across
    /// `instances` members: the worst of the per-instance slowdown
    /// draws, in `[0, spread]`.  Pointwise monotone in `instances`
    /// (more members can only raise the max).
    pub fn skew(&self, step: u64, instances: usize) -> f64 {
        if self.spread <= 0.0 || instances <= 1 {
            return 0.0;
        }
        let mut worst = 0.0f64;
        for i in 0..instances as u64 {
            let h = splitmix64(
                self.seed
                    ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ i.wrapping_mul(0xD6E8_FEB8_6659_FD93),
            );
            let u = (h >> 11) as f64 / (1u64 << 53) as f64;
            worst = worst.max(u);
        }
        worst * self.spread
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignVars;

    fn model() -> LinkModel {
        LinkModel::new(&DesignVars::default())
    }

    #[test]
    fn bandwidth_derivation() {
        // 12.5 GB/s * 0.8 / 240 MHz = ~41.67 B/cycle
        let m = model();
        assert!((m.bytes_per_cycle - 41.67).abs() < 0.1,
                "B/cyc = {}", m.bytes_per_cycle);
    }

    #[test]
    fn message_overhead_charged() {
        let m = model();
        assert_eq!(m.message_cycles(0), 0);
        assert_eq!(m.message_cycles(1),
                   MESSAGE_OVERHEAD_CYCLES + 1);
    }

    #[test]
    fn payload_scales_linearly() {
        let m = model();
        let small = m.message_cycles(1 << 16);
        let big = m.message_cycles(1 << 26);
        let ratio = (big - MESSAGE_OVERHEAD_CYCLES) as f64
            / (small - MESSAGE_OVERHEAD_CYCLES) as f64;
        assert!((ratio / 1024.0 - 1.0).abs() < 0.02, "ratio = {ratio}");
    }

    #[test]
    fn single_instance_costs_nothing() {
        let c = ring_cost(1 << 20, 1, &model());
        assert_eq!(c.steps, 0);
        assert_eq!(c.cycles, 0);
    }

    #[test]
    fn ring_step_count_and_chunking() {
        let c = ring_cost(1 << 20, 4, &model());
        assert_eq!(c.steps, 6); // 2 * (4 - 1)
        assert_eq!(c.chunk_bytes, (1u64 << 20).div_ceil(4));
        assert_eq!(c.bytes_per_instance, 6 * c.chunk_bytes);
        assert!(c.cycles > 0);
    }

    #[test]
    fn overhead_makes_wide_rings_costlier_on_small_payloads() {
        // tiny gradient: per-step overhead dominates, so more instances
        // cost strictly more cycles
        let m = model();
        let c2 = ring_cost(1024, 2, &m);
        let c8 = ring_cost(1024, 8, &m);
        assert!(c8.cycles > c2.cycles, "{} !> {}", c8.cycles, c2.cycles);
    }

    #[test]
    fn large_payload_cost_roughly_bandwidth_bound() {
        // 2(N-1)/N of the data crosses each link: for large payloads the
        // total cycles approach 2 * total / bandwidth regardless of N
        let m = model();
        let total = 1u64 << 28;
        let c4 = ring_cost(total, 4, &m);
        let ideal = 2.0 * total as f64 / m.bytes_per_cycle;
        let ratio = c4.cycles as f64 / ideal;
        assert!(ratio > 0.7 && ratio < 1.1, "ratio = {ratio}");
    }

    #[test]
    fn plan_cost_matches_analytic_ring() {
        use crate::engine::collective::{Collective, RingCollective};
        let m = model();
        // words divisible by N so plan and analytic chunking agree
        let words = 1u64 << 18;
        let plan = RingCollective.steps(4, words);
        assert_eq!(plan_cost(&plan, &m),
                   ring_cost(words * 4, 4, &m).cycles);
    }

    #[test]
    fn hier_plan_beats_ring_on_overhead_dominated_payloads() {
        use crate::engine::collective::{Collective, HierCollective,
                                        RingCollective};
        // tiny gradient at N=16: the flat ring pays 30 message
        // overheads, the 4x4 hierarchy only 12 — fewer steps win even
        // though inter-group steps share the trunk 4 ways
        let m = model();
        let words = 1024u64;
        let ring = plan_cost(&RingCollective.steps(16, words), &m);
        let hier = plan_cost(
            &HierCollective { group: 4 }.steps(16, words), &m);
        assert!(hier < ring, "{hier} !< {ring}");
    }

    #[test]
    fn straggler_skew_is_deterministic_and_bounded() {
        let d = StragglerDist { seed: 42, spread: 0.2 };
        for step in 0..50u64 {
            let s = d.skew(step, 8);
            assert!((0.0..=0.2).contains(&s), "step {step}: skew {s}");
            assert_eq!(s, d.skew(step, 8), "skew not deterministic");
        }
        // spread 0 and single instances never skew
        assert_eq!(StragglerDist::default().skew(3, 8), 0.0);
        assert_eq!(d.skew(3, 1), 0.0);
        // more members can only wait longer (pointwise max over draws)
        for step in 0..20u64 {
            assert!(d.skew(step, 16) >= d.skew(step, 4));
        }
        // a different seed actually moves the draws somewhere
        let d2 = StragglerDist { seed: 43, spread: 0.2 };
        assert!((0..50u64).any(|s| d.skew(s, 8) != d2.skew(s, 8)));
    }
}
