//! FPGA resource estimation (DSP / ALM / BRAM) for a generated
//! accelerator instance, calibrated against the paper's Table II.
//!
//! Calibration protocol (DESIGN.md): fit each power-law on the 1X and 4X
//! rows of Table II, then treat the 2X row — and everything downstream
//! (Fig. 9/10, Table III) — as *predictions*.  The 2X predictions land
//! within ~8% of the paper for DSP/ALM, which is the "shape holds"
//! criterion.

use crate::config::{DesignVars, Network};
use crate::hw::bram::BufferPlan;

/// Stratix 10 GX device limits from the paper's §IV-A setup.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub dsp: u64,
    pub alm: u64,
    pub bram_mbits: f64,
}

/// The paper's Stratix 10 GX development kit device.
pub const STRATIX10_GX: Device =
    Device { dsp: 5760, alm: 93_000, bram_mbits: 240.0 };

/// Estimated resource usage of one accelerator instance.
#[derive(Debug, Clone, Copy)]
pub struct ResourceReport {
    pub dsp: u64,
    pub dsp_frac: f64,
    pub alm: u64,
    pub alm_frac: f64,
    pub bram_mbits: f64,
    pub bram_frac: f64,
    /// True if the design fits the device.
    pub fits: bool,
}

impl ResourceReport {
    /// Aggregate totals for `instances` replicated accelerator
    /// instances (one device each): absolute resources scale linearly;
    /// per-device utilization fractions and the fit verdict are
    /// unchanged because every instance occupies its own FPGA.
    pub fn aggregate(&self, instances: usize) -> ResourceReport {
        let n = instances.max(1) as u64;
        ResourceReport {
            dsp: self.dsp * n,
            alm: self.alm * n,
            bram_mbits: self.bram_mbits * n as f64,
            ..*self
        }
    }
}

// DSP = A_DSP * macs^B_DSP, through (1024, 1699) and (4096, 5760).
const A_DSP: f64 = 3.79357;
const B_DSP: f64 = 0.88069;

// ALM = A_ALM * macs^B_ALM, through (1024, 20_800) and (4096, 72_000)
// (Table II's "720K" at 76.2% of a 93K-ALM device reads as 72.0K).
const A_ALM: f64 = 42.06;
const B_ALM: f64 = 0.8952;

// BRAM = fixed IP blocks (DDR controller, DMA FIFOs, control) + slope *
// structural buffer plan.  Both constants are solved from the 1X and 4X
// rows of Table II (10.6 and 54.5 Mbit) against our structural plans, so
// the 2X row is a genuine prediction.
fn bram_calibration() -> (f64, f64) {
    let p1 = BufferPlan::plan(&Network::cifar(1),
                              &DesignVars::for_scale(1))
        .total_mbits();
    let p4 = BufferPlan::plan(&Network::cifar(4),
                              &DesignVars::for_scale(4))
        .total_mbits();
    let fixed = (54.5 * p1 - 10.6 * p4) / (p1 - p4);
    let slope = (10.6 - fixed) / p1;
    (fixed, slope)
}

/// Estimate resources for `net` under `dv` on `device`.
pub fn estimate(net: &Network, dv: &DesignVars, device: &Device)
                -> ResourceReport {
    let macs = dv.mac_count() as f64;
    let dsp = (A_DSP * macs.powf(B_DSP)).round() as u64;
    let dsp = dsp.min(device.dsp); // the 4X design saturates the device
    let alm = (A_ALM * macs.powf(B_ALM)).round() as u64;

    let plan = BufferPlan::plan(net, dv);
    let (fixed, slope) = bram_calibration();
    let bram_mbits = plan.total_mbits() * slope + fixed;

    ResourceReport {
        dsp,
        dsp_frac: dsp as f64 / device.dsp as f64,
        alm,
        alm_frac: alm as f64 / device.alm as f64,
        bram_mbits,
        bram_frac: bram_mbits / device.bram_mbits,
        fits: dsp <= device.dsp
            && alm <= device.alm
            && bram_mbits <= device.bram_mbits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    fn report(scale: usize) -> ResourceReport {
        estimate(&Network::cifar(scale), &DesignVars::for_scale(scale),
                 &STRATIX10_GX)
    }

    #[test]
    fn dsp_matches_calibration_points() {
        let r1 = report(1);
        let r4 = report(4);
        assert!((r1.dsp as i64 - 1699).abs() <= 17, "1X dsp {}", r1.dsp);
        assert_eq!(r4.dsp, 5760, "4X saturates the device");
    }

    #[test]
    fn dsp_2x_prediction_within_10pct() {
        let r2 = report(2);
        let err = (r2.dsp as f64 - 3363.0).abs() / 3363.0;
        assert!(err < 0.10, "2X dsp {} ({:.1}% off)", r2.dsp, err * 100.0);
    }

    #[test]
    fn alm_2x_prediction_within_10pct() {
        let r2 = report(2);
        let err = (r2.alm as f64 - 41_500.0).abs() / 41_500.0;
        assert!(err < 0.10, "2X alm {} ({:.1}% off)", r2.alm, err * 100.0);
    }

    #[test]
    fn bram_1x_matches_calibration() {
        let r1 = report(1);
        assert!((r1.bram_mbits - 10.6).abs() < 0.2,
                "1X bram {}", r1.bram_mbits);
    }

    #[test]
    fn bram_scales_with_width() {
        let (r1, r2, r4) = (report(1), report(2), report(4));
        assert!(r1.bram_mbits < r2.bram_mbits);
        assert!(r2.bram_mbits < r4.bram_mbits);
        // 4X is a calibration point: Table II says 54.5 Mbit
        assert!((r4.bram_mbits - 54.5).abs() < 0.2,
                "4X bram {}", r4.bram_mbits);
    }

    #[test]
    fn bram_2x_prediction_within_30pct() {
        // Table II 2X: 22.8 Mbit (held out of the calibration)
        let r2 = report(2);
        let err = (r2.bram_mbits - 22.8).abs() / 22.8;
        assert!(err < 0.30, "2X bram {} ({:.0}% off)",
                r2.bram_mbits, err * 100.0);
    }

    #[test]
    fn all_paper_designs_fit() {
        for s in [1, 2, 4] {
            assert!(report(s).fits, "{s}x does not fit");
        }
    }

    #[test]
    fn aggregate_scales_absolutes_only() {
        let r = report(1);
        let agg = r.aggregate(4);
        assert_eq!(agg.dsp, 4 * r.dsp);
        assert_eq!(agg.alm, 4 * r.alm);
        assert!((agg.bram_mbits - 4.0 * r.bram_mbits).abs() < 1e-9);
        assert!((agg.dsp_frac - r.dsp_frac).abs() < 1e-12);
        assert_eq!(agg.fits, r.fits);
        // degenerate instance counts clamp to one
        assert_eq!(r.aggregate(0).dsp, r.dsp);
    }

    #[test]
    fn fractions_consistent() {
        let r = report(2);
        assert!((r.dsp_frac - r.dsp as f64 / 5760.0).abs() < 1e-12);
        assert!(r.bram_frac > 0.0 && r.bram_frac < 1.0);
    }
}
