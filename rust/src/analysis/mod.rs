//! Static fixed-point range analysis: prove, at compile time, which
//! Q-format accumulators of a compiled network can never wrap an i32 —
//! and name the first batch size at which the ones that can, do.
//!
//! The paper's premise is 16-bit fixed-point training (FA=8 activation,
//! FW=12 weight, FG=12 gradient fractional bits) with i32 accumulation.
//! Nothing in the compiler *proved* the chosen formats safe for a given
//! net, batch size, and DesignVars; PR 4 hit exactly that bug by hand
//! (BN second moments wrapped the i32 batch sum and were patched to
//! `2*FA - FQ_SHIFT` headroom).  This pass makes the bound machine-
//! checked, the way compile-time bit-width verification is a core pass
//! in the CNN-accelerator-compiler literature (arXiv:2203.04015;
//! quantization-range analysis as the precondition for credible
//! fixed-point accelerators, arXiv:1712.08934).
//!
//! ## Model
//!
//! Every layer descriptor publishes [`AccContract`]s — the exact
//! worst-case magnitude each of its i32 accumulators reaches under
//! fully ±i16-saturated inputs (chain length × largest tap, from the
//! layer geometry: `nif·k·k` for conv FP, `nof·k·k` for BP, `Noy·Nox`
//! products per weight-gradient tap, per-image statistic bounds for
//! BN).  This pass propagates them through the requant shifts
//! (`SHIFT_CONV_FP/BP`, `SHIFT_WU_STORE`, BN's `FQ_SHIFT` headroom)
//! and the batch accumulation, and renders a per-layer, per-phase
//! bit-width table with one verdict per accumulator:
//!
//! - `proven` / `headroom(N bits)` — fits i32 at the analyzed batch
//!   size, with N spare magnitude bits;
//! - `wrap-by-contract` — the bound exceeds i32, but wrapping here is
//!   the documented deterministic contract: per-image MAC chains and
//!   the gradient accumulators share exact wrapping-i32 semantics with
//!   the XLA-lowered kernels on every path (engine shards, cluster
//!   ring), so a wrap is bit-identical everywhere and reproducible —
//!   reported, never refused;
//! - `overflow-possible(>= K images)` — a **must-stay-exact**
//!   accumulator (the BN statistic sums, which feed `inv_std` and the
//!   running-statistics EMA where a wrap silently poisons training)
//!   can wrap: K is the first image count that can exceed `i32::MAX`.
//!
//! The cluster ring merge adds no magnitude beyond the full-batch sum:
//! `engine::cluster` splits the batch across instances and the ring
//! all-reduce's partial sums are each a subset of the per-image
//! contributions, so the batch bound already covers any accelerator
//! count — which is exactly why bit-identity holds at any
//! workers × accelerators.  The report still records the cluster size
//! it was derived under.
//!
//! `session::validate` runs this pass on every spec build and refuses
//! (typed [`crate::session::SpecError`]) any spec with an
//! overflow-possible verdict; `stratus analyze` renders the full table
//! (`--json` for the CI artifact form) without refusing, via
//! `Spec::resolve_for_analysis`.

use std::collections::BTreeMap;

use crate::config::{DesignVars, Network};
use crate::hw::mac_array::Phase;
use crate::jsonx::Json;
use crate::nn::bn::FQ_SHIFT;
use crate::ops::{self, AccContract};

/// Largest value a wrapping i32 batch accumulator may reach while
/// staying exact.  (The negative range holds one more, so using the
/// positive bound is conservative by a single count.)
pub const I32_SAFE: i64 = i32::MAX as i64;

/// Model knobs for historical/what-if layouts.  The default models the
/// kernels as shipped; the PR-4 regression test swaps
/// `bn_moment_shift` to 0 to re-derive the pre-fix overflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Model {
    /// Headroom shift applied to per-image BN second moments before
    /// they enter the i32 batch sum (`nn::bn::FQ_SHIFT` as shipped;
    /// 0 models the pre-PR-4 layout that stored them at full 2·FA).
    pub bn_moment_shift: u32,
}

impl Default for Model {
    fn default() -> Self {
        Model { bn_moment_shift: FQ_SHIFT }
    }
}

/// One analyzed accumulator: a layer × phase × accumulator row of the
/// report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AccRow {
    pub layer: String,
    pub phase: Phase,
    /// Accumulator tag from the op's contract (`fp-mac`, `wgrad-sum`,
    /// `moment-sum`, ...).
    pub acc: &'static str,
    /// Worst |value| one image contributes (the raw chain peak for
    /// per-image accumulators; the post-store-shift contribution for
    /// batch accumulators).
    pub per_image: i64,
    /// Worst |value| the i32 accumulator can mathematically reach at
    /// the analyzed batch size (i128: the point is describing values
    /// that do not fit).
    pub worst: i128,
    /// Bit-width needed to hold `worst` exactly (magnitude + sign).
    pub bits: u32,
    pub per_batch: bool,
    pub must_stay_exact: bool,
    pub verdict: Verdict,
}

/// The analyzer's per-accumulator conclusion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Fits i32 at the analyzed batch size with N spare magnitude bits.
    Proven { headroom_bits: u32 },
    /// Exceeds i32, but wrapping is the documented deterministic
    /// contract for this accumulator class.
    WrapByContract,
    /// A must-stay-exact accumulator can wrap; `first_wrap_images` is
    /// the smallest image count whose worst-case sum exceeds i32.
    OverflowPossible { first_wrap_images: u64 },
}

impl Verdict {
    pub fn is_overflow(&self) -> bool {
        matches!(self, Verdict::OverflowPossible { .. })
    }

    /// The pinned rendering (`proven`, `headroom(N bits)`,
    /// `wrap-by-contract`, `overflow-possible(>= K images)`) — CI greps
    /// for `overflow-possible`.
    pub fn label(&self) -> String {
        match self {
            Verdict::Proven { headroom_bits: 0 } => "proven".into(),
            Verdict::Proven { headroom_bits } => {
                format!("headroom({headroom_bits} bits)")
            }
            Verdict::WrapByContract => "wrap-by-contract".into(),
            Verdict::OverflowPossible { first_wrap_images } => {
                format!("overflow-possible(>= {first_wrap_images} \
                         images)")
            }
        }
    }
}

/// The full range-analysis report for one (network, design, batch).
#[derive(Debug, Clone, PartialEq)]
pub struct RangeReport {
    pub net: String,
    pub batch: usize,
    pub cluster: usize,
    pub rows: Vec<AccRow>,
}

fn phase_tag(p: Phase) -> &'static str {
    match p {
        Phase::Fp => "FP",
        Phase::Bp => "BP",
        Phase::Wu => "WU",
    }
}

/// Magnitude + sign bits needed to hold `worst` exactly (0 -> 1 bit).
fn bits_for(worst: i128) -> u32 {
    debug_assert!(worst >= 0);
    (128 - worst.leading_zeros()) + 1
}

fn analyze_contract(c: &AccContract, batch: usize) -> (i64, i128) {
    if c.per_batch {
        let per_image = c.per_image_stored();
        (per_image, i128::from(per_image) * batch as i128)
    } else {
        (c.per_image_raw, i128::from(c.per_image_raw))
    }
}

/// Run the pass with the as-shipped kernel model.
pub fn analyze(net: &Network, dv: &DesignVars, batch: usize)
               -> RangeReport {
    analyze_model(net, dv, batch, &Model::default())
}

/// Run the pass with explicit model knobs (regression tests of
/// historical layouts).
pub fn analyze_model(net: &Network, dv: &DesignVars, batch: usize,
                     model: &Model) -> RangeReport {
    let mut rows = Vec::new();
    for l in &net.layers {
        for mut c in ops::for_layer(l).range_contracts(l) {
            if c.acc == "moment-sum" {
                c.store_shift = model.bn_moment_shift;
            }
            let (per_image, worst) = analyze_contract(&c, batch);
            let bits = bits_for(worst);
            let verdict = if worst <= i128::from(I32_SAFE) {
                // 32 bits total = magnitude 31: headroom counts spare
                // magnitude bits below the i32 limit
                Verdict::Proven { headroom_bits: 32 - bits }
            } else if c.must_stay_exact {
                let first_wrap =
                    (I32_SAFE / per_image) as u64 + 1;
                Verdict::OverflowPossible {
                    first_wrap_images: first_wrap,
                }
            } else {
                Verdict::WrapByContract
            };
            rows.push(AccRow {
                layer: l.name().to_string(),
                phase: c.phase,
                acc: c.acc,
                per_image,
                worst,
                bits,
                per_batch: c.per_batch,
                must_stay_exact: c.must_stay_exact,
                verdict,
            });
        }
    }
    RangeReport {
        net: net.name.clone(),
        batch,
        cluster: dv.cluster,
        rows,
    }
}

impl RangeReport {
    /// The first overflow-possible row, if any — what the spec gate
    /// reports and refuses on.
    pub fn first_overflow(&self) -> Option<&AccRow> {
        self.rows.iter().find(|r| r.verdict.is_overflow())
    }

    pub fn overflow_count(&self) -> usize {
        self.rows.iter().filter(|r| r.verdict.is_overflow()).count()
    }

    /// Smallest headroom among the proven must-stay-exact batch
    /// accumulators — how close the analyzed batch sails to the limit.
    pub fn min_exact_headroom_bits(&self) -> Option<u32> {
        self.rows
            .iter()
            .filter(|r| r.must_stay_exact)
            .filter_map(|r| match r.verdict {
                Verdict::Proven { headroom_bits } => Some(headroom_bits),
                _ => None,
            })
            .min()
    }

    /// The human table `stratus analyze` prints.
    pub fn render(&self) -> String {
        let mut out = format!(
            "range analysis: {} · batch {} · {} accelerator(s)\n\
             worst-case i32 accumulator magnitudes under fully \
             ±i16-saturated inputs\n\n",
            self.net, self.batch, self.cluster
        );
        out.push_str(&format!(
            "{:<6} {:<5} {:<11} {:>16} {:>20} {:>5}  {}\n",
            "layer", "phase", "accumulator", "per-image", "worst-case",
            "bits", "verdict"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<6} {:<5} {:<11} {:>16} {:>20} {:>5}  {}\n",
                r.layer,
                phase_tag(r.phase),
                r.acc,
                r.per_image,
                r.worst,
                r.bits,
                r.verdict.label()
            ));
        }
        let proven = self
            .rows
            .iter()
            .filter(|r| matches!(r.verdict, Verdict::Proven { .. }))
            .count();
        let wrap = self
            .rows
            .iter()
            .filter(|r| r.verdict == Verdict::WrapByContract)
            .count();
        out.push_str(&format!(
            "\n{} accumulators: {} proven, {} wrap-by-contract, {} \
             overflow-possible\n",
            self.rows.len(),
            proven,
            wrap,
            self.overflow_count()
        ));
        if let Some(bits) = self.min_exact_headroom_bits() {
            out.push_str(&format!(
                "exact-class headroom at batch {}: {} bit(s)\n",
                self.batch, bits
            ));
        }
        out
    }

    /// The machine-readable report (`stratus analyze --json`; CI
    /// uploads these per preset).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("layer".into(), Json::Str(r.layer.clone()));
                m.insert(
                    "phase".into(),
                    Json::Str(phase_tag(r.phase).into()),
                );
                m.insert("acc".into(), Json::Str(r.acc.into()));
                // i128 worst cases exceed f64's exact-integer range;
                // strings keep the report lossless
                m.insert(
                    "per_image".into(),
                    Json::Str(r.per_image.to_string()),
                );
                m.insert("worst".into(), Json::Str(r.worst.to_string()));
                m.insert("bits".into(), Json::Num(f64::from(r.bits)));
                m.insert("per_batch".into(), Json::Bool(r.per_batch));
                m.insert(
                    "must_stay_exact".into(),
                    Json::Bool(r.must_stay_exact),
                );
                m.insert(
                    "verdict".into(),
                    Json::Str(r.verdict.label()),
                );
                if let Verdict::OverflowPossible { first_wrap_images } =
                    r.verdict
                {
                    m.insert(
                        "first_wrap_images".into(),
                        Json::Num(first_wrap_images as f64),
                    );
                }
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("net".into(), Json::Str(self.net.clone()));
        m.insert("batch".into(), Json::Num(self.batch as f64));
        m.insert("cluster".into(), Json::Num(self.cluster as f64));
        m.insert("rows".into(), Json::Arr(rows));
        m.insert(
            "overflow_possible".into(),
            Json::Num(self.overflow_count() as f64),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{SAT_MAX, TAP_MAX};

    fn dv() -> DesignVars {
        DesignVars::for_scale(1)
    }

    #[test]
    fn all_presets_clean_at_default_batch() {
        for net in [
            Network::cifar(1),
            Network::cifar(2),
            Network::cifar(4),
            Network::cifar_bn(1),
            Network::cifar_bn(2),
            Network::cifar_bn(4),
        ] {
            let report =
                analyze(&net, &dv(), crate::session::DEFAULT_BATCH);
            assert_eq!(report.overflow_count(), 0, "{}", net.name);
            assert!(report.first_overflow().is_none());
            // every layer with accumulators is represented
            assert!(report.rows.len() >= net.layers.len() - 3);
        }
    }

    #[test]
    fn bn_moment_sum_wraps_at_128_images() {
        let net = Network::cifar_bn(1);
        // 127 worst-case images fit exactly...
        assert_eq!(analyze(&net, &dv(), 127).overflow_count(), 0);
        // ...and 128 is the first wrapping count
        let report = analyze(&net, &dv(), 128);
        let row = report.first_overflow().expect("moment-sum flagged");
        assert_eq!(row.acc, "moment-sum");
        assert_eq!(row.layer, "n1");
        assert_eq!(
            row.verdict,
            Verdict::OverflowPossible { first_wrap_images: 128 }
        );
    }

    #[test]
    fn pre_pr4_moment_layout_is_rediscovered() {
        // the PR-4 bug: second moments stored at full 2·FA (no
        // FQ_SHIFT headroom) wrap the i32 batch sum at 2 saturated
        // images — the analyzer must rediscover this automatically
        let net = Network::cifar_bn(1);
        let legacy = Model { bn_moment_shift: 0 };
        let report = analyze_model(&net, &dv(), 128, &legacy);
        let row = report.first_overflow().expect("legacy layout flagged");
        assert_eq!(row.acc, "moment-sum");
        assert_eq!(
            row.verdict,
            Verdict::OverflowPossible { first_wrap_images: 2 }
        );
        // even batch 2 is refusable under the legacy layout
        assert_eq!(
            analyze_model(&net, &dv(), 2, &legacy).overflow_count(),
            1
        );
        assert_eq!(
            analyze_model(&net, &dv(), 1, &legacy).overflow_count(),
            0
        );
    }

    #[test]
    fn conv_chain_bounds_match_geometry() {
        let net = Network::cifar(1);
        let report = analyze(&net, &dv(), 40);
        // c1: cin=3, k=3 -> 27 taps + bias seed
        let fp = report
            .rows
            .iter()
            .find(|r| r.layer == "c1" && r.acc == "fp-mac")
            .unwrap();
        assert_eq!(
            i128::from((1i64 << 28) + 27 * TAP_MAX),
            fp.worst
        );
        assert_eq!(fp.verdict, Verdict::WrapByContract);
        // c1 bias-grad: 32·32 pixels × sat bound × batch
        let bg = report
            .rows
            .iter()
            .find(|r| r.layer == "c1" && r.acc == "bgrad-sum")
            .unwrap();
        assert_eq!(bg.worst, i128::from(1024 * SAT_MAX) * 40);
    }

    #[test]
    fn verdict_labels_are_pinned() {
        assert_eq!(Verdict::Proven { headroom_bits: 0 }.label(),
                   "proven");
        assert_eq!(Verdict::Proven { headroom_bits: 7 }.label(),
                   "headroom(7 bits)");
        assert_eq!(Verdict::WrapByContract.label(), "wrap-by-contract");
        assert_eq!(
            Verdict::OverflowPossible { first_wrap_images: 128 }
                .label(),
            "overflow-possible(>= 128 images)"
        );
    }

    #[test]
    fn bits_and_headroom_are_exact() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 2);
        assert_eq!(bits_for(i128::from(i32::MAX)), 32);
        assert_eq!(bits_for(1 << 31), 33);
        // a batch-40 moment sum: 40 · 2^24 needs 31 bits incl. sign
        assert_eq!(bits_for(40 << 24), 31);
    }

    #[test]
    fn json_report_shape() {
        let net = Network::cifar_bn(1);
        let json = analyze(&net, &dv(), 40).to_json();
        assert_eq!(json.get("net").and_then(Json::as_str),
                   Some("cifar10-bn-1x"));
        let rows = json.get("rows").and_then(Json::as_arr).unwrap();
        assert!(!rows.is_empty());
        let first = rows[0].get("verdict").and_then(Json::as_str);
        assert!(first.is_some());
        assert_eq!(
            json.get("overflow_possible").and_then(Json::as_f64),
            Some(0.0)
        );
    }
}
