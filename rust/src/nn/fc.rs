//! Golden-model fully-connected layer: forward, backward (transposed
//! weights, §II) and weight update (outer product), bit-exact with the
//! Pallas matmul kernel.
//!
//! Register-blocked over `RB` weight rows (§Perf; DESIGN.md "Tiled host
//! kernels"): FP streams `x` once across `RB` row dot products, BP
//! chains `RB` rows into the output vector per pass so each output
//! element is loaded/stored once per block instead of once per row, and
//! WU skips whole zero-gradient rows (`shift_round(0) == 0`).  Per
//! output element the wrapping adds keep the scalar order (FP: k
//! ascending; BP: rows ascending), and skipped zero operands add
//! nothing, so results are bit-identical to
//! [`reference`](crate::nn::reference) — property-tested in
//! `tests/kernels.rs`.

use crate::fixed::{requant, shift_round, SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE};
use crate::nn::tensor::Tensor;

/// Weight-row register-block height.
const RB: usize = 4;

/// FC forward: x (K,) at FA, w (N, K) at FW, b (N,) at FA+FW -> (N,) at FA.
pub fn fc_fp(x: &[i32], w: &Tensor, b: &[i32]) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), n);
    let wd = w.data();
    let mut out = vec![0i32; n];
    let mut row0 = 0;
    while row0 < n {
        let nb = RB.min(n - row0);
        let mut acc = [0i32; RB];
        for (t, &xv) in x.iter().enumerate() {
            // post-ReLU activations are sparse; zero terms are the
            // wrapping-add identity either way
            if xv == 0 {
                continue;
            }
            for (u, a) in acc.iter_mut().enumerate().take(nb) {
                *a = a.wrapping_add(
                    xv.wrapping_mul(wd[(row0 + u) * k + t]),
                );
            }
        }
        for (u, &a) in acc.iter().enumerate().take(nb) {
            out[row0 + u] =
                requant(a.wrapping_add(b[row0 + u]), SHIFT_CONV_FP);
        }
        row0 += nb;
    }
    out
}

/// FC backward with the transposed weight matrix: g (N,) at FG -> (K,) at FG.
pub fn fc_bp(g: &[i32], w: &Tensor) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(g.len(), n);
    let wd = w.data();
    let mut out = vec![0i32; k];
    let mut row0 = 0;
    while row0 < n {
        let nb = RB.min(n - row0);
        if nb == RB {
            // full block: four row streams chained per output element
            // (rows ascending, matching the scalar accumulation order)
            let (g0, g1, g2, g3) =
                (g[row0], g[row0 + 1], g[row0 + 2], g[row0 + 3]);
            if (g0, g1, g2, g3) != (0, 0, 0, 0) {
                let rows = &wd[row0 * k..(row0 + 4) * k];
                let (r0, rest) = rows.split_at(k);
                let (r1, rest) = rest.split_at(k);
                let (r2, r3) = rest.split_at(k);
                for (t, o) in out.iter_mut().enumerate() {
                    let mut v = o.wrapping_add(g0.wrapping_mul(r0[t]));
                    v = v.wrapping_add(g1.wrapping_mul(r1[t]));
                    v = v.wrapping_add(g2.wrapping_mul(r2[t]));
                    *o = v.wrapping_add(g3.wrapping_mul(r3[t]));
                }
            }
        } else {
            for u in row0..row0 + nb {
                let gv = g[u];
                if gv == 0 {
                    continue;
                }
                let wrow = &wd[u * k..(u + 1) * k];
                for (o, &wi) in out.iter_mut().zip(wrow) {
                    *o = o.wrapping_add(gv.wrapping_mul(wi));
                }
            }
        }
        row0 += nb;
    }
    out.iter().map(|&v| requant(v, SHIFT_CONV_BP)).collect()
}

/// FC weight gradients: outer(g, x) at FWG plus bias gradients at FG.
pub fn fc_wu(g: &[i32], x: &[i32]) -> (Tensor, Vec<i32>) {
    let (n, k) = (g.len(), x.len());
    let mut dw = Tensor::zeros(&[n, k]);
    let dd = dw.data_mut();
    for (row, &gv) in g.iter().enumerate() {
        if gv == 0 {
            // shift_round(0 * x) == 0: the zeroed row is already exact
            continue;
        }
        for (o, &xv) in dd[row * k..(row + 1) * k].iter_mut().zip(x) {
            *o = shift_round(gv.wrapping_mul(xv), SHIFT_WU_STORE);
        }
    }
    (dw, g.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FA, FW};

    #[test]
    fn fc_fp_identity() {
        // W = I at FW scale, zero bias -> output == input
        let k = 4;
        let mut w = Tensor::zeros(&[k, k]);
        for i in 0..k {
            w.data_mut()[i * k + i] = 1 << FW;
        }
        let x = vec![100, -200, 300, 0];
        assert_eq!(fc_fp(&x, &w, &[0; 4]), x);
    }

    #[test]
    fn fc_fp_bias_only() {
        let w = Tensor::zeros(&[2, 3]);
        let b = vec![1 << (FA + FW), -(1 << (FA + FW))];
        assert_eq!(fc_fp(&[0, 0, 0], &w, &b), vec![256, -256]);
    }

    #[test]
    fn fc_bp_is_transpose_action() {
        // g @ W with W (N,K): check against hand computation
        let w = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let g = vec![1 << 12, 2 << 12]; // scaled so requant shift cancels
        let out = fc_bp(&g, &w);
        assert_eq!(out, vec![1 + 2 * 4, 2 + 2 * 5, 3 + 2 * 6]);
    }

    #[test]
    fn fc_bp_remainder_rows_accumulate() {
        // n = 6 exercises one full 4-row block plus a 2-row remainder
        let w = Tensor::from_vec(
            &[6, 2],
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12],
        );
        let g: Vec<i32> = (1..=6).map(|v| v << 12).collect();
        let out = fc_bp(&g, &w);
        assert_eq!(
            out,
            vec![
                1 + 2 * 3 + 3 * 5 + 4 * 7 + 5 * 9 + 6 * 11,
                2 + 2 * 4 + 3 * 6 + 4 * 8 + 5 * 10 + 6 * 12
            ]
        );
    }

    #[test]
    fn fc_wu_outer_product() {
        let g = vec![16, -32];
        let x = vec![1 << 4, 2 << 4, 3 << 4];
        let (dw, db) = fc_wu(&g, &x);
        // products are multiples of 2^8, shift 4 -> exact division by 16
        assert_eq!(dw.shape(), &[2, 3]);
        assert_eq!(dw.data(), &[16, 32, 48, -32, -64, -96]);
        assert_eq!(db, g);
    }
}
