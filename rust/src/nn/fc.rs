//! Golden-model fully-connected layer: forward, backward (transposed
//! weights, §II) and weight update (outer product), bit-exact with the
//! Pallas matmul kernel.

use crate::fixed::{requant, shift_round, SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE};
use crate::nn::tensor::Tensor;

/// FC forward: x (K,) at FA, w (N, K) at FW, b (N,) at FA+FW -> (N,) at FA.
pub fn fc_fp(x: &[i32], w: &Tensor, b: &[i32]) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), n);
    let wd = w.data();
    (0..n)
        .map(|row| {
            let mut acc = 0i32;
            let wrow = &wd[row * k..(row + 1) * k];
            for (xi, wi) in x.iter().zip(wrow) {
                acc = acc.wrapping_add(xi.wrapping_mul(*wi));
            }
            requant(acc.wrapping_add(b[row]), SHIFT_CONV_FP)
        })
        .collect()
}

/// FC backward with the transposed weight matrix: g (N,) at FG -> (K,) at FG.
pub fn fc_bp(g: &[i32], w: &Tensor) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(g.len(), n);
    let wd = w.data();
    let mut out = vec![0i32; k];
    for (row, &gv) in g.iter().enumerate() {
        let wrow = &wd[row * k..(row + 1) * k];
        for (o, wi) in out.iter_mut().zip(wrow) {
            *o = o.wrapping_add(gv.wrapping_mul(*wi));
        }
    }
    out.iter().map(|&v| requant(v, SHIFT_CONV_BP)).collect()
}

/// FC weight gradients: outer(g, x) at FWG plus bias gradients at FG.
pub fn fc_wu(g: &[i32], x: &[i32]) -> (Tensor, Vec<i32>) {
    let (n, k) = (g.len(), x.len());
    let mut dw = Tensor::zeros(&[n, k]);
    let dd = dw.data_mut();
    for (row, &gv) in g.iter().enumerate() {
        for (col, &xv) in x.iter().enumerate() {
            dd[row * k + col] =
                shift_round(gv.wrapping_mul(xv), SHIFT_WU_STORE);
        }
    }
    (dw, g.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FA, FW};

    #[test]
    fn fc_fp_identity() {
        // W = I at FW scale, zero bias -> output == input
        let k = 4;
        let mut w = Tensor::zeros(&[k, k]);
        for i in 0..k {
            w.data_mut()[i * k + i] = 1 << FW;
        }
        let x = vec![100, -200, 300, 0];
        assert_eq!(fc_fp(&x, &w, &[0; 4]), x);
    }

    #[test]
    fn fc_fp_bias_only() {
        let w = Tensor::zeros(&[2, 3]);
        let b = vec![1 << (FA + FW), -(1 << (FA + FW))];
        assert_eq!(fc_fp(&[0, 0, 0], &w, &b), vec![256, -256]);
    }

    #[test]
    fn fc_bp_is_transpose_action() {
        // g @ W with W (N,K): check against hand computation
        let w = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let g = vec![1 << 12, 2 << 12]; // scaled so requant shift cancels
        let out = fc_bp(&g, &w);
        assert_eq!(out, vec![1 + 2 * 4, 2 + 2 * 5, 3 + 2 * 6]);
    }

    #[test]
    fn fc_wu_outer_product() {
        let g = vec![16, -32];
        let x = vec![1 << 4, 2 << 4, 3 << 4];
        let (dw, db) = fc_wu(&g, &x);
        // products are multiples of 2^8, shift 4 -> exact division by 16
        assert_eq!(dw.shape(), &[2, 3]);
        assert_eq!(dw.data(), &[16, 32, 48, -32, -64, -96]);
        assert_eq!(db, g);
    }
}
