//! Per-shard scratch workspace for the tiled golden kernels.
//!
//! The scalar kernels allocated on every call: a padded input plane per
//! conv FP/BP/WU and a fresh `transpose_flip` weight tensor per conv BP
//! — per *image*, per *layer*.  [`Scratch`] hoists both past per-shard
//! lifetime: the persistent worker pool
//! ([`engine::pool`](crate::engine::pool)) owns one workspace per
//! worker slot and reuses it across batches, so steady-state training
//! performs no per-image *or* per-batch heap allocation in the conv
//! hot path — the pad plane and flip-cache capacity survive from one
//! batch to the next.
//!
//! # Lifetime / invalidation contract
//!
//! - `pad` is a reusable zero-padded plane buffer.  It holds no state
//!   between kernel calls — each call overwrites it fully — so it never
//!   needs invalidation, only capacity.
//! - `flips` caches `transpose_flip(w)` per conv layer, keyed by layer
//!   name.  Weights are frozen within a batch (updates apply at
//!   `end_batch`), so the cache is valid for exactly one batch:
//!   [`Scratch::invalidate`] must run whenever parameters change —
//!   the coordinator calls it from `end_batch` and `resume_from`.
//!   Pool-owned per-shard scratches persist across batches, so the
//!   pool invalidates every slot's flip cache at the start of each
//!   batch before any worker touches the new weights; only the buffer
//!   *capacity* is carried over, never weight-derived state.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::Network;
use crate::nn::conv::transpose_flip;
use crate::nn::tensor::Tensor;

/// Reusable buffers threaded through the golden step; see module docs.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Zero-padded input plane, overwritten by [`Scratch::pad_hw_into`].
    pub(crate) pad: Vec<i32>,
    /// Per-batch cache of 180-degree-rotated, if/of-interchanged conv
    /// kernels (Fig. 5), keyed by conv layer name.
    flips: HashMap<String, Arc<Tensor>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Workspace presized for `net`: the pad plane gets the largest
    /// padded-plane footprint any layer reports via
    /// [`LayerOps::host_scratch_words`](crate::ops::LayerOps::host_scratch_words),
    /// so even the first image of the first batch allocates nothing
    /// mid-kernel.
    pub fn for_net(net: &Network) -> Scratch {
        let words = net
            .layers
            .iter()
            .map(|l| crate::ops::for_layer(l).host_scratch_words(l))
            .max()
            .unwrap_or(0);
        Scratch { pad: Vec::with_capacity(words), flips: HashMap::new() }
    }

    /// Zero-pad `x` (C, H, W) by `p` into the internal plane buffer and
    /// return the padded (Hp, Wp).  The buffer is fully overwritten;
    /// capacity is retained across calls.
    pub(crate) fn pad_hw_into(&mut self, x: &Tensor, p: usize)
                              -> (usize, usize) {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (hp, wp) = (h + 2 * p, w + 2 * p);
        self.pad.clear();
        self.pad.resize(c * hp * wp, 0);
        if p == 0 {
            self.pad.copy_from_slice(x.data());
        } else {
            let xd = x.data();
            for ci in 0..c {
                for y in 0..h {
                    let src = (ci * h + y) * w;
                    let dst = (ci * hp + y + p) * wp + p;
                    self.pad[dst..dst + w]
                        .copy_from_slice(&xd[src..src + w]);
                }
            }
        }
        (hp, wp)
    }

    /// The transposed-flipped view of conv weights `w`, computed once
    /// per `key` per batch.  The `Arc` detaches the returned tensor
    /// from the workspace borrow so the caller can keep using the
    /// scratch (e.g. its pad plane) while holding the weights.
    pub(crate) fn flipped(&mut self, key: &str, w: &Tensor) -> Arc<Tensor> {
        if let Some(t) = self.flips.get(key) {
            return Arc::clone(t);
        }
        let t = Arc::new(transpose_flip(w));
        self.flips.insert(key.to_string(), Arc::clone(&t));
        t
    }

    /// Drop all weight-derived cache entries.  Must run whenever
    /// parameters change (batch end, checkpoint resume).
    pub fn invalidate(&mut self) {
        self.flips.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn pad_into_matches_tensor_pad_hw() {
        let mut rng = Lcg::new(21);
        let mut s = Scratch::new();
        for p in 0..3usize {
            let x = randi(&mut rng, &[3, 5, 4], 500);
            let (hp, wp) = s.pad_hw_into(&x, p);
            let want = x.pad_hw(p);
            assert_eq!((hp, wp), (want.shape()[1], want.shape()[2]));
            assert_eq!(s.pad, want.data());
        }
    }

    #[test]
    fn pad_buffer_is_fully_overwritten_between_shapes() {
        // shrink after a larger padded plane: stale tail must not leak
        let mut s = Scratch::new();
        let big = randi(&mut Lcg::new(1), &[4, 8, 8], 900);
        s.pad_hw_into(&big, 2);
        let small = randi(&mut Lcg::new(2), &[1, 3, 3], 900);
        s.pad_hw_into(&small, 1);
        assert_eq!(s.pad, small.pad_hw(1).data());
    }

    #[test]
    fn flip_cache_returns_same_result_until_invalidated() {
        let mut rng = Lcg::new(3);
        let w = randi(&mut rng, &[4, 3, 3, 3], 300);
        let mut s = Scratch::new();
        let a = s.flipped("c1", &w);
        assert_eq!(*a, transpose_flip(&w));
        // stale-by-design within a batch: the cache ignores new weights
        // under the same key until invalidate()
        let w2 = randi(&mut rng, &[4, 3, 3, 3], 300);
        assert_eq!(*s.flipped("c1", &w2), transpose_flip(&w));
        s.invalidate();
        assert_eq!(*s.flipped("c1", &w2), transpose_flip(&w2));
    }

    #[test]
    fn for_net_presizes_the_largest_conv_plane() {
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 \
             relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let s = Scratch::for_net(&net);
        // widest padded plane: c2's input, 4 x (8+2) x (8+2)
        assert!(s.pad.capacity() >= 400);
    }
}
