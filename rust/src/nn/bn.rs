//! Integer batch normalization — the second extension the paper names
//! (§IV-B, after FxpNet [22]): a normalization unit implementable in the
//! same 16-bit datapath.
//!
//! Hardware-friendly formulation (matching how integer-BN RTL is built):
//! running per-channel statistics are maintained with fixed-point EMA
//! updates; the forward pass normalizes with a *precomputed integer
//! scale* `s = gamma / sqrt(var + eps)` quantized to Q2.14 and a shifted
//! add for beta, so the datapath is one multiply + shift + add per pixel
//! — no division or square root in the loop (those happen once per
//! statistics refresh, off the critical path).  The backward pass treats
//! the statistics as constants (the usual cheap-hardware BN
//! simplification): dL/dx = dL/dy * s.

use crate::fixed::{
    dequantize, quantize, requant, sat16, shift_round, FA, FW,
    SHIFT_WU_STORE,
};
use crate::nn::tensor::Tensor;

/// Fraction bits of the normalization scale.
pub const FS: u32 = 14;

/// EMA momentum of the running statistics as Q15 (0.9, FxpNet's
/// default) — a BN architecture constant, deliberately independent of
/// the SGD momentum.
pub const BN_EMA_Q15: i32 = 29491;

/// Variance floor added before the square root (off-critical-path f64
/// math; the per-pixel datapath never divides).
pub const BN_EPS: f64 = 1e-5;

/// Right-shift applied to per-image second moments before they enter
/// the i32 batch accumulators (stored at `2*FA - FQ_SHIFT`).  A fully
/// saturated image's moment is at most 2^30; shifted by 6 it is 2^24,
/// so the wrapping batch sum stays exact up to 128 worst-case images
/// per batch instead of overflowing at 2 — [`ema_update`] shifts the
/// averaged moment back before forming the variance.
pub const FQ_SHIFT: u32 = 6;

// ---------------------------------------------------------------------
// Network-level BN primitives: stateless functions over the trainer's
// parameter tensors (gamma `w_*` at FW, beta `b_*` at FA+FW like conv
// biases, running mean `rm_*` at FA, running variance `rv_*` at 2*FA).
// The golden model ([`crate::nn::golden`]) calls these; the per-batch
// statistic merge + [`ema_update`] runs in the coordinator at batch
// end, so every image in a batch normalizes against the same frozen
// statistics — which is what keeps sharded training bit-identical.
// ---------------------------------------------------------------------

/// Round-half-up arithmetic shift on a 64-bit product, saturated to the
/// i16 range (the BN unit's wide product register in front of the
/// output saturator).
#[inline(always)]
// clamp() bounds the shifted product to the i16 range before the cast.
#[allow(clippy::cast_possible_truncation)]
fn requant64(acc: i64, shift: u32) -> i32 {
    ((acc + (1i64 << (shift - 1))) >> shift).clamp(-32768, 32767) as i32
}

/// Per-channel integer scale `gamma / sqrt(var + eps)` at FS, i32-wide
/// (the scale refresh runs once per batch, off the critical path).
// clamp(±2^28) bounds the rounded f64 before the cast narrows.
#[allow(clippy::cast_possible_truncation)]
pub fn scales_q(gamma: &Tensor, rv: &Tensor) -> Vec<i32> {
    gamma
        .data()
        .iter()
        .zip(rv.data())
        .map(|(&g, &v)| {
            let var = dequantize(v, 2 * FA).max(0.0) + BN_EPS;
            let s = dequantize(g, FW) / var.sqrt();
            (s * f64::from(1u32 << FS)).round().clamp(
                -f64::from(1u32 << 28),
                f64::from(1u32 << 28),
            ) as i32
        })
        .collect()
}

/// Per-channel inverse standard deviation `1 / sqrt(var + eps)` at FS
/// (the xhat factor of the gamma gradient).
// clamp(±2^28) bounds the rounded f64 before the cast narrows.
#[allow(clippy::cast_possible_truncation)]
pub fn inv_std_q(rv: &Tensor) -> Vec<i32> {
    rv.data()
        .iter()
        .map(|&v| {
            let var = dequantize(v, 2 * FA).max(0.0) + BN_EPS;
            (f64::from(1u32 << FS) / var.sqrt()).round().clamp(
                -f64::from(1u32 << 28),
                f64::from(1u32 << 28),
            ) as i32
        })
        .collect()
}

/// Per-image channel statistics of a (C, H, W) activation tensor: the
/// channel mean at FA and the channel second moment at `2*FA -
/// FQ_SHIFT` (shifted for accumulator headroom — see [`FQ_SHIFT`]).
/// These are what the per-image schedule streams into the DRAM
/// statistic accumulators; averaging them over a batch gives the batch
/// statistics (every image contributes the same pixel count).
// the mean of i16-saturated pixels fits i16; the moment is clamped to
// i32::MAX before the cast narrows.
#[allow(clippy::cast_possible_truncation)]
pub fn image_stats(x: &Tensor) -> (Tensor, Tensor) {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let n = (h * w) as i64;
    let mut means = vec![0i32; c];
    let mut moments = vec![0i32; c];
    for ci in 0..c {
        let base = ci * h * w;
        let mut sum: i64 = 0;
        let mut sq: i64 = 0;
        for &v in &x.data()[base..base + h * w] {
            sum += i64::from(v);
            sq += i64::from(v) * i64::from(v);
        }
        means[ci] = (sum / n) as i32; // at FA
        moments[ci] = ((sq / n) >> FQ_SHIFT)
            .clamp(0, i64::from(i32::MAX)) as i32;
    }
    (
        Tensor::from_vec(&[c], means),
        Tensor::from_vec(&[c], moments),
    )
}

/// BN forward against frozen running statistics:
/// `y = (x - mean) * scale >> FS + beta`, optionally ReLU-clamped —
/// one multiply + shift + add per pixel, per §IV-B / FxpNet.
pub fn forward_affine(x: &Tensor, gamma: &Tensor, beta: &Tensor,
                      rm: &Tensor, rv: &Tensor, relu: bool) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(c, gamma.len(), "bn channel mismatch");
    let scales = scales_q(gamma, rv);
    let mut out = Tensor::zeros(x.shape());
    let od = out.data_mut();
    for ci in 0..c {
        let base = ci * h * w;
        let mu = i64::from(rm.data()[ci]);
        let s = i64::from(scales[ci]);
        // beta lives at FA+FW (like conv biases); align it into the
        // FA+FS product domain before the shared requantization
        let b = i64::from(beta.data()[ci]) << (FS - FW);
        for (o, &v) in od[base..base + h * w]
            .iter_mut()
            .zip(&x.data()[base..base + h * w])
        {
            let acc = (i64::from(v) - mu) * s + b;
            let mut y = requant64(acc, FS);
            if relu && y < 0 {
                y = 0;
            }
            *o = y;
        }
    }
    out
}

/// BN backward through the input (statistics as constants, the cheap-
/// hardware simplification): `dL/dx = dL/dy * scale >> FS`.
pub fn backward_input(g: &Tensor, gamma: &Tensor, rv: &Tensor)
                      -> Tensor {
    let (c, h, w) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    let scales = scales_q(gamma, rv);
    let mut out = Tensor::zeros(g.shape());
    let od = out.data_mut();
    for ci in 0..c {
        let base = ci * h * w;
        let s = i64::from(scales[ci]);
        for (o, &v) in od[base..base + h * w]
            .iter_mut()
            .zip(&g.data()[base..base + h * w])
        {
            *o = requant64(i64::from(v) * s, FS);
        }
    }
    out
}

/// BN parameter gradients from the (already ReLU-masked) output
/// gradient and the layer's input: `dgamma = sum(g * xhat)` stored at
/// FWG like conv kernel gradients, `dbeta = sum(g)` at FG like conv
/// bias gradients (wrapping i32 sums, matching the accumulators).
pub fn backward_params(g: &Tensor, x_in: &Tensor, rm: &Tensor,
                       rv: &Tensor) -> (Tensor, Vec<i32>) {
    let (c, h, w) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    assert_eq!(x_in.shape(), g.shape(), "bn input/gradient mismatch");
    let inv = inv_std_q(rv);
    let mut dgamma = vec![0i32; c];
    let mut dbeta = vec![0i32; c];
    for ci in 0..c {
        let base = ci * h * w;
        let mu = i64::from(rm.data()[ci]);
        let iv = i64::from(inv[ci]);
        let mut acc: i32 = 0;
        let mut db: i32 = 0;
        for (&gv, &xv) in g.data()[base..base + h * w]
            .iter()
            .zip(&x_in.data()[base..base + h * w])
        {
            // xhat at FA through the same wide multiply as forward
            let xhat = requant64((i64::from(xv) - mu) * iv, FS);
            acc = acc.wrapping_add(gv.wrapping_mul(xhat));
            db = db.wrapping_add(gv);
        }
        dgamma[ci] = shift_round(acc, SHIFT_WU_STORE);
        dbeta[ci] = db;
    }
    (Tensor::from_vec(&[c], dgamma), dbeta)
}

/// Fold one batch's merged statistic accumulators into the running
/// statistics: batch mean/variance from the accumulated per-image
/// moments, then the Q15 EMA (`r = m*r + (1-m)*batch`).  Pure integer
/// arithmetic — deterministic at any worker/accelerator grouping
/// because the accumulators merge in fixed order before this runs.
// the Q15 EMA of two i32-range operands is bounded by the larger one,
// so the >> 15 result fits i32 before the cast narrows.
#[allow(clippy::cast_possible_truncation)]
pub fn ema_update(rm: &mut Tensor, rv: &mut Tensor, sm_acc: &[i32],
                  sq_acc: &[i32], count: usize) {
    if count == 0 {
        return;
    }
    assert_eq!(rm.len(), sm_acc.len());
    assert_eq!(rv.len(), sq_acc.len());
    let n = count as i64;
    let m = i64::from(BN_EMA_Q15);
    let one_m = (1i64 << 15) - m;
    let rmd = rm.data_mut();
    for (r, &acc) in rmd.iter_mut().zip(sm_acc) {
        let mean = i64::from(acc) / n; // at FA
        *r = ((m * i64::from(*r) + one_m * mean) >> 15) as i32;
    }
    let rvd = rv.data_mut();
    for ((r, &qacc), &macc) in
        rvd.iter_mut().zip(sq_acc).zip(sm_acc)
    {
        let mean = i64::from(macc) / n; // at FA
        // averaged moment back to 2*FA (accumulated at the shifted
        // resolution for wrap headroom — see FQ_SHIFT)
        let q = (i64::from(qacc) / n) << FQ_SHIFT;
        let var = (q - mean * mean).clamp(0, i64::from(i32::MAX));
        *r = ((m * i64::from(*r) + one_m * var) >> 15) as i32;
    }
}

/// Per-channel integer BN state.
#[derive(Debug, Clone)]
pub struct IntBatchNorm {
    /// Running mean at FA.
    pub mean: Vec<i32>,
    /// Running variance at 2*FA (variance of FA-scaled values).
    pub var: Vec<i32>,
    /// Learnable gain at FS.
    pub gamma: Vec<i32>,
    /// Learnable shift at FA.
    pub beta: Vec<i32>,
    /// Precomputed integer scale gamma/sqrt(var+eps) at FS.
    scale: Vec<i32>,
    /// EMA momentum as Q15 (e.g. 0.9 -> 29491).
    pub ema_q15: i32,
}

impl IntBatchNorm {
    // ema is a momentum in [0, 1]; its Q15 image fits i16.
    #[allow(clippy::cast_possible_truncation)]
    pub fn new(channels: usize, ema: f64) -> IntBatchNorm {
        let mut bn = IntBatchNorm {
            mean: vec![0; channels],
            var: vec![1 << (2 * FA); channels], // var = 1.0
            gamma: vec![1 << FS; channels],
            beta: vec![0; channels],
            scale: vec![0; channels],
            ema_q15: (ema * f64::from(1 << 15)).round() as i32,
        };
        bn.refresh_scale();
        bn
    }

    /// Recompute the integer scales from the running statistics (done
    /// once per refresh, off the per-pixel critical path).
    pub fn refresh_scale(&mut self) {
        for c in 0..self.mean.len() {
            let var = dequantize(self.var[c], 2 * FA).max(0.0) + 1e-5;
            let gamma = dequantize(self.gamma[c], FS);
            self.scale[c] = quantize(gamma / var.sqrt(), FS);
        }
    }

    /// Update running statistics from one (C, H, W) activation tensor
    /// (per-image EMA — images stream one at a time on the accelerator).
    // means of i16-saturated pixels fit i16, the variance is clamped to
    // i32::MAX, and the Q15 EMA is bounded by its operands.
    #[allow(clippy::cast_possible_truncation)]
    pub fn observe(&mut self, x: &Tensor) {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        assert_eq!(c, self.mean.len());
        let n = (h * w) as i64;
        for ci in 0..c {
            let base = ci * h * w;
            let mut sum: i64 = 0;
            for &v in &x.data()[base..base + h * w] {
                sum += i64::from(v);
            }
            let mean = (sum / n) as i32; // at FA
            let mut var_acc: i64 = 0;
            for &v in &x.data()[base..base + h * w] {
                let d = i64::from(v - mean);
                var_acc += d * d; // at 2*FA
            }
            let var = (var_acc / n)
                .clamp(0, i64::from(i32::MAX)) as i32;
            // EMA: s = m*s + (1-m)*new, all Q15 arithmetic
            let m = i64::from(self.ema_q15);
            let one_m = (1i64 << 15) - m;
            self.mean[ci] = ((m * i64::from(self.mean[ci])
                + one_m * i64::from(mean))
                >> 15) as i32;
            self.var[ci] = ((m * i64::from(self.var[ci])
                + one_m * i64::from(var))
                >> 15) as i32;
        }
        self.refresh_scale();
    }

    /// Forward: y = (x - mean) * scale >> FS + beta, per channel.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let mut out = Tensor::zeros(x.shape());
        let od = out.data_mut();
        for ci in 0..c {
            let base = ci * h * w;
            let (mu, s, b) =
                (self.mean[ci], self.scale[ci], self.beta[ci]);
            for (o, &v) in od[base..base + h * w]
                .iter_mut()
                .zip(&x.data()[base..base + h * w])
            {
                let centered = v.wrapping_sub(mu);
                *o = sat16(
                    requant(centered.wrapping_mul(s), FS)
                        .wrapping_add(b),
                );
            }
        }
        out
    }

    /// Backward (statistics-as-constants): dL/dx = dL/dy * scale >> FS.
    pub fn backward(&self, g: &Tensor) -> Tensor {
        let (c, h, w) = (g.shape()[0], g.shape()[1], g.shape()[2]);
        let mut out = Tensor::zeros(g.shape());
        let od = out.data_mut();
        for ci in 0..c {
            let base = ci * h * w;
            let s = self.scale[ci];
            for (o, &v) in od[base..base + h * w]
                .iter_mut()
                .zip(&g.data()[base..base + h * w])
            {
                *o = requant(v.wrapping_mul(s), FS);
            }
        }
        out
    }
}

#[cfg(test)]
// Test fixtures narrow small hand-picked constants; the casts are
// value-checked by the assertions themselves.
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn identity_at_init_for_unit_variance_data() {
        // fresh BN has mean 0, var 1, gamma 1, beta 0: y ~= x for data
        // that actually has those statistics
        let bn = IntBatchNorm::new(2, 0.9);
        let x = Tensor::from_vec(&[2, 1, 2],
                                 vec![256, -256, 128, -128]);
        let y = bn.forward(&x);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a - b).abs() <= 2, "{a} vs {b}");
        }
    }

    #[test]
    fn normalizes_shifted_scaled_data() {
        let mut bn = IntBatchNorm::new(1, 0.0); // ema 0: adopt stats fully
        let mut rng = Lcg::new(4);
        // data ~ N(4.0, 2.0) at FA
        let mut x = randi(&mut rng, &[1, 16, 16], 512);
        for v in x.data_mut() {
            *v += 4 * 256;
        }
        bn.observe(&x);
        let y = bn.forward(&x);
        // output mean ~ 0, std ~ 1 (in FA units)
        let mean: f64 = y.data().iter().map(|&v| f64::from(v)).sum::<f64>()
            / y.len() as f64;
        assert!(mean.abs() < 16.0, "mean = {mean}");
        let var: f64 = y
            .data()
            .iter()
            .map(|&v| (f64::from(v) - mean).powi(2))
            .sum::<f64>()
            / y.len() as f64;
        let std_fa = var.sqrt() / 256.0;
        assert!((std_fa - 1.0).abs() < 0.15, "std = {std_fa}");
    }

    #[test]
    fn gamma_beta_apply() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![2 << FS];
        bn.beta = vec![3 * 256];
        bn.refresh_scale();
        // with mean 0 / var 1: y = 2x + 3
        let x = Tensor::from_vec(&[1, 1, 2], vec![256, -256]);
        let y = bn.forward(&x);
        assert!((y.data()[0] - (2 * 256 + 3 * 256)).abs() <= 4);
        assert!((y.data()[1] - (-2 * 256 + 3 * 256)).abs() <= 4);
    }

    #[test]
    fn backward_scales_gradient() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![2 << FS];
        bn.refresh_scale();
        let g = Tensor::from_vec(&[1, 1, 2], vec![100, -50]);
        let gx = bn.backward(&g);
        assert!((gx.data()[0] - 200).abs() <= 1);
        assert!((gx.data()[1] + 100).abs() <= 1);
    }

    #[test]
    fn ema_converges_to_stream_statistics() {
        let mut bn = IntBatchNorm::new(1, 0.7);
        let mut rng = Lcg::new(5);
        for _ in 0..50 {
            let mut x = randi(&mut rng, &[1, 8, 8], 256);
            for v in x.data_mut() {
                *v += 512; // mean 2.0 at FA
            }
            bn.observe(&x);
        }
        let mean_fa = f64::from(bn.mean[0]) / 256.0;
        assert!((mean_fa - 2.0).abs() < 0.2, "mean = {mean_fa}");
    }

    #[test]
    fn saturates_not_wraps() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![100 << FS]; // absurd gain
        bn.refresh_scale();
        let x = Tensor::from_vec(&[1, 1, 1], vec![30000]);
        let y = bn.forward(&x);
        assert_eq!(y.data()[0], 32767);
    }

    #[test]
    fn saturates_negative_edge_too() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![100 << FS];
        bn.refresh_scale();
        let x = Tensor::from_vec(&[1, 1, 1], vec![-30000]);
        assert_eq!(bn.forward(&x).data()[0], -32768);
        // backward saturates symmetrically
        let g = Tensor::from_vec(&[1, 1, 1], vec![-32000]);
        assert_eq!(bn.backward(&g).data()[0], -32768);
        assert_eq!(
            bn.backward(&Tensor::from_vec(&[1, 1, 1], vec![32000]))
                .data()[0],
            32767
        );
    }

    // ------------- property tests against the float reference -------

    /// Float reference of the IntBatchNorm forward for one value.
    fn float_fwd(bn: &IntBatchNorm, ci: usize, x: i32) -> f64 {
        let mean = f64::from(bn.mean[ci]) / 256.0;
        let var = (f64::from(bn.var[ci]) / 65536.0).max(0.0) + 1e-5;
        let gamma = f64::from(bn.gamma[ci]) / f64::from(1 << FS);
        let beta = f64::from(bn.beta[ci]) / 256.0;
        let xf = f64::from(x) / 256.0;
        (gamma * (xf - mean) / var.sqrt() + beta) * 256.0
    }

    #[test]
    fn forward_tracks_float_reference_property() {
        // sweep random (safe-range) statistics and inputs: the integer
        // forward must agree with the f64 formula within quantization
        // tolerance (scale LSB + output rounding => a couple of LSBs)
        let mut rng = Lcg::new(11);
        for _ in 0..50 {
            let mut bn = IntBatchNorm::new(3, 0.9);
            for ci in 0..3 {
                bn.mean[ci] = rng.int_pm(512);
                // var in [0.64, 4.0] at 2*FA: keeps the Q2.14 scale
                // away from its saturation edge
                bn.var[ci] =
                    (42_000 + rng.below(220_000) as i64) as i32;
                // gamma in ~[-1.5, 1.5] at FS
                bn.gamma[ci] = rng.int_pm(3 * (1 << FS) / 2);
                bn.beta[ci] = rng.int_pm(512);
            }
            bn.refresh_scale();
            let x = randi(&mut rng, &[3, 4, 4], 2000);
            let y = bn.forward(&x);
            for ci in 0..3 {
                for i in 0..16 {
                    let got = f64::from(y.data()[ci * 16 + i]);
                    let want = float_fwd(&bn, ci, x.data()[ci * 16 + i])
                        .clamp(-32768.0, 32767.0);
                    assert!(
                        (got - want).abs() <= 2.0 + want.abs() * 1e-3,
                        "ch {ci}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn backward_tracks_float_reference_property() {
        let mut rng = Lcg::new(12);
        for _ in 0..50 {
            let mut bn = IntBatchNorm::new(2, 0.9);
            for ci in 0..2 {
                bn.var[ci] =
                    (42_000 + rng.below(220_000) as i64) as i32;
                bn.gamma[ci] = rng.int_pm(3 * (1 << FS) / 2);
            }
            bn.refresh_scale();
            let g = randi(&mut rng, &[2, 3, 3], 4000);
            let gx = bn.backward(&g);
            for ci in 0..2 {
                let sf = f64::from(bn.scale[ci]) / f64::from(1 << FS);
                for i in 0..9 {
                    let got = f64::from(gx.data()[ci * 9 + i]);
                    let want = (f64::from(g.data()[ci * 9 + i]) * sf)
                        .clamp(-32768.0, 32767.0);
                    assert!((got - want).abs() <= 1.0,
                            "{got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn ema_variance_converges_to_stream_statistics() {
        // satellite: EMA *variance* convergence, not just the mean
        let mut bn = IntBatchNorm::new(1, 0.7);
        let mut rng = Lcg::new(6);
        for _ in 0..60 {
            // uniform in [-512, 512] at FA => var = (1024)^2/12 at 2FA
            let x = randi(&mut rng, &[1, 16, 16], 512);
            bn.observe(&x);
        }
        let var_fa2 = f64::from(bn.var[0]);
        let want = f64::from(1024 * 1024) / 12.0;
        let rel = (var_fa2 - want).abs() / want;
        assert!(rel < 0.25, "var {var_fa2} vs {want} ({rel:.2} rel)");
    }

    // ------------- the network-level free functions ------------------

    #[test]
    fn forward_affine_identity_at_unit_stats() {
        // gamma 1.0 (FW), var 1.0 (2*FA), mean 0, beta 0 => y ~= x
        let gamma = Tensor::from_vec(&[1], vec![1 << FW]);
        let beta = Tensor::zeros(&[1]);
        let rm = Tensor::zeros(&[1]);
        let rv = Tensor::from_vec(&[1], vec![1 << (2 * FA)]);
        let x = Tensor::from_vec(&[1, 2, 2], vec![300, -300, 77, -1]);
        let y = forward_affine(&x, &gamma, &beta, &rm, &rv, false);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a - b).abs() <= 1, "{a} vs {b}");
        }
        // and the fused relu clamps the negatives
        let yr = forward_affine(&x, &gamma, &beta, &rm, &rv, true);
        assert_eq!(yr.data()[0], y.data()[0]);
        assert_eq!(yr.data()[1], 0);
        assert_eq!(yr.data()[3], 0);
    }

    #[test]
    fn forward_affine_beta_at_accumulator_fraction() {
        // beta of 3.0 at FA+FW lands as 3.0 at FA on the output,
        // mirroring how conv biases ride the accumulator domain
        let gamma = Tensor::from_vec(&[1], vec![1 << FW]);
        let beta = Tensor::from_vec(&[1], vec![3 << (FA + FW)]);
        let rm = Tensor::zeros(&[1]);
        let rv = Tensor::from_vec(&[1], vec![1 << (2 * FA)]);
        let x = Tensor::zeros(&[1, 1, 2]);
        let y = forward_affine(&x, &gamma, &beta, &rm, &rv, false);
        assert!((y.data()[0] - 3 * 256).abs() <= 1, "{}", y.data()[0]);
    }

    #[test]
    fn backward_input_applies_known_scale() {
        // gamma 2.0, var 4.0 => scale ~= 1.0
        let gamma = Tensor::from_vec(&[1], vec![2 << FW]);
        let rv = Tensor::from_vec(&[1], vec![4 << (2 * FA)]);
        let g = Tensor::from_vec(&[1, 1, 3], vec![1000, -500, 3]);
        let gx = backward_input(&g, &gamma, &rv);
        for (a, b) in g.data().iter().zip(gx.data()) {
            assert!((a - b).abs() <= 1, "{a} vs {b}");
        }
    }

    #[test]
    fn image_stats_exact_small_case() {
        let x =
            Tensor::from_vec(&[1, 2, 2], vec![256, 512, 768, 1024]);
        let (m, q) = image_stats(&x);
        assert_eq!(m.data(), &[640]);
        // (256^2 + 512^2 + 768^2 + 1024^2)/4 = 491520, >> FQ_SHIFT
        assert_eq!(q.data(), &[491520 >> FQ_SHIFT]);
        // two channels stay independent
        let x2 = Tensor::from_vec(&[2, 1, 2],
                                  vec![1024, 2048, -512, 512]);
        let (m2, q2) = image_stats(&x2);
        assert_eq!(m2.data(), &[1536, 0]);
        assert_eq!(q2.data(),
                   &[2_621_440 >> FQ_SHIFT, 262_144 >> FQ_SHIFT]);
    }

    #[test]
    fn image_stats_survive_saturated_batches() {
        // a fully saturated image must leave headroom for the wrapping
        // batch accumulator: 40 such moments must sum without wrapping
        let x = Tensor::from_vec(&[1, 8, 8], vec![32767; 64]);
        let (_, q) = image_stats(&x);
        let per_image = i64::from(q.data()[0]);
        assert!(per_image * 40 < i64::from(i32::MAX),
                "saturated moment {per_image} wraps at batch 40");
    }

    #[test]
    fn backward_params_constant_gradient() {
        // g = const c over n pixels: dbeta = n*c exactly; with mean 0
        // and unit variance, dgamma ~= sum(g * x) >> SHIFT_WU_STORE
        let g = Tensor::from_vec(&[1, 2, 2], vec![100, 100, 100, 100]);
        let x = Tensor::from_vec(&[1, 2, 2], vec![256, -256, 512, 0]);
        let rm = Tensor::zeros(&[1]);
        let rv = Tensor::from_vec(&[1], vec![1 << (2 * FA)]);
        let (dgamma, dbeta) = backward_params(&g, &x, &rm, &rv);
        assert_eq!(dbeta, vec![400]);
        // xhat ~= x (unit stats): sum(g*xhat) ~= 100*512 = 51200,
        // stored at FWG via >> 4 => ~3200
        let got = dgamma.data()[0];
        assert!((got - 3200).abs() <= 8, "dgamma = {got}");
    }

    #[test]
    fn ema_update_exact_small_case() {
        let mut rm = Tensor::zeros(&[1]);
        let mut rv = Tensor::from_vec(&[1], vec![1 << (2 * FA)]);
        // two images, each with channel mean 512 (2.0) and second
        // moment 327680 at 2*FA (5.0), accumulated at the FQ_SHIFTed
        // resolution: batch var = 5.0 - 4.0 = 1.0
        ema_update(&mut rm, &mut rv, &[1024],
                   &[(655_360 >> FQ_SHIFT) as i32], 2);
        // rm: (29491*0 + 3277*512) >> 15 = 51
        assert_eq!(rm.data()[0], 51);
        // rv: var == running var == 1.0 => unchanged
        assert_eq!(rv.data()[0], 1 << (2 * FA));
        // zero count is a no-op
        let before = rm.data()[0];
        ema_update(&mut rm, &mut rv, &[999], &[999], 0);
        assert_eq!(rm.data()[0], before);
    }

    #[test]
    fn ema_update_is_deterministic_in_accumulated_form() {
        // the merge rule: shard sums add (wrapping), the EMA runs once
        // on the merged totals — grouping must not matter
        let mk = || {
            (Tensor::from_vec(&[1], vec![100]),
             Tensor::from_vec(&[1], vec![70000]))
        };
        let (mut rm1, mut rv1) = mk();
        let (mut rm2, mut rv2) = mk();
        // shards (3 + 1 images) vs direct 4 images: same totals
        let sm: Vec<i32> = vec![300 + 900];
        let sq: Vec<i32> = vec![3 * 80_000 + 75_000];
        ema_update(&mut rm1, &mut rv1, &sm, &sq, 4);
        let sm_d: Vec<i32> = vec![1200];
        let sq_d: Vec<i32> = vec![315_000];
        ema_update(&mut rm2, &mut rv2, &sm_d, &sq_d, 4);
        assert_eq!(rm1.data(), rm2.data());
        assert_eq!(rv1.data(), rv2.data());
    }
}
