//! Integer batch normalization — the second extension the paper names
//! (§IV-B, after FxpNet [22]): a normalization unit implementable in the
//! same 16-bit datapath.
//!
//! Hardware-friendly formulation (matching how integer-BN RTL is built):
//! running per-channel statistics are maintained with fixed-point EMA
//! updates; the forward pass normalizes with a *precomputed integer
//! scale* `s = gamma / sqrt(var + eps)` quantized to Q2.14 and a shifted
//! add for beta, so the datapath is one multiply + shift + add per pixel
//! — no division or square root in the loop (those happen once per
//! statistics refresh, off the critical path).  The backward pass treats
//! the statistics as constants (the usual cheap-hardware BN
//! simplification): dL/dx = dL/dy * s.

use crate::fixed::{dequantize, quantize, requant, sat16, FA};
use crate::nn::tensor::Tensor;

/// Fraction bits of the normalization scale.
pub const FS: u32 = 14;

/// Per-channel integer BN state.
#[derive(Debug, Clone)]
pub struct IntBatchNorm {
    /// Running mean at FA.
    pub mean: Vec<i32>,
    /// Running variance at 2*FA (variance of FA-scaled values).
    pub var: Vec<i32>,
    /// Learnable gain at FS.
    pub gamma: Vec<i32>,
    /// Learnable shift at FA.
    pub beta: Vec<i32>,
    /// Precomputed integer scale gamma/sqrt(var+eps) at FS.
    scale: Vec<i32>,
    /// EMA momentum as Q15 (e.g. 0.9 -> 29491).
    pub ema_q15: i32,
}

impl IntBatchNorm {
    pub fn new(channels: usize, ema: f64) -> IntBatchNorm {
        let mut bn = IntBatchNorm {
            mean: vec![0; channels],
            var: vec![1 << (2 * FA); channels], // var = 1.0
            gamma: vec![1 << FS; channels],
            beta: vec![0; channels],
            scale: vec![0; channels],
            ema_q15: (ema * f64::from(1 << 15)).round() as i32,
        };
        bn.refresh_scale();
        bn
    }

    /// Recompute the integer scales from the running statistics (done
    /// once per refresh, off the per-pixel critical path).
    pub fn refresh_scale(&mut self) {
        for c in 0..self.mean.len() {
            let var = dequantize(self.var[c], 2 * FA).max(0.0) + 1e-5;
            let gamma = dequantize(self.gamma[c], FS);
            self.scale[c] = quantize(gamma / var.sqrt(), FS);
        }
    }

    /// Update running statistics from one (C, H, W) activation tensor
    /// (per-image EMA — images stream one at a time on the accelerator).
    pub fn observe(&mut self, x: &Tensor) {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        assert_eq!(c, self.mean.len());
        let n = (h * w) as i64;
        for ci in 0..c {
            let base = ci * h * w;
            let mut sum: i64 = 0;
            for &v in &x.data()[base..base + h * w] {
                sum += i64::from(v);
            }
            let mean = (sum / n) as i32; // at FA
            let mut var_acc: i64 = 0;
            for &v in &x.data()[base..base + h * w] {
                let d = i64::from(v - mean);
                var_acc += d * d; // at 2*FA
            }
            let var = (var_acc / n)
                .clamp(0, i64::from(i32::MAX)) as i32;
            // EMA: s = m*s + (1-m)*new, all Q15 arithmetic
            let m = i64::from(self.ema_q15);
            let one_m = (1i64 << 15) - m;
            self.mean[ci] = ((m * i64::from(self.mean[ci])
                + one_m * i64::from(mean))
                >> 15) as i32;
            self.var[ci] = ((m * i64::from(self.var[ci])
                + one_m * i64::from(var))
                >> 15) as i32;
        }
        self.refresh_scale();
    }

    /// Forward: y = (x - mean) * scale >> FS + beta, per channel.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let mut out = Tensor::zeros(x.shape());
        let od = out.data_mut();
        for ci in 0..c {
            let base = ci * h * w;
            let (mu, s, b) =
                (self.mean[ci], self.scale[ci], self.beta[ci]);
            for (o, &v) in od[base..base + h * w]
                .iter_mut()
                .zip(&x.data()[base..base + h * w])
            {
                let centered = v.wrapping_sub(mu);
                *o = sat16(
                    requant(centered.wrapping_mul(s), FS)
                        .wrapping_add(b),
                );
            }
        }
        out
    }

    /// Backward (statistics-as-constants): dL/dx = dL/dy * scale >> FS.
    pub fn backward(&self, g: &Tensor) -> Tensor {
        let (c, h, w) = (g.shape()[0], g.shape()[1], g.shape()[2]);
        let mut out = Tensor::zeros(g.shape());
        let od = out.data_mut();
        for ci in 0..c {
            let base = ci * h * w;
            let s = self.scale[ci];
            for (o, &v) in od[base..base + h * w]
                .iter_mut()
                .zip(&g.data()[base..base + h * w])
            {
                *o = requant(v.wrapping_mul(s), FS);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn identity_at_init_for_unit_variance_data() {
        // fresh BN has mean 0, var 1, gamma 1, beta 0: y ~= x for data
        // that actually has those statistics
        let bn = IntBatchNorm::new(2, 0.9);
        let x = Tensor::from_vec(&[2, 1, 2],
                                 vec![256, -256, 128, -128]);
        let y = bn.forward(&x);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a - b).abs() <= 2, "{a} vs {b}");
        }
    }

    #[test]
    fn normalizes_shifted_scaled_data() {
        let mut bn = IntBatchNorm::new(1, 0.0); // ema 0: adopt stats fully
        let mut rng = Lcg::new(4);
        // data ~ N(4.0, 2.0) at FA
        let mut x = randi(&mut rng, &[1, 16, 16], 512);
        for v in x.data_mut() {
            *v += 4 * 256;
        }
        bn.observe(&x);
        let y = bn.forward(&x);
        // output mean ~ 0, std ~ 1 (in FA units)
        let mean: f64 = y.data().iter().map(|&v| f64::from(v)).sum::<f64>()
            / y.len() as f64;
        assert!(mean.abs() < 16.0, "mean = {mean}");
        let var: f64 = y
            .data()
            .iter()
            .map(|&v| (f64::from(v) - mean).powi(2))
            .sum::<f64>()
            / y.len() as f64;
        let std_fa = var.sqrt() / 256.0;
        assert!((std_fa - 1.0).abs() < 0.15, "std = {std_fa}");
    }

    #[test]
    fn gamma_beta_apply() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![2 << FS];
        bn.beta = vec![3 * 256];
        bn.refresh_scale();
        // with mean 0 / var 1: y = 2x + 3
        let x = Tensor::from_vec(&[1, 1, 2], vec![256, -256]);
        let y = bn.forward(&x);
        assert!((y.data()[0] - (2 * 256 + 3 * 256)).abs() <= 4);
        assert!((y.data()[1] - (-2 * 256 + 3 * 256)).abs() <= 4);
    }

    #[test]
    fn backward_scales_gradient() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![2 << FS];
        bn.refresh_scale();
        let g = Tensor::from_vec(&[1, 1, 2], vec![100, -50]);
        let gx = bn.backward(&g);
        assert!((gx.data()[0] - 200).abs() <= 1);
        assert!((gx.data()[1] + 100).abs() <= 1);
    }

    #[test]
    fn ema_converges_to_stream_statistics() {
        let mut bn = IntBatchNorm::new(1, 0.7);
        let mut rng = Lcg::new(5);
        for _ in 0..50 {
            let mut x = randi(&mut rng, &[1, 8, 8], 256);
            for v in x.data_mut() {
                *v += 512; // mean 2.0 at FA
            }
            bn.observe(&x);
        }
        let mean_fa = f64::from(bn.mean[0]) / 256.0;
        assert!((mean_fa - 2.0).abs() < 0.2, "mean = {mean_fa}");
    }

    #[test]
    fn saturates_not_wraps() {
        let mut bn = IntBatchNorm::new(1, 0.0);
        bn.gamma = vec![100 << FS]; // absurd gain
        bn.refresh_scale();
        let x = Tensor::from_vec(&[1, 1, 1], vec![30000]);
        let y = bn.forward(&x);
        assert_eq!(y.data()[0], 32767);
    }
}
