//! Minimal dense i32 tensor used by the golden model, the weight-update
//! unit, and the PJRT literal bridge.  Row-major, shape-checked.

use std::fmt;

/// Dense row-major i32 tensor (fixed-point payload).
#[derive(Clone, PartialEq, Eq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl Tensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    /// Wrap an existing buffer; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<i32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 3D access (c, y, x) — the activation/gradient layout.
    #[inline(always)]
    pub fn at3(&self, c: usize, y: usize, x: usize) -> i32 {
        let (_, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * h + y) * w + x]
    }

    #[inline(always)]
    pub fn set3(&mut self, c: usize, y: usize, x: usize, v: i32) {
        let (_, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * h + y) * w + x] = v;
    }

    /// 4D access (o, i, ky, kx) — the conv-kernel layout.
    #[inline(always)]
    pub fn at4(&self, o: usize, i: usize, ky: usize, kx: usize) -> i32 {
        let (_, ci, kh, kw) =
            (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((o * ci + i) * kh + ky) * kw + kx]
    }

    #[inline(always)]
    pub fn set4(&mut self, o: usize, i: usize, ky: usize, kx: usize, v: i32) {
        let (_, ci, kh, kw) =
            (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((o * ci + i) * kh + ky) * kw + kx] = v;
    }

    /// Zero-pad the two trailing (H, W) dims of a (C, H, W) tensor.
    pub fn pad_hw(&self, p: usize) -> Tensor {
        assert_eq!(self.shape.len(), 3);
        let (c, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        let mut out = Tensor::zeros(&[c, h + 2 * p, w + 2 * p]);
        for ci in 0..c {
            for y in 0..h {
                let src = (ci * h + y) * w;
                let dst = (ci * (h + 2 * p) + y + p) * (w + 2 * p) + p;
                out.data[dst..dst + w]
                    .copy_from_slice(&self.data[src..src + w]);
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(i32) -> i32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Max absolute value (reporting / overflow diagnostics).
    pub fn max_abs(&self) -> i32 {
        self.data.iter().map(|v| v.saturating_abs()).max().unwrap_or(0)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} els", self.shape, self.data.len())?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert!(t.data().iter().all(|&v| v == 0));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_count() {
        Tensor::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn at3_row_major() {
        let t = Tensor::from_vec(&[2, 2, 3], (0..12).collect());
        assert_eq!(t.at3(0, 0, 0), 0);
        assert_eq!(t.at3(0, 1, 2), 5);
        assert_eq!(t.at3(1, 0, 1), 7);
    }

    #[test]
    fn at4_row_major() {
        let t = Tensor::from_vec(&[2, 2, 2, 2], (0..16).collect());
        assert_eq!(t.at4(1, 0, 1, 0), 10);
        assert_eq!(t.at4(0, 1, 1, 1), 7);
    }

    #[test]
    fn pad_hw_places_interior() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1, 2, 3, 4]);
        let p = t.pad_hw(1);
        assert_eq!(p.shape(), &[1, 4, 4]);
        assert_eq!(p.at3(0, 0, 0), 0);
        assert_eq!(p.at3(0, 1, 1), 1);
        assert_eq!(p.at3(0, 2, 2), 4);
        assert_eq!(p.at3(0, 3, 3), 0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }
}
