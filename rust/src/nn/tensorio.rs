//! FXTB tensor-bundle reader/writer — the binary interchange format used
//! for initial parameters and golden test vectors emitted by
//! `python/compile/aot.py` (see its module docstring for the layout).

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::nn::tensor::Tensor;

const MAGIC: &[u8; 4] = b"FXTB";

/// Ordered name -> tensor bundle.
#[derive(Debug, Clone, Default)]
pub struct Bundle {
    entries: Vec<(String, Tensor)>,
}

impl Bundle {
    pub fn new() -> Bundle {
        Bundle::default()
    }

    pub fn push(&mut self, name: &str, t: Tensor) {
        self.entries.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Like [`Bundle::get`], but a missing tensor is an error naming it
    /// (the checkpoint loader's "all fields or nothing" validation).
    pub fn get_req(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .ok_or_else(|| anyhow!("bundle is missing tensor `{name}`"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.entries.iter().map(|(n, t)| (n.as_str(), t))
    }

    /// Parse a bundle from bytes.
    pub fn from_bytes(blob: &[u8]) -> Result<Bundle> {
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > blob.len() {
                bail!("truncated bundle at offset {off}");
            }
            let s = &blob[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let u32le = |off: &mut usize| -> Result<u32> {
            let b = take(off, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        if take(&mut off, 4)? != MAGIC {
            bail!("bad magic (expected FXTB)");
        }
        let count = u32le(&mut off)? as usize;
        let mut bundle = Bundle::new();
        for _ in 0..count {
            let name_len = u32le(&mut off)? as usize;
            let name = String::from_utf8(take(&mut off, name_len)?.to_vec())
                .context("tensor name not utf8")?;
            let ndim = u32le(&mut off)? as usize;
            if ndim > 8 {
                bail!("implausible ndim {ndim} for `{name}`");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32le(&mut off)? as usize);
            }
            let n: usize = shape.iter().product();
            let raw = take(&mut off, 4 * n)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            bundle.push(&name, Tensor::from_vec(&shape, data));
        }
        if off != blob.len() {
            bail!("{} trailing bytes in bundle", blob.len() - off);
        }
        Ok(bundle)
    }

    pub fn load(path: &Path) -> Result<Bundle> {
        let blob = fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Bundle::from_bytes(&blob)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Serialize to bytes (same layout the python writer produces).
    // the u32 length fields mirror the on-disk format; entry counts,
    // name lengths and tensor dims are all far below 2^32.
    #[allow(clippy::cast_possible_truncation)]
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
            for &d in t.shape() {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in t.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = Bundle::new();
        b.push("a", Tensor::from_vec(&[2, 3], (0..6).collect()));
        b.push("b", Tensor::from_vec(&[1], vec![-5]));
        let blob = b.to_bytes();
        let r = Bundle::from_bytes(&blob).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.get("a").unwrap().shape(), &[2, 3]);
        assert_eq!(r.get("b").unwrap().data(), &[-5]);
        assert_eq!(r.names(), vec!["a", "b"]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Bundle::from_bytes(b"NOPE\0\0\0\0").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut b = Bundle::new();
        b.push("t", Tensor::from_vec(&[4], vec![1, 2, 3, 4]));
        let blob = b.to_bytes();
        for cut in [3, 8, 12, blob.len() - 1] {
            assert!(Bundle::from_bytes(&blob[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut b = Bundle::new();
        b.push("t", Tensor::from_vec(&[1], vec![7]));
        let mut blob = b.to_bytes();
        blob.push(0);
        assert!(Bundle::from_bytes(&blob).is_err());
    }
}
