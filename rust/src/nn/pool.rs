//! Golden-model pooling / upsampling / scaling units (§III-G), bit-exact
//! with the Pallas kernels.
//!
//! The hot loops are **row-blocked**: [`maxpool`] walks each input row
//! exactly once, splitting it into `k`-wide windows with
//! `chunks_exact` and folding every window of the row into the output
//! row's running max/argmax (one sequential read stream per row, no
//! per-window strided gathers); [`upsample_scale`] reads its gradient
//! and index rows as slices and computes each scatter target from the
//! row base.  The per-window comparison sequence (dy → dx, strict `>`,
//! best starts at `i32::MIN` with index 0) is exactly the scalar
//! [`reference`](crate::nn::reference) order, so outputs and argmax
//! tie-breaks are bit-identical — property-tested in
//! `tests/kernels.rs`, and raced against the scalar oracles in the
//! `hotpath` bench's `pool_fp`/`pool_bp` rows.

use crate::fixed::sat16;
use crate::nn::tensor::Tensor;

/// k x k max pooling with flat window-argmax indices (row-major within the
/// window: idx = dy * k + dx).  Ties pick the first maximum, matching
/// `jnp.argmax`.
// the window-local index is < k*k (k is 2 or 3), far inside i32.
#[allow(clippy::cast_possible_truncation)]
pub fn maxpool(x: &Tensor, k: usize) -> (Tensor, Tensor) {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    let mut idx = Tensor::zeros(&[c, oh, ow]);
    let xd = x.data();
    let od = out.data_mut();
    let id = idx.data_mut();
    for ci in 0..c {
        for oy in 0..oh {
            let obase = (ci * oh + oy) * ow;
            let orow = &mut od[obase..obase + ow];
            let irow = &mut id[obase..obase + ow];
            orow.fill(i32::MIN);
            for dy in 0..k {
                let xrow = (ci * h + oy * k + dy) * w;
                let row = &xd[xrow..xrow + w];
                for (ox, win) in row.chunks_exact(k).enumerate() {
                    for (dx, &v) in win.iter().enumerate() {
                        if v > orow[ox] {
                            orow[ox] = v;
                            irow[ox] = (dy * k + dx) as i32;
                        }
                    }
                }
            }
        }
    }
    (out, idx)
}

/// Upsample pooled gradients through the stored indices (demultiplexer)
/// and scale by the binary ReLU activation gradient.
// stored argmax indices are in [0, k*k) by construction in `maxpool`.
#[allow(clippy::cast_sign_loss)]
pub fn upsample_scale(g: &Tensor, idx: &Tensor, mask: &Tensor, k: usize)
                      -> Tensor {
    let (c, oh, ow) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    let (h, w) = (oh * k, ow * k);
    assert_eq!(mask.shape(), &[c, h, w]);
    let mut out = Tensor::zeros(&[c, h, w]);
    let od = out.data_mut();
    let gd = g.data();
    let idxd = idx.data();
    let md = mask.data();
    for ci in 0..c {
        for oy in 0..oh {
            let gbase = (ci * oh + oy) * ow;
            let grow = &gd[gbase..gbase + ow];
            let irow = &idxd[gbase..gbase + ow];
            let xbase = (ci * h + oy * k) * w;
            for (ox, (&gv, &i)) in grow.iter().zip(irow).enumerate() {
                let i = i as usize;
                let (dy, dx) = (i / k, i % k);
                let p = xbase + dy * w + ox * k + dx;
                od[p] = sat16(gv.wrapping_mul(md[p]));
            }
        }
    }
    out
}

/// Scaling unit at a ReLU node without pooling: g * relu'(a).
pub fn scale_mask(g: &Tensor, mask: &Tensor) -> Tensor {
    assert_eq!(g.shape(), mask.shape());
    let data = g
        .data()
        .iter()
        .zip(mask.data())
        .map(|(&gv, &mv)| sat16(gv.wrapping_mul(mv)))
        .collect();
    Tensor::from_vec(g.shape(), data)
}

/// Binary activation gradient of ReLU, recomputed from post-ReLU
/// activations (a > 0), exactly as the JAX side derives it.
pub fn relu_mask(a: &Tensor) -> Tensor {
    a.map(|v| i32::from(v > 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn maxpool_picks_window_max_and_index() {
        let x = Tensor::from_vec(
            &[1, 4, 4],
            vec![1, 5, 2, 2, 3, 4, 2, 9, 7, 6, 1, 1, 5, 8, 0, 3],
        );
        let (p, idx) = maxpool(&x, 2);
        assert_eq!(p.data(), &[5, 9, 8, 3]);
        assert_eq!(idx.data(), &[1, 3, 3, 3]);
    }

    #[test]
    fn maxpool_tie_picks_first() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![7, 7, 7, 7]);
        let (_, idx) = maxpool(&x, 2);
        assert_eq!(idx.data(), &[0]);
    }

    #[test]
    fn maxpool_indices_fit_2_bits_for_2x2() {
        let mut rng = Lcg::new(9);
        let x = randi(&mut rng, &[16, 16, 16], 500);
        let (_, idx) = maxpool(&x, 2);
        assert!(idx.data().iter().all(|&v| (0..4).contains(&v)));
    }

    #[test]
    fn upsample_routes_to_max_only() {
        let x = Tensor::from_vec(
            &[1, 4, 4],
            vec![1, 5, 2, 2, 3, 4, 2, 9, 7, 6, 1, 1, 5, 8, 0, 3],
        );
        let (_, idx) = maxpool(&x, 2);
        let g = Tensor::from_vec(&[1, 2, 2], vec![10, 20, 30, 40]);
        let ones = Tensor::from_vec(&[1, 4, 4], vec![1; 16]);
        let up = upsample_scale(&g, &idx, &ones, 2);
        // one nonzero per window, at the argmax position
        assert_eq!(up.at3(0, 0, 1), 10);
        assert_eq!(up.at3(0, 1, 3), 20);
        assert_eq!(up.at3(0, 3, 1), 30);
        assert_eq!(up.at3(0, 3, 3), 40);
        assert_eq!(up.data().iter().filter(|&&v| v != 0).count(), 4);
    }

    #[test]
    fn upsample_zero_mask_kills_gradient() {
        let mut rng = Lcg::new(2);
        let x = randi(&mut rng, &[4, 8, 8], 300);
        let (_, idx) = maxpool(&x, 2);
        let g = randi(&mut rng, &[4, 4, 4], 300);
        let zero = Tensor::zeros(&[4, 8, 8]);
        let up = upsample_scale(&g, &idx, &zero, 2);
        assert!(up.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn pool_roundtrip_property() {
        // maxpool(upsample(pooled)) == pooled for positive inputs
        let mut rng = Lcg::new(11);
        for _ in 0..10 {
            let mut x = randi(&mut rng, &[4, 8, 8], 900);
            for v in x.data_mut() {
                *v = v.abs() + 1;
            }
            let (p, idx) = maxpool(&x, 2);
            let ones = Tensor::from_vec(&[4, 8, 8], vec![1; 4 * 64]);
            let up = upsample_scale(&p, &idx, &ones, 2);
            let (p2, _) = maxpool(&up, 2);
            assert_eq!(p2, p);
        }
    }

    #[test]
    fn relu_mask_binary() {
        let a = Tensor::from_vec(&[1, 1, 4], vec![-3, 0, 2, 100]);
        assert_eq!(relu_mask(&a).data(), &[0, 0, 1, 1]);
    }

    #[test]
    fn scale_mask_elementwise() {
        let g = Tensor::from_vec(&[1, 1, 3], vec![5, -7, 9]);
        let m = Tensor::from_vec(&[1, 1, 3], vec![1, 0, 1]);
        assert_eq!(scale_mask(&g, &m).data(), &[5, 0, 9]);
    }
}
