//! Golden-model convolutions — the bit-exact rust mirror of
//! `python/compile/kernels/ref.py` (Eqs. 1, 3, 4 of the paper).
//!
//! These run the same i32 wrap-around accumulation and round-half-up
//! requantization as the lowered Pallas kernels, so outputs from the PJRT
//! artifacts and from this module are identical integers.

use crate::fixed::{requant, shift_round, SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE};
use crate::nn::tensor::Tensor;

/// FP convolution, Eq. (1): stride 1, square kernel, zero padding.
///
/// `x`: (Nif, H, W) at FA; `w`: (Nof, Nif, K, K) at FW; `b`: (Nof,) at
/// FA+FW.  Returns (Nof, H, W) at FA (post-ReLU if `relu`).
pub fn conv_fp(x: &Tensor, w: &Tensor, b: &[i32], pad: usize, relu: bool,
               shift: u32) -> Tensor {
    let (nof, nif, k) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    assert_eq!(x.shape()[0], nif, "input channel mismatch");
    assert_eq!(b.len(), nof);
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let (oh, ow) = (hp - k + 1, wp - k + 1);
    let mut out = Tensor::zeros(&[nof, oh, ow]);
    let xd = xp.data();
    let od = out.data_mut();
    // Weight-stationary loop order (§Perf): for each scalar tap, stream a
    // contiguous input row into a contiguous accumulator row — the inner
    // loop auto-vectorizes, ~8x over the naive per-pixel loop nest.
    let mut acc = vec![0i32; oh * ow];
    for of in 0..nof {
        acc.fill(b[of]);
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    let wt = w.at4(of, ci, ky, kx);
                    if wt == 0 {
                        continue;
                    }
                    for oy in 0..oh {
                        let xrow = (ci * hp + oy + ky) * wp + kx;
                        let arow = oy * ow;
                        let xs = &xd[xrow..xrow + ow];
                        let ac = &mut acc[arow..arow + ow];
                        for (a, &xv) in ac.iter_mut().zip(xs) {
                            *a = a.wrapping_add(wt.wrapping_mul(xv));
                        }
                    }
                }
            }
        }
        let orow = of * oh * ow;
        for (o, &a) in od[orow..orow + oh * ow].iter_mut().zip(&acc) {
            let mut v = requant(a, shift);
            if relu && v < 0 {
                v = 0;
            }
            *o = v;
        }
    }
    out
}

/// Convenience: FP conv with the standard activation requantization.
pub fn conv_fp_std(x: &Tensor, w: &Tensor, b: &[i32], relu: bool) -> Tensor {
    conv_fp(x, w, b, (w.shape()[2] - 1) / 2, relu, SHIFT_CONV_FP)
}

/// The transposable-buffer access pattern (Fig. 5) in index space:
/// interchange if/of and rotate the taps 180 degrees.
pub fn transpose_flip(w: &Tensor) -> Tensor {
    let (nof, nif, kh, kw) =
        (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    let mut out = Tensor::zeros(&[nif, nof, kh, kw]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..kh {
                for kx in 0..kw {
                    out.set4(ci, of, kh - 1 - ky, kw - 1 - kx,
                             w.at4(of, ci, ky, kx));
                }
            }
        }
    }
    out
}

/// BP convolution, Eq. (3): local gradients of layer l from those of
/// layer l+1 through the 180-degree-rotated, if/of-interchanged kernels.
pub fn conv_bp(g: &Tensor, w: &Tensor, pad: usize) -> Tensor {
    let wt = transpose_flip(w);
    let zeros = vec![0i32; wt.shape()[0]];
    conv_fp(g, &wt, &zeros, pad, false, SHIFT_CONV_BP)
}

/// WU convolution, Eq. (4): kernel gradients (Nof, Nif, K, K) at FWG and
/// bias gradients (Nof,) at FG.
pub fn conv_wu(x: &Tensor, g: &Tensor, pad: usize) -> (Tensor, Vec<i32>) {
    let k = 2 * pad + 1;
    let nif = x.shape()[0];
    let (nof, oh, ow) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let xd = xp.data();
    let gd = g.data();
    let mut dw = Tensor::zeros(&[nof, nif, k, k]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    // row-wise dot products over contiguous slices
                    // (auto-vectorized; §Perf)
                    let mut acc: i32 = 0;
                    for y in 0..oh {
                        let grow = (of * oh + y) * ow;
                        let xrow = (ci * hp + y + ky) * wp + kx;
                        let gs = &gd[grow..grow + ow];
                        let xs = &xd[xrow..xrow + ow];
                        for (&gv, &xv) in gs.iter().zip(xs) {
                            acc = acc.wrapping_add(gv.wrapping_mul(xv));
                        }
                    }
                    dw.set4(of, ci, ky, kx, shift_round(acc, SHIFT_WU_STORE));
                }
            }
        }
    }
    let mut db = vec![0i32; nof];
    for of in 0..nof {
        let base = of * oh * ow;
        let mut s: i32 = 0;
        for v in &gd[base..base + oh * ow] {
            s = s.wrapping_add(*v);
        }
        db[of] = s;
    }
    (dw, db)
}

#[cfg(test)]
// The float-reference comparisons narrow small in-range values; the
// assertions value-check the casts.
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn conv_identity_kernel_passes_through() {
        // 1x1-channel 3x3 identity kernel scaled to 1.0 at FW
        let x = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|v| v * 16).collect());
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set4(0, 0, 1, 1, 1 << crate::fixed::FW);
        let out = conv_fp_std(&x, &w, &[0], false);
        assert_eq!(out.data(), x.data());
    }

    #[test]
    fn conv_relu_clamps_negative() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![-100, -100, -100, -100]);
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set4(0, 0, 1, 1, 1 << crate::fixed::FW);
        let out = conv_fp_std(&x, &w, &[0], true);
        assert!(out.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn conv_bias_at_accumulator_fraction() {
        let x = Tensor::zeros(&[1, 2, 2]);
        let w = Tensor::zeros(&[1, 1, 3, 3]);
        // bias of 1.0 at FA+FW requantizes to 1.0 at FA = 256
        let out = conv_fp_std(&x, &w, &[1 << (crate::fixed::FA
                                              + crate::fixed::FW)], false);
        assert!(out.data().iter().all(|&v| v == 256));
    }

    #[test]
    fn transpose_flip_is_involution() {
        let mut rng = Lcg::new(7);
        let w = randi(&mut rng, &[6, 4, 3, 3], 400);
        assert_eq!(transpose_flip(&transpose_flip(&w)), w);
    }

    #[test]
    fn transpose_flip_places_rotated_taps() {
        let mut w = Tensor::zeros(&[2, 3, 3, 3]);
        w.set4(1, 2, 0, 2, 77);
        let t = transpose_flip(&w);
        assert_eq!(t.at4(2, 1, 2, 0), 77);
    }

    #[test]
    fn conv_bp_shape_interchanges_channels() {
        let mut rng = Lcg::new(3);
        let g = randi(&mut rng, &[8, 4, 4], 300);
        let w = randi(&mut rng, &[8, 5, 3, 3], 150);
        let out = conv_bp(&g, &w, 1);
        assert_eq!(out.shape(), &[5, 4, 4]);
    }

    #[test]
    fn conv_wu_zero_gradient_zero_update() {
        let mut rng = Lcg::new(4);
        let x = randi(&mut rng, &[3, 6, 6], 300);
        let g = Tensor::zeros(&[4, 6, 6]);
        let (dw, db) = conv_wu(&x, &g, 1);
        assert!(dw.data().iter().all(|&v| v == 0));
        assert!(db.iter().all(|&v| v == 0));
    }

    #[test]
    fn conv_wu_single_plane_manual_check() {
        // mirror of test_conv_wu_is_4d_intra_tile_accumulation in python
        let mut rng = Lcg::new(5);
        let x = randi(&mut rng, &[3, 8, 8], 400);
        let g = randi(&mut rng, &[4, 8, 8], 400);
        let (dw, _) = conv_wu(&x, &g, 1);
        let xp = x.pad_hw(1);
        for ky in 0..3 {
            for kx in 0..3 {
                let mut acc: i64 = 0;
                for y in 0..8 {
                    for xx in 0..8 {
                        acc += i64::from(g.at3(2, y, xx))
                            * i64::from(xp.at3(1, y + ky, xx + kx));
                    }
                }
                let want = ((acc as f64 / f64::from(1u32 << SHIFT_WU_STORE))
                    + 0.5)
                    .floor() as i32;
                assert_eq!(dw.at4(2, 1, ky, kx), want);
            }
        }
    }
}
