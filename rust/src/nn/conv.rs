//! Golden-model convolutions — the bit-exact rust mirror of
//! `python/compile/kernels/ref.py` (Eqs. 1, 3, 4 of the paper), tiled
//! for host throughput.
//!
//! These run the same i32 wrap-around accumulation and round-half-up
//! requantization as the lowered Pallas kernels, so outputs from the PJRT
//! artifacts and from this module are identical integers.
//!
//! # Tiled layout (§Perf; DESIGN.md "Tiled host kernels")
//!
//! The software analogue of the paper's `Pox x Pof` MAC-array tiling:
//!
//! - **FP/BP**: register-blocked over `OFB` output channels by `TW`
//!   output pixels.  All `Nif * K * K` taps stream through a
//!   `[[i32; TW]; OFB]` accumulator block that lives in registers, so
//!   each accumulator is loaded/stored once per output tile instead of
//!   once per tap, and each padded input row is reused across the
//!   `OFB` channels of the block.
//! - **WU**: one pass per `(of, ci)` pair computing all `K*K` tap
//!   accumulators simultaneously — the gradient row is read once
//!   instead of `K*K` times, and zero gradient pixels (the common case
//!   behind a maxpool upsampler, which leaves `1 - 1/k^2` of the plane
//!   zero) skip all `K*K` multiplies.
//!
//! Every kernel preserves the scalar term order *per output element*
//! (FP/BP: ci → ky → kx; WU: y → ox per tap), so outputs are
//! bit-identical to [`reference`](crate::nn::reference) by
//! construction — property-tested in `tests/kernels.rs`.  The `_s`
//! variants reuse a per-shard [`Scratch`] for the padded plane and the
//! per-batch `transpose_flip` cache; the plain functions allocate a
//! transient workspace and exist for call sites without one (tests,
//! one-shot evaluation).

use crate::fixed::{requant, shift_round, SHIFT_CONV_BP, SHIFT_CONV_FP,
                   SHIFT_WU_STORE};
use crate::nn::scratch::Scratch;
use crate::nn::tensor::Tensor;

/// Output-channel register-block height of the FP/BP tile.
const OFB: usize = 4;
/// Output-pixel register-block width of the FP/BP tile.
const TW: usize = 16;

/// Geometry of one conv invocation over the padded plane.
struct Geom {
    nof: usize,
    nif: usize,
    k: usize,
    hp: usize,
    wp: usize,
    oh: usize,
    ow: usize,
}

/// The tiled FP/BP inner loops over a pre-padded plane `xd`.
///
/// Per output element the taps arrive in scalar order (ci → ky → kx,
/// zero taps skipped), so the wrapped i32 accumulator matches the
/// reference bit for bit; only the order *across* elements differs.
fn conv_fp_kernel(xd: &[i32], wd: &[i32], b: &[i32], od: &mut [i32],
                  g: &Geom, relu: bool, shift: u32) {
    let k = g.k;
    let mut of0 = 0;
    while of0 < g.nof {
        let nb = OFB.min(g.nof - of0);
        for oy in 0..g.oh {
            let mut ox0 = 0;
            while ox0 < g.ow {
                let tw = TW.min(g.ow - ox0);
                let mut acc = [[0i32; TW]; OFB];
                for (u, a) in acc.iter_mut().enumerate().take(nb) {
                    a[..tw].fill(b[of0 + u]);
                }
                for ci in 0..g.nif {
                    for ky in 0..k {
                        let xrow = (ci * g.hp + oy + ky) * g.wp + ox0;
                        let xs = &xd[xrow..xrow + tw + k - 1];
                        for (u, a) in
                            acc.iter_mut().enumerate().take(nb)
                        {
                            let wrow =
                                ((of0 + u) * g.nif + ci) * k * k + ky * k;
                            for (kx, &wt) in
                                wd[wrow..wrow + k].iter().enumerate()
                            {
                                if wt == 0 {
                                    continue;
                                }
                                for (av, &xv) in a[..tw]
                                    .iter_mut()
                                    .zip(&xs[kx..kx + tw])
                                {
                                    *av = av
                                        .wrapping_add(wt.wrapping_mul(xv));
                                }
                            }
                        }
                    }
                }
                for (u, a) in acc.iter().enumerate().take(nb) {
                    let orow =
                        (of0 + u) * g.oh * g.ow + oy * g.ow + ox0;
                    for (o, &av) in
                        od[orow..orow + tw].iter_mut().zip(&a[..tw])
                    {
                        let mut v = requant(av, shift);
                        if relu && v < 0 {
                            v = 0;
                        }
                        *o = v;
                    }
                }
                ox0 += tw;
            }
        }
        of0 += nb;
    }
}

/// FP convolution, Eq. (1): stride 1, square kernel, zero padding.
///
/// `x`: (Nif, H, W) at FA; `w`: (Nof, Nif, K, K) at FW; `b`: (Nof,) at
/// FA+FW.  Returns (Nof, H, W) at FA (post-ReLU if `relu`).
pub fn conv_fp(x: &Tensor, w: &Tensor, b: &[i32], pad: usize, relu: bool,
               shift: u32) -> Tensor {
    let mut s = Scratch::new();
    conv_fp_s(x, w, b, pad, relu, shift, &mut s)
}

/// [`conv_fp`] against a reusable per-shard workspace.
pub fn conv_fp_s(x: &Tensor, w: &Tensor, b: &[i32], pad: usize,
                 relu: bool, shift: u32, s: &mut Scratch) -> Tensor {
    let (nof, nif, k) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    assert_eq!(x.shape()[0], nif, "input channel mismatch");
    assert_eq!(b.len(), nof);
    let (hp, wp) = s.pad_hw_into(x, pad);
    let (oh, ow) = (hp - k + 1, wp - k + 1);
    let mut out = Tensor::zeros(&[nof, oh, ow]);
    let g = Geom { nof, nif, k, hp, wp, oh, ow };
    conv_fp_kernel(&s.pad, w.data(), b, out.data_mut(), &g, relu, shift);
    out
}

/// Convenience: FP conv with the standard activation requantization.
pub fn conv_fp_std(x: &Tensor, w: &Tensor, b: &[i32], relu: bool) -> Tensor {
    conv_fp(x, w, b, (w.shape()[2] - 1) / 2, relu, SHIFT_CONV_FP)
}

/// [`conv_fp_std`] against a reusable per-shard workspace.
pub fn conv_fp_std_s(x: &Tensor, w: &Tensor, b: &[i32], relu: bool,
                     s: &mut Scratch) -> Tensor {
    conv_fp_s(x, w, b, (w.shape()[2] - 1) / 2, relu, SHIFT_CONV_FP, s)
}

/// The transposable-buffer access pattern (Fig. 5) in index space:
/// interchange if/of and rotate the taps 180 degrees.
pub fn transpose_flip(w: &Tensor) -> Tensor {
    let (nof, nif, kh, kw) =
        (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    let mut out = Tensor::zeros(&[nif, nof, kh, kw]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..kh {
                for kx in 0..kw {
                    out.set4(ci, of, kh - 1 - ky, kw - 1 - kx,
                             w.at4(of, ci, ky, kx));
                }
            }
        }
    }
    out
}

/// BP convolution, Eq. (3): local gradients of layer l from those of
/// layer l+1 through the 180-degree-rotated, if/of-interchanged kernels.
pub fn conv_bp(g: &Tensor, w: &Tensor, pad: usize) -> Tensor {
    let wt = transpose_flip(w);
    let zeros = vec![0i32; wt.shape()[0]];
    conv_fp(g, &wt, &zeros, pad, false, SHIFT_CONV_BP)
}

/// [`conv_bp`] against a reusable workspace: the flipped kernels are
/// cached under `key` (the conv layer name) for the rest of the batch,
/// so the flip runs once per batch instead of once per image.  The
/// caller owns invalidation ([`Scratch::invalidate`] on any parameter
/// change).
pub fn conv_bp_s(g: &Tensor, w: &Tensor, key: &str, pad: usize,
                 s: &mut Scratch) -> Tensor {
    let wt = s.flipped(key, w);
    let zeros = vec![0i32; wt.shape()[0]];
    conv_fp_s(g, wt.as_ref(), &zeros, pad, false, SHIFT_CONV_BP, s)
}

/// WU convolution, Eq. (4): kernel gradients (Nof, Nif, K, K) at FWG and
/// bias gradients (Nof,) at FG.
pub fn conv_wu(x: &Tensor, g: &Tensor, pad: usize) -> (Tensor, Vec<i32>) {
    let mut s = Scratch::new();
    conv_wu_s(x, g, pad, &mut s)
}

/// [`conv_wu`] against a reusable per-shard workspace.
///
/// One pass per (of, ci): all K*K tap accumulators advance together
/// while the gradient row streams once.  Per tap the terms still
/// arrive y → ox ascending, and zero gradient pixels contribute
/// nothing either way, so the wrapped sums equal the reference's.
pub fn conv_wu_s(x: &Tensor, g: &Tensor, pad: usize, s: &mut Scratch)
                 -> (Tensor, Vec<i32>) {
    let k = 2 * pad + 1;
    let nif = x.shape()[0];
    let (nof, oh, ow) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    let (hp, wp) = s.pad_hw_into(x, pad);
    let gd = g.data();
    let mut dw = Tensor::zeros(&[nof, nif, k, k]);
    let dd = dw.data_mut();
    let mut accs = vec![0i32; k * k];
    for of in 0..nof {
        for ci in 0..nif {
            accs.fill(0);
            for y in 0..oh {
                let grow = (of * oh + y) * ow;
                let gs = &gd[grow..grow + ow];
                for ky in 0..k {
                    let xrow = (ci * hp + y + ky) * wp;
                    let xs = &s.pad[xrow..xrow + wp];
                    let arow = &mut accs[ky * k..(ky + 1) * k];
                    for (t, &gv) in gs.iter().enumerate() {
                        if gv == 0 {
                            continue;
                        }
                        for (a, &xv) in
                            arow.iter_mut().zip(&xs[t..t + k])
                        {
                            *a = a.wrapping_add(gv.wrapping_mul(xv));
                        }
                    }
                }
            }
            let base = (of * nif + ci) * k * k;
            for (o, &a) in dd[base..base + k * k].iter_mut().zip(&accs) {
                *o = shift_round(a, SHIFT_WU_STORE);
            }
        }
    }
    let mut db = vec![0i32; nof];
    for (of, d) in db.iter_mut().enumerate() {
        let base = of * oh * ow;
        let mut sum: i32 = 0;
        for v in &gd[base..base + oh * ow] {
            sum = sum.wrapping_add(*v);
        }
        *d = sum;
    }
    (dw, db)
}

#[cfg(test)]
// The float-reference comparisons narrow small in-range values; the
// assertions value-check the casts.
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::nn::testutil::{randi, Lcg};

    #[test]
    fn conv_identity_kernel_passes_through() {
        // 1x1-channel 3x3 identity kernel scaled to 1.0 at FW
        let x = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|v| v * 16).collect());
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set4(0, 0, 1, 1, 1 << crate::fixed::FW);
        let out = conv_fp_std(&x, &w, &[0], false);
        assert_eq!(out.data(), x.data());
    }

    #[test]
    fn conv_relu_clamps_negative() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![-100, -100, -100, -100]);
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set4(0, 0, 1, 1, 1 << crate::fixed::FW);
        let out = conv_fp_std(&x, &w, &[0], true);
        assert!(out.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn conv_bias_at_accumulator_fraction() {
        let x = Tensor::zeros(&[1, 2, 2]);
        let w = Tensor::zeros(&[1, 1, 3, 3]);
        // bias of 1.0 at FA+FW requantizes to 1.0 at FA = 256
        let out = conv_fp_std(&x, &w, &[1 << (crate::fixed::FA
                                              + crate::fixed::FW)], false);
        assert!(out.data().iter().all(|&v| v == 256));
    }

    #[test]
    fn transpose_flip_is_involution() {
        let mut rng = Lcg::new(7);
        let w = randi(&mut rng, &[6, 4, 3, 3], 400);
        assert_eq!(transpose_flip(&transpose_flip(&w)), w);
    }

    #[test]
    fn transpose_flip_places_rotated_taps() {
        let mut w = Tensor::zeros(&[2, 3, 3, 3]);
        w.set4(1, 2, 0, 2, 77);
        let t = transpose_flip(&w);
        assert_eq!(t.at4(2, 1, 2, 0), 77);
    }

    #[test]
    fn conv_bp_shape_interchanges_channels() {
        let mut rng = Lcg::new(3);
        let g = randi(&mut rng, &[8, 4, 4], 300);
        let w = randi(&mut rng, &[8, 5, 3, 3], 150);
        let out = conv_bp(&g, &w, 1);
        assert_eq!(out.shape(), &[5, 4, 4]);
    }

    #[test]
    fn conv_bp_scratch_variant_matches_and_caches() {
        let mut rng = Lcg::new(8);
        let g = randi(&mut rng, &[8, 4, 4], 300);
        let w = randi(&mut rng, &[8, 5, 3, 3], 150);
        let want = conv_bp(&g, &w, 1);
        let mut s = Scratch::new();
        assert_eq!(conv_bp_s(&g, &w, "c", 1, &mut s), want);
        // second call hits the flip cache, same result
        assert_eq!(conv_bp_s(&g, &w, "c", 1, &mut s), want);
    }

    #[test]
    fn conv_wu_zero_gradient_zero_update() {
        let mut rng = Lcg::new(4);
        let x = randi(&mut rng, &[3, 6, 6], 300);
        let g = Tensor::zeros(&[4, 6, 6]);
        let (dw, db) = conv_wu(&x, &g, 1);
        assert!(dw.data().iter().all(|&v| v == 0));
        assert!(db.iter().all(|&v| v == 0));
    }

    #[test]
    fn conv_wu_single_plane_manual_check() {
        // mirror of test_conv_wu_is_4d_intra_tile_accumulation in python
        let mut rng = Lcg::new(5);
        let x = randi(&mut rng, &[3, 8, 8], 400);
        let g = randi(&mut rng, &[4, 8, 8], 400);
        let (dw, _) = conv_wu(&x, &g, 1);
        let xp = x.pad_hw(1);
        for ky in 0..3 {
            for kx in 0..3 {
                let mut acc: i64 = 0;
                for y in 0..8 {
                    for xx in 0..8 {
                        acc += i64::from(g.at3(2, y, xx))
                            * i64::from(xp.at3(1, y + ky, xx + kx));
                    }
                }
                let want = ((acc as f64 / f64::from(1u32 << SHIFT_WU_STORE))
                    + 0.5)
                    .floor() as i32;
                assert_eq!(dw.at4(2, 1, ky, kx), want);
            }
        }
    }
}
