//! The weight-update unit's arithmetic (§III-E, Fig. 7): batch
//! accumulation of weight gradients and SGD-with-momentum updates,
//! Eq. (5)/(6), all in fixed point.
//!
//! Per image, freshly computed weight gradients (at FWG) are accumulated
//! into the DRAM-resident i32 accumulators; at the end of the batch the
//! average gradient is formed (multiply by a Q15 reciprocal — batch sizes
//! need not be powers of two), the momentum buffer is advanced
//! (`v = beta*v - lr*g_avg`) and the weights are stepped.  Weights saturate
//! to the i16 range (they live in 16-bit DRAM words); momentum stays i32.

use crate::fixed::{sat16, FG, FV, FW};
use crate::nn::tensor::Tensor;

/// Hyper-parameters in fixed point.
#[derive(Debug, Clone, Copy)]
pub struct SgdHyper {
    /// Learning rate as Q16 (paper: 0.002 -> 131).
    pub lr_q16: i32,
    /// Momentum beta as Q15 (0.9 -> 29491).
    pub beta_q15: i32,
    /// Batch size.
    pub batch: usize,
}

impl SgdHyper {
    // lr and beta are small training hyper-parameters (|x| << 2^14);
    // their Q16/Q15 images fit i32 by orders of magnitude.
    #[allow(clippy::cast_possible_truncation)]
    pub fn new(lr: f64, beta: f64, batch: usize) -> SgdHyper {
        SgdHyper {
            lr_q16: (lr * f64::from(1 << 16)).round() as i32,
            beta_q15: (beta * f64::from(1 << 15)).round() as i32,
            batch,
        }
    }

    /// Q15 reciprocal of the batch size.
    // 2^15 / batch <= 2^15: the rounded value always fits i64.
    #[allow(clippy::cast_possible_truncation)]
    fn recip_q15(&self) -> i64 {
        ((f64::from(1 << 15)) / self.batch as f64).round() as i64
    }
}

/// Whether a parameter is a weight (i16, frac FW), a bias (i32
/// accumulator-resident, frac FA+FW), or a batch-statistic accumulator
/// (BN shard sums: merged like gradients but consumed by the BN
/// statistic refresh at batch end, never by the SGD step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Weight,
    Bias,
    Stat,
}

/// Gradient accumulator + momentum state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct ParamState {
    pub kind: ParamKind,
    /// Batch gradient accumulator (frac FWG for weights, FG for biases).
    pub grad_acc: Tensor,
    /// Momentum buffer (frac FV for weights, FA+FW for biases).
    pub momentum: Tensor,
    /// Images accumulated since the last update.
    pub count: usize,
}

impl ParamState {
    pub fn new(kind: ParamKind, shape: &[usize]) -> ParamState {
        ParamState {
            kind,
            grad_acc: Tensor::zeros(shape),
            momentum: Tensor::zeros(shape),
            count: 0,
        }
    }

    /// Rebuild a state from checkpointed pieces (ckpt restore path).
    /// Errors when the accumulator and momentum geometries disagree —
    /// a checkpoint that would half-load is rejected instead.
    pub fn from_snapshot(kind: ParamKind, grad_acc: Tensor,
                         momentum: Tensor, count: usize)
                         -> anyhow::Result<ParamState> {
        if grad_acc.shape() != momentum.shape() {
            anyhow::bail!(
                "optimizer snapshot is inconsistent: accumulator shape \
                 {:?} vs momentum shape {:?}",
                grad_acc.shape(),
                momentum.shape()
            );
        }
        Ok(ParamState { kind, grad_acc, momentum, count })
    }

    /// Accumulate one image's gradients (Fig. 7: "accumulated tile-by-tile
    /// and repeated for the entire batch").
    pub fn accumulate(&mut self, g: &Tensor) {
        assert_eq!(g.shape(), self.grad_acc.shape());
        for (a, &v) in self.grad_acc.data_mut().iter_mut().zip(g.data()) {
            *a = a.wrapping_add(v);
        }
        self.count += 1;
    }

    /// Fork a zeroed shard-local accumulator with this state's kind and
    /// geometry (the engine's thread-local gradient store).  The fork
    /// carries no momentum: shards only accumulate; momentum advances
    /// once per batch in [`ParamState::apply`] on the merged state.
    pub fn fork_shard(&self) -> ParamState {
        ParamState::new(self.kind, self.grad_acc.shape())
    }

    /// Fold a shard accumulator back into this state.  Accumulation is
    /// wrapping i32 addition — associative and commutative mod 2^32 —
    /// so the merged result is bit-identical to having accumulated every
    /// image directly, at any shard count and in any merge order.  (The
    /// engine still merges shards in fixed index order; this method just
    /// doesn't depend on it.)
    pub fn merge_shard(&mut self, shard: &ParamState) {
        assert_eq!(shard.kind, self.kind, "shard kind mismatch");
        assert_eq!(shard.grad_acc.shape(), self.grad_acc.shape());
        for (a, &v) in self
            .grad_acc
            .data_mut()
            .iter_mut()
            .zip(shard.grad_acc.data())
        {
            *a = a.wrapping_add(v);
        }
        self.count += shard.count;
    }

    /// Discard any accumulated gradients (batch abandoned before its
    /// weight update, e.g. a step failed mid-batch).  Momentum is
    /// untouched: it only advances in [`ParamState::apply`].
    pub fn reset(&mut self) {
        for a in self.grad_acc.data_mut() {
            *a = 0;
        }
        self.count = 0;
    }

    /// End-of-batch weight update, Eq. (6).  Mutates `param` in place and
    /// clears the accumulator.  Statistic accumulators take no SGD step
    /// (the coordinator folds them into the BN running statistics via
    /// `nn::bn::ema_update` and resets them itself).
    // every narrowing cast sits behind a clamp to the i32 (or ±2^28
    // bias) range, so the cast can never change the value.
    #[allow(clippy::cast_possible_truncation)]
    pub fn apply(&mut self, param: &mut Tensor, hy: &SgdHyper) {
        assert_ne!(self.kind, ParamKind::Stat,
                   "statistic accumulators are not SGD-stepped");
        assert_eq!(param.shape(), self.grad_acc.shape());
        let recip = hy.recip_q15();
        let lr = i64::from(hy.lr_q16);
        let beta = i64::from(hy.beta_q15);
        // bias gradients arrive at FG but the bias lives at FA+FW;
        // align fractions before the lr multiply.
        let bias_shift = (crate::fixed::FA + FW) as i64 - FG as i64;
        for ((p, v), &acc) in param
            .data_mut()
            .iter_mut()
            .zip(self.momentum.data_mut())
            .zip(self.grad_acc.data())
        {
            // batch average: multiply by Q15 reciprocal, round
            let mut g_avg = (i64::from(acc) * recip + (1 << 14)) >> 15;
            if self.kind == ParamKind::Bias {
                g_avg <<= bias_shift;
            }
            // v = beta * v - lr * g_avg   (Q15 and Q16 multiplies)
            let bv = (beta * i64::from(*v) + (1 << 14)) >> 15;
            let lg = (lr * g_avg + (1 << 15)) >> 16;
            let vn = bv - lg;
            *v = vn.clamp(i64::from(i32::MIN), i64::from(i32::MAX)) as i32;
            match self.kind {
                ParamKind::Weight => {
                    // v at FV -> weight at FW, saturate to 16-bit DRAM word
                    let step = (vn + (1 << ((FV - FW) as i64 - 1)))
                        >> (FV - FW) as i64;
                    *p = sat16((i64::from(*p) + step)
                        .clamp(i64::from(i32::MIN), i64::from(i32::MAX))
                        as i32);
                }
                ParamKind::Bias => {
                    // bias momentum already at FA+FW; add directly
                    *p = (i64::from(*p) + vn)
                        .clamp(-(1 << 28), 1 << 28) as i32;
                }
                ParamKind::Stat => unreachable!("guarded above"),
            }
        }
        self.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{dequantize, quantize, FWG};

    fn hy(batch: usize) -> SgdHyper {
        SgdHyper::new(0.002, 0.9, batch)
    }

    #[test]
    fn paper_hyperparams_quantize() {
        let h = hy(40);
        assert_eq!(h.lr_q16, 131); // 0.002 * 65536
        assert_eq!(h.beta_q15, 29491); // 0.9 * 32768
    }

    #[test]
    fn accumulate_sums_and_counts() {
        let mut st = ParamState::new(ParamKind::Weight, &[2, 2]);
        st.accumulate(&Tensor::from_vec(&[2, 2], vec![1, 2, 3, 4]));
        st.accumulate(&Tensor::from_vec(&[2, 2], vec![10, 20, 30, 40]));
        assert_eq!(st.grad_acc.data(), &[11, 22, 33, 44]);
        assert_eq!(st.count, 2);
    }

    #[test]
    fn fork_shard_copies_geometry_not_state() {
        let mut st = ParamState::new(ParamKind::Bias, &[2, 3]);
        st.accumulate(&Tensor::from_vec(&[2, 3], vec![1; 6]));
        let f = st.fork_shard();
        assert_eq!(f.kind, ParamKind::Bias);
        assert_eq!(f.grad_acc.shape(), &[2, 3]);
        assert!(f.grad_acc.data().iter().all(|&v| v == 0));
        assert!(f.momentum.data().iter().all(|&v| v == 0));
        assert_eq!(f.count, 0);
    }

    #[test]
    fn merge_shard_equals_direct_accumulation() {
        // accumulating 4 grads directly must equal accumulating them
        // into two shard forks and merging — including wrapping
        let grads: Vec<Tensor> = [i32::MAX - 3, 7, i32::MAX - 11, 23]
            .iter()
            .map(|&v| Tensor::from_vec(&[2], vec![v, -v]))
            .collect();
        let mut direct = ParamState::new(ParamKind::Weight, &[2]);
        for g in &grads {
            direct.accumulate(g);
        }
        let mut merged = ParamState::new(ParamKind::Weight, &[2]);
        let mut s0 = merged.fork_shard();
        let mut s1 = merged.fork_shard();
        s0.accumulate(&grads[0]);
        s0.accumulate(&grads[1]);
        s1.accumulate(&grads[2]);
        s1.accumulate(&grads[3]);
        merged.merge_shard(&s0);
        merged.merge_shard(&s1);
        assert_eq!(merged.grad_acc, direct.grad_acc);
        assert_eq!(merged.count, direct.count);
    }

    #[test]
    fn merge_shard_is_order_independent() {
        let g0 = Tensor::from_vec(&[1], vec![i32::MAX]);
        let g1 = Tensor::from_vec(&[1], vec![12345]);
        let mut a = ParamState::new(ParamKind::Weight, &[1]);
        let mut b = ParamState::new(ParamKind::Weight, &[1]);
        let mut s0 = a.fork_shard();
        let mut s1 = a.fork_shard();
        s0.accumulate(&g0);
        s1.accumulate(&g1);
        a.merge_shard(&s0);
        a.merge_shard(&s1);
        b.merge_shard(&s1);
        b.merge_shard(&s0);
        assert_eq!(a.grad_acc, b.grad_acc);
        assert_eq!(a.count, b.count);
    }

    #[test]
    fn apply_steps_against_gradient() {
        let mut st = ParamState::new(ParamKind::Weight, &[1]);
        let mut w = Tensor::from_vec(&[1], vec![quantize(0.5, FW)]);
        // constant positive gradient of 1.0 at FWG for a batch of 1
        st.accumulate(&Tensor::from_vec(&[1], vec![1 << FWG]));
        st.apply(&mut w, &hy(1));
        let w1 = dequantize(w.data()[0], FW);
        // one step of lr 0.002 against gradient +1 -> ~0.498
        assert!((w1 - 0.498).abs() < 1e-3, "w1 = {w1}");
        assert_eq!(st.count, 0);
        assert!(st.grad_acc.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn momentum_accelerates_repeated_steps() {
        let mut st = ParamState::new(ParamKind::Weight, &[1]);
        let mut w = Tensor::from_vec(&[1], vec![0]);
        let mut deltas = Vec::new();
        let mut prev = 0i32;
        for _ in 0..5 {
            st.accumulate(&Tensor::from_vec(&[1], vec![1 << FWG]));
            st.apply(&mut w, &hy(1));
            deltas.push(prev - w.data()[0]);
            prev = w.data()[0];
        }
        // steady gradient + momentum -> step size grows
        assert!(deltas[4] > deltas[0], "deltas = {deltas:?}");
    }

    #[test]
    fn batch_average_divides() {
        let mut a = ParamState::new(ParamKind::Weight, &[1]);
        let mut b = ParamState::new(ParamKind::Weight, &[1]);
        let mut wa = Tensor::from_vec(&[1], vec![0]);
        let mut wb = Tensor::from_vec(&[1], vec![0]);
        // batch of 4 identical grads must equal a single grad at batch 1
        for _ in 0..4 {
            a.accumulate(&Tensor::from_vec(&[1], vec![1 << FWG]));
        }
        b.accumulate(&Tensor::from_vec(&[1], vec![1 << FWG]));
        a.apply(&mut wa, &hy(4));
        b.apply(&mut wb, &hy(1));
        assert_eq!(wa.data()[0], wb.data()[0]);
    }

    #[test]
    fn weight_saturates_at_i16() {
        let mut st = ParamState::new(ParamKind::Weight, &[1]);
        let mut w = Tensor::from_vec(&[1], vec![32767]);
        // huge negative gradient pushes weight up; must clamp at 32767
        st.accumulate(&Tensor::from_vec(&[1], vec![i32::MIN / 2]));
        st.apply(&mut w, &hy(1));
        assert_eq!(w.data()[0], 32767);
    }

    #[test]
    fn bias_update_aligns_fraction() {
        let mut st = ParamState::new(ParamKind::Bias, &[1]);
        let mut b = Tensor::from_vec(&[1], vec![0]);
        // gradient of 1.0 at FG
        st.accumulate(&Tensor::from_vec(&[1], vec![1 << FG]));
        st.apply(&mut b, &hy(1));
        // expect roughly -lr at FA+FW = -0.002 * 2^20 = -2097
        let got = b.data()[0];
        assert!((-2300..=-1900).contains(&got), "bias step = {got}");
    }
}
