//! f32 floating-point reference trainer — the "baseline with
//! floating-point precision" the paper compares its 16-bit fixed-point
//! training against (§IV-B).
//!
//! A line-by-line port of the golden fixed-point model (`conv`, `pool`,
//! `fc`, `loss`, `golden`) with requantization removed: same layer walk,
//! same SGD-with-momentum, IEEE f32 arithmetic.  Unit tests check that
//! its gradients agree with the dequantized fixed-point gradients on
//! small nets, which is exactly the fixed-vs-float fidelity claim.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{Layer, Loss, Network};
use crate::fixed::{dequantize, FA, FW};
use crate::nn::golden::Params;
use crate::nn::tensor::Tensor;

/// Dense f32 tensor (shape + data), minimal.
#[derive(Debug, Clone)]
pub struct FTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl FTensor {
    pub fn zeros(shape: &[usize]) -> FTensor {
        FTensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    // dequantized 16-bit values (< 2^16 with <= 16 fraction bits) are
    // exactly representable in f32.
    #[allow(clippy::cast_possible_truncation)]
    pub fn from_fixed(t: &Tensor, frac: u32) -> FTensor {
        FTensor {
            shape: t.shape().to_vec(),
            data: t
                .data()
                .iter()
                .map(|&q| dequantize(q, frac) as f32)
                .collect(),
        }
    }

    #[inline(always)]
    fn at3(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.shape[1] + y) * self.shape[2] + x]
    }
}

fn pad_hw(x: &FTensor, p: usize) -> FTensor {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let mut out = FTensor::zeros(&[c, h + 2 * p, w + 2 * p]);
    for ci in 0..c {
        for y in 0..h {
            let src = (ci * h + y) * w;
            let dst = (ci * (h + 2 * p) + y + p) * (w + 2 * p) + p;
            out.data[dst..dst + w].copy_from_slice(&x.data[src..src + w]);
        }
    }
    out
}

fn conv_fp(x: &FTensor, w: &FTensor, b: &[f32], pad: usize, relu: bool)
           -> FTensor {
    let (nof, nif, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let xp = pad_hw(x, pad);
    let (hp, wp) = (xp.shape[1], xp.shape[2]);
    let (oh, ow) = (hp - k + 1, wp - k + 1);
    let mut out = FTensor::zeros(&[nof, oh, ow]);
    for of in 0..nof {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = b[of];
                for ci in 0..nif {
                    for ky in 0..k {
                        let xrow = (ci * hp + oy + ky) * wp + ox;
                        let wrow = ((of * nif + ci) * k + ky) * k;
                        for kx in 0..k {
                            acc += w.data[wrow + kx] * xp.data[xrow + kx];
                        }
                    }
                }
                out.data[(of * oh + oy) * ow + ox] =
                    if relu { acc.max(0.0) } else { acc };
            }
        }
    }
    out
}

fn transpose_flip(w: &FTensor) -> FTensor {
    let (nof, nif, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let mut out = FTensor::zeros(&[nif, nof, k, k]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    out.data[((ci * nof + of) * k + k - 1 - ky) * k + k
                             - 1 - kx] =
                        w.data[((of * nif + ci) * k + ky) * k + kx];
                }
            }
        }
    }
    out
}

fn conv_bp(g: &FTensor, w: &FTensor, pad: usize) -> FTensor {
    let wt = transpose_flip(w);
    let zeros = vec![0.0; wt.shape[0]];
    conv_fp(g, &wt, &zeros, pad, false)
}

fn conv_wu(x: &FTensor, g: &FTensor, pad: usize)
           -> (FTensor, Vec<f32>) {
    let k = 2 * pad + 1;
    let nif = x.shape[0];
    let (nof, oh, ow) = (g.shape[0], g.shape[1], g.shape[2]);
    let xp = pad_hw(x, pad);
    let (hp, wp) = (xp.shape[1], xp.shape[2]);
    let mut dw = FTensor::zeros(&[nof, nif, k, k]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    let mut acc = 0.0f32;
                    for y in 0..oh {
                        let grow = (of * oh + y) * ow;
                        let xrow = (ci * hp + y + ky) * wp + kx;
                        for xx in 0..ow {
                            acc += g.data[grow + xx] * xp.data[xrow + xx];
                        }
                    }
                    dw.data[((of * nif + ci) * k + ky) * k + kx] = acc;
                }
            }
        }
    }
    let db: Vec<f32> = (0..nof)
        .map(|of| g.data[of * oh * ow..(of + 1) * oh * ow].iter().sum())
        .collect();
    (dw, db)
}

fn maxpool(x: &FTensor, k: usize) -> (FTensor, Vec<usize>) {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / k, w / k);
    let mut out = FTensor::zeros(&[c, oh, ow]);
    let mut idx = vec![0usize; c * oh * ow];
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f32::MIN;
                let mut bi = 0;
                for dy in 0..k {
                    for dx in 0..k {
                        let v = x.at3(ci, oy * k + dy, ox * k + dx);
                        if v > best {
                            best = v;
                            bi = dy * k + dx;
                        }
                    }
                }
                out.data[(ci * oh + oy) * ow + ox] = best;
                idx[(ci * oh + oy) * ow + ox] = bi;
            }
        }
    }
    (out, idx)
}

fn upsample_scale(g: &FTensor, idx: &[usize], below: &FTensor, k: usize)
                  -> FTensor {
    let (c, oh, ow) = (g.shape[0], g.shape[1], g.shape[2]);
    let mut out = FTensor::zeros(&[c, oh * k, ow * k]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let gi = (ci * oh + oy) * ow + ox;
                let (dy, dx) = (idx[gi] / k, idx[gi] % k);
                let (y, x) = (oy * k + dy, ox * k + dx);
                if below.at3(ci, y, x) > 0.0 {
                    out.data[(ci * oh * k + y) * ow * k + x] = g.data[gi];
                }
            }
        }
    }
    out
}

/// Float parameters + momentum for the whole network.  BN layers carry
/// their (dequantized) running statistics too; like the fixed model,
/// the float reference treats them as constants within a batch
/// (statistics-as-constants backward).
pub struct FloatTrainer {
    net: Network,
    weights: HashMap<String, FTensor>,
    biases: HashMap<String, Vec<f32>>,
    bn_mean: HashMap<String, Vec<f32>>,
    bn_var: HashMap<String, Vec<f32>>,
    mw: HashMap<String, Vec<f32>>,
    mb: HashMap<String, Vec<f32>>,
    lr: f32,
    beta: f32,
}

impl FloatTrainer {
    /// Start from the SAME (dequantized) parameters as a fixed trainer.
    // dequantized fixed-point values and the (small) hyper-parameters
    // round to f32 within the reference model's own tolerance; this is
    // the float baseline, not the bit-exact path.
    #[allow(clippy::cast_possible_truncation)]
    pub fn from_params(net: &Network, params: &Params, lr: f64,
                       beta: f64) -> Result<FloatTrainer> {
        let mut weights = HashMap::new();
        let mut biases = HashMap::new();
        let mut bn_mean = HashMap::new();
        let mut bn_var = HashMap::new();
        let mut mw = HashMap::new();
        let mut mb = HashMap::new();
        for l in &net.layers {
            if l.weight_elems() == 0 {
                continue;
            }
            let n = l.name();
            let w = params.get(&format!("w_{n}"))?;
            let b = params.get(&format!("b_{n}"))?;
            // bn gamma lives at FW like weights; beta at FA+FW like
            // biases — the generic dequantization covers both kinds
            let wf = FTensor::from_fixed(w, FW);
            let bf: Vec<f32> = b
                .data()
                .iter()
                .map(|&q| dequantize(q, FA + FW) as f32)
                .collect();
            mw.insert(n.to_string(), vec![0.0; wf.data.len()]);
            mb.insert(n.to_string(), vec![0.0; bf.len()]);
            weights.insert(n.to_string(), wf);
            biases.insert(n.to_string(), bf);
            if let Layer::Bn { name, .. } = l {
                let rm = params.get(&format!("rm_{name}"))?;
                let rv = params.get(&format!("rv_{name}"))?;
                bn_mean.insert(
                    name.clone(),
                    rm.data()
                        .iter()
                        .map(|&q| dequantize(q, FA) as f32)
                        .collect(),
                );
                bn_var.insert(
                    name.clone(),
                    rv.data()
                        .iter()
                        .map(|&q| dequantize(q, 2 * FA) as f32)
                        .collect(),
                );
            }
        }
        Ok(FloatTrainer {
            net: net.clone(),
            weights,
            biases,
            bn_mean,
            bn_var,
            mw,
            mb,
            lr: lr as f32,
            beta: beta as f32,
        })
    }

    /// Per-channel `gamma / sqrt(var + eps)` scales of a BN layer.
    fn bn_scales(&self, name: &str) -> Vec<f32> {
        self.weights[name]
            .data
            .iter()
            .zip(&self.bn_var[name])
            .map(|(&g, &v)| g / (v.max(0.0) + 1e-5).sqrt())
            .collect()
    }

    /// Forward pass; returns (logits, cache of activations, pool indices,
    /// flattened input to fc).
    #[allow(clippy::type_complexity)]
    fn forward(&self, x: &FTensor)
               -> (Vec<f32>, HashMap<String, FTensor>,
                   HashMap<String, Vec<usize>>, Vec<f32>) {
        let mut acts = HashMap::new();
        let mut idxs = HashMap::new();
        let mut a = x.clone();
        let mut logits = Vec::new();
        let mut flat = Vec::new();
        for l in &self.net.layers {
            match l {
                Layer::Conv { name, pad, relu, .. } => {
                    a = conv_fp(&a, &self.weights[name],
                                &self.biases[name], *pad, *relu);
                    acts.insert(name.clone(), a.clone());
                }
                Layer::Bn { name, relu, .. } => {
                    let scales = self.bn_scales(name);
                    let mu = &self.bn_mean[name];
                    let beta = &self.biases[name];
                    let (c, hh, ww) =
                        (a.shape[0], a.shape[1], a.shape[2]);
                    let mut out = FTensor::zeros(&a.shape);
                    for ci in 0..c {
                        let base = ci * hh * ww;
                        for i in 0..hh * ww {
                            let mut y = (a.data[base + i] - mu[ci])
                                * scales[ci]
                                + beta[ci];
                            if *relu && y < 0.0 {
                                y = 0.0;
                            }
                            out.data[base + i] = y;
                        }
                    }
                    a = out;
                    acts.insert(name.clone(), a.clone());
                }
                Layer::Pool { name, k, .. } => {
                    let (p, idx) = maxpool(&a, *k);
                    acts.insert(name.clone(), p.clone());
                    idxs.insert(name.clone(), idx);
                    a = p;
                }
                Layer::Fc { name, cout, .. } => {
                    flat = a.data.clone();
                    let w = &self.weights[name];
                    let b = &self.biases[name];
                    let kk = flat.len();
                    logits = (0..*cout)
                        .map(|n| {
                            b[n] + (0..kk)
                                .map(|k| w.data[n * kk + k] * flat[k])
                                .sum::<f32>()
                        })
                        .collect();
                }
            }
        }
        (logits, acts, idxs, flat)
    }

    pub fn predict(&self, x: &FTensor) -> usize {
        let (logits, ..) = self.forward(x);
        let mut best = (f32::MIN, 0usize);
        for (i, &v) in logits.iter().enumerate() {
            if v > best.0 {
                best = (v, i);
            }
        }
        best.1
    }

    /// One-image loss + gradients (square hinge or euclidean).
    #[allow(clippy::type_complexity)]
    fn grads(&self, x: &FTensor, label: usize)
             -> (f32, HashMap<String, FTensor>, HashMap<String, Vec<f32>>) {
        let (logits, acts, idxs, flat) = self.forward(x);
        let n_out = logits.len();
        let mut g = vec![0.0f32; n_out];
        let mut loss = 0.0f32;
        match self.net.loss {
            Loss::SquareHinge => {
                for (n, gv) in g.iter_mut().enumerate() {
                    let y = if n == label { 1.0 } else { -1.0 };
                    let margin = (1.0 - y * logits[n]).max(0.0);
                    loss += margin * margin;
                    *gv = -2.0 * y * margin;
                }
            }
            Loss::Euclidean => {
                for (n, gv) in g.iter_mut().enumerate() {
                    let y = if n == label { 1.0 } else { -1.0 };
                    let d = logits[n] - y;
                    loss += 0.5 * d * d;
                    *gv = d;
                }
            }
        }
        let mut dws: HashMap<String, FTensor> = HashMap::new();
        let mut dbs: HashMap<String, Vec<f32>> = HashMap::new();

        // fc
        let fc_name = self.net.layers.last().unwrap().name().to_string();
        let kk = flat.len();
        let mut dw_fc = FTensor::zeros(&[n_out, kk]);
        for n in 0..n_out {
            for k in 0..kk {
                dw_fc.data[n * kk + k] = g[n] * flat[k];
            }
        }
        dws.insert(format!("{fc_name}"), dw_fc);
        dbs.insert(fc_name.clone(), g.clone());
        let w_fc = &self.weights[&fc_name];
        let g_flat: Vec<f32> = (0..kk)
            .map(|k| {
                (0..n_out).map(|n| g[n] * w_fc.data[n * kk + k]).sum()
            })
            .collect();

        // reverse feature-map walk (same structure as golden::backward)
        let rev: Vec<&Layer> = self
            .net
            .layers
            .iter()
            .filter(|l| !matches!(l, Layer::Fc { .. }))
            .rev()
            .collect();
        let &last = rev.first().expect("a feature-map layer before fc");
        let geom = crate::ops::for_layer(last).out_geom(last);
        let mut grad = FTensor {
            shape: vec![geom.c, geom.h, geom.w],
            data: g_flat,
        };
        // consumer-applies-the-mask convention, mirroring golden: a
        // layer's fused ReLU is applied by whoever propagates into it
        let mask_below = |grad: &mut FTensor, b: &Layer| {
            if b.fused_relu() {
                let ba = &acts[b.name()];
                for (gv, &av) in grad.data.iter_mut().zip(&ba.data) {
                    if av <= 0.0 {
                        *gv = 0.0;
                    }
                }
            }
        };
        // fc consumes `last`'s output: apply its fused-ReLU mask (if
        // any) before walking down, mirroring golden::backward
        mask_below(&mut grad, last);
        for (i, l) in rev.iter().enumerate() {
            match l {
                Layer::Pool { name, k, .. } => {
                    // upsample_scale masks on mask_src > 0: feed the
                    // below layer's activations only when it fuses a
                    // ReLU, all-ones otherwise (golden's fused_mask
                    // rule; ones also covers pool-on-input)
                    let ones;
                    let mask_src: &FTensor = match rev.get(i + 1) {
                        Some(&b) if b.fused_relu() => &acts[b.name()],
                        Some(&b) => {
                            let ba = &acts[b.name()];
                            ones = FTensor {
                                shape: ba.shape.clone(),
                                data: vec![1.0; ba.data.len()],
                            };
                            &ones
                        }
                        None => {
                            ones = FTensor {
                                shape: x.shape.clone(),
                                data: vec![1.0; x.data.len()],
                            };
                            &ones
                        }
                    };
                    grad = upsample_scale(&grad, &idxs[name],
                                          mask_src, *k);
                }
                Layer::Bn { name, .. } => {
                    let below = rev.get(i + 1);
                    let x_in: &FTensor = match below {
                        None => x,
                        Some(b) => &acts[b.name()],
                    };
                    let scales = self.bn_scales(name);
                    let mu = &self.bn_mean[name];
                    let var = &self.bn_var[name];
                    let c = grad.shape[0];
                    let hw = grad.shape[1] * grad.shape[2];
                    let mut dgamma = FTensor::zeros(&[c]);
                    let mut db = vec![0.0f32; c];
                    for ci in 0..c {
                        let inv =
                            1.0 / (var[ci].max(0.0) + 1e-5).sqrt();
                        let base = ci * hw;
                        let mut dg = 0.0f32;
                        for i in 0..hw {
                            let gv = grad.data[base + i];
                            let xhat =
                                (x_in.data[base + i] - mu[ci]) * inv;
                            dg += gv * xhat;
                            db[ci] += gv;
                            grad.data[base + i] = gv * scales[ci];
                        }
                        dgamma.data[ci] = dg;
                    }
                    dws.insert(name.clone(), dgamma);
                    dbs.insert(name.clone(), db);
                    if let Some(&b) = below {
                        mask_below(&mut grad, b);
                    }
                }
                Layer::Conv { name, pad, .. } => {
                    let below = rev.get(i + 1);
                    let x_in: &FTensor = match below {
                        None => x,
                        Some(b) => &acts[b.name()],
                    };
                    let (dw, db) = conv_wu(x_in, &grad, *pad);
                    dws.insert(name.clone(), dw);
                    dbs.insert(name.clone(), db);
                    if let Some(&b) = below {
                        grad = conv_bp(&grad, &self.weights[name], *pad);
                        mask_below(&mut grad, b);
                    }
                }
                Layer::Fc { .. } => unreachable!(),
            }
        }
        (loss, dws, dbs)
    }

    /// Train one batch (accumulate, average, momentum step); mean loss.
    pub fn train_batch(&mut self, batch: &[(FTensor, usize)]) -> f32 {
        let bs = batch.len() as f32;
        let mut acc_w: HashMap<String, Vec<f32>> = HashMap::new();
        let mut acc_b: HashMap<String, Vec<f32>> = HashMap::new();
        let mut loss_sum = 0.0;
        for (x, label) in batch {
            let (loss, dws, dbs) = self.grads(x, *label);
            loss_sum += loss;
            for (n, dw) in dws {
                let e = acc_w
                    .entry(n)
                    .or_insert_with(|| vec![0.0; dw.data.len()]);
                for (a, v) in e.iter_mut().zip(&dw.data) {
                    *a += v;
                }
            }
            for (n, db) in dbs {
                let e = acc_b
                    .entry(n)
                    .or_insert_with(|| vec![0.0; db.len()]);
                for (a, v) in e.iter_mut().zip(&db) {
                    *a += v;
                }
            }
        }
        let names: Vec<String> = self.weights.keys().cloned().collect();
        for n in names {
            let gw = &acc_w[&n];
            let mw = self.mw.get_mut(&n).unwrap();
            let w = self.weights.get_mut(&n).unwrap();
            for j in 0..w.data.len() {
                mw[j] = self.beta * mw[j] - self.lr * gw[j] / bs;
                w.data[j] += mw[j];
            }
            let gb = &acc_b[&n];
            let mb = self.mb.get_mut(&n).unwrap();
            let b = self.biases.get_mut(&n).unwrap();
            for j in 0..b.len() {
                mb[j] = self.beta * mb[j] - self.lr * gb[j] / bs;
                b[j] += mb[j];
            }
        }
        loss_sum / bs
    }
}

/// Convert a fixed-point image (at FA) to the float domain.
pub fn image_f32(x: &Tensor) -> FTensor {
    FTensor::from_fixed(x, FA)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::fixed::{FG, FWG};
    use crate::nn::golden;
    use crate::nn::init::init_params;
    use crate::nn::loss::encode_label;
    use crate::nn::testutil::{randi, Lcg};

    fn tiny_net() -> Network {
        Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 \
             relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    #[test]
    fn float_gradients_track_fixed_gradients() {
        // the fixed-vs-float fidelity claim, at gradient granularity:
        // dequantized fixed grads must correlate strongly with f32 grads
        let net = tiny_net();
        let params = init_params(&net, 3);
        let ft = FloatTrainer::from_params(&net, &params, 0.01, 0.9)
            .unwrap();
        let mut rng = Lcg::new(8);
        let x = randi(&mut rng, &[3, 8, 8], 200);
        let y = encode_label(2, 10);
        let (_, _, fixed_grads) =
            golden::train_step(&net, &params, &x, &y).unwrap();
        let (_, dws, _) = ft.grads(&image_f32(&x), 2);
        for lname in ["c1", "c2", "fc"] {
            let fg = &fixed_grads[&format!("w_{lname}")];
            let fl = &dws[lname];
            // cosine similarity between dequantized fixed and float
            let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
            for (&q, &f) in fg.data().iter().zip(&fl.data) {
                let a = dequantize(q, FWG);
                let b = f as f64;
                dot += a * b;
                na += a * a;
                nb += b * b;
            }
            let cos = dot / (na.sqrt() * nb.sqrt() + 1e-12);
            assert!(cos > 0.99, "{lname}: cos = {cos}");
            let _ = FG;
        }
    }

    #[test]
    fn float_gradients_track_fixed_through_bn() {
        // the fidelity claim must survive a BN layer in the chain: at
        // init the integer BN is near-identity (gamma 1, var 1), so the
        // dequantized fixed conv gradients must still track the float
        // reference closely
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1\nbn n1 relu\nconv c2 4 k3 \
             s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let params = init_params(&net, 3);
        let ft = FloatTrainer::from_params(&net, &params, 0.01, 0.9)
            .unwrap();
        let mut rng = Lcg::new(8);
        let x = randi(&mut rng, &[3, 8, 8], 200);
        let y = encode_label(2, 10);
        let (_, _, fixed_grads) =
            golden::train_step(&net, &params, &x, &y).unwrap();
        let (_, dws, dbs) = ft.grads(&image_f32(&x), 2);
        for lname in ["c1", "c2", "fc"] {
            let fg = &fixed_grads[&format!("w_{lname}")];
            let fl = &dws[lname];
            let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
            for (&q, &f) in fg.data().iter().zip(&fl.data) {
                let a = dequantize(q, FWG);
                let b = f as f64;
                dot += a * b;
                na += a * a;
                nb += b * b;
            }
            let cos = dot / (na.sqrt() * nb.sqrt() + 1e-12);
            assert!(cos > 0.9, "{lname}: cos = {cos}");
        }
        // beta gradients are plain sums of the masked local gradient:
        // dequantized fixed dbeta must track the float one per channel
        let fb = &fixed_grads["b_n1"];
        let flb = &dbs["n1"];
        for (&q, &f) in fb.data().iter().zip(flb) {
            let a = dequantize(q, FG);
            let d = (a - f64::from(f)).abs();
            assert!(d <= 0.1 * f64::from(f).abs() + 0.5,
                    "dbeta {a} vs {f}");
        }
    }

    #[test]
    fn float_training_reduces_loss() {
        let net = tiny_net();
        let params = init_params(&net, 5);
        let mut ft = FloatTrainer::from_params(&net, &params, 0.01, 0.9)
            .unwrap();
        let mut rng = Lcg::new(9);
        let batch: Vec<(FTensor, usize)> = (0..4)
            .map(|i| {
                (image_f32(&randi(&mut rng, &[3, 8, 8], 200)), i % 10)
            })
            .collect();
        let first = ft.train_batch(&batch);
        let mut last = first;
        for _ in 0..5 {
            last = ft.train_batch(&batch);
        }
        assert!(last < first, "loss {first} -> {last}");
        assert!(last.is_finite());
    }

    #[test]
    fn predict_is_nan_safe() {
        let net = tiny_net();
        let params = init_params(&net, 1);
        let ft = FloatTrainer::from_params(&net, &params, 0.01, 0.9)
            .unwrap();
        let x = FTensor::zeros(&[3, 8, 8]);
        let p = ft.predict(&x);
        assert!(p < 10);
    }
}
