//! Deterministic pseudo-random helpers shared by unit tests and the
//! synthetic dataset generator (no external RNG crates are available in
//! the offline build, so we carry a small LCG + Box–Muller-free normal).

use crate::nn::tensor::Tensor;

/// 64-bit LCG (Knuth MMIX constants) with helpers for the value ranges the
/// fixed-point stack uses.
#[derive(Debug, Clone)]
pub struct Lcg {
    state: u64,
}

impl Lcg {
    pub fn new(seed: u64) -> Lcg {
        // avoid the all-zeros fixed point and decorrelate tiny seeds
        Lcg { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        // xor-fold the high bits down; raw LCG low bits are weak
        self.state ^ (self.state >> 33)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in [-amp, amp].
    // 2*amp+1 is positive for any sane amplitude, and the sampled
    // value is < 2*amp+1, so both casts preserve the value.
    #[inline]
    #[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
    pub fn int_pm(&mut self, amp: i32) -> i32 {
        (self.below((2 * amp + 1) as u64) as i32) - amp
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Approximate standard normal: Irwin–Hall sum of 12 uniforms - 6.
    #[inline]
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.unit()).sum::<f64>() - 6.0
    }
}

/// Random tensor with entries uniform in [-amp, amp].
pub fn randi(rng: &mut Lcg, shape: &[usize], amp: i32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.int_pm(amp)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Lcg::new(5);
        let mut b = Lcg::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn int_pm_in_range() {
        let mut r = Lcg::new(1);
        for _ in 0..1000 {
            let v = r.int_pm(10);
            assert!((-10..=10).contains(&v));
        }
    }

    #[test]
    fn unit_in_range_and_spread() {
        let mut r = Lcg::new(2);
        let xs: Vec<f64> = (0..1000).map(|_| r.unit()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Lcg::new(3);
        let xs: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.1, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.15, "var = {var}");
    }
}
