//! Scalar reference kernels — the bit-exactness oracle for the tiled
//! hot-path kernels in [`conv`](crate::nn::conv),
//! [`fc`](crate::nn::fc) and [`pool`](crate::nn::pool).
//!
//! These are the original per-image triple-loop kernels, kept verbatim.
//! Every optimized kernel must produce bit-identical output to its
//! function here for all shapes — `tests/kernels.rs` sweeps randomized
//! shapes, paddings and saturated inputs, and the per-kernel hotpath
//! bench measures the tiled speedup against this module.  The
//! accumulation contract both sides implement:
//!
//! - i32 **wrapping** adds, per output element in a **fixed term
//!   order** (conv FP/BP: ci → ky → kx; conv WU: y → ox per tap;
//!   fc FP: k ascending; fc BP: row ascending),
//! - round-half-up requantization at the documented shifts,
//! - zero operands may be skipped (adding 0 is the identity, so the
//!   remaining adds land on the same wrapped value).
//!
//! Do not optimize anything in this file: its value is being obviously
//! equivalent to Eqs. (1), (3), (4) of the paper.

use crate::fixed::{
    requant, shift_round, SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE,
};
use crate::nn::conv::transpose_flip;
use crate::nn::tensor::Tensor;

/// Scalar FP convolution, Eq. (1): stride 1, square kernel, zero
/// padding.  Signature and semantics identical to
/// [`conv::conv_fp`](crate::nn::conv::conv_fp).
pub fn conv_fp(x: &Tensor, w: &Tensor, b: &[i32], pad: usize, relu: bool,
               shift: u32) -> Tensor {
    let (nof, nif, k) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    assert_eq!(x.shape()[0], nif, "input channel mismatch");
    assert_eq!(b.len(), nof);
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let (oh, ow) = (hp - k + 1, wp - k + 1);
    let mut out = Tensor::zeros(&[nof, oh, ow]);
    let xd = xp.data();
    let od = out.data_mut();
    let mut acc = vec![0i32; oh * ow];
    for of in 0..nof {
        acc.fill(b[of]);
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    let wt = w.at4(of, ci, ky, kx);
                    if wt == 0 {
                        continue;
                    }
                    for oy in 0..oh {
                        let xrow = (ci * hp + oy + ky) * wp + kx;
                        let arow = oy * ow;
                        let xs = &xd[xrow..xrow + ow];
                        let ac = &mut acc[arow..arow + ow];
                        for (a, &xv) in ac.iter_mut().zip(xs) {
                            *a = a.wrapping_add(wt.wrapping_mul(xv));
                        }
                    }
                }
            }
        }
        let orow = of * oh * ow;
        for (o, &a) in od[orow..orow + oh * ow].iter_mut().zip(&acc) {
            let mut v = requant(a, shift);
            if relu && v < 0 {
                v = 0;
            }
            *o = v;
        }
    }
    out
}

/// Scalar FP conv with the standard activation requantization.
pub fn conv_fp_std(x: &Tensor, w: &Tensor, b: &[i32], relu: bool)
                   -> Tensor {
    conv_fp(x, w, b, (w.shape()[2] - 1) / 2, relu, SHIFT_CONV_FP)
}

/// Scalar BP convolution, Eq. (3).
pub fn conv_bp(g: &Tensor, w: &Tensor, pad: usize) -> Tensor {
    let wt = transpose_flip(w);
    let zeros = vec![0i32; wt.shape()[0]];
    conv_fp(g, &wt, &zeros, pad, false, SHIFT_CONV_BP)
}

/// Scalar WU convolution, Eq. (4): one row-dot pass per (of, ci, ky,
/// kx) tap.
pub fn conv_wu(x: &Tensor, g: &Tensor, pad: usize) -> (Tensor, Vec<i32>) {
    let k = 2 * pad + 1;
    let nif = x.shape()[0];
    let (nof, oh, ow) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    let xp = x.pad_hw(pad);
    let (hp, wp) = (xp.shape()[1], xp.shape()[2]);
    let xd = xp.data();
    let gd = g.data();
    let mut dw = Tensor::zeros(&[nof, nif, k, k]);
    for of in 0..nof {
        for ci in 0..nif {
            for ky in 0..k {
                for kx in 0..k {
                    let mut acc: i32 = 0;
                    for y in 0..oh {
                        let grow = (of * oh + y) * ow;
                        let xrow = (ci * hp + y + ky) * wp + kx;
                        let gs = &gd[grow..grow + ow];
                        let xs = &xd[xrow..xrow + ow];
                        for (&gv, &xv) in gs.iter().zip(xs) {
                            acc = acc.wrapping_add(gv.wrapping_mul(xv));
                        }
                    }
                    dw.set4(of, ci, ky, kx, shift_round(acc, SHIFT_WU_STORE));
                }
            }
        }
    }
    let mut db = vec![0i32; nof];
    for of in 0..nof {
        let base = of * oh * ow;
        let mut s: i32 = 0;
        for v in &gd[base..base + oh * ow] {
            s = s.wrapping_add(*v);
        }
        db[of] = s;
    }
    (dw, db)
}

/// Scalar FC forward: per-row dot product, k ascending.
pub fn fc_fp(x: &[i32], w: &Tensor, b: &[i32]) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), n);
    let wd = w.data();
    (0..n)
        .map(|row| {
            let mut acc = 0i32;
            let wrow = &wd[row * k..(row + 1) * k];
            for (xi, wi) in x.iter().zip(wrow) {
                acc = acc.wrapping_add(xi.wrapping_mul(*wi));
            }
            requant(acc.wrapping_add(b[row]), SHIFT_CONV_FP)
        })
        .collect()
}

/// Scalar FC backward: rows accumulate in ascending order.
pub fn fc_bp(g: &[i32], w: &Tensor) -> Vec<i32> {
    let (n, k) = (w.shape()[0], w.shape()[1]);
    assert_eq!(g.len(), n);
    let wd = w.data();
    let mut out = vec![0i32; k];
    for (row, &gv) in g.iter().enumerate() {
        let wrow = &wd[row * k..(row + 1) * k];
        for (o, wi) in out.iter_mut().zip(wrow) {
            *o = o.wrapping_add(gv.wrapping_mul(*wi));
        }
    }
    out.iter().map(|&v| requant(v, SHIFT_CONV_BP)).collect()
}

/// Scalar FC weight update: outer(g, x) plus bias gradients.
pub fn fc_wu(g: &[i32], x: &[i32]) -> (Tensor, Vec<i32>) {
    let (n, k) = (g.len(), x.len());
    let mut dw = Tensor::zeros(&[n, k]);
    let dd = dw.data_mut();
    for (row, &gv) in g.iter().enumerate() {
        for (col, &xv) in x.iter().enumerate() {
            dd[row * k + col] =
                shift_round(gv.wrapping_mul(xv), SHIFT_WU_STORE);
        }
    }
    (dw, g.to_vec())
}

/// Scalar k x k max pooling: per-window dy → dx scan, strict `>` so
/// ties pick the first maximum.
// the window-local index is < k*k (k is 2 or 3), far inside i32.
#[allow(clippy::cast_possible_truncation)]
pub fn maxpool(x: &Tensor, k: usize) -> (Tensor, Tensor) {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    let mut idx = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = i32::MIN;
                let mut best_i = 0i32;
                for dy in 0..k {
                    for dx in 0..k {
                        let v = x.at3(ci, oy * k + dy, ox * k + dx);
                        if v > best {
                            best = v;
                            best_i = (dy * k + dx) as i32;
                        }
                    }
                }
                out.set3(ci, oy, ox, best);
                idx.set3(ci, oy, ox, best_i);
            }
        }
    }
    (out, idx)
}

/// Scalar gradient upsampling through the stored pool indices.
// stored argmax indices are in [0, k*k) by construction in `maxpool`.
#[allow(clippy::cast_sign_loss)]
pub fn upsample_scale(g: &Tensor, idx: &Tensor, mask: &Tensor, k: usize)
                      -> Tensor {
    let (c, oh, ow) = (g.shape()[0], g.shape()[1], g.shape()[2]);
    assert_eq!(mask.shape(), &[c, oh * k, ow * k]);
    let mut out = Tensor::zeros(&[c, oh * k, ow * k]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let i = idx.at3(ci, oy, ox) as usize;
                let (dy, dx) = (i / k, i % k);
                let (y, x) = (oy * k + dy, ox * k + dx);
                let v = crate::fixed::sat16(
                    g.at3(ci, oy, ox).wrapping_mul(mask.at3(ci, y, x)),
                );
                out.set3(ci, y, x, v);
            }
        }
    }
    out
}
