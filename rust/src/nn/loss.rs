//! Loss units (§III-B): square hinge (default) and euclidean, bit-exact
//! with `ref.py`'s `loss_grad_*_ref`.

use crate::config::Loss;
use crate::fixed::{sat16, shift_round, FA, FG};

/// Square hinge loss and gradient.  `a`: logits at FA; `y`: ±1 * 2^FA.
/// Returns (gradient at FG, loss at FA).
pub fn loss_grad_hinge(a: &[i32], y: &[i32]) -> (Vec<i32>, i32) {
    let one = 1i32 << FA;
    let mut loss = 0i32;
    let g = a
        .iter()
        .zip(y)
        .map(|(&av, &yv)| {
            let ya = shift_round(av.wrapping_mul(yv), FA);
            let margin = (one - ya).max(0);
            loss = loss
                .wrapping_add(shift_round(margin.wrapping_mul(margin), FA));
            let g_fa = sat16(-2 * shift_round(yv.wrapping_mul(margin), FA));
            sat16(g_fa << (FG - FA))
        })
        .collect();
    (g, loss)
}

/// Euclidean (quadratic) loss, Eq. (2).  `a`, `y` at FA.
pub fn loss_grad_euclid(a: &[i32], y: &[i32]) -> (Vec<i32>, i32) {
    let mut loss = 0i32;
    let g = a
        .iter()
        .zip(y)
        .map(|(&av, &yv)| {
            let d = sat16(av - yv);
            loss = loss.wrapping_add(shift_round(d.wrapping_mul(d), FA));
            sat16(d << (FG - FA))
        })
        .collect();
    (g, loss >> 1)
}

/// Dispatch on the configured loss unit.
pub fn loss_grad(kind: Loss, a: &[i32], y: &[i32]) -> (Vec<i32>, i32) {
    match kind {
        Loss::SquareHinge => loss_grad_hinge(a, y),
        Loss::Euclidean => loss_grad_euclid(a, y),
    }
}

/// Encode a class label as the ±1 one-hot target at FA (what the paper's
/// loss unit consumes alongside the logits).
pub fn encode_label(class: usize, nclass: usize) -> Vec<i32> {
    (0..nclass)
        .map(|i| if i == class { 1 << FA } else { -(1 << FA) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hinge_zero_when_margins_met() {
        let one = 1 << FA;
        // y*a = 2.0 > 1 -> margin 0
        let a = vec![2 * one, -2 * one];
        let y = vec![one, -one];
        let (g, loss) = loss_grad_hinge(&a, &y);
        assert_eq!(loss, 0);
        assert!(g.iter().all(|&v| v == 0));
    }

    #[test]
    fn hinge_gradient_signs() {
        let one = 1 << FA;
        let a = vec![0, 0];
        let y = vec![one, -one];
        let (g, loss) = loss_grad_hinge(&a, &y);
        assert!(loss > 0);
        assert!(g[0] < 0, "correct class pushed up");
        assert!(g[1] > 0, "wrong class pushed down");
    }

    #[test]
    fn euclid_gradient_is_difference() {
        let a = vec![300, -200];
        let y = vec![256, 0];
        let (g, loss) = loss_grad_euclid(&a, &y);
        assert_eq!(g, vec![44 << (FG - FA), -200 << (FG - FA)]);
        let t1 = (44 * 44 + (1 << (FA - 1))) >> FA;
        let t2 = (200 * 200 + (1 << (FA - 1))) >> FA;
        assert_eq!(loss, (t1 + t2) >> 1);
    }

    #[test]
    fn encode_label_one_hot_pm1() {
        let y = encode_label(2, 4);
        assert_eq!(y, vec![-256, -256, 256, -256]);
    }
}
