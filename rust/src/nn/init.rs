//! Fallback parameter initialization for networks without AOT artifacts
//! (the canonical initial parameters for the CIFAR nets come from
//! `artifacts/params_<scale>.bin`, single-sourced from python so the two
//! golden models start identical).
//!
//! He-style scaling with a deterministic LCG-driven approximate normal
//! (sum of uniforms), quantized to the FW grid.

use crate::config::{Layer, Network};
use crate::fixed::{quantize, FW};
use crate::nn::golden::Params;
use crate::nn::tensor::Tensor;
use crate::nn::testutil::Lcg;

/// Deterministic He-init of all parameters of `net` (biases zero).
pub fn init_params(net: &Network, seed: u64) -> Params {
    let mut rng = Lcg::new(seed);
    let mut params = Params::default();
    for l in &net.layers {
        let (name, fan_in, wshape): (&str, usize, Vec<usize>) = match l {
            Layer::Conv { name, cin, cout, k, .. } => {
                (name, cin * k * k, vec![*cout, *cin, *k, *k])
            }
            Layer::Fc { name, cin, cout, .. } => {
                (name, *cin, vec![*cout, *cin])
            }
            Layer::Pool { .. } => continue,
        };
        let std = (2.0 / fan_in as f64).sqrt();
        let n: usize = wshape.iter().product();
        let data: Vec<i32> = (0..n)
            .map(|_| quantize(rng.normal() * std, FW))
            .collect();
        params.insert(&format!("w_{name}"), Tensor::from_vec(&wshape, data));
        let nb = match l {
            Layer::Conv { cout, .. } | Layer::Fc { cout, .. } => *cout,
            Layer::Pool { .. } => unreachable!(),
        };
        params.insert(&format!("b_{name}"), Tensor::zeros(&[nb]));
    }
    params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    #[test]
    fn deterministic_across_calls() {
        let net = Network::cifar(1);
        let a = init_params(&net, 42);
        let b = init_params(&net, 42);
        for name in net.param_order() {
            assert_eq!(a.get(&name).unwrap(), b.get(&name).unwrap());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let net = Network::cifar(1);
        let a = init_params(&net, 1);
        let b = init_params(&net, 2);
        assert_ne!(a.get("w_c1").unwrap(), b.get("w_c1").unwrap());
    }

    #[test]
    fn weights_scale_with_fan_in() {
        let net = Network::cifar(1);
        let p = init_params(&net, 3);
        // c1 fan-in 27, c6 fan-in 576: c1 weights should be larger typically
        let m1 = p.get("w_c1").unwrap().max_abs();
        let m6 = p.get("w_c6").unwrap().max_abs();
        assert!(m1 > m6, "m1={m1} m6={m6}");
    }

    #[test]
    fn covers_param_order() {
        let net = Network::cifar(2);
        let p = init_params(&net, 4);
        for name in net.param_order() {
            assert!(p.get(&name).is_ok(), "{name}");
        }
    }
}
