//! Fallback parameter initialization for networks without AOT artifacts
//! (the canonical initial parameters for the CIFAR nets come from
//! `artifacts/params_<scale>.bin`, single-sourced from python so the two
//! golden models start identical).
//!
//! He-style scaling with a deterministic LCG-driven approximate normal
//! (sum of uniforms), quantized to the FW grid.

use crate::config::{Layer, Network};
use crate::fixed::{quantize, FA, FW};
use crate::nn::golden::Params;
use crate::nn::tensor::Tensor;
use crate::nn::testutil::Lcg;

/// Deterministic He-init of all parameters of `net` (biases zero).
/// BN layers get the standard deterministic constants — gamma 1.0,
/// beta 0, running mean 0, running variance 1.0 — and consume no LCG
/// draws, so the weight streams of the other layers are unchanged by
/// inserting BN into a topology.
pub fn init_params(net: &Network, seed: u64) -> Params {
    let mut rng = Lcg::new(seed);
    let mut params = Params::default();
    for l in &net.layers {
        let (name, fan_in, wshape): (&str, usize, Vec<usize>) = match l {
            Layer::Conv { name, cin, cout, k, .. } => {
                (name, cin * k * k, vec![*cout, *cin, *k, *k])
            }
            Layer::Fc { name, cin, cout, .. } => {
                (name, *cin, vec![*cout, *cin])
            }
            Layer::Bn { name, c, .. } => {
                // gamma 1.0 at FW, beta 0 at FA+FW
                params.insert(&format!("w_{name}"),
                              Tensor::from_vec(&[*c],
                                               vec![1 << FW; *c]));
                params.insert(&format!("b_{name}"), Tensor::zeros(&[*c]));
                // running mean 0 at FA, running variance 1.0 at 2*FA
                params.insert(&format!("rm_{name}"),
                              Tensor::zeros(&[*c]));
                params.insert(&format!("rv_{name}"),
                              Tensor::from_vec(&[*c],
                                               vec![1 << (2 * FA); *c]));
                continue;
            }
            Layer::Pool { .. } => continue,
        };
        let std = (2.0 / fan_in as f64).sqrt();
        let n: usize = wshape.iter().product();
        let data: Vec<i32> = (0..n)
            .map(|_| quantize(rng.normal() * std, FW))
            .collect();
        params.insert(&format!("w_{name}"), Tensor::from_vec(&wshape, data));
        let nb = match l {
            Layer::Conv { cout, .. } | Layer::Fc { cout, .. } => *cout,
            // pool/bn `continue`d above (bn initializes its own params)
            Layer::Pool { .. } | Layer::Bn { .. } => unreachable!(),
        };
        params.insert(&format!("b_{name}"), Tensor::zeros(&[nb]));
    }
    params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    #[test]
    fn deterministic_across_calls() {
        let net = Network::cifar(1);
        let a = init_params(&net, 42);
        let b = init_params(&net, 42);
        for name in net.param_order() {
            assert_eq!(a.get(&name).unwrap(), b.get(&name).unwrap());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let net = Network::cifar(1);
        let a = init_params(&net, 1);
        let b = init_params(&net, 2);
        assert_ne!(a.get("w_c1").unwrap(), b.get("w_c1").unwrap());
    }

    #[test]
    fn weights_scale_with_fan_in() {
        let net = Network::cifar(1);
        let p = init_params(&net, 3);
        // c1 fan-in 27, c6 fan-in 576: c1 weights should be larger typically
        let m1 = p.get("w_c1").unwrap().max_abs();
        let m6 = p.get("w_c6").unwrap().max_abs();
        assert!(m1 > m6, "m1={m1} m6={m6}");
    }

    #[test]
    fn covers_param_order() {
        let net = Network::cifar(2);
        let p = init_params(&net, 4);
        for name in net.param_order() {
            assert!(p.get(&name).is_ok(), "{name}");
        }
    }

    #[test]
    fn bn_init_is_identity_and_burns_no_rng() {
        use crate::fixed::FA;
        let net = Network::cifar_bn(1);
        let p = init_params(&net, 9);
        // params + running statistics all present
        for name in net.param_order().iter().chain(&net.state_order()) {
            assert!(p.get(name).is_ok(), "{name}");
        }
        assert!(p.get("w_n1").unwrap().data().iter()
            .all(|&v| v == 1 << FW));
        assert!(p.get("b_n1").unwrap().data().iter().all(|&v| v == 0));
        assert!(p.get("rm_n1").unwrap().data().iter().all(|&v| v == 0));
        assert!(p.get("rv_n1").unwrap().data().iter()
            .all(|&v| v == 1 << (2 * FA)));
        // bn layers consume no LCG draws: the conv weights match the
        // bn-free topology's exactly (same names, same dims, same seed)
        let plain = init_params(&Network::cifar(1), 9);
        for l in ["c1", "c3", "c6"] {
            assert_eq!(p.get(&format!("w_{l}")).unwrap(),
                       plain.get(&format!("w_{l}")).unwrap(),
                       "{l}");
        }
    }
}
