//! Golden-model neural-network substrate: bit-exact fixed-point CNN
//! training primitives (the rust mirror of the paper's PyTorch
//! fixed-point verification model), tensor/IO utilities, and the
//! SGD-with-momentum weight-update arithmetic.

#![warn(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

pub mod bn;
pub mod conv;
pub mod fc;
pub mod floatref;
pub mod golden;
pub mod init;
pub mod loss;
pub mod pool;
pub mod reference;
pub mod scratch;
pub mod sgd;
pub mod tensor;
pub mod tensorio;
pub mod testutil;

pub use golden::{backward, forward, train_step, FwdCache, Grads, Params};
pub use scratch::Scratch;
pub use tensor::Tensor;
pub use tensorio::Bundle;
