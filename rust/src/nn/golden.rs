//! Whole-network golden model: per-image FP / BP / WU over a
//! [`Network`](crate::config::Network) description, mirroring
//! `python/compile/model.py` exactly (the rust analogue of the paper's
//! PyTorch fixed-point verification model, §IV-A).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::config::{Layer, Network};
use crate::nn::conv::{conv_bp, conv_fp_std, conv_wu};
use crate::nn::fc::{fc_bp, fc_fp, fc_wu};
use crate::nn::loss::loss_grad;
use crate::nn::pool::{maxpool, relu_mask, scale_mask, upsample_scale};
use crate::nn::tensor::Tensor;
use crate::nn::tensorio::Bundle;

/// Named parameter set (weights at FW, biases at FA+FW).
#[derive(Debug, Clone, Default)]
pub struct Params {
    map: HashMap<String, Tensor>,
}

impl Params {
    pub fn from_bundle(b: &Bundle) -> Params {
        let mut map = HashMap::new();
        for (name, t) in b.iter() {
            map.insert(name.to_string(), t.clone());
        }
        Params { map }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("missing parameter `{name}`"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow!("missing parameter `{name}`"))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Everything the accelerator stores during FP for reuse in BP/WU:
/// post-ReLU activations (whence the binary activation-gradient masks)
/// and max-pool indices.
#[derive(Debug, Clone)]
pub struct FwdCache {
    pub x: Tensor,
    pub acts: HashMap<String, Tensor>,
    pub idxs: HashMap<String, Tensor>,
    pub flat: Vec<i32>,
}

/// Per-image gradients, keyed like the params (`w_*` at FWG, `b_*` at FG).
pub type Grads = HashMap<String, Tensor>;

/// FP phase for one image.
pub fn forward(net: &Network, params: &Params, x: &Tensor)
               -> Result<(Vec<i32>, FwdCache)> {
    let mut cache = FwdCache {
        x: x.clone(),
        acts: HashMap::new(),
        idxs: HashMap::new(),
        flat: Vec::new(),
    };
    let mut a = x.clone();
    let mut logits = Vec::new();
    for l in &net.layers {
        match l {
            Layer::Conv { name, relu, .. } => {
                let w = params.get(&format!("w_{name}"))?;
                let b = params.get(&format!("b_{name}"))?;
                a = conv_fp_std(&a, w, b.data(), *relu);
                cache.acts.insert(name.clone(), a.clone());
            }
            Layer::Pool { name, k, .. } => {
                let (p, idx) = maxpool(&a, *k);
                cache.acts.insert(name.clone(), p.clone());
                cache.idxs.insert(name.clone(), idx);
                a = p;
            }
            Layer::Fc { name, .. } => {
                cache.flat = a.data().to_vec();
                let w = params.get(&format!("w_{name}"))?;
                let b = params.get(&format!("b_{name}"))?;
                logits = fc_fp(&cache.flat, w, b.data());
            }
        }
    }
    Ok((logits, cache))
}

/// BP + per-image WU phases, given the loss gradient at the logits.
pub fn backward(net: &Network, params: &Params, cache: &FwdCache,
                g_out: &[i32]) -> Result<Grads> {
    let mut grads: Grads = HashMap::new();

    // FC weight update + backward
    let fc_name = net.layers.last().unwrap().name().to_string();
    let w_fc = params.get(&format!("w_{fc_name}"))?;
    let (dw_fc, db_fc) = fc_wu(g_out, &cache.flat);
    grads.insert(format!("w_{fc_name}"), dw_fc);
    grads.insert(format!("b_{fc_name}"),
                 Tensor::from_vec(&[db_fc.len()], db_fc));
    let g_flat = fc_bp(g_out, w_fc);

    // walk conv/pool layers in reverse
    let rev: Vec<&Layer> = net
        .layers
        .iter()
        .filter(|l| !matches!(l, Layer::Fc { .. }))
        .rev()
        .collect();
    let (lc, lh, lw, lk) = match rev.first() {
        Some(Layer::Pool { c, h, w, k, .. }) => (*c, *h, *w, *k),
        _ => return Err(anyhow!("expected pool before fc")),
    };
    let mut g = Tensor::from_vec(&[lc, lh / lk, lw / lk], g_flat);

    for (i, l) in rev.iter().enumerate() {
        match l {
            Layer::Pool { name, k, .. } => {
                let below = match rev.get(i + 1) {
                    Some(Layer::Conv { name, .. }) => name,
                    _ => return Err(anyhow!("pool must follow a conv")),
                };
                let mask = relu_mask(&cache.acts[below]);
                g = upsample_scale(&g, &cache.idxs[name], &mask, *k);
            }
            Layer::Conv { name, pad, .. } => {
                let below = rev.get(i + 1);
                let x_in: &Tensor = match below {
                    None => &cache.x,
                    Some(b) => &cache.acts[b.name()],
                };
                let (dw, db) = conv_wu(x_in, &g, *pad);
                grads.insert(format!("w_{name}"), dw);
                grads.insert(format!("b_{name}"),
                             Tensor::from_vec(&[db.len()], db));
                if let Some(b) = below {
                    let w = params.get(&format!("w_{name}"))?;
                    g = conv_bp(&g, w, *pad);
                    if matches!(b, Layer::Conv { .. }) {
                        let mask = relu_mask(&cache.acts[b.name()]);
                        g = scale_mask(&g, &mask);
                    }
                }
            }
            Layer::Fc { .. } => unreachable!(),
        }
    }
    Ok(grads)
}

/// One whole per-image FP + loss + BP + WU pass.
pub fn train_step(net: &Network, params: &Params, x: &Tensor, y: &[i32])
                  -> Result<(i32, Vec<i32>, Grads)> {
    let (logits, cache) = forward(net, params, x)?;
    let (g, loss) = loss_grad(net.loss, &logits, y);
    let grads = backward(net, params, &cache, &g)?;
    Ok((loss, logits, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::fixed::FA;
    use crate::nn::init::init_params;
    use crate::nn::loss::encode_label;
    use crate::nn::testutil::{randi, Lcg};

    fn tiny_net() -> Network {
        Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 relu\n\
             pool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    #[test]
    fn forward_shapes() {
        let net = tiny_net();
        let params = init_params(&net, 1);
        let mut rng = Lcg::new(1);
        let x = randi(&mut rng, &[3, 8, 8], 256);
        let (logits, cache) = forward(&net, &params, &x).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(cache.acts["c1"].shape(), &[4, 8, 8]);
        assert_eq!(cache.acts["p1"].shape(), &[4, 4, 4]);
        assert_eq!(cache.flat.len(), 64);
    }

    #[test]
    fn backward_grad_shapes_match_params() {
        let net = tiny_net();
        let params = init_params(&net, 1);
        let mut rng = Lcg::new(2);
        let x = randi(&mut rng, &[3, 8, 8], 256);
        let y = encode_label(3, 10);
        let (_, _, grads) = train_step(&net, &params, &x, &y).unwrap();
        for name in net.param_order() {
            assert_eq!(
                grads[&name].shape(),
                params.get(&name).unwrap().shape(),
                "{name}"
            );
        }
    }

    #[test]
    fn cifar1x_runs_end_to_end() {
        let net = Network::cifar(1);
        let params = init_params(&net, 7);
        let mut rng = Lcg::new(3);
        let x = randi(&mut rng, &[3, 32, 32], 128);
        let y = encode_label(0, 10);
        let (loss, logits, grads) = train_step(&net, &params, &x, &y).unwrap();
        assert!(loss >= 0);
        assert_eq!(logits.len(), 10);
        assert_eq!(grads.len(), 14);
    }

    #[test]
    fn loss_decreases_under_plain_sgd() {
        // rust analogue of test_loss_decreases_under_sgd in python
        use crate::fixed::{FG, FW, FWG};
        let net = tiny_net();
        let mut params = init_params(&net, 5);
        let mut rng = Lcg::new(6);
        let x = randi(&mut rng, &[3, 8, 8], 128);
        let y = encode_label(2, 10);
        let loss_of = |p: &Params| {
            let (logits, _) = forward(&net, p, &x).unwrap();
            loss_grad(net.loss, &logits, &y).1
        };
        let l0 = loss_of(&params);
        for _ in 0..4 {
            let (_, _, grads) = train_step(&net, &params, &x, &y).unwrap();
            for name in net.param_order() {
                let g = &grads[&name];
                let sh = if name.starts_with("w_") {
                    FWG - FW + 6
                } else {
                    FG - FW + 6
                };
                let p = params.get_mut(&name).unwrap();
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv = crate::fixed::sat16(*pv - (gv >> sh));
                }
            }
        }
        assert!(loss_of(&params) <= l0, "loss did not decrease");
    }

    #[test]
    fn zero_input_gives_bias_only_logits() {
        let net = tiny_net();
        let params = init_params(&net, 9); // biases are zero
        let x = Tensor::zeros(&[3, 8, 8]);
        let (logits, _) = forward(&net, &params, &x).unwrap();
        assert!(logits.iter().all(|&v| v == 0));
        let _ = FA; // silence unused import in some cfgs
    }
}
