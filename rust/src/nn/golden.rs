//! Whole-network golden model: per-image FP / BP / WU over a
//! [`Network`](crate::config::Network) description, mirroring
//! `python/compile/model.py` exactly (the rust analogue of the paper's
//! PyTorch fixed-point verification model, §IV-A).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::config::{Layer, Network};
use crate::nn::bn;
use crate::nn::conv::{conv_bp_s, conv_fp_std_s, conv_wu_s};
use crate::nn::fc::{fc_bp, fc_fp, fc_wu};
use crate::nn::loss::loss_grad;
use crate::nn::pool::{maxpool, relu_mask, scale_mask, upsample_scale};
use crate::nn::scratch::Scratch;
use crate::nn::tensor::Tensor;
use crate::nn::tensorio::Bundle;

/// Named parameter set (weights at FW, biases at FA+FW).
#[derive(Debug, Clone, Default)]
pub struct Params {
    map: HashMap<String, Tensor>,
}

impl Params {
    pub fn from_bundle(b: &Bundle) -> Params {
        let mut map = HashMap::new();
        for (name, t) in b.iter() {
            map.insert(name.to_string(), t.clone());
        }
        Params { map }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("missing parameter `{name}`"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow!("missing parameter `{name}`"))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Everything the accelerator stores during FP for reuse in BP/WU:
/// post-ReLU activations (whence the binary activation-gradient masks),
/// max-pool indices, and per-image BN input statistics (channel mean at
/// FA, channel second moment at 2*FA — what the BnFp step streams to
/// the DRAM statistic accumulators).
#[derive(Debug, Clone)]
pub struct FwdCache {
    pub x: Tensor,
    pub acts: HashMap<String, Tensor>,
    pub idxs: HashMap<String, Tensor>,
    pub bn_stats: HashMap<String, (Tensor, Tensor)>,
    pub flat: Vec<i32>,
}

/// Per-image gradients, keyed like the params (`w_*` at FWG, `b_*` at FG).
pub type Grads = HashMap<String, Tensor>;

/// FP phase for one image (transient workspace; prefer [`forward_s`]
/// in a loop).
pub fn forward(net: &Network, params: &Params, x: &Tensor)
               -> Result<(Vec<i32>, FwdCache)> {
    let mut sc = Scratch::new();
    forward_s(net, params, x, &mut sc)
}

/// FP phase for one image against a reusable per-shard workspace.
pub fn forward_s(net: &Network, params: &Params, x: &Tensor,
                 sc: &mut Scratch) -> Result<(Vec<i32>, FwdCache)> {
    let mut cache = FwdCache {
        x: x.clone(),
        acts: HashMap::new(),
        idxs: HashMap::new(),
        bn_stats: HashMap::new(),
        flat: Vec::new(),
    };
    let mut a = x.clone();
    let mut logits = Vec::new();
    for l in &net.layers {
        match l {
            Layer::Conv { name, relu, .. } => {
                let w = params.get(&format!("w_{name}"))?;
                let b = params.get(&format!("b_{name}"))?;
                a = conv_fp_std_s(&a, w, b.data(), *relu, sc);
                cache.acts.insert(name.clone(), a.clone());
            }
            Layer::Bn { name, relu, .. } => {
                // normalize against the running statistics, frozen for
                // the whole batch (the statistic refresh happens at
                // batch end — that is what keeps sharded batches
                // bit-identical); record this image's input statistics
                // for the batch-end EMA
                let gamma = params.get(&format!("w_{name}"))?;
                let beta = params.get(&format!("b_{name}"))?;
                let rm = params.get(&format!("rm_{name}"))?;
                let rv = params.get(&format!("rv_{name}"))?;
                cache
                    .bn_stats
                    .insert(name.clone(), bn::image_stats(&a));
                a = bn::forward_affine(&a, gamma, beta, rm, rv, *relu);
                cache.acts.insert(name.clone(), a.clone());
            }
            Layer::Pool { name, k, .. } => {
                let (p, idx) = maxpool(&a, *k);
                cache.acts.insert(name.clone(), p.clone());
                cache.idxs.insert(name.clone(), idx);
                a = p;
            }
            Layer::Fc { name, .. } => {
                cache.flat = a.data().to_vec();
                let w = params.get(&format!("w_{name}"))?;
                let b = params.get(&format!("b_{name}"))?;
                logits = fc_fp(&cache.flat, w, b.data());
            }
        }
    }
    Ok((logits, cache))
}

/// BP + per-image WU phases, given the loss gradient at the logits
/// (transient workspace; prefer [`backward_s`] in a loop).
pub fn backward(net: &Network, params: &Params, cache: &FwdCache,
                g_out: &[i32]) -> Result<Grads> {
    let mut sc = Scratch::new();
    backward_s(net, params, cache, g_out, &mut sc)
}

/// BP + per-image WU phases against a reusable per-shard workspace.
/// The workspace caches each conv layer's flipped BP kernels (keyed by
/// layer name) for the rest of the batch; the coordinator invalidates
/// it whenever parameters change.
pub fn backward_s(net: &Network, params: &Params, cache: &FwdCache,
                  g_out: &[i32], sc: &mut Scratch) -> Result<Grads> {
    let mut grads: Grads = HashMap::new();

    // FC weight update + backward
    let fc_name = net.layers.last().unwrap().name().to_string();
    let w_fc = params.get(&format!("w_{fc_name}"))?;
    let (dw_fc, db_fc) = fc_wu(g_out, &cache.flat);
    grads.insert(format!("w_{fc_name}"), dw_fc);
    grads.insert(format!("b_{fc_name}"),
                 Tensor::from_vec(&[db_fc.len()], db_fc));
    let g_flat = fc_bp(g_out, w_fc);

    // walk the feature-map layers in reverse
    let rev: Vec<&Layer> = net
        .layers
        .iter()
        .filter(|l| !matches!(l, Layer::Fc { .. }))
        .rev()
        .collect();
    let &last = rev
        .first()
        .ok_or_else(|| anyhow!("expected a feature-map layer before fc"))?;
    let geom = crate::ops::for_layer(last).out_geom(last);
    let mut g = Tensor::from_vec(&[geom.c, geom.h, geom.w], g_flat);

    // The mask convention: a layer's fused ReLU is applied by its
    // *consumer* — the pool's upsampler, or the scaling unit after the
    // conv/bn above propagates its gradient.  `fused_mask` derives the
    // below layer's binary activation-gradient mask (all-ones when the
    // layer fuses no ReLU).
    let fused_mask = |b: &Layer| -> Result<Tensor> {
        let act = cache
            .acts
            .get(b.name())
            .ok_or_else(|| anyhow!("no cached acts for {}", b.name()))?;
        if b.fused_relu() {
            Ok(relu_mask(act))
        } else {
            Ok(Tensor::from_vec(act.shape(), vec![1; act.len()]))
        }
    };

    // the fc layer consumes `last`'s output: if that layer fuses a
    // ReLU (e.g. a conv-relu or bn-relu directly before fc, with no
    // pool in between), fc applies its mask here — same convention
    if last.fused_relu() {
        g = scale_mask(&g, &fused_mask(last)?);
    }

    for (i, l) in rev.iter().enumerate() {
        match l {
            Layer::Pool { name, k, .. } => {
                let mask = match rev.get(i + 1) {
                    Some(&b) => fused_mask(b)?,
                    None => {
                        let n = cache.x.len();
                        Tensor::from_vec(cache.x.shape(), vec![1; n])
                    }
                };
                g = upsample_scale(&g, &cache.idxs[name], &mask, *k);
            }
            Layer::Bn { name, .. } => {
                // the consumer above already applied this layer's own
                // fused-ReLU mask, so `g` is dL/d(pre-ReLU bn output)
                let below = rev.get(i + 1);
                let x_in: &Tensor = match below {
                    None => &cache.x,
                    Some(b) => &cache.acts[b.name()],
                };
                let gamma = params.get(&format!("w_{name}"))?;
                let rm = params.get(&format!("rm_{name}"))?;
                let rv = params.get(&format!("rv_{name}"))?;
                let (dgamma, dbeta) =
                    bn::backward_params(&g, x_in, rm, rv);
                grads.insert(format!("w_{name}"), dgamma);
                grads.insert(format!("b_{name}"),
                             Tensor::from_vec(&[dbeta.len()], dbeta));
                g = bn::backward_input(&g, gamma, rv);
                if let Some(&b) = below {
                    if b.fused_relu() {
                        g = scale_mask(&g, &fused_mask(b)?);
                    }
                }
            }
            Layer::Conv { name, pad, .. } => {
                let below = rev.get(i + 1);
                let x_in: &Tensor = match below {
                    None => &cache.x,
                    Some(b) => &cache.acts[b.name()],
                };
                let (dw, db) = conv_wu_s(x_in, &g, *pad, sc);
                grads.insert(format!("w_{name}"), dw);
                grads.insert(format!("b_{name}"),
                             Tensor::from_vec(&[db.len()], db));
                if let Some(&b) = below {
                    let w = params.get(&format!("w_{name}"))?;
                    g = conv_bp_s(&g, w, name, *pad, sc);
                    if b.fused_relu() {
                        g = scale_mask(&g, &fused_mask(b)?);
                    }
                }
            }
            Layer::Fc { .. } => unreachable!(),
        }
    }
    Ok(grads)
}

/// One whole per-image FP + loss + BP + WU pass.  Besides the `w_*` /
/// `b_*` parameter gradients, the returned map carries the per-image BN
/// statistic contributions (`sm_*` channel means, `sq_*` channel second
/// moments) — they accumulate across the batch exactly like gradients
/// and fold into the running statistics at batch end.
pub fn train_step(net: &Network, params: &Params, x: &Tensor, y: &[i32])
                  -> Result<(i32, Vec<i32>, Grads)> {
    let mut sc = Scratch::new();
    train_step_s(net, params, x, y, &mut sc)
}

/// [`train_step`] against a reusable per-shard workspace.
pub fn train_step_s(net: &Network, params: &Params, x: &Tensor,
                    y: &[i32], sc: &mut Scratch)
                    -> Result<(i32, Vec<i32>, Grads)> {
    let (logits, cache) = forward_s(net, params, x, sc)?;
    let (g, loss) = loss_grad(net.loss, &logits, y);
    let mut grads = backward_s(net, params, &cache, &g, sc)?;
    for (name, (sm, sq)) in cache.bn_stats {
        grads.insert(format!("sm_{name}"), sm);
        grads.insert(format!("sq_{name}"), sq);
    }
    Ok((loss, logits, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::fixed::FA;
    use crate::nn::init::init_params;
    use crate::nn::loss::encode_label;
    use crate::nn::testutil::{randi, Lcg};

    fn tiny_net() -> Network {
        Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 relu\n\
             pool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    #[test]
    fn forward_shapes() {
        let net = tiny_net();
        let params = init_params(&net, 1);
        let mut rng = Lcg::new(1);
        let x = randi(&mut rng, &[3, 8, 8], 256);
        let (logits, cache) = forward(&net, &params, &x).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(cache.acts["c1"].shape(), &[4, 8, 8]);
        assert_eq!(cache.acts["p1"].shape(), &[4, 4, 4]);
        assert_eq!(cache.flat.len(), 64);
    }

    #[test]
    fn backward_grad_shapes_match_params() {
        let net = tiny_net();
        let params = init_params(&net, 1);
        let mut rng = Lcg::new(2);
        let x = randi(&mut rng, &[3, 8, 8], 256);
        let y = encode_label(3, 10);
        let (_, _, grads) = train_step(&net, &params, &x, &y).unwrap();
        for name in net.param_order() {
            assert_eq!(
                grads[&name].shape(),
                params.get(&name).unwrap().shape(),
                "{name}"
            );
        }
    }

    #[test]
    fn cifar1x_runs_end_to_end() {
        let net = Network::cifar(1);
        let params = init_params(&net, 7);
        let mut rng = Lcg::new(3);
        let x = randi(&mut rng, &[3, 32, 32], 128);
        let y = encode_label(0, 10);
        let (loss, logits, grads) = train_step(&net, &params, &x, &y).unwrap();
        assert!(loss >= 0);
        assert_eq!(logits.len(), 10);
        assert_eq!(grads.len(), 14);
    }

    #[test]
    fn loss_decreases_under_plain_sgd() {
        // rust analogue of test_loss_decreases_under_sgd in python
        use crate::fixed::{FG, FW, FWG};
        let net = tiny_net();
        let mut params = init_params(&net, 5);
        let mut rng = Lcg::new(6);
        let x = randi(&mut rng, &[3, 8, 8], 128);
        let y = encode_label(2, 10);
        let loss_of = |p: &Params| {
            let (logits, _) = forward(&net, p, &x).unwrap();
            loss_grad(net.loss, &logits, &y).1
        };
        let l0 = loss_of(&params);
        for _ in 0..4 {
            let (_, _, grads) = train_step(&net, &params, &x, &y).unwrap();
            for name in net.param_order() {
                let g = &grads[&name];
                let sh = if name.starts_with("w_") {
                    FWG - FW + 6
                } else {
                    FG - FW + 6
                };
                let p = params.get_mut(&name).unwrap();
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv = crate::fixed::sat16(*pv - (gv >> sh));
                }
            }
        }
        assert!(loss_of(&params) <= l0, "loss did not decrease");
    }

    fn tiny_bn_net() -> Network {
        Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1\nbn n1 relu\nconv c2 4 k3 \
             s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    #[test]
    fn bn_forward_shapes_and_stats() {
        let net = tiny_bn_net();
        let params = init_params(&net, 2);
        let mut rng = Lcg::new(4);
        let x = randi(&mut rng, &[3, 8, 8], 256);
        let (logits, cache) = forward(&net, &params, &x).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(cache.acts["n1"].shape(), &[4, 8, 8]);
        assert_eq!(cache.acts["n2"].shape(), &[4, 8, 8]);
        // the fused relu lives on the bn output, not the conv
        assert!(cache.acts["n1"].data().iter().all(|&v| v >= 0));
        // per-image statistics recorded for both bn layers
        let (sm, sq) = &cache.bn_stats["n1"];
        assert_eq!(sm.shape(), &[4]);
        assert_eq!(sq.shape(), &[4]);
        assert!(sq.data().iter().all(|&v| v >= 0));
    }

    #[test]
    fn bn_train_step_emits_param_grads_and_stats() {
        let net = tiny_bn_net();
        let params = init_params(&net, 3);
        let mut rng = Lcg::new(5);
        let x = randi(&mut rng, &[3, 8, 8], 200);
        let y = encode_label(1, 10);
        let (loss, _, grads) = train_step(&net, &params, &x, &y).unwrap();
        assert!(loss >= 0);
        // every trainable parameter has a gradient of matching shape
        for name in net.param_order() {
            assert_eq!(grads[&name].shape(),
                       params.get(&name).unwrap().shape(),
                       "{name}");
        }
        // and every bn layer contributed its statistic tensors
        for name in net.stat_order() {
            assert_eq!(grads[&name].shape(), &[4], "{name}");
        }
    }

    #[test]
    fn bn_loss_decreases_under_plain_sgd() {
        use crate::fixed::{FG, FW, FWG};
        let net = tiny_bn_net();
        let mut params = init_params(&net, 5);
        let mut rng = Lcg::new(6);
        let x = randi(&mut rng, &[3, 8, 8], 128);
        let y = encode_label(2, 10);
        let loss_of = |p: &Params| {
            let (logits, _) = forward(&net, p, &x).unwrap();
            loss_grad(net.loss, &logits, &y).1
        };
        let l0 = loss_of(&params);
        for _ in 0..4 {
            let (_, _, grads) = train_step(&net, &params, &x, &y).unwrap();
            for name in net.param_order() {
                let g = &grads[&name];
                let sh = if name.starts_with("w_") {
                    FWG - FW + 6
                } else {
                    FG - FW + 6
                };
                let p = params.get_mut(&name).unwrap();
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv = crate::fixed::sat16(*pv - (gv >> sh));
                }
            }
        }
        assert!(loss_of(&params) <= l0, "loss did not decrease");
    }

    #[test]
    fn zero_input_gives_bias_only_logits() {
        let net = tiny_net();
        let params = init_params(&net, 9); // biases are zero
        let x = Tensor::zeros(&[3, 8, 8]);
        let (logits, _) = forward(&net, &params, &x).unwrap();
        assert!(logits.iter().all(|&v| v == 0));
        let _ = FA; // silence unused import in some cfgs
    }
}
