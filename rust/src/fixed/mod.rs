//! 16-bit fixed-point (Q-format) arithmetic — bit-exact mirror of
//! `python/compile/fixedpoint.py`.
//!
//! The paper trains with 16-bit fixed point for weights, activations and
//! local/weight gradients (§II), with dedicated resolution/range per
//! variable kind.  Values are carried in `i32` (saturated to the i16 range
//! at layer boundaries); accumulators are `i32` with wrap-around semantics,
//! matching what XLA emits for the lowered Pallas kernels, so the rust
//! golden model and the PJRT artifacts agree to the last bit.

#![warn(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

/// Fraction bits of activations (range ±128, resolution 1/256).
pub const FA: u32 = 8;
/// Fraction bits of weights and biases.
pub const FW: u32 = 12;
/// Fraction bits of local gradients.
pub const FG: u32 = 12;
/// Fraction bits of DRAM-resident accumulated weight gradients (i32).
pub const FWG: u32 = 16;
/// Fraction bits of the SGD momentum buffer (i32).
pub const FV: u32 = 16;

/// Requantization shift for FP convolutions: FA + FW -> FA.
pub const SHIFT_CONV_FP: u32 = FW;
/// Requantization shift for BP convolutions: FG + FW -> FG.
pub const SHIFT_CONV_BP: u32 = FW;
/// Requantization shift when storing weight gradients: FA + FG -> FWG.
pub const SHIFT_WU_STORE: u32 = FA + FG - FWG;

pub const I16_MIN: i32 = -32768;
pub const I16_MAX: i32 = 32767;

/// Saturate into the i16 value range (the DSP-block output register).
#[inline(always)]
pub fn sat16(x: i32) -> i32 {
    x.clamp(I16_MIN, I16_MAX)
}

/// Round-half-up arithmetic right shift WITHOUT saturation (used for the
/// i32 weight-gradient accumulators kept in DRAM).
#[inline(always)]
pub fn shift_round(acc: i32, shift: u32) -> i32 {
    if shift > 0 {
        acc.wrapping_add(1 << (shift - 1)) >> shift
    } else {
        acc
    }
}

/// Round-half-up arithmetic right shift, then saturate to the i16 range —
/// the accelerator's requantization unit after every MAC-array pass.
#[inline(always)]
pub fn requant(acc: i32, shift: u32) -> i32 {
    sat16(shift_round(acc, shift))
}

/// Float -> fixed grid at `frac` fraction bits (build-time/test helper;
/// rounds half away from zero like numpy's `round`).
#[inline]
// clamp() bounds v to [-32768.0, 32767.0] before the cast narrows.
#[allow(clippy::cast_possible_truncation)]
pub fn quantize(x: f64, frac: u32) -> i32 {
    let v = (x * f64::from(1u32 << frac)).round();
    v.clamp(f64::from(I16_MIN), f64::from(I16_MAX)) as i32
}

/// Fixed -> float (test/reporting helper).
#[inline]
pub fn dequantize(q: i32, frac: u32) -> f64 {
    f64::from(q) / f64::from(1u32 << frac)
}

/// Multiply two fixed-point scalars and requantize by `shift`.
#[inline(always)]
pub fn mul_q(a: i32, b: i32, shift: u32) -> i32 {
    requant(a.wrapping_mul(b), shift)
}

#[cfg(test)]
// Test vectors narrow deliberately (an LCG sliced to ~±2^30, clamped
// float references): the casts are the point of the tests.
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;

    #[test]
    fn fraction_bookkeeping_matches_python() {
        assert_eq!(FA + FW - SHIFT_CONV_FP, FA);
        assert_eq!(FG + FW - SHIFT_CONV_BP, FG);
        assert_eq!(FA + FG - SHIFT_WU_STORE, FWG);
    }

    #[test]
    fn sat16_clamps() {
        assert_eq!(sat16(32768), 32767);
        assert_eq!(sat16(-32769), -32768);
        assert_eq!(sat16(5), 5);
    }

    #[test]
    fn requant_rounds_half_up() {
        // floor(x / 4 + 0.5), same vectors as test_fixedpoint.py
        let xs = [2, -2, 3, -3, 6, -6];
        let want = [1, 0, 1, -1, 2, -1];
        for (x, w) in xs.iter().zip(want) {
            assert_eq!(requant(*x, 2), w, "x={x}");
        }
    }

    #[test]
    fn requant_shift_zero_saturates_only() {
        assert_eq!(requant(70000, 0), 32767);
        assert_eq!(requant(-7, 0), -7);
    }

    #[test]
    fn requant_matches_float_reference() {
        // mirror of the hypothesis property in python
        let mut v: i64 = -123456789;
        for s in 1..=16u32 {
            for _ in 0..64 {
                v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
                let x = (v >> 33) as i32; // ~±2^30
                let want =
                    ((f64::from(x) / f64::from(1u32 << s) + 0.5).floor())
                        .clamp(-32768.0, 32767.0) as i32;
                assert_eq!(requant(x, s), want, "x={x} s={s}");
            }
        }
    }

    #[test]
    fn quantize_roundtrip_on_grid() {
        for v in [0.0, 1.0, -1.0, 0.5, 127.99609375] {
            let q = quantize(v, FA);
            assert!((dequantize(q, FA) - v).abs() < 1e-12);
        }
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(quantize(1000.0, FA), 32767);
        assert_eq!(quantize(-1000.0, FA), -32768);
    }
}
