//! The training coordinator — the accelerator's global control logic
//! (Fig. 4) in rust: executes the compiled layer-by-layer schedule for
//! every image (FP -> loss -> BP/WU interleaved), accumulates weight
//! gradients across the batch, and runs the weight-update unit at batch
//! end, while accounting simulated hardware cycles from the `sim` model.
//!
//! Batches are dispatched through the batch-parallel
//! [`engine`](crate::engine): with `workers > 1` the golden backend
//! shards a batch across threads with thread-local accumulators and a
//! deterministic merge, bit-identical to the sequential path (see the
//! engine docs for the contract).  With `accelerators > 1` batches go
//! through the cluster engine instead: per-instance shards plus a
//! deterministic ring all-reduce of the gradient accumulators,
//! bit-identical to single-instance training at any cluster size.
//! [`Trainer::train_image`] remains the single-shard path and the
//! faithful per-image hardware analogue.
//!
//! Long runs go through [`Trainer::run`], the loop refactored from
//! "run to completion" to "run between checkpoints": it drives
//! epochs × batches from a [`Cursor`], snapshots crash-safe
//! checkpoints ([`crate::ckpt`]) on a cadence, and
//! [`Trainer::resume_from`] restarts a killed run bit-identically to
//! never having stopped.
//!
//! Numerics run through one of three backends:
//! - [`Backend::PerOp`] — every scheduled op executes its own AOT
//!   artifact on the PJRT runtime (the accelerator's layer-by-layer
//!   dataflow, DRAM round-trip per key layer and all);
//! - [`Backend::Fused`] — one whole-image fused artifact per step (the
//!   ablation fast path; numerically identical by construction);
//! - [`Backend::Golden`] — the pure-rust golden model (bit-identical to
//!   the artifacts; used for networks without artifacts, e.g. 2X/4X).

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::{Checkpoint, Cursor};
use crate::compiler::{choose_collective_bucketed, Accelerator, OpKind,
                      RtlCompiler};
use crate::config::{DesignVars, Network};
use crate::data::{Sample, Synthetic};
use crate::engine::cluster::ClusterReport;
use crate::engine::collective::BucketPlan;
use crate::engine::pool::ClusterPool;
use crate::hw::link::LinkModel;
use crate::engine::{EngineReport, StepOut};
use crate::nn::bn;
use crate::nn::golden;
use crate::nn::loss::encode_label;
use crate::nn::pool::relu_mask;
use crate::nn::scratch::Scratch;
use crate::nn::sgd::{ParamKind, ParamState, SgdHyper};
use crate::nn::tensor::Tensor;
use crate::nn::tensorio::Bundle;
use crate::nn::Params;
use crate::runtime::{In, Prepared, Runtime};
use crate::sim::{simulate, SimReport};

/// Numerics backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    PerOp,
    Fused,
    Golden,
}

impl fmt::Display for Backend {
    /// The canonical lowercase name, accepted back by [`FromStr`] —
    /// used in spec files, CLI flags, and error messages.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Backend::Golden => "golden",
            Backend::PerOp => "perop",
            Backend::Fused => "fused",
        })
    }
}

/// Error from parsing a backend name (see [`Backend::from_str`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseBackendError(pub String);

impl fmt::Display for ParseBackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown backend `{}` (golden|perop|fused)", self.0)
    }
}

impl std::error::Error for ParseBackendError {}

impl std::str::FromStr for Backend {
    type Err = ParseBackendError;

    /// Parse a backend name — shared by the CLI flag and the spec
    /// parser, so the accepted spellings can never diverge.
    fn from_str(s: &str) -> Result<Backend, ParseBackendError> {
        match s {
            "golden" => Ok(Backend::Golden),
            "perop" | "per-op" => Ok(Backend::PerOp),
            "fused" => Ok(Backend::Fused),
            other => Err(ParseBackendError(other.to_string())),
        }
    }
}

/// Rolling training metrics.
#[derive(Debug, Clone, Default)]
pub struct TrainMetrics {
    pub images: u64,
    pub batches: u64,
    pub loss_sum: f64,
    /// Simulated accelerator cycles spent (per the hw model).  With
    /// bucketed overlap on (`bucket_kwords > 0`) the per-batch
    /// communication term is the projected **exposed** comm rather
    /// than the full serial epilogue.
    pub sim_cycles: f64,
    /// Host wall-clock seconds spent in numerics.
    pub host_seconds: f64,
    /// Portion of `host_seconds` spent computing (shard fork/join and
    /// sequential numerics).  Session-local: not serialized into
    /// checkpoints, so it restarts at zero on resume.
    pub host_compute_seconds: f64,
    /// Portion of `host_seconds` spent in the cluster collective +
    /// gradient fold epilogue.  Session-local, like
    /// [`TrainMetrics::host_compute_seconds`].
    pub host_comm_seconds: f64,
}

impl TrainMetrics {
    pub fn mean_loss(&self) -> f64 {
        if self.images == 0 {
            0.0
        } else {
            self.loss_sum / self.images as f64
        }
    }

    /// Simulated wall-clock at the accelerator's clock.
    pub fn sim_seconds(&self, clock_hz: f64) -> f64 {
        self.sim_cycles / clock_hz
    }

    /// Host-side training throughput (engine metric): images per second
    /// of numerics wall-clock across all batches so far.
    pub fn images_per_second(&self) -> f64 {
        if self.host_seconds > 0.0 {
            self.images as f64 / self.host_seconds
        } else {
            0.0
        }
    }
}

/// One scheduled elastic resize for [`Trainer::run`]: once this run
/// has executed `after_batches` batches and the covering checkpoint is
/// on disk, the trainer re-shards onto `accelerators` instances.  The
/// cluster merge contract keeps the training stream bit-identical
/// across the switch (the fingerprint deliberately excludes
/// accelerator counts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resize {
    /// Apply once this many batches *of this run* have executed.
    pub after_batches: u64,
    /// The new data-parallel instance count (0 clamps to 1).
    pub accelerators: usize,
}

/// Checkpoint cadence for [`Trainer::run`]: write to `path` every
/// `every_batches` trained batches (and at every epoch boundary).
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Checkpoint file (one file, atomically replaced on every save).
    pub path: PathBuf,
    /// Save after this many batches (≥ 1; epoch ends always save too).
    pub every_batches: u64,
    /// Optional mid-run elastic resize, applied at the first
    /// checkpoint boundary at/after its `after_batches`.
    pub resize: Option<Resize>,
}

/// One training run's shape for [`Trainer::run`]: how far to train and
/// when to checkpoint.  The run starts wherever its `start` cursor says
/// — `Cursor::start(seed, images)` for a fresh run, or the cursor
/// returned by
/// [`Trainer::resume_from`] to continue a checkpointed one.
#[derive(Debug, Clone)]
pub struct TrainRun {
    /// Train until this many epochs are complete (absolute, not
    /// relative to the start cursor).
    pub epochs: u64,
    /// Images per epoch; batches cover `[b*batch, min((b+1)*batch,
    /// images))` of the dataset index space, so the last batch of an
    /// epoch may be short.
    pub images: u64,
    /// Checkpoint cadence; `None` trains without checkpoints.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Stop after this many batches *of this run* (a preemption point
    /// for tests and budgeted runs); `None` runs to `epochs`.
    pub max_batches: Option<u64>,
}

/// What [`Trainer::run`] reports at each epoch boundary.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    /// 0-based epoch index that just finished.
    pub epoch: u64,
    /// Mean of the per-batch mean losses over the batches this run
    /// executed in the epoch (a mid-epoch resume covers only the
    /// remainder; [`TrainMetrics`] carries the exact cross-run totals).
    pub mean_loss: f64,
    /// Batches this run executed in the epoch.
    pub batches: u64,
}

/// Per-BN-layer bookkeeping for the batch-end statistic refresh: the
/// names of the layer's shard-sum accumulators (`sm_*`/`sq_*`, kept in
/// the trainer's states) and of its running statistics (`rm_*`/`rv_*`,
/// kept in the parameter set).
#[derive(Debug, Clone)]
struct BnMeta {
    sm: String,
    sq: String,
    rm: String,
    rv: String,
}

/// The trainer: compiled accelerator + parameters + optimizer state +
/// (optionally) the PJRT runtime.
pub struct Trainer {
    pub acc: Accelerator,
    pub params: Params,
    states: Vec<(String, ParamState)>,
    pub hyper: SgdHyper,
    pub backend: Backend,
    runtime: Option<Runtime>,
    /// per-image simulated cycles (constant per design point)
    image_cycles: f64,
    batch_cycles: f64,
    /// Engine worker shards for `train_batch` (1 = sequential, the
    /// hardware-faithful default; golden backend only beyond 1).
    pub workers: usize,
    /// Dataset noise amplitude this run draws with.  Rides the
    /// fingerprint (appended only when non-default) so a resume
    /// cannot silently switch data distributions; the default is the
    /// historical hard-coded CLI value, keeping pre-Spec checkpoints
    /// byte-compatible.
    pub noise: f64,
    /// Data-parallel accelerator instances for `train_batch` (1 = the
    /// single-device setup; golden backend only beyond 1).  Initialized
    /// from `dv.cluster`; results stay bit-identical at any count.
    pub accelerators: usize,
    /// Cached per-batch ring all-reduce cycles, keyed by the ring size
    /// it was simulated at (recomputed lazily when the effective
    /// instance count changes).
    allreduce_cache: Option<(usize, f64)>,
    /// Engine observations from the most recent `train_batch` (`None`
    /// when that batch ran through the cluster path instead).
    pub last_engine: Option<EngineReport>,
    /// Cluster observations from the most recent `train_batch` (`None`
    /// when that batch ran through the single-instance engine path).
    pub last_cluster: Option<ClusterReport>,
    pub metrics: TrainMetrics,
    /// parameter literals cached for the current batch (§Perf:
    /// parameters only change at end_batch, so their host->literal
    /// conversion is hoisted out of the per-image loop)
    param_lits: HashMap<String, Prepared>,
    /// pool layer -> (acts-producing layer feeding it, fused-relu?)
    /// for the per-op upsample mask lookup
    pool_prev: HashMap<String, (String, bool)>,
    /// conv/fc layer -> layer below it in FP order (None for the
    /// first); the bool records whether the below layer fuses a ReLU
    conv_below: HashMap<String, Option<(String, bool)>>,
    /// per-BN-layer statistic bookkeeping (empty for BN-free nets)
    bn_meta: Vec<BnMeta>,
    /// Reusable kernel workspace for the sequential golden paths
    /// (`train_image`, `step_golden`); the engine paths hold one per
    /// worker shard in [`Trainer::pool`] instead.  Invalidated
    /// whenever parameters change (end_batch, resume) — its flip
    /// cache is weight-derived.
    scratch: Scratch,
    /// Persistent worker pool for the engine/cluster batch paths:
    /// per-shard scratch workspaces, forked accumulators, and flat
    /// collective staging buffers are allocated on the first batch and
    /// reused for the trainer's lifetime (resized in place on
    /// worker/accelerator changes).
    pool: ClusterPool,
}

impl Trainer {
    /// Build a trainer.  `artifacts`: directory for PerOp/Fused backends;
    /// initial parameters load from the bundle when present, otherwise
    /// fall back to the deterministic rust init.
    ///
    /// Crate-internal: the public construction path is
    /// [`crate::session::Session::trainer`] (a validated
    /// `session::Spec` drives every trainer), which keeps the 7
    /// positional arguments from spreading to call sites again.
    pub(crate) fn new(net: &Network, dv: &DesignVars, batch: usize,
                      lr: f64, momentum: f64, backend: Backend,
                      artifacts: Option<&Path>) -> Result<Trainer> {
        if backend != Backend::Golden && net.has_stats() {
            bail!(
                "network `{}` contains batch-norm layers, which are \
                 golden-backend-only until Pallas BN kernels land in \
                 python/compile/ — train with the golden backend",
                net.name
            );
        }
        let acc = RtlCompiler::default().compile(net, dv)?;
        let runtime = match backend {
            Backend::Golden => None,
            _ => {
                let dir = artifacts.ok_or_else(|| {
                    anyhow!("backend {backend:?} needs an artifacts dir")
                })?;
                Some(Runtime::open(dir)?)
            }
        };
        // initial parameters: canonical bundle if available
        let params = if let Some(rt) = &runtime {
            let tag = net.scale_tag();
            let (pf, _) = rt
                .manifest
                .nets
                .get(tag)
                .ok_or_else(|| {
                    anyhow!("no artifacts for scale `{tag}`; rebuild with \
                             --scales {tag}")
                })?
                .clone();
            let bundle =
                Bundle::load(&artifacts.unwrap().join(pf))?;
            Params::from_bundle(&bundle)
        } else {
            crate::nn::init::init_params(net, 1234)
        };

        // optimizer states for the trainable params, then statistic
        // accumulators for the BN layers — exactly the accum_order the
        // per-image step emits its tensors in
        let mut states = Vec::new();
        for name in net.param_order() {
            let kind = if name.starts_with("w_") {
                ParamKind::Weight
            } else {
                ParamKind::Bias
            };
            let shape = params.get(&name)?.shape().to_vec();
            states.push((name, ParamState::new(kind, &shape)));
        }
        let mut bn_meta = Vec::new();
        for l in &net.layers {
            let ops = crate::ops::for_layer(l);
            let stats = ops.stat_tensors(l);
            if stats.is_empty() {
                continue;
            }
            let running = ops.state_tensors(l);
            // the registry's order contract: [moment-sum, square-sum]
            // paired with [running-mean, running-variance]
            if stats.len() != 2 || running.len() != 2 {
                bail!(
                    "layer `{}`: statistic descriptor must provide \
                     exactly 2 accumulators and 2 running states \
                     (got {} / {})",
                    l.name(),
                    stats.len(),
                    running.len()
                );
            }
            bn_meta.push(BnMeta {
                sm: stats[0].0.clone(),
                sq: stats[1].0.clone(),
                rm: running[0].0.clone(),
                rv: running[1].0.clone(),
            });
            for (name, shape) in stats {
                states.push((name,
                             ParamState::new(ParamKind::Stat, &shape)));
            }
        }

        let report: SimReport = simulate(&acc, batch);
        let image_cycles = (report.fp.latency_cycles
            + report.bp.latency_cycles
            + report.wu.latency_cycles) as f64;
        let batch_cycles = report.update.latency_cycles as f64;
        // with bucketed overlap the batch only pays the comm the
        // projection leaves exposed past the backward pass
        let comm_cycles = if dv.bucket_kwords > 0 && dv.cluster > 1 {
            crate::sim::project_overlap(&acc, batch)
                .exposed_comm_cycles as f64
        } else {
            report.allreduce.latency_cycles as f64
        };
        let allreduce_cache = Some((dv.cluster.max(1), comm_cycles));

        // below-layer maps for the per-op runtime walk: which layer's
        // cached activations feed each conv/fc/pool, and whether that
        // producer fuses a ReLU (drives mask vs all-ones semantics,
        // matching golden::backward's fused_mask rule)
        let mut pool_prev = HashMap::new();
        let mut conv_below = HashMap::new();
        // (name, produces cached acts?, fused relu?)
        let mut prev: Option<(String, bool, bool)> = None;
        for l in &net.layers {
            let ops = crate::ops::for_layer(l);
            let entry = || {
                prev.as_ref().map(|(n, _, r)| (n.clone(), *r))
            };
            match ops.kind() {
                "conv" | "fc" => {
                    conv_below.insert(l.name().to_string(), entry());
                }
                "pool" => {
                    if let Some((p, true, r)) = &prev {
                        pool_prev.insert(l.name().to_string(),
                                         (p.clone(), *r));
                    }
                }
                _ => {}
            }
            let produces_acts = ops.kind() != "fc";
            prev = Some((l.name().to_string(), produces_acts,
                         ops.fused_relu(l)));
        }

        Ok(Trainer {
            acc,
            params,
            states,
            hyper: SgdHyper::new(lr, momentum, batch),
            backend,
            runtime,
            image_cycles,
            batch_cycles,
            workers: 1,
            noise: crate::session::DEFAULT_NOISE,
            accelerators: dv.cluster.max(1),
            allreduce_cache,
            last_engine: None,
            last_cluster: None,
            metrics: TrainMetrics::default(),
            param_lits: HashMap::new(),
            pool_prev,
            conv_below,
            bn_meta,
            scratch: Scratch::for_net(net),
            pool: ClusterPool::new(),
        })
    }

    /// Set the engine worker count (builder style).  `train_batch`
    /// shards golden-backend batches across this many threads; results
    /// stay bit-identical to `workers == 1` (engine contract).
    ///
    /// A count of 0 is normalized to 1 — the documented clamp shared
    /// with [`Trainer::with_accelerators`] (the CLI rejects 0 before it
    /// gets here; in code, "no parallelism" and "one worker" are the
    /// same thing).
    pub fn with_workers(mut self, workers: usize) -> Trainer {
        self.workers = workers.max(1);
        self
    }

    /// Set the dataset noise amplitude recorded in the fingerprint
    /// (builder style; see the `noise` field).  Called by
    /// `Session::trainer` with the spec's value.
    pub fn with_noise(mut self, noise: f64) -> Trainer {
        self.noise = noise;
        self
    }

    /// Set the data-parallel accelerator instance count (builder
    /// style).  `train_batch` shards golden-backend batches across this
    /// many instances and ring-all-reduces their gradient accumulators;
    /// results stay bit-identical to one instance (cluster contract).
    /// The simulated per-batch all-reduce cost is recomputed from the
    /// compiled cluster schedule on the next cluster batch.
    ///
    /// A count of 0 is normalized to 1 — the documented clamp shared
    /// with [`Trainer::with_workers`] (the CLI rejects 0 before it gets
    /// here).
    pub fn with_accelerators(mut self, accelerators: usize) -> Trainer {
        self.accelerators = accelerators.max(1);
        self
    }

    /// Per-batch all-reduce cycles for a cluster of `instances`,
    /// simulated from the compiled cluster schedule (which resolves
    /// `dv.topology` at that count) and cached until the instance
    /// count changes (so writing [`Trainer::accelerators`] directly —
    /// e.g. through an elastic resize — stays consistent too; the
    /// topology itself is fixed for a trainer's lifetime).  With
    /// bucketed overlap on, the charged cycles are the projection's
    /// **exposed** comm — the buckets hidden under the backward pass
    /// cost the simulated cluster nothing.
    fn cluster_allreduce_cycles(&mut self, instances: usize)
                                -> Result<f64> {
        if let Some((n, cycles)) = self.allreduce_cache {
            if n == instances {
                return Ok(cycles);
            }
        }
        let mut dv = self.acc.dv.clone();
        dv.cluster = instances;
        let acc = RtlCompiler::default().compile(&self.acc.net, &dv)?;
        let cycles = if dv.bucket_kwords > 0 && instances > 1 {
            crate::sim::project_overlap(&acc, self.hyper.batch)
                .exposed_comm_cycles as f64
        } else {
            simulate(&acc, self.hyper.batch)
                .allreduce
                .latency_cycles as f64
        };
        self.allreduce_cache = Some((instances, cycles));
        Ok(cycles)
    }

    /// Optimizer state (gradient accumulators + momentum) per parameter,
    /// in the network's canonical order — exposed for equivalence tests
    /// and checkpoint tooling.
    pub fn param_states(&self) -> &[(String, ParamState)] {
        &self.states
    }

    /// Every parameter flattened in canonical `param_order` — the shape
    /// used by the engine's bit-identity checks.
    pub fn flat_params(&self) -> Vec<i32> {
        self.acc
            .net
            .param_order()
            .iter()
            .flat_map(|p| {
                self.params
                    .get(p)
                    .expect("param_order names exist")
                    .data()
                    .to_vec()
            })
            .collect()
    }

    // ---------------- checkpoint / resume ----------------

    /// Canonical description of everything that must match for a
    /// resumed run to continue bit-identically: the network (every
    /// layer dimension), the loss, the SGD hyper-parameters, and the
    /// design variables that feed the simulated-cycle metrics.  Worker
    /// and accelerator counts are deliberately **excluded** — the
    /// engine/cluster merge contract makes gradient grouping
    /// irrelevant, so a checkpoint taken at any `--workers` /
    /// `--accelerators` resumes at any other count.
    /// The derivation (and the string format, byte-compatible with
    /// pre-Spec checkpoints) lives in [`crate::session::fingerprint`]
    /// — the canonical serialization of the fingerprint-relevant Spec
    /// subset.
    pub fn fingerprint(&self) -> String {
        crate::session::fingerprint(&self.acc.net, &self.acc.dv,
                                    &self.hyper, self.noise)
    }

    /// Snapshot the complete training state (params, optimizer state,
    /// metrics, fingerprint) plus `cursor` into an atomic checkpoint
    /// file at `path` (tmp + rename + dir fsync; see [`crate::ckpt`]).
    /// Tensors are copied once to assemble the snapshot and then move
    /// into the serialized payload ([`Checkpoint::into_bytes`]).
    pub fn save_checkpoint(&self, path: &Path, cursor: Cursor)
                           -> Result<()> {
        // trainable params, then the BN running statistics — both must
        // restore for a bit-identical resume
        let mut order = self.acc.net.param_order();
        order.extend(self.acc.net.state_order());
        let mut params = Vec::with_capacity(order.len());
        for name in &order {
            params.push((name.clone(), self.params.get(name)?.clone()));
        }
        let ck = Checkpoint {
            fingerprint: self.fingerprint(),
            cursor,
            hyper: self.hyper,
            metrics: self.metrics.clone(),
            params,
            states: self.states.clone(),
        };
        ck.save_atomic(path)
    }

    /// Restore params, optimizer state, and metrics from a checkpoint
    /// and return its cursor (the next batch to run).  Refuses — with
    /// the trainer untouched — a corrupted/truncated file (CRC), a
    /// checkpoint written for a different network / design point /
    /// hyper-parameters (fingerprint), or any geometry mismatch.
    pub fn resume_from(&mut self, path: &Path) -> Result<Cursor> {
        let ck = Checkpoint::load(path)?;
        let want = self.fingerprint();
        if ck.fingerprint != want {
            bail!(
                "cannot resume from {}: the checkpoint fingerprint does \
                 not match this run's network/design/hyper \
                 configuration\n  checkpoint: {}\n  this run  : {}",
                path.display(),
                ck.fingerprint,
                want
            );
        }
        // validate everything before mutating anything, so a bad file
        // can never leave the trainer half-restored
        let mut order = self.acc.net.param_order();
        order.extend(self.acc.net.state_order());
        if ck.params.len() != order.len()
            || ck.states.len() != self.states.len()
        {
            bail!(
                "cannot resume from {}: checkpoint holds {} params / {} \
                 states, this network has {} / {}",
                path.display(),
                ck.params.len(),
                ck.states.len(),
                order.len(),
                self.states.len()
            );
        }
        for ((name, t), want_name) in ck.params.iter().zip(&order) {
            if name != want_name {
                bail!("cannot resume from {}: parameter order mismatch \
                       (`{name}` where `{want_name}` was expected)",
                      path.display());
            }
            let shape = self.params.get(name)?.shape();
            if t.shape() != shape {
                bail!("cannot resume from {}: `{name}` has shape {:?} \
                       in the checkpoint but {:?} here",
                      path.display(),
                      t.shape(),
                      shape);
            }
        }
        for ((name, st), (want_name, cur)) in
            ck.states.iter().zip(&self.states)
        {
            if name != want_name
                || st.kind != cur.kind
                || st.grad_acc.shape() != cur.grad_acc.shape()
            {
                bail!("cannot resume from {}: optimizer state `{name}` \
                       does not match this network's `{want_name}`",
                      path.display());
            }
        }
        for (name, t) in ck.params {
            *self.params.get_mut(&name)? = t;
        }
        self.states = ck.states;
        self.metrics = ck.metrics;
        self.param_lits.clear(); // parameters changed (§Perf cache)
        self.scratch.invalidate(); // ditto for the flipped-kernel cache
        Ok(ck.cursor)
    }

    /// Drive training from `start` until `cfg.epochs` epochs are
    /// complete (or `cfg.max_batches` batches of this run have
    /// executed), checkpointing per `cfg.checkpoint` — the training
    /// loop refactored from "run to completion" to "run between
    /// checkpoints".  Batch `b` of every epoch covers dataset indices
    /// `[b*batch, min((b+1)*batch, images))`, so the position is fully
    /// described by the returned [`Cursor`]; `on_epoch` fires at every
    /// epoch boundary this run reaches (after that epoch's final
    /// checkpoint is on disk).
    ///
    /// Checkpoints are written every `every_batches` batches and at
    /// every epoch boundary, always carrying the cursor of the *next*
    /// batch; a run killed anywhere replays at most `every_batches - 1`
    /// batches after [`Trainer::resume_from`], and the replayed stream
    /// is bit-identical to the uninterrupted one (see `tests/ckpt.rs`).
    pub fn run(
        &mut self,
        data: &Synthetic,
        cfg: &TrainRun,
        start: Cursor,
        mut on_epoch: impl FnMut(&mut Trainer, &EpochStats) -> Result<()>,
    ) -> Result<Cursor> {
        if cfg.images == 0 {
            bail!("run: images must be at least 1");
        }
        let bs = self.hyper.batch as u64;
        if bs == 0 {
            bail!("run: batch size must be at least 1");
        }
        if let Some(ck) = &cfg.checkpoint {
            if ck.every_batches == 0 {
                bail!("run: checkpoint cadence must be at least 1 batch");
            }
        }
        if data.seed != start.seed {
            bail!(
                "run: dataset seed {} does not match the cursor seed {} \
                 (a resumed run must rebuild the dataset from the \
                 checkpoint's recorded seed)",
                data.seed,
                start.seed
            );
        }
        if cfg.images != start.images {
            bail!(
                "run: images {} does not match the cursor's recorded \
                 epoch width {} — the batch index would address a \
                 different data window (a resumed run must keep the \
                 recorded --images)",
                cfg.images,
                start.images
            );
        }
        let bpe = cfg.images.div_ceil(bs); // batches per epoch
        if start.epoch < cfg.epochs && start.batch >= bpe {
            bail!("run: start cursor batch {} is outside the epoch's \
                   {bpe} batches",
                  start.batch);
        }
        let mut cur = start;
        let mut executed = 0u64;
        'epochs: while cur.epoch < cfg.epochs {
            let mut epoch_loss = 0.0f64;
            let mut epoch_batches = 0u64;
            while cur.batch < bpe {
                if cfg.max_batches.is_some_and(|m| executed >= m) {
                    break 'epochs;
                }
                let lo = cur.batch * bs;
                let hi = ((cur.batch + 1) * bs).min(cfg.images);
                let samples = data.batch(lo, (hi - lo) as usize);
                epoch_loss += self.train_batch(&samples)?;
                epoch_batches += 1;
                executed += 1;
                cur.batch += 1;
                let epoch_done = cur.batch == bpe;
                if epoch_done {
                    // normalize the boundary to (epoch + 1, 0)
                    cur = Cursor {
                        epoch: cur.epoch + 1,
                        batch: 0,
                        ..cur
                    };
                }
                if let Some(ck) = &cfg.checkpoint {
                    if epoch_done || executed % ck.every_batches == 0 {
                        self.save_checkpoint(&ck.path, cur)?;
                        // elastic resize: the covering checkpoint is on
                        // disk, so re-sharding here is indistinguishable
                        // from a kill + resume at this exact cursor
                        if let Some(rz) = ck.resize {
                            if executed >= rz.after_batches {
                                self.accelerators =
                                    rz.accelerators.max(1);
                            }
                        }
                    }
                }
                if epoch_done {
                    let stats = EpochStats {
                        epoch: cur.epoch - 1,
                        mean_loss: epoch_loss / epoch_batches as f64,
                        batches: epoch_batches,
                    };
                    on_epoch(self, &stats)?;
                    continue 'epochs;
                }
            }
        }
        Ok(cur)
    }

    fn runtime(&self) -> Result<&Runtime> {
        self.runtime
            .as_ref()
            .ok_or_else(|| anyhow!("no runtime attached"))
    }

    /// Ensure every parameter has a cached literal for this batch.
    fn refresh_param_lits(&mut self) -> Result<()> {
        if !self.param_lits.is_empty() {
            return Ok(());
        }
        let order = self.acc.net.param_order();
        let rt = self
            .runtime
            .as_ref()
            .ok_or_else(|| anyhow!("no runtime attached"))?;
        let mut lits = HashMap::new();
        for n in &order {
            lits.insert(n.clone(), rt.prepare(self.params.get(n)?)?);
        }
        self.param_lits = lits;
        Ok(())
    }

    fn accumulate(&mut self, name: &str, g: &Tensor) -> Result<()> {
        let st = self
            .states
            .iter_mut()
            .find(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("no state for {name}"))?;
        st.1.accumulate(g);
        Ok(())
    }

    /// Train on one image: run the per-image schedule, return the loss.
    pub fn train_image(&mut self, sample: &Sample) -> Result<i32> {
        let y = encode_label(sample.label, self.acc.net.nclass);
        let t0 = std::time::Instant::now();
        let loss = match self.backend {
            Backend::Golden => self.step_golden(&sample.image, &y)?,
            Backend::PerOp => self.step_per_op(&sample.image, &y)?,
            Backend::Fused => self.step_fused(&sample.image, &y)?,
        };
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.host_seconds += dt;
        self.metrics.host_compute_seconds += dt;
        self.metrics.images += 1;
        self.metrics.loss_sum += f64::from(loss);
        self.metrics.sim_cycles += self.image_cycles;
        Ok(loss)
    }

    /// End-of-batch weight update (the weight update unit, §III-E) plus
    /// the BN statistic refresh: SGD steps every trainable parameter
    /// from its merged gradient accumulator, then the merged BN shard
    /// sums fold into the running statistics (`nn::bn::ema_update`).
    /// Both run on accumulators merged in fixed order, so the result is
    /// bit-identical at any worker/accelerator grouping.
    pub fn end_batch(&mut self) -> Result<()> {
        for (name, st) in &mut self.states {
            if st.kind == ParamKind::Stat {
                continue; // consumed by the statistic refresh below
            }
            let p = self.params.get_mut(name)?;
            st.apply(p, &self.hyper);
        }
        self.refresh_bn_stats()?;
        self.param_lits.clear(); // parameters changed (§Perf cache)
        self.scratch.invalidate(); // ditto for the flipped-kernel cache
        self.metrics.batches += 1;
        self.metrics.sim_cycles += self.batch_cycles;
        Ok(())
    }

    /// Fold each BN layer's merged per-batch statistic accumulators
    /// into its running mean/variance and clear the accumulators.  The
    /// accumulators hold wrapping sums of per-image channel moments,
    /// merged across shards in fixed index order before this runs —
    /// the deterministic BN statistics merge rule (see DESIGN.md).
    fn refresh_bn_stats(&mut self) -> Result<()> {
        for meta in &self.bn_meta {
            let take = |states: &mut Vec<(String, ParamState)>,
                        name: &str|
             -> Result<(Vec<i32>, usize)> {
                let (_, st) = states
                    .iter_mut()
                    .find(|(n, _)| n == name)
                    .ok_or_else(|| {
                        anyhow!("no statistic state `{name}`")
                    })?;
                let acc = st.grad_acc.data().to_vec();
                let count = st.count;
                st.reset();
                Ok((acc, count))
            };
            let (sm_acc, count) = take(&mut self.states, &meta.sm)?;
            let (sq_acc, _) = take(&mut self.states, &meta.sq)?;
            if count == 0 {
                continue;
            }
            let mut rm = self.params.get(&meta.rm)?.clone();
            let mut rv = self.params.get(&meta.rv)?.clone();
            bn::ema_update(&mut rm, &mut rv, &sm_acc, &sq_acc, count);
            *self.params.get_mut(&meta.rm)? = rm;
            *self.params.get_mut(&meta.rv)? = rv;
        }
        Ok(())
    }

    /// Train a full batch of samples and run the end-of-batch weight
    /// update.  Golden-backend batches go through the batch-parallel
    /// [`engine`] (sharded across [`Trainer::workers`] threads, merged
    /// deterministically — bit-identical to sequential at any worker
    /// count) or, with [`Trainer::accelerators`] > 1, through the
    /// cluster engine (per-instance shards merged with a deterministic
    /// ring all-reduce — bit-identical to one instance at any count);
    /// runtime backends execute image-by-image, like the hardware.
    /// Errors on an empty batch.  On any step error the batch's partial
    /// gradient accumulation is discarded (all-or-nothing on every
    /// backend), so a caller may retry the batch without
    /// double-counting.
    pub fn train_batch(&mut self, samples: &[Sample]) -> Result<f64> {
        if samples.is_empty() {
            bail!("train_batch: empty batch (nothing to train on)");
        }
        let sum = match self.backend {
            Backend::Golden if self.accelerators > 1 => {
                self.train_batch_cluster(samples)?
            }
            Backend::Golden => self.train_batch_engine(samples)?,
            _ if self.workers > 1 || self.accelerators > 1 => bail!(
                "train_batch: workers = {} / accelerators = {} require \
                 the golden backend (the PJRT runtime executes on a \
                 single host thread)",
                self.workers,
                self.accelerators
            ),
            _ => {
                let mut sum = 0f64;
                for s in samples {
                    match self.train_image(s) {
                        Ok(loss) => sum += f64::from(loss),
                        Err(e) => {
                            // discard the partial batch (see doc above)
                            for (_, st) in &mut self.states {
                                st.reset();
                            }
                            return Err(e);
                        }
                    }
                }
                sum
            }
        };
        self.end_batch()?;
        Ok(sum / samples.len() as f64)
    }

    /// Golden-backend batch through the engine (any worker count; a
    /// single worker runs inline through the same fork/merge
    /// machinery), on the trainer's persistent worker pool — shard
    /// scratch and forked accumulators are reused across batches.
    fn train_batch_engine(&mut self, samples: &[Sample]) -> Result<f64> {
        let net = &self.acc.net;
        let params = &self.params;
        let order = net.accum_order();
        let step = |s: &Sample, sc: &mut Scratch| {
            golden_step(net, params, &order, s, sc)
        };
        let (loss_sum, report) = self.pool.run_engine(
            samples, self.workers, &mut self.states, &step)?;
        self.metrics.images += samples.len() as u64;
        self.metrics.loss_sum += loss_sum as f64;
        self.metrics.sim_cycles +=
            self.image_cycles * samples.len() as f64;
        self.metrics.host_seconds += report.wall_seconds;
        self.metrics.host_compute_seconds += report.wall_seconds;
        self.last_engine = Some(report);
        self.last_cluster = None;
        Ok(loss_sum as f64)
    }

    /// Golden-backend batch through the cluster engine: the batch
    /// shards across [`Trainer::accelerators`] instances (each itself
    /// sharding across [`Trainer::workers`] threads), and the
    /// per-instance accumulators merge through the collective the
    /// compiler chose for `dv.topology` at the live instance count.
    /// Simulated cycles advance by the longest instance shard
    /// (instances run concurrently) plus the per-batch all-reduce
    /// communication.
    fn train_batch_cluster(&mut self, samples: &[Sample]) -> Result<f64> {
        // the full deployed collective runs every batch (idle instances
        // contribute zero gradients), matching the simulate projection
        let allreduce_cycles =
            self.cluster_allreduce_cycles(self.accelerators)?;
        // with `--bucket-kwords` the merge walks per-layer buckets in
        // reverse-BP order (bit-identical to the monolithic reduce by
        // the partition argument; see engine::collective), and the
        // topology policy prices the actual bucket sizes
        let plan = if self.acc.dv.bucket_kwords > 0 {
            Some(BucketPlan::build(
                &self.acc.net.ring_segments(),
                self.acc.dv.bucket_kwords * 1024,
            ))
        } else {
            None
        };
        let words = plan.as_ref().map_or_else(
            || vec![self.acc.net.ring_words() as u64],
            |p| p.bucket_words(),
        );
        let coll = choose_collective_bucketed(
            self.acc.dv.topology,
            self.accelerators,
            &words,
            &LinkModel::new(&self.acc.dv),
        );
        let net = &self.acc.net;
        let params = &self.params;
        let order = net.accum_order();
        let step = |s: &Sample, sc: &mut Scratch| {
            golden_step(net, params, &order, s, sc)
        };
        let (loss_sum, report) = self.pool.run_cluster(
            samples, self.accelerators, self.workers, &mut self.states,
            &step, coll.as_ref(), plan.as_ref())?;
        self.metrics.images += samples.len() as u64;
        self.metrics.loss_sum += loss_sum as f64;
        let max_shard =
            report.shard_sizes.iter().copied().max().unwrap_or(0);
        self.metrics.sim_cycles += self.image_cycles * max_shard as f64
            + allreduce_cycles;
        self.metrics.host_seconds += report.wall_seconds;
        self.metrics.host_comm_seconds += report.comm_seconds;
        self.metrics.host_compute_seconds +=
            (report.wall_seconds - report.comm_seconds).max(0.0);
        self.last_cluster = Some(report);
        self.last_engine = None;
        Ok(loss_sum as f64)
    }

    /// Classification accuracy over samples (golden forward; numerics are
    /// bit-identical to the artifacts, see integration tests).  Errors on
    /// an empty sample set.
    pub fn evaluate(&self, samples: &[Sample]) -> Result<f64> {
        if samples.is_empty() {
            bail!("evaluate: empty sample set (accuracy undefined)");
        }
        let mut correct = 0usize;
        // local workspace: evaluate is &self and must not disturb the
        // trainer's batch-scoped flip cache
        let mut scratch = Scratch::for_net(&self.acc.net);
        for s in samples {
            let (logits, _) = golden::forward_s(&self.acc.net,
                                                &self.params, &s.image,
                                                &mut scratch)?;
            let pred = logits
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i)
                .unwrap_or(0);
            if pred == s.label {
                correct += 1;
            }
        }
        Ok(correct as f64 / samples.len() as f64)
    }

    // ---------------- backends ----------------

    fn step_golden(&mut self, x: &Tensor, y: &[i32]) -> Result<i32> {
        let (loss, _logits, grads) =
            golden::train_step_s(&self.acc.net, &self.params, x, y,
                                 &mut self.scratch)?;
        // parameter gradients AND per-image BN statistics, in the same
        // accumulator order as the engine path
        for name in self.acc.net.accum_order() {
            let g = grads
                .get(&name)
                .ok_or_else(|| anyhow!("missing grad {name}"))?
                .clone();
            self.accumulate(&name, &g)?;
        }
        Ok(loss)
    }

    fn step_fused(&mut self, x: &Tensor, y: &[i32]) -> Result<i32> {
        let tag = self.acc.net.scale_tag().to_string();
        let order = self.acc.net.param_order();
        self.refresh_param_lits()?;
        let mut inputs: Vec<In> = Vec::with_capacity(order.len() + 2);
        for n in &order {
            inputs.push(In::P(&self.param_lits[n]));
        }
        let y_t = Tensor::from_vec(&[1, y.len()], y.to_vec());
        inputs.push(In::T(x));
        inputs.push(In::T(&y_t));
        let outs = self
            .runtime()?
            .execute_prepared(&format!("fused_step_{tag}"), &inputs)?;
        if outs.len() != order.len() + 2 {
            bail!("fused step returned {} outputs", outs.len());
        }
        let loss = outs[0].data()[0];
        for (name, g) in order.iter().zip(&outs[2..]) {
            self.accumulate(name, g)?;
        }
        Ok(loss)
    }

    /// The faithful path: every scheduled op is its own PJRT execution,
    /// exactly as every key layer on the FPGA is its own DRAM-to-DRAM
    /// pass.  Walks `schedule.per_image` in order, threading activations
    /// (FP) and gradients (BP) through an environment.
    fn step_per_op(&mut self, x: &Tensor, y: &[i32]) -> Result<i32> {
        let tag = self.acc.net.scale_tag().to_string();
        let steps = self.acc.schedule.per_image.clone();
        let mut env: HashMap<String, Tensor> = HashMap::new();
        let mut cur = x.clone(); // FP activation / BP gradient carrier
        let mut flat: Option<Tensor> = None;
        let mut logits: Option<Tensor> = None;
        let mut g_out: Option<Tensor> = None;
        let mut loss: i32 = 0;
        // pending per-layer grads to accumulate after the walk
        let mut pending: Vec<(String, Tensor)> = Vec::new();

        self.refresh_param_lits()?;
        for step in &steps {
            let lname = step.layer.clone();
            match step.op {
                OpKind::ConvFp => {
                    let art = step.artifact.as_ref().unwrap();
                    let w = &self.param_lits[&format!("w_{lname}")];
                    let b = &self.param_lits[&format!("b_{lname}")];
                    let outs = self
                        .runtime()?
                        .execute_prepared(
                            art, &[In::T(&cur), In::P(w), In::P(b)])
                        .with_context(|| format!("step {art}"))?;
                    cur = outs.into_iter().next().unwrap();
                    env.insert(format!("a_{lname}"), cur.clone());
                }
                OpKind::Pool => {
                    let art = step.artifact.as_ref().unwrap();
                    let outs = self.runtime()?.execute(art, &[&cur])?;
                    let mut it = outs.into_iter();
                    cur = it.next().unwrap();
                    env.insert(format!("a_{lname}"), cur.clone());
                    env.insert(format!("idx_{lname}"), it.next().unwrap());
                }
                OpKind::FcFp => {
                    let f = cur.clone().reshape(&[1, cur.len()]);
                    let w = &self.param_lits[&format!("w_{lname}")];
                    let b = &self.param_lits[&format!("b_{lname}")];
                    let outs = self.runtime()?.execute_prepared(
                        &format!("fc_fp_{tag}"),
                        &[In::T(&f), In::P(w), In::P(b)])?;
                    flat = Some(f);
                    logits = Some(outs.into_iter().next().unwrap());
                }
                OpKind::LossGrad => {
                    let art = step.artifact.as_ref().unwrap();
                    let lg = logits
                        .as_ref()
                        .ok_or_else(|| anyhow!("loss before fc"))?;
                    let y_t =
                        Tensor::from_vec(&[1, y.len()], y.to_vec());
                    let outs =
                        self.runtime()?.execute(art, &[lg, &y_t])?;
                    let mut it = outs.into_iter();
                    g_out = Some(it.next().unwrap());
                    loss = it.next().unwrap().data()[0];
                }
                OpKind::FcWu => {
                    let g = g_out.as_ref().unwrap();
                    let f = flat.as_ref().unwrap();
                    let outs = self
                        .runtime()?
                        .execute(&format!("fc_wu_{tag}"), &[g, f])?;
                    let mut it = outs.into_iter();
                    pending.push((format!("w_{lname}"),
                                  it.next().unwrap()));
                    let db = it.next().unwrap();
                    let n = db.len();
                    pending.push((format!("b_{lname}"),
                                  db.reshape(&[n])));
                }
                OpKind::FcBp => {
                    let g = g_out.as_ref().unwrap();
                    let w = &self.param_lits[&format!("w_{lname}")];
                    let outs = self.runtime()?.execute_prepared(
                        &format!("fc_bp_{tag}"), &[In::T(g), In::P(w)])?;
                    let gf = outs.into_iter().next().unwrap();
                    // the schedule step carries the geometry the
                    // gradient re-enters (the fc layer's input geometry)
                    cur = gf.reshape(&step.out_shape);
                }
                OpKind::Upsample => {
                    let art = step.artifact.as_ref().unwrap();
                    let idx = env
                        .get(&format!("idx_{lname}"))
                        .ok_or_else(|| anyhow!("no idx for {lname}"))?
                        .clone();
                    let (prev, fused) = self
                        .pool_prev
                        .get(&lname)
                        .ok_or_else(|| anyhow!("no prev layer"))?;
                    let act = env
                        .get(&format!("a_{prev}"))
                        .ok_or_else(|| anyhow!("no acts for {prev}"))?;
                    // mask only when the producer fuses a ReLU —
                    // all-ones otherwise (golden's fused_mask rule)
                    let mask = if *fused {
                        relu_mask(act)
                    } else {
                        Tensor::from_vec(act.shape(),
                                         vec![1; act.len()])
                    };
                    let outs = self
                        .runtime()?
                        .execute(art, &[&cur, &idx, &mask])?;
                    cur = outs.into_iter().next().unwrap();
                }
                OpKind::ConvWu => {
                    let art = step.artifact.as_ref().unwrap();
                    let below = self.conv_below[&lname].clone();
                    let x_in = match &below {
                        None => x.clone(),
                        Some((b, _)) => env[&format!("a_{b}")].clone(),
                    };
                    let outs =
                        self.runtime()?.execute(art, &[&x_in, &cur])?;
                    let mut it = outs.into_iter();
                    pending.push((format!("w_{lname}"),
                                  it.next().unwrap()));
                    pending.push((format!("b_{lname}"),
                                  it.next().unwrap()));
                }
                OpKind::ConvBp => {
                    let art = step.artifact.as_ref().unwrap();
                    let w = &self.param_lits[&format!("w_{lname}")];
                    let outs = self.runtime()?.execute_prepared(
                        art, &[In::T(&cur), In::P(w)])?;
                    cur = outs.into_iter().next().unwrap();
                }
                OpKind::ScaleMask => {
                    let art = step.artifact.as_ref().unwrap();
                    let below = self
                        .conv_below
                        .get(&lname)
                        .and_then(|b| b.clone())
                        .ok_or_else(|| anyhow!("scale without below"))?;
                    let mask = relu_mask(&env[&format!("a_{}", below.0)]);
                    let outs =
                        self.runtime()?.execute(art, &[&cur, &mask])?;
                    cur = outs.into_iter().next().unwrap();
                }
                OpKind::BnFp | OpKind::BnBp => {
                    bail!(
                        "batch-norm ops have no PJRT artifacts yet — \
                         BN networks are golden-backend-only"
                    )
                }
                OpKind::WeightUpdate | OpKind::AllReduce => {
                    unreachable!("per-batch only")
                }
            }
        }
        for (name, g) in pending {
            self.accumulate(&name, &g)?;
        }
        Ok(loss)
    }
}

/// Golden-model per-image step in engine form — loss plus gradients in
/// canonical `order` — shared by the engine and cluster batch paths so
/// gradient ordering can never diverge between them.
fn golden_step(net: &Network, params: &Params, order: &[String],
               sample: &Sample, sc: &mut Scratch) -> Result<StepOut> {
    let y = encode_label(sample.label, net.nclass);
    let (loss, _logits, mut grads) =
        golden::train_step_s(net, params, &sample.image, &y, sc)?;
    let mut gs = Vec::with_capacity(order.len());
    for name in order {
        gs.push(grads.remove(name).ok_or_else(|| {
            anyhow!("missing grad {name}")
        })?);
    }
    Ok(StepOut { loss, grads: gs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Synthetic;

    fn tiny_net() -> Network {
        // small net in the paper's layer grammar: fast in debug builds
        Network::parse(
            "input 3 8 8\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
             relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    fn tiny_trainer() -> Trainer {
        Trainer::new(&tiny_net(), &DesignVars::for_scale(1), 4, 0.02, 0.9,
                     Backend::Golden, None)
            .unwrap()
    }

    #[test]
    fn golden_backend_trains_a_batch() {
        let mut t = tiny_trainer();
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 4);
        let loss = t.train_batch(&batch).unwrap();
        assert!(loss > 0.0);
        assert_eq!(t.metrics.images, 4);
        assert_eq!(t.metrics.batches, 1);
        assert!(t.metrics.sim_cycles > 0.0);
    }

    #[test]
    fn loss_decreases_over_batches_golden() {
        let mut t = tiny_trainer();
        let data = Synthetic::new(10, (3, 8, 8), 3, 0.3);
        let batch = data.batch(0, 4);
        let first = t.train_batch(&batch).unwrap();
        let mut last = first;
        for _ in 0..6 {
            last = t.train_batch(&batch).unwrap();
        }
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn accuracy_improves_on_tiny_set() {
        let mut t = tiny_trainer();
        let data = Synthetic::new(10, (3, 8, 8), 5, 0.2);
        let train = data.batch(0, 40);
        let a0 = t.evaluate(&train).unwrap();
        for _ in 0..6 {
            for chunk in train.chunks(4) {
                t.train_batch(chunk).unwrap();
            }
        }
        let a1 = t.evaluate(&train).unwrap();
        assert!(a1 > a0, "acc {a0} -> {a1}");
    }

    #[test]
    fn empty_batch_and_eval_are_errors() {
        let mut t = tiny_trainer();
        let err = t.train_batch(&[]).unwrap_err();
        assert!(format!("{err:#}").contains("empty batch"));
        let err = t.evaluate(&[]).unwrap_err();
        assert!(format!("{err:#}").contains("empty sample set"));
        // nothing was recorded by the failed calls
        assert_eq!(t.metrics.images, 0);
        assert_eq!(t.metrics.batches, 0);
    }

    #[test]
    fn four_workers_bit_identical_to_one() {
        // same seed, same batch: the engine's sharded path must produce
        // bit-identical params, loss, and optimizer state (engine
        // merge contract; ISSUE 1 acceptance criterion)
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 10);
        let mut t1 = tiny_trainer();
        let mut t4 = tiny_trainer().with_workers(4);
        for _ in 0..2 {
            // two batches so momentum state is exercised too
            let l1 = t1.train_batch(&batch).unwrap();
            let l4 = t4.train_batch(&batch).unwrap();
            assert_eq!(l1, l4, "mean loss diverged");
        }
        for name in t1.acc.net.param_order() {
            assert_eq!(
                t1.params.get(&name).unwrap(),
                t4.params.get(&name).unwrap(),
                "params diverged for {name}"
            );
        }
        for ((n1, s1), (n4, s4)) in
            t1.param_states().iter().zip(t4.param_states())
        {
            assert_eq!(n1, n4);
            assert_eq!(s1.grad_acc, s4.grad_acc, "{n1} accumulator");
            assert_eq!(s1.momentum, s4.momentum, "{n1} momentum");
            assert_eq!(s1.count, s4.count);
        }
        let rep = t4.last_engine.as_ref().unwrap();
        assert_eq!(rep.workers, 4);
        assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn engine_matches_manual_train_image_loop() {
        // cross-path pin: the engine's positional fork/merge must land
        // every gradient on the same parameter as the name-addressed
        // train_image + end_batch path (guards param_order alignment)
        let data = Synthetic::new(10, (3, 8, 8), 9, 0.3);
        let batch = data.batch(0, 6);
        let mut manual = tiny_trainer();
        for s in &batch {
            manual.train_image(s).unwrap();
        }
        manual.end_batch().unwrap();
        let mut sharded = tiny_trainer().with_workers(3);
        sharded.train_batch(&batch).unwrap();
        assert_eq!(manual.flat_params(), sharded.flat_params());
        for ((n, s), (_, p)) in manual
            .param_states()
            .iter()
            .zip(sharded.param_states())
        {
            assert_eq!(s.momentum, p.momentum, "{n} momentum");
            assert_eq!(s.count, p.count);
        }
        assert_eq!(manual.metrics.loss_sum, sharded.metrics.loss_sum);
    }

    #[test]
    fn four_accelerators_bit_identical_to_one() {
        // the cluster engine is a pure performance transform: same
        // batch stream, any instance count => identical params, losses
        // and optimizer state (ISSUE 2 acceptance criterion)
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 10);
        let mut t1 = tiny_trainer();
        let mut t4 = tiny_trainer().with_accelerators(4);
        for _ in 0..2 {
            let l1 = t1.train_batch(&batch).unwrap();
            let l4 = t4.train_batch(&batch).unwrap();
            assert_eq!(l1, l4, "mean loss diverged");
        }
        for name in t1.acc.net.param_order() {
            assert_eq!(
                t1.params.get(&name).unwrap(),
                t4.params.get(&name).unwrap(),
                "params diverged for {name}"
            );
        }
        for ((n1, s1), (n4, s4)) in
            t1.param_states().iter().zip(t4.param_states())
        {
            assert_eq!(n1, n4);
            assert_eq!(s1.momentum, s4.momentum, "{n1} momentum");
            assert_eq!(s1.count, s4.count);
        }
        let rep = t4.last_cluster.as_ref().unwrap();
        assert_eq!(rep.instances, 4);
        assert_eq!(rep.shard_sizes, vec![3, 3, 2, 2]);
        assert_eq!(rep.ring_steps, 6);
        // instances run concurrently: the cluster's simulated time is
        // below the sequential trainer's
        assert!(t4.metrics.sim_cycles < t1.metrics.sim_cycles);
        assert!(t4.metrics.sim_cycles > 0.0);
    }

    #[test]
    fn accelerators_compose_with_workers() {
        let data = Synthetic::new(10, (3, 8, 8), 3, 0.3);
        let batch = data.batch(0, 8);
        let mut seq = tiny_trainer();
        let mut cl = tiny_trainer().with_accelerators(2).with_workers(2);
        seq.train_batch(&batch).unwrap();
        cl.train_batch(&batch).unwrap();
        assert_eq!(seq.flat_params(), cl.flat_params());
        assert_eq!(cl.last_cluster.as_ref().unwrap().instances, 2);
    }

    #[test]
    fn mid_run_resize_applies_and_stays_bit_identical() {
        // an elastic resize scheduled on the checkpoint policy switches
        // the instance count at a checkpoint boundary without touching
        // the training stream (cluster merge contract)
        let dir = std::env::temp_dir().join(format!(
            "stratus-resize-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("elastic.ckpt");
        let data = Synthetic::new(10, (3, 8, 8), 11, 0.3);
        let run = |resize: Option<Resize>| {
            let mut t = tiny_trainer();
            let cfg = TrainRun {
                epochs: 1,
                images: 16,
                checkpoint: Some(CheckpointPolicy {
                    path: path.clone(),
                    every_batches: 1,
                    resize,
                }),
                max_batches: None,
            };
            t.run(&data, &cfg, Cursor::start(11, 16), |_, _| Ok(()))
                .unwrap();
            t
        };
        let plain = run(None);
        let resized = run(Some(Resize {
            after_batches: 2,
            accelerators: 3,
        }));
        assert_eq!(plain.accelerators, 1);
        assert_eq!(resized.accelerators, 3, "resize never applied");
        assert_eq!(resized.last_cluster.as_ref().unwrap().instances, 3);
        assert_eq!(plain.flat_params(), resized.flat_params());
        assert_eq!(plain.metrics.loss_sum, resized.metrics.loss_sum);
        let _ = std::fs::remove_file(&path);
    }

    fn tiny_bn_net() -> Network {
        Network::parse(
            "input 3 8 8\nconv c1 8 k3 s1 p1\nbn n1 relu\nconv c2 8 k3 \
             s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap()
    }

    fn tiny_bn_trainer() -> Trainer {
        Trainer::new(&tiny_bn_net(), &DesignVars::for_scale(1), 4, 0.02,
                     0.9, Backend::Golden, None)
            .unwrap()
    }

    #[test]
    fn bn_net_trains_and_refreshes_statistics() {
        let mut t = tiny_bn_trainer();
        // param states cover params + stat accumulators
        assert_eq!(t.param_states().len(),
                   t.acc.net.accum_order().len());
        let rv0 = t.params.get("rv_n1").unwrap().clone();
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 4);
        let first = t.train_batch(&batch).unwrap();
        // the batch-end refresh moved the running statistics off init
        // (synthetic activations do not have exactly unit variance)
        assert_ne!(t.params.get("rv_n1").unwrap(), &rv0,
                   "running variance never updated");
        // stat accumulators were consumed and reset
        for (name, st) in t.param_states() {
            if name.starts_with("sm_") || name.starts_with("sq_") {
                assert_eq!(st.count, 0, "{name} not reset");
                assert!(st.grad_acc.data().iter().all(|&v| v == 0));
            }
        }
        // and training makes progress
        let mut last = first;
        for _ in 0..6 {
            last = t.train_batch(&batch).unwrap();
        }
        assert!(last < first, "bn loss {first} -> {last}");
    }

    #[test]
    fn bn_manual_image_loop_matches_engine_path() {
        // the name-addressed train_image path and the positional engine
        // path must agree on params AND running statistics
        let data = Synthetic::new(10, (3, 8, 8), 9, 0.3);
        let batch = data.batch(0, 6);
        let mut manual = tiny_bn_trainer();
        for s in &batch {
            manual.train_image(s).unwrap();
        }
        manual.end_batch().unwrap();
        let mut sharded = tiny_bn_trainer().with_workers(3);
        sharded.train_batch(&batch).unwrap();
        assert_eq!(manual.flat_params(), sharded.flat_params());
        for name in manual.acc.net.state_order() {
            assert_eq!(manual.params.get(&name).unwrap(),
                       sharded.params.get(&name).unwrap(),
                       "{name} diverged");
        }
    }

    #[test]
    fn bn_requires_golden_backend() {
        let err = match Trainer::new(&tiny_bn_net(),
                                     &DesignVars::for_scale(1), 4, 0.02,
                                     0.9, Backend::PerOp,
                                     Some(Path::new("artifacts"))) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("golden-backend-only"),
                "{err:#}");
    }

    #[test]
    fn bn_fingerprint_differs_from_plain_topology() {
        let plain = tiny_trainer().fingerprint();
        let bn = tiny_bn_trainer().fingerprint();
        assert_ne!(plain, bn);
    }

    #[test]
    fn cluster_requires_golden_backend() {
        let mut t = tiny_trainer();
        t.backend = Backend::PerOp;
        t.accelerators = 4;
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 4);
        let err = t.train_batch(&batch).unwrap_err();
        assert!(format!("{err:#}").contains("golden backend"));
    }

    #[test]
    fn more_workers_than_images_still_works() {
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let batch = data.batch(0, 3);
        let mut t = tiny_trainer().with_workers(16);
        t.train_batch(&batch).unwrap();
        let rep = t.last_engine.as_ref().unwrap();
        assert_eq!(rep.workers, 3); // clamped to one image per shard
        assert_eq!(t.metrics.images, 3);
    }

    #[test]
    fn zero_workers_and_accelerators_clamp_to_one() {
        // the documented clamp (ISSUE 3 satellite): 0 normalizes to 1
        // in the builders, consistently for both axes
        let t = tiny_trainer().with_workers(0).with_accelerators(0);
        assert_eq!(t.workers, 1);
        assert_eq!(t.accelerators, 1);
    }

    #[test]
    fn fingerprint_ignores_parallelism_but_not_design() {
        // resume composes with any workers/accelerators count, so the
        // fingerprint must not depend on either; it must depend on the
        // design point and hyper-parameters
        let base = tiny_trainer().fingerprint();
        let par = tiny_trainer()
            .with_workers(4)
            .with_accelerators(3)
            .fingerprint();
        assert_eq!(base, par);
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 4;
        let clustered =
            Trainer::new(&tiny_net(), &dv, 4, 0.02, 0.9, Backend::Golden,
                         None)
                .unwrap()
                .fingerprint();
        assert_eq!(base, clustered, "dv.cluster leaked into fingerprint");
        let other_lr =
            Trainer::new(&tiny_net(), &DesignVars::for_scale(1), 4, 0.05,
                         0.9, Backend::Golden, None)
                .unwrap()
                .fingerprint();
        assert_ne!(base, other_lr);
        let mut small = DesignVars::for_scale(1);
        small.pox = 4;
        let other_dv =
            Trainer::new(&tiny_net(), &small, 4, 0.02, 0.9,
                         Backend::Golden, None)
                .unwrap()
                .fingerprint();
        assert_ne!(base, other_dv);
    }

    #[test]
    fn run_trains_epochs_and_returns_end_cursor() {
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let cfg = TrainRun {
            epochs: 2,
            images: 10,
            checkpoint: None,
            max_batches: None,
        };
        let mut t = tiny_trainer(); // batch size 4 -> 3 batches/epoch
        let mut seen = Vec::new();
        let end = t
            .run(&data, &cfg, crate::ckpt::Cursor::start(7, 10),
                 |_, stats| {
                     seen.push((stats.epoch, stats.batches));
                     Ok(())
                 })
            .unwrap();
        assert_eq!(end, crate::ckpt::Cursor { epoch: 2, batch: 0,
                                              seed: 7, images: 10 });
        assert_eq!(seen, vec![(0, 3), (1, 3)]);
        assert_eq!(t.metrics.batches, 6);
        assert_eq!(t.metrics.images, 20);
    }

    #[test]
    fn run_max_batches_stops_mid_epoch() {
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let cfg = TrainRun {
            epochs: 2,
            images: 10,
            checkpoint: None,
            max_batches: Some(2),
        };
        let mut t = tiny_trainer();
        let end = t
            .run(&data, &cfg, crate::ckpt::Cursor::start(7, 10),
                 |_, _| Ok(()))
            .unwrap();
        assert_eq!(end, crate::ckpt::Cursor { epoch: 0, batch: 2,
                                              seed: 7, images: 10 });
        assert_eq!(t.metrics.batches, 2);
    }

    #[test]
    fn run_rejects_mismatched_dataset_seed() {
        let data = Synthetic::new(10, (3, 8, 8), 8, 0.3);
        let cfg = TrainRun {
            epochs: 1,
            images: 4,
            checkpoint: None,
            max_batches: None,
        };
        let mut t = tiny_trainer();
        let err = t
            .run(&data, &cfg, crate::ckpt::Cursor::start(7, 4),
                 |_, _| Ok(()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("seed"));
    }

    #[test]
    fn run_rejects_mismatched_epoch_width() {
        // the cursor records the epoch width; running with a different
        // --images would silently retrain a different data window
        let data = Synthetic::new(10, (3, 8, 8), 7, 0.3);
        let cfg = TrainRun {
            epochs: 1,
            images: 8,
            checkpoint: None,
            max_batches: None,
        };
        let mut t = tiny_trainer();
        let err = t
            .run(&data, &cfg, crate::ckpt::Cursor::start(7, 12),
                 |_, _| Ok(()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("epoch width"), "{err:#}");
    }

    #[test]
    fn backend_parses_and_displays_canonical_names() {
        // FromStr/Display are shared by the CLI flag, the spec
        // parser, and error messages — spellings must round-trip
        for (name, backend) in [("golden", Backend::Golden),
                                ("perop", Backend::PerOp),
                                ("fused", Backend::Fused)] {
            assert_eq!(name.parse::<Backend>().unwrap(), backend);
            assert_eq!(backend.to_string(), name);
        }
        // the historical alias stays accepted
        assert_eq!("per-op".parse::<Backend>().unwrap(),
                   Backend::PerOp);
        let err = "cuda".parse::<Backend>().unwrap_err();
        assert_eq!(err.to_string(),
                   "unknown backend `cuda` (golden|perop|fused)");
        // parsing is case-sensitive like every other CLI token
        assert!("Golden".parse::<Backend>().is_err());
    }

    #[test]
    fn per_op_backend_requires_artifacts() {
        let net = Network::cifar(1);
        let err = match Trainer::new(&net, &DesignVars::for_scale(1), 4,
                                     0.002, 0.9, Backend::PerOp, None) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("artifacts"));
    }
}
