//! Network descriptions and FPGA design variables — the *inputs* to the
//! RTL compiler (Fig. 3: "high-level CNN description" + "design
//! variables").
//!
//! A network can be built programmatically ([`Network::cifar`]) or parsed
//! from the text format accepted by `stratus compile -f net.cfg`:
//!
//! ```text
//! # CIFAR-10 1X (paper §IV-A)
//! name  cifar10-1x
//! input 3 32 32
//! conv  c1 16 k3 s1 p1 relu
//! conv  c2 16 k3 s1 p1 relu
//! pool  p1 2
//! ...
//! fc    fc 10
//! loss  hinge
//! ```

use anyhow::{anyhow, bail, Context, Result};

/// One layer of the CNN, with every dimension the RTL compiler needs
/// (Table I naming: Nkx/Nky kernel, Nox/Noy/Nof output, Nix/Niy/Nif input).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// 2D convolution (+ fused ReLU, an affiliated layer in the paper).
    Conv {
        name: String,
        /// Nif / Nof
        cin: usize,
        cout: usize,
        /// Nox == Nix (stride-1 same conv), Noy == Niy
        h: usize,
        w: usize,
        /// Nkx == Nky
        k: usize,
        pad: usize,
        stride: usize,
        relu: bool,
    },
    /// Max pooling with stored indices (key layer).
    Pool { name: String, c: usize, h: usize, w: usize, k: usize },
    /// Fully-connected classifier (flatten is an affiliated layer).
    Fc { name: String, cin: usize, cout: usize },
    /// Integer batch normalization (§IV-B extension, after FxpNet):
    /// per-channel scale/shift against running statistics, with an
    /// optionally fused ReLU (an affiliated layer, like conv's).
    Bn { name: String, c: usize, h: usize, w: usize, relu: bool },
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. }
            | Layer::Pool { name, .. }
            | Layer::Fc { name, .. }
            | Layer::Bn { name, .. } => name,
        }
    }

    // The per-kind semantics below live in the layer-ops registry
    // (`crate::ops`) — these delegates keep the call sites ergonomic
    // while the registry stays the single source of truth.

    /// Output activation element count (what FP writes to DRAM).
    pub fn out_elems(&self) -> usize {
        crate::ops::for_layer(self).out_geom(self).elems()
    }

    /// Weight parameter count (0 for pool; gamma for bn).
    pub fn weight_elems(&self) -> usize {
        crate::ops::for_layer(self).weight_elems(self)
    }

    /// Bias parameter count (beta for bn).
    pub fn bias_elems(&self) -> usize {
        crate::ops::for_layer(self).bias_elems(self)
    }

    /// MAC count of the FP pass through this layer.
    pub fn macs_fp(&self) -> u64 {
        crate::ops::for_layer(self).macs_fp(self)
    }

    /// MAC count of the BP pass (zero for the first conv layer is
    /// handled by the caller; structurally it equals the FP count with
    /// if/of interchanged, i.e. the same product).
    pub fn macs_bp(&self) -> u64 {
        crate::ops::for_layer(self).macs_bp(self)
    }

    /// MAC count of the weight-gradient (WU) pass.
    pub fn macs_wu(&self) -> u64 {
        crate::ops::for_layer(self).macs_wu(self)
    }

    /// Whether the layer fuses a ReLU on its output (conv's `relu`
    /// flag, bn's `relu` flag) — drives the activation-gradient mask.
    pub fn fused_relu(&self) -> bool {
        crate::ops::for_layer(self).fused_relu(self)
    }
}

/// Loss unit selection (§III-B: square hinge and euclidean supported).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Loss {
    #[default]
    SquareHinge,
    Euclidean,
}

/// High-level CNN description, the first input to the RTL compiler.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    /// input image (c, h, w)
    pub input: (usize, usize, usize),
    pub layers: Vec<Layer>,
    pub nclass: usize,
    pub loss: Loss,
}

impl Network {
    /// The paper's CIFAR-10 family (§IV-A): `scale` in {1, 2, 4} builds
    /// 1X / 2X / 4X — `16s C3-16s C3-P-32s C3-32s C3-P-64s C3-64s C3-P-FC`.
    pub fn cifar(scale: usize) -> Network {
        assert!(matches!(scale, 1 | 2 | 4), "scale must be 1, 2 or 4");
        let widths: Vec<usize> =
            [16, 16, 32, 32, 64, 64].iter().map(|w| w * scale).collect();
        let mut layers = Vec::new();
        let (mut cin, mut h) = (3usize, 32usize);
        for (i, &cout) in widths.iter().enumerate() {
            layers.push(Layer::Conv {
                name: format!("c{}", i + 1),
                cin,
                cout,
                h,
                w: h,
                k: 3,
                pad: 1,
                stride: 1,
                relu: true,
            });
            cin = cout;
            if i % 2 == 1 {
                layers.push(Layer::Pool {
                    name: format!("p{}", i / 2 + 1),
                    c: cout,
                    h,
                    w: h,
                    k: 2,
                });
                h /= 2;
            }
        }
        layers.push(Layer::Fc {
            name: "fc".into(),
            cin: cin * h * h,
            cout: 10,
        });
        Network {
            name: format!("cifar10-{scale}x"),
            input: (3, 32, 32),
            layers,
            nclass: 10,
            loss: Loss::SquareHinge,
        }
    }

    /// The CIFAR-10 family with integer batch normalization: every conv
    /// drops its fused ReLU and is followed by a BN layer that fuses it
    /// instead (`conv -> bn+relu -> [pool] -> ... -> fc`).  This is the
    /// §IV-B extension topology; it trains on the golden backend only
    /// until Pallas BN kernels land in `python/compile/`.
    pub fn cifar_bn(scale: usize) -> Network {
        assert!(matches!(scale, 1 | 2 | 4), "scale must be 1, 2 or 4");
        let widths: Vec<usize> =
            [16, 16, 32, 32, 64, 64].iter().map(|w| w * scale).collect();
        let mut layers = Vec::new();
        let (mut cin, mut h) = (3usize, 32usize);
        for (i, &cout) in widths.iter().enumerate() {
            layers.push(Layer::Conv {
                name: format!("c{}", i + 1),
                cin,
                cout,
                h,
                w: h,
                k: 3,
                pad: 1,
                stride: 1,
                relu: false, // the bn layer fuses the relu instead
            });
            layers.push(Layer::Bn {
                name: format!("n{}", i + 1),
                c: cout,
                h,
                w: h,
                relu: true,
            });
            cin = cout;
            if i % 2 == 1 {
                layers.push(Layer::Pool {
                    name: format!("p{}", i / 2 + 1),
                    c: cout,
                    h,
                    w: h,
                    k: 2,
                });
                h /= 2;
            }
        }
        layers.push(Layer::Fc {
            name: "fc".into(),
            cin: cin * h * h,
            cout: 10,
        });
        Network {
            name: format!("cifar10-bn-{scale}x"),
            input: (3, 32, 32),
            layers,
            nclass: 10,
            loss: Loss::SquareHinge,
        }
    }

    /// Scale name used in artifact files ("1x", "2x", "4x").
    pub fn scale_tag(&self) -> &str {
        if self.name.ends_with("4x") {
            "4x"
        } else if self.name.ends_with("2x") {
            "2x"
        } else {
            "1x"
        }
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.weight_elems() + l.bias_elems())
            .sum()
    }

    /// Canonical parameter ordering shared with python (`param_order`).
    pub fn param_order(&self) -> Vec<String> {
        let mut names = Vec::new();
        for l in &self.layers {
            if l.weight_elems() > 0 {
                names.push(format!("w_{}", l.name()));
                names.push(format!("b_{}", l.name()));
            }
        }
        names
    }

    /// Per-batch statistic accumulator names (BN shard sums), in layer
    /// order — these merge across workers/accelerators exactly like
    /// gradient accumulators (fixed-order wrapping-i32 merge) and fold
    /// into the running statistics at batch end.
    pub fn stat_order(&self) -> Vec<String> {
        self.layers
            .iter()
            .flat_map(|l| {
                crate::ops::for_layer(l)
                    .stat_tensors(l)
                    .into_iter()
                    .map(|(n, _)| n)
            })
            .collect()
    }

    /// Persistent non-SGD state tensor names (BN running mean/var), in
    /// layer order; they live in the parameter set and ride in
    /// checkpoints alongside the trainable parameters.
    pub fn state_order(&self) -> Vec<String> {
        self.layers
            .iter()
            .flat_map(|l| {
                crate::ops::for_layer(l)
                    .state_tensors(l)
                    .into_iter()
                    .map(|(n, _)| n)
            })
            .collect()
    }

    /// Canonical accumulator order for the batch engine: trainable
    /// parameters first, then the per-batch statistic accumulators.
    /// This is the order the per-image step emits gradients in and the
    /// order the trainer's optimizer/stat states are kept in.
    pub fn accum_order(&self) -> Vec<String> {
        let mut order = self.param_order();
        order.extend(self.stat_order());
        order
    }

    /// Whether any layer maintains batch statistics (BN present).
    pub fn has_stats(&self) -> bool {
        self.layers.iter().any(|l| {
            !crate::ops::for_layer(l).stat_tensors(l).is_empty()
        })
    }

    /// Total i32 words the cluster ring all-reduces per batch: one
    /// gradient-accumulator word per trainable parameter plus every
    /// BN statistic-accumulator word (the cluster engine flattens and
    /// reduces both — the modeled ring must match).
    pub fn ring_words(&self) -> usize {
        let stats: usize = self
            .layers
            .iter()
            .flat_map(|l| crate::ops::for_layer(l).stat_tensors(l))
            .map(|(_, shape)| shape.iter().product::<usize>())
            .sum();
        self.param_count() + stats
    }

    /// The ring-reduced flat gradient vector as named segments, in
    /// flat-vector order (`accum_order`: trainable parameters, then BN
    /// statistic accumulators): one `(accumulator name, i32 words)`
    /// pair per tensor the cluster engine concatenates.  Segment word
    /// counts sum to [`Network::ring_words`].  This is the
    /// layer-boundary input of the bucketed all-reduce planner
    /// ([`crate::engine::collective::BucketPlan`]): bucket boundaries
    /// may only fall between segments, never inside one.
    pub fn ring_segments(&self) -> Vec<(String, usize)> {
        let mut segs = Vec::new();
        for l in &self.layers {
            if l.weight_elems() > 0 {
                segs.push((format!("w_{}", l.name()), l.weight_elems()));
                segs.push((format!("b_{}", l.name()), l.bias_elems()));
            }
        }
        for l in &self.layers {
            for (name, shape) in crate::ops::for_layer(l).stat_tensors(l)
            {
                segs.push((name, shape.iter().product::<usize>()));
            }
        }
        segs
    }

    /// Total training operations per image, counted as the paper counts
    /// GOPS: 2 ops per MAC, over FP + BP + WU.
    pub fn ops_per_image(&self) -> u64 {
        let mut total = 0u64;
        for (i, l) in self.layers.iter().enumerate() {
            total += 2 * l.macs_fp() + 2 * l.macs_wu();
            // first conv layer propagates no input gradient
            let first_conv = i == 0;
            if !first_conv {
                total += 2 * l.macs_bp();
            }
        }
        total
    }

    /// Parse the `net.cfg` text format (see module docs).
    pub fn parse(text: &str) -> Result<Network> {
        let mut name = String::from("custom");
        let mut input: Option<(usize, usize, usize)> = None;
        let mut layers: Vec<Layer> = Vec::new();
        let mut loss = Loss::default();
        // rolling state: current feature-map shape
        let (mut cur_c, mut cur_h) = (0usize, 0usize);

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("line {}: `{}`", lineno + 1, raw.trim());
            match toks[0] {
                "name" => {
                    name = toks
                        .get(1)
                        .ok_or_else(|| anyhow!("{}: missing name", ctx()))?
                        .to_string();
                }
                "input" => {
                    if toks.len() != 4 {
                        bail!("{}: input wants `input C H W`", ctx());
                    }
                    let c = toks[1].parse().with_context(ctx)?;
                    let h = toks[2].parse().with_context(ctx)?;
                    let w: usize = toks[3].parse().with_context(ctx)?;
                    if h != w {
                        bail!("{}: only square inputs supported", ctx());
                    }
                    input = Some((c, h, w));
                    cur_c = c;
                    cur_h = h;
                }
                "conv" => {
                    if input.is_none() {
                        bail!("{}: `input` must precede layers", ctx());
                    }
                    let lname = toks
                        .get(1)
                        .ok_or_else(|| anyhow!("{}: missing layer name", ctx()))?
                        .to_string();
                    let cout: usize = toks
                        .get(2)
                        .ok_or_else(|| anyhow!("{}: missing channels", ctx()))?
                        .parse()
                        .with_context(ctx)?;
                    let mut k = 3;
                    let mut pad = 1;
                    let mut stride = 1;
                    let mut relu = false;
                    for t in &toks[3..] {
                        if let Some(v) = t.strip_prefix('k') {
                            k = v.parse().with_context(ctx)?;
                        } else if let Some(v) = t.strip_prefix('s') {
                            stride = v.parse().with_context(ctx)?;
                        } else if let Some(v) = t.strip_prefix('p') {
                            pad = v.parse().with_context(ctx)?;
                        } else if *t == "relu" {
                            relu = true;
                        } else {
                            bail!("{}: unknown conv attribute `{t}`", ctx());
                        }
                    }
                    if stride != 1 || pad != (k - 1) / 2 {
                        bail!(
                            "{}: only stride-1 same convolutions are \
                             supported by the RTL library",
                            ctx()
                        );
                    }
                    layers.push(Layer::Conv {
                        name: lname,
                        cin: cur_c,
                        cout,
                        h: cur_h,
                        w: cur_h,
                        k,
                        pad,
                        stride,
                        relu,
                    });
                    cur_c = cout;
                }
                "bn" => {
                    if input.is_none() {
                        bail!("{}: `input` must precede layers", ctx());
                    }
                    if matches!(layers.last(), Some(Layer::Fc { .. })) {
                        bail!("{}: bn must precede the fc classifier \
                               (it normalizes feature maps)",
                              ctx());
                    }
                    let lname = toks
                        .get(1)
                        .ok_or_else(|| anyhow!("{}: missing layer name", ctx()))?
                        .to_string();
                    let mut relu = false;
                    for t in &toks[2..] {
                        if *t == "relu" {
                            relu = true;
                        } else {
                            bail!("{}: unknown bn attribute `{t}`", ctx());
                        }
                    }
                    layers.push(Layer::Bn {
                        name: lname,
                        c: cur_c,
                        h: cur_h,
                        w: cur_h,
                        relu,
                    });
                    // elementwise: geometry unchanged
                }
                "pool" => {
                    let lname = toks
                        .get(1)
                        .ok_or_else(|| anyhow!("{}: missing layer name", ctx()))?
                        .to_string();
                    let k: usize = toks
                        .get(2)
                        .ok_or_else(|| anyhow!("{}: missing window", ctx()))?
                        .parse()
                        .with_context(ctx)?;
                    if cur_h % k != 0 {
                        bail!("{}: H={} not divisible by window {k}",
                              ctx(), cur_h);
                    }
                    layers.push(Layer::Pool {
                        name: lname,
                        c: cur_c,
                        h: cur_h,
                        w: cur_h,
                        k,
                    });
                    cur_h /= k;
                }
                "fc" => {
                    let lname = toks
                        .get(1)
                        .ok_or_else(|| anyhow!("{}: missing layer name", ctx()))?
                        .to_string();
                    let cout: usize = toks
                        .get(2)
                        .ok_or_else(|| anyhow!("{}: missing outputs", ctx()))?
                        .parse()
                        .with_context(ctx)?;
                    layers.push(Layer::Fc {
                        name: lname,
                        cin: cur_c * cur_h * cur_h,
                        cout,
                    });
                    cur_c = cout;
                }
                "loss" => {
                    loss = match toks.get(1).copied() {
                        Some("hinge") => Loss::SquareHinge,
                        Some("euclid") | Some("euclidean") => Loss::Euclidean,
                        other => bail!("{}: unknown loss {:?}", ctx(), other),
                    };
                }
                other => bail!("{}: unknown directive `{other}`", ctx()),
            }
        }
        let input = input.ok_or_else(|| anyhow!("no `input` line"))?;
        let nclass = match layers.last() {
            Some(Layer::Fc { cout, .. }) => *cout,
            _ => bail!("network must end with an fc layer"),
        };
        Ok(Network { name, input, layers, nclass, loss })
    }
}

/// All-reduce topology for cluster designs (`dv.cluster > 1`).  The
/// gradient merge itself is wrapping-i32 addition — associative and
/// commutative mod 2^32 — so *every* topology produces bit-identical
/// parameters; the choice only moves communication cycles around.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// Flat ring all-reduce: reduce-scatter + all-gather, `2*(N-1)`
    /// steps.  The default (and the paper's small-cluster shape): every
    /// pinned small-N behavior in the repo assumes it.
    #[default]
    Ring,
    /// Hierarchical group reduce: intra-group ring reduce-scatter,
    /// inter-group ring all-reduce over slice owners, intra-group
    /// all-gather — `2*(G-1) + 2*(N/G-1)` steps for group size G.
    /// Degenerates to the flat ring when N has no proper divisor.
    Hier,
    /// Let the compiler pick ring vs hierarchical (and the group size)
    /// by minimizing the link model's projected cycles.
    Auto,
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Topology::Ring => "ring",
            Topology::Hier => "hier",
            Topology::Auto => "auto",
        })
    }
}

impl std::str::FromStr for Topology {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Topology> {
        match s {
            "ring" => Ok(Topology::Ring),
            "hier" => Ok(Topology::Hier),
            "auto" => Ok(Topology::Auto),
            other => bail!("unknown topology `{other}` (ring|hier|auto)"),
        }
    }
}

/// FPGA design variables (the second compiler input): unroll factors,
/// clock, memory system parameters, optimization toggles.
#[derive(Debug, Clone)]
pub struct DesignVars {
    /// Loop unroll factors Pox, Poy, Pof (Table I) — the MAC array is
    /// Pox * Poy * Pof units (Fig. 6).
    pub pox: usize,
    pub poy: usize,
    pub pof: usize,
    /// Accelerator clock in MHz (paper: 240 MHz on Stratix 10 GX).
    pub clock_mhz: f64,
    /// Off-chip DRAM peak bandwidth in GBYTE/s.  The paper prints
    /// "16.9Gb/s", but its own Table III consistency check (Titan XP has
    /// "30X" the accelerator's bandwidth; 547 GB/s / 30 = 18.2 GB/s)
    /// shows the unit is gigabytes — 16.9 Gbit/s would also make the WU
    /// phase alone ~5x slower than the paper's total epoch latency.
    pub dram_gbytes: f64,
    /// Effective fraction of peak DRAM bandwidth after protocol
    /// overheads (calibrated with the DMA descriptor overhead against
    /// Table II's 1X/4X epoch latencies — see hw::dram).
    pub dram_efficiency: f64,
    /// Enable the MAC load-balance unit for WU convolutions (§III-F).
    pub load_balance: bool,
    /// Enable double buffering of on-chip tiles (§IV-B).
    pub double_buffer: bool,
    /// Activation-tile rows kept on chip per DMA burst.
    pub tile_rows: usize,
    /// Data width in bits (the paper's entire datapath is 16-bit fixed).
    pub data_bits: usize,
    /// Accelerator instances training data-parallel (1 = the paper's
    /// single-FPGA setup).  Beyond 1 the compiler emits per-instance
    /// schedules plus a ring all-reduce of the WU gradient accumulators
    /// between batch accumulation and the weight update.
    pub cluster: usize,
    /// Inter-accelerator serial-link peak bandwidth in GB/s per
    /// direction (one point-to-point link per ring neighbor; sized like
    /// the devkit's transceiver-based SerialLite links).
    pub link_gbytes: f64,
    /// Effective fraction of link peak bandwidth after framing/protocol
    /// overheads (see hw::link, mirroring dram_efficiency).
    pub link_efficiency: f64,
    /// All-reduce topology for cluster designs; irrelevant at
    /// `cluster == 1`.  Excluded from the checkpoint fingerprint (like
    /// `cluster` itself): any topology merges bit-identically.
    pub topology: Topology,
    /// Gradient-bucket size cap, in kibi-words (1024 i32 words), for
    /// the pipelined cluster all-reduce: the flat gradient vector is
    /// partitioned at layer parameter boundaries into buckets walked in
    /// reverse-layer (BP) order, so each bucket's reduce becomes
    /// eligible the moment BP retires its layers and overlaps the
    /// remaining backward compute.  `0` (the default) keeps the
    /// monolithic serial epilogue — every pinned small-N behavior
    /// assumes it.  Excluded from the checkpoint fingerprint (like
    /// `cluster` and `topology`): bucketing regroups the same
    /// wrapping-i32 sums, never what they sum to.
    pub bucket_kwords: usize,
}

impl Default for DesignVars {
    fn default() -> Self {
        DesignVars {
            pox: 8,
            poy: 8,
            pof: 16,
            clock_mhz: 240.0,
            dram_gbytes: 16.9,
            dram_efficiency: 0.60,
            load_balance: true,
            double_buffer: true,
            tile_rows: 8,
            data_bits: 16,
            cluster: 1,
            link_gbytes: 12.5,
            link_efficiency: 0.80,
            topology: Topology::default(),
            bucket_kwords: 0,
        }
    }
}

impl DesignVars {
    /// Paper configuration for a given CIFAR scale (Pof = 16/32/64).
    pub fn for_scale(scale: usize) -> DesignVars {
        DesignVars { pof: 16 * scale, ..DesignVars::default() }
    }

    /// Total MAC units in the array.
    pub fn mac_count(&self) -> usize {
        self.pox * self.poy * self.pof
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar_1x_structure() {
        let n = Network::cifar(1);
        assert_eq!(n.layers.len(), 10);
        assert_eq!(n.nclass, 10);
        let convs: Vec<usize> = n
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv { cout, .. } => Some(*cout),
                _ => None,
            })
            .collect();
        assert_eq!(convs, [16, 16, 32, 32, 64, 64]);
        match n.layers.last().unwrap() {
            Layer::Fc { cin, cout, .. } => {
                assert_eq!(*cin, 1024);
                assert_eq!(*cout, 10);
            }
            _ => panic!("expected fc last"),
        }
    }

    #[test]
    fn cifar_params_near_paper_2m() {
        // paper abstract: "CNNs with 2M parameters" for the 4X model; the
        // structural count of the stated topology is ~1.19M (the paper's
        // figure is approximate), so assert order of magnitude.
        let n = Network::cifar(4);
        let p = n.param_count();
        assert!(p > 1_000_000 && p < 2_500_000, "4x params = {p}");
    }

    #[test]
    fn mac_array_sizes_match_table2() {
        assert_eq!(DesignVars::for_scale(1).mac_count(), 1024);
        assert_eq!(DesignVars::for_scale(2).mac_count(), 2048);
        assert_eq!(DesignVars::for_scale(4).mac_count(), 4096);
    }

    #[test]
    fn topology_parses_and_round_trips() {
        for t in [Topology::Ring, Topology::Hier, Topology::Auto] {
            assert_eq!(t.to_string().parse::<Topology>().unwrap(), t);
        }
        assert_eq!(DesignVars::default().topology, Topology::Ring);
        let err = "mesh".parse::<Topology>().unwrap_err();
        assert!(err.to_string().contains("ring|hier|auto"));
    }

    #[test]
    fn ops_per_image_is_about_3x_inference()
    {
        // training ops should be ~3x inference ops (paper §I cites >3X)
        let n = Network::cifar(1);
        let fp: u64 =
            n.layers.iter().map(|l| 2 * l.macs_fp()).sum();
        let total = n.ops_per_image();
        let ratio = total as f64 / fp as f64;
        assert!(ratio > 2.5 && ratio < 3.5, "ratio = {ratio}");
    }

    #[test]
    fn parse_roundtrip_cifar1x() {
        let cfg = "\
name cifar10-1x
input 3 32 32
conv c1 16 k3 s1 p1 relu
conv c2 16 k3 s1 p1 relu
pool p1 2
conv c3 32 k3 s1 p1 relu
conv c4 32 k3 s1 p1 relu
pool p2 2
conv c5 64 k3 s1 p1 relu
conv c6 64 k3 s1 p1 relu
pool p3 2
fc fc 10
loss hinge
";
        let parsed = Network::parse(cfg).unwrap();
        let built = Network::cifar(1);
        assert_eq!(parsed.layers, built.layers);
        assert_eq!(parsed.loss, built.loss);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(Network::parse("conv c1 16").is_err());
        assert!(Network::parse("input 3 32 32\nconv c1 16 k3 s2 p1")
            .is_err());
        assert!(Network::parse("input 3 32 32\nbogus x").is_err());
        assert!(Network::parse("input 3 32 32\nconv c1 16").is_err());
    }

    #[test]
    fn parse_rejects_nonunit_stride() {
        // the grammar accepts a stride token but the RTL library (and
        // nn/conv) only implement stride-1 same convs: s2 must be a
        // clear error, not silently trained as stride 1
        let err = Network::parse(
            "input 3 32 32\nconv c1 16 k3 s2 p1 relu\nfc f 10",
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stride-1"), "{msg}");
        // stride 1 spelled explicitly stays fine
        assert!(Network::parse(
            "input 3 32 32\nconv c1 16 k3 s1 p1 relu\n\
             conv c2 16 k3 s1 p1 relu\npool p 2\nfc f 10"
        )
        .is_ok());
    }

    #[test]
    fn parse_rejects_indivisible_pool() {
        // 9 % 2 != 0: the h/k geometry math would silently truncate a
        // row; the parser must reject it instead
        let err = Network::parse("input 3 9 9\npool p 2\nfc f 10")
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("divisible"), "{msg}");
        assert!(msg.contains("line 2"), "{msg}");
        // divisible windows parse
        assert!(Network::parse("input 3 9 9\npool p 3\nfc f 10").is_ok());
    }

    #[test]
    fn parse_roundtrip_cifar_bn_1x() {
        let cfg = "\
name cifar10-bn-1x
input 3 32 32
conv c1 16 k3 s1 p1
bn n1 relu
conv c2 16 k3 s1 p1
bn n2 relu
pool p1 2
conv c3 32 k3 s1 p1
bn n3 relu
conv c4 32 k3 s1 p1
bn n4 relu
pool p2 2
conv c5 64 k3 s1 p1
bn n5 relu
conv c6 64 k3 s1 p1
bn n6 relu
pool p3 2
fc fc 10
loss hinge
";
        let parsed = Network::parse(cfg).unwrap();
        let built = Network::cifar_bn(1);
        assert_eq!(parsed.layers, built.layers);
        assert_eq!(parsed.name, built.name);
    }

    #[test]
    fn parse_rejects_bad_bn() {
        // bn before input
        assert!(Network::parse("bn n1 relu").is_err());
        // unknown attribute
        assert!(Network::parse("input 3 8 8\nbn n1 glu\nfc f 10")
            .is_err());
        // bn after the classifier
        let err = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1\nfc f 10\nbn n1 relu",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("classifier"));
    }

    #[test]
    fn cifar_bn_structure() {
        let n = Network::cifar_bn(1);
        // 6 conv + 6 bn + 3 pool + 1 fc
        assert_eq!(n.layers.len(), 16);
        assert_eq!(n.scale_tag(), "1x");
        // every conv's relu moved into the bn that follows it
        for l in &n.layers {
            match l {
                Layer::Conv { relu, .. } => assert!(!relu),
                Layer::Bn { relu, c, h, .. } => {
                    assert!(*relu);
                    assert!(*c > 0 && *h > 0);
                }
                _ => {}
            }
        }
        // (6 conv + 6 bn + 1 fc) * (w + b)
        assert_eq!(n.param_order().len(), 26);
        // 2 stat accumulators and 2 running-state tensors per bn layer
        assert_eq!(n.stat_order().len(), 12);
        assert_eq!(n.state_order().len(), 12);
        assert!(n.has_stats());
        assert_eq!(n.accum_order().len(), 26 + 12);
        assert!(n.stat_order()[0].starts_with("sm_"));
        assert!(n.state_order()[1].starts_with("rv_"));
    }

    #[test]
    fn plain_nets_have_no_stats() {
        let n = Network::cifar(1);
        assert!(!n.has_stats());
        assert!(n.stat_order().is_empty());
        assert!(n.state_order().is_empty());
        assert_eq!(n.accum_order(), n.param_order());
        // without statistics the ring reduces exactly the gradients
        assert_eq!(n.ring_words(), n.param_count());
    }

    #[test]
    fn bn_ring_words_cover_statistics() {
        let n = Network::cifar_bn(1);
        // 2 stat words per bn channel: (16+16+32+32+64+64) * 2
        assert_eq!(n.ring_words(), n.param_count() + 448);
    }

    #[test]
    fn parse_reports_line_numbers() {
        let err = Network::parse("input 3 32 32\nconv c1 16 k3 s2 p1\nfc f 10")
            .unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
    }

    #[test]
    fn param_order_matches_python_convention() {
        let n = Network::cifar(1);
        let order = n.param_order();
        assert_eq!(order.len(), 14);
        assert_eq!(order[0], "w_c1");
        assert_eq!(order[13], "b_fc");
    }
}
