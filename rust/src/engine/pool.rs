//! Persistent worker pool: the allocation-reuse backend behind both
//! engine levels ([`super::run_batch`] and the cluster runners in
//! [`super::cluster`]).
//!
//! The transient engines rebuilt everything every batch: per-shard
//! forked accumulators ([`ParamState::fork_shard`]), per-shard
//! [`Scratch`] workspaces, and (one level up) per-instance flat
//! gradient staging buffers for the collective.  None of that state
//! carries information across batches — forks start zeroed, flats are
//! overwritten, scratch contents never influence results — so a pool
//! can own all of it and reuse the allocations:
//!
//! - [`WorkerPool`]: one slot per worker shard, each holding a
//!   persistent `Scratch` and a forked accumulator set.  Forks are
//!   [`ParamState::reset`] (zeroed) at batch start, which is
//!   bit-equivalent to a fresh `fork_shard`; scratches are
//!   [`Scratch::invalidate`]d at batch start because the flip-kernel
//!   cache is weight-derived and weights change at `end_batch`.
//! - [`ClusterPool`]: one slot per accelerator instance, each holding
//!   an inner `WorkerPool` plus the instance's named accumulator
//!   replica, and a pool-owned flat staging vector per instance for
//!   the collective (`clear()` keeps capacity).
//!
//! Threads themselves are still scoped per batch — OS thread spawn is
//! microseconds against a multi-millisecond batch, and scoped borrows
//! keep the pool free of channels and `unsafe`; the measurable
//! per-batch churn was the allocations, which this module hoists.
//!
//! # Bucketed (pipelined) cluster merge
//!
//! [`ClusterPool::run_cluster`] accepts an optional
//! [`BucketPlan`]: `None` reproduces the monolithic all-reduce
//! epilogue byte-for-byte, while `Some(plan)` walks the buckets in
//! reverse-layer (BP) order, reducing each bucket range through
//! [`Collective::all_reduce_range`] and folding it into the caller's
//! accumulators as soon as it completes — the host-side analogue of
//! the schedule's compute/communication overlap.  Bit-identity is
//! structural: every element belongs to exactly one bucket and is
//! summed by the same fixed wrapping-i32 walk as the monolithic
//! reduce (asserted across bucket sizes x topologies x N in
//! `rust/tests/overlap.rs`).

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::data::Sample;
use crate::engine::cluster::ClusterReport;
use crate::engine::collective::{BucketPlan, Collective, CollectiveStats};
use crate::engine::{shard_sizes, EngineReport, StepOut};
use crate::nn::scratch::Scratch;
use crate::nn::sgd::ParamState;

/// One worker shard's reusable state.
struct WorkerSlot {
    scratch: Scratch,
    fork: Vec<ParamState>,
}

/// Persistent per-shard state for the batch-parallel engine: forked
/// accumulators and scratch workspaces allocated once and reused
/// across batches.  See the module docs for the reuse contract.
#[derive(Default)]
pub struct WorkerPool {
    slots: Vec<WorkerSlot>,
}

/// Accumulate `shard` into `fork` through `step`, reusing `scratch`
/// across the slice.  The loop body is identical to the transient
/// engine's shard runner — only the state's lifetime changed.
fn run_shard_pooled<F>(shard: &[Sample], fork: &mut [ParamState],
                       scratch: &mut Scratch, step: &F) -> Result<i64>
where
    F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
{
    let mut loss_sum = 0i64;
    for s in shard {
        let out = step(s, scratch)?;
        if out.grads.len() != fork.len() {
            bail!(
                "engine: step produced {} gradients for {} parameters",
                out.grads.len(),
                fork.len()
            );
        }
        for (st, g) in fork.iter_mut().zip(&out.grads) {
            st.accumulate(g);
        }
        loss_sum += i64::from(out.loss);
    }
    Ok(loss_sum)
}

impl WorkerPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Make the first `shards` slots ready for a batch against
    /// `states`: reuse forks whose geometry still matches (zeroed via
    /// [`ParamState::reset`], bit-equivalent to a fresh fork), rebuild
    /// on mismatch (first use, or a changed parameter set), and
    /// invalidate every scratch (weights changed since last batch).
    fn ensure(&mut self, shards: usize,
              states: &[(String, ParamState)]) {
        for slot in self.slots.iter_mut().take(shards) {
            let matches = slot.fork.len() == states.len()
                && slot.fork.iter().zip(states).all(|(f, (_, st))| {
                    f.grad_acc.data().len() == st.grad_acc.data().len()
                });
            if matches {
                for f in &mut slot.fork {
                    f.reset();
                }
            } else {
                slot.fork =
                    states.iter().map(|(_, st)| st.fork_shard()).collect();
            }
            slot.scratch.invalidate();
        }
        while self.slots.len() < shards {
            self.slots.push(WorkerSlot {
                scratch: Scratch::new(),
                fork: states
                    .iter()
                    .map(|(_, st)| st.fork_shard())
                    .collect(),
            });
        }
    }

    /// Run one batch sharded across up to `workers` threads, merging
    /// into `states` — the pooled equivalent of [`super::run_batch`]
    /// (same sharding, same fixed-order merge, same all-or-nothing
    /// error contract, bit-identical results).
    pub fn run_batch<F>(&mut self, samples: &[Sample], workers: usize,
                        states: &mut [(String, ParamState)], step: &F)
                        -> Result<(i64, EngineReport)>
    where
        F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
    {
        if samples.is_empty() {
            bail!("engine: cannot run an empty batch");
        }
        let t0 = Instant::now();
        let sizes = shard_sizes(samples.len(), workers);
        let mut slices: Vec<&[Sample]> = Vec::with_capacity(sizes.len());
        let mut off = 0usize;
        for &sz in &sizes {
            slices.push(&samples[off..off + sz]);
            off += sz;
        }
        self.ensure(sizes.len(), states);

        let results: Vec<Result<i64>> = if slices.len() == 1 {
            let slot = &mut self.slots[0];
            vec![run_shard_pooled(slices[0], &mut slot.fork,
                                  &mut slot.scratch, step)]
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = slices
                    .iter()
                    .zip(self.slots.iter_mut())
                    .map(|(&sl, slot)| {
                        scope.spawn(move || {
                            run_shard_pooled(sl, &mut slot.fork,
                                             &mut slot.scratch, step)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join().unwrap_or_else(|_| {
                            Err(anyhow!("engine: worker thread panicked"))
                        })
                    })
                    .collect()
            })
        };

        // all-or-nothing: if any shard failed, propagate before
        // touching `states` (failed forks are zeroed at next use)
        let losses = results.into_iter().collect::<Result<Vec<i64>>>()?;
        let loss_sum: i64 = losses.iter().sum();
        // fixed-order merge: shard 0 first, then 1, ...
        for slot in self.slots.iter().take(sizes.len()) {
            for ((_, st), f) in states.iter_mut().zip(&slot.fork) {
                st.merge_shard(f);
            }
        }
        let report = EngineReport {
            workers: sizes.len(),
            images: samples.len(),
            shard_sizes: sizes,
            wall_seconds: t0.elapsed().as_secs_f64(),
        };
        Ok((loss_sum, report))
    }
}

/// One accelerator instance's reusable state.
struct InstanceSlot {
    /// Inner worker pool for the instance's shard.
    pool: WorkerPool,
    /// The instance's DRAM-resident accumulator replica (named, so
    /// geometry checks and flattening walk the caller's order).
    fork: Vec<(String, ParamState)>,
}

/// Persistent per-instance state for the cluster engine: inner worker
/// pools, accumulator replicas, and flat staging buffers for the
/// collective, all allocated once and reused across batches.
#[derive(Default)]
pub struct ClusterPool {
    slots: Vec<InstanceSlot>,
    /// Per-instance flat gradient vectors (parallel to `slots`; kept
    /// outside `InstanceSlot` so the collective can borrow them as one
    /// `&mut [Vec<i32>]`).
    flats: Vec<Vec<i32>>,
}

/// Fold `reduced[lo..hi]` into the matching element range of the
/// caller's accumulators (wrapping add) — the bucket-granular version
/// of the cluster merge epilogue.  `states` is walked in flat-vector
/// order; segments outside `[lo, hi)` are untouched.
fn fold_range(states: &mut [(String, ParamState)], reduced: &[i32],
              lo: usize, hi: usize) {
    let mut off = 0usize;
    for (_, st) in states.iter_mut() {
        let data = st.grad_acc.data_mut();
        let len = data.len();
        let s = off.max(lo);
        let e = (off + len).min(hi);
        if s < e {
            for (a, &v) in
                data[s - off..e - off].iter_mut().zip(&reduced[s..e])
            {
                *a = a.wrapping_add(v);
            }
        }
        off += len;
    }
}

impl ClusterPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run a single-accelerator batch through instance slot 0's worker
    /// pool, merging directly into `states` — the pooled equivalent of
    /// [`super::run_batch`] for the engine-only training path.
    pub fn run_engine<F>(&mut self, samples: &[Sample], workers: usize,
                         states: &mut [(String, ParamState)], step: &F)
                         -> Result<(i64, EngineReport)>
    where
        F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
    {
        if self.slots.is_empty() {
            self.slots.push(InstanceSlot { pool: WorkerPool::new(),
                                           fork: Vec::new() });
        }
        self.slots[0].pool.run_batch(samples, workers, states, step)
    }

    /// Make the first `ring` instance slots (and staging buffers)
    /// ready for a batch against `states`.
    fn ensure(&mut self, ring: usize,
              states: &[(String, ParamState)]) {
        for slot in self.slots.iter_mut().take(ring) {
            let matches = slot.fork.len() == states.len()
                && slot.fork.iter().zip(states).all(
                    |((fname, f), (name, st))| {
                        fname == name
                            && f.grad_acc.data().len()
                                == st.grad_acc.data().len()
                    });
            if matches {
                for (_, f) in &mut slot.fork {
                    f.reset();
                }
            } else {
                slot.fork = states
                    .iter()
                    .map(|(name, st)| (name.clone(), st.fork_shard()))
                    .collect();
            }
        }
        while self.slots.len() < ring {
            self.slots.push(InstanceSlot {
                pool: WorkerPool::new(),
                fork: states
                    .iter()
                    .map(|(name, st)| (name.clone(), st.fork_shard()))
                    .collect(),
            });
        }
        while self.flats.len() < ring {
            self.flats.push(Vec::new());
        }
    }

    /// Run one batch data-parallel across `instances` accelerator
    /// instances — the pooled core behind
    /// [`super::cluster::run_batch_cluster_with`].  With `plan =
    /// None` the gradient merge is the monolithic collective
    /// all-reduce; with `Some(plan)` each bucket is reduced and folded
    /// into `states` the moment it completes, in reverse-layer order.
    /// Either way the result is bit-identical (see module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn run_cluster<F>(&mut self, samples: &[Sample],
                          instances: usize, workers: usize,
                          states: &mut [(String, ParamState)], step: &F,
                          collective: &dyn Collective,
                          plan: Option<&BucketPlan>)
                          -> Result<(i64, ClusterReport)>
    where
        F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
    {
        if samples.is_empty() {
            bail!("cluster: cannot run an empty batch");
        }
        let t0 = Instant::now();
        let ring = instances.max(1);
        let sizes = shard_sizes(samples.len(), ring);
        let n = sizes.len(); // instances that received work (≤ ring)
        let mut slices: Vec<&[Sample]> = Vec::with_capacity(n);
        let mut off = 0usize;
        for &sz in &sizes {
            slices.push(&samples[off..off + sz]);
            off += sz;
        }
        // idle instances (beyond the shard count) keep their zeroed
        // replica but still join the collective, like idle members of
        // a deployed ring
        self.ensure(ring, states);

        let results: Vec<Result<i64>> = if n == 1 {
            let InstanceSlot { pool, fork } = &mut self.slots[0];
            vec![pool.run_batch(slices[0], workers, fork, step)
                     .map(|(loss, _)| loss)]
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = slices
                    .iter()
                    .zip(self.slots.iter_mut())
                    .map(|(&sl, slot)| {
                        scope.spawn(move || {
                            let InstanceSlot { pool, fork } = slot;
                            pool.run_batch(sl, workers, fork, step)
                                .map(|(loss, _)| loss)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join().unwrap_or_else(|_| {
                            Err(anyhow!(
                                "cluster: instance thread panicked"))
                        })
                    })
                    .collect()
            })
        };
        // all-or-nothing: propagate before the collective so `states`
        // never sees a partial cluster
        let losses = results.into_iter().collect::<Result<Vec<i64>>>()?;
        let loss_sum: i64 = losses.iter().sum();

        // flatten each instance's accumulators into its persistent
        // staging buffer (clear() keeps the allocation)
        for (slot, flat) in
            self.slots.iter().zip(self.flats.iter_mut()).take(ring)
        {
            flat.clear();
            for (_, st) in &slot.fork {
                flat.extend_from_slice(st.grad_acc.data());
            }
        }
        let flats = &mut self.flats[..ring];

        let tc = Instant::now();
        let stats = match plan {
            Some(p) => {
                debug_assert_eq!(
                    p.total_words() as usize, flats[0].len(),
                    "bucket plan does not cover the gradient vector");
                let mut steps = 0usize;
                let mut total_words = 0u64;
                // pipelined merge: reduce each bucket in reverse-layer
                // order and fold it the moment it completes
                for b in &p.buckets {
                    let st =
                        collective.all_reduce_range(flats, b.lo, b.hi);
                    steps += st.steps;
                    total_words += st.total_words;
                    fold_range(states, &flats[0], b.lo, b.hi);
                }
                CollectiveStats { steps, total_words }
            }
            None => {
                let st = collective.all_reduce(flats);
                let hi = flats[0].len();
                fold_range(states, &flats[0], 0, hi);
                st
            }
        };
        let comm_seconds = tc.elapsed().as_secs_f64();
        debug_assert!(
            flats.iter().all(|f| *f == flats[0]),
            "collective left instances with diverged accumulators");

        let images: usize = self
            .slots
            .iter()
            .take(ring)
            .map(|s| s.fork.first().map_or(0, |(_, st)| st.count))
            .sum();
        for (_, st) in states.iter_mut() {
            st.count += images;
        }

        let report = ClusterReport {
            instances: ring,
            images: samples.len(),
            shard_sizes: sizes,
            ring_steps: stats.steps,
            ring_words: stats.total_words,
            wall_seconds: t0.elapsed().as_secs_f64(),
            comm_seconds,
        };
        Ok((loss_sum, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::nn::sgd::ParamKind;
    use crate::nn::tensor::Tensor;

    fn samples(count: usize) -> Vec<Sample> {
        (0..count)
            .map(|i| Sample {
                image: Tensor::from_vec(
                    &[4],
                    vec![
                        i as i32 + 1,
                        -(i as i32) - 1,
                        i32::MAX - i as i32,
                        i32::MIN + i as i32,
                    ],
                ),
                label: i % 3,
            })
            .collect()
    }

    fn step(s: &Sample, _: &mut Scratch) -> Result<StepOut> {
        Ok(StepOut { loss: s.label as i32,
                     grads: vec![s.image.clone()] })
    }

    fn fresh_states() -> Vec<(String, ParamState)> {
        vec![("w".to_string(),
              ParamState::new(ParamKind::Weight, &[4]))]
    }

    #[test]
    fn pooled_engine_reuse_is_bit_identical_across_batches() {
        // run the same batches through a fresh transient engine and a
        // reused pool; every batch must match to the bit
        let mut pool = WorkerPool::new();
        for round in 0..3 {
            let batch = samples(10 + round);
            let mut seq = fresh_states();
            let (l_seq, _) =
                engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
            let mut pooled = fresh_states();
            let (l_pool, rep) = pool
                .run_batch(&batch, 4, &mut pooled, &step)
                .unwrap();
            assert_eq!(l_pool, l_seq, "round {round}");
            assert_eq!(pooled[0].1.grad_acc, seq[0].1.grad_acc,
                       "round {round}");
            assert_eq!(pooled[0].1.count, seq[0].1.count);
            assert_eq!(rep.workers, 4);
        }
    }

    #[test]
    fn pooled_engine_shrinking_worker_count_reuses_slots() {
        let mut pool = WorkerPool::new();
        let batch = samples(12);
        let mut seq = fresh_states();
        engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        for workers in [6, 2, 4, 1] {
            let mut pooled = fresh_states();
            pool.run_batch(&batch, workers, &mut pooled, &step)
                .unwrap();
            assert_eq!(pooled[0].1.grad_acc, seq[0].1.grad_acc,
                       "workers={workers}");
        }
    }

    #[test]
    fn pooled_cluster_reuse_is_bit_identical_across_batches() {
        use crate::engine::collective::HierCollective;
        let mut pool = ClusterPool::new();
        for round in 0..3 {
            let batch = samples(9 + round);
            let mut seq = fresh_states();
            let (l_seq, _) =
                engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
            let mut cl = fresh_states();
            let (l_cl, rep) = pool
                .run_cluster(&batch, 4, 2, &mut cl, &step,
                             &HierCollective { group: 2 }, None)
                .unwrap();
            assert_eq!(l_cl, l_seq, "round {round}");
            assert_eq!(cl[0].1.grad_acc, seq[0].1.grad_acc,
                       "round {round}");
            assert_eq!(cl[0].1.count, seq[0].1.count);
            assert_eq!(rep.ring_steps, 4);
            assert!(rep.comm_seconds <= rep.wall_seconds);
        }
    }

    #[test]
    fn pooled_cluster_failed_batch_leaves_states_untouched() {
        use crate::engine::collective::RingCollective;
        let mut pool = ClusterPool::new();
        let batch = samples(8);
        let failing =
            |s: &Sample, sc: &mut Scratch| -> Result<StepOut> {
                if s.label == 2 {
                    bail!("injected failure");
                }
                step(s, sc)
            };
        let mut st = fresh_states();
        let err = pool
            .run_cluster(&batch, 4, 1, &mut st, &failing,
                         &RingCollective, None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
        assert!(st[0].1.grad_acc.data().iter().all(|&v| v == 0));
        assert_eq!(st[0].1.count, 0);
        // the pool recovers: the next (clean) batch reuses the slots
        // whose forks were left half-accumulated by the failure
        let mut seq = fresh_states();
        engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        pool.run_cluster(&batch, 4, 1, &mut st, &step,
                         &RingCollective, None)
            .unwrap();
        assert_eq!(st[0].1.grad_acc, seq[0].1.grad_acc);
    }

    #[test]
    fn bucketed_cluster_merge_matches_monolithic() {
        use crate::engine::collective::RingCollective;
        let batch = samples(10);
        let mut mono = fresh_states();
        let mut pool = ClusterPool::new();
        pool.run_cluster(&batch, 4, 1, &mut mono, &step,
                         &RingCollective, None)
            .unwrap();
        // one 4-word parameter split into two 2-word buckets
        let plan = BucketPlan::build(
            &[("w_a".to_string(), 2), ("w_b".to_string(), 2)], 2);
        assert_eq!(plan.buckets.len(), 2);
        let mut bucketed = fresh_states();
        let mut pool2 = ClusterPool::new();
        pool2
            .run_cluster(&batch, 4, 1, &mut bucketed, &step,
                         &RingCollective, Some(&plan))
            .unwrap();
        assert_eq!(bucketed[0].1.grad_acc, mono[0].1.grad_acc);
        assert_eq!(bucketed[0].1.count, mono[0].1.count);
    }
}
