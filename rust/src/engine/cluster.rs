//! Multi-accelerator cluster engine: data-parallel training across N
//! simulated accelerator instances with a deterministic ring all-reduce
//! of the WU gradient accumulators.
//!
//! This extends the batch-parallel engine one level up: where
//! [`super::run_batch`] shards a batch across worker threads *inside*
//! one accelerator, the cluster engine shards it across accelerator
//! *instances* — each with its own DRAM-resident accumulator state
//! (modeled by [`ParamState::fork_shard`]) — and merges per-instance
//! batch gradients with the ring all-reduce every multi-device training
//! system uses (reduce-scatter + all-gather, `2*(N-1)` steps).
//!
//! # Determinism / bit-identity contract
//!
//! - The batch splits into **contiguous per-instance shards** in sample
//!   order ([`super::shard_sizes`]), and each instance runs its shard
//!   through the inner engine (so instances can themselves use worker
//!   threads).
//! - The ring walks chunks in **fixed slot order**: chunk `c` of the
//!   flattened gradient vector accumulates through instances `c, c+1,
//!   ...` — the addition order is a pure function of `(N, len)`,
//!   independent of thread scheduling.
//! - Accumulation is wrapping i32 addition (associative and commutative
//!   mod 2^32), so the reduced vector — and every parameter after
//!   `end_batch` — is **bit-identical to 1-instance training at any
//!   N**, and every instance ends the all-reduce with the identical
//!   accumulator (asserted in tests).  Loss totals sum in i64, exact.

use anyhow::Result;

use crate::data::Sample;
use crate::engine::collective::{BucketPlan, Collective, RingCollective};
use crate::engine::pool::ClusterPool;
use crate::engine::StepOut;
use crate::nn::scratch::Scratch;
use crate::nn::sgd::ParamState;

/// What the cluster engine observed while running one batch.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Ring size: every deployed instance joins the all-reduce, even
    /// ones that received no images this batch (they contribute zero
    /// gradients, exactly like idle accelerators in a real ring).
    pub instances: usize,
    pub images: usize,
    /// Contiguous per-instance shard sizes for the instances that
    /// received work, in instance order (shorter than `instances` when
    /// the batch has fewer images than the ring has members).
    pub shard_sizes: Vec<usize>,
    /// Collective steps executed: `2 * (instances - 1)` for the flat
    /// ring, `2*(G-1) + 2*(N/G-1)` for the hierarchical reduce, 0 for
    /// one instance.
    pub ring_steps: usize,
    /// i32 words moved across all links in total (for the flat ring,
    /// `2 * (instances - 1) * gradient_len`; divide by `instances`
    /// for the average per-link traffic).
    pub ring_words: u64,
    /// Wall-clock of the cluster section (fork -> ring -> merge).
    pub wall_seconds: f64,
    /// Wall-clock of the communication epilogue alone (collective
    /// all-reduce plus the fold into the caller's accumulators) —
    /// the host-side analogue of the simulator's exposed-comm split.
    /// Always `<= wall_seconds`.
    pub comm_seconds: f64,
}

/// Statistics of one host-side ring all-reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingStats {
    /// Ring steps walked (reduce-scatter plus all-gather).
    pub steps: usize,
    /// i32 words moved across all ring links in total.
    pub total_words: u64,
}

/// Deterministic fixed-order ring all-reduce over per-instance flat
/// gradient vectors (reduce-scatter then all-gather).  After the call
/// every buffer holds the identical element-wise wrapping-i32 sum of
/// all inputs.  Buffers shorter than the instance count are handled
/// (some ring chunks are empty).  Panics on ragged buffer lengths.
pub fn ring_all_reduce(bufs: &mut [Vec<i32>]) -> RingStats {
    let hi = bufs.first().map_or(0, |b| b.len());
    ring_all_reduce_range(bufs, 0, hi)
}

/// [`ring_all_reduce`] restricted to the element range `[lo, hi)` of
/// every buffer — the bucket-reduce primitive behind the pipelined
/// cluster merge.  Elements outside the range are untouched; the walk
/// inside it is the identical fixed index formula, so reducing a
/// partition of `[0, len)` bucket by bucket reproduces the full
/// reduce bit-for-bit.
pub fn ring_all_reduce_range(bufs: &mut [Vec<i32>],
                             range_lo: usize, range_hi: usize)
                             -> RingStats {
    let n = bufs.len();
    if n <= 1 {
        return RingStats { steps: 0, total_words: 0 };
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len),
            "ring_all_reduce: ragged buffers");
    assert!(range_lo <= range_hi && range_hi <= len,
            "ring_all_reduce: range [{range_lo}, {range_hi}) outside \
             buffers of len {len}");
    let span = range_hi - range_lo;
    // balanced chunk ranges per ring slot (empty when span < n)
    let bound = |c: usize| range_lo + c * span / n;
    let mut words = 0u64;
    // reduce-scatter: at step s, instance (c+s)%n sends its partial of
    // chunk c one hop to (c+s+1)%n, which accumulates it; after n-1
    // steps instance (c+n-1)%n owns the fully reduced chunk c
    for s in 0..n - 1 {
        for c in 0..n {
            let src = (c + s) % n;
            let dst = (c + s + 1) % n;
            let (lo, hi) = (bound(c), bound(c + 1));
            let (from, to) = pair_mut(bufs, src, dst);
            for (d, &v) in to[lo..hi].iter_mut().zip(&from[lo..hi]) {
                *d = d.wrapping_add(v);
            }
            words += (hi - lo) as u64;
        }
    }
    // all-gather: each reduced chunk circulates one hop per step until
    // every instance holds every chunk
    for s in 0..n - 1 {
        for c in 0..n {
            let src = (c + n - 1 + s) % n;
            let dst = (src + 1) % n;
            let (lo, hi) = (bound(c), bound(c + 1));
            let (from, to) = pair_mut(bufs, src, dst);
            to[lo..hi].copy_from_slice(&from[lo..hi]);
            words += (hi - lo) as u64;
        }
    }
    // every step moves the full range in total across the n links
    RingStats { steps: 2 * (n - 1), total_words: words }
}

/// Split-borrow two distinct ring members: shared access to `src`,
/// mutable access to `dst` — the ring's hot loop moves gradient chunks
/// with no temporary allocations.
fn pair_mut(bufs: &mut [Vec<i32>], src: usize, dst: usize)
            -> (&[i32], &mut Vec<i32>) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (head, tail) = bufs.split_at_mut(dst);
        (head[src].as_slice(), &mut tail[0])
    } else {
        let (head, tail) = bufs.split_at_mut(src);
        (tail[0].as_slice(), &mut head[dst])
    }
}

/// [`run_batch_cluster_with`] over the default flat ring — the shape
/// every pre-topology call site (and the `Topology::Ring` default)
/// uses.
pub fn run_batch_cluster<F>(samples: &[Sample], instances: usize,
                            workers: usize,
                            states: &mut [(String, ParamState)], step: &F)
                            -> Result<(i64, ClusterReport)>
where
    F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
{
    run_batch_cluster_with(samples, instances, workers, states, step,
                           &RingCollective)
}

/// Run one batch data-parallel across `instances` accelerator
/// instances, each sharding its sub-batch across up to `workers`
/// threads through the inner engine, then all-reduce the per-instance
/// gradient accumulators through `collective` and merge the
/// (identical) reduced result into `states`.  Every instance joins the
/// collective even when the batch has fewer images than the cluster
/// has members — idle instances contribute zero gradients, so the
/// simulated communication cost matches the deployed topology.
/// Returns the exact i64 loss sum and a [`ClusterReport`].
///
/// Any [`Collective`] yields bit-identical results (the merge is
/// wrapping-i32 addition); only the reported step/word traffic
/// differs.
///
/// All-or-nothing like the inner engine: if any instance fails,
/// `states` is left untouched.
pub fn run_batch_cluster_with<F>(samples: &[Sample], instances: usize,
                                 workers: usize,
                                 states: &mut [(String, ParamState)],
                                 step: &F, collective: &dyn Collective)
                                 -> Result<(i64, ClusterReport)>
where
    F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
{
    run_batch_cluster_bucketed(samples, instances, workers, states,
                               step, collective, None)
}

/// [`run_batch_cluster_with`] with an optional gradient
/// [`BucketPlan`]: `None` runs the monolithic all-reduce epilogue,
/// `Some(plan)` reduces and folds each bucket in reverse-layer (BP)
/// order as soon as it completes — bit-identical either way (each
/// element is summed by the same fixed wrapping walk exactly once).
///
/// Like the other free functions this builds a throwaway
/// [`ClusterPool`] per call; the trainer's batch loop holds a
/// persistent pool so per-instance forks, inner worker scratch, and
/// flat staging buffers are reused across batches.
pub fn run_batch_cluster_bucketed<F>(
    samples: &[Sample], instances: usize, workers: usize,
    states: &mut [(String, ParamState)], step: &F,
    collective: &dyn Collective, plan: Option<&BucketPlan>)
    -> Result<(i64, ClusterReport)>
where
    F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
{
    ClusterPool::new().run_cluster(samples, instances, workers, states,
                                   step, collective, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::nn::sgd::ParamKind;
    use crate::nn::tensor::Tensor;
    use anyhow::bail;

    fn samples(count: usize) -> Vec<Sample> {
        (0..count)
            .map(|i| Sample {
                // adversarial payloads: large magnitudes force wrapping
                image: Tensor::from_vec(
                    &[4],
                    vec![
                        i as i32 + 1,
                        -(i as i32) - 1,
                        i32::MAX - i as i32,
                        i32::MIN + i as i32,
                    ],
                ),
                label: i % 3,
            })
            .collect()
    }

    fn step(s: &Sample, _: &mut Scratch) -> Result<StepOut> {
        Ok(StepOut { loss: s.label as i32, grads: vec![s.image.clone()] })
    }

    fn fresh_states() -> Vec<(String, ParamState)> {
        vec![("w".to_string(), ParamState::new(ParamKind::Weight, &[4]))]
    }

    #[test]
    fn ring_matches_direct_sum_with_wrapping() {
        for n in [2usize, 3, 4, 7] {
            let mut bufs: Vec<Vec<i32>> = (0..n)
                .map(|i| {
                    vec![
                        i as i32 + 1,
                        i32::MAX - i as i32,
                        i32::MIN + 17 * i as i32,
                        -(i as i32) * 1_000_003,
                        42,
                    ]
                })
                .collect();
            let mut direct = vec![0i32; 5];
            for b in &bufs {
                for (d, &v) in direct.iter_mut().zip(b) {
                    *d = d.wrapping_add(v);
                }
            }
            let stats = ring_all_reduce(&mut bufs);
            assert_eq!(stats.steps, 2 * (n - 1));
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(*b, direct, "instance {i} diverged at n={n}");
            }
        }
    }

    #[test]
    fn ring_handles_fewer_elements_than_instances() {
        let mut bufs: Vec<Vec<i32>> =
            (0..5).map(|i| vec![i as i32, 10 + i as i32]).collect();
        let stats = ring_all_reduce(&mut bufs);
        assert_eq!(stats.steps, 8);
        for b in &bufs {
            assert_eq!(*b, vec![10, 60]);
        }
    }

    #[test]
    fn ring_single_instance_is_noop() {
        let mut bufs = vec![vec![1, 2, 3]];
        let stats = ring_all_reduce(&mut bufs);
        assert_eq!(stats.steps, 0);
        assert_eq!(stats.total_words, 0);
        assert_eq!(bufs[0], vec![1, 2, 3]);
    }

    #[test]
    fn cluster_bit_identical_to_inner_engine() {
        let batch = samples(10);
        let mut seq = fresh_states();
        let (loss_seq, _) =
            engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        for instances in [1usize, 2, 3, 4, 10] {
            let mut cl = fresh_states();
            let (loss_cl, rep) =
                run_batch_cluster(&batch, instances, 1, &mut cl, &step)
                    .unwrap();
            assert_eq!(loss_cl, loss_seq, "{instances} instances");
            assert_eq!(cl[0].1.grad_acc, seq[0].1.grad_acc,
                       "accumulators diverged at {instances} instances");
            assert_eq!(cl[0].1.count, seq[0].1.count);
            assert_eq!(rep.instances, instances);
            assert_eq!(rep.images, 10);
            assert_eq!(rep.ring_steps, 2 * (instances - 1));
        }
    }

    #[test]
    fn cluster_composes_with_inner_workers() {
        let batch = samples(12);
        let mut seq = fresh_states();
        engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        let mut cl = fresh_states();
        let (_, rep) =
            run_batch_cluster(&batch, 3, 2, &mut cl, &step).unwrap();
        assert_eq!(rep.instances, 3);
        assert_eq!(rep.shard_sizes, vec![4, 4, 4]);
        assert_eq!(cl[0].1.grad_acc, seq[0].1.grad_acc);
        assert_eq!(cl[0].1.count, seq[0].1.count);
    }

    #[test]
    fn idle_instances_still_join_the_ring() {
        // 16 deployed instances, 3 images: 3 shards of work, but the
        // full 16-member ring runs (idle members add zero gradients)
        // and the result stays bit-identical to the sequential sum
        let batch = samples(3);
        let mut seq = fresh_states();
        engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        let mut cl = fresh_states();
        let (_, rep) =
            run_batch_cluster(&batch, 16, 1, &mut cl, &step).unwrap();
        assert_eq!(rep.instances, 16);
        assert_eq!(rep.shard_sizes, vec![1, 1, 1]);
        assert_eq!(rep.ring_steps, 30); // 2 * (16 - 1)
        assert_eq!(cl[0].1.grad_acc, seq[0].1.grad_acc);
        assert_eq!(cl[0].1.count, 3);
    }

    #[test]
    fn hier_collective_is_bit_identical_through_the_engine() {
        use crate::engine::collective::HierCollective;
        let batch = samples(10);
        let mut seq = fresh_states();
        engine::run_batch(&batch, 1, &mut seq, &step).unwrap();
        let mut cl = fresh_states();
        let (_, rep) = run_batch_cluster_with(&batch, 4, 1, &mut cl,
                                              &step,
                                              &HierCollective { group: 2 })
            .unwrap();
        // 2*(G-1) + 2*(N/G-1) = 4 steps vs the flat ring's 6
        assert_eq!(rep.ring_steps, 4);
        assert_eq!(cl[0].1.grad_acc, seq[0].1.grad_acc);
        assert_eq!(cl[0].1.count, seq[0].1.count);
    }

    #[test]
    fn empty_batch_is_an_error() {
        let mut st = fresh_states();
        let err = run_batch_cluster(&[], 4, 1, &mut st, &step)
            .unwrap_err();
        assert!(format!("{err:#}").contains("empty"));
    }

    #[test]
    fn instance_errors_leave_states_untouched() {
        let batch = samples(8);
        let failing = |s: &Sample, sc: &mut Scratch| -> Result<StepOut> {
            if s.label == 2 {
                bail!("injected failure");
            }
            step(s, sc)
        };
        let mut st = fresh_states();
        let err = run_batch_cluster(&batch, 4, 1, &mut st, &failing)
            .unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
        assert!(st[0].1.grad_acc.data().iter().all(|&v| v == 0),
                "accumulators polluted by a failed cluster batch");
        assert_eq!(st[0].1.count, 0);
    }

    #[test]
    fn ring_words_reflect_traffic() {
        let batch = samples(8);
        let mut st = fresh_states();
        let (_, rep) =
            run_batch_cluster(&batch, 4, 1, &mut st, &step).unwrap();
        // 4 words over 4 instances: every step moves 4 words across the
        // ring -> 6 steps * 4 words = 24 words in total
        assert_eq!(rep.ring_steps, 6);
        assert_eq!(rep.ring_words, 24);
    }
}
