//! Batch-parallel training engine: shards a batch across worker threads
//! and merges per-shard gradient accumulators deterministically.
//!
//! The paper trains with gradient accumulation — per image, FP/BP/WU
//! produce weight gradients that are summed into DRAM-resident i32
//! accumulators, and the weight-update unit runs once per batch (§III-E,
//! Fig. 7).  Nothing inside a batch depends on any other image, so the
//! batch dimension is embarrassingly parallel: the FPGA-CNN literature
//! calls this batch-level parallelism, the standard throughput lever
//! that layer-level tiling alone cannot provide (one accelerator
//! instance per shard; arXiv:2505.13461 §IV).
//!
//! # Sharding / merge contract
//!
//! - The batch is split into **contiguous** shards in sample order,
//!   sizes differing by at most one ([`shard_sizes`]).
//! - Each shard runs the per-image step on its own OS thread with
//!   **thread-local** accumulators forked from the trainer's
//!   ([`ParamState::fork_shard`]) — workers never contend on shared
//!   state.
//! - Shard accumulators merge back in **fixed index order** (shard 0
//!   first).  Because accumulation is wrapping i32 addition (associative
//!   and commutative mod 2^32), the merged accumulator — and therefore
//!   every parameter after `end_batch` — is **bit-identical** to the
//!   sequential path at any worker count.  Loss totals are summed in
//!   i64, which is exact.
//!
//! The step function is pluggable (`Fn(&Sample, &mut Scratch) ->
//! Result<StepOut> + Sync`): the coordinator plugs in the golden model
//! today, and any thread-safe runtime step can slot in without
//! touching the engine.  Each shard owns one [`Scratch`] workspace for
//! its whole slice, so per-image buffer allocations (padded conv
//! planes, flipped BP kernels) amortize across the shard — scratch
//! contents never influence results (bit-identity is asserted against
//! scratch-free reference kernels in `tests/kernels.rs`), so sharding
//! stays deterministic.
//!
//! One level up, [`cluster`] shards a batch across accelerator
//! *instances* (data parallelism between devices rather than threads)
//! and merges per-instance accumulators through a [`collective`]
//! topology (flat ring or hierarchical group reduce) — same
//! bit-identity contract, cluster-sized.
//!
//! Both levels execute on the persistent worker [`pool`]: per-shard
//! scratch workspaces, forked accumulators, and flat collective
//! staging buffers are allocated once and reused across batches
//! (see `pool`'s module docs for the reuse contract).  The free
//! functions here remain as transient-pool wrappers.

pub mod cluster;
pub mod collective;
pub mod pool;

use anyhow::Result;

use crate::data::Sample;
use crate::nn::scratch::Scratch;
use crate::nn::sgd::ParamState;
use crate::nn::tensor::Tensor;

/// One image's step result: fixed-point loss plus weight/bias gradients
/// in the network's canonical `param_order`.
pub struct StepOut {
    pub loss: i32,
    pub grads: Vec<Tensor>,
}

/// What the engine observed while running one batch.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Shards actually used (≤ requested workers, ≥ 1).
    pub workers: usize,
    pub images: usize,
    /// Contiguous shard sizes, in shard index order.
    pub shard_sizes: Vec<usize>,
    /// Wall-clock of the sharded section (fork -> join -> merge).
    pub wall_seconds: f64,
}

/// Deterministic contiguous shard sizes: `n` images over at most
/// `workers` shards, the first `n % shards` one image larger.  Never
/// produces an empty shard; returns an empty vec only for `n == 0`.
pub fn shard_sizes(n: usize, workers: usize) -> Vec<usize> {
    let w = workers.max(1).min(n);
    if w == 0 {
        return Vec::new();
    }
    let base = n / w;
    let extra = n % w;
    (0..w).map(|i| base + usize::from(i < extra)).collect()
}

/// Run one batch through `step`, sharded across up to `workers` threads,
/// accumulating into `states` (name, accumulator) pairs whose order must
/// match the gradient order `step` emits.  Returns the exact i64 loss
/// sum and an [`EngineReport`].
///
/// `workers == 1` (or a single-image batch) runs inline on the calling
/// thread through the same fork/merge machinery, so the two paths cannot
/// drift.
///
/// This is the transient entry point: it builds a throwaway
/// [`pool::WorkerPool`] per call.  Long-lived callers (the trainer's
/// batch loop) hold a persistent pool instead so forks and scratch
/// workspaces are allocated once and reused across batches — both
/// paths run the identical shard/merge walk, so results are
/// bit-identical.
pub fn run_batch<F>(samples: &[Sample], workers: usize,
                    states: &mut [(String, ParamState)], step: &F)
                    -> Result<(i64, EngineReport)>
where
    F: Fn(&Sample, &mut Scratch) -> Result<StepOut> + Sync,
{
    pool::WorkerPool::new().run_batch(samples, workers, states, step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::sgd::ParamKind;

    fn samples(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample {
                // adversarial payloads: large magnitudes force wrapping
                image: Tensor::from_vec(
                    &[4],
                    vec![
                        i as i32 + 1,
                        -(i as i32) - 1,
                        i32::MAX - i as i32,
                        i32::MIN + i as i32,
                    ],
                ),
                label: i % 3,
            })
            .collect()
    }

    /// Step under test: gradient = the image itself, loss = label.
    fn step(s: &Sample, _: &mut Scratch) -> Result<StepOut> {
        Ok(StepOut { loss: s.label as i32, grads: vec![s.image.clone()] })
    }

    fn fresh_states() -> Vec<(String, ParamState)> {
        vec![("w".to_string(), ParamState::new(ParamKind::Weight, &[4]))]
    }

    #[test]
    fn shard_sizes_partition_evenly() {
        assert_eq!(shard_sizes(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(shard_sizes(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_sizes(3, 8), vec![1, 1, 1]);
        assert_eq!(shard_sizes(5, 1), vec![5]);
        assert_eq!(shard_sizes(0, 4), Vec::<usize>::new());
        for (n, w) in [(17, 5), (40, 3), (1, 1), (9, 9)] {
            let s = shard_sizes(n, w);
            assert_eq!(s.iter().sum::<usize>(), n);
            assert!(s.iter().all(|&x| x > 0));
            let (mn, mx) =
                (s.iter().min().unwrap(), s.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced: {s:?}");
        }
    }

    #[test]
    fn sharded_merge_is_bit_identical_to_sequential() {
        let batch = samples(10);
        let mut seq = fresh_states();
        let (loss_seq, r1) =
            run_batch(&batch, 1, &mut seq, &step).unwrap();
        assert_eq!(r1.workers, 1);
        for workers in [2, 3, 4, 10] {
            let mut par = fresh_states();
            let (loss_par, rep) =
                run_batch(&batch, workers, &mut par, &step).unwrap();
            assert_eq!(loss_par, loss_seq);
            assert_eq!(rep.workers, workers.min(10));
            assert_eq!(rep.images, 10);
            assert_eq!(
                par[0].1.grad_acc, seq[0].1.grad_acc,
                "accumulators diverged at {workers} workers"
            );
            assert_eq!(par[0].1.count, seq[0].1.count);
        }
    }

    #[test]
    fn empty_batch_is_an_error() {
        let mut st = fresh_states();
        let err = run_batch(&[], 4, &mut st, &step).unwrap_err();
        assert!(format!("{err:#}").contains("empty"));
    }

    #[test]
    fn step_errors_propagate_from_any_shard() {
        let batch = samples(8);
        let failing = |s: &Sample, sc: &mut Scratch| -> Result<StepOut> {
            if s.label == 2 {
                bail!("injected failure");
            }
            step(s, sc)
        };
        let mut st = fresh_states();
        let err = run_batch(&batch, 4, &mut st, &failing).unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
        // all-or-nothing: no shard merged, regardless of which failed
        assert!(st[0].1.grad_acc.data().iter().all(|&v| v == 0),
                "accumulators polluted by a failed batch");
        assert_eq!(st[0].1.count, 0);
    }

    #[test]
    fn gradient_arity_mismatch_is_an_error() {
        let batch = samples(4);
        let bad = |_: &Sample, _: &mut Scratch| -> Result<StepOut> {
            Ok(StepOut { loss: 0, grads: Vec::new() })
        };
        let mut st = fresh_states();
        let err = run_batch(&batch, 2, &mut st, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("gradients"));
    }
}
