//! Collective-communication topologies for the cluster engine's
//! gradient all-reduce — the seam that lets the compiler choose *how*
//! N accelerator instances merge their WU accumulators without
//! touching *what* they merge.
//!
//! Two implementations:
//!
//! - [`RingCollective`] — the flat reduce-scatter + all-gather ring,
//!   `2*(N-1)` steps (the paper's small-cluster shape; delegates to
//!   [`super::cluster::ring_all_reduce`]).
//! - [`HierCollective`] — a hierarchical group reduce for large N:
//!   intra-group ring reduce-scatter (G-1 steps), an inter-group ring
//!   all-reduce run concurrently by the G slice owners (2*(N/G-1)
//!   steps), then an intra-group all-gather (G-1 steps) — `2*(G-1) +
//!   2*(N/G-1)` steps in total, vs the flat ring's `2*(N-1)`.
//!
//! # Why every topology is bit-identical
//!
//! The merge operation is wrapping i32 addition — associative and
//! commutative mod 2^32 — so *any* reduction tree over the same
//! per-instance addends produces the identical bits.  What each
//! implementation must still guarantee is that its traffic pattern is
//! a pure function of `(N, len)` (never of thread scheduling), which
//! both are: all loops below walk fixed index formulas.  The
//! bit-identity of hierarchical vs flat vs direct summation is
//! asserted across group shapes in the unit tests, and end-to-end at
//! 64 instances in `rust/tests/cluster.rs`.

use super::cluster::ring_all_reduce;

/// One step of a collective's communication plan, as consumed by the
/// compiler (schedule emission) and the simulator (link costing).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveStep {
    /// Step label, unique within the plan (becomes the schedule step's
    /// layer name): `ring_rs{s}`/`ring_ag{s}` for the flat ring,
    /// `hier_rs{s}`/`hier_xrs{s}`/`hier_xag{s}`/`hier_ag{s}` for the
    /// hierarchical phases.
    pub label: String,
    /// i32 words each participating link carries in this step.
    pub chunk_words: u64,
    /// How many concurrent messages share one physical link during
    /// this step.  Intra-group and flat-ring steps use dedicated
    /// neighbor links (1); inter-group steps cross a shared trunk
    /// carrying all G slice-rings at once (G).
    pub link_share: u64,
}

/// What a host-side all-reduce actually moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStats {
    /// Plan steps executed (0 for a single instance).
    pub steps: usize,
    /// i32 words moved across all links in total.
    pub total_words: u64,
}

/// A gradient all-reduce topology: produces the communication plan the
/// compiler schedules and prices, and performs the host-side merge the
/// cluster engine runs.  Implementations must keep the merge a pure
/// function of `(N, len)` so the bit-identity contract holds.
pub trait Collective: Send + Sync {
    /// Topology name as accepted by `--topology` / reported in tables.
    fn name(&self) -> &'static str;

    /// The communication plan for `n` instances reducing `words` i32
    /// words.  Empty when `n <= 1`.
    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep>;

    /// In-place all-reduce over per-instance flat gradient buffers:
    /// after the call every buffer holds the identical element-wise
    /// wrapping-i32 sum of all inputs.
    fn all_reduce(&self, bufs: &mut [Vec<i32>]) -> CollectiveStats;
}

/// The flat reduce-scatter + all-gather ring (`2*(N-1)` steps).
#[derive(Debug, Clone, Copy, Default)]
pub struct RingCollective;

impl Collective for RingCollective {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep> {
        if n <= 1 {
            return Vec::new();
        }
        let chunk = words.div_ceil(n as u64);
        let mut plan = Vec::with_capacity(2 * (n - 1));
        for s in 0..n - 1 {
            plan.push(CollectiveStep {
                label: format!("ring_rs{s}"),
                chunk_words: chunk,
                link_share: 1,
            });
        }
        for s in 0..n - 1 {
            plan.push(CollectiveStep {
                label: format!("ring_ag{s}"),
                chunk_words: chunk,
                link_share: 1,
            });
        }
        plan
    }

    fn all_reduce(&self, bufs: &mut [Vec<i32>]) -> CollectiveStats {
        let stats = ring_all_reduce(bufs);
        CollectiveStats { steps: stats.steps,
                          total_words: stats.total_words }
    }
}

/// Hierarchical group reduce: N instances in N/G groups of G members
/// each (group q owns global indices `[q*G, (q+1)*G)`).
///
/// 1. **Intra-group reduce-scatter** over G slices of the full vector
///    (G-1 steps): after it, local member `owner(c) = (c+G-1) % G` of
///    every group holds its group's sum of slice c.
/// 2. **Inter-group ring all-reduce** (2*(N/G-1) steps): for each
///    slice c the N/G owners `q*G + owner(c)` run a flat ring over
///    sub-chunks of slice c; all G slice-rings proceed concurrently
///    across the shared inter-group trunk (`link_share = G`).
/// 3. **Intra-group all-gather** (G-1 steps): each globally reduced
///    slice circulates around its group until every member holds all
///    of them.
///
/// Requires `1 < group < n` and `group | n`; the compiler's chooser
/// ([`crate::compiler::choose_collective`]) falls back to the flat
/// ring when no such group size exists (N prime or N <= 3).
#[derive(Debug, Clone, Copy)]
pub struct HierCollective {
    /// Group size G.
    pub group: usize,
}

impl HierCollective {
    /// Panics unless `1 < group < n` and `group` divides `n` — the
    /// shape invariant both `steps` and `all_reduce` rely on.
    fn check(&self, n: usize) {
        assert!(self.group > 1 && self.group < n
                    && n % self.group == 0,
                "hier collective: group {} does not partition {n}",
                self.group);
    }
}

impl Collective for HierCollective {
    fn name(&self) -> &'static str {
        "hier"
    }

    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep> {
        if n <= 1 {
            return Vec::new();
        }
        self.check(n);
        let g = self.group as u64;
        let m = (n / self.group) as u64;
        let slice = words.div_ceil(g);
        let sub = slice.div_ceil(m);
        let mut plan = Vec::new();
        for s in 0..self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_rs{s}"),
                chunk_words: slice,
                link_share: 1,
            });
        }
        for s in 0..n / self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_xrs{s}"),
                chunk_words: sub,
                link_share: g,
            });
        }
        for s in 0..n / self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_xag{s}"),
                chunk_words: sub,
                link_share: g,
            });
        }
        for s in 0..self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_ag{s}"),
                chunk_words: slice,
                link_share: 1,
            });
        }
        plan
    }

    fn all_reduce(&self, bufs: &mut [Vec<i32>]) -> CollectiveStats {
        let n = bufs.len();
        if n <= 1 {
            return CollectiveStats { steps: 0, total_words: 0 };
        }
        self.check(n);
        let g = self.group;
        let m = n / g;
        let len = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == len),
                "hier all_reduce: ragged buffers");
        // balanced slice ranges per intra-group slot
        let gb = |c: usize| c * len / g;
        let owner = |c: usize| (c + g - 1) % g;
        let mut words = 0u64;

        // phase 1: intra-group reduce-scatter (same index walk as the
        // flat ring, restricted to each group's G members)
        for s in 0..g - 1 {
            for q in 0..m {
                for c in 0..g {
                    let src = q * g + (c + s) % g;
                    let dst = q * g + (c + s + 1) % g;
                    let (lo, hi) = (gb(c), gb(c + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    for (d, &v) in
                        to[lo..hi].iter_mut().zip(&from[lo..hi])
                    {
                        *d = d.wrapping_add(v);
                    }
                    words += (hi - lo) as u64;
                }
            }
        }

        // phase 2: per slice c, the N/G owners ring-all-reduce slice c
        // over balanced sub-chunks (reduce-scatter then all-gather)
        for c in 0..g {
            let (lo, hi) = (gb(c), gb(c + 1));
            let span = hi - lo;
            let sb = |k: usize| lo + k * span / m;
            let member = |q: usize| q * g + owner(c);
            for s in 0..m - 1 {
                for k in 0..m {
                    let src = member((k + s) % m);
                    let dst = member((k + s + 1) % m);
                    let (slo, shi) = (sb(k), sb(k + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    for (d, &v) in
                        to[slo..shi].iter_mut().zip(&from[slo..shi])
                    {
                        *d = d.wrapping_add(v);
                    }
                    words += (shi - slo) as u64;
                }
            }
            for s in 0..m - 1 {
                for k in 0..m {
                    let src = member((k + m - 1 + s) % m);
                    let dst = member(((k + m - 1 + s) % m + 1) % m);
                    let (slo, shi) = (sb(k), sb(k + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    to[slo..shi].copy_from_slice(&from[slo..shi]);
                    words += (shi - slo) as u64;
                }
            }
        }

        // phase 3: intra-group all-gather — each reduced slice
        // circulates one hop per step from its owner
        for s in 0..g - 1 {
            for q in 0..m {
                for c in 0..g {
                    let src = q * g + (owner(c) + s) % g;
                    let dst = q * g + ((owner(c) + s) % g + 1) % g;
                    let (lo, hi) = (gb(c), gb(c + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    to[lo..hi].copy_from_slice(&from[lo..hi]);
                    words += (hi - lo) as u64;
                }
            }
        }

        CollectiveStats {
            steps: 2 * (g - 1) + 2 * (m - 1),
            total_words: words,
        }
    }
}

/// Split-borrow two distinct members: shared `src`, mutable `dst`
/// (same shape as the cluster module's helper, local so the hier walk
/// has no cross-module borrow gymnastics).
fn pair_mut(bufs: &mut [Vec<i32>], src: usize, dst: usize)
            -> (&[i32], &mut Vec<i32>) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (head, tail) = bufs.split_at_mut(dst);
        (head[src].as_slice(), &mut tail[0])
    } else {
        let (head, tail) = bufs.split_at_mut(src);
        (tail[0].as_slice(), &mut head[dst])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adversarial_bufs(n: usize, len: usize) -> Vec<Vec<i32>> {
        (0..n)
            .map(|i| {
                (0..len)
                    .map(|j| match j % 4 {
                        0 => i as i32 + j as i32 + 1,
                        1 => i32::MAX - (i * 31 + j) as i32,
                        2 => i32::MIN + (i * 17 + j) as i32,
                        _ => -((i * 1_000_003 + j) as i32),
                    })
                    .collect()
            })
            .collect()
    }

    fn direct_sum(bufs: &[Vec<i32>]) -> Vec<i32> {
        let mut out = vec![0i32; bufs[0].len()];
        for b in bufs {
            for (d, &v) in out.iter_mut().zip(b) {
                *d = d.wrapping_add(v);
            }
        }
        out
    }

    #[test]
    fn ring_collective_matches_direct_sum() {
        for n in [2usize, 3, 4, 7, 16] {
            let mut bufs = adversarial_bufs(n, 37);
            let want = direct_sum(&bufs);
            let stats = RingCollective.all_reduce(&mut bufs);
            assert_eq!(stats.steps, 2 * (n - 1));
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(*b, want, "ring instance {i} diverged, n={n}");
            }
        }
    }

    #[test]
    fn hier_matches_direct_sum_across_group_shapes() {
        // every (n, g) with g a proper divisor, over an awkward length
        // that leaves ragged slices and sub-chunks
        for (n, g) in [(4usize, 2usize), (6, 2), (6, 3), (8, 2), (8, 4),
                       (9, 3), (12, 3), (12, 4), (16, 4), (64, 8)] {
            let mut bufs = adversarial_bufs(n, 53);
            let want = direct_sum(&bufs);
            let hier = HierCollective { group: g };
            let stats = hier.all_reduce(&mut bufs);
            assert_eq!(stats.steps, 2 * (g - 1) + 2 * (n / g - 1),
                       "n={n} g={g}");
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(*b, want,
                           "hier instance {i} diverged, n={n} g={g}");
            }
        }
    }

    #[test]
    fn hier_matches_ring_bit_for_bit() {
        // the two topologies reduce the same inputs to the same bits
        let mut ring = adversarial_bufs(16, 41);
        let mut hier = ring.clone();
        RingCollective.all_reduce(&mut ring);
        HierCollective { group: 4 }.all_reduce(&mut hier);
        assert_eq!(ring, hier);
    }

    #[test]
    fn hier_handles_fewer_elements_than_instances() {
        let mut bufs = adversarial_bufs(8, 3);
        let want = direct_sum(&bufs);
        HierCollective { group: 4 }.all_reduce(&mut bufs);
        for b in &bufs {
            assert_eq!(*b, want);
        }
    }

    #[test]
    fn step_counts_and_labels() {
        let plan = RingCollective.steps(4, 100);
        assert_eq!(plan.len(), 6);
        assert_eq!(plan[0].label, "ring_rs0");
        assert_eq!(plan[3].label, "ring_ag0");
        assert!(plan.iter().all(|s| s.chunk_words == 25
                                    && s.link_share == 1));

        let plan = HierCollective { group: 4 }.steps(64, 1 << 20);
        // 2*(4-1) + 2*(16-1) = 36 steps vs the flat ring's 126
        assert_eq!(plan.len(), 36);
        assert_eq!(plan[0].label, "hier_rs0");
        assert_eq!(plan[3].label, "hier_xrs0");
        assert_eq!(plan[18].label, "hier_xag0");
        assert_eq!(plan[33].label, "hier_ag0");
        // intra steps carry words/G on dedicated links; inter steps
        // carry words/N each but share the trunk G ways
        assert_eq!(plan[0].chunk_words, (1u64 << 20) / 4);
        assert_eq!(plan[0].link_share, 1);
        assert_eq!(plan[3].chunk_words, (1u64 << 20) / 64);
        assert_eq!(plan[3].link_share, 4);
    }

    #[test]
    fn single_instance_plans_are_empty() {
        assert!(RingCollective.steps(1, 100).is_empty());
        assert!(HierCollective { group: 2 }.steps(1, 100).is_empty());
        let mut one = vec![vec![1, 2, 3]];
        let st = HierCollective { group: 2 }.all_reduce(&mut one);
        assert_eq!(st.steps, 0);
        assert_eq!(one[0], vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "does not partition")]
    fn hier_rejects_non_dividing_group() {
        HierCollective { group: 3 }.steps(8, 100);
    }
}
