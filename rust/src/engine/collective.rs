//! Collective-communication topologies for the cluster engine's
//! gradient all-reduce — the seam that lets the compiler choose *how*
//! N accelerator instances merge their WU accumulators without
//! touching *what* they merge.
//!
//! Two implementations:
//!
//! - [`RingCollective`] — the flat reduce-scatter + all-gather ring,
//!   `2*(N-1)` steps (the paper's small-cluster shape; delegates to
//!   [`super::cluster::ring_all_reduce`]).
//! - [`HierCollective`] — a hierarchical group reduce for large N:
//!   intra-group ring reduce-scatter (G-1 steps), an inter-group ring
//!   all-reduce run concurrently by the G slice owners (2*(N/G-1)
//!   steps), then an intra-group all-gather (G-1 steps) — `2*(G-1) +
//!   2*(N/G-1)` steps in total, vs the flat ring's `2*(N-1)`.
//!
//! # Why every topology is bit-identical
//!
//! The merge operation is wrapping i32 addition — associative and
//! commutative mod 2^32 — so *any* reduction tree over the same
//! per-instance addends produces the identical bits.  What each
//! implementation must still guarantee is that its traffic pattern is
//! a pure function of `(N, len)` (never of thread scheduling), which
//! both are: all loops below walk fixed index formulas.  The
//! bit-identity of hierarchical vs flat vs direct summation is
//! asserted across group shapes in the unit tests, and end-to-end at
//! 64 instances in `rust/tests/cluster.rs`.
//!
//! # Bucketing ([`BucketPlan`])
//!
//! The pipelined cluster engine partitions the flat gradient vector
//! into contiguous *buckets* whose boundaries fall only at layer
//! parameter boundaries, walked in reverse-layer (BP) order — the
//! order in which the backward pass retires each layer's gradients.
//! Every topology reduces a bucket through [`Collective::
//! all_reduce_range`], the same fixed index walk restricted to
//! `[lo, hi)`; concatenating the per-bucket results is *exactly* the
//! monolithic reduce because each element is touched by exactly one
//! bucket and summed by the identical wrapping-i32 walk.

use super::cluster::ring_all_reduce_range;

/// One step of a collective's communication plan, as consumed by the
/// compiler (schedule emission) and the simulator (link costing).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveStep {
    /// Step label, unique within the plan (becomes the schedule step's
    /// layer name): `ring_rs{s}`/`ring_ag{s}` for the flat ring,
    /// `hier_rs{s}`/`hier_xrs{s}`/`hier_xag{s}`/`hier_ag{s}` for the
    /// hierarchical phases.
    pub label: String,
    /// i32 words each participating link carries in this step.
    pub chunk_words: u64,
    /// How many concurrent messages share one physical link during
    /// this step.  Intra-group and flat-ring steps use dedicated
    /// neighbor links (1); inter-group steps cross a shared trunk
    /// carrying all G slice-rings at once (G).
    pub link_share: u64,
}

/// What a host-side all-reduce actually moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStats {
    /// Plan steps executed (0 for a single instance).
    pub steps: usize,
    /// i32 words moved across all links in total.
    pub total_words: u64,
}

/// A gradient all-reduce topology: produces the communication plan the
/// compiler schedules and prices, and performs the host-side merge the
/// cluster engine runs.  Implementations must keep the merge a pure
/// function of `(N, len)` so the bit-identity contract holds.
pub trait Collective: Send + Sync {
    /// Topology name as accepted by `--topology` / reported in tables.
    fn name(&self) -> &'static str;

    /// The communication plan for `n` instances reducing `words` i32
    /// words.  Empty when `n <= 1`.
    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep>;

    /// In-place all-reduce restricted to the element range `[lo, hi)`
    /// of every buffer: after the call the range holds the identical
    /// element-wise wrapping-i32 sum of all inputs' ranges; elements
    /// outside the range are untouched.  This is the bucket-reduce
    /// primitive — the full-vector [`Collective::all_reduce`] is just
    /// the `[0, len)` range.
    fn all_reduce_range(&self, bufs: &mut [Vec<i32>],
                        lo: usize, hi: usize) -> CollectiveStats;

    /// In-place all-reduce over per-instance flat gradient buffers:
    /// after the call every buffer holds the identical element-wise
    /// wrapping-i32 sum of all inputs.
    fn all_reduce(&self, bufs: &mut [Vec<i32>]) -> CollectiveStats {
        let hi = bufs.first().map_or(0, |b| b.len());
        self.all_reduce_range(bufs, 0, hi)
    }
}

/// The flat reduce-scatter + all-gather ring (`2*(N-1)` steps).
#[derive(Debug, Clone, Copy, Default)]
pub struct RingCollective;

impl Collective for RingCollective {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep> {
        if n <= 1 {
            return Vec::new();
        }
        let chunk = words.div_ceil(n as u64);
        let mut plan = Vec::with_capacity(2 * (n - 1));
        for s in 0..n - 1 {
            plan.push(CollectiveStep {
                label: format!("ring_rs{s}"),
                chunk_words: chunk,
                link_share: 1,
            });
        }
        for s in 0..n - 1 {
            plan.push(CollectiveStep {
                label: format!("ring_ag{s}"),
                chunk_words: chunk,
                link_share: 1,
            });
        }
        plan
    }

    fn all_reduce_range(&self, bufs: &mut [Vec<i32>],
                        lo: usize, hi: usize) -> CollectiveStats {
        let stats = ring_all_reduce_range(bufs, lo, hi);
        CollectiveStats { steps: stats.steps,
                          total_words: stats.total_words }
    }
}

/// Hierarchical group reduce: N instances in N/G groups of G members
/// each (group q owns global indices `[q*G, (q+1)*G)`).
///
/// 1. **Intra-group reduce-scatter** over G slices of the full vector
///    (G-1 steps): after it, local member `owner(c) = (c+G-1) % G` of
///    every group holds its group's sum of slice c.
/// 2. **Inter-group ring all-reduce** (2*(N/G-1) steps): for each
///    slice c the N/G owners `q*G + owner(c)` run a flat ring over
///    sub-chunks of slice c; all G slice-rings proceed concurrently
///    across the shared inter-group trunk (`link_share = G`).
/// 3. **Intra-group all-gather** (G-1 steps): each globally reduced
///    slice circulates around its group until every member holds all
///    of them.
///
/// Requires `1 < group < n` and `group | n`; the compiler's chooser
/// ([`crate::compiler::choose_collective`]) falls back to the flat
/// ring when no such group size exists (N prime or N <= 3).
#[derive(Debug, Clone, Copy)]
pub struct HierCollective {
    /// Group size G.
    pub group: usize,
}

impl HierCollective {
    /// Panics unless `1 < group < n` and `group` divides `n` — the
    /// shape invariant both `steps` and `all_reduce` rely on.
    fn check(&self, n: usize) {
        assert!(self.group > 1 && self.group < n
                    && n % self.group == 0,
                "hier collective: group {} does not partition {n}",
                self.group);
    }
}

impl Collective for HierCollective {
    fn name(&self) -> &'static str {
        "hier"
    }

    fn steps(&self, n: usize, words: u64) -> Vec<CollectiveStep> {
        if n <= 1 {
            return Vec::new();
        }
        self.check(n);
        let g = self.group as u64;
        let m = (n / self.group) as u64;
        let slice = words.div_ceil(g);
        let sub = slice.div_ceil(m);
        let mut plan = Vec::new();
        for s in 0..self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_rs{s}"),
                chunk_words: slice,
                link_share: 1,
            });
        }
        for s in 0..n / self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_xrs{s}"),
                chunk_words: sub,
                link_share: g,
            });
        }
        for s in 0..n / self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_xag{s}"),
                chunk_words: sub,
                link_share: g,
            });
        }
        for s in 0..self.group - 1 {
            plan.push(CollectiveStep {
                label: format!("hier_ag{s}"),
                chunk_words: slice,
                link_share: 1,
            });
        }
        plan
    }

    fn all_reduce_range(&self, bufs: &mut [Vec<i32>],
                        range_lo: usize, range_hi: usize)
                        -> CollectiveStats {
        let n = bufs.len();
        if n <= 1 {
            return CollectiveStats { steps: 0, total_words: 0 };
        }
        self.check(n);
        let g = self.group;
        let m = n / g;
        let len = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == len),
                "hier all_reduce: ragged buffers");
        assert!(range_lo <= range_hi && range_hi <= len,
                "hier all_reduce: range [{range_lo}, {range_hi}) \
                 outside buffers of len {len}");
        let range_span = range_hi - range_lo;
        // balanced slice ranges per intra-group slot, within the range
        let gb = |c: usize| range_lo + c * range_span / g;
        let owner = |c: usize| (c + g - 1) % g;
        let mut words = 0u64;

        // phase 1: intra-group reduce-scatter (same index walk as the
        // flat ring, restricted to each group's G members)
        for s in 0..g - 1 {
            for q in 0..m {
                for c in 0..g {
                    let src = q * g + (c + s) % g;
                    let dst = q * g + (c + s + 1) % g;
                    let (lo, hi) = (gb(c), gb(c + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    for (d, &v) in
                        to[lo..hi].iter_mut().zip(&from[lo..hi])
                    {
                        *d = d.wrapping_add(v);
                    }
                    words += (hi - lo) as u64;
                }
            }
        }

        // phase 2: per slice c, the N/G owners ring-all-reduce slice c
        // over balanced sub-chunks (reduce-scatter then all-gather)
        for c in 0..g {
            let (lo, hi) = (gb(c), gb(c + 1));
            let span = hi - lo;
            let sb = |k: usize| lo + k * span / m;
            let member = |q: usize| q * g + owner(c);
            for s in 0..m - 1 {
                for k in 0..m {
                    let src = member((k + s) % m);
                    let dst = member((k + s + 1) % m);
                    let (slo, shi) = (sb(k), sb(k + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    for (d, &v) in
                        to[slo..shi].iter_mut().zip(&from[slo..shi])
                    {
                        *d = d.wrapping_add(v);
                    }
                    words += (shi - slo) as u64;
                }
            }
            for s in 0..m - 1 {
                for k in 0..m {
                    let src = member((k + m - 1 + s) % m);
                    let dst = member(((k + m - 1 + s) % m + 1) % m);
                    let (slo, shi) = (sb(k), sb(k + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    to[slo..shi].copy_from_slice(&from[slo..shi]);
                    words += (shi - slo) as u64;
                }
            }
        }

        // phase 3: intra-group all-gather — each reduced slice
        // circulates one hop per step from its owner
        for s in 0..g - 1 {
            for q in 0..m {
                for c in 0..g {
                    let src = q * g + (owner(c) + s) % g;
                    let dst = q * g + ((owner(c) + s) % g + 1) % g;
                    let (lo, hi) = (gb(c), gb(c + 1));
                    let (from, to) = pair_mut(bufs, src, dst);
                    to[lo..hi].copy_from_slice(&from[lo..hi]);
                    words += (hi - lo) as u64;
                }
            }
        }

        CollectiveStats {
            steps: 2 * (g - 1) + 2 * (m - 1),
            total_words: words,
        }
    }
}

/// One contiguous gradient bucket: an element range of the flat
/// accumulator vector plus the layer whose backward-pass retirement
/// makes the whole range final.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Bucket label in reduce order: `b0`, `b1`, ... (`b0` covers the
    /// tail of the vector — the first layers BP retires).
    pub label: String,
    /// First element (i32 word) of the bucket, inclusive.
    pub lo: usize,
    /// One past the last element, exclusive.
    pub hi: usize,
    /// Layer name after whose last per-image schedule step every
    /// segment in the bucket is final.  Segments are laid out in
    /// forward-layer order and BP retires layers in reverse, so this
    /// is the layer of the bucket's front-most (lowest-offset)
    /// segment — the last of its layers to retire.
    pub eligible_after: String,
}

impl Bucket {
    /// i32 words the bucket covers.
    pub fn words(&self) -> u64 {
        (self.hi - self.lo) as u64
    }
}

/// A size-capped partition of the flat gradient vector into contiguous
/// buckets with boundaries only at segment (per-layer parameter /
/// per-stat tensor) boundaries, listed in reverse-layer reduce order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BucketPlan {
    /// Buckets in the order they are reduced (tail of the vector
    /// first, matching BP's reverse-layer retirement order).
    pub buckets: Vec<Bucket>,
}

impl BucketPlan {
    /// Partition `segments` — `(name, words)` pairs in flat-vector
    /// (forward accumulation) order, as produced by
    /// `Network::ring_segments` — into buckets of at most `cap_words`
    /// each, packing greedily from the *tail* of the vector so bucket
    /// `b0` holds the layers BP retires first.  A single segment
    /// larger than the cap becomes its own (over-cap) bucket; a cap of
    /// `0` means "no cap" and yields one bucket covering everything
    /// (the degenerate monolithic plan, eligible only once BP fully
    /// drains).
    pub fn build(segments: &[(String, usize)], cap_words: usize)
                 -> BucketPlan {
        let total: usize = segments.iter().map(|s| s.1).sum();
        let mut buckets = Vec::new();
        if total == 0 {
            return BucketPlan { buckets };
        }
        let layer_of = |name: &str| {
            name.split_once('_')
                .map(|(_, l)| l.to_string())
                .unwrap_or_else(|| name.to_string())
        };
        let mut hi = total;
        let mut lo = total;
        // front-most segment currently inside the open bucket
        let mut front: Option<&str> = None;
        for (name, words) in segments.iter().rev() {
            let over = cap_words > 0
                && lo < hi
                && (hi - lo) + words > cap_words;
            if over {
                buckets.push((lo, hi, front.unwrap().to_string()));
                hi = lo;
            }
            lo -= words;
            front = Some(name.as_str());
        }
        buckets.push((0, hi, front.unwrap().to_string()));
        let buckets = buckets
            .into_iter()
            .enumerate()
            .map(|(i, (lo, hi, seg))| Bucket {
                label: format!("b{i}"),
                lo,
                hi,
                eligible_after: layer_of(&seg),
            })
            .collect();
        BucketPlan { buckets }
    }

    /// Per-bucket word counts, in reduce order.
    pub fn bucket_words(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.words()).collect()
    }

    /// Total i32 words across all buckets.
    pub fn total_words(&self) -> u64 {
        self.buckets.iter().map(|b| b.words()).sum()
    }
}

/// Reduce every bucket of `plan` in order through `coll`: walked in
/// reverse-layer order so the host merge mirrors the schedule's
/// pipelined reduce.  Concatenating the per-bucket results is exactly
/// the monolithic [`Collective::all_reduce`] — each element belongs to
/// exactly one bucket and is summed by the identical wrapping walk.
pub fn all_reduce_bucketed(coll: &dyn Collective,
                           bufs: &mut [Vec<i32>],
                           plan: &BucketPlan) -> CollectiveStats {
    let mut steps = 0usize;
    let mut total_words = 0u64;
    for b in &plan.buckets {
        let st = coll.all_reduce_range(bufs, b.lo, b.hi);
        steps += st.steps;
        total_words += st.total_words;
    }
    CollectiveStats { steps, total_words }
}

/// Split-borrow two distinct members: shared `src`, mutable `dst`
/// (same shape as the cluster module's helper, local so the hier walk
/// has no cross-module borrow gymnastics).
fn pair_mut(bufs: &mut [Vec<i32>], src: usize, dst: usize)
            -> (&[i32], &mut Vec<i32>) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (head, tail) = bufs.split_at_mut(dst);
        (head[src].as_slice(), &mut tail[0])
    } else {
        let (head, tail) = bufs.split_at_mut(src);
        (tail[0].as_slice(), &mut head[dst])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adversarial_bufs(n: usize, len: usize) -> Vec<Vec<i32>> {
        (0..n)
            .map(|i| {
                (0..len)
                    .map(|j| match j % 4 {
                        0 => i as i32 + j as i32 + 1,
                        1 => i32::MAX - (i * 31 + j) as i32,
                        2 => i32::MIN + (i * 17 + j) as i32,
                        _ => -((i * 1_000_003 + j) as i32),
                    })
                    .collect()
            })
            .collect()
    }

    fn direct_sum(bufs: &[Vec<i32>]) -> Vec<i32> {
        let mut out = vec![0i32; bufs[0].len()];
        for b in bufs {
            for (d, &v) in out.iter_mut().zip(b) {
                *d = d.wrapping_add(v);
            }
        }
        out
    }

    #[test]
    fn ring_collective_matches_direct_sum() {
        for n in [2usize, 3, 4, 7, 16] {
            let mut bufs = adversarial_bufs(n, 37);
            let want = direct_sum(&bufs);
            let stats = RingCollective.all_reduce(&mut bufs);
            assert_eq!(stats.steps, 2 * (n - 1));
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(*b, want, "ring instance {i} diverged, n={n}");
            }
        }
    }

    #[test]
    fn hier_matches_direct_sum_across_group_shapes() {
        // every (n, g) with g a proper divisor, over an awkward length
        // that leaves ragged slices and sub-chunks
        for (n, g) in [(4usize, 2usize), (6, 2), (6, 3), (8, 2), (8, 4),
                       (9, 3), (12, 3), (12, 4), (16, 4), (64, 8)] {
            let mut bufs = adversarial_bufs(n, 53);
            let want = direct_sum(&bufs);
            let hier = HierCollective { group: g };
            let stats = hier.all_reduce(&mut bufs);
            assert_eq!(stats.steps, 2 * (g - 1) + 2 * (n / g - 1),
                       "n={n} g={g}");
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(*b, want,
                           "hier instance {i} diverged, n={n} g={g}");
            }
        }
    }

    #[test]
    fn hier_matches_ring_bit_for_bit() {
        // the two topologies reduce the same inputs to the same bits
        let mut ring = adversarial_bufs(16, 41);
        let mut hier = ring.clone();
        RingCollective.all_reduce(&mut ring);
        HierCollective { group: 4 }.all_reduce(&mut hier);
        assert_eq!(ring, hier);
    }

    #[test]
    fn hier_handles_fewer_elements_than_instances() {
        let mut bufs = adversarial_bufs(8, 3);
        let want = direct_sum(&bufs);
        HierCollective { group: 4 }.all_reduce(&mut bufs);
        for b in &bufs {
            assert_eq!(*b, want);
        }
    }

    #[test]
    fn step_counts_and_labels() {
        let plan = RingCollective.steps(4, 100);
        assert_eq!(plan.len(), 6);
        assert_eq!(plan[0].label, "ring_rs0");
        assert_eq!(plan[3].label, "ring_ag0");
        assert!(plan.iter().all(|s| s.chunk_words == 25
                                    && s.link_share == 1));

        let plan = HierCollective { group: 4 }.steps(64, 1 << 20);
        // 2*(4-1) + 2*(16-1) = 36 steps vs the flat ring's 126
        assert_eq!(plan.len(), 36);
        assert_eq!(plan[0].label, "hier_rs0");
        assert_eq!(plan[3].label, "hier_xrs0");
        assert_eq!(plan[18].label, "hier_xag0");
        assert_eq!(plan[33].label, "hier_ag0");
        // intra steps carry words/G on dedicated links; inter steps
        // carry words/N each but share the trunk G ways
        assert_eq!(plan[0].chunk_words, (1u64 << 20) / 4);
        assert_eq!(plan[0].link_share, 1);
        assert_eq!(plan[3].chunk_words, (1u64 << 20) / 64);
        assert_eq!(plan[3].link_share, 4);
    }

    #[test]
    fn single_instance_plans_are_empty() {
        assert!(RingCollective.steps(1, 100).is_empty());
        assert!(HierCollective { group: 2 }.steps(1, 100).is_empty());
        let mut one = vec![vec![1, 2, 3]];
        let st = HierCollective { group: 2 }.all_reduce(&mut one);
        assert_eq!(st.steps, 0);
        assert_eq!(one[0], vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "does not partition")]
    fn hier_rejects_non_dividing_group() {
        HierCollective { group: 3 }.steps(8, 100);
    }

    fn segs(v: &[(&str, usize)]) -> Vec<(String, usize)> {
        v.iter().map(|(n, w)| (n.to_string(), *w)).collect()
    }

    #[test]
    fn bucket_plan_packs_from_the_tail_at_segment_boundaries() {
        let segments = segs(&[("w_c1", 10), ("b_c1", 2),
                              ("w_c2", 20), ("b_c2", 4),
                              ("w_fc", 30), ("b_fc", 6)]);
        let plan = BucketPlan::build(&segments, 25);
        // tail-first packing at cap 25: b_fc alone (adding w_fc's 30
        // overflows), the over-cap w_fc alone, then {w_c2, b_c2} = 24
        // (adding b_c1 overflows), and the rest
        assert_eq!(plan.buckets.len(), 4);
        assert_eq!((plan.buckets[0].lo, plan.buckets[0].hi), (66, 72));
        assert_eq!(plan.buckets[0].label, "b0");
        assert_eq!(plan.buckets[0].eligible_after, "fc");
        assert_eq!((plan.buckets[1].lo, plan.buckets[1].hi), (36, 66));
        assert_eq!(plan.buckets[1].eligible_after, "fc");
        assert_eq!((plan.buckets[2].lo, plan.buckets[2].hi), (12, 36));
        assert_eq!(plan.buckets[2].eligible_after, "c2");
        assert_eq!((plan.buckets[3].lo, plan.buckets[3].hi), (0, 12));
        assert_eq!(plan.buckets[3].eligible_after, "c1");
        assert_eq!(plan.total_words(), 72);
        assert_eq!(plan.bucket_words(), vec![6, 30, 24, 12]);
    }

    #[test]
    fn bucket_plan_boundary_cases() {
        let segments = segs(&[("w_c1", 10), ("b_c1", 2),
                              ("w_fc", 30), ("b_fc", 6)]);
        // cap 0 = no cap: one bucket covering everything, eligible
        // only after the front-most layer retires
        let plan = BucketPlan::build(&segments, 0);
        assert_eq!(plan.buckets.len(), 1);
        assert_eq!((plan.buckets[0].lo, plan.buckets[0].hi), (0, 48));
        assert_eq!(plan.buckets[0].eligible_after, "c1");
        // cap smaller than the largest segment: the over-cap segment
        // forms its own bucket, boundaries never split a segment
        let plan = BucketPlan::build(&segments, 8);
        assert_eq!(plan.bucket_words(), vec![6, 30, 2, 10]);
        assert_eq!(plan.buckets[1].eligible_after, "fc");
        assert_eq!(plan.buckets[3].eligible_after, "c1");
        // huge cap: one bucket
        assert_eq!(BucketPlan::build(&segments, 1 << 20)
                       .buckets.len(), 1);
        // empty segment list: empty plan
        assert!(BucketPlan::build(&[], 64).buckets.is_empty());
    }

    #[test]
    fn bucketed_reduce_matches_monolithic_bit_for_bit() {
        // sweep bucket caps x topologies x N over adversarial data:
        // any partition of the index space must reproduce the
        // monolithic reduce exactly
        let segments = segs(&[("w_c1", 11), ("b_c1", 3),
                              ("w_c2", 17), ("b_c2", 5),
                              ("w_fc", 13), ("b_fc", 4)]);
        let len = 53usize;
        let colls: Vec<(Box<dyn Collective>, usize)> = vec![
            (Box::new(RingCollective), 4),
            (Box::new(RingCollective), 7),
            (Box::new(HierCollective { group: 4 }), 16),
            (Box::new(HierCollective { group: 2 }), 6),
        ];
        for (coll, n) in &colls {
            for cap in [0usize, 1, 8, 16, 21, 1 << 20] {
                let plan = BucketPlan::build(&segments, cap);
                assert_eq!(plan.total_words() as usize, len);
                let mut bufs = adversarial_bufs(*n, len);
                let want = direct_sum(&bufs);
                all_reduce_bucketed(coll.as_ref(), &mut bufs, &plan);
                for (i, b) in bufs.iter().enumerate() {
                    assert_eq!(*b, want,
                               "instance {i} diverged: {} n={n} \
                                cap={cap}", coll.name());
                }
            }
        }
    }

    #[test]
    fn range_reduce_leaves_outside_elements_untouched() {
        for coll in [&RingCollective as &dyn Collective,
                     &HierCollective { group: 2 }] {
            let mut bufs = adversarial_bufs(4, 31);
            let orig = bufs.clone();
            let want = direct_sum(&bufs);
            coll.all_reduce_range(&mut bufs, 7, 20);
            for (i, b) in bufs.iter().enumerate() {
                assert_eq!(b[..7], orig[i][..7], "{}", coll.name());
                assert_eq!(b[7..20], want[7..20], "{}", coll.name());
                assert_eq!(b[20..], orig[i][20..], "{}", coll.name());
            }
        }
    }
}
