//! Titan XP GPU baseline model for Table III.
//!
//! The paper compares the accelerator against a Titan XP (12.15 TFLOPS
//! fp32 peak, 547.6 GB/s, 250 W TDP) training the same CNNs in PyTorch at
//! batch sizes 1 and 40.  We model achieved throughput as a power law of
//! the per-image training work (bigger nets -> bigger GEMMs -> higher GPU
//! utilization) anchored at batch 1, with log-linear batch scaling up to
//! batch 40; both exponents are fitted through the paper's 1X and 4X
//! Titan XP columns, leaving 2X as the held-out check (within ~5% at B1,
//! ~15% at B40).  Board power is an affine function of achieved GOPS.

use crate::config::Network;

/// Titan XP datasheet numbers.
pub const TITAN_XP_PEAK_GOPS: f64 = 12_150.0;
pub const TITAN_XP_BW_GBS: f64 = 547.6;
pub const TITAN_XP_TDP_W: f64 = 250.0;

// Achieved GOPS at batch 1: C1 * (ops_per_image / 1e9) ^ A1
// through (0.0585 Gop, 45.67 GOPS) and (0.92 Gop, 331.41 GOPS).
const C1: f64 = 354.0;
const A1: f64 = 0.72;

// Achieved GOPS at batch 40: C40 * gops ^ A40
// through (0.0585, 551.87) and (0.92, 2353.79).
const C40: f64 = 2464.0;
const A40: f64 = 0.527;

// Board power = P_BASE + P_SLOPE * achieved_gops (fit over Table III).
const P_BASE: f64 = 95.0;
const P_SLOPE: f64 = 0.0364;

/// Modeled GPU measurement.
#[derive(Debug, Clone, Copy)]
pub struct GpuPoint {
    pub gops: f64,
    pub power_w: f64,
}

impl GpuPoint {
    pub fn efficiency(&self) -> f64 {
        self.gops / self.power_w
    }
}

/// Achieved training throughput for `net` at `batch` on the modeled
/// Titan XP.
pub fn titan_xp(net: &Network, batch: usize) -> GpuPoint {
    let gop_img = net.ops_per_image() as f64 / 1e9;
    let g1 = C1 * gop_img.powf(A1);
    let g40 = C40 * gop_img.powf(A40);
    let b = (batch.max(1) as f64).min(40.0);
    // log-linear interpolation between the B1 and B40 anchors
    let beta = (g40 / g1).ln() / 40f64.ln();
    let gops = (g1 * b.powf(beta)).min(TITAN_XP_PEAK_GOPS);
    let power_w = (P_BASE + P_SLOPE * gops).min(TITAN_XP_TDP_W);
    GpuPoint { gops, power_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    #[test]
    fn calibration_anchors_match_table3() {
        // 1X and 4X at B1/B40 are calibration points: within 10%
        let cases = [
            (1, 1, 45.67),
            (1, 40, 551.87),
            (4, 1, 331.41),
            (4, 40, 2353.79),
        ];
        for (scale, b, want) in cases {
            let got = titan_xp(&Network::cifar(scale), b).gops;
            let err = (got - want).abs() / want;
            assert!(err < 0.10, "{scale}X B{b}: {got} vs {want}");
        }
    }

    #[test]
    fn heldout_2x_prediction() {
        // Table III 2X: 128.84 (B1) and 1337.98 (B40)
        let b1 = titan_xp(&Network::cifar(2), 1).gops;
        let b40 = titan_xp(&Network::cifar(2), 40).gops;
        assert!((b1 - 128.84).abs() / 128.84 < 0.15, "B1 {b1}");
        assert!((b40 - 1337.98).abs() / 1337.98 < 0.25, "B40 {b40}");
    }

    #[test]
    fn batch_scaling_monotone() {
        let net = Network::cifar(2);
        let mut prev = 0.0;
        for b in [1, 2, 5, 10, 20, 40] {
            let g = titan_xp(&net, b).gops;
            assert!(g > prev, "b={b}");
            prev = g;
        }
    }

    #[test]
    fn never_exceeds_peak_or_tdp() {
        for scale in [1, 2, 4] {
            for b in [1, 8, 40, 400] {
                let p = titan_xp(&Network::cifar(scale), b);
                assert!(p.gops <= TITAN_XP_PEAK_GOPS);
                assert!(p.power_w <= TITAN_XP_TDP_W);
            }
        }
    }

    #[test]
    fn efficiency_shape_of_table3() {
        // GPU efficiency at B1 is poor (~0.5 GOPS/W for 1X) and improves
        // by roughly an order of magnitude at B40
        let e1 = titan_xp(&Network::cifar(1), 1).efficiency();
        let e40 = titan_xp(&Network::cifar(1), 40).efficiency();
        assert!(e1 < 0.8, "B1 eff {e1}");
        assert!(e40 / e1 > 4.0, "improvement {}", e40 / e1);
    }
}
