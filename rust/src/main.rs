//! `stratus` — CLI for the compiler-based FPGA CNN-training accelerator.
//!
//! Subcommands:
//!   compile   run the RTL compiler on a network, print the design report
//!   simulate  cycle-simulate a design point (Table II style numbers)
//!   train     train a CNN through the coordinator (golden/perop/fused)
//!   report    regenerate a paper table/figure (table2|table3|fig9|fig10)
//!
//! Run `stratus` with no arguments for usage.  (The offline build
//! environment vendors no CLI crates, so argument parsing is manual.)

use std::path::PathBuf;
use std::process::exit;

use anyhow::{anyhow, bail, Context, Result};

use stratus::compiler::{calibrate, RtlCompiler};
use stratus::config::{DesignVars, Network};
use stratus::coordinator::{Backend, Trainer};
use stratus::data::Synthetic;
use stratus::metrics;
use stratus::sim::simulate;

/// Minimal flag parser: `--key value` pairs plus positionals.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.push((key.to_string(), argv[i + 1].clone()));
                    i += 2;
                } else {
                    switches.push(key.to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags, switches }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants an integer")),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants a number")),
        }
    }
}

fn load_network(args: &Args) -> Result<Network> {
    if let Some(file) = args.get("net") {
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading {file}"))?;
        return Network::parse(&text);
    }
    let scale = args.get_or("scale", "1x");
    let s = match scale.as_str() {
        "1x" | "1" => 1,
        "2x" | "2" => 2,
        "4x" | "4" => 4,
        other => bail!("unknown scale `{other}` (use 1x|2x|4x or --net)"),
    };
    Ok(Network::cifar(s))
}

fn design_vars(args: &Args, net: &Network) -> Result<DesignVars> {
    let scale = match net.scale_tag() {
        "4x" => 4,
        "2x" => 2,
        _ => 1,
    };
    let mut dv = DesignVars::for_scale(scale);
    dv.pox = args.usize_or("pox", dv.pox)?;
    dv.poy = args.usize_or("poy", dv.poy)?;
    dv.pof = args.usize_or("pof", dv.pof)?;
    dv.clock_mhz = args.f64_or("clock-mhz", dv.clock_mhz)?;
    dv.dram_gbytes = args.f64_or("dram-gbs", dv.dram_gbytes)?;
    dv.tile_rows = args.usize_or("tile-rows", dv.tile_rows)?;
    dv.cluster = args.usize_or("accelerators", dv.cluster)?.max(1);
    dv.link_gbytes = args.f64_or("link-gbs", dv.link_gbytes)?;
    if args.has("no-load-balance") {
        dv.load_balance = false;
    }
    if args.has("no-double-buffer") {
        dv.double_buffer = false;
    }
    Ok(dv)
}

fn cmd_compile(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let acc = RtlCompiler::default().compile(&net, &dv)?;
    println!("== stratus RTL compiler ==");
    println!("network        : {} ({} layers, {} parameters)",
             net.name, net.layers.len(), net.param_count());
    println!("MAC array      : {}x{}x{} = {} MACs @ {} MHz",
             dv.pox, dv.poy, dv.pof, dv.mac_count(), dv.clock_mhz);
    println!("modules        : {}",
             acc.modules
                 .iter()
                 .map(|m| m.entity())
                 .collect::<Vec<_>>()
                 .join(", "));
    let r = &acc.resources;
    println!("resources      : {} DSP ({:.0}%), {:.1}K ALM ({:.0}%), \
              {:.1} Mbit BRAM ({:.1}%)",
             r.dsp, r.dsp_frac * 100.0, r.alm as f64 / 1e3,
             r.alm_frac * 100.0, r.bram_mbits, r.bram_frac * 100.0);
    println!("power          : {:.1} W total ({:.2} dsp / {:.1} ram / \
              {:.1} logic / {:.2} clock / {:.2} static)",
             acc.power.total(), acc.power.dsp_w, acc.power.ram_w,
             acc.power.logic_w, acc.power.clock_w, acc.power.static_w);
    println!("schedule       : {} per-image steps, {} per-batch steps",
             acc.schedule.per_image.len(), acc.schedule.per_batch.len());
    println!("DRAM traffic   : {:.2} MB/image, {:.2} MB/batch-update",
             acc.schedule.image_bytes() as f64 / 1e6,
             acc.schedule.batch_bytes() as f64 / 1e6);
    if dv.cluster > 1 {
        let ar = acc.resources.aggregate(dv.cluster);
        let ap = acc.power.aggregate(dv.cluster);
        println!("cluster        : {} instances -> {} DSP, {:.1}K ALM, \
                  {:.1} Mbit BRAM, {:.1} W aggregate",
                 dv.cluster, ar.dsp, ar.alm as f64 / 1e3, ar.bram_mbits,
                 ap.total());
    }
    if let Some(out) = args.get("emit-verilog") {
        let v = RtlCompiler::default().verilog(&acc);
        std::fs::write(out, &v)
            .with_context(|| format!("writing {out}"))?;
        println!("netlist        : wrote {} bytes to {out}", v.len());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let bs = args.usize_or("batch", 40)?;
    let acc = RtlCompiler::default().compile(&net, &dv)?;
    let r = simulate(&acc, bs);
    println!("== cycle simulation: {} @ BS {bs} ==", net.name);
    println!("{:<9} {:>12} {:>12} {:>12}", "phase", "logic cyc",
             "dram cyc", "latency cyc");
    let mut phases = vec![("FP", &r.fp), ("BP", &r.bp), ("WU", &r.wu),
                          ("UPDATE", &r.update)];
    if dv.cluster > 1 {
        phases.push(("ALLREDUCE", &r.allreduce));
    }
    for (name, p) in phases {
        println!("{:<9} {:>12} {:>12} {:>12}", name, p.logic_cycles,
                 p.dram_cycles, p.latency_cycles);
    }
    println!("per image      : {:.0} cycles = {:.3} ms",
             r.cycles_per_image(), r.seconds_per_image() * 1e3);
    println!("epoch (50k)    : {:.2} s",
             r.seconds_per_epoch(metrics::EPOCH_IMAGES));
    println!("throughput     : {:.0} GOPS", r.gops());
    if dv.cluster > 1 {
        // 1-instance baseline: the sharded projection at N=1 equals the
        // single-accelerator iteration (no recompile needed)
        let base = r.sharded_images_per_second(1);
        println!("cluster        : {} instances, {} ring steps, \
                  all-reduce {} cycles/batch",
                 dv.cluster, 2 * (dv.cluster - 1),
                 r.allreduce.latency_cycles);
        println!("iteration      : {} cycles -> {:.0} images/s \
                  ({:.2}x vs 1 instance)",
                 r.cluster_cycles_per_iteration(),
                 r.cluster_images_per_second(),
                 r.cluster_images_per_second() / base);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let batch = args.usize_or("batch", 40)?;
    let epochs = args.usize_or("epochs", 5)?;
    let images = args.usize_or("images", 512)?;
    let eval_n = args.usize_or("eval", 256)?;
    let lr = args.f64_or("lr", 0.002)?;
    let momentum = args.f64_or("momentum", 0.9)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let workers = args.usize_or("workers", 1)?;
    let backend = match args.get_or("backend", "golden").as_str() {
        "golden" => Backend::Golden,
        "perop" | "per-op" => Backend::PerOp,
        "fused" => Backend::Fused,
        other => bail!("unknown backend `{other}`"),
    };
    let artifacts: Option<PathBuf> =
        Some(PathBuf::from(args.get_or("artifacts", "artifacts")));
    let mut t = Trainer::new(&net, &dv, batch, lr, momentum, backend,
                             artifacts.as_deref())?
        .with_workers(workers);
    let data = Synthetic::new(net.nclass, net.input, seed, 0.3);
    let train: Vec<_> = data.batch(0, images);
    let test: Vec<_> = data.batch(1_000_000, eval_n);
    println!("== training {} ({:?} backend, {} images, BS {batch}, \
              {} accelerator{} x {} worker{}) ==",
             net.name, backend, images, t.accelerators,
             if t.accelerators == 1 { "" } else { "s" }, t.workers,
             if t.workers == 1 { "" } else { "s" });
    for epoch in 0..epochs {
        let mut loss_sum = 0.0;
        let mut nb = 0;
        for chunk in train.chunks(batch) {
            loss_sum += t.train_batch(chunk)?;
            nb += 1;
        }
        let acc_tr = t.evaluate(&train)?;
        let acc_te = t.evaluate(&test)?;
        println!(
            "epoch {:>3}: loss {:>10.1}  train-acc {:>5.1}%  \
             test-acc {:>5.1}%  sim {:>8.2}s  host {:>6.1}s  \
             eng {:>7.0} img/s",
            epoch + 1,
            loss_sum / nb as f64,
            acc_tr * 100.0,
            acc_te * 100.0,
            t.metrics.sim_seconds(dv.clock_mhz * 1e6),
            t.metrics.host_seconds,
            t.metrics.images_per_second()
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    // adaptive fixed-point calibration pass (paper §IV-B extension)
    let net = load_network(args)?;
    let n = args.usize_or("samples", 16)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let params = stratus::nn::init::init_params(&net, 1234);
    let (c, h, w) = net.input;
    let data = stratus::data::Synthetic::new(net.nclass, (c, h, w), seed,
                                             0.3);
    let samples = data.batch(0, n);
    let report = calibrate(&net, &params, &samples)?;
    println!("== adaptive fixed-point calibration: {} ({} samples) ==",
             net.name, report.samples);
    print!("{}", report.render());
    let mism = report.act_mismatches().len();
    println!("\n{mism} layer(s) would benefit from a non-default \
              activation format (static Q{}.{})",
             15 - stratus::fixed::FA, stratus::fixed::FA);
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let mut any = false;
    if which == "table2" || which == "all" {
        println!("== Table II: accelerator evaluation ==\n{}",
                 metrics::table2());
        any = true;
    }
    if which == "table3" || which == "all" {
        println!("== Table III: FPGA vs Titan XP ==\n{}",
                 metrics::table3());
        any = true;
    }
    if which == "fig9" || which == "all" {
        println!("== Fig. 9: 4X latency breakdown ==\n{}",
                 metrics::fig9());
        any = true;
    }
    if which == "fig10" || which == "all" {
        println!("== Fig. 10: 4X buffer usage ==\n{}", metrics::fig10());
        any = true;
    }
    if which == "engine" || which == "all" {
        println!("== engine scaling: 1X @ BS 40, sharded accelerator \
                  instances ==\n{}",
                 metrics::engine_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if which == "cluster" || which == "all" {
        println!("== cluster scaling: 1X @ BS 40, ring all-reduce data \
                  parallelism ==\n{}",
                 metrics::cluster_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if !any {
        bail!("unknown report `{which}` \
               (table2|table3|fig9|fig10|engine|cluster|all)");
    }
    Ok(())
}

const USAGE: &str = "\
stratus — compiler-based FPGA CNN-training accelerator (reproduction)

USAGE: stratus <command> [flags]

COMMANDS:
  compile   --scale 1x|2x|4x | --net FILE   run the RTL compiler
            [--pox N --poy N --pof N --clock-mhz F --emit-verilog OUT]
            [--no-load-balance --no-double-buffer]
            [--accelerators N  compile an N-instance cluster: emits the
                               ring all-reduce schedule + control-ROM
                               word and reports aggregate resources]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
  simulate  --scale .. --batch N            cycle-level simulation
            [--accelerators N  project N data-parallel instances with a
                               ring all-reduce of WU gradients between
                               batch accumulation and weight update]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
  train     --scale .. --backend golden|perop|fused --images N
            --epochs N --batch N --lr F [--artifacts DIR --eval N]
            [--workers N       shard each batch across N engine threads
                               (golden backend; bit-identical results)]
            [--accelerators N  train data-parallel across N simulated
                               accelerator instances with a deterministic
                               ring all-reduce (golden backend;
                               bit-identical to one instance)]
  report    table2|table3|fig9|fig10|engine|cluster|all  regenerate
  calibrate --scale .. --samples N          adaptive fixed-point pass
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(String::as_str);
    let result = match cmd {
        Some("compile") => cmd_compile(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("train") => cmd_train(&args),
        Some("report") => cmd_report(&args),
        Some("calibrate") => cmd_calibrate(&args),
        _ => Err(anyhow!("{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        exit(1);
    }
}
