//! `stratus` — CLI for the compiler-based FPGA CNN-training accelerator.
//!
//! Subcommands:
//!   compile   run the RTL compiler on a network, print the design report
//!   simulate  cycle-simulate a design point (Table II style numbers)
//!   train     train a CNN through the coordinator (golden/perop/fused)
//!   report    regenerate a paper table/figure (table2|table3|fig9|fig10)
//!
//! Run `stratus` with no arguments for usage.  (The offline build
//! environment vendors no CLI crates, so argument parsing is manual —
//! but strict: every subcommand declares which flags take values and
//! which are switches, a value flag with its value missing is an error
//! rather than a silent switch demotion, and unrecognized flags are
//! rejected with a usage hint instead of being ignored.)

use std::path::PathBuf;
use std::process::exit;

use anyhow::{anyhow, bail, Context, Result};

use stratus::ckpt::Cursor;
use stratus::compiler::{calibrate, RtlCompiler};
use stratus::config::{DesignVars, Network};
use stratus::coordinator::{Backend, CheckpointPolicy, TrainRun, Trainer};
use stratus::data::Synthetic;
use stratus::metrics;
use stratus::sim::simulate;

/// Parsed arguments: `--key value` pairs, `--switch`es, positionals.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    /// Strict parse against a subcommand's flag spec.  `value_flags`
    /// must be followed by a value (a missing one — end of line or
    /// another `--flag` — is an error, never a silent demotion to a
    /// switch); names in neither list are rejected.
    fn parse(argv: &[String], value_flags: &[&str],
             switch_flags: &[&str]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if value_flags.contains(&key) {
                    match argv.get(i + 1) {
                        Some(v) if !v.starts_with("--") => {
                            flags.push((key.to_string(), v.clone()));
                            i += 2;
                        }
                        _ => bail!("flag --{key} expects a value"),
                    }
                } else if switch_flags.contains(&key) {
                    switches.push(key.to_string());
                    i += 1;
                } else {
                    bail!("unknown flag --{key}");
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants an integer")),
        }
    }

    /// Like [`Args::usize_or`] but 0 is rejected — the one place zero
    /// worker/instance/batch counts are normalized (the library-side
    /// builders clamp 0 to 1; the CLI refuses it outright so a typo'd
    /// `--workers 0` cannot silently train single-threaded).
    fn usize_positive(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.usize_or(key, default)?;
        if v == 0 {
            bail!("--{key} must be at least 1");
        }
        Ok(v)
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants a number")),
        }
    }
}

/// Flag spec per subcommand: (flags that take a value, switches).
/// Anything not listed is rejected by [`Args::parse`].
fn flag_spec(cmd: &str)
             -> Option<(Vec<&'static str>, Vec<&'static str>)> {
    // design-point flags shared by compile/simulate/train
    const DESIGN: &[&str] = &["net", "scale", "pox", "poy", "pof",
                              "clock-mhz", "dram-gbs", "tile-rows",
                              "accelerators", "link-gbs"];
    const DESIGN_SW: &[&str] = &["no-load-balance", "no-double-buffer"];
    let (design, extra, extra_sw): (bool, &[&str], &[&str]) = match cmd {
        "compile" => (true, &["emit-verilog"], &[]),
        "simulate" => (true, &["batch"], &[]),
        "train" => (true,
                    &["batch", "epochs", "images", "eval", "lr",
                      "momentum", "seed", "workers", "backend",
                      "artifacts", "checkpoint-dir", "checkpoint-every"],
                    &["resume"]),
        "report" => (false, &[], &[]),
        "calibrate" => (false, &["net", "scale", "samples", "seed"], &[]),
        _ => return None,
    };
    let mut value_flags = Vec::new();
    let mut switches = Vec::new();
    if design {
        value_flags.extend_from_slice(DESIGN);
        switches.extend_from_slice(DESIGN_SW);
    }
    value_flags.extend_from_slice(extra);
    switches.extend_from_slice(extra_sw);
    Some((value_flags, switches))
}

fn load_network(args: &Args) -> Result<Network> {
    if let Some(file) = args.get("net") {
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading {file}"))?;
        return Network::parse(&text);
    }
    let scale = args.get_or("scale", "1x");
    // "bnNx" selects the §IV-B batch-norm topology at scale N
    let (bn, tag) = match scale.strip_prefix("bn") {
        Some(rest) => (true, rest),
        None => (false, scale.as_str()),
    };
    let s = match tag {
        "1x" | "1" => 1,
        "2x" | "2" => 2,
        "4x" | "4" => 4,
        _ => bail!("unknown scale `{scale}` \
                    (use 1x|2x|4x|bn1x|bn2x|bn4x or --net)"),
    };
    Ok(if bn { Network::cifar_bn(s) } else { Network::cifar(s) })
}

fn design_vars(args: &Args, net: &Network) -> Result<DesignVars> {
    let scale = match net.scale_tag() {
        "4x" => 4,
        "2x" => 2,
        _ => 1,
    };
    let mut dv = DesignVars::for_scale(scale);
    dv.pox = args.usize_positive("pox", dv.pox)?;
    dv.poy = args.usize_positive("poy", dv.poy)?;
    dv.pof = args.usize_positive("pof", dv.pof)?;
    dv.clock_mhz = args.f64_or("clock-mhz", dv.clock_mhz)?;
    dv.dram_gbytes = args.f64_or("dram-gbs", dv.dram_gbytes)?;
    dv.tile_rows = args.usize_positive("tile-rows", dv.tile_rows)?;
    dv.cluster = args.usize_positive("accelerators", dv.cluster)?;
    dv.link_gbytes = args.f64_or("link-gbs", dv.link_gbytes)?;
    if args.has("no-load-balance") {
        dv.load_balance = false;
    }
    if args.has("no-double-buffer") {
        dv.double_buffer = false;
    }
    Ok(dv)
}

fn cmd_compile(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let acc = RtlCompiler::default().compile(&net, &dv)?;
    println!("== stratus RTL compiler ==");
    println!("network        : {} ({} layers, {} parameters)",
             net.name, net.layers.len(), net.param_count());
    println!("MAC array      : {}x{}x{} = {} MACs @ {} MHz",
             dv.pox, dv.poy, dv.pof, dv.mac_count(), dv.clock_mhz);
    println!("modules        : {}",
             acc.modules
                 .iter()
                 .map(|m| m.entity())
                 .collect::<Vec<_>>()
                 .join(", "));
    let r = &acc.resources;
    println!("resources      : {} DSP ({:.0}%), {:.1}K ALM ({:.0}%), \
              {:.1} Mbit BRAM ({:.1}%)",
             r.dsp, r.dsp_frac * 100.0, r.alm as f64 / 1e3,
             r.alm_frac * 100.0, r.bram_mbits, r.bram_frac * 100.0);
    println!("power          : {:.1} W total ({:.2} dsp / {:.1} ram / \
              {:.1} logic / {:.2} clock / {:.2} static)",
             acc.power.total(), acc.power.dsp_w, acc.power.ram_w,
             acc.power.logic_w, acc.power.clock_w, acc.power.static_w);
    println!("schedule       : {} per-image steps, {} per-batch steps",
             acc.schedule.per_image.len(), acc.schedule.per_batch.len());
    println!("DRAM traffic   : {:.2} MB/image, {:.2} MB/batch-update",
             acc.schedule.image_bytes() as f64 / 1e6,
             acc.schedule.batch_bytes() as f64 / 1e6);
    if dv.cluster > 1 {
        let ar = acc.resources.aggregate(dv.cluster);
        let ap = acc.power.aggregate(dv.cluster);
        println!("cluster        : {} instances -> {} DSP, {:.1}K ALM, \
                  {:.1} Mbit BRAM, {:.1} W aggregate",
                 dv.cluster, ar.dsp, ar.alm as f64 / 1e3, ar.bram_mbits,
                 ap.total());
    }
    if let Some(out) = args.get("emit-verilog") {
        let v = RtlCompiler::default().verilog(&acc);
        std::fs::write(out, &v)
            .with_context(|| format!("writing {out}"))?;
        println!("netlist        : wrote {} bytes to {out}", v.len());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let bs = args.usize_positive("batch", 40)?;
    let acc = RtlCompiler::default().compile(&net, &dv)?;
    let r = simulate(&acc, bs);
    println!("== cycle simulation: {} @ BS {bs} ==", net.name);
    println!("{:<9} {:>12} {:>12} {:>12}", "phase", "logic cyc",
             "dram cyc", "latency cyc");
    let mut phases = vec![("FP", &r.fp), ("BP", &r.bp), ("WU", &r.wu),
                          ("UPDATE", &r.update)];
    if dv.cluster > 1 {
        phases.push(("ALLREDUCE", &r.allreduce));
    }
    for (name, p) in phases {
        println!("{:<9} {:>12} {:>12} {:>12}", name, p.logic_cycles,
                 p.dram_cycles, p.latency_cycles);
    }
    println!("per image      : {:.0} cycles = {:.3} ms",
             r.cycles_per_image(), r.seconds_per_image() * 1e3);
    println!("epoch (50k)    : {:.2} s",
             r.seconds_per_epoch(metrics::EPOCH_IMAGES));
    println!("throughput     : {:.0} GOPS", r.gops());
    if dv.cluster > 1 {
        // 1-instance baseline: the sharded projection at N=1 equals the
        // single-accelerator iteration (no recompile needed)
        let base = r.sharded_images_per_second(1);
        println!("cluster        : {} instances, {} ring steps, \
                  all-reduce {} cycles/batch",
                 dv.cluster, 2 * (dv.cluster - 1),
                 r.allreduce.latency_cycles);
        println!("iteration      : {} cycles -> {:.0} images/s \
                  ({:.2}x vs 1 instance)",
                 r.cluster_cycles_per_iteration(),
                 r.cluster_images_per_second(),
                 r.cluster_images_per_second() / base);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let net = load_network(args)?;
    let dv = design_vars(args, &net)?;
    let batch = args.usize_positive("batch", 40)?;
    let epochs = args.usize_positive("epochs", 5)? as u64;
    let images = args.usize_positive("images", 512)? as u64;
    let eval_n = args.usize_positive("eval", 256)?;
    let lr = args.f64_or("lr", 0.002)?;
    let momentum = args.f64_or("momentum", 0.9)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let workers = args.usize_positive("workers", 1)?;
    let backend = match args.get_or("backend", "golden").as_str() {
        "golden" => Backend::Golden,
        "perop" | "per-op" => Backend::PerOp,
        "fused" => Backend::Fused,
        other => bail!("unknown backend `{other}`"),
    };
    let artifacts: Option<PathBuf> =
        Some(PathBuf::from(args.get_or("artifacts", "artifacts")));
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    let ckpt_every = args.usize_positive("checkpoint-every", 50)? as u64;
    let resume = args.has("resume");
    if ckpt_dir.is_none() && args.get("checkpoint-every").is_some() {
        bail!("--checkpoint-every needs --checkpoint-dir (where the \
               checkpoints go) — without it nothing would be saved");
    }
    let ckpt_path = ckpt_dir.as_ref().map(|d| d.join("ckpt.stratus"));

    let mut t = Trainer::new(&net, &dv, batch, lr, momentum, backend,
                             artifacts.as_deref())?
        .with_workers(workers);
    let start = if resume {
        let path = ckpt_path.as_ref().ok_or_else(|| {
            anyhow!("--resume needs --checkpoint-dir (where the \
                     checkpoint lives)")
        })?;
        let cur = t.resume_from(path)?;
        if args.get("seed").is_some() && cur.seed != seed {
            bail!("--seed {seed} conflicts with the checkpoint's \
                   recorded seed {}; drop --seed to continue the \
                   recorded run",
                  cur.seed);
        }
        if args.get("images").is_some() && cur.images != images {
            bail!("--images {images} conflicts with the checkpoint's \
                   recorded epoch width {}; drop --images to continue \
                   the recorded run",
                  cur.images);
        }
        println!("resumed        : {} -> epoch {}, batch {} (seed {}, \
                  {} images/epoch)",
                 path.display(), cur.epoch + 1, cur.batch, cur.seed,
                 cur.images);
        cur
    } else {
        Cursor::start(seed, images)
    };
    // the cursor's recorded epoch width wins on resume (== `images`
    // for fresh runs; an explicitly conflicting --images errored above)
    let images = start.images;
    println!("== training {} ({:?} backend, {} images, BS {batch}, \
              {} accelerator{} x {} worker{}) ==",
             net.name, backend, images, t.accelerators,
             if t.accelerators == 1 { "" } else { "s" }, t.workers,
             if t.workers == 1 { "" } else { "s" });
    if let Some(dir) = &ckpt_dir {
        std::fs::create_dir_all(dir).with_context(|| {
            format!("creating checkpoint dir {}", dir.display())
        })?;
    }
    if start.epoch >= epochs {
        if resume {
            println!("checkpoint already covers epoch {}; nothing to \
                      do (raise --epochs to train further)",
                     start.epoch);
        }
        return Ok(());
    }

    let data = Synthetic::new(net.nclass, net.input, start.seed, 0.3);
    let train: Vec<_> = data.batch(0, images as usize);
    let test: Vec<_> = data.batch(1_000_000, eval_n);
    let cfg = TrainRun {
        epochs,
        images,
        checkpoint: ckpt_path.map(|path| CheckpointPolicy {
            path,
            every_batches: ckpt_every,
        }),
        max_batches: None,
    };
    let clock_hz = dv.clock_mhz * 1e6;
    t.run(&data, &cfg, start, |tr, stats| {
        let acc_tr = tr.evaluate(&train)?;
        let acc_te = tr.evaluate(&test)?;
        println!(
            "epoch {:>3}: loss {:>10.1}  train-acc {:>5.1}%  \
             test-acc {:>5.1}%  sim {:>8.2}s  host {:>6.1}s  \
             eng {:>7.0} img/s",
            stats.epoch + 1,
            stats.mean_loss,
            acc_tr * 100.0,
            acc_te * 100.0,
            tr.metrics.sim_seconds(clock_hz),
            tr.metrics.host_seconds,
            tr.metrics.images_per_second()
        );
        Ok(())
    })?;
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    // adaptive fixed-point calibration pass (paper §IV-B extension)
    let net = load_network(args)?;
    let n = args.usize_positive("samples", 16)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let params = stratus::nn::init::init_params(&net, 1234);
    let (c, h, w) = net.input;
    let data = stratus::data::Synthetic::new(net.nclass, (c, h, w), seed,
                                             0.3);
    let samples = data.batch(0, n);
    let report = calibrate(&net, &params, &samples)?;
    println!("== adaptive fixed-point calibration: {} ({} samples) ==",
             net.name, report.samples);
    print!("{}", report.render());
    let mism = report.act_mismatches().len();
    println!("\n{mism} layer(s) would benefit from a non-default \
              activation format (static Q{}.{})",
             15 - stratus::fixed::FA, stratus::fixed::FA);
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut any = false;
    if which == "table2" || which == "all" {
        println!("== Table II: accelerator evaluation ==\n{}",
                 metrics::table2());
        any = true;
    }
    if which == "table3" || which == "all" {
        println!("== Table III: FPGA vs Titan XP ==\n{}",
                 metrics::table3());
        any = true;
    }
    if which == "fig9" || which == "all" {
        println!("== Fig. 9: 4X latency breakdown ==\n{}",
                 metrics::fig9());
        any = true;
    }
    if which == "fig10" || which == "all" {
        println!("== Fig. 10: 4X buffer usage ==\n{}", metrics::fig10());
        any = true;
    }
    if which == "engine" || which == "all" {
        println!("== engine scaling: 1X @ BS 40, sharded accelerator \
                  instances ==\n{}",
                 metrics::engine_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if which == "cluster" || which == "all" {
        println!("== cluster scaling: 1X @ BS 40, ring all-reduce data \
                  parallelism ==\n{}",
                 metrics::cluster_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if !any {
        bail!("unknown report `{which}` \
               (table2|table3|fig9|fig10|engine|cluster|all)");
    }
    Ok(())
}

const USAGE: &str = "\
stratus — compiler-based FPGA CNN-training accelerator (reproduction)

USAGE: stratus <command> [flags]

COMMANDS:
  compile   --scale 1x|2x|4x | --net FILE   run the RTL compiler
            (--scale bn1x|bn2x|bn4x selects the batch-norm topology;
             BN networks train on the golden backend only)
            [--pox N --poy N --pof N --clock-mhz F --emit-verilog OUT]
            [--no-load-balance --no-double-buffer]
            [--accelerators N  compile an N-instance cluster: emits the
                               ring all-reduce schedule + control-ROM
                               word and reports aggregate resources]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
  simulate  --scale .. --batch N            cycle-level simulation
            [--accelerators N  project N data-parallel instances with a
                               ring all-reduce of WU gradients between
                               batch accumulation and weight update]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
  train     --scale .. --backend golden|perop|fused --images N
            --epochs N --batch N --lr F [--artifacts DIR --eval N]
            [--workers N       shard each batch across N engine threads
                               (golden backend; bit-identical results)]
            [--accelerators N  train data-parallel across N simulated
                               accelerator instances with a deterministic
                               ring all-reduce (golden backend;
                               bit-identical to one instance)]
            [--checkpoint-dir D    write crash-safe checkpoints to
                                   D/ckpt.stratus (atomic tmp+rename,
                                   CRC-guarded; see DESIGN.md)]
            [--checkpoint-every N  checkpoint every N batches
                                   (default 50; epoch ends always save)]
            [--resume              continue from D/ckpt.stratus at its
                                   recorded epoch/batch/seed cursor —
                                   bit-identical to never having
                                   stopped, at any worker/accelerator
                                   count]
  report    table2|table3|fig9|fig10|engine|cluster|all  regenerate
  calibrate --scale .. --samples N          adaptive fixed-point pass

Flags that take a value error when the value is missing; unrecognized
flags are rejected.
";

fn run_cli(argv: &[String]) -> Result<()> {
    let cmd = match argv.first() {
        Some(c) if !c.starts_with("--") => c.as_str(),
        _ => bail!("{USAGE}"),
    };
    let Some((value_flags, switches)) = flag_spec(cmd) else {
        bail!("unknown command `{cmd}`\n\n{USAGE}");
    };
    let args = Args::parse(&argv[1..], &value_flags, &switches)
        .map_err(|e| {
            anyhow!("{cmd}: {e:#} (run `stratus` without arguments for \
                     usage)")
        })?;
    match cmd {
        "compile" => cmd_compile(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "report" => cmd_report(&args),
        "calibrate" => cmd_calibrate(&args),
        _ => unreachable!("flag_spec gates the command set"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run_cli(&argv) {
        eprintln!("error: {e:#}");
        exit(1);
    }
}
