//! `stratus` — CLI for the compiler-based FPGA CNN-training accelerator.
//!
//! Subcommands:
//!   compile   run the RTL compiler on a network, print the design report
//!   analyze   static fixed-point range analysis of every accumulator
//!   simulate  cycle-simulate a design point (Table II style numbers)
//!   train     train a CNN through the coordinator (golden/perop/fused)
//!   serve     crash-safe multi-tenant experiment service: watch a
//!             submission dir, time-slice queued runs by priority
//!   report    regenerate a paper table/figure (table2|table3|fig9|fig10)
//!
//! Every experiment-shaped subcommand (compile/analyze/simulate/
//! train/calibrate) is a thin shell over [`stratus::session`]: flags
//! build a validated `session::Spec`, and a `Session` does the work.
//! compile/simulate/train additionally take `--spec run.json` (load a
//! serialized spec; explicit flags still override it) and
//! `--dump-spec out.json` (write the resolved spec and exit —
//! `stratus train --spec out.json` then reproduces the identical run:
//! same fingerprint, bit-identical training).
//!
//! Run `stratus` with no arguments for usage.  (The offline build
//! environment vendors no CLI crates, so argument parsing is manual —
//! but strict: every subcommand declares which flags take values and
//! which are switches, a value flag with its value missing is an error
//! rather than a silent switch demotion, and unrecognized flags are
//! rejected with a usage hint instead of being ignored.)

use std::path::Path;
use std::process::exit;

use anyhow::{anyhow, bail, Context, Result};

use stratus::analysis;
use stratus::compiler::{calibrate, RtlCompiler};
use stratus::metrics;
use stratus::serve::{Scheduler, ServeConfig};
use stratus::session::{Session, Spec, SpecBuilder, DEFAULT_SEED};

/// Parsed arguments: `--key value` pairs, `--switch`es, positionals.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    /// Strict parse against a subcommand's flag spec.  `value_flags`
    /// must be followed by a value (a missing one — end of line or
    /// another `--flag` — is an error, never a silent demotion to a
    /// switch); names in neither list are rejected.
    fn parse(argv: &[String], value_flags: &[&str],
             switch_flags: &[&str]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if value_flags.contains(&key) {
                    match argv.get(i + 1) {
                        Some(v) if !v.starts_with("--") => {
                            flags.push((key.to_string(), v.clone()));
                            i += 2;
                        }
                        _ => bail!("flag --{key} expects a value"),
                    }
                } else if switch_flags.contains(&key) {
                    switches.push(key.to_string());
                    i += 1;
                } else {
                    bail!("unknown flag --{key}");
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// The flag's value parsed as usize, `None` when absent.  (Range
    /// validation — e.g. "workers must be at least 1" — lives in the
    /// `SpecBuilder`, not here.)
    fn usize_opt(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .with_context(|| format!("--{key} wants an integer"))
            })
            .transpose()
    }

    fn u64_opt(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .with_context(|| format!("--{key} wants an integer"))
            })
            .transpose()
    }

    fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .with_context(|| format!("--{key} wants a number"))
            })
            .transpose()
    }
}

/// Flag spec per subcommand: (flags that take a value, switches).
/// Anything not listed is rejected by [`Args::parse`].
fn flag_spec(cmd: &str)
             -> Option<(Vec<&'static str>, Vec<&'static str>)> {
    // design-point + spec-file flags shared by compile/simulate/train
    const DESIGN: &[&str] = &["net", "scale", "pox", "poy", "pof",
                              "bucket-kwords",
                              "clock-mhz", "dram-gbs", "tile-rows",
                              "accelerators", "link-gbs", "link-eff",
                              "topology", "spec", "dump-spec"];
    const DESIGN_SW: &[&str] = &["no-load-balance", "no-double-buffer"];
    let (design, extra, extra_sw): (bool, &[&str], &[&str]) = match cmd {
        "compile" => (true, &["emit-verilog"], &[]),
        "simulate" => (true, &["batch"], &[]),
        "analyze" => (true, &["batch"], &["json"]),
        "train" => (true,
                    &["batch", "epochs", "images", "eval", "lr",
                      "momentum", "seed", "workers", "backend",
                      "artifacts", "checkpoint-dir", "checkpoint-every",
                      "resize-accelerators"],
                    &["resume"]),
        "report" => (false, &["root"], &[]),
        "serve" => (false,
                    &["root", "watch", "slice-batches", "active",
                      "workers-budget", "poll-ms"],
                    &["drain", "stdin", "status"]),
        "calibrate" => (false, &["net", "scale", "samples", "seed"], &[]),
        _ => return None,
    };
    let mut value_flags = Vec::new();
    let mut switches = Vec::new();
    if design {
        value_flags.extend_from_slice(DESIGN);
        switches.extend_from_slice(DESIGN_SW);
    }
    value_flags.extend_from_slice(extra);
    switches.extend_from_slice(extra_sw);
    Some((value_flags, switches))
}

/// Flags -> spec: start from `--spec FILE` when given (defaults
/// otherwise) and override with every explicitly present flag, so the
/// precedence is always flag > spec file > default.  Args::parse has
/// already gated which flags each subcommand accepts, so absent flags
/// simply never fire here.
fn build_spec(args: &Args) -> Result<Spec> {
    Ok(spec_builder(args)?.build()?)
}

/// The flag -> builder wiring shared by [`build_spec`] and
/// `cmd_analyze` (which finishes with the gate-free
/// `build_for_analysis` so it can report on specs `build` refuses).
fn spec_builder(args: &Args) -> Result<SpecBuilder> {
    let mut b: SpecBuilder = match args.get("spec") {
        Some(file) => Spec::load(Path::new(file))?.to_builder(),
        None => Spec::builder(),
    };
    if let Some(file) = args.get("net") {
        b = b.net_file(file);
    } else if let Some(scale) = args.get("scale") {
        b = b.preset(scale);
    }
    if let Some(v) = args.usize_opt("pox")? {
        b = b.pox(v);
    }
    if let Some(v) = args.usize_opt("poy")? {
        b = b.poy(v);
    }
    if let Some(v) = args.usize_opt("pof")? {
        b = b.pof(v);
    }
    if let Some(v) = args.f64_opt("clock-mhz")? {
        b = b.clock_mhz(v);
    }
    if let Some(v) = args.f64_opt("dram-gbs")? {
        b = b.dram_gbytes(v);
    }
    if let Some(v) = args.usize_opt("tile-rows")? {
        b = b.tile_rows(v);
    }
    if let Some(v) = args.usize_opt("accelerators")? {
        b = b.accelerators(v);
    }
    if let Some(v) = args.f64_opt("link-gbs")? {
        b = b.link_gbytes(v);
    }
    if let Some(v) = args.f64_opt("link-eff")? {
        b = b.link_efficiency(v);
    }
    if let Some(v) = args.get("topology") {
        b = b.topology(v.parse()?);
    }
    if let Some(v) = args.usize_opt("bucket-kwords")? {
        b = b.bucket_kwords(v);
    }
    if args.has("no-load-balance") {
        b = b.load_balance(false);
    }
    if args.has("no-double-buffer") {
        b = b.double_buffer(false);
    }
    if let Some(v) = args.usize_opt("batch")? {
        b = b.batch(v);
    }
    if let Some(v) = args.u64_opt("epochs")? {
        b = b.epochs(v);
    }
    if let Some(v) = args.u64_opt("images")? {
        b = b.images(v);
    }
    if let Some(v) = args.usize_opt("eval")? {
        b = b.eval(v);
    }
    if let Some(v) = args.f64_opt("lr")? {
        b = b.lr(v);
    }
    if let Some(v) = args.f64_opt("momentum")? {
        b = b.momentum(v);
    }
    if let Some(v) = args.u64_opt("seed")? {
        b = b.seed(v);
    }
    if let Some(v) = args.usize_opt("workers")? {
        b = b.workers(v);
    }
    if let Some(v) = args.get("backend") {
        b = b.backend(v.parse()?);
    }
    if let Some(v) = args.get("artifacts") {
        b = b.artifacts(v);
    }
    if let Some(v) = args.get("checkpoint-dir") {
        b = b.checkpoint_dir(v);
    }
    if let Some(v) = args.u64_opt("checkpoint-every")? {
        b = b.checkpoint_every(v);
    }
    if let Some(v) = args.usize_opt("resize-accelerators")? {
        b = b.resize_accelerators(v);
    }
    if args.has("resume") {
        b = b.resume(true);
    }
    Ok(b)
}

/// Handle `--dump-spec OUT`: write the resolved spec and skip the run.
/// Returns true when the command is done.
fn maybe_dump_spec(args: &Args, spec: &Spec) -> Result<bool> {
    let Some(out) = args.get("dump-spec") else {
        return Ok(false);
    };
    if out == "-" {
        print!("{}", spec.render());
    } else {
        spec.save(Path::new(out))?;
        println!("spec           : wrote {out} (rerun with --spec \
                  {out})");
    }
    Ok(true)
}

fn cmd_compile(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    if maybe_dump_spec(args, &spec)? {
        return Ok(());
    }
    let session = Session::new(spec)?;
    let (net, dv) = (session.network(), session.design());
    let acc = session.compile()?;
    println!("== stratus RTL compiler ==");
    println!("network        : {} ({} layers, {} parameters)",
             net.name, net.layers.len(), net.param_count());
    println!("MAC array      : {}x{}x{} = {} MACs @ {} MHz",
             dv.pox, dv.poy, dv.pof, dv.mac_count(), dv.clock_mhz);
    println!("modules        : {}",
             acc.modules
                 .iter()
                 .map(|m| m.entity())
                 .collect::<Vec<_>>()
                 .join(", "));
    let r = &acc.resources;
    println!("resources      : {} DSP ({:.0}%), {:.1}K ALM ({:.0}%), \
              {:.1} Mbit BRAM ({:.1}%)",
             r.dsp, r.dsp_frac * 100.0, r.alm as f64 / 1e3,
             r.alm_frac * 100.0, r.bram_mbits, r.bram_frac * 100.0);
    println!("power          : {:.1} W total ({:.2} dsp / {:.1} ram / \
              {:.1} logic / {:.2} clock / {:.2} static)",
             acc.power.total(), acc.power.dsp_w, acc.power.ram_w,
             acc.power.logic_w, acc.power.clock_w, acc.power.static_w);
    println!("schedule       : {} per-image steps, {} per-batch steps",
             acc.schedule.per_image.len(), acc.schedule.per_batch.len());
    println!("DRAM traffic   : {:.2} MB/image, {:.2} MB/batch-update",
             acc.schedule.image_bytes() as f64 / 1e6,
             acc.schedule.batch_bytes() as f64 / 1e6);
    if dv.cluster > 1 {
        let ar = acc.resources.aggregate(dv.cluster);
        let ap = acc.power.aggregate(dv.cluster);
        println!("cluster        : {} instances -> {} DSP, {:.1}K ALM, \
                  {:.1} Mbit BRAM, {:.1} W aggregate",
                 dv.cluster, ar.dsp, ar.alm as f64 / 1e3, ar.bram_mbits,
                 ap.total());
    }
    if let Some(out) = args.get("emit-verilog") {
        let v = RtlCompiler::default().verilog(&acc);
        std::fs::write(out, &v)
            .with_context(|| format!("writing {out}"))?;
        println!("netlist        : wrote {} bytes to {out}", v.len());
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (spec, net, dv) = spec_builder(args)?.build_for_analysis()?;
    if maybe_dump_spec(args, &spec)? {
        return Ok(());
    }
    let report = analysis::analyze(&net, &dv, spec.batch);
    if args.has("json") {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render());
    }
    if let Some(row) = report.first_overflow() {
        bail!("{} overflow-possible accumulator(s); first is the {} \
               of layer `{}` — `stratus train`/`compile` will refuse \
               this spec",
              report.overflow_count(), row.acc, row.layer);
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    if maybe_dump_spec(args, &spec)? {
        return Ok(());
    }
    let session = Session::new(spec)?;
    let (net, dv) = (session.network(), session.design());
    let bs = session.spec().batch;
    let acc = session.compile()?;
    let r = stratus::sim::simulate(&acc, bs);
    println!("== cycle simulation: {} @ BS {bs} ==", net.name);
    println!("{:<9} {:>12} {:>12} {:>12}", "phase", "logic cyc",
             "dram cyc", "latency cyc");
    let mut phases = vec![("FP", &r.fp), ("BP", &r.bp), ("WU", &r.wu),
                          ("UPDATE", &r.update)];
    if dv.cluster > 1 {
        phases.push(("ALLREDUCE", &r.allreduce));
    }
    for (name, p) in phases {
        println!("{:<9} {:>12} {:>12} {:>12}", name, p.logic_cycles,
                 p.dram_cycles, p.latency_cycles);
    }
    println!("per image      : {:.0} cycles = {:.3} ms",
             r.cycles_per_image(), r.seconds_per_image() * 1e3);
    println!("epoch (50k)    : {:.2} s",
             r.seconds_per_epoch(metrics::EPOCH_IMAGES));
    println!("throughput     : {:.0} GOPS", r.gops());
    if dv.cluster > 1 {
        // 1-instance baseline: the sharded projection at N=1 equals the
        // single-accelerator iteration (no recompile needed)
        let base = r.sharded_images_per_second(1);
        // the compiled plan already resolved --topology (incl. auto)
        let coll = &acc.schedule.collective;
        let topo = coll.first().map_or("ring", |s| {
            if s.label.starts_with("hier") { "hier" } else { "ring" }
        });
        println!("cluster        : {} instances, {} collective ({} \
                  steps), all-reduce {} cycles/batch",
                 dv.cluster, topo, coll.len(),
                 r.allreduce.latency_cycles);
        println!("iteration      : {} cycles -> {:.0} images/s \
                  ({:.2}x vs 1 instance)",
                 r.cluster_cycles_per_iteration(),
                 r.cluster_images_per_second(),
                 r.cluster_images_per_second() / base);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    if maybe_dump_spec(args, &spec)? {
        return Ok(());
    }
    let session = Session::new(spec)?;
    let spec = session.spec();
    let run = session.begin(spec.resume)?;
    let start = run.start();
    if spec.resume {
        let path = session
            .checkpoint_path()
            .ok_or_else(|| anyhow!("resume requires a checkpoint"))?;
        println!("resumed        : {} -> epoch {}, batch {} (seed {}, \
                  {} images/epoch)",
                 path.display(), start.epoch + 1, start.batch,
                 start.seed, start.images);
    }
    if run.finished() {
        if spec.resume {
            println!("checkpoint already covers epoch {}; nothing to \
                      do (raise --epochs to train further)",
                     start.epoch);
        }
        return Ok(());
    }
    let t = run.trainer();
    println!("== training {} ({} backend, {} images, BS {}, \
              {} accelerator{} x {} worker{}) ==",
             session.network().name, spec.backend, start.images,
             spec.batch, t.accelerators,
             if t.accelerators == 1 { "" } else { "s" }, t.workers,
             if t.workers == 1 { "" } else { "s" });
    let clock_hz = session.design().clock_mhz * 1e6;
    run.execute(|tr, stats, ev| {
        let acc_tr = tr.evaluate(ev.train)?;
        let acc_te = tr.evaluate(ev.eval)?;
        println!(
            "epoch {:>3}: loss {:>10.1}  train-acc {:>5.1}%  \
             test-acc {:>5.1}%  sim {:>8.2}s  host {:>6.1}s  \
             eng {:>7.0} img/s",
            stats.epoch + 1,
            stats.mean_loss,
            acc_tr * 100.0,
            acc_te * 100.0,
            tr.metrics.sim_seconds(clock_hz),
            tr.metrics.host_seconds,
            tr.metrics.images_per_second()
        );
        Ok(())
    })?;
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    // adaptive fixed-point calibration pass (paper §IV-B extension):
    // the spec resolves the network; --samples stays command-local
    let mut b = Spec::builder();
    if let Some(file) = args.get("net") {
        b = b.net_file(file);
    } else if let Some(scale) = args.get("scale") {
        b = b.preset(scale);
    }
    if let Some(v) = args.u64_opt("seed")? {
        b = b.seed(v);
    }
    let session = Session::new(b.build()?)?;
    let net = session.network();
    let n = args.usize_opt("samples")?.unwrap_or(16);
    if n == 0 {
        bail!("--samples must be at least 1");
    }
    let seed = session.spec().seed.unwrap_or(DEFAULT_SEED);
    let params = stratus::nn::init::init_params(net, 1234);
    let (c, h, w) = net.input;
    let data = stratus::data::Synthetic::new(net.nclass, (c, h, w), seed,
                                             0.3);
    let samples = data.batch(0, n);
    let report = calibrate(net, &params, &samples)?;
    println!("== adaptive fixed-point calibration: {} ({} samples) ==",
             net.name, report.samples);
    print!("{}", report.render());
    let mism = report.act_mismatches().len();
    println!("\n{mism} layer(s) would benefit from a non-default \
              activation format (static Q{}.{})",
             15 - stratus::fixed::FA, stratus::fixed::FA);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let root = args.get("root").ok_or_else(|| {
        anyhow!("serve needs --root DIR (the serve root holding the \
                 queue, checkpoints, and event log)")
    })?;
    let root = std::path::PathBuf::from(root);
    if args.has("status") {
        print!("{}", metrics::serve_report(&root)?);
        return Ok(());
    }
    let mut cfg = ServeConfig::new(root);
    cfg.watch = args.get("watch").map(std::path::PathBuf::from);
    if let Some(v) = args.u64_opt("slice-batches")? {
        cfg.slice_batches = v;
    }
    if let Some(v) = args.usize_opt("active")? {
        cfg.max_active = v;
    }
    if let Some(v) = args.usize_opt("workers-budget")? {
        cfg.worker_budget = v;
    }
    if let Some(v) = args.u64_opt("poll-ms")? {
        cfg.poll_ms = v;
    }
    cfg.drain = args.has("drain");
    cfg.stdin = args.has("stdin");
    cfg.echo = true;
    Scheduler::open(cfg)?.run_loop()
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut any = false;
    if which == "table2" || which == "all" {
        println!("== Table II: accelerator evaluation ==\n{}",
                 metrics::table2());
        any = true;
    }
    if which == "table3" || which == "all" {
        println!("== Table III: FPGA vs Titan XP ==\n{}",
                 metrics::table3());
        any = true;
    }
    if which == "fig9" || which == "all" {
        println!("== Fig. 9: 4X latency breakdown ==\n{}",
                 metrics::fig9());
        any = true;
    }
    if which == "fig10" || which == "all" {
        println!("== Fig. 10: 4X buffer usage ==\n{}", metrics::fig10());
        any = true;
    }
    if which == "engine" || which == "all" {
        println!("== engine scaling: 1X @ BS 40, sharded accelerator \
                  instances ==\n{}",
                 metrics::engine_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if which == "cluster" || which == "all" {
        println!("== cluster scaling: 1X @ BS 40, ring all-reduce data \
                  parallelism ==\n{}",
                 metrics::cluster_scaling(1, 40, &[1, 2, 4, 8, 16]));
        any = true;
    }
    if which == "topology" || which == "all" {
        println!("== collective topologies: 1X @ BS 40, ring vs \
                  hierarchical all-reduce ==\n{}",
                 metrics::topology_scaling(1, 40, &[4, 16, 64]));
        any = true;
    }
    if which == "overlap" || which == "all" {
        println!("== bucketed all-reduce overlap: 1X @ BS 64, hidden \
                  vs exposed comm ==\n{}",
                 metrics::overlap_scaling(1, 64, &[4, 16, 64]));
        any = true;
    }
    if which == "serve" {
        // not part of `all`: it reads a serve root, not the paper's
        // models
        let root = args.get("root").ok_or_else(|| {
            anyhow!("report serve needs --root DIR (the serve root \
                     to summarize)")
        })?;
        print!("{}", metrics::serve_report(Path::new(root))?);
        any = true;
    }
    if !any {
        bail!("unknown report `{which}` \
               (table2|table3|fig9|fig10|engine|cluster|topology|\
               overlap|all, or serve --root DIR)");
    }
    Ok(())
}

const USAGE: &str = "\
stratus — compiler-based FPGA CNN-training accelerator (reproduction)

USAGE: stratus <command> [flags]

compile, analyze, simulate, and train also accept
  --spec FILE       load a serialized session::Spec (JSON); explicit
                    flags still override individual fields
  --dump-spec OUT   write the resolved spec to OUT (or - for stdout)
                    and exit without running — `--spec OUT` later
                    reproduces the identical run

COMMANDS:
  compile   --scale 1x|2x|4x | --net FILE   run the RTL compiler
            (--scale bn1x|bn2x|bn4x selects the batch-norm topology;
             BN networks train on the golden backend only)
            [--pox N --poy N --pof N --clock-mhz F --emit-verilog OUT]
            [--no-load-balance --no-double-buffer]
            [--accelerators N  compile an N-instance cluster: emits the
                               all-reduce schedule + control-ROM word
                               and reports aggregate resources]
            [--topology T      collective topology: ring (default),
                               hier (grouped two-level all-reduce), or
                               auto (compiler picks the cheaper plan
                               from the link parameters)]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
            [--link-eff F      link efficiency derate, in (0, 1]]
  analyze   --scale .. [--batch N] [--json]  static fixed-point range
            analysis: worst-case magnitude and bit-width of every i32
            accumulator (FP/BP/WU, per-image and per-batch), with a
            per-row verdict — proven / headroom(N bits) /
            wrap-by-contract / overflow-possible(>= K images).  Exits
            non-zero when any accumulator is overflow-possible (the
            same condition `compile`/`train` refuse at spec-build
            time).  --json emits the machine-readable report
  simulate  --scale .. --batch N            cycle-level simulation
            [--accelerators N  project N data-parallel instances with a
                               gradient all-reduce between batch
                               accumulation and weight update]
            [--topology T      ring|hier|auto collective (see compile)]
            [--link-gbs F      inter-accelerator link bandwidth, GB/s]
            [--link-eff F      link efficiency derate, in (0, 1]]
            [--bucket-kwords N cap per-layer gradient buckets at N
                               kibi-words and overlap their all-reduce
                               with the backward pass (0 = off; a
                               parallelism knob, never fingerprinted)]
  train     --scale .. --backend golden|perop|fused --images N
            --epochs N --batch N --lr F [--eval N]
            [--artifacts DIR   AOT artifact bundle — required by the
                               perop/fused backends (the golden
                               backend runs artifact-free); the eval
                               set is drawn right after the training
                               window, so it never overlaps]
            [--workers N       shard each batch across N engine threads
                               (golden backend; bit-identical results)]
            [--accelerators N  train data-parallel across N simulated
                               accelerator instances with a
                               deterministic collective (golden
                               backend; bit-identical to one instance)]
            [--topology T      ring|hier|auto collective (see compile);
                               any topology trains bit-identically]
            [--bucket-kwords N bucket the cluster merge per layer and
                               launch each bucket as its gradients
                               finalize (bit-identical to monolithic)]
            [--checkpoint-dir D    write crash-safe checkpoints to
                                   D/ckpt.stratus (atomic tmp+rename,
                                   CRC-guarded; see DESIGN.md)]
            [--checkpoint-every N  checkpoint every N batches
                                   (default 50; epoch ends always save)]
            [--resume              continue from D/ckpt.stratus at its
                                   recorded epoch/batch/seed cursor —
                                   bit-identical to never having
                                   stopped, at any worker/accelerator
                                   count]
            [--resize-accelerators N  elastic resize: re-shard this run
                                   onto N instances (with --resume, at
                                   the checkpoint boundary) —
                                   bit-identical to never resizing;
                                   requires --checkpoint-dir]
  serve     --root DIR                 crash-safe experiment service:
            maintains a durable priority queue of submitted specs
            under DIR and time-slices them (each run trains for a
            slice, checkpoints, and swaps out; `kill -9` recovers the
            exact queue, and interrupted runs resume bit-identically)
            [--watch DIR        watched submission dir (default
                                DIR/inbox); drop spec JSONs there,
                                optionally with a top-level
                                \"priority\" integer (higher first)]
            [--stdin            also accept one spec JSON per stdin
                                line]
            [--slice-batches N  batches per time slice (default 8) —
                                the preemption granularity]
            [--active N         runs time-sharing at once (default 2)]
            [--workers-budget N engine-thread budget per slice; specs
                                asking for more are capped
                                (bit-identical — workers are never
                                fingerprinted) (default 4)]
            [--poll-ms N        idle poll interval (default 200)]
            [--drain            exit when queue + inbox are empty]
            [--status           print the queue snapshot and exit]
            Progress streams as JSON lines (also appended to
            DIR/events.jsonl); malformed submissions move to
            DIR/failed/ with a .reason file, never crashing the
            daemon.
  report    table2|table3|fig9|fig10|engine|cluster|topology|overlap|all
            serve --root DIR     summarize a serve root (per-run
                                 phases + aggregate throughput)
  calibrate --scale .. --samples N          adaptive fixed-point pass

Flags that take a value error when the value is missing; unrecognized
flags are rejected.
";

fn run_cli(argv: &[String]) -> Result<()> {
    let cmd = match argv.first() {
        Some(c) if !c.starts_with("--") => c.as_str(),
        _ => bail!("{USAGE}"),
    };
    let Some((value_flags, switches)) = flag_spec(cmd) else {
        bail!("unknown command `{cmd}`\n\n{USAGE}");
    };
    let args = Args::parse(&argv[1..], &value_flags, &switches)
        .map_err(|e| {
            anyhow!("{cmd}: {e:#} (run `stratus` without arguments for \
                     usage)")
        })?;
    match cmd {
        "compile" => cmd_compile(&args),
        "analyze" => cmd_analyze(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "report" => cmd_report(&args),
        "calibrate" => cmd_calibrate(&args),
        _ => unreachable!("flag_spec gates the command set"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run_cli(&argv) {
        eprintln!("error: {e:#}");
        exit(1);
    }
}
