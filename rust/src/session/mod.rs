//! `session` — one validated, serializable experiment description
//! that drives the CLI, the library, the benches, and the checkpoints.
//!
//! The paper's pitch is an *automatic compiler*: the user states the
//! network and the design constraints once and the toolchain derives
//! everything else.  [`Spec`] is that single user-facing artifact on
//! the training side: the network source (a named preset, inline
//! grammar text, or a file), the [`DesignVars`] overrides, the SGD
//! hyper-parameters, the backend, the parallelism, the synthetic-data
//! parameters, and the checkpoint policy — all in one plain-data
//! struct that serializes to JSON (via the vendored [`crate::jsonx`])
//! and back without loss.
//!
//! Three layers:
//!
//! - [`SpecBuilder`] — the only construction path.  `build()` runs
//!   every validation rule that used to be scattered through the CLI's
//!   `cmd_train` (positive counts, backend-vs-batch-norm refusal,
//!   checkpoint-cadence-without-a-directory, resume-without-a-
//!   checkpoint, eval/train window overlap) and returns a typed
//!   [`SpecError`] naming the exact constraint violated.
//! - [`Spec`] — validated plain data.  `render()`/`parse()` round-trip
//!   through JSON; `to_builder()` reopens a spec for overrides (the
//!   CLI's `--spec file.json` + explicit-flag precedence).
//! - [`Session`] — the execution facade: `compile()`, `simulate()`,
//!   `trainer()`, and `train(observer)` / `resume(observer)` (or the
//!   two-phase `begin(resume)` + [`Run::execute`] when the caller
//!   wants to inspect the start cursor first, as the CLI does).
//!
//! # Fingerprint derivation
//!
//! [`fingerprint`] is the canonical serialization of the
//! fingerprint-relevant subset of a resolved Spec — the network (every
//! layer dimension), the loss, the quantized SGD hyper-parameters, and
//! the design variables that feed the simulated-cycle metrics.  Worker
//! and accelerator counts are deliberately excluded (the engine /
//! cluster merge contract makes gradient grouping irrelevant), as are
//! the data/checkpoint fields (the cursor carries those).  The format
//! is byte-identical to the pre-Spec `Trainer::fingerprint` — which
//! now delegates here — so existing `SCKP` version-1 checkpoints
//! resume unchanged (pinned by `tests/session.rs`).
//!
//! # Eval window derivation
//!
//! The evaluation set is drawn *after* the training window: samples
//! `[images, images + eval)` by default.  (The old CLI hard-coded
//! offset 1'000'000, which collided with training data once `--images`
//! reached it.)  An explicit `eval_offset` below the epoch width is
//! rejected as [`SpecError::EvalOverlap`].

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use anyhow::{Context, Result};

use crate::ckpt::Cursor;
use crate::compiler::{Accelerator, RtlCompiler};
use crate::config::{DesignVars, Network, Topology};
use crate::coordinator::{Backend, CheckpointPolicy, EpochStats,
                         ParseBackendError, TrainRun, Trainer};
use crate::data::{Sample, Synthetic};
use crate::jsonx::Json;
use crate::nn::sgd::SgdHyper;
use crate::sim::{simulate, SimReport};

/// Spec file format version (the `"version"` key).
pub const SPEC_VERSION: u32 = 1;

/// Checkpoint file name inside a checkpoint directory.
pub const CKPT_FILE: &str = "ckpt.stratus";

/// Defaults applied by [`SpecBuilder::build`] (matching the historical
/// CLI defaults, so flag-free invocations keep their meaning).
pub const DEFAULT_BATCH: usize = 40;
pub const DEFAULT_LR: f64 = 0.002;
pub const DEFAULT_MOMENTUM: f64 = 0.9;
pub const DEFAULT_EPOCHS: u64 = 5;
pub const DEFAULT_IMAGES: u64 = 512;
pub const DEFAULT_SEED: u64 = 7;
pub const DEFAULT_EVAL: usize = 256;
pub const DEFAULT_NOISE: f64 = 0.3;
pub const DEFAULT_CKPT_EVERY: u64 = 50;

// ---------------- typed validation errors ----------------

/// Every constraint a [`Spec`] can violate, as a typed error.  The
/// Display strings are part of the user-facing contract and pinned by
/// `tests/session.rs`.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// A count field that must be >= 1 was 0.
    NonPositive(&'static str),
    /// A preset scale outside 1x|2x|4x|bn1x|bn2x|bn4x.
    UnknownScale(String),
    /// An unrecognized backend name.
    Backend(ParseBackendError),
    /// The network source failed to read or parse.
    Net(String),
    /// A runtime backend (perop/fused) with no artifacts directory.
    BackendNeedsArtifacts(Backend),
    /// A batch-norm network on a non-golden backend.
    BnNeedsGolden { net: String, backend: Backend },
    /// A checkpoint cadence with nowhere to write checkpoints.
    CheckpointEveryWithoutDir,
    /// A non-positive inter-accelerator link bandwidth.
    LinkBandwidth { given: f64 },
    /// A link efficiency derating factor outside (0, 1].
    LinkEfficiency { given: f64 },
    /// An elastic resize with no checkpoint directory to resize at.
    ResizeWithoutCheckpoint,
    /// Resume requested with no checkpoint directory configured.
    ResumeWithoutCheckpoint,
    /// A slice-bounded run ([`Session::begin_slice`]) with no
    /// checkpoint directory — the slice boundary must land on a
    /// checkpoint or the swapped-out run would lose its progress.
    SliceWithoutCheckpoint,
    /// An explicit seed conflicting with a checkpoint's recorded seed.
    SeedConflict { given: u64, recorded: u64 },
    /// An explicit epoch width conflicting with a checkpoint's.
    ImagesConflict { given: u64, recorded: u64 },
    /// An eval window that would overlap the training window.
    EvalOverlap { offset: u64, images: u64 },
    /// A batch size whose worst-case accumulation provably wraps a
    /// must-stay-exact i32 accumulator — the static range analyzer's
    /// spec gate (see `crate::analysis`; today this fires on the BN
    /// statistic sums of `bn*` nets).
    AccumulatorOverflow {
        layer: String,
        acc: &'static str,
        batch: usize,
        first_wrap: u64,
    },
    /// An unrecognized key in a spec JSON object (strict parsing, like
    /// the CLI's strict flag handling: typos error, never no-op).
    UnknownField { section: &'static str, key: String },
    /// A spec JSON value of the wrong type.
    FieldType { field: String, want: &'static str },
    /// A required spec JSON field that was absent.
    MissingField(&'static str),
    /// A spec file written by a newer format.
    UnsupportedVersion(i64),
    /// A spec JSON node that should have been an object.
    NotAnObject(&'static str),
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::NonPositive(name) => {
                write!(f, "{name} must be at least 1")
            }
            SpecError::UnknownScale(s) => {
                write!(f, "unknown scale `{s}` (use 1x|2x|4x|bn1x|bn2x|\
                           bn4x, or an inline/file network)")
            }
            SpecError::Backend(e) => write!(f, "{e}"),
            SpecError::Net(msg) => {
                write!(f, "invalid network description: {msg}")
            }
            SpecError::BackendNeedsArtifacts(b) => {
                write!(f, "backend {b} needs an artifacts directory \
                           (pass --artifacts DIR or set \"artifacts\" \
                           in the spec; the golden backend runs \
                           artifact-free)")
            }
            SpecError::BnNeedsGolden { net, backend } => {
                write!(f, "network `{net}` contains batch-norm layers, \
                           which are golden-backend-only until Pallas \
                           BN kernels land — backend {backend} cannot \
                           train it")
            }
            SpecError::CheckpointEveryWithoutDir => {
                write!(f, "checkpoint-every needs checkpoint-dir \
                           (where the checkpoints go) — without it \
                           nothing would be saved")
            }
            SpecError::LinkBandwidth { given } => {
                write!(f, "link-gbs must be positive (got {given}) — \
                           the collective cost model divides by the \
                           link bandwidth")
            }
            SpecError::LinkEfficiency { given } => {
                write!(f, "link-eff must be in (0, 1] (got {given}) — \
                           it derates the peak link bandwidth")
            }
            SpecError::ResizeWithoutCheckpoint => {
                write!(f, "resize-accelerators needs checkpoint-dir \
                           (elastic resizing happens at a checkpoint \
                           boundary)")
            }
            SpecError::ResumeWithoutCheckpoint => {
                write!(f, "resume needs checkpoint-dir (where the \
                           checkpoint lives)")
            }
            SpecError::SliceWithoutCheckpoint => {
                write!(f, "a slice-bounded run needs checkpoint-dir \
                           (the slice boundary must land on a \
                           checkpoint so the next slice can resume)")
            }
            SpecError::SeedConflict { given, recorded } => {
                write!(f, "seed {given} conflicts with the \
                           checkpoint's recorded seed {recorded}; \
                           drop the seed override to continue the \
                           recorded run")
            }
            SpecError::ImagesConflict { given, recorded } => {
                write!(f, "images {given} conflicts with the \
                           checkpoint's recorded epoch width \
                           {recorded}; drop the images override to \
                           continue the recorded run")
            }
            SpecError::EvalOverlap { offset, images } => {
                write!(f, "eval window starting at {offset} overlaps \
                           the training window [0, {images}) — raise \
                           eval_offset to at least the epoch width")
            }
            SpecError::AccumulatorOverflow {
                layer,
                acc,
                batch,
                first_wrap,
            } => {
                write!(f, "batch {batch} can wrap the i32 {acc} \
                           accumulator of layer `{layer}` (worst-case \
                           exactness is lost at {first_wrap} images \
                           per batch) — use batch {} or smaller, or \
                           run `stratus analyze` for the full range \
                           report",
                       first_wrap - 1)
            }
            SpecError::UnknownField { section, key } => {
                write!(f, "unknown field `{key}` in {section}")
            }
            SpecError::FieldType { field, want } => {
                write!(f, "{field} wants {want}")
            }
            SpecError::MissingField(name) => {
                write!(f, "missing required field {name}")
            }
            SpecError::UnsupportedVersion(v) => {
                write!(f, "unsupported spec version {v} (this build \
                           reads version {SPEC_VERSION})")
            }
            SpecError::NotAnObject(what) => {
                write!(f, "{what} must be a JSON object")
            }
        }
    }
}

impl std::error::Error for SpecError {}

// ---------------- network source ----------------

/// Where the network description comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum NetSource {
    /// A named CIFAR-family preset: `1x|2x|4x` (and `bn1x|bn2x|bn4x`
    /// for the §IV-B batch-norm topology).
    Preset { scale: String },
    /// Inline text in the layer grammar (see [`Network::parse`]).
    Inline { text: String },
    /// A `.cfg` file in the layer grammar, read at resolution time.
    File { path: PathBuf },
}

impl NetSource {
    pub fn preset(scale: impl Into<String>) -> NetSource {
        NetSource::Preset { scale: scale.into() }
    }

    pub fn inline(text: impl Into<String>) -> NetSource {
        NetSource::Inline { text: text.into() }
    }

    pub fn file(path: impl Into<PathBuf>) -> NetSource {
        NetSource::File { path: path.into() }
    }

    /// Resolve to a [`Network`].
    pub fn resolve(&self) -> Result<Network, SpecError> {
        match self {
            NetSource::Preset { scale } => {
                let (bn, tag) = match scale.strip_prefix("bn") {
                    Some(rest) => (true, rest),
                    None => (false, scale.as_str()),
                };
                let s = match tag {
                    "1x" | "1" => 1,
                    "2x" | "2" => 2,
                    "4x" | "4" => 4,
                    _ => return Err(
                        SpecError::UnknownScale(scale.clone())),
                };
                Ok(if bn {
                    Network::cifar_bn(s)
                } else {
                    Network::cifar(s)
                })
            }
            NetSource::Inline { text } => Network::parse(text)
                .map_err(|e| SpecError::Net(format!("{e:#}"))),
            NetSource::File { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    SpecError::Net(format!("reading {}: {e}",
                                           path.display()))
                })?;
                Network::parse(&text).map_err(|e| {
                    SpecError::Net(format!("{}: {e:#}", path.display()))
                })
            }
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            NetSource::Preset { scale } => {
                m.insert("preset".to_string(), Json::Str(scale.clone()));
            }
            NetSource::Inline { text } => {
                m.insert("inline".to_string(), Json::Str(text.clone()));
            }
            NetSource::File { path } => {
                m.insert("file".to_string(),
                         Json::Str(path.display().to_string()));
            }
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<NetSource, SpecError> {
        let m = j.as_obj().ok_or(SpecError::NotAnObject("net"))?;
        check_keys(m, &["preset", "inline", "file"], "net")?;
        match (m.get("preset"), m.get("inline"), m.get("file")) {
            (Some(p), None, None) => Ok(NetSource::Preset {
                scale: str_value(p, "net.preset")?,
            }),
            (None, Some(t), None) => Ok(NetSource::Inline {
                text: str_value(t, "net.inline")?,
            }),
            (None, None, Some(f)) => Ok(NetSource::File {
                path: PathBuf::from(str_value(f, "net.file")?),
            }),
            _ => Err(SpecError::FieldType {
                field: "net".to_string(),
                want: "exactly one of preset|inline|file",
            }),
        }
    }
}

// ---------------- design overrides ----------------

/// Sparse [`DesignVars`] overrides.  Unset fields keep the per-scale
/// defaults (`DesignVars::for_scale` from the network's scale tag), so
/// a spec stays minimal and scale-portable.  `cluster` is the
/// data-parallel accelerator-instance count (the CLI's
/// `--accelerators`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DesignOverrides {
    pub pox: Option<usize>,
    pub poy: Option<usize>,
    pub pof: Option<usize>,
    pub clock_mhz: Option<f64>,
    pub dram_gbytes: Option<f64>,
    pub tile_rows: Option<usize>,
    pub cluster: Option<usize>,
    pub link_gbytes: Option<f64>,
    pub link_efficiency: Option<f64>,
    pub topology: Option<Topology>,
    /// Gradient-bucket size cap in kibi-words for the overlapped
    /// cluster all-reduce (0 = monolithic serial epilogue).  A
    /// parallelism knob like `cluster`/`topology`: excluded from the
    /// checkpoint fingerprint.
    pub bucket_kwords: Option<usize>,
    pub load_balance: Option<bool>,
    pub double_buffer: Option<bool>,
}

impl DesignOverrides {
    /// Apply onto per-scale defaults.
    pub fn apply(&self, dv: &mut DesignVars) {
        if let Some(v) = self.pox { dv.pox = v; }
        if let Some(v) = self.poy { dv.poy = v; }
        if let Some(v) = self.pof { dv.pof = v; }
        if let Some(v) = self.clock_mhz { dv.clock_mhz = v; }
        if let Some(v) = self.dram_gbytes { dv.dram_gbytes = v; }
        if let Some(v) = self.tile_rows { dv.tile_rows = v; }
        if let Some(v) = self.cluster { dv.cluster = v; }
        if let Some(v) = self.link_gbytes { dv.link_gbytes = v; }
        if let Some(v) = self.link_efficiency {
            dv.link_efficiency = v;
        }
        if let Some(v) = self.topology { dv.topology = v; }
        if let Some(v) = self.bucket_kwords { dv.bucket_kwords = v; }
        if let Some(v) = self.load_balance { dv.load_balance = v; }
        if let Some(v) = self.double_buffer { dv.double_buffer = v; }
    }

    fn is_empty(&self) -> bool {
        *self == DesignOverrides::default()
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let mut us = |k: &str, v: Option<usize>| {
            if let Some(v) = v {
                m.insert(k.to_string(), Json::Num(v as f64));
            }
        };
        us("pox", self.pox);
        us("poy", self.poy);
        us("pof", self.pof);
        us("tile_rows", self.tile_rows);
        us("cluster", self.cluster);
        us("bucket_kwords", self.bucket_kwords);
        let mut fs = |k: &str, v: Option<f64>| {
            if let Some(v) = v {
                m.insert(k.to_string(), Json::Num(v));
            }
        };
        fs("clock_mhz", self.clock_mhz);
        fs("dram_gbytes", self.dram_gbytes);
        fs("link_gbytes", self.link_gbytes);
        fs("link_efficiency", self.link_efficiency);
        if let Some(v) = self.topology {
            m.insert("topology".to_string(),
                     Json::Str(v.to_string()));
        }
        if let Some(v) = self.load_balance {
            m.insert("load_balance".to_string(), Json::Bool(v));
        }
        if let Some(v) = self.double_buffer {
            m.insert("double_buffer".to_string(), Json::Bool(v));
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<DesignOverrides, SpecError> {
        let m = j.as_obj().ok_or(SpecError::NotAnObject("design"))?;
        check_keys(m,
                   &["pox", "poy", "pof", "clock_mhz", "dram_gbytes",
                     "tile_rows", "cluster", "link_gbytes",
                     "link_efficiency", "topology", "bucket_kwords",
                     "load_balance", "double_buffer"],
                   "design")?;
        let topology = match m.get("topology") {
            None => None,
            Some(j) => {
                let s = str_value(j, "design.topology")?;
                Some(s.parse::<Topology>().map_err(|_| {
                    SpecError::FieldType {
                        field: "design.topology".to_string(),
                        want: "ring|hier|auto",
                    }
                })?)
            }
        };
        Ok(DesignOverrides {
            pox: usize_key(m, "pox", "design")?,
            poy: usize_key(m, "poy", "design")?,
            pof: usize_key(m, "pof", "design")?,
            clock_mhz: f64_key(m, "clock_mhz", "design")?,
            dram_gbytes: f64_key(m, "dram_gbytes", "design")?,
            tile_rows: usize_key(m, "tile_rows", "design")?,
            cluster: usize_key(m, "cluster", "design")?,
            link_gbytes: f64_key(m, "link_gbytes", "design")?,
            link_efficiency: f64_key(m, "link_efficiency", "design")?,
            topology,
            bucket_kwords: usize_key(m, "bucket_kwords", "design")?,
            load_balance: bool_key(m, "load_balance", "design")?,
            double_buffer: bool_key(m, "double_buffer", "design")?,
        })
    }
}

/// Checkpoint policy: where checkpoints go and how often.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointSpec {
    /// Directory holding `ckpt.stratus` (created on first use).
    pub dir: PathBuf,
    /// Save every N batches (epoch ends always save).
    pub every_batches: u64,
}

// ---------------- the spec ----------------

/// One validated experiment description.  Construct through
/// [`Spec::builder`] (or [`Spec::parse`] for JSON text) — both run the
/// full validation rule set, so a `Spec` value in hand is always
/// internally consistent.
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    pub net: NetSource,
    pub backend: Backend,
    /// AOT artifact bundle for the perop/fused backends; required for
    /// them, optional (and unused by the numerics) for golden.
    pub artifacts: Option<PathBuf>,
    pub design: DesignOverrides,
    pub batch: usize,
    pub lr: f64,
    pub momentum: f64,
    pub epochs: u64,
    /// Epoch width in images.  `None` means "the default
    /// ([`DEFAULT_IMAGES`]) for fresh runs, the recorded width for
    /// resumed ones" — an explicit value conflicting with a resumed
    /// checkpoint is refused ([`SpecError::ImagesConflict`]).
    pub images: Option<u64>,
    /// Dataset seed, with the same explicit-vs-recorded semantics as
    /// `images` ([`SpecError::SeedConflict`]).
    pub seed: Option<u64>,
    /// Evaluation set size.
    pub eval: usize,
    /// First eval sample index; `None` derives the epoch width (the
    /// eval window starts where the training window ends).
    pub eval_offset: Option<u64>,
    /// Synthetic dataset noise amplitude.
    pub noise: f64,
    /// Engine worker threads per accelerator instance.
    pub workers: usize,
    pub checkpoint: Option<CheckpointSpec>,
    pub resume: bool,
    /// Re-shard the run onto this many accelerator instances at the
    /// next checkpoint boundary (the resume point).  The fingerprint
    /// deliberately excludes accelerator counts, so the resized run
    /// continues bit-identically; requires a checkpoint directory.
    pub resize_accelerators: Option<usize>,
}

impl Spec {
    pub fn builder() -> SpecBuilder {
        SpecBuilder::default()
    }

    /// Resolve the network and design variables with every structural
    /// rule applied EXCEPT the range-analyzer overflow gate —
    /// `stratus analyze` reports on wrapping specs instead of refusing
    /// to look at them.  [`SpecBuilder::build`] and [`Session::new`]
    /// run the gate on top of this.
    pub fn resolve_for_analysis(
        &self,
    ) -> Result<(Network, DesignVars), SpecError> {
        resolve(self)
    }

    /// Reopen for overrides (e.g. `--spec file.json` + explicit flags).
    pub fn to_builder(&self) -> SpecBuilder {
        SpecBuilder {
            net: Some(self.net.clone()),
            backend: Some(self.backend),
            artifacts: self.artifacts.clone(),
            design: self.design.clone(),
            batch: Some(self.batch),
            lr: Some(self.lr),
            momentum: Some(self.momentum),
            epochs: Some(self.epochs),
            images: self.images,
            seed: self.seed,
            eval: Some(self.eval),
            eval_offset: self.eval_offset,
            noise: Some(self.noise),
            workers: Some(self.workers),
            checkpoint_dir: self.checkpoint.as_ref()
                .map(|c| c.dir.clone()),
            checkpoint_every: self.checkpoint.as_ref()
                .map(|c| c.every_batches),
            resume: self.resume,
            resize_accelerators: self.resize_accelerators,
        }
    }

    /// Serialize to the spec JSON schema (see DESIGN.md §Session API).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("version".to_string(),
                    Json::Num(f64::from(SPEC_VERSION)));
        root.insert("net".to_string(), self.net.to_json());
        root.insert("backend".to_string(),
                    Json::Str(self.backend.to_string()));
        if let Some(a) = &self.artifacts {
            root.insert("artifacts".to_string(),
                        Json::Str(a.display().to_string()));
        }
        if !self.design.is_empty() {
            root.insert("design".to_string(), self.design.to_json());
        }
        let mut hyper = BTreeMap::new();
        hyper.insert("batch".to_string(),
                     Json::Num(self.batch as f64));
        hyper.insert("lr".to_string(), Json::Num(self.lr));
        hyper.insert("momentum".to_string(), Json::Num(self.momentum));
        root.insert("hyper".to_string(), Json::Obj(hyper));
        let mut run = BTreeMap::new();
        run.insert("epochs".to_string(),
                   Json::Num(self.epochs as f64));
        if let Some(v) = self.images {
            run.insert("images".to_string(), Json::Num(v as f64));
        }
        if let Some(v) = self.seed {
            run.insert("seed".to_string(), Json::Num(v as f64));
        }
        run.insert("eval".to_string(), Json::Num(self.eval as f64));
        if let Some(v) = self.eval_offset {
            run.insert("eval_offset".to_string(), Json::Num(v as f64));
        }
        run.insert("noise".to_string(), Json::Num(self.noise));
        run.insert("workers".to_string(),
                   Json::Num(self.workers as f64));
        root.insert("run".to_string(), Json::Obj(run));
        if let Some(ck) = &self.checkpoint {
            let mut c = BTreeMap::new();
            c.insert("dir".to_string(),
                     Json::Str(ck.dir.display().to_string()));
            c.insert("every_batches".to_string(),
                     Json::Num(ck.every_batches as f64));
            if self.resume {
                c.insert("resume".to_string(), Json::Bool(true));
            }
            if let Some(n) = self.resize_accelerators {
                c.insert("resize_accelerators".to_string(),
                         Json::Num(n as f64));
            }
            root.insert("checkpoint".to_string(), Json::Obj(c));
        }
        Json::Obj(root)
    }

    /// Pretty-printed, re-parseable JSON (what `--dump-spec` writes).
    pub fn render(&self) -> String {
        self.to_json().pretty()
    }

    /// Parse and validate spec JSON text.
    pub fn parse(text: &str) -> Result<Spec> {
        let j = Json::parse(text).context("parsing spec JSON")?;
        Ok(Spec::from_json(&j)?)
    }

    /// Read, parse, and validate a spec file.
    pub fn load(path: &Path) -> Result<Spec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Spec::parse(&text)
            .with_context(|| format!("in {}", path.display()))
    }

    /// Write the rendered spec to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.render())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Build a validated spec from a parsed JSON value.  Strict: an
    /// unknown key anywhere is an error, never a silent no-op.
    pub fn from_json(j: &Json) -> Result<Spec, SpecError> {
        let root = j.as_obj().ok_or(SpecError::NotAnObject("spec"))?;
        check_keys(root,
                   &["version", "net", "backend", "artifacts",
                     "design", "hyper", "run", "checkpoint"],
                   "the spec")?;
        if let Some(v) = root.get("version") {
            let n = v.as_f64().ok_or(SpecError::FieldType {
                field: "version".to_string(),
                want: "an integer",
            })?;
            if n != f64::from(SPEC_VERSION) {
                return Err(SpecError::UnsupportedVersion(n as i64));
            }
        }
        let mut b = Spec::builder();
        let net = root.get("net").ok_or(SpecError::MissingField("net"))?;
        b = b.net(NetSource::from_json(net)?);
        if let Some(v) = root.get("backend") {
            let s = str_value(v, "backend")?;
            b = b.backend(Backend::from_str(&s)
                .map_err(SpecError::Backend)?);
        }
        if let Some(v) = root.get("artifacts") {
            b = b.artifacts(str_value(v, "artifacts")?);
        }
        if let Some(v) = root.get("design") {
            b = b.design(DesignOverrides::from_json(v)?);
        }
        if let Some(v) = root.get("hyper") {
            let m = v.as_obj().ok_or(SpecError::NotAnObject("hyper"))?;
            check_keys(m, &["batch", "lr", "momentum"], "hyper")?;
            if let Some(x) = usize_key(m, "batch", "hyper")? {
                b = b.batch(x);
            }
            if let Some(x) = f64_key(m, "lr", "hyper")? {
                b = b.lr(x);
            }
            if let Some(x) = f64_key(m, "momentum", "hyper")? {
                b = b.momentum(x);
            }
        }
        if let Some(v) = root.get("run") {
            let m = v.as_obj().ok_or(SpecError::NotAnObject("run"))?;
            check_keys(m,
                       &["epochs", "images", "seed", "eval",
                         "eval_offset", "noise", "workers"],
                       "run")?;
            if let Some(x) = u64_key(m, "epochs", "run")? {
                b = b.epochs(x);
            }
            if let Some(x) = u64_key(m, "images", "run")? {
                b = b.images(x);
            }
            if let Some(x) = u64_key(m, "seed", "run")? {
                b = b.seed(x);
            }
            if let Some(x) = usize_key(m, "eval", "run")? {
                b = b.eval(x);
            }
            if let Some(x) = u64_key(m, "eval_offset", "run")? {
                b = b.eval_offset(x);
            }
            if let Some(x) = f64_key(m, "noise", "run")? {
                b = b.noise(x);
            }
            if let Some(x) = usize_key(m, "workers", "run")? {
                b = b.workers(x);
            }
        }
        if let Some(v) = root.get("checkpoint") {
            let m = v.as_obj()
                .ok_or(SpecError::NotAnObject("checkpoint"))?;
            check_keys(m,
                       &["dir", "every_batches", "resume",
                         "resize_accelerators"],
                       "checkpoint")?;
            let dir = m.get("dir")
                .ok_or(SpecError::MissingField("checkpoint.dir"))?;
            b = b.checkpoint_dir(str_value(dir, "checkpoint.dir")?);
            if let Some(x) = u64_key(m, "every_batches", "checkpoint")? {
                b = b.checkpoint_every(x);
            }
            if let Some(x) = bool_key(m, "resume", "checkpoint")? {
                b = b.resume(x);
            }
            if let Some(x) =
                usize_key(m, "resize_accelerators", "checkpoint")?
            {
                b = b.resize_accelerators(x);
            }
        }
        b.build()
    }
}

// ---------------- strict-JSON helpers ----------------

fn check_keys(m: &BTreeMap<String, Json>, allowed: &[&str],
              section: &'static str) -> Result<(), SpecError> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(SpecError::UnknownField {
                section,
                key: k.clone(),
            });
        }
    }
    Ok(())
}

fn qualify(section: &str, key: &str) -> String {
    format!("{section}.{key}")
}

fn str_value(j: &Json, field: &str) -> Result<String, SpecError> {
    j.as_str().map(str::to_string).ok_or(SpecError::FieldType {
        field: field.to_string(),
        want: "a string",
    })
}

fn f64_key(m: &BTreeMap<String, Json>, key: &str, section: &str)
           -> Result<Option<f64>, SpecError> {
    match m.get(key) {
        None => Ok(None),
        Some(j) => j.as_f64().map(Some).ok_or(SpecError::FieldType {
            field: qualify(section, key),
            want: "a number",
        }),
    }
}

/// Largest u64 a JSON number (f64) represents exactly; bigger values
/// would silently round on serialization, so both the parser and
/// [`validate`] refuse them.
const MAX_EXACT_JSON_INT: u64 = 1 << 53;

fn u64_key(m: &BTreeMap<String, Json>, key: &str, section: &str)
           -> Result<Option<u64>, SpecError> {
    match f64_key(m, key, section)? {
        None => Ok(None),
        Some(n) if n >= 0.0
            && n.fract() == 0.0
            && n <= MAX_EXACT_JSON_INT as f64 =>
        {
            Ok(Some(n as u64))
        }
        Some(_) => Err(SpecError::FieldType {
            field: qualify(section, key),
            want: "a non-negative integer at most 2^53",
        }),
    }
}

fn usize_key(m: &BTreeMap<String, Json>, key: &str, section: &str)
             -> Result<Option<usize>, SpecError> {
    Ok(u64_key(m, key, section)?.map(|v| v as usize))
}

fn bool_key(m: &BTreeMap<String, Json>, key: &str, section: &str)
            -> Result<Option<bool>, SpecError> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(SpecError::FieldType {
            field: qualify(section, key),
            want: "a boolean",
        }),
    }
}

// ---------------- the builder ----------------

/// Builder for [`Spec`] — the single construction path.  Unset fields
/// default per the `DEFAULT_*` constants; `build()` validates every
/// constraint and returns a typed [`SpecError`] on violation.
#[derive(Debug, Clone, Default)]
pub struct SpecBuilder {
    net: Option<NetSource>,
    backend: Option<Backend>,
    artifacts: Option<PathBuf>,
    design: DesignOverrides,
    batch: Option<usize>,
    lr: Option<f64>,
    momentum: Option<f64>,
    epochs: Option<u64>,
    images: Option<u64>,
    seed: Option<u64>,
    eval: Option<usize>,
    eval_offset: Option<u64>,
    noise: Option<f64>,
    workers: Option<usize>,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_every: Option<u64>,
    resume: bool,
    resize_accelerators: Option<usize>,
}

impl SpecBuilder {
    pub fn net(mut self, src: NetSource) -> SpecBuilder {
        self.net = Some(src);
        self
    }

    /// Named preset: `1x|2x|4x|bn1x|bn2x|bn4x`.
    pub fn preset(self, scale: impl Into<String>) -> SpecBuilder {
        self.net(NetSource::preset(scale))
    }

    /// Inline network text in the layer grammar.
    pub fn net_inline(self, text: impl Into<String>) -> SpecBuilder {
        self.net(NetSource::inline(text))
    }

    /// Network `.cfg` file path.
    pub fn net_file(self, path: impl Into<PathBuf>) -> SpecBuilder {
        self.net(NetSource::file(path))
    }

    pub fn backend(mut self, backend: Backend) -> SpecBuilder {
        self.backend = Some(backend);
        self
    }

    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> SpecBuilder {
        self.artifacts = Some(dir.into());
        self
    }

    /// Replace the whole override set (spec-file parsing).
    pub fn design(mut self, d: DesignOverrides) -> SpecBuilder {
        self.design = d;
        self
    }

    pub fn pox(mut self, v: usize) -> SpecBuilder {
        self.design.pox = Some(v);
        self
    }

    pub fn poy(mut self, v: usize) -> SpecBuilder {
        self.design.poy = Some(v);
        self
    }

    pub fn pof(mut self, v: usize) -> SpecBuilder {
        self.design.pof = Some(v);
        self
    }

    pub fn clock_mhz(mut self, v: f64) -> SpecBuilder {
        self.design.clock_mhz = Some(v);
        self
    }

    pub fn dram_gbytes(mut self, v: f64) -> SpecBuilder {
        self.design.dram_gbytes = Some(v);
        self
    }

    pub fn tile_rows(mut self, v: usize) -> SpecBuilder {
        self.design.tile_rows = Some(v);
        self
    }

    /// Data-parallel accelerator instances (`DesignVars::cluster`).
    pub fn accelerators(mut self, v: usize) -> SpecBuilder {
        self.design.cluster = Some(v);
        self
    }

    pub fn link_gbytes(mut self, v: f64) -> SpecBuilder {
        self.design.link_gbytes = Some(v);
        self
    }

    /// Link bandwidth derating factor, in (0, 1].
    pub fn link_efficiency(mut self, v: f64) -> SpecBuilder {
        self.design.link_efficiency = Some(v);
        self
    }

    /// Collective all-reduce topology (`DesignVars::topology`).
    pub fn topology(mut self, v: Topology) -> SpecBuilder {
        self.design.topology = Some(v);
        self
    }

    /// Gradient-bucket size cap in kibi-words for the overlapped
    /// cluster all-reduce (`DesignVars::bucket_kwords`; 0 = off).
    pub fn bucket_kwords(mut self, v: usize) -> SpecBuilder {
        self.design.bucket_kwords = Some(v);
        self
    }

    pub fn load_balance(mut self, v: bool) -> SpecBuilder {
        self.design.load_balance = Some(v);
        self
    }

    pub fn double_buffer(mut self, v: bool) -> SpecBuilder {
        self.design.double_buffer = Some(v);
        self
    }

    pub fn batch(mut self, v: usize) -> SpecBuilder {
        self.batch = Some(v);
        self
    }

    pub fn lr(mut self, v: f64) -> SpecBuilder {
        self.lr = Some(v);
        self
    }

    pub fn momentum(mut self, v: f64) -> SpecBuilder {
        self.momentum = Some(v);
        self
    }

    pub fn epochs(mut self, v: u64) -> SpecBuilder {
        self.epochs = Some(v);
        self
    }

    pub fn images(mut self, v: u64) -> SpecBuilder {
        self.images = Some(v);
        self
    }

    pub fn seed(mut self, v: u64) -> SpecBuilder {
        self.seed = Some(v);
        self
    }

    pub fn eval(mut self, v: usize) -> SpecBuilder {
        self.eval = Some(v);
        self
    }

    pub fn eval_offset(mut self, v: u64) -> SpecBuilder {
        self.eval_offset = Some(v);
        self
    }

    pub fn noise(mut self, v: f64) -> SpecBuilder {
        self.noise = Some(v);
        self
    }

    pub fn workers(mut self, v: usize) -> SpecBuilder {
        self.workers = Some(v);
        self
    }

    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>)
                          -> SpecBuilder {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    pub fn checkpoint_every(mut self, v: u64) -> SpecBuilder {
        self.checkpoint_every = Some(v);
        self
    }

    pub fn resume(mut self, v: bool) -> SpecBuilder {
        self.resume = v;
        self
    }

    /// Re-shard onto `v` accelerator instances at the next checkpoint
    /// boundary (see [`Spec::resize_accelerators`]).
    pub fn resize_accelerators(mut self, v: usize) -> SpecBuilder {
        self.resize_accelerators = Some(v);
        self
    }

    /// Apply defaults, validate every constraint, and produce the
    /// [`Spec`].
    pub fn build(self) -> Result<Spec, SpecError> {
        let spec = self.assemble()?;
        validate(&spec)?;
        Ok(spec)
    }

    /// Like [`SpecBuilder::build`], but stops short of the range
    /// analyzer's overflow gate: structural validation still runs
    /// (unknown preset, zero batch, checkpoint wiring, ...), while a
    /// spec whose accumulators provably wrap is *returned* rather
    /// than refused, together with the resolved network and design
    /// variables.  This is what `stratus analyze` uses so it can
    /// report on exactly the specs that [`SpecBuilder::build`] would
    /// reject.
    pub fn build_for_analysis(
        self,
    ) -> Result<(Spec, Network, DesignVars), SpecError> {
        let spec = self.assemble()?;
        let (net, dv) = resolve(&spec)?;
        Ok((spec, net, dv))
    }

    /// Apply defaults and produce the raw [`Spec`] (no resolution or
    /// range analysis beyond builder-local consistency checks).
    fn assemble(self) -> Result<Spec, SpecError> {
        if self.checkpoint_dir.is_none()
            && self.checkpoint_every.is_some()
        {
            return Err(SpecError::CheckpointEveryWithoutDir);
        }
        let spec = Spec {
            net: self.net
                .unwrap_or_else(|| NetSource::preset("1x")),
            backend: self.backend.unwrap_or(Backend::Golden),
            artifacts: self.artifacts,
            design: self.design,
            batch: self.batch.unwrap_or(DEFAULT_BATCH),
            lr: self.lr.unwrap_or(DEFAULT_LR),
            momentum: self.momentum.unwrap_or(DEFAULT_MOMENTUM),
            epochs: self.epochs.unwrap_or(DEFAULT_EPOCHS),
            images: self.images,
            seed: self.seed,
            eval: self.eval.unwrap_or(DEFAULT_EVAL),
            eval_offset: self.eval_offset,
            noise: self.noise.unwrap_or(DEFAULT_NOISE),
            workers: self.workers.unwrap_or(1),
            checkpoint: self.checkpoint_dir.map(|dir| CheckpointSpec {
                dir,
                every_batches: self.checkpoint_every
                    .unwrap_or(DEFAULT_CKPT_EVERY),
            }),
            resume: self.resume,
            resize_accelerators: self.resize_accelerators,
        };
        Ok(spec)
    }
}

/// The full validation rule set (shared by [`SpecBuilder::build`] and
/// [`Session::new`]); returns the resolved network + design variables.
/// On top of the structural rules in [`Spec::resolve_for_analysis`]
/// this runs the static fixed-point range analyzer and refuses any
/// spec whose must-stay-exact accumulators can provably wrap — the
/// PR-4 BN moment overflow class becomes a typed build-time error
/// instead of silently poisoned statistics.
fn validate(spec: &Spec) -> Result<(Network, DesignVars), SpecError> {
    let (net, dv) = resolve(spec)?;
    let report = crate::analysis::analyze(&net, &dv, spec.batch);
    if let Some(row) = report.first_overflow() {
        let crate::analysis::Verdict::OverflowPossible {
            first_wrap_images,
        } = row.verdict
        else {
            unreachable!("first_overflow returns overflow rows only")
        };
        return Err(SpecError::AccumulatorOverflow {
            layer: row.layer.clone(),
            acc: row.acc,
            batch: spec.batch,
            first_wrap: first_wrap_images,
        });
    }
    Ok((net, dv))
}

/// The structural rule set: everything [`validate`] checks except the
/// range-analyzer overflow gate.
fn resolve(spec: &Spec) -> Result<(Network, DesignVars), SpecError> {
    fn positive(v: usize, name: &'static str) -> Result<(), SpecError> {
        if v == 0 {
            Err(SpecError::NonPositive(name))
        } else {
            Ok(())
        }
    }
    positive(spec.batch, "batch")?;
    positive(spec.eval, "eval")?;
    positive(spec.workers, "workers")?;
    if spec.epochs == 0 {
        return Err(SpecError::NonPositive("epochs"));
    }
    if spec.images == Some(0) {
        return Err(SpecError::NonPositive("images"));
    }
    if let Some(ck) = &spec.checkpoint {
        if ck.every_batches == 0 {
            return Err(SpecError::NonPositive("checkpoint-every"));
        }
    }
    if spec.resume && spec.checkpoint.is_none() {
        return Err(SpecError::ResumeWithoutCheckpoint);
    }
    // serializability guards: u64 fields must survive the JSON f64
    // round trip exactly, and floats must be finite (JSON has no
    // inf/NaN — a dumped spec would not parse back)
    for (v, name) in [(Some(spec.epochs), "epochs"),
                      (spec.images, "images"),
                      (spec.seed, "seed"),
                      (spec.eval_offset, "eval_offset")] {
        if let Some(v) = v {
            if v > MAX_EXACT_JSON_INT {
                return Err(SpecError::FieldType {
                    field: name.to_string(),
                    want: "an integer at most 2^53 (JSON numbers \
                           round-trip exactly only up to that)",
                });
            }
        }
    }
    for (v, name) in [(Some(spec.lr), "lr"),
                      (Some(spec.momentum), "momentum"),
                      (Some(spec.noise), "noise"),
                      (spec.design.clock_mhz, "clock_mhz"),
                      (spec.design.dram_gbytes, "dram_gbytes"),
                      (spec.design.link_gbytes, "link_gbytes"),
                      (spec.design.link_efficiency,
                       "link_efficiency")] {
        if let Some(v) = v {
            if !v.is_finite() {
                return Err(SpecError::FieldType {
                    field: name.to_string(),
                    want: "a finite number",
                });
            }
        }
    }
    // the collective cost model divides by the effective link
    // bandwidth — zero/negative bandwidth or a derating factor outside
    // (0, 1] would poison every topology decision
    if let Some(v) = spec.design.link_gbytes {
        if v <= 0.0 {
            return Err(SpecError::LinkBandwidth { given: v });
        }
    }
    if let Some(v) = spec.design.link_efficiency {
        if v <= 0.0 || v > 1.0 {
            return Err(SpecError::LinkEfficiency { given: v });
        }
    }
    if spec.resize_accelerators == Some(0) {
        return Err(SpecError::NonPositive("resize-accelerators"));
    }
    if spec.resize_accelerators.is_some() && spec.checkpoint.is_none() {
        return Err(SpecError::ResizeWithoutCheckpoint);
    }
    if spec.backend != Backend::Golden && spec.artifacts.is_none() {
        return Err(SpecError::BackendNeedsArtifacts(spec.backend));
    }
    for (v, name) in [(spec.design.pox, "pox"),
                      (spec.design.poy, "poy"),
                      (spec.design.pof, "pof"),
                      (spec.design.tile_rows, "tile-rows"),
                      (spec.design.cluster, "accelerators")] {
        if v == Some(0) {
            return Err(SpecError::NonPositive(name));
        }
    }
    let net = spec.net.resolve()?;
    if net.has_stats() && spec.backend != Backend::Golden {
        return Err(SpecError::BnNeedsGolden {
            net: net.name.clone(),
            backend: spec.backend,
        });
    }
    if let (Some(offset), Some(images)) =
        (spec.eval_offset, spec.images)
    {
        if offset < images {
            return Err(SpecError::EvalOverlap { offset, images });
        }
    }
    let scale = match net.scale_tag() {
        "4x" => 4,
        "2x" => 2,
        _ => 1,
    };
    let mut dv = DesignVars::for_scale(scale);
    spec.design.apply(&mut dv);
    Ok((net, dv))
}

// ---------------- fingerprint ----------------

/// Canonical serialization of the fingerprint-relevant Spec subset:
/// everything that must match for a resumed run to continue
/// bit-identically — the network (every layer dimension), the loss,
/// the quantized SGD hyper-parameters, the design variables that
/// feed the simulated-cycle metrics, and the dataset noise amplitude
/// (the one data parameter not already recorded in the cursor; a
/// resume with a different `noise` would silently train on different
/// pixels).  Worker and accelerator counts are deliberately
/// **excluded** — the engine/cluster merge contract makes gradient
/// grouping irrelevant, so a checkpoint taken at any
/// `workers`/`accelerators` resumes at any other.  The format is
/// byte-compatible with pre-Spec checkpoints (`Trainer::fingerprint`
/// delegates here; pinned by `tests/session.rs`): the noise term is
/// appended only when it differs from the historical hard-coded
/// [`DEFAULT_NOISE`], so every checkpoint written before noise was
/// configurable still matches default-noise runs byte-for-byte.
pub fn fingerprint(net: &Network, dv: &DesignVars, hyper: &SgdHyper,
                   noise: f64) -> String {
    let layers: Vec<String> =
        net.layers.iter().map(|l| format!("{l:?}")).collect();
    let mut s = format!(
        "stratus-ckpt net={} input={:?} nclass={} loss={:?} \
         layers=[{}] hyper(lr_q16={},beta_q15={},batch={}) \
         dv(pox={},poy={},pof={},clock_mhz={},dram_gbytes={},\
         dram_efficiency={},load_balance={},double_buffer={},\
         tile_rows={},data_bits={})",
        net.name,
        net.input,
        net.nclass,
        net.loss,
        layers.join(";"),
        hyper.lr_q16,
        hyper.beta_q15,
        hyper.batch,
        dv.pox,
        dv.poy,
        dv.pof,
        dv.clock_mhz,
        dv.dram_gbytes,
        dv.dram_efficiency,
        dv.load_balance,
        dv.double_buffer,
        dv.tile_rows,
        dv.data_bits,
    );
    if noise != DEFAULT_NOISE {
        s.push_str(&format!(" data(noise={noise})"));
    }
    s
}

// ---------------- the session facade ----------------

/// A [`Spec`] resolved against its network and design point, ready to
/// compile, simulate, or train.
pub struct Session {
    spec: Spec,
    net: Network,
    dv: DesignVars,
}

/// The sample sets a [`Run`] evaluates against, handed to the epoch
/// observer: the training window and the (non-overlapping) eval
/// window.
pub struct EvalData<'a> {
    pub train: &'a [Sample],
    pub eval: &'a [Sample],
}

/// What a completed (or already-complete) run hands back.
pub struct TrainOutcome {
    /// The trained (or restored) trainer, for inspection.
    pub trainer: Trainer,
    /// Where the run started (fresh: epoch 0; resumed: the
    /// checkpoint's cursor).
    pub start: Cursor,
    /// Where the run ended.
    pub end: Cursor,
}

/// A prepared training run: trainer built (and restored, when
/// resuming), dataset + eval windows derived, checkpoint directory
/// created.  [`Run::execute`] drives it to completion.
pub struct Run {
    trainer: Trainer,
    start: Cursor,
    data: Synthetic,
    cfg: TrainRun,
    train_set: Vec<Sample>,
    eval_set: Vec<Sample>,
}

impl Run {
    pub fn start(&self) -> Cursor {
        self.start
    }

    /// True when the start cursor already covers every requested epoch
    /// (a resume of a finished run); `execute` is then a no-op.
    pub fn finished(&self) -> bool {
        self.start.epoch >= self.cfg.epochs
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    pub fn train_set(&self) -> &[Sample] {
        &self.train_set
    }

    pub fn eval_set(&self) -> &[Sample] {
        &self.eval_set
    }

    /// Lower the batch bound without touching the checkpoint cadence.
    /// This is the chaos-test hook for `stratus serve`: a run capped
    /// below its slice length stops where a `kill -9` would have,
    /// with only whatever checkpoints the cadence (and epoch
    /// boundaries) already put on disk — recovery then replays from
    /// the newest one, bit-identically.
    pub fn cap_batches(mut self, n: u64) -> Run {
        let cap = self.cfg.max_batches.map_or(n, |m| m.min(n));
        self.cfg.max_batches = Some(cap);
        self
    }

    /// Train to completion, invoking `on_epoch` at every epoch
    /// boundary (after that epoch's checkpoint is on disk).
    pub fn execute(
        self,
        mut on_epoch: impl FnMut(&mut Trainer, &EpochStats, &EvalData)
                             -> Result<()>,
    ) -> Result<TrainOutcome> {
        let Run { mut trainer, start, data, cfg, train_set, eval_set } =
            self;
        if start.epoch >= cfg.epochs {
            return Ok(TrainOutcome { trainer, start, end: start });
        }
        let end = trainer.run(&data, &cfg, start, |t, stats| {
            let ev = EvalData { train: &train_set, eval: &eval_set };
            on_epoch(t, stats, &ev)
        })?;
        Ok(TrainOutcome { trainer, start, end })
    }
}

impl Session {
    /// Resolve and re-validate a spec (specs from `SpecBuilder::build`
    /// / `Spec::parse` are already valid; this also covers hand-built
    /// `Spec` values).
    pub fn new(spec: Spec) -> Result<Session> {
        let (net, dv) = validate(&spec)?;
        Ok(Session { spec, net, dv })
    }

    pub fn spec(&self) -> &Spec {
        &self.spec
    }

    /// The resolved network description.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The resolved design variables (per-scale defaults + overrides).
    pub fn design(&self) -> &DesignVars {
        &self.dv
    }

    /// The checkpoint file this session reads/writes, if any.
    pub fn checkpoint_path(&self) -> Option<PathBuf> {
        self.spec.checkpoint.as_ref().map(|c| c.dir.join(CKPT_FILE))
    }

    /// This session's run fingerprint (see [`fingerprint`]); equal to
    /// `self.trainer()?.fingerprint()` without building a trainer.
    pub fn fingerprint(&self) -> String {
        let hyper = SgdHyper::new(self.spec.lr, self.spec.momentum,
                                  self.spec.batch);
        fingerprint(&self.net, &self.dv, &hyper, self.spec.noise)
    }

    /// Run the RTL compiler on the resolved (network, design) pair.
    pub fn compile(&self) -> Result<Accelerator> {
        RtlCompiler::default().compile(&self.net, &self.dv)
    }

    /// Cycle-simulate the compiled design at the spec's batch size.
    pub fn simulate(&self) -> Result<SimReport> {
        Ok(simulate(&self.compile()?, self.spec.batch))
    }

    /// Build the configured trainer (the only construction path for
    /// `Trainer` outside this crate): backend, artifacts, hyper, and
    /// worker count from the spec; the accelerator-instance count
    /// rides in through `DesignVars::cluster`.
    pub fn trainer(&self) -> Result<Trainer> {
        Ok(Trainer::new(&self.net, &self.dv, self.spec.batch,
                        self.spec.lr, self.spec.momentum,
                        self.spec.backend,
                        self.spec.artifacts.as_deref())?
            .with_workers(self.spec.workers)
            .with_noise(self.spec.noise))
    }

    /// Prepare a run: build the trainer (restoring the checkpoint when
    /// `resume`), resolve the start cursor, refuse explicit
    /// seed/images conflicting with a resumed checkpoint, derive the
    /// eval window from the epoch width, and create the checkpoint
    /// directory.
    pub fn begin(&self, resume: bool) -> Result<Run> {
        let mut trainer = self.trainer()?;
        let ckpt_path = self.checkpoint_path();
        let start = if resume {
            let path = ckpt_path.as_ref()
                .ok_or(SpecError::ResumeWithoutCheckpoint)?;
            let cur = trainer.resume_from(path)?;
            if let Some(seed) = self.spec.seed {
                if seed != cur.seed {
                    return Err(SpecError::SeedConflict {
                        given: seed,
                        recorded: cur.seed,
                    }
                    .into());
                }
            }
            if let Some(images) = self.spec.images {
                if images != cur.images {
                    return Err(SpecError::ImagesConflict {
                        given: images,
                        recorded: cur.images,
                    }
                    .into());
                }
            }
            cur
        } else {
            Cursor::start(self.spec.seed.unwrap_or(DEFAULT_SEED),
                          self.spec.images.unwrap_or(DEFAULT_IMAGES))
        };
        // elastic resize: re-shard the (possibly resumed) trainer onto
        // the requested instance count.  The fingerprint deliberately
        // excludes accelerator counts, so the checkpoint restores
        // unchanged and the training stream stays bit-identical.
        if let Some(n) = self.spec.resize_accelerators {
            trainer = trainer.with_accelerators(n);
        }
        let images = start.images;
        let eval_offset = self.spec.eval_offset.unwrap_or(images);
        if eval_offset < images {
            return Err(SpecError::EvalOverlap {
                offset: eval_offset,
                images,
            }
            .into());
        }
        if let Some(ck) = &self.spec.checkpoint {
            std::fs::create_dir_all(&ck.dir).with_context(|| {
                format!("creating checkpoint dir {}", ck.dir.display())
            })?;
        }
        let data = Synthetic::new(self.net.nclass, self.net.input,
                                  start.seed, self.spec.noise);
        let train_set = data.batch(0, images as usize);
        let eval_set = data.batch(eval_offset, self.spec.eval);
        let cfg = TrainRun {
            epochs: self.spec.epochs,
            images,
            checkpoint: self.spec.checkpoint.as_ref().map(|ck| {
                CheckpointPolicy {
                    path: ckpt_path.clone()
                        .expect("checkpoint dir implies a path"),
                    every_batches: ck.every_batches,
                    resize: None,
                }
            }),
            max_batches: None,
        };
        Ok(Run { trainer, start, data, cfg, train_set, eval_set })
    }

    /// Like [`Session::begin`], but bounded to a time slice of
    /// `slice_batches` batches — the preemption contract `stratus
    /// serve` schedules runs with.  The checkpoint cadence is pinned
    /// to the slice length, so when [`Run::execute`] returns (at the
    /// slice bound, or earlier at the final epoch boundary) a
    /// checkpoint covering the returned cursor is always on disk:
    /// swapping in another run loses nothing, and the next
    /// `begin_slice(true, ..)` resumes bit-identically.  Requires a
    /// checkpoint section in the spec
    /// ([`SpecError::SliceWithoutCheckpoint`]).
    pub fn begin_slice(&self, resume: bool, slice_batches: u64)
                       -> Result<Run> {
        if slice_batches == 0 {
            return Err(SpecError::NonPositive("slice-batches").into());
        }
        if self.spec.checkpoint.is_none() {
            return Err(SpecError::SliceWithoutCheckpoint.into());
        }
        let mut run = self.begin(resume)?;
        run.cfg.max_batches = Some(slice_batches);
        if let Some(ck) = &mut run.cfg.checkpoint {
            // epoch ends still save unconditionally; the tightened
            // cadence only guarantees the slice end is covered too
            ck.every_batches = slice_batches;
        }
        Ok(run)
    }

    /// Train a fresh run to completion.
    pub fn train(
        &self,
        on_epoch: impl FnMut(&mut Trainer, &EpochStats, &EvalData)
                         -> Result<()>,
    ) -> Result<TrainOutcome> {
        self.begin(false)?.execute(on_epoch)
    }

    /// Resume from the configured checkpoint and train to completion.
    pub fn resume(
        &self,
        on_epoch: impl FnMut(&mut Trainer, &EpochStats, &EvalData)
                         -> Result<()>,
    ) -> Result<TrainOutcome> {
        self.begin(true)?.execute(on_epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "name tiny\ninput 3 8 8\nconv c1 8 k3 s1 p1 \
                        relu\nconv c2 8 k3 s1 p1 relu\npool p1 2\n\
                        fc fc 10\nloss hinge";

    #[test]
    fn defaults_match_the_historical_cli() {
        let spec = Spec::builder().build().unwrap();
        assert_eq!(spec.net, NetSource::preset("1x"));
        assert_eq!(spec.backend, Backend::Golden);
        assert_eq!(spec.batch, 40);
        assert_eq!(spec.lr, 0.002);
        assert_eq!(spec.momentum, 0.9);
        assert_eq!(spec.epochs, 5);
        assert_eq!(spec.images, None);
        assert_eq!(spec.seed, None);
        assert_eq!(spec.eval, 256);
        assert_eq!(spec.workers, 1);
        assert!(spec.checkpoint.is_none());
        assert!(!spec.resume);
    }

    #[test]
    fn design_overrides_apply_onto_scale_defaults() {
        let spec = Spec::builder()
            .preset("2x")
            .pox(4)
            .clock_mhz(100.0)
            .accelerators(3)
            .load_balance(false)
            .build()
            .unwrap();
        let s = Session::new(spec).unwrap();
        let dv = s.design();
        assert_eq!(dv.pox, 4);
        assert_eq!(dv.poy, 8); // untouched default
        assert_eq!(dv.pof, 32); // 2x scale default
        assert_eq!(dv.clock_mhz, 100.0);
        assert_eq!(dv.cluster, 3);
        assert!(!dv.load_balance);
        assert!(dv.double_buffer);
    }

    #[test]
    fn inline_and_preset_sources_resolve() {
        let net = NetSource::inline(TINY).resolve().unwrap();
        assert_eq!(net.name, "tiny");
        let net = NetSource::preset("bn2x").resolve().unwrap();
        assert!(net.has_stats());
        assert_eq!(net.scale_tag(), "2x");
        let err = NetSource::preset("9x").resolve().unwrap_err();
        assert!(err.to_string().contains("unknown scale `9x`"));
    }

    #[test]
    fn to_builder_round_trips_every_field() {
        let spec = Spec::builder()
            .net_inline(TINY)
            .backend(Backend::Golden)
            .batch(8)
            .lr(0.02)
            .momentum(0.8)
            .epochs(3)
            .images(24)
            .seed(9)
            .eval(16)
            .eval_offset(64)
            .noise(0.25)
            .workers(2)
            .accelerators(3)
            .pof(32)
            .checkpoint_dir("/tmp/ck")
            .checkpoint_every(2)
            .build()
            .unwrap();
        assert_eq!(spec.to_builder().build().unwrap(), spec);
    }

    #[test]
    fn checkpoint_json_rides_resume_flag() {
        let spec = Spec::builder()
            .net_inline(TINY)
            .checkpoint_dir("/tmp/ck")
            .resume(true)
            .build()
            .unwrap();
        let back = Spec::parse(&spec.render()).unwrap();
        assert!(back.resume);
        assert_eq!(back, spec);
    }

    #[test]
    fn strict_json_rejects_unknown_and_mistyped_fields() {
        let err = Spec::parse(
            r#"{"net":{"preset":"1x"},"runn":{"epochs":1}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown field `runn`"),
                "{err:#}");
        let err = Spec::parse(
            r#"{"net":{"preset":"1x"},"hyper":{"batch":1.5}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}")
                    .contains("hyper.batch wants a non-negative"),
                "{err:#}");
        let err = Spec::parse(r#"{"net":{"preset":"1x"},"version":7}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("unsupported spec version"),
                "{err:#}");
        let err =
            Spec::parse(r#"{"net":{"preset":"1x","inline":"x"}}"#)
                .unwrap_err();
        assert!(format!("{err:#}")
                    .contains("exactly one of preset|inline|file"),
                "{err:#}");
    }
}
