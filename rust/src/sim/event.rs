//! Event-driven tile-level simulator: a finer-grained cross-check of the
//! analytic cycle model in `sim::simulate`.
//!
//! Where the analytic model charges each scheduled step
//! `max(logic, dram)` under double buffering, this simulator plays out
//! every DMA tile and compute tile as discrete events against a single
//! DDR3 channel and a single MAC-array engine:
//!
//! - the DMA engine prefetches tile `t+1` while the array computes tile
//!   `t` (double buffering) or strictly serializes (single buffering);
//! - the DRAM channel is a shared resource across the whole schedule —
//!   a step's writes can collide with the next step's prefetch, which
//!   the analytic model ignores;
//! - per-tile compute cannot start before its tile's DMA completes.
//!
//! `cargo test sim::event` asserts the two models agree within a
//! tolerance band on all three CIFAR designs, which is the usual
//! validation argument for using the (fast) analytic model in
//! design-space sweeps.

use crate::compiler::{Accelerator, OpKind};
use crate::hw::dram::{DramModel, DESCRIPTOR_OVERHEAD_CYCLES};
use crate::hw::link::StragglerDist;
use crate::sim::{logic_cycles_for_step, simulate, SimReport};

/// Result of an event-driven run over one image's schedule.
#[derive(Debug, Clone)]
pub struct EventReport {
    /// Cycle at which the last event retires.
    pub makespan: u64,
    /// Per-step completion latency (schedule order).
    pub step_latency: Vec<u64>,
    /// Fraction of the makespan the DRAM channel was busy.
    pub dram_utilization: f64,
    /// Fraction of the makespan the MAC array was busy.
    pub compute_utilization: f64,
}

/// Play one image's per-image schedule through the event model.
pub fn simulate_events(acc: &Accelerator) -> EventReport {
    let dram = DramModel::new(&acc.dv);
    let double = acc.dv.double_buffer;

    let mut dram_free: u64 = 0; // channel next-free cycle
    let mut compute_free: u64 = 0; // MAC array next-free cycle
    let mut dram_busy: u64 = 0;
    let mut compute_busy: u64 = 0;
    let mut step_latency = Vec::new();
    let mut makespan: u64 = 0;

    for step in &acc.schedule.per_image {
        let tiles = step.tiles.max(1);
        let bytes = step.dram_read_bytes + step.dram_write_bytes;
        let logic = logic_cycles_for_step(acc, step);
        // split the step's traffic and compute evenly across its tiles
        let bytes_per_tile = bytes / tiles;
        let dma_per_tile = if bytes == 0 {
            0
        } else {
            DESCRIPTOR_OVERHEAD_CYCLES
                + (bytes_per_tile as f64 / dram.bytes_per_cycle).ceil()
                    as u64
        };
        let compute_per_tile = logic / tiles;
        let start = makespan;
        let mut tile_dma_done = vec![0u64; tiles as usize];
        for t in 0..tiles as usize {
            // DMA for tile t: channel availability; under single
            // buffering it must also wait for the previous tile's compute
            let earliest = if double || t == 0 {
                dram_free.max(start)
            } else {
                dram_free.max(compute_free)
            };
            let done = earliest + dma_per_tile;
            dram_busy += dma_per_tile;
            dram_free = done;
            tile_dma_done[t] = done;
            // compute for tile t starts when the array is free AND the
            // tile's data has landed
            let cstart = compute_free.max(done);
            compute_free = cstart + compute_per_tile;
            compute_busy += compute_per_tile;
        }
        let end = compute_free.max(dram_free);
        step_latency.push(end - start);
        makespan = end;
    }

    EventReport {
        makespan,
        step_latency,
        dram_utilization: if makespan == 0 {
            0.0
        } else {
            dram_busy as f64 / makespan as f64
        },
        compute_utilization: if makespan == 0 {
            0.0
        } else {
            compute_busy as f64 / makespan as f64
        },
    }
}

/// Analytic per-image latency for comparison (FP+BP+WU, no batch update).
pub fn analytic_image_cycles(report: &SimReport) -> u64 {
    report.fp.latency_cycles
        + report.bp.latency_cycles
        + report.wu.latency_cycles
}

/// One labeled interval on the cluster batch timeline.
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub label: String,
    pub start: u64,
    pub end: u64,
}

/// Event timeline of one cluster batch iteration: per-instance compute
/// (the event-driven per-image makespan times the shard length), the
/// collective all-reduce phases of the compiler-chosen topology, then
/// the batch weight update on the merged accumulators.
#[derive(Debug, Clone)]
pub struct ClusterEventReport {
    pub instances: usize,
    /// Cycle at which the iteration's last event retires.
    pub makespan: u64,
    /// Compute span (longest instance shard through the event model).
    pub compute_cycles: u64,
    /// Total cycles spent in the collective all-reduce phases.
    pub allreduce_cycles: u64,
    /// Every interval, in timeline order: one `compute` event, the
    /// `allreduce/...` collective phases, one `weight-update` event.
    pub events: Vec<TimelineEvent>,
}

/// Schedule one batch of `batch` images on the compiled cluster
/// (`acc.dv.cluster` instances) into an event timeline.  Instances run
/// their shards concurrently, so compute spans ceil(batch/N) images;
/// the collective all-reduce phases then serialize (each step is a
/// barrier for its participants), followed by the weight update.  Step
/// durations come from the same per-step costs `simulate` charges
/// (which include per-link contention via the plan's `link_share`), so
/// the timeline and the analytic cluster projection agree on
/// communication.
pub fn simulate_cluster_events(acc: &Accelerator, batch: usize)
                               -> ClusterEventReport {
    simulate_cluster_events_with(acc, batch, &StragglerDist::default())
}

/// [`simulate_cluster_events`] under a straggler distribution: every
/// collective step waits for its slowest member, stretching the step by
/// the distribution's per-step worst-case skew.  The default
/// (spread 0) distribution reproduces `simulate_cluster_events`
/// exactly.
pub fn simulate_cluster_events_with(acc: &Accelerator, batch: usize,
                                    stragglers: &StragglerDist)
                                    -> ClusterEventReport {
    let n = acc.dv.cluster.max(1);
    let report = simulate(acc, batch.max(1));
    let image = simulate_events(acc);
    let shard = (batch.max(1) as u64).div_ceil(n as u64);
    let compute_cycles = image.makespan * shard;
    let mut events = vec![TimelineEvent {
        label: format!("compute x{shard}"),
        start: 0,
        end: compute_cycles,
    }];
    let mut t = compute_cycles;
    let mut allreduce_cycles = 0u64;
    let mut ring = 0usize;
    for (_, layer, op, cost) in &report.steps {
        if *op == OpKind::AllReduce {
            let skew = stragglers.skew(ring as u64, n);
            let dur = cost.latency_cycles
                + (cost.latency_cycles as f64 * skew).ceil() as u64;
            events.push(TimelineEvent {
                label: format!("allreduce/{layer}"),
                start: t,
                end: t + dur,
            });
            t += dur;
            allreduce_cycles += dur;
            ring += 1;
        }
    }
    debug_assert_eq!(ring, acc.schedule.collective.len(),
                     "timeline must replay the whole collective plan");
    let update = report.update.latency_cycles;
    events.push(TimelineEvent {
        label: "weight-update".into(),
        start: t,
        end: t + update,
    });
    ClusterEventReport {
        instances: n,
        makespan: t + update,
        compute_cycles,
        allreduce_cycles,
        events,
    }
}

/// Event timeline of one **overlapped** cluster batch iteration: the
/// compute span, each gradient bucket's all-reduce split into its
/// hidden segment (under remaining backward-pass compute) and its
/// exposed segment (past the compute span), then the weight update.
///
/// Anchored on the analytic model via [`crate::sim::project_overlap`]
/// (not the event-driven per-image makespan), so the timeline and the
/// overlap projection agree cycle-for-cycle on what is hidden.
#[derive(Debug, Clone)]
pub struct OverlapEventReport {
    pub instances: usize,
    /// Cycle at which the weight update retires.
    pub makespan: u64,
    /// Shard compute span (per-image latency × ceil(batch/N)).
    pub compute_cycles: u64,
    /// Collective cycles overlapped with compute.
    pub hidden_cycles: u64,
    /// Collective cycles paid past the compute span.
    pub exposed_cycles: u64,
    /// Timeline intervals: one `compute` event, per-bucket
    /// `allreduce/{bucket}/hidden` and `allreduce/{bucket}/exposed`
    /// segments (only the non-empty ones), one `weight-update` event.
    pub events: Vec<TimelineEvent>,
}

/// Render [`crate::sim::project_overlap`]'s bucket timeline as labeled
/// events.  With bucketing off the projection degenerates to a single
/// fully-exposed `allreduce/all/exposed` segment — the serial epilogue
/// [`simulate_cluster_events`] draws.
pub fn simulate_overlap_events(acc: &Accelerator, batch: usize)
                               -> OverlapEventReport {
    let r = crate::sim::project_overlap(acc, batch);
    let compute = r.compute_cycles;
    let mut events = vec![TimelineEvent {
        label: format!(
            "compute x{}",
            (batch.max(1) as u64)
                .div_ceil(acc.dv.cluster.max(1) as u64)
        ),
        start: 0,
        end: compute,
    }];
    let mut comm_end = compute;
    for b in &r.buckets {
        if b.hidden_cycles > 0 {
            events.push(TimelineEvent {
                label: format!("allreduce/{}/hidden", b.label),
                start: b.start_cycles,
                end: b.start_cycles + b.hidden_cycles,
            });
        }
        if b.exposed_cycles > 0 {
            events.push(TimelineEvent {
                label: format!("allreduce/{}/exposed", b.label),
                start: b.end_cycles - b.exposed_cycles,
                end: b.end_cycles,
            });
        }
        comm_end = comm_end.max(b.end_cycles);
    }
    let update = r.update_cycles;
    events.push(TimelineEvent {
        label: "weight-update".into(),
        start: comm_end,
        end: comm_end + update,
    });
    OverlapEventReport {
        instances: r.instances,
        makespan: comm_end + update,
        compute_cycles: compute,
        hidden_cycles: r.hidden_comm_cycles,
        exposed_cycles: r.exposed_comm_cycles,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::RtlCompiler;
    use crate::config::{DesignVars, Network};
    use crate::sim::simulate;

    fn acc_for(scale: usize) -> crate::compiler::Accelerator {
        RtlCompiler::default()
            .compile(&Network::cifar(scale), &DesignVars::for_scale(scale))
            .unwrap()
    }

    #[test]
    fn event_and_analytic_models_agree() {
        // the event model serializes cross-step channel contention that
        // the analytic model ignores, so it should be equal-or-slower,
        // but within 35% on all paper designs
        for scale in [1, 2, 4] {
            let acc = acc_for(scale);
            let ev = simulate_events(&acc);
            let an = analytic_image_cycles(&simulate(&acc, 40));
            let ratio = ev.makespan as f64 / an as f64;
            assert!(
                (0.9..1.35).contains(&ratio),
                "{scale}X: event {} vs analytic {an} (ratio {ratio:.3})",
                ev.makespan
            );
        }
    }

    #[test]
    fn step_count_matches_schedule() {
        let acc = acc_for(1);
        let ev = simulate_events(&acc);
        assert_eq!(ev.step_latency.len(), acc.schedule.per_image.len());
    }

    #[test]
    fn utilizations_are_fractions() {
        let acc = acc_for(4);
        let ev = simulate_events(&acc);
        assert!(ev.dram_utilization > 0.0 && ev.dram_utilization <= 1.0);
        assert!(ev.compute_utilization > 0.0
            && ev.compute_utilization <= 1.0);
    }

    #[test]
    fn training_is_dram_bound_in_event_model_too() {
        // Fig. 9's conclusion must survive the finer model
        let acc = acc_for(4);
        let ev = simulate_events(&acc);
        assert!(ev.dram_utilization > ev.compute_utilization,
                "dram {} vs compute {}", ev.dram_utilization,
                ev.compute_utilization);
    }

    #[test]
    fn single_buffering_slower_in_event_model() {
        let net = Network::cifar(2);
        let mut dv = DesignVars::for_scale(2);
        let on = simulate_events(
            &RtlCompiler::default().compile(&net, &dv).unwrap());
        dv.double_buffer = false;
        let off = simulate_events(
            &RtlCompiler::default().compile(&net, &dv).unwrap());
        assert!(on.makespan < off.makespan,
                "{} !< {}", on.makespan, off.makespan);
    }

    #[test]
    fn makespan_monotone_in_network_width() {
        let m1 = simulate_events(&acc_for(1)).makespan;
        let m4 = simulate_events(&acc_for(4)).makespan;
        assert!(m4 > 3 * m1);
    }

    fn cluster_acc(instances: usize) -> crate::compiler::Accelerator {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = instances;
        RtlCompiler::default()
            .compile(&Network::cifar(1), &dv)
            .unwrap()
    }

    #[test]
    fn cluster_timeline_contains_allreduce_events() {
        let ev = simulate_cluster_events(&cluster_acc(4), 40);
        let ring: Vec<&TimelineEvent> = ev
            .events
            .iter()
            .filter(|e| e.label.starts_with("allreduce/"))
            .collect();
        assert_eq!(ring.len(), 6); // 2 * (4 - 1)
        assert!(ev.allreduce_cycles > 0);
        assert_eq!(ev.allreduce_cycles,
                   ring.iter().map(|e| e.end - e.start).sum::<u64>());
        // ring phases sit between compute and the weight update
        assert!(ring.iter().all(|e| e.start >= ev.compute_cycles));
        let update = ev.events.last().unwrap();
        assert_eq!(update.label, "weight-update");
        assert!(ring.iter().all(|e| e.end <= update.start));
        assert_eq!(update.end, ev.makespan);
    }

    #[test]
    fn cluster_timeline_is_contiguous() {
        let ev = simulate_cluster_events(&cluster_acc(4), 40);
        for pair in ev.events.windows(2) {
            assert_eq!(pair[0].end, pair[1].start,
                       "gap between {} and {}", pair[0].label,
                       pair[1].label);
        }
    }

    #[test]
    fn allreduce_events_scale_with_instances() {
        let e2 = simulate_cluster_events(&cluster_acc(2), 40);
        let e4 = simulate_cluster_events(&cluster_acc(4), 40);
        let e8 = simulate_cluster_events(&cluster_acc(8), 40);
        let count = |ev: &ClusterEventReport| {
            ev.events
                .iter()
                .filter(|e| e.label.starts_with("allreduce/"))
                .count()
        };
        assert_eq!(count(&e2), 2);
        assert_eq!(count(&e4), 6);
        assert_eq!(count(&e8), 14);
        assert!(e2.allreduce_cycles < e4.allreduce_cycles);
        assert!(e4.allreduce_cycles < e8.allreduce_cycles);
    }

    #[test]
    fn hier_timeline_replays_the_grouped_plan() {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 16;
        dv.topology = crate::config::Topology::Hier;
        let acc = RtlCompiler::default()
            .compile(&Network::cifar(1), &dv)
            .unwrap();
        let ev = simulate_cluster_events(&acc, 40);
        let coll: Vec<&TimelineEvent> = ev
            .events
            .iter()
            .filter(|e| e.label.starts_with("allreduce/"))
            .collect();
        assert_eq!(coll.len(), acc.schedule.collective.len());
        assert!(coll[0].label.starts_with("allreduce/hier_rs"));
        assert!(coll.iter().any(|e| e.label.contains("hier_xrs")));
        // still contiguous between compute and the weight update
        for pair in ev.events.windows(2) {
            assert_eq!(pair[0].end, pair[1].start);
        }
    }

    #[test]
    fn stragglers_stretch_the_collective_only() {
        let acc = cluster_acc(8);
        let base = simulate_cluster_events(&acc, 40);
        let slow = simulate_cluster_events_with(
            &acc, 40, &StragglerDist { seed: 7, spread: 0.25 });
        assert!(slow.allreduce_cycles > base.allreduce_cycles);
        assert!(slow.allreduce_cycles as f64
                    <= base.allreduce_cycles as f64 * 1.25
                        + acc.schedule.collective.len() as f64);
        assert_eq!(slow.compute_cycles, base.compute_cycles);
        assert_eq!(slow.makespan - base.makespan,
                   slow.allreduce_cycles - base.allreduce_cycles);
        // deterministic: same seed, same timeline
        let again = simulate_cluster_events_with(
            &acc, 40, &StragglerDist { seed: 7, spread: 0.25 });
        assert_eq!(again.makespan, slow.makespan);
        // spread 0 reproduces the plain timeline exactly
        let zero = simulate_cluster_events_with(
            &acc, 40, &StragglerDist::default());
        assert_eq!(zero.makespan, base.makespan);
    }

    #[test]
    fn single_instance_timeline_has_no_allreduce() {
        let ev = simulate_cluster_events(&cluster_acc(1), 40);
        assert_eq!(ev.instances, 1);
        assert_eq!(ev.allreduce_cycles, 0);
        assert!(ev
            .events
            .iter()
            .all(|e| !e.label.starts_with("allreduce/")));
        // compute + update only
        assert_eq!(ev.events.len(), 2);
    }

    #[test]
    fn cluster_shrinks_compute_span() {
        let e1 = simulate_cluster_events(&cluster_acc(1), 40);
        let e4 = simulate_cluster_events(&cluster_acc(4), 40);
        assert_eq!(e1.compute_cycles, 4 * e4.compute_cycles);
        assert!(e4.makespan < e1.makespan);
    }

    fn bucketed_acc(instances: usize, kwords: usize)
                    -> crate::compiler::Accelerator {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = instances;
        dv.bucket_kwords = kwords;
        RtlCompiler::default()
            .compile(&Network::cifar(1), &dv)
            .unwrap()
    }

    #[test]
    fn overlap_timeline_splits_hidden_and_exposed() {
        let acc = bucketed_acc(4, 16);
        let ev = simulate_overlap_events(&acc, 40);
        let hidden: Vec<&TimelineEvent> = ev
            .events
            .iter()
            .filter(|e| e.label.ends_with("/hidden"))
            .collect();
        let exposed: Vec<&TimelineEvent> = ev
            .events
            .iter()
            .filter(|e| e.label.ends_with("/exposed"))
            .collect();
        assert!(!hidden.is_empty(),
                "bucketed run overlapped nothing");
        // hidden segments live inside the compute span, exposed ones
        // strictly after it
        assert!(hidden
            .iter()
            .all(|e| e.end <= ev.compute_cycles));
        assert!(exposed
            .iter()
            .all(|e| e.start >= ev.compute_cycles));
        // segment sums reconcile with the projection's split
        assert_eq!(
            hidden.iter().map(|e| e.end - e.start).sum::<u64>(),
            ev.hidden_cycles);
        assert_eq!(
            exposed.iter().map(|e| e.end - e.start).sum::<u64>(),
            ev.exposed_cycles);
        // the weight update is last and starts once compute and every
        // bucket are done
        let update = ev.events.last().unwrap();
        assert_eq!(update.label, "weight-update");
        assert_eq!(update.end, ev.makespan);
        assert!(update.start >= ev.compute_cycles);
        assert!(ev
            .events
            .iter()
            .all(|e| e.end <= update.start
                || e.label == "weight-update"));
    }

    #[test]
    fn overlap_timeline_monolithic_is_all_exposed() {
        // bucketing off: one fully-exposed segment, zero hidden —
        // exactly the serial epilogue the plain cluster timeline draws
        let acc = cluster_acc(4);
        let ev = simulate_overlap_events(&acc, 40);
        assert_eq!(ev.hidden_cycles, 0);
        assert!(ev.exposed_cycles > 0);
        let segs: Vec<&TimelineEvent> = ev
            .events
            .iter()
            .filter(|e| e.label.starts_with("allreduce/"))
            .collect();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].label, "allreduce/all/exposed");
        assert_eq!(segs[0].end - segs[0].start, ev.exposed_cycles);
        let analytic = simulate(&acc, 40);
        assert_eq!(ev.exposed_cycles,
                   analytic.allreduce.latency_cycles);
    }
}
