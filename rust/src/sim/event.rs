//! Event-driven tile-level simulator: a finer-grained cross-check of the
//! analytic cycle model in `sim::simulate`.
//!
//! Where the analytic model charges each scheduled step
//! `max(logic, dram)` under double buffering, this simulator plays out
//! every DMA tile and compute tile as discrete events against a single
//! DDR3 channel and a single MAC-array engine:
//!
//! - the DMA engine prefetches tile `t+1` while the array computes tile
//!   `t` (double buffering) or strictly serializes (single buffering);
//! - the DRAM channel is a shared resource across the whole schedule —
//!   a step's writes can collide with the next step's prefetch, which
//!   the analytic model ignores;
//! - per-tile compute cannot start before its tile's DMA completes.
//!
//! `cargo test sim::event` asserts the two models agree within a
//! tolerance band on all three CIFAR designs, which is the usual
//! validation argument for using the (fast) analytic model in
//! design-space sweeps.

use crate::compiler::Accelerator;
use crate::hw::dram::{DramModel, DESCRIPTOR_OVERHEAD_CYCLES};
use crate::sim::{logic_cycles_for_step, SimReport};

/// Result of an event-driven run over one image's schedule.
#[derive(Debug, Clone)]
pub struct EventReport {
    /// Cycle at which the last event retires.
    pub makespan: u64,
    /// Per-step completion latency (schedule order).
    pub step_latency: Vec<u64>,
    /// Fraction of the makespan the DRAM channel was busy.
    pub dram_utilization: f64,
    /// Fraction of the makespan the MAC array was busy.
    pub compute_utilization: f64,
}

/// Play one image's per-image schedule through the event model.
pub fn simulate_events(acc: &Accelerator) -> EventReport {
    let dram = DramModel::new(&acc.dv);
    let double = acc.dv.double_buffer;

    let mut dram_free: u64 = 0; // channel next-free cycle
    let mut compute_free: u64 = 0; // MAC array next-free cycle
    let mut dram_busy: u64 = 0;
    let mut compute_busy: u64 = 0;
    let mut step_latency = Vec::new();
    let mut makespan: u64 = 0;

    for step in &acc.schedule.per_image {
        let tiles = step.tiles.max(1);
        let bytes = step.dram_read_bytes + step.dram_write_bytes;
        let logic = logic_cycles_for_step(acc, step);
        // split the step's traffic and compute evenly across its tiles
        let bytes_per_tile = bytes / tiles;
        let dma_per_tile = if bytes == 0 {
            0
        } else {
            DESCRIPTOR_OVERHEAD_CYCLES
                + (bytes_per_tile as f64 / dram.bytes_per_cycle).ceil()
                    as u64
        };
        let compute_per_tile = logic / tiles;
        let start = makespan;
        let mut tile_dma_done = vec![0u64; tiles as usize];
        for t in 0..tiles as usize {
            // DMA for tile t: channel availability; under single
            // buffering it must also wait for the previous tile's compute
            let earliest = if double || t == 0 {
                dram_free.max(start)
            } else {
                dram_free.max(compute_free)
            };
            let done = earliest + dma_per_tile;
            dram_busy += dma_per_tile;
            dram_free = done;
            tile_dma_done[t] = done;
            // compute for tile t starts when the array is free AND the
            // tile's data has landed
            let cstart = compute_free.max(done);
            compute_free = cstart + compute_per_tile;
            compute_busy += compute_per_tile;
        }
        let end = compute_free.max(dram_free);
        step_latency.push(end - start);
        makespan = end;
    }

    EventReport {
        makespan,
        step_latency,
        dram_utilization: if makespan == 0 {
            0.0
        } else {
            dram_busy as f64 / makespan as f64
        },
        compute_utilization: if makespan == 0 {
            0.0
        } else {
            compute_busy as f64 / makespan as f64
        },
    }
}

/// Analytic per-image latency for comparison (FP+BP+WU, no batch update).
pub fn analytic_image_cycles(report: &SimReport) -> u64 {
    report.fp.latency_cycles
        + report.bp.latency_cycles
        + report.wu.latency_cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::RtlCompiler;
    use crate::config::{DesignVars, Network};
    use crate::sim::simulate;

    fn acc_for(scale: usize) -> crate::compiler::Accelerator {
        RtlCompiler::default()
            .compile(&Network::cifar(scale), &DesignVars::for_scale(scale))
            .unwrap()
    }

    #[test]
    fn event_and_analytic_models_agree() {
        // the event model serializes cross-step channel contention that
        // the analytic model ignores, so it should be equal-or-slower,
        // but within 35% on all paper designs
        for scale in [1, 2, 4] {
            let acc = acc_for(scale);
            let ev = simulate_events(&acc);
            let an = analytic_image_cycles(&simulate(&acc, 40));
            let ratio = ev.makespan as f64 / an as f64;
            assert!(
                (0.9..1.35).contains(&ratio),
                "{scale}X: event {} vs analytic {an} (ratio {ratio:.3})",
                ev.makespan
            );
        }
    }

    #[test]
    fn step_count_matches_schedule() {
        let acc = acc_for(1);
        let ev = simulate_events(&acc);
        assert_eq!(ev.step_latency.len(), acc.schedule.per_image.len());
    }

    #[test]
    fn utilizations_are_fractions() {
        let acc = acc_for(4);
        let ev = simulate_events(&acc);
        assert!(ev.dram_utilization > 0.0 && ev.dram_utilization <= 1.0);
        assert!(ev.compute_utilization > 0.0
            && ev.compute_utilization <= 1.0);
    }

    #[test]
    fn training_is_dram_bound_in_event_model_too() {
        // Fig. 9's conclusion must survive the finer model
        let acc = acc_for(4);
        let ev = simulate_events(&acc);
        assert!(ev.dram_utilization > ev.compute_utilization,
                "dram {} vs compute {}", ev.dram_utilization,
                ev.compute_utilization);
    }

    #[test]
    fn single_buffering_slower_in_event_model() {
        let net = Network::cifar(2);
        let mut dv = DesignVars::for_scale(2);
        let on = simulate_events(
            &RtlCompiler::default().compile(&net, &dv).unwrap());
        dv.double_buffer = false;
        let off = simulate_events(
            &RtlCompiler::default().compile(&net, &dv).unwrap());
        assert!(on.makespan < off.makespan,
                "{} !< {}", on.makespan, off.makespan);
    }

    #[test]
    fn makespan_monotone_in_network_width() {
        let m1 = simulate_events(&acc_for(1)).makespan;
        let m4 = simulate_events(&acc_for(4)).makespan;
        assert!(m4 > 3 * m1);
    }
}
