//! Cycle-accurate training simulator: interprets a compiled accelerator's
//! schedule against the hardware models to produce the paper's evaluation
//! numbers — per-phase latency breakdowns (Fig. 9), epoch latency vs batch
//! size and GOPS (Table II), and efficiency (Table III).
//!
//! This is the same methodology as the paper ("latency was measured using
//! simulation of the synthesized accelerator", §IV-A): each scheduled step
//! costs `logic` cycles from the MAC-array model and `dram` cycles from
//! the DDR3 model; with double buffering the two overlap per §IV-B.

pub mod event;

use std::collections::HashMap;

use crate::compiler::{Accelerator, OpKind, Step};
use crate::hw::bram::overlap_latency;
use crate::hw::dram::DramModel;
use crate::hw::link::LinkModel;
use crate::hw::mac_array::Phase;

/// Cost of one scheduled step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepCost {
    pub logic_cycles: u64,
    pub dram_cycles: u64,
    pub latency_cycles: u64,
}

/// Aggregate over a phase (Fig. 9's bar groups).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseCost {
    pub logic_cycles: u64,
    pub dram_cycles: u64,
    pub latency_cycles: u64,
}

/// Full simulation result for one network + design point + batch size.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Per (phase, layer) step costs, in schedule order.
    pub steps: Vec<(Phase, String, OpKind, StepCost)>,
    /// Per-image latency by phase: FP, BP, WU-conv layers.
    pub fp: PhaseCost,
    pub bp: PhaseCost,
    pub wu: PhaseCost,
    /// Batch-end weight-update cost (amortized per batch).
    pub update: PhaseCost,
    /// Per-batch ring all-reduce cost (cluster designs; zero at one
    /// instance).  Latency cycles are the communication bound: each
    /// ring step costs the slower of the link message and the local
    /// DRAM staging + accumulate.
    pub allreduce: PhaseCost,
    /// Accelerator instances the schedule was compiled for
    /// (`dv.cluster`).
    pub instances: usize,
    pub batch_size: usize,
    pub clock_hz: f64,
    /// Training ops per image (2 * MACs over FP+BP+WU).
    pub ops_per_image: u64,
}

impl SimReport {
    /// Per-image latency in cycles, amortizing the batch-end update.
    pub fn cycles_per_image(&self) -> f64 {
        (self.fp.latency_cycles
            + self.bp.latency_cycles
            + self.wu.latency_cycles) as f64
            + self.update.latency_cycles as f64 / self.batch_size as f64
    }

    /// Latency of one full batch iteration (BS images + one update).
    pub fn cycles_per_iteration(&self) -> u64 {
        (self.fp.latency_cycles
            + self.bp.latency_cycles
            + self.wu.latency_cycles)
            * self.batch_size as u64
            + self.update.latency_cycles
    }

    pub fn seconds_per_image(&self) -> f64 {
        self.cycles_per_image() / self.clock_hz
    }

    /// Training throughput of one accelerator instance (per-image
    /// latency inverted; the engine-scaling baseline).
    pub fn images_per_second(&self) -> f64 {
        1.0 / self.seconds_per_image()
    }

    /// Latency of one batch iteration when the batch is sharded across
    /// `engines` replicated accelerator instances (the hardware analogue
    /// of the host engine's `--workers`): shards of ceil(BS/N) images
    /// run concurrently, then the batch-end weight update runs once on
    /// the merged accumulators.
    pub fn sharded_cycles_per_iteration(&self, engines: usize) -> u64 {
        let n = engines.max(1).min(self.batch_size.max(1)) as u64;
        let per_image = self.fp.latency_cycles
            + self.bp.latency_cycles
            + self.wu.latency_cycles;
        let shard = (self.batch_size as u64).div_ceil(n);
        per_image * shard + self.update.latency_cycles
    }

    /// Sharded-engine training throughput in images per second.
    pub fn sharded_images_per_second(&self, engines: usize) -> f64 {
        let secs = self.sharded_cycles_per_iteration(engines) as f64
            / self.clock_hz;
        self.batch_size as f64 / secs
    }

    /// Latency of one batch iteration on the compiled cluster: each of
    /// the `instances` replicas trains ceil(BS/N) images concurrently,
    /// the full deployed ring all-reduces the WU gradient accumulators
    /// (idle instances contribute zero gradients, exactly like the
    /// cluster engine), then the weight update runs on every instance
    /// in parallel (identical merged accumulators, so one update's
    /// latency).  Unlike [`SimReport::sharded_cycles_per_iteration`]
    /// this includes the inter-accelerator communication the schedule
    /// carries.
    pub fn cluster_cycles_per_iteration(&self) -> u64 {
        let n = self.instances.max(1) as u64;
        let per_image = self.fp.latency_cycles
            + self.bp.latency_cycles
            + self.wu.latency_cycles;
        per_image * (self.batch_size as u64).div_ceil(n)
            + self.allreduce.latency_cycles
            + self.update.latency_cycles
    }

    /// Cluster training throughput in images per second.
    pub fn cluster_images_per_second(&self) -> f64 {
        let secs =
            self.cluster_cycles_per_iteration() as f64 / self.clock_hz;
        self.batch_size as f64 / secs
    }

    /// Epoch latency for `images` training images (Table II).
    pub fn seconds_per_epoch(&self, images: u64) -> f64 {
        self.seconds_per_image() * images as f64
    }

    /// Achieved throughput in GOPS (Table II's metric: training ops over
    /// wall-clock).
    pub fn gops(&self) -> f64 {
        self.ops_per_image as f64 / self.seconds_per_image() / 1e9
    }

    /// Latency by phase in milliseconds for the Fig. 9 breakdown,
    /// splitting logic vs DRAM.  Returns (phase, logic_ms, dram_ms,
    /// latency_ms) rows for FP / BP / WU / update (the paper's
    /// single-accelerator phases; cluster all-reduce is reported
    /// separately via [`SimReport::allreduce`]).
    pub fn breakdown_ms(&self) -> Vec<(&'static str, f64, f64, f64)> {
        let to_ms = |c: u64| c as f64 / self.clock_hz * 1e3;
        vec![
            ("FP", to_ms(self.fp.logic_cycles), to_ms(self.fp.dram_cycles),
             to_ms(self.fp.latency_cycles)),
            ("BP", to_ms(self.bp.logic_cycles), to_ms(self.bp.dram_cycles),
             to_ms(self.bp.latency_cycles)),
            ("WU", to_ms(self.wu.logic_cycles), to_ms(self.wu.dram_cycles),
             to_ms(self.wu.latency_cycles)),
            ("UPDATE", to_ms(self.update.logic_cycles),
             to_ms(self.update.dram_cycles),
             to_ms(self.update.latency_cycles)),
        ]
    }
}

/// Pipeline-fill cycles charged per double-buffered step.
const PIPELINE_FILL: u64 = 16;

/// Logic cycles for one scheduled step (shared with the event-driven
/// model in [`event`]).  Per-layer op costs come from the layer-ops
/// registry; only the layer-less ops (the loss/scaling function units
/// and the cluster ring) are costed here.
pub fn logic_cycles_for_step(acc: &Accelerator, step: &Step) -> u64 {
    match step.op {
        OpKind::ScaleMask | OpKind::LossGrad => {
            // affiliated elementwise units keep pace with the datapath
            8
        }
        OpKind::AllReduce => {
            // fold the received gradient chunk into the local
            // accumulator through the Pof-wide update datapath
            (step.dram_write_bytes / 4).div_ceil(acc.dv.pof as u64)
        }
        op => acc
            .net
            .layers
            .iter()
            .find(|l| l.name() == step.layer)
            .map_or(0, |l| {
                crate::ops::for_layer(l).logic_cycles(&acc.dv, l, op)
            }),
    }
}

fn cost_step(acc: &Accelerator, dram: &DramModel, step: &Step) -> StepCost {
    let logic = logic_cycles_for_step(acc, step);
    let dram_cycles = dram.tiled_transfer_cycles(
        step.dram_read_bytes + step.dram_write_bytes,
        step.tiles,
    );
    let latency = overlap_latency(
        logic,
        dram_cycles,
        acc.dv.double_buffer,
        if acc.dv.double_buffer { PIPELINE_FILL } else { 0 },
    );
    StepCost { logic_cycles: logic, dram_cycles, latency_cycles: latency }
}

/// Cost of one all-reduce step: the local DRAM staging + accumulate
/// overlaps the (full-duplex) link message, so the slower of the two
/// bounds the step — the link shares the DRAM model's cost shape
/// (per-message overhead + payload at derated bandwidth).  `link_share`
/// is the number of concurrent messages time-sharing the busiest
/// physical link (1 for ring steps; the group size on hierarchical
/// cross-group steps, whose slice rings all cross the inter-group
/// trunk at once).
fn cost_allreduce_step(acc: &Accelerator, dram: &DramModel,
                       link: &LinkModel, step: &Step, link_share: u64)
                       -> StepCost {
    let local = cost_step(acc, dram, step);
    let link_cycles =
        link.message_cycles(link_share.max(1) * step.dram_read_bytes);
    StepCost {
        logic_cycles: local.logic_cycles,
        dram_cycles: local.dram_cycles,
        latency_cycles: local.latency_cycles.max(link_cycles),
    }
}

/// Simulate one compiled accelerator at a given batch size.
pub fn simulate(acc: &Accelerator, batch_size: usize) -> SimReport {
    let dram = DramModel::new(&acc.dv);
    let link = LinkModel::new(&acc.dv);
    let mut steps = Vec::new();
    let mut fp = PhaseCost::default();
    let mut bp = PhaseCost::default();
    let mut wu = PhaseCost::default();
    let mut update = PhaseCost::default();
    let mut allreduce = PhaseCost::default();

    for s in &acc.schedule.per_image {
        let c = cost_step(acc, &dram, s);
        let bucket = match s.phase {
            Phase::Fp => &mut fp,
            Phase::Bp => &mut bp,
            Phase::Wu => &mut wu,
        };
        bucket.logic_cycles += c.logic_cycles;
        bucket.dram_cycles += c.dram_cycles;
        bucket.latency_cycles += c.latency_cycles;
        steps.push((s.phase, s.layer.clone(), s.op, c));
    }
    // AllReduce steps zip 1:1 with the schedule's collective plan,
    // which carries the per-step link sharing the Step cannot express
    let mut ar_idx = 0usize;
    for s in &acc.schedule.per_batch {
        let (c, bucket) = if s.op == OpKind::AllReduce {
            let share = acc
                .schedule
                .collective
                .get(ar_idx)
                .map_or(1, |cs| cs.link_share);
            ar_idx += 1;
            (cost_allreduce_step(acc, &dram, &link, s, share),
             &mut allreduce)
        } else {
            (cost_step(acc, &dram, s), &mut update)
        };
        bucket.logic_cycles += c.logic_cycles;
        bucket.dram_cycles += c.dram_cycles;
        bucket.latency_cycles += c.latency_cycles;
        steps.push((s.phase, s.layer.clone(), s.op, c));
    }

    SimReport {
        steps,
        fp,
        bp,
        wu,
        update,
        allreduce,
        instances: acc.dv.cluster.max(1),
        batch_size,
        clock_hz: acc.dv.clock_mhz * 1e6,
        ops_per_image: acc.net.ops_per_image(),
    }
}

/// One gradient bucket's place on the overlapped cluster timeline, in
/// absolute cycles from the start of the batch iteration.
#[derive(Debug, Clone)]
pub struct BucketTimeline {
    pub label: String,
    /// i32 words this bucket reduces.
    pub words: u64,
    /// Layer whose backward pass retiring makes the bucket final.
    pub eligible_after: String,
    /// When the bucket becomes reducible: the shard's **last** image
    /// retires `eligible_after` (earlier images' contributions are
    /// already accumulated by then).
    pub eligible_cycles: u64,
    /// When the bucket's all-reduce actually starts: its eligibility
    /// point, or later if the previous bucket still occupies the link.
    pub start_cycles: u64,
    pub end_cycles: u64,
    /// Pure communication cost (sum of this bucket's collective step
    /// latencies under the link + local-staging model).
    pub comm_cycles: u64,
    /// Portion of `comm_cycles` hidden under remaining shard compute.
    pub hidden_cycles: u64,
    /// Portion extending past the end of shard compute.
    pub exposed_cycles: u64,
}

/// Overlapped-timeline projection of a cluster iteration: per-layer
/// gradient buckets all-reduce as soon as the backward pass retires
/// their layers, pipelined over one full-duplex link, so only the comm
/// that outlives the compute span is paid
/// (`exposed = max(0, last bucket end − compute)`).
///
/// For a monolithic schedule (`bucket_kwords == 0`) the projection
/// degenerates to the serial epilogue: one pseudo-bucket eligible at
/// the end of compute, fully exposed — identical to
/// [`SimReport::cluster_cycles_per_iteration`].
#[derive(Debug, Clone)]
pub struct OverlapReport {
    pub instances: usize,
    pub batch_size: usize,
    pub clock_hz: f64,
    /// Shard compute span: per-image latency × ceil(BS/N).
    pub compute_cycles: u64,
    /// The serial baseline: one monolithic all-reduce under the same
    /// topology policy, priced with the same step cost model, paid
    /// entirely after compute.
    pub serial_comm_cycles: u64,
    /// Total bucket communication (Σ `comm_cycles` over buckets).
    pub total_comm_cycles: u64,
    /// Comm overlapped with compute (`total − exposed`).
    pub hidden_comm_cycles: u64,
    /// Comm left exposed past compute — what the iteration pays.
    pub exposed_comm_cycles: u64,
    /// Batch-end weight-update latency (after the last bucket folds).
    pub update_cycles: u64,
    pub buckets: Vec<BucketTimeline>,
}

impl OverlapReport {
    /// Latency of one overlapped batch iteration.
    pub fn cycles_per_iteration(&self) -> u64 {
        self.compute_cycles
            + self.exposed_comm_cycles
            + self.update_cycles
    }

    /// Latency of the same iteration with the serial epilogue.
    pub fn serial_cycles_per_iteration(&self) -> u64 {
        self.compute_cycles
            + self.serial_comm_cycles
            + self.update_cycles
    }

    /// Overlapped cluster training throughput in images per second.
    pub fn images_per_second(&self) -> f64 {
        let secs = self.cycles_per_iteration() as f64 / self.clock_hz;
        self.batch_size as f64 / secs
    }
}

/// Project the overlapped cluster timeline for one compiled
/// accelerator at a given batch size.
///
/// Eligibility points come from the simulated per-image step walk: a
/// bucket tagged `eligible_after = L` becomes reducible when the
/// cumulative per-image latency reaches the **last** scheduled step of
/// layer `L` (its BP/WU retirement — FP steps of the same layer occur
/// earlier and never win), offset by the shard's preceding images.
/// Buckets then pipeline over the link in schedule order:
/// `start = max(prev end, eligible)`, `end = start + comm`.
pub fn project_overlap(acc: &Accelerator, batch_size: usize)
                       -> OverlapReport {
    let report = simulate(acc, batch_size);
    let n = acc.dv.cluster.max(1) as u64;
    let per_image = report.fp.latency_cycles
        + report.bp.latency_cycles
        + report.wu.latency_cycles;
    let shard = (batch_size.max(1) as u64).div_ceil(n);
    let compute = per_image * shard;
    let mut out = OverlapReport {
        instances: acc.dv.cluster.max(1),
        batch_size,
        clock_hz: acc.dv.clock_mhz * 1e6,
        compute_cycles: compute,
        serial_comm_cycles: 0,
        total_comm_cycles: 0,
        hidden_comm_cycles: 0,
        exposed_comm_cycles: 0,
        update_cycles: report.update.latency_cycles,
        buckets: Vec::new(),
    };
    if n <= 1 {
        return out;
    }

    // Serial baseline: the monolithic plan the same topology policy
    // would pick for the whole gradient vector, priced step by step
    // with the same cost model the simulator uses.
    let dram = DramModel::new(&acc.dv);
    let link = LinkModel::new(&acc.dv);
    let words = acc.net.ring_words() as u64;
    let coll = crate::compiler::choose_collective(
        acc.dv.topology, acc.dv.cluster, words, &link);
    out.serial_comm_cycles = coll
        .steps(acc.dv.cluster, words)
        .iter()
        .map(|cs| {
            let s = crate::compiler::schedule::allreduce_step(
                &acc.dv, cs.label.clone(), cs.chunk_words);
            cost_allreduce_step(acc, &dram, &link, &s, cs.link_share)
                .latency_cycles
        })
        .sum();

    if acc.schedule.buckets.is_empty() {
        // Monolithic schedule: the whole reduce is one bucket, final
        // only when the last image's walk completes — fully exposed.
        let comm = report.allreduce.latency_cycles;
        out.total_comm_cycles = comm;
        out.exposed_comm_cycles = comm;
        out.buckets.push(BucketTimeline {
            label: "all".to_string(),
            words,
            eligible_after: String::new(),
            eligible_cycles: compute,
            start_cycles: compute,
            end_cycles: compute + comm,
            comm_cycles: comm,
            hidden_cycles: 0,
            exposed_cycles: comm,
        });
        return out;
    }

    // Cumulative per-image latency at the *last* step of each layer:
    // the retirement point the bucket's eligibility tag refers to.
    let mut retire: HashMap<&str, u64> = HashMap::new();
    let mut cum = 0u64;
    for (_, layer, _, cost) in
        report.steps.iter().take(acc.schedule.per_image.len())
    {
        cum += cost.latency_cycles;
        retire.insert(layer.as_str(), cum);
    }

    // Per-bucket comm: the simulated AllReduce steps, in plan order,
    // chunked by each scheduled bucket's step count.
    let mut ar = report
        .steps
        .iter()
        .skip(acc.schedule.per_image.len())
        .filter(|(_, _, op, _)| *op == OpKind::AllReduce)
        .map(|(_, _, _, c)| c.latency_cycles);

    let mut cursor = 0u64;
    for sb in &acc.schedule.buckets {
        let comm: u64 = ar.by_ref().take(sb.steps).sum();
        let eligible = (shard - 1) * per_image
            + retire
                .get(sb.eligible_after.as_str())
                .copied()
                .unwrap_or(per_image);
        let start = cursor.max(eligible);
        let end = start + comm;
        cursor = end;
        out.total_comm_cycles += comm;
        out.buckets.push(BucketTimeline {
            label: sb.label.clone(),
            words: sb.words,
            eligible_after: sb.eligible_after.clone(),
            eligible_cycles: eligible,
            start_cycles: start,
            end_cycles: end,
            comm_cycles: comm,
            // hidden = intersection with [0, compute), exposed the rest
            hidden_cycles: end
                .min(compute)
                .saturating_sub(start.min(compute)),
            exposed_cycles: end.saturating_sub(start.max(compute)),
        });
    }
    out.exposed_comm_cycles = cursor.saturating_sub(compute);
    out.hidden_comm_cycles =
        out.total_comm_cycles - out.exposed_comm_cycles;
    out
}

/// Per-layer [FP, BP, WU] latency table, for detailed reports.
pub fn per_layer_latency(report: &SimReport)
                         -> HashMap<String, [u64; 3]> {
    let mut map: HashMap<String, [u64; 3]> = HashMap::new();
    for (phase, layer, _, cost) in &report.steps {
        let e = map.entry(layer.clone()).or_default();
        let i = match phase {
            Phase::Fp => 0,
            Phase::Bp => 1,
            Phase::Wu => 2,
        };
        e[i] += cost.latency_cycles;
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::RtlCompiler;
    use crate::config::{DesignVars, Network};

    fn sim(scale: usize, bs: usize) -> SimReport {
        let acc = RtlCompiler::default()
            .compile(&Network::cifar(scale), &DesignVars::for_scale(scale))
            .unwrap();
        simulate(&acc, bs)
    }

    #[test]
    fn epoch_latency_order_matches_table2() {
        // Table II: 1X ~18 s, 2X ~41 s, 4X ~96 s per 50k-image epoch at
        // BS-40.  The model must land within 2x of each (shape criterion).
        for (scale, want) in [(1, 18.0), (2, 41.0), (4, 96.2)] {
            let got = sim(scale, 40).seconds_per_epoch(50_000);
            assert!(
                got > want / 2.0 && got < want * 2.0,
                "{scale}X epoch {got:.1}s vs paper {want}s"
            );
        }
    }

    #[test]
    fn gops_increase_with_scale() {
        let (g1, g2, g4) =
            (sim(1, 40).gops(), sim(2, 40).gops(), sim(4, 40).gops());
        assert!(g1 < g2 && g2 < g4, "{g1} {g2} {g4}");
        // Table II: 163 / 282 / 479 GOPS — within 2x each
        assert!(g1 > 80.0 && g1 < 330.0, "1X {g1}");
        assert!(g4 > 240.0 && g4 < 960.0, "4X {g4}");
    }

    #[test]
    fn larger_batch_slightly_faster_epoch() {
        // Table II: BS-10 -> BS-40 improves epoch latency slightly
        // (fewer weight updates per epoch)
        let r10 = sim(1, 10);
        let r40 = sim(1, 40);
        let (e10, e40) = (r10.seconds_per_epoch(50_000),
                          r40.seconds_per_epoch(50_000));
        assert!(e40 < e10, "{e40} !< {e10}");
        let improvement = (e10 - e40) / e10;
        assert!(improvement < 0.10,
                "improvement should be small: {improvement}");
    }

    #[test]
    fn wu_phase_dominates_4x_iteration() {
        // Fig. 9: 51% of one batch iteration's latency is in the weight
        // update layers (WU convs + batch update) for the 4X design
        let r = sim(4, 40);
        let wu_total = r.wu.latency_cycles as f64
            + r.update.latency_cycles as f64 / r.batch_size as f64;
        let frac = wu_total / r.cycles_per_image();
        assert!(frac > 0.35 && frac < 0.75, "WU fraction = {frac}");
    }

    #[test]
    fn wu_layers_are_dram_bound() {
        // Fig. 9's point: WU-layer DRAM cycles exceed logic cycles
        let r = sim(4, 40);
        assert!(r.wu.dram_cycles > r.wu.logic_cycles);
        assert!(r.update.dram_cycles > r.update.logic_cycles);
    }

    #[test]
    fn double_buffering_helps() {
        let net = Network::cifar(4);
        let mut dv = DesignVars::for_scale(4);
        let on = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        dv.double_buffer = false;
        let off = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        assert!(on.cycles_per_image() < off.cycles_per_image());
        // §IV-B: double buffering reduced WU-layer latency by ~11%
        let wu_gain = 1.0
            - on.wu.latency_cycles as f64 / off.wu.latency_cycles as f64;
        assert!(wu_gain > 0.02 && wu_gain < 0.45,
                "WU gain = {wu_gain:.3}");
    }

    #[test]
    fn load_balance_cuts_wu_logic_4x() {
        let net = Network::cifar(4);
        let mut dv = DesignVars::for_scale(4);
        let on = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        dv.load_balance = false;
        let off = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        let ratio =
            off.wu.logic_cycles as f64 / on.wu.logic_cycles as f64;
        assert!(ratio > 3.0 && ratio <= 4.2, "ratio = {ratio}");
    }

    #[test]
    fn sharded_one_engine_matches_sequential_iteration() {
        let r = sim(1, 40);
        assert_eq!(r.sharded_cycles_per_iteration(1),
                   r.cycles_per_iteration());
        // and the degenerate engine counts clamp sanely
        assert_eq!(r.sharded_cycles_per_iteration(0),
                   r.cycles_per_iteration());
        assert_eq!(r.sharded_cycles_per_iteration(1000),
                   r.sharded_cycles_per_iteration(40));
    }

    #[test]
    fn sharded_throughput_scales_with_engines() {
        let r = sim(1, 40);
        let t1 = r.sharded_images_per_second(1);
        let t4 = r.sharded_images_per_second(4);
        let t8 = r.sharded_images_per_second(8);
        assert!(t1 < t4 && t4 < t8, "{t1} {t4} {t8}");
        // speedup is sublinear: the batch-end update is serialized
        assert!(t8 / t1 < 8.0);
        // but the image phases themselves scale: 4 engines on BS-40
        // cut shard length 40 -> 10
        assert!(t4 / t1 > 2.0, "4-engine speedup only {}", t4 / t1);
    }

    fn sim_cluster(scale: usize, bs: usize, instances: usize)
                   -> SimReport {
        let mut dv = DesignVars::for_scale(scale);
        dv.cluster = instances;
        let acc = RtlCompiler::default()
            .compile(&Network::cifar(scale), &dv)
            .unwrap();
        simulate(&acc, bs)
    }

    #[test]
    fn single_instance_has_zero_allreduce() {
        let r = sim(1, 40);
        assert_eq!(r.instances, 1);
        assert_eq!(r.allreduce.latency_cycles, 0);
        assert_eq!(r.cluster_cycles_per_iteration(),
                   r.cycles_per_iteration());
        assert_eq!(r.cluster_cycles_per_iteration(),
                   r.sharded_cycles_per_iteration(1));
    }

    #[test]
    fn allreduce_cycles_nonzero_and_grow_with_instances() {
        let a2 = sim_cluster(1, 40, 2).allreduce.latency_cycles;
        let a4 = sim_cluster(1, 40, 4).allreduce.latency_cycles;
        let a8 = sim_cluster(1, 40, 8).allreduce.latency_cycles;
        assert!(a2 > 0);
        // more ring steps -> more per-step overhead, monotone in N
        assert!(a2 < a4 && a4 < a8, "{a2} {a4} {a8}");
    }

    #[test]
    fn allreduce_at_least_link_bound() {
        // the schedule-based cost must not undercut the pure link-bound
        // analytic ring cost (each step is max(local, link))
        use crate::hw::link::{ring_cost, LinkModel};
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 4;
        let net = Network::cifar(1);
        let acc = RtlCompiler::default().compile(&net, &dv).unwrap();
        let r = simulate(&acc, 40);
        let link = LinkModel::new(&dv);
        let analytic =
            ring_cost(net.param_count() as u64 * 4, 4, &link);
        assert_eq!(analytic.steps, 6);
        assert!(r.allreduce.latency_cycles >= analytic.cycles,
                "{} < {}", r.allreduce.latency_cycles, analytic.cycles);
    }

    #[test]
    fn cluster_throughput_scales_with_instances() {
        let t1 = sim_cluster(1, 40, 1).cluster_images_per_second();
        let t2 = sim_cluster(1, 40, 2).cluster_images_per_second();
        let t4 = sim_cluster(1, 40, 4).cluster_images_per_second();
        assert!(t1 < t2 && t2 < t4, "{t1} {t2} {t4}");
        // communication + the serialized update keep it sublinear
        assert!(t4 / t1 < 4.0, "superlinear? {}", t4 / t1);
        // but compute dominates at this scale: 4 instances > 2.5x
        assert!(t4 / t1 > 2.5, "4-instance speedup only {}", t4 / t1);
    }

    #[test]
    fn hier_projects_fewer_cluster_cycles_at_scale() {
        // acceptance: at N >= 16 under identical link parameters the
        // hierarchical collective finishes the batch in fewer projected
        // cycles than the flat ring — 126 per-step message overheads vs
        // the grouped plan's handful
        use crate::config::Topology;
        let net = Network::cifar(1);
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 64;
        dv.topology = Topology::Ring;
        let ring = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 64);
        dv.topology = Topology::Hier;
        let hier = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 64);
        assert!(hier.allreduce.latency_cycles
                    < ring.allreduce.latency_cycles,
                "hier {} !< ring {}",
                hier.allreduce.latency_cycles,
                ring.allreduce.latency_cycles);
        assert!(hier.cluster_cycles_per_iteration()
                    < ring.cluster_cycles_per_iteration());
        // Auto resolves to one of the two explicit plans
        dv.topology = Topology::Auto;
        let auto = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 64);
        assert!(auto.allreduce.latency_cycles
                    == hier.allreduce.latency_cycles
                || auto.allreduce.latency_cycles
                    == ring.allreduce.latency_cycles);
    }

    #[test]
    fn cluster_slower_than_free_sharding() {
        // the sharded_* projection ignores communication; the cluster
        // projection must pay for it
        let r4 = sim_cluster(1, 40, 4);
        assert!(r4.cluster_cycles_per_iteration()
            > r4.sharded_cycles_per_iteration(4));
        assert_eq!(r4.cluster_cycles_per_iteration()
                       - r4.sharded_cycles_per_iteration(4),
                   r4.allreduce.latency_cycles);
    }

    fn overlap(scale: usize, bs: usize, instances: usize,
               kwords: usize, topo: crate::config::Topology)
               -> OverlapReport {
        let mut dv = DesignVars::for_scale(scale);
        dv.cluster = instances;
        dv.bucket_kwords = kwords;
        dv.topology = topo;
        let acc = RtlCompiler::default()
            .compile(&Network::cifar(scale), &dv)
            .unwrap();
        project_overlap(&acc, bs)
    }

    #[test]
    fn overlap_timeline_is_consistent() {
        use crate::config::Topology;
        let r = overlap(1, 40, 4, 16, Topology::Ring);
        assert!(r.buckets.len() > 1, "16 kwords must split the 1X net");
        let total: u64 =
            r.buckets.iter().map(|b| b.comm_cycles).sum();
        assert_eq!(total, r.total_comm_cycles);
        assert_eq!(r.hidden_comm_cycles + r.exposed_comm_cycles,
                   r.total_comm_cycles);
        let mut prev_end = 0u64;
        for b in &r.buckets {
            assert!(b.comm_cycles > 0, "{}: empty bucket comm", b.label);
            assert!(b.start_cycles >= b.eligible_cycles);
            assert!(b.start_cycles >= prev_end,
                    "{}: bucket overtook the link", b.label);
            assert_eq!(b.end_cycles, b.start_cycles + b.comm_cycles);
            assert_eq!(b.hidden_cycles + b.exposed_cycles,
                       b.comm_cycles);
            prev_end = b.end_cycles;
        }
        // reverse-BP retirement order: the tail-layer bucket is
        // eligible strictly before the front-layer bucket
        assert!(r.buckets.first().unwrap().eligible_cycles
                    < r.buckets.last().unwrap().eligible_cycles);
        assert_eq!(r.exposed_comm_cycles,
                   prev_end.saturating_sub(r.compute_cycles));
        assert_eq!(r.cycles_per_iteration(),
                   r.compute_cycles + r.exposed_comm_cycles
                       + r.update_cycles);
    }

    #[test]
    fn monolithic_projection_matches_serial_epilogue() {
        use crate::config::Topology;
        // bucketing off: the projection must price exactly the serial
        // epilogue the pinned cluster projection charges
        let r = overlap(1, 40, 4, 0, Topology::Ring);
        let sim = sim_cluster(1, 40, 4);
        assert_eq!(r.buckets.len(), 1);
        assert_eq!(r.hidden_comm_cycles, 0);
        assert_eq!(r.exposed_comm_cycles,
                   sim.allreduce.latency_cycles);
        assert_eq!(r.serial_comm_cycles, r.exposed_comm_cycles);
        assert_eq!(r.cycles_per_iteration(),
                   sim.cluster_cycles_per_iteration());
        // single instance: nothing to reduce, nothing to hide
        let r1 = overlap(1, 40, 1, 16, Topology::Ring);
        assert!(r1.buckets.is_empty());
        assert_eq!(r1.total_comm_cycles, 0);
        assert_eq!(r1.exposed_comm_cycles, 0);
    }

    #[test]
    fn overlap_hides_comm_across_scales() {
        use crate::config::Topology;
        // acceptance: exposed comm never exceeds the serial epilogue,
        // and at N >= 16 the overlap wins outright (the topology
        // policy resolves per bucket list, so hier kicks in where the
        // flat ring's per-step overhead would swamp the buckets)
        for n in [4usize, 16, 64] {
            let r = overlap(1, 64, n, 32, Topology::Auto);
            assert!(r.buckets.len() > 1);
            assert!(r.hidden_comm_cycles > 0,
                    "N={n}: nothing overlapped");
            assert!(r.exposed_comm_cycles <= r.serial_comm_cycles,
                    "N={n}: exposed {} > serial {}",
                    r.exposed_comm_cycles, r.serial_comm_cycles);
            if n >= 16 {
                assert!(r.exposed_comm_cycles < r.serial_comm_cycles,
                        "N={n}: overlap bought nothing");
            }
            assert!(r.cycles_per_iteration()
                        <= r.serial_cycles_per_iteration());
        }
    }

    #[test]
    fn bn_network_simulates_with_bn_costs() {
        let acc = RtlCompiler::default()
            .compile(&Network::cifar_bn(1), &DesignVars::for_scale(1))
            .unwrap();
        let r = simulate(&acc, 40);
        // every bn layer costs cycles in FP and BP
        let bn_fp: u64 = r
            .steps
            .iter()
            .filter(|(_, _, op, _)| *op == OpKind::BnFp)
            .map(|(_, _, _, c)| c.latency_cycles)
            .sum();
        let bn_bp: u64 = r
            .steps
            .iter()
            .filter(|(_, _, op, _)| *op == OpKind::BnBp)
            .map(|(_, _, _, c)| c.latency_cycles)
            .sum();
        assert!(bn_fp > 0 && bn_bp > 0);
        // elementwise normalization is cheap next to the convolutions
        let plain = sim(1, 40);
        let ratio = r.cycles_per_image() / plain.cycles_per_image();
        assert!(ratio > 1.0 && ratio < 1.6, "bn overhead ratio {ratio}");
        // and the per-layer table covers the bn layers
        let t = per_layer_latency(&r);
        for l in ["n1", "n3", "n6"] {
            assert!(t.contains_key(l), "{l} missing");
        }
    }

    #[test]
    fn per_layer_table_covers_all_layers() {
        let r = sim(1, 40);
        let t = per_layer_latency(&r);
        for l in ["c1", "c2", "c3", "c4", "c5", "c6", "p1", "p2", "p3",
                  "fc"] {
            assert!(t.contains_key(l), "{l} missing");
        }
    }

    #[test]
    fn breakdown_rows_sum_to_total() {
        let r = sim(2, 20);
        let rows = r.breakdown_ms();
        assert_eq!(rows.len(), 4);
        let sum: f64 = rows.iter().map(|(_, _, _, l)| l).sum();
        let direct = (r.fp.latency_cycles + r.bp.latency_cycles
            + r.wu.latency_cycles + r.update.latency_cycles)
            as f64
            / r.clock_hz
            * 1e3;
        assert!((sum - direct).abs() < 1e-9);
    }
}
