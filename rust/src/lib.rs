//! # stratus — compiler-based FPGA CNN-training accelerator, reproduced
//!
//! Reproduction of *"Automatic Compiler Based FPGA Accelerator for CNN
//! Training"* (Venkataramanaiah et al., 2019) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's system contribution: the RTL
//!   compiler ([`compiler`]), the accelerator's global control and
//!   layer-by-layer training schedule ([`coordinator`]), the
//!   batch-parallel training engine that shards batches across worker
//!   threads with bit-identical results ([`engine`]), the validated,
//!   serializable experiment description that drives the CLI, library,
//!   benches, and checkpoints ([`session`]), crash-safe
//!   checkpoint/resume with bit-identical restarts ([`ckpt`]), the
//!   preemptive multi-tenant experiment service that queues and
//!   time-slices submitted specs ([`serve`]), a
//!   cycle-accurate hardware model of the generated accelerator ([`hw`],
//!   [`sim`]), and a PJRT runtime that executes the AOT-compiled
//!   numerics ([`runtime`]).
//! - **Layer 2 (python/compile/model.py, build-time)** — the fixed-point
//!   CNN training step in JAX, lowered per layer-op to HLO text artifacts.
//! - **Layer 1 (python/compile/kernels/, build-time)** — Pallas kernels
//!   tiled like the paper's `Pox x Poy x Pof` MAC array.
//!
//! Python never runs at request time: `make artifacts` lowers everything
//! once; the `stratus` binary is self-contained afterwards.
//!
//! See DESIGN.md for the full system inventory and the experiment index
//! (every table and figure of the paper mapped to a bench target).

pub mod analysis;
pub mod ckpt;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fixed;
pub mod gpu_model;
pub mod hw;
pub mod jsonx;
pub mod metrics;
pub mod nn;
pub mod ops;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sim;
