//! Layer-by-layer training schedule generation (§III-A: "execution of
//! training operations in one iteration of a batch can be scheduled
//! sequentially similar to layer-by-layer execution of inference tasks").
//!
//! The compiler expands a network into:
//! - a **per-image** step list: FP layers in order, the loss unit, then BP
//!   and WU interleaved walking the layers in reverse (WU gradients are
//!   accumulated into DRAM tile-by-tile each image, Fig. 7);
//! - a **per-batch** step list: for cluster designs (`dv.cluster > 1`),
//!   the gradient all-reduce steps of the compiler-chosen collective
//!   topology ([`crate::compiler::choose_collective`]: flat ring or
//!   hierarchical group reduce), then the weight-update passes that run
//!   once per batch (read weights + momentum + accumulated gradients,
//!   write new weights tile-by-tile, §III-E).  With `dv.bucket_kwords
//!   > 0` the all-reduce is emitted per gradient *bucket* in
//!   reverse-layer order, each run tagged ([`ScheduledBucket`]) with
//!   the BP step after which it becomes eligible — the seam the
//!   simulator uses to overlap communication with the remaining
//!   backward compute.
//!
//! Every step carries its phase, the key/affiliated classification
//! (§III-B: key layers read fresh tiles from DRAM; affiliated layers
//! consume key-layer outputs on chip), its DRAM traffic, its DMA tile
//! count, its output geometry, and — when the op has numerics — the AOT
//! artifact that executes it on the PJRT runtime.
//!
//! Per-layer step emission lives in the layer-ops registry
//! ([`crate::ops`]): this module only walks the network (forward, then
//! the loss unit, then the reverse BP/WU walk) and asks each layer's
//! descriptor for its steps, threading the geometry chain through a
//! [`StepCtx`](crate::ops::StepCtx).  The per-batch steps (ring
//! all-reduce + weight update) are network-global and stay here.

use crate::compiler::adaptive::{choose_collective,
                                choose_collective_bucketed};
use crate::config::{DesignVars, Loss, Network};
use crate::engine::collective::{BucketPlan, CollectiveStep};
use crate::hw::link::LinkModel;
use crate::hw::mac_array::Phase;
use crate::ops::{for_layer, Geom, StepCtx, W16, W32};

/// What a schedule step does (1:1 with the artifact kinds emitted by
/// `python/compile/aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    ConvFp,
    ConvBp,
    ConvWu,
    Pool,
    Upsample,
    ScaleMask,
    FcFp,
    FcBp,
    FcWu,
    /// Integer batch-norm forward: normalize with the running
    /// statistics and stream per-image channel sums to the DRAM
    /// statistic accumulators (golden-backend-only; no artifact).
    BnFp,
    /// Integer batch-norm backward: scale the gradient by the constant
    /// per-channel scale and fold dgamma/dbeta into their accumulators
    /// in the same pass (golden-backend-only; no artifact).
    BnBp,
    LossGrad,
    WeightUpdate,
    /// One ring step of the cluster gradient all-reduce (per batch,
    /// cluster designs only): stage a gradient chunk from DRAM, move it
    /// over the inter-accelerator link, fold the received chunk into the
    /// local accumulator.
    AllReduce,
}

/// One scheduled operation.
#[derive(Debug, Clone)]
pub struct Step {
    pub phase: Phase,
    pub layer: String,
    pub op: OpKind,
    /// Key layers read fresh data from DRAM; affiliated layers do not.
    pub key: bool,
    /// AOT artifact name (without the `.hlo.txt` suffix), when the op is
    /// executed numerically on the PJRT runtime.
    pub artifact: Option<String>,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// DMA descriptor count for the step's transfers.
    pub tiles: u64,
    /// Shape of the tensor this step produces (activation/gradient
    /// carrier for FP/BP ops, weight-gradient shape for WU ops).  The
    /// per-op runtime walk reads this instead of re-deriving geometry
    /// from the layer list (e.g. FcBp's re-entry into the feature-map
    /// domain used to scan backwards for the last pool layer).
    pub out_shape: Vec<usize>,
}

/// One gradient bucket of a pipelined (bucketed) cluster schedule,
/// tagging the contiguous run of per-bucket `AllReduce` steps with its
/// eligibility point in the per-image BP walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledBucket {
    /// Bucket label (`b0`, `b1`, ... in reduce order); the bucket's
    /// emitted AllReduce steps carry `{label}/`-prefixed layer names.
    pub label: String,
    /// i32 words the bucket reduces.
    pub words: u64,
    /// The bucket becomes eligible for its all-reduce the moment the
    /// per-image schedule retires the *last* step of this layer — the
    /// front-most layer the bucket covers, i.e. the last of its layers
    /// the reverse BP walk reaches.
    pub eligible_after: String,
    /// How many consecutive entries of `Schedule::collective` (and
    /// per-batch AllReduce steps) belong to this bucket.
    pub steps: usize,
}

/// Complete schedule for one network + design point.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Steps executed for every image.
    pub per_image: Vec<Step>,
    /// Steps executed once per batch (weight update).
    pub per_batch: Vec<Step>,
    /// The collective communication plan behind the per-batch AllReduce
    /// steps, 1:1 by index (empty for single-instance designs).  Carries
    /// per-step link sharing (`link_share`) the DRAM-byte view of a
    /// [`Step`] cannot express; the simulator zips the two to charge
    /// trunk contention on hierarchical cross-group steps.
    pub collective: Vec<CollectiveStep>,
    /// Bucket tags for pipelined cluster designs (`dv.cluster > 1 &&
    /// dv.bucket_kwords > 0`): partitions `collective` into contiguous
    /// per-bucket runs in reverse-layer reduce order, each carrying its
    /// BP eligibility point.  Empty when bucketing is off — the
    /// monolithic serial epilogue every pinned small-N behavior
    /// assumes.
    pub buckets: Vec<ScheduledBucket>,
}

/// Synthesize the per-batch schedule [`Step`] for one collective plan
/// step: stage `chunk_words` of gradient out of DRAM, move them over
/// the link, write the received chunk back.  Shared by the monolithic
/// and bucketed emission paths and by the overlap projector
/// (`crate::sim::project_overlap`), so every consumer prices an
/// AllReduce step identically.
pub fn allreduce_step(dv: &DesignVars, label: String,
                      chunk_words: u64) -> Step {
    let chunk_bytes = chunk_words * W32;
    let tiles = (2 * (chunk_words as usize)
        .div_ceil(dv.pof * dv.tile_rows * 64)
        .max(1)) as u64;
    Step {
        phase: Phase::Wu,
        layer: label,
        op: OpKind::AllReduce,
        key: true,
        artifact: None, // runs on the link + update datapath
        dram_read_bytes: chunk_bytes,
        dram_write_bytes: chunk_bytes,
        tiles,
        out_shape: vec![chunk_words as usize],
    }
}

/// Input geometry of every layer (the geometry chain the registry
/// descriptors consume).
fn in_geoms(net: &Network) -> Vec<Geom> {
    let mut geoms = Vec::with_capacity(net.layers.len());
    let (c, h, w) = net.input;
    let mut geom = Geom { c, h, w };
    for l in &net.layers {
        geoms.push(geom);
        geom = for_layer(l).out_geom(l);
    }
    geoms
}

/// Build the full schedule.
pub fn build(net: &Network, dv: &DesignVars) -> Schedule {
    let tag = net.scale_tag();
    let geoms = in_geoms(net);
    let mut per_image = Vec::new();

    // ---------------- FP phase ----------------
    for (i, l) in net.layers.iter().enumerate() {
        let ctx = StepCtx {
            tag,
            in_geom: geoms[i],
            is_first: i == 0,
            below: i.checked_sub(1).map(|j| &net.layers[j]),
        };
        per_image.extend(for_layer(l).fp_steps(l, dv, &ctx));
    }

    // loss unit (affiliated: logits are already on chip)
    let loss_art = match net.loss {
        Loss::SquareHinge => "loss_hinge",
        Loss::Euclidean => "loss_euclid",
    };
    per_image.push(Step {
        phase: Phase::Bp,
        layer: "loss".into(),
        op: OpKind::LossGrad,
        key: false,
        artifact: Some(format!("{loss_art}_{tag}")),
        dram_read_bytes: (net.nclass as u64) * W16,
        dram_write_bytes: (net.nclass as u64) * W16,
        tiles: 1,
        out_shape: vec![net.nclass],
    });

    // ---------------- BP + WU phases (reverse walk) ----------------
    for (i, l) in net.layers.iter().enumerate().rev() {
        let ctx = StepCtx {
            tag,
            in_geom: geoms[i],
            is_first: i == 0,
            below: i.checked_sub(1).map(|j| &net.layers[j]),
        };
        per_image.extend(for_layer(l).bp_wu_steps(l, dv, &ctx));
    }

    // ---------------- per-batch cluster all-reduce ----------------
    // With N > 1 accelerator instances the batch's gradient
    // accumulators all-reduce before the weight update runs on the
    // merged — bit-identical — accumulators.  The topology (flat ring
    // or hierarchical group reduce) is chosen by the compiler from
    // `dv.topology` and the link parameters; each plan step stages one
    // chunk out of DRAM and writes the received chunk back.
    let mut per_batch = Vec::new();
    let mut collective = Vec::new();
    let mut buckets = Vec::new();
    if dv.cluster > 1 && dv.bucket_kwords > 0 {
        // pipelined emission: partition the gradient vector at layer
        // boundaries, walk the buckets in reverse-layer (BP) order,
        // and emit each bucket's own collective plan tagged with its
        // eligibility point.  The topology is priced on the bucketed
        // plan — splitting multiplies per-step message overhead, which
        // shifts Auto toward the hierarchy at large N.
        let plan = BucketPlan::build(&net.ring_segments(),
                                     dv.bucket_kwords * 1024);
        let link = LinkModel::new(dv);
        let coll = choose_collective_bucketed(
            dv.topology, dv.cluster, &plan.bucket_words(), &link);
        for b in &plan.buckets {
            let steps = coll.steps(dv.cluster, b.words());
            for cs in &steps {
                let label = format!("{}/{}", b.label, cs.label);
                per_batch.push(allreduce_step(dv, label.clone(),
                                              cs.chunk_words));
                collective.push(CollectiveStep {
                    label,
                    chunk_words: cs.chunk_words,
                    link_share: cs.link_share,
                });
            }
            buckets.push(ScheduledBucket {
                label: b.label.clone(),
                words: b.words(),
                eligible_after: b.eligible_after.clone(),
                steps: steps.len(),
            });
        }
    } else if dv.cluster > 1 {
        // every accumulator the cluster engine reduces: gradient words
        // plus BN statistic words (Network::ring_words)
        let grad_words = net.ring_words() as u64;
        collective = choose_collective(
            dv.topology, dv.cluster, grad_words, &LinkModel::new(dv))
            .steps(dv.cluster, grad_words);
        for cs in &collective {
            per_batch.push(allreduce_step(dv, cs.label.clone(),
                                          cs.chunk_words));
        }
    }

    // ---------------- per-batch weight update ----------------
    for l in &net.layers {
        let we = l.weight_elems() as u64;
        if we == 0 {
            continue;
        }
        let be = l.bias_elems() as u64;
        // read: old weights (16b, transposable layout), momentum (32b),
        // accumulated gradients (32b); write: new weights + momentum
        per_batch.push(Step {
            phase: Phase::Wu,
            layer: l.name().to_string(),
            op: OpKind::WeightUpdate,
            key: true,
            artifact: None, // runs on the rust weight-update unit
            dram_read_bytes: we * W16 + (we + be) * W32 * 2,
            dram_write_bytes: we * W16 + (we + be) * W32,
            tiles: 4 * (we as usize)
                .div_ceil(dv.pof * dv.tile_rows * 64)
                .max(1) as u64,
            out_shape: for_layer(l)
                .weight_shape(l)
                .unwrap_or_default(),
        });
    }

    Schedule { per_image, per_batch, collective, buckets }
}

impl Schedule {
    /// Total DRAM bytes moved per image.
    pub fn image_bytes(&self) -> u64 {
        self.per_image
            .iter()
            .map(|s| s.dram_read_bytes + s.dram_write_bytes)
            .sum()
    }

    /// Total DRAM bytes moved per batch-end update.
    pub fn batch_bytes(&self) -> u64 {
        self.per_batch
            .iter()
            .map(|s| s.dram_read_bytes + s.dram_write_bytes)
            .sum()
    }

    /// All artifact names the schedule needs (for runtime preloading).
    pub fn artifacts(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self
            .per_image
            .iter()
            .filter_map(|s| s.artifact.as_deref())
            .collect();
        names.sort_unstable();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignVars, Network};

    fn sched1x() -> Schedule {
        build(&Network::cifar(1), &DesignVars::for_scale(1))
    }

    #[test]
    fn fp_steps_in_layer_order() {
        let s = sched1x();
        let fp: Vec<&str> = s
            .per_image
            .iter()
            .filter(|st| st.phase == Phase::Fp)
            .map(|st| st.layer.as_str())
            .collect();
        assert_eq!(fp, ["c1", "c2", "p1", "c3", "c4", "p2", "c5", "c6",
                        "p3", "fc"]);
    }

    #[test]
    fn bp_walks_reverse_and_skips_first_conv() {
        let s = sched1x();
        let bp: Vec<(&str, OpKind)> = s
            .per_image
            .iter()
            .filter(|st| st.phase == Phase::Bp)
            .map(|st| (st.layer.as_str(), st.op))
            .collect();
        assert_eq!(bp[0], ("loss", OpKind::LossGrad));
        assert_eq!(bp[1], ("fc", OpKind::FcBp));
        assert!(bp.iter().any(|(l, o)| *l == "p3"
            && *o == OpKind::Upsample));
        // c1 must not appear as ConvBp
        assert!(!bp.iter().any(|(l, o)| *l == "c1"
            && *o == OpKind::ConvBp));
        assert!(bp.iter().any(|(l, o)| *l == "c2"
            && *o == OpKind::ConvBp));
    }

    #[test]
    fn every_conv_and_fc_gets_wu() {
        let s = sched1x();
        let wu: Vec<&str> = s
            .per_image
            .iter()
            .filter(|st| st.phase == Phase::Wu)
            .map(|st| st.layer.as_str())
            .collect();
        for l in ["c1", "c2", "c3", "c4", "c5", "c6", "fc"] {
            assert!(wu.contains(&l), "{l} missing WU");
        }
    }

    #[test]
    fn scale_mask_at_conv_conv_boundaries_only() {
        let s = sched1x();
        let sm: Vec<&str> = s
            .per_image
            .iter()
            .filter(|st| st.op == OpKind::ScaleMask)
            .map(|st| st.artifact.as_deref().unwrap())
            .collect();
        assert_eq!(sm, ["smask_c5_1x", "smask_c3_1x", "smask_c1_1x"]);
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        let s = sched1x();
        let arts = s.artifacts();
        assert!(arts.contains(&"conv_fp_c1_1x"));
        assert!(arts.contains(&"conv_bp_c6_1x"));
        assert!(arts.contains(&"ups_p2_1x"));
        assert!(arts.contains(&"loss_hinge_1x"));
        assert!(!arts.iter().any(|a| a.starts_with("conv_bp_c1")));
        // 30 distinct numeric artifacts for the 1X net (aot.py emits 31:
        // both loss units; the schedule references only the configured one)
        assert_eq!(arts.len(), 30);
    }

    #[test]
    fn batch_update_covers_all_weighted_layers() {
        let s = sched1x();
        assert_eq!(s.per_batch.len(), 7); // 6 conv + 1 fc
        assert!(s
            .per_batch
            .iter()
            .all(|st| st.op == OpKind::WeightUpdate));
    }

    #[test]
    fn single_instance_schedule_has_no_allreduce() {
        let s = sched1x();
        assert!(!s
            .per_batch
            .iter()
            .any(|st| st.op == OpKind::AllReduce));
    }

    #[test]
    fn cluster_schedule_rings_before_updating() {
        let net = Network::cifar(1);
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 4;
        let s = build(&net, &dv);
        let ring: Vec<&Step> = s
            .per_batch
            .iter()
            .filter(|st| st.op == OpKind::AllReduce)
            .collect();
        assert_eq!(ring.len(), 6); // 2 * (4 - 1)
        // reduce-scatter steps first, then all-gather
        assert_eq!(ring[0].layer, "ring_rs0");
        assert_eq!(ring[3].layer, "ring_ag0");
        // every ring step stages one chunk out and one chunk in
        let chunk = (net.param_count() as u64).div_ceil(4) * 4;
        for st in &ring {
            assert_eq!(st.dram_read_bytes, chunk);
            assert_eq!(st.dram_write_bytes, chunk);
            assert!(st.tiles >= 2);
        }
        // the all-reduce precedes every weight-update step
        let first_wu = s
            .per_batch
            .iter()
            .position(|st| st.op == OpKind::WeightUpdate)
            .unwrap();
        let last_ring = s
            .per_batch
            .iter()
            .rposition(|st| st.op == OpKind::AllReduce)
            .unwrap();
        assert!(last_ring < first_wu);
        // weight updates themselves are unchanged
        assert_eq!(s.per_batch.len(), 6 + 7);
        // and the plan mirrors the emitted steps 1:1
        assert_eq!(s.collective.len(), ring.len());
    }

    #[test]
    fn hier_schedule_emits_grouped_steps() {
        use crate::config::Topology;
        let net = Network::cifar(1);
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 16;
        dv.topology = Topology::Hier;
        let s = build(&net, &dv);
        let steps: Vec<&Step> = s
            .per_batch
            .iter()
            .filter(|st| st.op == OpKind::AllReduce)
            .collect();
        // 2*(G-1) + 2*(16/G - 1) for the compiler-chosen divisor G;
        // recover G from the plan instead of pinning the cost model
        let g = s
            .collective
            .iter()
            .filter(|cs| cs.label.starts_with("hier_rs"))
            .count()
            + 1;
        assert!(g > 1 && g < 16 && 16 % g == 0, "bad group {g}");
        assert_eq!(steps.len(), 2 * (g - 1) + 2 * (16 / g - 1));
        assert_eq!(steps[0].layer, "hier_rs0");
        assert!(steps.iter().any(|st| st.layer.starts_with("hier_xrs")));
        assert!(steps.iter().any(|st| st.layer.starts_with("hier_xag")));
        assert_eq!(steps.last().unwrap().layer,
                   format!("hier_ag{}", g - 2));
        // plan and steps zip 1:1: same labels, bytes match chunk words
        assert_eq!(s.collective.len(), steps.len());
        for (cs, st) in s.collective.iter().zip(&steps) {
            assert_eq!(cs.label, st.layer);
            assert_eq!(st.dram_read_bytes, cs.chunk_words * W32);
            assert!(cs.link_share >= 1);
        }
        // the all-reduce still precedes every weight update
        let first_wu = s
            .per_batch
            .iter()
            .position(|st| st.op == OpKind::WeightUpdate)
            .unwrap();
        assert!(s
            .per_batch
            .iter()
            .rposition(|st| st.op == OpKind::AllReduce)
            .unwrap()
            < first_wu);
    }

    #[test]
    fn single_instance_has_empty_collective_plan() {
        assert!(sched1x().collective.is_empty());
        assert!(sched1x().buckets.is_empty());
    }

    #[test]
    fn monolithic_cluster_schedule_has_no_buckets() {
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 4;
        let s = build(&Network::cifar(1), &dv);
        assert!(s.buckets.is_empty());
        assert!(!s.collective.is_empty());
    }

    #[test]
    fn bucketed_cluster_schedule_tags_eligibility_points() {
        let net = Network::cifar(1);
        let mut dv = DesignVars::for_scale(1);
        dv.cluster = 4;
        dv.bucket_kwords = 16;
        let s = build(&net, &dv);
        assert!(s.buckets.len() > 1,
                "16 kwords should split the ~80 kword 1X gradient");
        // buckets partition the full reduced vector ...
        let total: u64 = s.buckets.iter().map(|b| b.words).sum();
        assert_eq!(total, net.ring_words() as u64);
        // ... and the collective plan 1:1 into contiguous runs whose
        // labels carry the bucket prefix
        let step_sum: usize = s.buckets.iter().map(|b| b.steps).sum();
        assert_eq!(step_sum, s.collective.len());
        let mut idx = 0usize;
        for b in &s.buckets {
            for cs in &s.collective[idx..idx + b.steps] {
                assert!(cs.label.starts_with(&format!("{}/", b.label)),
                        "{} not in bucket {}", cs.label, b.label);
            }
            idx += b.steps;
        }
        // per-batch AllReduce steps mirror the plan, and still precede
        // every weight update
        let ar: Vec<&Step> = s
            .per_batch
            .iter()
            .filter(|st| st.op == OpKind::AllReduce)
            .collect();
        assert_eq!(ar.len(), s.collective.len());
        for (cs, st) in s.collective.iter().zip(&ar) {
            assert_eq!(cs.label, st.layer);
            assert_eq!(st.dram_read_bytes, cs.chunk_words * W32);
        }
        let first_wu = s
            .per_batch
            .iter()
            .position(|st| st.op == OpKind::WeightUpdate)
            .unwrap();
        assert!(s
            .per_batch
            .iter()
            .rposition(|st| st.op == OpKind::AllReduce)
            .unwrap()
            < first_wu);
        // reverse-layer reduce order: the first bucket retires with the
        // tail of the net, the last with its head
        assert_eq!(s.buckets[0].label, "b0");
        assert_eq!(s.buckets[0].eligible_after, "fc");
        assert_eq!(s.buckets.last().unwrap().eligible_after, "c1");
        // every eligibility point is a real per-image BP layer
        for b in &s.buckets {
            assert!(s.per_image.iter().any(|st| st.layer
                == b.eligible_after),
                    "bucket {} eligible after unknown layer {}",
                    b.label, b.eligible_after);
        }
    }

    #[test]
    fn steps_carry_their_geometry() {
        // the per-op runtime reads step.out_shape instead of re-deriving
        // geometry from the layer list; pin the load-bearing cases
        let s = sched1x();
        let fcbp = s
            .per_image
            .iter()
            .find(|st| st.op == OpKind::FcBp)
            .unwrap();
        // fc consumes p3's output: the gradient re-enters (64, 4, 4)
        assert_eq!(fcbp.out_shape, vec![64, 4, 4]);
        let c1fp = s
            .per_image
            .iter()
            .find(|st| st.layer == "c1" && st.op == OpKind::ConvFp)
            .unwrap();
        assert_eq!(c1fp.out_shape, vec![16, 32, 32]);
        let p2bp = s
            .per_image
            .iter()
            .find(|st| st.layer == "p2" && st.op == OpKind::Upsample)
            .unwrap();
        assert_eq!(p2bp.out_shape, vec![32, 16, 16]);
    }

    #[test]
    fn bn_network_schedules_bnfp_and_bnbp() {
        let net = Network::cifar_bn(1);
        let s = build(&net, &DesignVars::for_scale(1));
        let fp: Vec<(&str, OpKind)> = s
            .per_image
            .iter()
            .filter(|st| st.phase == Phase::Fp)
            .map(|st| (st.layer.as_str(), st.op))
            .collect();
        // bn follows its conv in FP order
        assert_eq!(fp[0], ("c1", OpKind::ConvFp));
        assert_eq!(fp[1], ("n1", OpKind::BnFp));
        let bnfp =
            s.per_image.iter().filter(|st| st.op == OpKind::BnFp).count();
        let bnbp =
            s.per_image.iter().filter(|st| st.op == OpKind::BnBp).count();
        assert_eq!(bnfp, 6);
        assert_eq!(bnbp, 6);
        // BN is golden-backend-only: its steps carry no AOT artifact
        for st in s
            .per_image
            .iter()
            .filter(|st| matches!(st.op, OpKind::BnFp | OpKind::BnBp))
        {
            assert!(st.artifact.is_none(), "{}", st.layer);
            assert!(st.dram_read_bytes > 0);
            assert!(st.tiles > 0);
        }
        // every bn layer also gets a per-batch gamma/beta weight update
        let wu_layers: Vec<&str> = s
            .per_batch
            .iter()
            .map(|st| st.layer.as_str())
            .collect();
        for n in ["n1", "n2", "n3", "n4", "n5", "n6"] {
            assert!(wu_layers.contains(&n), "{n} missing batch update");
        }
        // 6 conv + 6 bn + 1 fc updates
        assert_eq!(s.per_batch.len(), 13);
    }

    #[test]
    fn bn_scale_mask_rides_the_conv_above() {
        // c2 propagates into n1's (relu-fused) output: the walk emits a
        // ScaleMask step for it, artifact-less (golden-only mask)
        let net = Network::cifar_bn(1);
        let s = build(&net, &DesignVars::for_scale(1));
        let sm: Vec<&Step> = s
            .per_image
            .iter()
            .filter(|st| st.op == OpKind::ScaleMask)
            .collect();
        assert!(!sm.is_empty());
        assert!(sm.iter().all(|st| st.artifact.is_none()));
        assert!(sm.iter().any(|st| st.layer == "c2"));
        // c1 emits no BP (first layer), hence no mask step either
        assert!(!sm.iter().any(|st| st.layer == "c1"));
    }

    #[test]
    fn wu_traffic_dominates_image_traffic() {
        // Fig. 9: weight-update layers are DRAM-bound; their gradient
        // accumulator r/w (i32) should be the largest traffic class
        let s = sched1x();
        let wu_bytes: u64 = s
            .per_image
            .iter()
            .filter(|st| st.phase == Phase::Wu)
            .map(|st| st.dram_read_bytes + st.dram_write_bytes)
            .sum();
        assert!(wu_bytes * 2 > s.image_bytes(),
                "WU bytes {} of {}", wu_bytes, s.image_bytes());
    }

    #[test]
    fn wider_net_moves_more_bytes() {
        let s1 = sched1x();
        let s4 = build(&Network::cifar(4), &DesignVars::for_scale(4));
        assert!(s4.image_bytes() > 4 * s1.image_bytes());
        assert!(s4.batch_bytes() > 4 * s1.batch_bytes());
    }
}
