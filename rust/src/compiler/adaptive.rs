//! Adaptive fixed-point analysis — the paper's stated extension path
//! (§IV-B: "higher accuracy will be achievable with addition of integer
//! batch normalization and adaptive fixed point features [22] to our RTL
//! module library"), following FxpNet's per-tensor format adaptation.
//!
//! The pass runs a calibration set through the golden model, records the
//! per-layer dynamic range of activations and local gradients, and
//! recommends per-tensor fraction bits: for a 16-bit word,
//! `frac = 15 - int_bits(max |value|)`, clamped to the implementable
//! range.  The report shows how much headroom the static Q8.8/Q4.12
//! assignment leaves on the table for each layer — exactly the signal an
//! adaptive-format RTL library would consume.
//!
//! This module also hosts the compiler's other adaptive decision:
//! [`choose_collective`] picks the cluster all-reduce topology (flat
//! ring vs hierarchical group reduce, and the group size) by pricing
//! each candidate's communication plan against the link model.

use anyhow::Result;

use crate::config::{Network, Topology};
use crate::data::Sample;
use crate::engine::collective::{Collective, HierCollective,
                                RingCollective};
use crate::fixed::{dequantize, FA, FG};
use crate::hw::link::{plan_cost, LinkModel};
use crate::nn::golden::{self, Params};
use crate::nn::loss::{encode_label, loss_grad};

// ---------------- topology choice ----------------

/// Total link-model cost of reducing every bucket in `buckets`
/// (per-bucket i32 word counts) through `coll`: each bucket runs the
/// collective's full plan over its own words, so fixed per-step
/// message overhead is paid once *per bucket* — the price of
/// pipelining that [`choose_collective_bucketed`] weighs against the
/// overlap it buys.
fn plan_cost_bucketed(coll: &dyn Collective, n: usize, buckets: &[u64],
                      link: &LinkModel) -> u64 {
    buckets
        .iter()
        .map(|&w| plan_cost(&coll.steps(n, w), link))
        .sum()
}

/// The lowest-cost hierarchical group size for `n` instances reducing
/// the per-bucket word counts in `buckets`, with the link model
/// pricing each candidate's plan (including the G-way trunk
/// contention on inter-group steps).  `None` when `n` has no proper
/// divisor (prime or <= 3), i.e. when the hierarchy cannot beat a
/// flat ring by construction.
fn best_hier_group(n: usize, buckets: &[u64], link: &LinkModel)
                   -> Option<(usize, u64)> {
    (2..n)
        .filter(|g| n % g == 0)
        .map(|g| {
            let coll = HierCollective { group: g };
            (g, plan_cost_bucketed(&coll, n, buckets, link))
        })
        .min_by_key(|&(g, cycles)| (cycles, g))
}

/// Compile-time collective choice: map the requested [`Topology`] (and
/// the link parameters) to a concrete [`Collective`] for `n` instances
/// reducing `words` gradient words in one monolithic piece.
///
/// - `Ring` always yields the flat ring — the default, and the shape
///   every pinned small-N behavior assumes.
/// - `Hier` yields the cost-minimal hierarchical group size, falling
///   back to the flat ring when `n` has no proper divisor.
/// - `Auto` prices both and keeps the cheaper plan (ring on ties).
pub fn choose_collective(topology: Topology, n: usize, words: u64,
                         link: &LinkModel) -> Box<dyn Collective> {
    choose_collective_bucketed(topology, n, &[words], link)
}

/// [`choose_collective`] generalized to a bucketed gradient: prices
/// each candidate topology as the *sum* of its per-bucket plans, so
/// the per-step message overhead multiplied across buckets is charged
/// to the candidate that suffers it.  Splitting into more buckets
/// shifts `Auto` toward the hierarchy at large N (fewer steps per
/// bucket means less repeated overhead); a single-element `buckets`
/// reproduces the monolithic choice exactly.
pub fn choose_collective_bucketed(topology: Topology, n: usize,
                                  buckets: &[u64], link: &LinkModel)
                                  -> Box<dyn Collective> {
    if n <= 1 {
        return Box::new(RingCollective);
    }
    match topology {
        Topology::Ring => Box::new(RingCollective),
        Topology::Hier => match best_hier_group(n, buckets, link) {
            Some((g, _)) => Box::new(HierCollective { group: g }),
            None => Box::new(RingCollective),
        },
        Topology::Auto => {
            let ring = plan_cost_bucketed(&RingCollective, n, buckets,
                                          link);
            match best_hier_group(n, buckets, link) {
                Some((g, cycles)) if cycles < ring => {
                    Box::new(HierCollective { group: g })
                }
                _ => Box::new(RingCollective),
            }
        }
    }
}

/// Range statistics for one tensor kind at one layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct RangeStat {
    pub max_abs: f64,
    /// Recommended fraction bits for a 16-bit word.
    pub frac_rec: u32,
    /// Fraction bits the static assignment uses.
    pub frac_static: u32,
}

fn recommend(max_abs: f64) -> u32 {
    // one sign bit + enough integer bits for max_abs, rest fraction
    let int_bits = if max_abs <= 1e-12 {
        0
    } else {
        (max_abs.log2().floor() as i32 + 1).max(0) as u32
    };
    (15u32).saturating_sub(int_bits).clamp(2, 15)
}

/// Per-layer adaptive-format recommendation.
#[derive(Debug, Clone)]
pub struct LayerRanges {
    pub layer: String,
    pub act: RangeStat,
    pub grad: RangeStat,
}

/// The full calibration report.
#[derive(Debug, Clone)]
pub struct AdaptiveReport {
    pub layers: Vec<LayerRanges>,
    pub samples: usize,
}

impl AdaptiveReport {
    /// Layers whose recommended activation format differs from static FA.
    pub fn act_mismatches(&self) -> Vec<&LayerRanges> {
        self.layers
            .iter()
            .filter(|l| l.act.frac_rec != l.act.frac_static)
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::from(
            "layer  | act max|x|  rec  static | grad max|g|  rec  static\n",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{:<6} | {:>10.4} {:>4} {:>7} | {:>11.4} {:>4} {:>7}\n",
                l.layer, l.act.max_abs, l.act.frac_rec,
                l.act.frac_static, l.grad.max_abs, l.grad.frac_rec,
                l.grad.frac_static,
            ));
        }
        out
    }
}

/// Run the calibration pass over `samples` through the golden model.
pub fn calibrate(net: &Network, params: &Params, samples: &[Sample])
                 -> Result<AdaptiveReport> {
    let mut acts: Vec<(String, f64)> = Vec::new();
    let mut grads: Vec<(String, f64)> = Vec::new();
    for l in &net.layers {
        // every parameterized layer (conv, fc, bn) carries activations
        // and bias-gradient proxies worth calibrating; pool layers are
        // pure routing
        if l.weight_elems() == 0 {
            continue;
        }
        acts.push((l.name().to_string(), 0.0));
        grads.push((l.name().to_string(), 0.0));
    }
    for s in samples {
        let (logits, cache) = golden::forward(net, params, &s.image)?;
        let y = encode_label(s.label, net.nclass);
        let (g, _) = loss_grad(net.loss, &logits, &y);
        let gradmap = golden::backward(net, params, &cache, &g)?;
        for (name, m) in acts.iter_mut() {
            let t = cache
                .acts
                .get(name)
                .map(|t| t.max_abs())
                .unwrap_or_else(|| {
                    logits.iter().map(|v| v.abs()).max().unwrap_or(0)
                });
            *m = m.max(dequantize(t, FA).abs());
        }
        for (name, m) in grads.iter_mut() {
            if let Some(t) = gradmap.get(&format!("b_{name}")) {
                // bias grads are the per-channel sums of local gradients
                // — a cheap online proxy for the local-gradient range
                *m = m.max(dequantize(t.max_abs(), FG).abs());
            }
        }
    }
    let layers = acts
        .into_iter()
        .zip(grads)
        .map(|((layer, a), (_, g))| LayerRanges {
            layer,
            act: RangeStat {
                max_abs: a,
                frac_rec: recommend(a),
                frac_static: FA,
            },
            grad: RangeStat {
                max_abs: g,
                frac_rec: recommend(g),
                frac_static: FG,
            },
        })
        .collect();
    Ok(AdaptiveReport { layers, samples: samples.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::data::Synthetic;
    use crate::nn::init::init_params;

    fn tiny() -> (Network, Params, Vec<Sample>) {
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 \
             relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let params = init_params(&net, 3);
        let data = Synthetic::new(10, (3, 8, 8), 1, 0.3);
        (net, params, data.batch(0, 6))
    }

    #[test]
    fn recommend_formats() {
        assert_eq!(recommend(0.0), 15); // clamped
        assert_eq!(recommend(0.9), 15);
        assert_eq!(recommend(1.5), 14);
        assert_eq!(recommend(100.0), 8);
        assert_eq!(recommend(1e9), 2); // clamped at minimum
    }

    #[test]
    fn calibrate_covers_all_weighted_layers() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        let names: Vec<&str> =
            r.layers.iter().map(|l| l.layer.as_str()).collect();
        assert_eq!(names, ["c1", "c2", "fc"]);
        assert_eq!(r.samples, 6);
        for l in &r.layers {
            assert!(l.act.max_abs >= 0.0);
            assert!((2..=15).contains(&l.act.frac_rec));
        }
    }

    #[test]
    fn calibrate_covers_bn_layers() {
        // the §IV-B pairing: the adaptive pass must see the bn layers'
        // activation and gradient ranges too
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1\nbn n1 relu\nconv c2 4 k3 \
             s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let params = init_params(&net, 3);
        let data = Synthetic::new(10, (3, 8, 8), 1, 0.3);
        let r = calibrate(&net, &params, &data.batch(0, 4)).unwrap();
        let names: Vec<&str> =
            r.layers.iter().map(|l| l.layer.as_str()).collect();
        assert_eq!(names, ["c1", "n1", "c2", "n2", "fc"]);
        for l in &r.layers {
            assert!((2..=15).contains(&l.act.frac_rec), "{}", l.layer);
        }
    }

    #[test]
    fn small_activations_recommend_more_fraction_bits() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        // early-layer activations of a fresh net are << 128 (the Q8.8
        // ceiling): the adaptive pass should recommend more fraction bits
        // than the static FA = 8 for at least one layer
        assert!(
            r.layers.iter().any(|l| l.act.frac_rec > FA),
            "{}",
            r.render()
        );
    }

    #[test]
    fn render_is_tabular() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        let text = r.render();
        assert_eq!(text.lines().count(), 1 + r.layers.len());
        assert!(text.contains("c1"));
    }

    #[test]
    fn chooser_respects_forced_topologies() {
        use crate::config::DesignVars;
        let link = LinkModel::new(&DesignVars::default());
        // forced ring stays a ring at any scale
        assert_eq!(choose_collective(Topology::Ring, 64, 1 << 20, &link)
                       .name(),
                   "ring");
        // forced hier picks a grouped reduce whenever one exists ...
        assert_eq!(choose_collective(Topology::Hier, 64, 1 << 20, &link)
                       .name(),
                   "hier");
        // ... and degenerates to the ring when N is prime or tiny
        for n in [1usize, 2, 3, 7, 13] {
            assert_eq!(
                choose_collective(Topology::Hier, n, 1 << 20, &link)
                    .name(),
                "ring",
                "n={n}"
            );
        }
    }

    #[test]
    fn auto_prefers_hier_when_overhead_dominates() {
        use crate::config::DesignVars;
        let link = LinkModel::new(&DesignVars::default());
        // a small gradient at N=64: per-step message overhead dominates
        // and the 36-step hierarchy beats the 126-step flat ring
        assert_eq!(choose_collective(Topology::Auto, 64, 4096, &link)
                       .name(),
                   "hier");
        // at N=2 there is no hierarchy to choose
        assert_eq!(choose_collective(Topology::Auto, 2, 4096, &link)
                       .name(),
                   "ring");
    }

    #[test]
    fn best_group_minimizes_plan_cost() {
        use crate::config::DesignVars;
        let link = LinkModel::new(&DesignVars::default());
        let (g, cycles) =
            best_hier_group(64, &[1 << 16], &link).unwrap();
        assert!(g > 1 && g < 64 && 64 % g == 0, "group {g}");
        // the winner is no worse than every other divisor's plan
        for other in (2..64usize).filter(|d| 64 % d == 0) {
            let c = plan_cost(
                &HierCollective { group: other }.steps(64, 1 << 16),
                &link);
            assert!(cycles <= c, "group {g} ({cycles}) beaten by \
                                  {other} ({c})");
        }
        assert_eq!(best_hier_group(13, &[1 << 16], &link), None);
    }

    #[test]
    fn bucketed_chooser_generalizes_the_monolithic_one() {
        use crate::config::DesignVars;
        let link = LinkModel::new(&DesignVars::default());
        // single-element bucket list == monolithic choice, everywhere
        for (topo, n, words) in [(Topology::Auto, 64, 4096u64),
                                 (Topology::Auto, 2, 4096),
                                 (Topology::Ring, 64, 1 << 20),
                                 (Topology::Hier, 16, 1 << 20)] {
            assert_eq!(
                choose_collective_bucketed(topo, n, &[words], &link)
                    .name(),
                choose_collective(topo, n, words, &link).name(),
                "topo={topo} n={n}"
            );
        }
        // splitting a large-N gradient into many buckets multiplies
        // the per-step overhead: Auto flips from ring (monolithic,
        // bandwidth-dominated) to hier (bucketed, overhead-dominated)
        let total = 1u64 << 20;
        let mono = choose_collective(Topology::Auto, 64, total, &link);
        assert_eq!(mono.name(), "ring");
        let buckets: Vec<u64> = vec![total / 16; 16];
        let bucketed = choose_collective_bucketed(Topology::Auto, 64,
                                                  &buckets, &link);
        assert_eq!(bucketed.name(), "hier");
    }
}
