//! Adaptive fixed-point analysis — the paper's stated extension path
//! (§IV-B: "higher accuracy will be achievable with addition of integer
//! batch normalization and adaptive fixed point features [22] to our RTL
//! module library"), following FxpNet's per-tensor format adaptation.
//!
//! The pass runs a calibration set through the golden model, records the
//! per-layer dynamic range of activations and local gradients, and
//! recommends per-tensor fraction bits: for a 16-bit word,
//! `frac = 15 - int_bits(max |value|)`, clamped to the implementable
//! range.  The report shows how much headroom the static Q8.8/Q4.12
//! assignment leaves on the table for each layer — exactly the signal an
//! adaptive-format RTL library would consume.

use anyhow::Result;

use crate::config::Network;
use crate::data::Sample;
use crate::fixed::{dequantize, FA, FG};
use crate::nn::golden::{self, Params};
use crate::nn::loss::{encode_label, loss_grad};

/// Range statistics for one tensor kind at one layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct RangeStat {
    pub max_abs: f64,
    /// Recommended fraction bits for a 16-bit word.
    pub frac_rec: u32,
    /// Fraction bits the static assignment uses.
    pub frac_static: u32,
}

fn recommend(max_abs: f64) -> u32 {
    // one sign bit + enough integer bits for max_abs, rest fraction
    let int_bits = if max_abs <= 1e-12 {
        0
    } else {
        (max_abs.log2().floor() as i32 + 1).max(0) as u32
    };
    (15u32).saturating_sub(int_bits).clamp(2, 15)
}

/// Per-layer adaptive-format recommendation.
#[derive(Debug, Clone)]
pub struct LayerRanges {
    pub layer: String,
    pub act: RangeStat,
    pub grad: RangeStat,
}

/// The full calibration report.
#[derive(Debug, Clone)]
pub struct AdaptiveReport {
    pub layers: Vec<LayerRanges>,
    pub samples: usize,
}

impl AdaptiveReport {
    /// Layers whose recommended activation format differs from static FA.
    pub fn act_mismatches(&self) -> Vec<&LayerRanges> {
        self.layers
            .iter()
            .filter(|l| l.act.frac_rec != l.act.frac_static)
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::from(
            "layer  | act max|x|  rec  static | grad max|g|  rec  static\n",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{:<6} | {:>10.4} {:>4} {:>7} | {:>11.4} {:>4} {:>7}\n",
                l.layer, l.act.max_abs, l.act.frac_rec,
                l.act.frac_static, l.grad.max_abs, l.grad.frac_rec,
                l.grad.frac_static,
            ));
        }
        out
    }
}

/// Run the calibration pass over `samples` through the golden model.
pub fn calibrate(net: &Network, params: &Params, samples: &[Sample])
                 -> Result<AdaptiveReport> {
    let mut acts: Vec<(String, f64)> = Vec::new();
    let mut grads: Vec<(String, f64)> = Vec::new();
    for l in &net.layers {
        // every parameterized layer (conv, fc, bn) carries activations
        // and bias-gradient proxies worth calibrating; pool layers are
        // pure routing
        if l.weight_elems() == 0 {
            continue;
        }
        acts.push((l.name().to_string(), 0.0));
        grads.push((l.name().to_string(), 0.0));
    }
    for s in samples {
        let (logits, cache) = golden::forward(net, params, &s.image)?;
        let y = encode_label(s.label, net.nclass);
        let (g, _) = loss_grad(net.loss, &logits, &y);
        let gradmap = golden::backward(net, params, &cache, &g)?;
        for (name, m) in acts.iter_mut() {
            let t = cache
                .acts
                .get(name)
                .map(|t| t.max_abs())
                .unwrap_or_else(|| {
                    logits.iter().map(|v| v.abs()).max().unwrap_or(0)
                });
            *m = m.max(dequantize(t, FA).abs());
        }
        for (name, m) in grads.iter_mut() {
            if let Some(t) = gradmap.get(&format!("b_{name}")) {
                // bias grads are the per-channel sums of local gradients
                // — a cheap online proxy for the local-gradient range
                *m = m.max(dequantize(t.max_abs(), FG).abs());
            }
        }
    }
    let layers = acts
        .into_iter()
        .zip(grads)
        .map(|((layer, a), (_, g))| LayerRanges {
            layer,
            act: RangeStat {
                max_abs: a,
                frac_rec: recommend(a),
                frac_static: FA,
            },
            grad: RangeStat {
                max_abs: g,
                frac_rec: recommend(g),
                frac_static: FG,
            },
        })
        .collect();
    Ok(AdaptiveReport { layers, samples: samples.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;
    use crate::data::Synthetic;
    use crate::nn::init::init_params;

    fn tiny() -> (Network, Params, Vec<Sample>) {
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nconv c2 4 k3 s1 p1 \
             relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let params = init_params(&net, 3);
        let data = Synthetic::new(10, (3, 8, 8), 1, 0.3);
        (net, params, data.batch(0, 6))
    }

    #[test]
    fn recommend_formats() {
        assert_eq!(recommend(0.0), 15); // clamped
        assert_eq!(recommend(0.9), 15);
        assert_eq!(recommend(1.5), 14);
        assert_eq!(recommend(100.0), 8);
        assert_eq!(recommend(1e9), 2); // clamped at minimum
    }

    #[test]
    fn calibrate_covers_all_weighted_layers() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        let names: Vec<&str> =
            r.layers.iter().map(|l| l.layer.as_str()).collect();
        assert_eq!(names, ["c1", "c2", "fc"]);
        assert_eq!(r.samples, 6);
        for l in &r.layers {
            assert!(l.act.max_abs >= 0.0);
            assert!((2..=15).contains(&l.act.frac_rec));
        }
    }

    #[test]
    fn calibrate_covers_bn_layers() {
        // the §IV-B pairing: the adaptive pass must see the bn layers'
        // activation and gradient ranges too
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1\nbn n1 relu\nconv c2 4 k3 \
             s1 p1\nbn n2 relu\npool p1 2\nfc fc 10\nloss hinge",
        )
        .unwrap();
        let params = init_params(&net, 3);
        let data = Synthetic::new(10, (3, 8, 8), 1, 0.3);
        let r = calibrate(&net, &params, &data.batch(0, 4)).unwrap();
        let names: Vec<&str> =
            r.layers.iter().map(|l| l.layer.as_str()).collect();
        assert_eq!(names, ["c1", "n1", "c2", "n2", "fc"]);
        for l in &r.layers {
            assert!((2..=15).contains(&l.act.frac_rec), "{}", l.layer);
        }
    }

    #[test]
    fn small_activations_recommend_more_fraction_bits() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        // early-layer activations of a fresh net are << 128 (the Q8.8
        // ceiling): the adaptive pass should recommend more fraction bits
        // than the static FA = 8 for at least one layer
        assert!(
            r.layers.iter().any(|l| l.act.frac_rec > FA),
            "{}",
            r.render()
        );
    }

    #[test]
    fn render_is_tabular() {
        let (net, params, samples) = tiny();
        let r = calibrate(&net, &params, &samples).unwrap();
        let text = r.render();
        assert_eq!(text.lines().count(), 1 + r.layers.len());
        assert!(text.contains("c1"));
    }
}
