//! The RTL module library (Fig. 3): a catalog of parameterized,
//! training-specific hardware modules.  The compiler *selects* from this
//! library based on the layers present in the network and the design
//! variables — "only the selected modules from the RTL library based on
//! the training algorithm will be synthesized" (§III-A).

use crate::config::{DesignVars, Loss, Network};

/// Every module the library provides (mirrors Fig. 4's blocks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Module {
    GlobalControl,
    DmaControl,
    DataScatter,
    DataGather,
    DataRouter,
    WeightRouter,
    MacArray,
    MacLoadBalance,
    TransposableWeightBuffer,
    WeightUpdateUnit,
    MaxPoolUnit,
    UpsampleUnit,
    ScalingUnit,
    ReluUnit,
    FlattenUnit,
    LossUnitHinge,
    LossUnitEuclid,
    FcUnit,
    /// Integer batch-normalization unit (§IV-B extension): per-channel
    /// multiply + shift + add against precomputed scales, plus the
    /// statistic accumulation datapath.
    BatchNormUnit,
}

impl Module {
    /// Verilog entity name the codegen emits for this module.
    pub fn entity(&self) -> &'static str {
        match self {
            Module::GlobalControl => "global_ctrl",
            Module::DmaControl => "dma_ctrl",
            Module::DataScatter => "data_scatter",
            Module::DataGather => "data_gather",
            Module::DataRouter => "data_router",
            Module::WeightRouter => "weight_router",
            Module::MacArray => "mac_array",
            Module::MacLoadBalance => "mac_load_balance",
            Module::TransposableWeightBuffer => "transposable_wbuf",
            Module::WeightUpdateUnit => "weight_update_unit",
            Module::MaxPoolUnit => "maxpool_unit",
            Module::UpsampleUnit => "upsample_unit",
            Module::ScalingUnit => "scaling_unit",
            Module::ReluUnit => "relu_unit",
            Module::FlattenUnit => "flatten_unit",
            Module::LossUnitHinge => "loss_unit_sqhinge",
            Module::LossUnitEuclid => "loss_unit_euclid",
            Module::FcUnit => "fc_unit",
            Module::BatchNormUnit => "batchnorm_unit",
        }
    }
}

/// Select the set of library modules a network + design point requires:
/// the base datapath every training accelerator instantiates, plus the
/// union of what each layer's descriptor asks for (layer-ops registry),
/// plus the configured loss unit.
pub fn select_modules(net: &Network, dv: &DesignVars) -> Vec<Module> {
    let mut mods = vec![
        Module::GlobalControl,
        Module::DmaControl,
        Module::DataScatter,
        Module::DataGather,
        Module::DataRouter,
        Module::WeightRouter,
        Module::MacArray,
        Module::TransposableWeightBuffer,
        Module::WeightUpdateUnit,
    ];
    if dv.load_balance {
        mods.push(Module::MacLoadBalance);
    }
    for l in &net.layers {
        for m in crate::ops::for_layer(l).modules(l) {
            if !mods.contains(&m) {
                mods.push(m);
            }
        }
    }
    mods.push(match net.loss {
        Loss::SquareHinge => Module::LossUnitHinge,
        Loss::Euclidean => Module::LossUnitEuclid,
    });
    mods
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignVars, Network};

    #[test]
    fn cifar_selects_full_set() {
        let mods = select_modules(&Network::cifar(1),
                                  &DesignVars::for_scale(1));
        for m in [
            Module::MacArray,
            Module::MacLoadBalance,
            Module::TransposableWeightBuffer,
            Module::MaxPoolUnit,
            Module::UpsampleUnit,
            Module::LossUnitHinge,
            Module::FcUnit,
        ] {
            assert!(mods.contains(&m), "{m:?} missing");
        }
        assert!(!mods.contains(&Module::LossUnitEuclid),
                "unused loss unit must not be synthesized");
    }

    #[test]
    fn load_balance_selectable() {
        let mut dv = DesignVars::for_scale(1);
        dv.load_balance = false;
        let mods = select_modules(&Network::cifar(1), &dv);
        assert!(!mods.contains(&Module::MacLoadBalance));
    }

    #[test]
    fn poolless_net_omits_pool_units() {
        let net = Network::parse(
            "input 3 8 8\nconv c1 4 k3 s1 p1 relu\nfc fc 10",
        )
        .unwrap();
        let mods = select_modules(&net, &DesignVars::default());
        assert!(!mods.contains(&Module::MaxPoolUnit));
        assert!(!mods.contains(&Module::UpsampleUnit));
    }

    #[test]
    fn bn_net_selects_batchnorm_unit() {
        let mods = select_modules(&Network::cifar_bn(1),
                                  &DesignVars::for_scale(1));
        assert!(mods.contains(&Module::BatchNormUnit));
        // the bn layers fuse the relus, so the relu/scaling units are
        // still required
        assert!(mods.contains(&Module::ReluUnit));
        assert!(mods.contains(&Module::ScalingUnit));
        // and a bn-free net must not synthesize the unit
        let plain = select_modules(&Network::cifar(1),
                                   &DesignVars::for_scale(1));
        assert!(!plain.contains(&Module::BatchNormUnit));
    }

    #[test]
    fn entities_unique() {
        use std::collections::HashSet;
        let mods = select_modules(&Network::cifar(2),
                                  &DesignVars::for_scale(2));
        let names: HashSet<&str> = mods.iter().map(|m| m.entity()).collect();
        assert_eq!(names.len(), mods.len());
    }
}
