//! The RTL compiler (§III-A, Fig. 3): from a high-level CNN description
//! plus FPGA design variables to a complete accelerator instance —
//! module selection from the training-specific RTL library, loop
//! tiling/unroll resolution, the layer-by-layer training schedule with
//! control parameters, buffer allocation, resource/power estimation and
//! structural netlist emission.

pub mod adaptive;
pub mod codegen;
pub mod module_library;
pub mod schedule;

use anyhow::{bail, Result};

use crate::config::{DesignVars, Network};
use crate::hw::bram::BufferPlan;
use crate::hw::power::{power_from_resources, PowerReport};
use crate::hw::resources::{estimate, Device, ResourceReport, STRATIX10_GX};

pub use adaptive::{calibrate, choose_collective,
                   choose_collective_bucketed, AdaptiveReport};
pub use codegen::{control_rom, emit_verilog, ControlWord};
pub use module_library::{select_modules, Module};
pub use schedule::{build as build_schedule, OpKind, Schedule, Step};

/// A fully compiled accelerator instance.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub net: Network,
    pub dv: DesignVars,
    pub modules: Vec<Module>,
    pub schedule: Schedule,
    pub buffers: BufferPlan,
    pub resources: ResourceReport,
    pub power: PowerReport,
    pub control: Vec<ControlWord>,
}

/// The RTL compiler entry point.
pub struct RtlCompiler {
    pub device: Device,
}

impl Default for RtlCompiler {
    fn default() -> Self {
        RtlCompiler { device: STRATIX10_GX }
    }
}

impl RtlCompiler {
    /// Compile `net` under `dv`.  Fails when the design cannot be
    /// realized on the target device (the paper's compiler rejects
    /// configurations exceeding user constraints the same way).
    pub fn compile(&self, net: &Network, dv: &DesignVars)
                   -> Result<Accelerator> {
        if dv.pox == 0 || dv.poy == 0 || dv.pof == 0 {
            bail!("unroll factors must be nonzero");
        }
        let resources = estimate(net, dv, &self.device);
        if !resources.fits {
            bail!(
                "design does not fit device: {} DSP (of {}), {} ALM (of \
                 {}), {:.1} Mbit BRAM (of {})",
                resources.dsp, self.device.dsp, resources.alm,
                self.device.alm, resources.bram_mbits,
                self.device.bram_mbits
            );
        }
        let power = power_from_resources(dv, &resources);
        Ok(Accelerator {
            net: net.clone(),
            dv: dv.clone(),
            modules: select_modules(net, dv),
            schedule: build_schedule(net, dv),
            buffers: BufferPlan::plan(net, dv),
            resources,
            power,
            control: control_rom(net, dv),
        })
    }

    /// Emit the generated structural netlist.
    pub fn verilog(&self, acc: &Accelerator) -> String {
        emit_verilog(&acc.net, &acc.dv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignVars, Network};

    #[test]
    fn compiles_all_paper_configs() {
        let c = RtlCompiler::default();
        for s in [1, 2, 4] {
            let acc = c
                .compile(&Network::cifar(s), &DesignVars::for_scale(s))
                .unwrap();
            assert!(!acc.schedule.per_image.is_empty());
            assert!(!acc.modules.is_empty());
            assert!(acc.resources.fits);
        }
    }

    #[test]
    fn compiles_bn_configs() {
        let c = RtlCompiler::default();
        for s in [1, 2, 4] {
            let acc = c
                .compile(&Network::cifar_bn(s), &DesignVars::for_scale(s))
                .unwrap();
            assert!(acc.resources.fits, "{s}x bn design does not fit");
            assert!(acc
                .modules
                .contains(&crate::compiler::Module::BatchNormUnit));
            assert_eq!(acc.control.len(), acc.net.layers.len());
        }
    }

    #[test]
    fn rejects_oversized_design() {
        let c = RtlCompiler::default();
        let mut dv = DesignVars::for_scale(4);
        dv.pox = 32;
        dv.poy = 32; // 65536 MACs: impossible on this device
        let err = c.compile(&Network::cifar(4), &dv).unwrap_err();
        assert!(format!("{err:#}").contains("does not fit"));
    }

    #[test]
    fn rejects_zero_unroll() {
        let c = RtlCompiler::default();
        let mut dv = DesignVars::for_scale(1);
        dv.pof = 0;
        assert!(c.compile(&Network::cifar(1), &dv).is_err());
    }

    #[test]
    fn verilog_generation_roundtrip() {
        let c = RtlCompiler::default();
        let acc = c
            .compile(&Network::cifar(1), &DesignVars::for_scale(1))
            .unwrap();
        let v = c.verilog(&acc);
        assert!(v.contains("cnn_train_top"));
    }
}
